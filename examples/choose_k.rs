//! Choosing k with proper quality criteria — the paper's Table 4 scenario
//! ("the 'best' clustering can be chosen by a heuristic such as the
//! 'Elbow' method, or any of the better alternatives [19]") done right:
//! sweep k with the Hybrid algorithm over one amortized cover tree, then
//! pick k by Calinski-Harabasz, simplified silhouette, and BIC.
//!
//!     cargo run --release --example choose_k [scale]

use covermeans::data::synth;
use covermeans::kmeans::{Algorithm, KMeans, Workspace};
use covermeans::metrics::quality::{
    bic, calinski_harabasz, simplified_silhouette,
};

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.01);
    // Ground truth: the MNIST analog has 10 generative classes.
    let data = synth::mnist(20, scale, 9);
    println!(
        "mnist-20d analog: n={} d={} (10 generative classes)",
        data.rows(),
        data.cols()
    );

    let mut ws = Workspace::new(); // one cover tree for the whole sweep
    let sweep = std::time::Instant::now();

    println!(
        "\n{:>4} {:>12} {:>12} {:>12} {:>12}",
        "k", "sse", "CH", "silhouette", "BIC"
    );
    let mut best = (0usize, f64::NEG_INFINITY, 0usize, f64::NEG_INFINITY, 0usize, f64::NEG_INFINITY);
    for k in [2usize, 4, 6, 8, 10, 13, 16, 20, 30] {
        let r = KMeans::new(k)
            .algorithm(Algorithm::Hybrid)
            .seed(17)
            .fit_with(&data, &mut ws)
            .expect("valid configuration");
        let ch = calinski_harabasz(&data, &r.labels, &r.centers);
        let sil = simplified_silhouette(&data, &r.labels, &r.centers);
        let b = bic(&data, &r.labels, &r.centers);
        println!(
            "{k:>4} {:>12.4e} {:>12.2} {:>12.4} {:>12.1}",
            r.sse(&data),
            ch,
            sil,
            b
        );
        if ch > best.1 {
            best.0 = k;
            best.1 = ch;
        }
        if sil > best.3 {
            best.2 = k;
            best.3 = sil;
        }
        if b > best.5 {
            best.4 = k;
            best.5 = b;
        }
    }
    println!(
        "\nchosen k:  CH -> {}   silhouette -> {}   BIC -> {}   (truth: 10)",
        best.0, best.2, best.4
    );
    println!("sweep time: {:.2?} (tree built once, Hybrid runs)", sweep.elapsed());
}
