//! Early stopping on an inertia plateau — the stepwise/observer API.
//!
//! Exact k-means runs to the assignment fixpoint, but a practitioner
//! often wants out as soon as the SSE curve flattens: the last few
//! iterations shuffle a handful of points for a relative improvement of
//! 1e-6 or less. This example runs the Hybrid algorithm twice on the same
//! seed — once to convergence, once with an observer that stops when the
//! relative SSE improvement stays below a threshold for `patience`
//! consecutive iterations — and reports what the plateau rule saved. It
//! also shows the raw `fit_step()` loop for custom drive-it-yourself
//! schedules.
//!
//!     cargo run --release --example early_stop [scale]

use covermeans::data::synth;
use covermeans::kmeans::{Algorithm, KMeans, Signal, StepView};

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.005);
    let data = synth::kdd04(scale, 11);
    let k = 40;
    println!(
        "kdd04 analog (overlap-heavy, converges slowly): n={} d={} k={k}",
        data.rows(),
        data.cols()
    );

    // --- Run 1: exact convergence (the paper's protocol).
    let full = KMeans::new(k)
        .algorithm(Algorithm::Hybrid)
        .seed(3)
        .fit(&data)
        .expect("valid configuration");
    println!(
        "\nto fixpoint  : {:>4} iters, {:>12} distances, sse {:.6e}",
        full.iterations,
        full.distances,
        full.sse(&data)
    );

    // --- Run 2: observer stops on an inertia plateau.
    let rel_tol = 1e-5;
    let patience = 3usize;
    let data_for_obs = data.clone();
    let mut prev_sse = f64::INFINITY;
    let mut flat = 0usize;
    let early = KMeans::new(k)
        .algorithm(Algorithm::Hybrid)
        .seed(3)
        .observer(move |view: &StepView<'_>| {
            let sse = view.sse(&data_for_obs);
            let rel = (prev_sse - sse) / prev_sse.max(f64::MIN_POSITIVE);
            flat = if rel < rel_tol { flat + 1 } else { 0 };
            prev_sse = sse;
            if flat >= patience { Signal::Stop } else { Signal::Continue }
        })
        .fit(&data)
        .expect("valid configuration");
    println!(
        "plateau stop : {:>4} iters, {:>12} distances, sse {:.6e}",
        early.iterations,
        early.distances,
        early.sse(&data)
    );
    let sse_gap = (early.sse(&data) - full.sse(&data)) / full.sse(&data);
    println!(
        "saved {:.0}% of iterations for a {:.2e} relative SSE gap",
        100.0 * (1.0 - early.iterations as f64 / full.iterations as f64),
        sse_gap
    );

    // --- The same control, driven by hand with fit_step().
    let mut fit = KMeans::new(k)
        .algorithm(Algorithm::Shallot)
        .seed(3)
        .fit_step(&data)
        .expect("valid configuration");
    println!("\nstepwise drive (Shallot), one line per iteration:");
    while let Some(info) = fit.step() {
        println!(
            "  iter {:>3}: {:>6} reassigned, {:>12} cumulative distances, max move {:.3e}",
            info.iter, info.changed, info.distances, info.max_movement
        );
        if info.iter >= 5 && !info.done {
            println!("  ... handing the rest to run-to-completion");
            break;
        }
    }
    let r = fit.run();
    println!(
        "final        : {:>4} iters, converged {}, sse {:.6e}",
        r.iterations,
        r.converged,
        r.sse(&data)
    );
}
