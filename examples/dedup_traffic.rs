//! Near-duplicate-heavy clustering — the paper's Traffic scenario: 2-d
//! accident locations where thousands of records share an intersection.
//! Cover-tree nodes collapse the duplicates (radius ~ 0) and assign them
//! en bloc; the stored-bounds algorithms must still touch every point.
//!
//! This is the regime where the paper reports tree methods at ~0.000-0.001
//! of the Standard algorithm's distance computations (Table 2, Traffic).
//!
//!     cargo run --release --example dedup_traffic [scale]

use covermeans::data::synth;
use covermeans::kmeans::{self, Algorithm, KMeans};
use covermeans::metrics::DistCounter;
use covermeans::tree::{CoverTree, CoverTreeParams};

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.002); // ~12k points; pass 1.0 for the paper's 6.2M
    let data = synth::traffic(scale, 3);
    let k = 100.min(data.rows() / 10);
    println!(
        "traffic analog: n={} d=2, k={k} (scale {scale} of 6.2M)",
        data.rows()
    );

    // Show how hard the duplicates compress in the tree.
    let tree = CoverTree::build(&data, CoverTreeParams::default());
    println!(
        "cover tree: {} nodes, {} singleton slots, depth {}, {:.1} points/node",
        tree.node_count,
        tree.singleton_count,
        tree.root.depth(),
        data.rows() as f64 / tree.node_count as f64
    );

    let mut init_counter = DistCounter::new();
    let init = kmeans::init::kmeans_plus_plus(&data, k, 11, &mut init_counter);

    let mut standard = 0u64;
    println!(
        "\n{:<12} {:>12} {:>8} {:>10}",
        "algorithm", "distances", "rel", "time ms"
    );
    for alg in [
        Algorithm::Standard,
        Algorithm::Hamerly,
        Algorithm::Shallot,
        Algorithm::Kanungo,
        Algorithm::CoverMeans,
        Algorithm::Hybrid,
    ] {
        let r = KMeans::new(k)
            .algorithm(alg)
            .warm_start(init.clone())
            .fit(&data)
            .expect("valid configuration");
        if alg == Algorithm::Standard {
            standard = r.distances;
        }
        println!(
            "{:<12} {:>12} {:>8.4} {:>10.2}",
            alg.name(),
            r.distances,
            r.distances as f64 / standard as f64,
            (r.time + r.build_time).as_secs_f64() * 1e3,
        );
    }
    println!(
        "\n(`distances` excludes tree construction; the `rel` column is the\n\
         paper's Table 2 metric — expect the tree rows to collapse toward 0\n\
         as scale grows and duplicates multiply.)"
    );
}
