//! Train once, serve many: the full life of a [`KMeansModel`].
//!
//! Fits a high-k model on a clustered dataset, persists it to the
//! checksummed `.kmm` format, reloads it as a fresh serving process
//! would, and batch-assigns a stream of out-of-sample points — comparing
//! the cover-tree query path (built over the centers) against the
//! Elkan-pruned scan and the naive n·k scan it replaces, at 1 and at all
//! available worker threads.
//!
//!     cargo run --release --example train_then_serve

use covermeans::data::synth;
use covermeans::kmeans::{
    Algorithm, KMeans, KMeansModel, PredictMode, PredictOptions,
};

fn main() -> anyhow::Result<()> {
    // --- train ----------------------------------------------------------
    let train = synth::istanbul(0.02, 42);
    let k = 128;
    println!(
        "train: istanbul analog, n={} d={} k={k} (Hybrid)",
        train.rows(),
        train.cols()
    );
    let model = KMeans::new(k)
        .algorithm(Algorithm::Hybrid)
        .seed(7)
        .threads(0) // all cores; byte-identical to threads(1)
        .fit_model(&train)
        .expect("valid configuration");
    println!(
        "fit: {} iterations (converged {}), inertia {:.4e}",
        model.iterations(),
        model.converged(),
        model.inertia()
    );

    // --- persist --------------------------------------------------------
    let path = std::env::temp_dir().join("covermeans_train_then_serve.kmm");
    model.save(&path)?;
    let bytes = std::fs::metadata(&path)?.len();
    println!("saved: {} ({bytes} bytes)", path.display());

    // --- serve (as a fresh process would: load from disk) ---------------
    let served = KMeansModel::load(&path)?;
    let queries = synth::istanbul(0.01, 99); // out-of-sample traffic
    let naive = queries.rows() as u64 * served.k() as u64;
    println!(
        "\nserve: {} fresh points against k={} centers (naive scan: {naive} distance evals)",
        queries.rows(),
        served.k()
    );
    println!(
        "{:<18} {:>9} {:>12} {:>10} {:>12}",
        "strategy", "threads", "query evals", "time ms", "points/s"
    );
    for mode in [PredictMode::Tree, PredictMode::Scan] {
        for threads in [1usize, 0] {
            let sw = std::time::Instant::now();
            let p = served.predict_opts(&queries, &PredictOptions { mode, threads, ..Default::default() });
            let secs = sw.elapsed().as_secs_f64();
            println!(
                "{:<18} {:>9} {:>12} {:>10.2} {:>12.0}",
                p.mode.name(),
                if threads == 0 { "all".to_string() } else { threads.to_string() },
                p.query_evals,
                secs * 1e3,
                queries.rows() as f64 / secs.max(1e-12)
            );
        }
    }

    // The contract, demonstrated: loaded model ≡ in-memory model, every
    // strategy ≡ the naive scan, labels identical.
    let a = model.predict(&queries);
    let b = served.predict(&queries);
    assert_eq!(a, b, "load must not change a single label");
    let (with_dist, dists) = served.predict_with_distances(&queries);
    assert_eq!(a, with_dist);
    let mean: f64 = dists.iter().sum::<f64>() / dists.len() as f64;
    println!("\nmean distance to assigned center: {mean:.5}");
    std::fs::remove_file(&path).ok();
    println!("train_then_serve OK");
    Ok(())
}
