//! End-to-end driver across all three layers (the repo's E2E validation):
//!
//!   L3 (Rust)   — this driver + the native algorithm suite,
//!   runtime     — PJRT CPU client executing the AOT artifacts,
//!   L2/L1       — the JAX assign-step graph wrapping the Pallas kernel
//!                 (compiled once by `make artifacts`, Python not running
//!                 here).
//!
//! It clusters a realistic workload twice — native f64 Lloyd and
//! XLA-backed Lloyd — verifies they agree, then runs the paper's headline
//! algorithms on the same data and reports relative cost and throughput.
//!
//!     make artifacts && cargo run --release --example end_to_end

use covermeans::data::synth;
use covermeans::kmeans::{self, Algorithm, KMeans, KMeansModel, KMeansParams};
use covermeans::metrics::DistCounter;
use covermeans::runtime::{lloyd_xla, AssignExecutor};

fn main() -> anyhow::Result<()> {
    let data = synth::mnist(30, 0.05, 5); // 3500 x 30 embedding vectors
    let k = 64;
    println!(
        "workload: mnist-autoencoder analog, n={} d={} k={k}",
        data.rows(),
        data.cols()
    );

    let mut init_counter = DistCounter::new();
    let init = kmeans::init::kmeans_plus_plus(&data, k, 3, &mut init_counter);
    let params = KMeansParams::default();

    // --- Layer check: native vs XLA assign path.
    let mut exec = AssignExecutor::load_default()?;
    println!("PJRT platform: {}", exec.platform());
    let entry = exec.manifest().pick(30, 64).expect("artifact");
    println!(
        "artifact: {} (VMEM est {:.0} KiB, MXU FLOP fraction {:.3})",
        entry.file,
        entry.vmem_bytes as f64 / 1024.0,
        entry.mxu_fraction
    );

    let t0 = std::time::Instant::now();
    let native = kmeans::lloyd::run(&data, &init, &params);
    let t_native = t0.elapsed();

    let t0 = std::time::Instant::now();
    let xla = lloyd_xla(&data, &init, &params, &mut exec)?;
    let t_xla = t0.elapsed();

    let sse_n = native.sse(&data);
    let sse_x = xla.sse(&data);
    let agree = native
        .labels
        .iter()
        .zip(&xla.labels)
        .filter(|(a, b)| a == b)
        .count();
    println!(
        "\nLloyd  native: {} iters, {:.1} ms   | xla: {} iters, {:.1} ms",
        native.iterations,
        t_native.as_secs_f64() * 1e3,
        xla.iterations,
        t_xla.as_secs_f64() * 1e3
    );
    println!(
        "labels agree: {agree}/{} ({:.2}%)   sse: native {sse_n:.4e} vs xla {sse_x:.4e}",
        data.rows(),
        100.0 * agree as f64 / data.rows() as f64
    );
    anyhow::ensure!(
        agree as f64 >= 0.999 * data.rows() as f64,
        "layers disagree"
    );
    anyhow::ensure!((sse_n - sse_x).abs() <= 1e-3 * (1.0 + sse_n));

    // --- The paper's algorithms on the same workload.
    println!(
        "\n{:<12} {:>12} {:>8} {:>10}  (same init, exact replicas)",
        "algorithm", "distances", "rel", "time ms"
    );
    let mut standard = 0u64;
    for alg in Algorithm::ALL {
        let r = KMeans::new(k)
            .algorithm(alg)
            .warm_start(init.clone())
            .fit(&data)
            .expect("valid configuration");
        if alg == Algorithm::Standard {
            standard = r.total_distances();
        }
        println!(
            "{:<12} {:>12} {:>8.4} {:>10.2}",
            alg.name(),
            r.total_distances(),
            r.total_distances() as f64 / standard as f64,
            r.total_time().as_secs_f64() * 1e3,
        );
        assert_eq!(r.iterations, native.iterations, "exactness");
    }

    // --- Serving round-trip: the fit leaves as a model, survives disk,
    // and `predict` reproduces the training assignment exactly — no
    // hand-rolled nearest-center re-derivation.
    let model = KMeansModel::from_run(&data, &native, Algorithm::Standard, 3);
    let path = std::env::temp_dir().join("covermeans_end_to_end.kmm");
    model.save(&path)?;
    let served = KMeansModel::load(&path)?;
    std::fs::remove_file(&path).ok();
    let predicted = served.predict(&data);
    anyhow::ensure!(
        native.converged,
        "training run hit the iteration cap; labels are not a fixpoint"
    );
    anyhow::ensure!(
        predicted == native.labels,
        "round-tripped model must reproduce the converged training labels"
    );
    println!(
        "\nmodel round-trip: save -> load -> predict reproduced all {} labels \
         (k={}, inertia {:.4e})",
        predicted.len(),
        served.k(),
        served.inertia()
    );

    // Throughput headline for the dense path.
    let evals = (data.rows() * k * xla.iterations) as f64;
    println!(
        "\nXLA dense path throughput: {:.1} M point-center distances/s",
        evals / t_xla.as_secs_f64() / 1e6
    );
    println!("end_to_end OK");
    Ok(())
}
