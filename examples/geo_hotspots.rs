//! Geo-hotspot clustering — the paper's Istanbul-tweets scenario (§4): a
//! practitioner sweeping k over a low-dimensional spatial dataset to find
//! a good number of clusters, amortizing one cover tree across the whole
//! sweep (the Table 4 protocol) and optionally *warm-starting* each k
//! from the previous k's solution (sweep-time center reuse).
//!
//!     cargo run --release --example geo_hotspots [scale]

use covermeans::data::synth;
use covermeans::kmeans::{self, Algorithm, KMeans, Workspace};
use covermeans::metrics::DistCounter;

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.01);
    let data = synth::istanbul(scale, 1);
    println!(
        "istanbul analog: n={} d={} (scale {scale})",
        data.rows(),
        data.cols()
    );

    let ks = [5usize, 10, 20, 40, 80];
    let restarts = 3;

    // One workspace per algorithm: the Hybrid/Cover tree is built once and
    // reused across the whole (k, restart) grid via fit_with.
    for (alg, warm) in [
        (Algorithm::Standard, false),
        (Algorithm::Shallot, false),
        (Algorithm::Hybrid, false),
        (Algorithm::Hybrid, true),
    ] {
        let mut ws = Workspace::new();
        let sweep_t = std::time::Instant::now();
        let mut total_dist = 0u64;
        let mut total_iters = 0usize;
        let mut best: Option<(usize, f64)> = None;
        // Per-restart previous-k solutions for the warm-started variant.
        let mut prev: Vec<Option<covermeans::data::Matrix>> = vec![None; restarts];
        for &k in &ks {
            let mut best_sse_for_k = f64::INFINITY;
            for (r, slot) in prev.iter_mut().enumerate() {
                let mut dc = DistCounter::new();
                let seed = 1000 + r as u64;
                let init = match slot.as_ref() {
                    Some(c) if warm && c.rows() <= k => {
                        kmeans::init::extend_centers(&data, c, k, seed, &mut dc)
                    }
                    _ => kmeans::init::kmeans_plus_plus(&data, k, seed, &mut dc),
                };
                let res = KMeans::new(k)
                    .algorithm(alg)
                    .warm_start(init)
                    .fit_with(&data, &mut ws)
                    .expect("valid configuration");
                if warm {
                    *slot = Some(res.centers.clone());
                }
                total_dist += res.total_distances();
                total_iters += res.iterations;
                best_sse_for_k = best_sse_for_k.min(res.sse(&data));
            }
            // "Elbow"-style bookkeeping (see the paper's §4 discussion —
            // better criteria exist; this example just needs a winner).
            let score = best_sse_for_k * (k as f64).sqrt();
            if best.map(|(_, s)| score < s).unwrap_or(true) {
                best = Some((k, score));
            }
        }
        let elapsed = sweep_t.elapsed();
        println!(
            "{:<10}{} sweep over k={ks:?} x{restarts}: {:>8.2?} total, {:>6} iters, {:>12} distances, chosen k={}",
            alg.name(),
            if warm { " +warm" } else { "      " },
            elapsed,
            total_iters,
            total_dist,
            best.unwrap().0,
        );
    }
    println!(
        "\nThe Hybrid sweeps reuse one cover tree for every restart and every k\n\
         (the paper's Table 4 protocol) — construction cost is paid once.\n\
         The warm-started sweep additionally seeds each k from the previous\n\
         k's centers (extend_centers), trading the paper's cold-start\n\
         protocol for fewer iterations per k."
    );
}
