//! Geo-hotspot clustering — the paper's Istanbul-tweets scenario (§4): a
//! practitioner sweeping k over a low-dimensional spatial dataset to find
//! a good number of clusters, amortizing one cover tree across the whole
//! sweep (the Table 4 protocol).
//!
//!     cargo run --release --example geo_hotspots [scale]

use covermeans::data::synth;
use covermeans::kmeans::{self, Algorithm, KMeansParams, Workspace};
use covermeans::metrics::DistCounter;

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.01);
    let data = synth::istanbul(scale, 1);
    println!(
        "istanbul analog: n={} d={} (scale {scale})",
        data.rows(),
        data.cols()
    );

    let ks = [5usize, 10, 20, 40, 80];
    let restarts = 3;

    // One workspace per algorithm: the Hybrid/Cover tree is built once and
    // reused across the whole (k, restart) grid.
    for alg in [Algorithm::Standard, Algorithm::Shallot, Algorithm::Hybrid] {
        let params = KMeansParams { algorithm: alg, ..KMeansParams::default() };
        let mut ws = Workspace::new();
        let sweep_t = std::time::Instant::now();
        let mut total_dist = 0u64;
        let mut best: Option<(usize, f64)> = None;
        for &k in &ks {
            let mut best_sse_for_k = f64::INFINITY;
            for r in 0..restarts {
                let mut dc = DistCounter::new();
                let init = kmeans::init::kmeans_plus_plus(
                    &data,
                    k,
                    1000 + r as u64,
                    &mut dc,
                );
                let res = kmeans::run(&data, &init, &params, &mut ws);
                total_dist += res.total_distances();
                best_sse_for_k = best_sse_for_k.min(res.sse(&data));
            }
            // "Elbow"-style bookkeeping (see the paper's §4 discussion —
            // better criteria exist; this example just needs a winner).
            let score = best_sse_for_k * (k as f64).sqrt();
            if best.map(|(_, s)| score < s).unwrap_or(true) {
                best = Some((k, score));
            }
        }
        let elapsed = sweep_t.elapsed();
        println!(
            "{:<10} sweep over k={ks:?} x{restarts}: {:>8.2?} total, {:>12} distances, chosen k={}",
            alg.name(),
            elapsed,
            total_dist,
            best.unwrap().0,
        );
    }
    println!(
        "\nThe Hybrid sweep reuses one cover tree for every restart and every k\n\
         (the paper's Table 4 protocol) — construction cost is paid once."
    );
}
