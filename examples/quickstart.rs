//! Quickstart: cluster a small synthetic dataset with every algorithm of
//! the paper and print their relative cost — a 30-second tour of the
//! fluent [`KMeans`] builder API.
//!
//!     cargo run --release --example quickstart

use covermeans::data::synth;
use covermeans::kmeans::{self, Algorithm, KMeans};
use covermeans::metrics::DistCounter;

fn main() {
    // A clustered 2-d dataset (Istanbul-tweets analog at 1% scale).
    let data = synth::istanbul(0.01, 42);
    let k = 50;
    println!("dataset: istanbul analog, n={} d={}, k={k}", data.rows(), data.cols());

    // The paper's protocol: identical k-means++ centers for everyone —
    // generated once and fed to each run via `warm_start`.
    let mut init_counter = DistCounter::new();
    let init = kmeans::init::kmeans_plus_plus(&data, k, 7, &mut init_counter);

    println!(
        "\n{:<12} {:>6} {:>12} {:>10} {:>10} {:>12}",
        "algorithm", "iters", "distances", "rel", "time ms", "sse"
    );
    let mut standard_dist = 0u64;
    for alg in Algorithm::ALL {
        let r = KMeans::new(k)
            .algorithm(alg)
            .warm_start(init.clone())
            .fit(&data)
            .expect("valid configuration");
        if alg == Algorithm::Standard {
            standard_dist = r.total_distances();
        }
        println!(
            "{:<12} {:>6} {:>12} {:>10.3} {:>10.2} {:>12.4e}",
            alg.name(),
            r.iterations,
            r.total_distances(),
            r.total_distances() as f64 / standard_dist as f64,
            r.total_time().as_secs_f64() * 1e3,
            r.sse(&data),
        );
    }
    println!(
        "\nAll algorithms are exact: identical SSE, identical iterations.\n\
         The tree methods (Cover-means, Hybrid) also pay a one-off build cost\n\
         included above; amortize it across runs by holding a\n\
         kmeans::Workspace and fitting with KMeans::fit_with."
    );
}
