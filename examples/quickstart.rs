//! Quickstart: cluster a small synthetic dataset with every algorithm of
//! the paper, print their relative cost, then turn the winner into a
//! servable model — a 30-second tour of the fluent [`KMeans`] builder
//! and the [`KMeansModel`] serving layer.
//!
//!     cargo run --release --example quickstart

use covermeans::data::synth;
use covermeans::kmeans::{self, Algorithm, KMeans};
use covermeans::metrics::DistCounter;

fn main() {
    // A clustered 2-d dataset (Istanbul-tweets analog at 1% scale).
    let data = synth::istanbul(0.01, 42);
    let k = 50;
    println!("dataset: istanbul analog, n={} d={}, k={k}", data.rows(), data.cols());

    // The paper's protocol: identical k-means++ centers for everyone —
    // generated once and fed to each run via `warm_start`.
    let mut init_counter = DistCounter::new();
    let init = kmeans::init::kmeans_plus_plus(&data, k, 7, &mut init_counter);

    println!(
        "\n{:<12} {:>6} {:>12} {:>10} {:>10} {:>12}",
        "algorithm", "iters", "distances", "rel", "time ms", "sse"
    );
    let mut standard_dist = 0u64;
    for alg in Algorithm::ALL {
        let r = KMeans::new(k)
            .algorithm(alg)
            .warm_start(init.clone())
            .fit(&data)
            .expect("valid configuration");
        if alg == Algorithm::Standard {
            standard_dist = r.total_distances();
        }
        println!(
            "{:<12} {:>6} {:>12} {:>10.3} {:>10.2} {:>12.4e}",
            alg.name(),
            r.iterations,
            r.total_distances(),
            r.total_distances() as f64 / standard_dist as f64,
            r.total_time().as_secs_f64() * 1e3,
            r.sse(&data),
        );
    }
    println!(
        "\nAll algorithms are exact: identical SSE, identical iterations.\n\
         The tree methods (Cover-means, Hybrid) also pay a one-off build cost\n\
         included above; amortize it across runs by holding a\n\
         kmeans::Workspace and fitting with KMeans::fit_with."
    );

    // From fit to serving: capture the fit as a model and let `predict`
    // assign fresh points — no hand-rolled nearest-center loop needed. At
    // this k (50 < 64) the auto strategy answers with the Elkan-pruned
    // scan; at k >= 64 it switches to a cover tree built over the centers.
    let model = KMeans::new(k)
        .algorithm(Algorithm::Hybrid)
        .warm_start(init)
        .fit_model(&data)
        .expect("valid configuration");
    let fresh = synth::istanbul(0.001, 43);
    let labels = model.predict(&fresh);
    let mut sizes = vec![0usize; model.k()];
    for &l in &labels {
        sizes[l as usize] += 1;
    }
    let busiest = sizes.iter().enumerate().max_by_key(|(_, &c)| c).unwrap();
    println!(
        "\nserving: {} fresh points assigned; busiest cluster {} took {} of them\n\
         (persist with model.save(path) and reload with KMeansModel::load —\n\
         see examples/train_then_serve.rs for the full loop)",
        fresh.rows(),
        busiest.0,
        busiest.1
    );
}
