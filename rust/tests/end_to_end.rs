//! Coordinator-level integration tests: full experiment protocols on small
//! scales, checking the cross-algorithm consistency the paper's tables
//! rely on, plus the report renderers.

use covermeans::coordinator::{report, run_experiment, sweep, Experiment};
use covermeans::kmeans::Algorithm;

#[test]
fn tables23_protocol_small() {
    let mut exp = sweep::tables23(0.002, 2);
    exp.datasets = vec!["istanbul".into(), "kdd04".into()];
    exp.threads = 4;
    let res = run_experiment(&exp, false).unwrap();
    assert_eq!(res.cells.len(), 2 * Algorithm::ALL.len());

    // Exactness across the full matrix: same SSE per (dataset, run).
    for ds in &exp.datasets {
        let std_runs = &res.cell(ds, Algorithm::Standard).unwrap().runs;
        for &alg in &exp.algorithms {
            let runs = &res.cell(ds, alg).unwrap().runs;
            for (a, b) in runs.iter().zip(std_runs) {
                assert!(
                    (a.sse - b.sse).abs() < 1e-6 * (1.0 + b.sse),
                    "{ds}/{}: sse {} vs standard {}",
                    alg.name(),
                    a.sse,
                    b.sse
                );
                assert_eq!(a.iterations, b.iterations, "{ds}/{}", alg.name());
            }
        }
    }

    // Table rendering produces a row per non-Standard algorithm.
    let table = report::render_ratio_table(&exp, &res, report::Metric::Distances, "t2");
    for alg in Algorithm::ALL {
        if alg != Algorithm::Standard {
            assert!(table.contains(alg.name()), "missing row {}", alg.name());
        }
    }
}

#[test]
fn table4_sweep_amortizes_and_reports() {
    let mut exp = sweep::table4(0.002, 1);
    exp.datasets = vec!["istanbul".into()];
    exp.ks = vec![5, 10, 20]; // reduced grid for test time
    exp.threads = 4;
    let res = run_experiment(&exp, false).unwrap();
    let cover = res.cell("istanbul", Algorithm::CoverMeans).unwrap();
    // One tree build across the whole sweep.
    let builds = cover
        .runs
        .iter()
        .filter(|r| r.build_dist > 0)
        .count();
    assert_eq!(builds, 1);
    assert_eq!(cover.runs.len(), 3);
    let csv = report::ratio_table_csv(&exp, &res, report::Metric::Time);
    assert!(csv.len() > 1);
}

#[test]
fn fig1_series_has_all_algorithms() {
    let mut exp = sweep::fig1(0.002);
    exp.ks = vec![30];
    exp.threads = 4;
    let res = run_experiment(&exp, true).unwrap();
    let rows = report::fig1_series_csv(&exp, &res);
    for alg in Algorithm::ALL {
        assert!(
            rows.iter().any(|r| r.starts_with(alg.name())),
            "fig1 missing {}",
            alg.name()
        );
    }
    // Cumulative series must be monotone per algorithm.
    let mut last: Option<(String, f64)> = None;
    for row in rows.iter().skip(1) {
        let cols: Vec<&str> = row.split(',').collect();
        let alg = cols[0].to_string();
        let v: f64 = cols[2].parse().unwrap();
        if let Some((ref la, lv)) = last {
            if *la == alg {
                assert!(v >= lv - 1e-12, "non-monotone series for {alg}");
            }
        }
        last = Some((alg, v));
    }
}

#[test]
fn fig2b_series_covers_k_grid() {
    let exp = Experiment {
        datasets: vec!["mnist10".into()],
        algorithms: vec![Algorithm::Standard, Algorithm::Shallot, Algorithm::Hybrid],
        ks: vec![5, 15],
        restarts: 1,
        scale: 0.002,
        threads: 4,
        ..Experiment::new("fig2b-test")
    };
    let res = run_experiment(&exp, false).unwrap();
    let rows = report::fig2_series_csv(&exp, &res, true);
    // header + 2 k values x 3 algorithms
    assert_eq!(rows.len(), 1 + 2 * 3);
}

#[test]
fn hybrid_wins_or_ties_shallot_on_tree_friendly_data() {
    // The paper's headline: Hybrid <= Shallot in distance computations on
    // most datasets (Table 2: hybrid 0.003 vs shallot 0.006 on istanbul).
    let exp = Experiment {
        datasets: vec!["istanbul".into()],
        algorithms: vec![Algorithm::Standard, Algorithm::Shallot, Algorithm::Hybrid],
        ks: vec![50],
        restarts: 3,
        scale: 0.004,
        threads: 4,
        ..Experiment::new("headline")
    };
    let res = run_experiment(&exp, false).unwrap();
    let shallot = res
        .ratio_vs_standard("istanbul", Algorithm::Shallot, |c| c.distances as f64)
        .unwrap();
    let hybrid = res
        .ratio_vs_standard("istanbul", Algorithm::Hybrid, |c| c.distances as f64)
        .unwrap();
    assert!(
        hybrid <= shallot * 1.15,
        "hybrid {hybrid:.4} should be <= ~shallot {shallot:.4}"
    );
}
