//! Serving-layer properties: the trained-model subsystem must hand back
//! exactly what the fit produced (save → load is bit-identical), answer
//! out-of-sample queries exactly like a naive lowest-index nearest-center
//! scan (in every [`PredictMode`], from a fresh or a loaded model), and do
//! so with strictly fewer counted distance evaluations than the naive
//! scan's `n * k` on a clustered k >= 64 workload — the acceptance bar of
//! the serving layer. Corrupt and truncated model files must fail loudly.

use covermeans::data::{synth, Matrix};
use covermeans::kmeans::{
    bounds, init, Algorithm, KMeans, KMeansModel, PredictMode, PredictOptions,
    Workspace,
};
use covermeans::metrics::DistCounter;

fn tmpdir() -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join(format!("covermeans_model_test_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Naive reference: full scan per query, ties to the lowest index.
fn naive_predict(queries: &Matrix, centers: &Matrix) -> (Vec<u32>, Vec<f64>, u64) {
    let mut dc = DistCounter::new();
    let mut labels = Vec::with_capacity(queries.rows());
    let mut dists = Vec::with_capacity(queries.rows());
    for i in 0..queries.rows() {
        let (c1, d1, _, _) = bounds::nearest_two(queries.row(i), centers, &mut dc);
        labels.push(c1);
        dists.push(d1);
    }
    (labels, dists, dc.count())
}

#[test]
fn save_load_predict_roundtrip_across_algorithms() {
    let train = synth::istanbul(0.002, 60);
    let queries = synth::istanbul(0.001, 61);
    let dir = tmpdir();
    for (i, alg) in [Algorithm::Standard, Algorithm::CoverMeans, Algorithm::Shallot]
        .into_iter()
        .enumerate()
    {
        let model = KMeans::new(24)
            .algorithm(alg)
            .seed(100 + i as u64)
            .fit_model(&train)
            .unwrap();
        let path = dir.join(format!("roundtrip_{}.kmm", alg.name()));
        model.save(&path).unwrap();
        let loaded = KMeansModel::load(&path).unwrap();

        // Centers round-trip bit for bit; so does every header field.
        for (a, b) in loaded
            .centers()
            .as_slice()
            .iter()
            .zip(model.centers().as_slice())
        {
            assert_eq!(a.to_bits(), b.to_bits(), "{}", alg.name());
        }
        assert_eq!(loaded.counts(), model.counts());
        assert_eq!(loaded.algorithm(), alg);
        assert_eq!(loaded.seed(), model.seed());
        assert_eq!(loaded.iterations(), model.iterations());
        assert_eq!(loaded.converged(), model.converged());
        for (a, b) in loaded.cluster_sse().iter().zip(model.cluster_sse()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // Prediction through the loaded model is indistinguishable from
        // the fresh one — labels, distances, and counted evaluations —
        // and both match the naive scan.
        let (want_labels, want_dists, _) = naive_predict(&queries, model.centers());
        for mode in [PredictMode::Tree, PredictMode::Scan] {
            let opts = PredictOptions { mode, ..Default::default() };
            let fresh = model.predict_opts(&queries, &opts);
            let served = loaded.predict_opts(&queries, &opts);
            assert_eq!(fresh.labels, want_labels, "{} {}", alg.name(), mode.name());
            assert_eq!(served.labels, want_labels, "{} {}", alg.name(), mode.name());
            assert_eq!(fresh.query_evals, served.query_evals);
            for (a, b) in fresh.distances.iter().zip(&want_dists) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn tree_predict_beats_naive_scan_at_high_k() {
    // The acceptance bar: a k >= 64 clustered workload must be answered
    // with strictly fewer counted distance evaluations than the naive
    // scan's n * k — even charging the one-off center-index build.
    let train = synth::istanbul(0.002, 62);
    let queries = synth::istanbul(0.001, 63);
    let k = 64;
    let model = KMeans::new(k)
        .algorithm(Algorithm::Hybrid)
        .seed(7)
        .fit_model(&train)
        .unwrap();
    let p = model.predict_opts(
        &queries,
        &PredictOptions { mode: PredictMode::Auto, ..Default::default() },
    );
    assert_eq!(p.mode, PredictMode::Tree, "auto must pick the tree at k=64");
    let naive = (queries.rows() * k) as u64;
    assert!(
        p.query_evals < naive,
        "tree predict spent {} evals, naive scan spends {naive}",
        p.query_evals
    );
    assert!(
        p.query_evals + p.prep_evals < naive,
        "even with index construction ({} + {}) the tree must beat {naive}",
        p.query_evals,
        p.prep_evals
    );
    // And the answers are still exact.
    let (want, _, _) = naive_predict(&queries, model.centers());
    assert_eq!(p.labels, want);

    // The pruned scan also beats naive on clustered data (its prune uses
    // the inter-center matrix, charged to prep once).
    let scan = model.predict_opts(
        &queries,
        &PredictOptions { mode: PredictMode::Scan, ..Default::default() },
    );
    assert_eq!(scan.labels, want);
    assert!(
        scan.query_evals < naive,
        "pruned scan spent {} evals, naive spends {naive}",
        scan.query_evals
    );
}

#[test]
fn predict_reuses_fit_workspace_pool() {
    // The serve path can ride the same persistent pool the fit used: the
    // workspace hands out its pool, and results stay byte-identical to a
    // fresh sequential predict.
    let train = synth::gaussian_blobs(800, 5, 8, 0.7, 64);
    let queries = synth::gaussian_blobs(300, 5, 8, 1.0, 65);
    let mut ws = Workspace::new();
    let model = KMeans::new(8)
        .algorithm(Algorithm::Elkan)
        .seed(3)
        .threads(4)
        .fit_model_with(&train, &mut ws)
        .unwrap();
    let pooled = model.predict_par(&queries, PredictMode::Scan, &ws.parallelism(4));
    let sequential = model.predict_opts(
        &queries,
        &PredictOptions { mode: PredictMode::Scan, ..Default::default() },
    );
    assert_eq!(pooled.labels, sequential.labels);
    assert_eq!(pooled.query_evals, sequential.query_evals);
    for (a, b) in pooled.distances.iter().zip(&sequential.distances) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn corrupt_and_truncated_files_error() {
    let train = synth::gaussian_blobs(150, 3, 4, 0.5, 66);
    let model = KMeans::new(4).seed(1).fit_model(&train).unwrap();
    let dir = tmpdir();
    let path = dir.join("target.kmm");
    model.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // Truncations at every boundary class: empty, inside the magic,
    // inside the header, inside the centers, inside the checksum.
    for len in [0usize, 2, 6, 30, bytes.len() / 2, bytes.len() - 4, bytes.len() - 1] {
        let p = dir.join(format!("trunc_{len}.kmm"));
        std::fs::write(&p, &bytes[..len]).unwrap();
        let err = KMeansModel::load(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("model") || msg.contains("checksum") || msg.contains("truncated"),
            "prefix {len}: undiagnostic error {msg}"
        );
        std::fs::remove_file(&p).ok();
    }

    // A flipped byte anywhere in the body trips the checksum.
    for pos in [4usize, 20, bytes.len() / 2, bytes.len() - 12] {
        let mut bad = bytes.clone();
        bad[pos] ^= 0x01;
        let p = dir.join(format!("flip_{pos}.kmm"));
        std::fs::write(&p, &bad).unwrap();
        assert!(
            KMeansModel::load(&p).is_err(),
            "bit flip at {pos} must not parse"
        );
        std::fs::remove_file(&p).ok();
    }

    // A non-model file errors without panicking.
    let p = dir.join("not_a_model.kmm");
    std::fs::write(&p, b"hello world, definitely not a model").unwrap();
    assert!(KMeansModel::load(&p).is_err());
    std::fs::remove_file(&p).ok();
    std::fs::remove_file(&path).ok();
}

#[test]
fn exports_write_centers_faithfully() {
    let train = synth::gaussian_blobs(200, 4, 5, 0.5, 67);
    let model = KMeans::new(5).seed(2).fit_model(&train).unwrap();
    let dir = tmpdir();

    // CSV: Rust's shortest-round-trip float formatting means reading the
    // CSV back reproduces the centers exactly.
    let csv = dir.join("centers.csv");
    model.export_centers_csv(&csv).unwrap();
    let back = covermeans::data::io::read_csv(&csv).unwrap();
    assert_eq!(back.rows(), model.k());
    assert_eq!(back.cols(), model.dim());
    for (a, b) in back.as_slice().iter().zip(model.centers().as_slice()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    std::fs::remove_file(&csv).ok();

    // JSON: structurally sane without a parser dependency — the header
    // fields and one row per center are present.
    let json = dir.join("model.json");
    model.export_json(&json).unwrap();
    let text = std::fs::read_to_string(&json).unwrap();
    assert!(text.contains("\"covermeans-kmeans-model\""));
    assert!(text.contains("\"k\": 5"));
    assert!(text.contains("\"algorithm\": \"Standard\""));
    assert_eq!(text.matches('[').count(), text.matches(']').count());
    assert_eq!(text.matches('{').count(), text.matches('}').count());
    std::fs::remove_file(&json).ok();
}

#[test]
fn warm_start_model_keeps_provenance_of_builder() {
    // Models built from warm-started fits still record the configured
    // algorithm and seed (the seed documents the builder config; the
    // centers came from the warm start).
    let data = synth::gaussian_blobs(300, 3, 6, 0.5, 68);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, 6, 9, &mut dc);
    let model = KMeans::new(6)
        .algorithm(Algorithm::Exponion)
        .seed(42)
        .warm_start(init_c)
        .fit_model(&data)
        .unwrap();
    assert_eq!(model.algorithm(), Algorithm::Exponion);
    assert_eq!(model.seed(), 42);
    assert_eq!(model.counts().iter().sum::<u64>(), 300);
    let total: f64 = model.cluster_sse().iter().sum();
    assert!((model.inertia() - total).abs() < 1e-12 * (1.0 + total));
}
