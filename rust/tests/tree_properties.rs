//! Property-based invariant tests for the spatial indexes, randomized over
//! data regimes and construction parameters.

use covermeans::data::{matrix::dist, synth, Matrix};
use covermeans::rng::Rng;
use covermeans::testutil::{check, usize_in, Config};
use covermeans::tree::centers::build_center_tree;
use covermeans::tree::covertree::{CoverTree, CoverTreeParams, Node};
use covermeans::tree::kdtree::{is_farther, KdTree, KdTreeParams};

fn random_data(rng: &mut Rng) -> Matrix {
    match rng.below(4) {
        0 => synth::gaussian_blobs(
            usize_in(rng, 50, 800),
            usize_in(rng, 1, 12),
            usize_in(rng, 1, 6),
            rng.f64() * 2.0 + 0.01,
            rng.next_u64(),
        ),
        1 => synth::istanbul(0.0003 + rng.f64() * 0.001, rng.next_u64()),
        2 => synth::traffic(0.00002 + rng.f64() * 0.00005, rng.next_u64()),
        _ => synth::aloi(usize_in(rng, 4, 27), 0.002, rng.next_u64()),
    }
}

/// Cover-tree invariants the k-means bounds (Eqs. 6-8) rely on.
fn check_cover_node(data: &Matrix, node: &Node) -> (u32, Vec<f64>) {
    let p = data.row(node.routing as usize);
    let mut count = 0u32;
    let mut sum = vec![0.0; data.cols()];
    node.for_each_point(&mut |idx| {
        let dd = dist(p, data.row(idx as usize));
        assert!(dd <= node.radius + 1e-9, "radius violated");
        count += 1;
        for (j, v) in data.row(idx as usize).iter().enumerate() {
            sum[j] += v;
        }
    });
    assert_eq!(count, node.weight, "aggregate weight");
    for j in 0..data.cols() {
        assert!(
            (sum[j] - node.sum[j]).abs() < 1e-6 * (1.0 + sum[j].abs()),
            "aggregate sum"
        );
    }
    for ch in &node.children {
        let dd = dist(p, data.row(ch.routing as usize));
        assert!((dd - ch.parent_dist).abs() < 1e-9, "parent distance");
        assert!(ch.radius <= node.radius + 1e-9, "radius monotone");
        check_cover_node(data, ch);
    }
    for &(idx, pd) in &node.singletons {
        let dd = dist(p, data.row(idx as usize));
        assert!((dd - pd).abs() < 1e-9, "singleton distance");
    }
    (count, sum)
}

#[test]
fn cover_tree_invariants_random() {
    check(Config { cases: 16, seed: 0xC0FE }, "cover-invariants", |rng| {
        let data = random_data(rng);
        let params = CoverTreeParams {
            scale_factor: 1.05 + rng.f64() * 1.5,
            min_node_size: usize_in(rng, 1, 200),
        };
        let tree = CoverTree::build(&data, params);
        assert_eq!(tree.len(), data.rows());
        let (count, _) = check_cover_node(&data, &tree.root);
        assert_eq!(count as usize, data.rows());
        // Partition: every point exactly once.
        let mut seen = vec![0u8; data.rows()];
        tree.root.for_each_point(&mut |i| seen[i as usize] += 1);
        assert!(seen.iter().all(|&c| c == 1), "each point exactly once");
    });
}

#[test]
fn kd_tree_invariants_random() {
    check(Config { cases: 16, seed: 0x6D }, "kd-invariants", |rng| {
        let data = random_data(rng);
        let params = KdTreeParams {
            leaf_size: usize_in(rng, 1, 200),
            max_depth: usize_in(rng, 8, 64),
        };
        let tree = KdTree::build(&data, params);
        assert_eq!(tree.len(), data.rows());
        check_kd(&data, &tree.root);
        let mut seen = vec![0u8; data.rows()];
        tree.root.for_each_point(&mut |i| seen[i as usize] += 1);
        assert!(seen.iter().all(|&c| c == 1));
    });
}

fn check_kd(data: &Matrix, node: &covermeans::tree::kdtree::KdNode) {
    let mut count = 0u32;
    node.for_each_point(&mut |i| {
        let row = data.row(i as usize);
        for j in 0..data.cols() {
            assert!(row[j] >= node.bbox_min[j] - 1e-12);
            assert!(row[j] <= node.bbox_max[j] + 1e-12);
        }
        count += 1;
    });
    assert_eq!(count, node.weight);
    if let (Some(l), Some(r)) = (&node.left, &node.right) {
        assert_eq!(l.weight + r.weight, node.weight);
        check_kd(data, l);
        check_kd(data, r);
    }
}

/// The dominance test must be *sound*: whenever it prunes `z`, every point
/// of the box really is at least as close to `z_star` as to `z`.
#[test]
fn dominance_test_sound() {
    check(Config { cases: 64, seed: 7 }, "dominance-sound", |rng| {
        let d = usize_in(rng, 1, 6);
        let mut bmin = vec![0.0; d];
        let mut bmax = vec![0.0; d];
        for j in 0..d {
            let a = rng.gaussian() * 3.0;
            let b = rng.gaussian() * 3.0;
            bmin[j] = a.min(b);
            bmax[j] = a.max(b);
        }
        let z: Vec<f64> = (0..d).map(|_| rng.gaussian() * 5.0).collect();
        let zs: Vec<f64> = (0..d).map(|_| rng.gaussian() * 5.0).collect();
        if is_farther(&z, &zs, &bmin, &bmax) {
            // Sample random points in the box; none may be closer to z.
            for _ in 0..64 {
                let q: Vec<f64> = (0..d)
                    .map(|j| bmin[j] + rng.f64() * (bmax[j] - bmin[j]))
                    .collect();
                assert!(
                    dist(&q, &z) + 1e-9 >= dist(&q, &zs),
                    "pruned z was closer for a box point"
                );
            }
        }
    });
}

/// The dual-tree pair prune must be a no-op on the result: whenever a
/// (point node, center subtree) pair satisfies the prune condition
/// `d(p, c_E) - r_E > d(p, c_1) + 2 r_x` (exact routing distances,
/// incumbent `c_1` minimal by `(distance, index)`), no point of the
/// point node's subtree has a center of the pruned subtree closer than
/// the incumbent's routing center.
#[test]
fn dual_tree_pair_prune_is_sound() {
    check(Config { cases: 10, seed: 0xD0A1 }, "dual-prune-sound", |rng| {
        let data = random_data(rng);
        let k = usize_in(rng, 4, 40).min(data.rows());
        let rows: Vec<&[f64]> = (0..k)
            .map(|_| data.row(usize_in(rng, 0, data.rows() - 1)))
            .collect();
        let centers = Matrix::from_rows(&rows);
        let ctree = build_center_tree(
            k,
            CoverTreeParams { scale_factor: 1.3, min_node_size: 4 },
            &|i, j| dist(centers.row(i), centers.row(j)),
        );
        let tree = CoverTree::build(
            &data,
            CoverTreeParams {
                scale_factor: 1.1 + rng.f64() * 0.4,
                min_node_size: usize_in(rng, 1, 100),
            },
        );
        // One expansion of the center root: its child subtrees plus its
        // resolved singletons — the entry shape the dual pass carries.
        let mut groups: Vec<(Vec<u32>, u32, f64)> = Vec::new();
        for ch in &ctree.root.children {
            let mut members = Vec::new();
            ch.for_each_center(&mut |c| members.push(c));
            groups.push((members, ch.center, ch.radius));
        }
        for &(c, _) in &ctree.root.singletons {
            groups.push((vec![c], c, 0.0));
        }
        let mut checked = 0usize;
        check_pair_prune_no_op(&data, &centers, &groups, &tree.root, &mut checked);
    });
}

/// Walk the point tree (capped for runtime) and verify the prune claim of
/// `dual_tree_pair_prune_is_sound` against exhaustive distances.
fn check_pair_prune_no_op(
    data: &Matrix,
    centers: &Matrix,
    groups: &[(Vec<u32>, u32, f64)],
    node: &Node,
    checked: &mut usize,
) {
    if *checked >= 48 {
        return;
    }
    *checked += 1;
    let p = data.row(node.routing as usize);
    let evals: Vec<f64> = groups
        .iter()
        .map(|&(_, c, _)| dist(p, centers.row(c as usize)))
        .collect();
    let mut bi = 0usize;
    for i in 1..groups.len() {
        if evals[i] < evals[bi] || (evals[i] == evals[bi] && groups[i].1 < groups[bi].1)
        {
            bi = i;
        }
    }
    let c1 = groups[bi].1;
    let d1 = evals[bi];
    let mut points = Vec::new();
    node.for_each_point(&mut |i| points.push(i));
    points.truncate(64);
    for (i, (members, _, r_e)) in groups.iter().enumerate() {
        if evals[i] - r_e <= d1 + 2.0 * node.radius {
            continue; // pair survives; the prune claims nothing
        }
        for &q in &points {
            let qr = data.row(q as usize);
            let dq1 = dist(qr, centers.row(c1 as usize));
            for &c in members {
                assert!(
                    dist(qr, centers.row(c as usize)) + 1e-9 >= dq1,
                    "pruned pair held a better center for a subtree point"
                );
            }
        }
    }
    for ch in &node.children {
        check_pair_prune_no_op(data, centers, groups, ch, checked);
    }
}

/// The paper's §1 memory claim: the ball representation (center vector +
/// radius, i.e. d+1 floats of payload) is ~2x more compact per node than
/// the k-d tree's boxes (midpoint+width or min+max = 2d floats, plus the
/// aggregate sum both need). Checked on a meaningful dimensionality.
#[test]
fn cover_node_payload_smaller_than_kd() {
    let d = 27; // ALOI-27
    // cover node payload: sum vector + radius + parent_dist.
    let cover_payload = (d + 2) * 8;
    // kd node payload: bbox min + max + sum vector.
    let kd_payload = 3 * d * 8;
    assert!(cover_payload * 2 <= kd_payload + 2 * 8);
}

/// On near-duplicate-heavy data the cover tree stays within a small factor
/// of the k-d tree's node count despite its self-child chains, and both
/// stay far below one node per point (duplicates collapse).
#[test]
fn cover_tree_compact_on_duplicates() {
    let data = synth::traffic(0.0005, 3);
    let tree = CoverTree::build(&data, CoverTreeParams::default());
    let kd = KdTree::build(&data, KdTreeParams::default());
    assert!(
        tree.node_count <= 3 * kd.node_count,
        "cover nodes {} vs kd nodes {}",
        tree.node_count,
        kd.node_count
    );
    assert!(tree.node_count * 10 < data.rows(), "duplicates must collapse");
    assert_eq!(tree.singleton_count, data.rows());
}

/// Build-cost sanity: construction distance count grows roughly
/// linearithmically, not quadratically, on clustered data.
#[test]
fn cover_tree_build_cost_subquadratic() {
    let small = synth::istanbul(0.001, 5);
    let large = synth::istanbul(0.004, 5);
    let t_small = CoverTree::build(&small, CoverTreeParams::default());
    let t_large = CoverTree::build(&large, CoverTreeParams::default());
    let ratio_n = large.rows() as f64 / small.rows() as f64;
    let ratio_dist = t_large.build_distances as f64 / t_small.build_distances as f64;
    assert!(
        ratio_dist < ratio_n * ratio_n / 2.0,
        "build cost scaled x{ratio_dist:.1} for n x{ratio_n:.1}"
    );
}
