//! CLI error-handling contract: malformed flags, missing required
//! arguments, invalid config values, and nonexistent files must exit
//! nonzero with a one-line `error: ...` diagnostic on stderr — never a
//! panic, never a silent success.

use std::process::{Command, Output};

fn covermeans(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_covermeans"))
        .args(args)
        .output()
        .expect("spawn covermeans")
}

/// Assert a nonzero exit with a single diagnosable `error:` line whose
/// text mentions every given needle.
fn assert_fails(args: &[&str], needles: &[&str]) {
    let out = covermeans(args);
    assert!(
        !out.status.success(),
        "`covermeans {}` must exit nonzero",
        args.join(" ")
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    let error_lines: Vec<&str> =
        stderr.lines().filter(|l| l.starts_with("error: ")).collect();
    assert_eq!(
        error_lines.len(),
        1,
        "`covermeans {}` must print exactly one error line, got stderr:\n{stderr}",
        args.join(" ")
    );
    assert!(
        !stderr.contains("panicked"),
        "`covermeans {}` panicked:\n{stderr}",
        args.join(" ")
    );
    for needle in needles {
        assert!(
            error_lines[0].contains(needle),
            "`covermeans {}`: error line {:?} does not mention {needle:?}",
            args.join(" "),
            error_lines[0]
        );
    }
}

#[test]
fn unknown_command_fails() {
    assert_fails(&["frobnicate"], &["unknown command", "frobnicate"]);
}

#[test]
fn malformed_flags_fail() {
    // Positional junk where a --flag is expected.
    assert_fails(&["run", "dataset"], &["expected --key"]);
    // A flag with no value.
    assert_fails(&["run", "--dataset"], &["--dataset needs a value"]);
    // A typo'd flag must be rejected, not silently ignored.
    assert_fails(&["run", "--datset", "aloi64"], &["unknown flag", "datset"]);
    assert_fails(&["predict", "--modle", "x.kmm"], &["unknown flag", "modle"]);
    assert_fails(&["serve", "--adr", "127.0.0.1:0"], &["unknown flag", "adr"]);
    assert_fails(&["table", "--ids", "2"], &["unknown flag", "ids"]);
    assert_fails(&["fig1", "--axis", "d"], &["unknown flag", "axis"]);
}

#[test]
fn invalid_config_values_fail() {
    assert_fails(&["run", "--k", "0"], &["k"]);
    assert_fails(&["run", "--scale", "-1"], &["scale"]);
    assert_fails(&["run", "--scale", "nan"], &["scale"]);
    assert_fails(&["serve", "--queue_depth", "0"], &["queue_depth"]);
    assert_fails(&["serve", "--max_batch", "0"], &["max_batch"]);
    assert_fails(&["predict", "--predict_auto_k", "0"], &["predict_auto_k"]);
    assert_fails(&["run", "--predict_mode", "psychic"], &["predict_mode"]);
}

#[test]
fn bad_resume_flags_fail() {
    // --resume wants a boolean, not free text.
    assert_fails(&["run", "--resume", "maybe"], &["--resume", "maybe"]);
    // Resuming without a snapshot path to resume from is an error, not a
    // silent cold start.
    assert_fails(&["run", "--resume", "1"], &["--resume", "checkpoint_path"]);
    // Resuming from a checkpoint that does not exist (in any generation)
    // names the path.
    assert_fails(
        &[
            "run",
            "--dataset",
            "blobs:200:4:4",
            "--k",
            "4",
            "--checkpoint_path",
            "/nonexistent/fit.kmc",
            "--resume",
            "1",
        ],
        &["fit.kmc"],
    );
    // The xla backend has no stepwise loop to hang checkpoints off.
    assert_fails(
        &[
            "run",
            "--backend",
            "xla",
            "--checkpoint_path",
            "/tmp/x.kmc",
        ],
        &["native"],
    );
    // MiniBatch has no exact iteration boundary to snapshot.
    assert_fails(
        &[
            "run",
            "--dataset",
            "blobs:200:4:4",
            "--k",
            "4",
            "--algorithm",
            "minibatch",
            "--checkpoint_path",
            "/tmp/x.kmc",
        ],
        &["minibatch", "checkpoint"],
    );
}

#[test]
fn missing_required_flags_fail() {
    assert_fails(&["predict"], &["--model"]);
    assert_fails(&["serve"], &["--model"]);
    assert_fails(
        &["predict", "--model", "m.kmm"],
        &["--input"],
    );
}

#[test]
fn nonexistent_files_fail() {
    assert_fails(
        &["predict", "--model", "/nonexistent/m.kmm", "--input", "/nonexistent/q.csv"],
        &["m.kmm"],
    );
    assert_fails(
        &["serve", "--model", "/nonexistent/m.kmm"],
        &["m.kmm"],
    );
    assert_fails(
        &["run", "--config", "/nonexistent/cfg.toml"],
        &["cfg.toml"],
    );
}

#[test]
fn bad_serve_addr_fails() {
    let dir = std::env::temp_dir()
        .join(format!("covermeans_cli_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tiny.kmm");
    let train = covermeans::data::synth::gaussian_blobs(200, 4, 4, 0.5, 9);
    let model = covermeans::kmeans::KMeans::new(4)
        .seed(9)
        .fit_model(&train)
        .unwrap();
    model.save(&path).unwrap();
    assert_fails(
        &["serve", "--model", path.to_str().unwrap(), "--addr", "not-an-addr"],
        &["bind", "not-an-addr"],
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn help_and_datasets_succeed() {
    for args in [&["help"][..], &["datasets"][..], &[][..]] {
        let out = covermeans(args);
        assert!(
            out.status.success(),
            "`covermeans {}` must exit 0",
            args.join(" ")
        );
    }
    let help = covermeans(&["help"]);
    let text = String::from_utf8_lossy(&help.stdout);
    assert!(text.contains("serve"), "help must document the serve verb");
    assert!(text.contains("predict_auto_k"), "help must list the new keys");
}
