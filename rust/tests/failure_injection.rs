//! Edge-case and failure-injection tests: degenerate datasets, forced
//! empty clusters, extreme parameters — the situations a library user hits
//! that a paper never mentions. Every exact algorithm must behave
//! identically to the Standard algorithm even here.

use covermeans::data::{synth, Matrix};
use covermeans::kmeans::{self, init, Algorithm, KMeansParams, Workspace};
use covermeans::metrics::DistCounter;

fn all_match(data: &Matrix, init_c: &Matrix, params: &KMeansParams) {
    let p = KMeansParams { algorithm: Algorithm::Standard, ..*params };
    let reference = kmeans::run(data, init_c, &p, &mut Workspace::new());
    for alg in [
        Algorithm::Elkan,
        Algorithm::Hamerly,
        Algorithm::Exponion,
        Algorithm::Shallot,
        Algorithm::Kanungo,
        Algorithm::PellegMoore,
        Algorithm::Phillips,
        Algorithm::CoverMeans,
        Algorithm::Hybrid,
    ] {
        let p = KMeansParams { algorithm: alg, ..*params };
        let r = kmeans::run(data, init_c, &p, &mut Workspace::new());
        assert_eq!(r.labels, reference.labels, "{}", alg.name());
        assert_eq!(r.iterations, reference.iterations, "{}", alg.name());
    }
}

#[test]
fn k_equals_n() {
    // Every point its own cluster: converges immediately, zero SSE.
    let data = synth::gaussian_blobs(40, 3, 4, 1.0, 60);
    let idx: Vec<usize> = (0..40).collect();
    let init_c = data.select_rows(&idx);
    let params = KMeansParams::default();
    all_match(&data, &init_c, &params);
    let r = kmeans::run(&data, &init_c, &params, &mut Workspace::new());
    assert!(r.sse(&data) < 1e-18);
}

#[test]
fn forced_empty_cluster_keeps_center() {
    // Two far blobs, three centers, one center far away from everything:
    // it captures nothing and must stay put in every algorithm.
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut rng = covermeans::rng::Rng::new(61);
    for _ in 0..50 {
        rows.push(vec![rng.gaussian() * 0.1, 0.0]);
        rows.push(vec![10.0 + rng.gaussian() * 0.1, 0.0]);
    }
    let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
    let data = Matrix::from_rows(&refs);
    let init_c = Matrix::from_rows(&[&[0.0, 0.0], &[10.0, 0.0], &[1000.0, 1000.0]]);
    let params = KMeansParams::default();
    all_match(&data, &init_c, &params);
    let r = kmeans::run(&data, &init_c, &params, &mut Workspace::new());
    assert_eq!(r.centers.row(2), &[1000.0, 1000.0], "empty cluster moved");
    assert!(r.labels.iter().all(|&l| l < 2));
}

#[test]
fn one_dimensional_data() {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut rng = covermeans::rng::Rng::new(62);
    for i in 0..200 {
        rows.push(vec![(i % 4) as f64 * 5.0 + rng.gaussian() * 0.2]);
    }
    let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
    let data = Matrix::from_rows(&refs);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, 4, 63, &mut dc);
    all_match(&data, &init_c, &KMeansParams::default());
}

#[test]
fn constant_dataset_all_points_identical() {
    // Every point AND every center coincide: an all-ties input. This is
    // the one regime where the documented tie caveat applies (exact
    // equality of distances), so cross-algorithm label equality is NOT
    // required — but every algorithm must converge, put all points in a
    // single cluster, and reach SSE 0.
    let rows: Vec<Vec<f64>> = vec![vec![3.5, -1.0, 2.0]; 150];
    let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
    let data = Matrix::from_rows(&refs);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, 3, 64, &mut dc);
    for alg in [
        Algorithm::Standard,
        Algorithm::Elkan,
        Algorithm::Hamerly,
        Algorithm::Exponion,
        Algorithm::Shallot,
        Algorithm::Kanungo,
        Algorithm::PellegMoore,
        Algorithm::Phillips,
        Algorithm::CoverMeans,
        Algorithm::Hybrid,
    ] {
        let p = KMeansParams { algorithm: alg, ..KMeansParams::default() };
        let r = kmeans::run(&data, &init_c, &p, &mut Workspace::new());
        assert!(r.converged, "{}", alg.name());
        let first = r.labels[0];
        assert!(
            r.labels.iter().all(|&l| l == first),
            "{}: identical points split across clusters",
            alg.name()
        );
        assert!(r.sse(&data) < 1e-18, "{}", alg.name());
    }
}

#[test]
fn max_iter_one_partial_run_is_consistent() {
    let data = synth::kdd04(0.001, 65);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, 8, 66, &mut dc);
    let params = KMeansParams { max_iter: 1, ..KMeansParams::default() };
    all_match(&data, &init_c, &params);
}

#[test]
fn huge_coordinates_no_overflow() {
    // 1e12-scale coordinates: squared distances ~1e24 stay finite in f64;
    // bounds arithmetic must not produce NaN/inf pruning errors.
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut rng = covermeans::rng::Rng::new(67);
    for i in 0..300 {
        let base = (i % 3) as f64 * 1e12;
        rows.push(vec![base + rng.gaussian() * 1e9, base - rng.gaussian() * 1e9]);
    }
    let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
    let data = Matrix::from_rows(&refs);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, 3, 68, &mut dc);
    all_match(&data, &init_c, &KMeansParams::default());
}

#[test]
fn tiny_scale_coordinates() {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut rng = covermeans::rng::Rng::new(69);
    for i in 0..300 {
        let base = (i % 3) as f64 * 1e-12;
        rows.push(vec![base + rng.gaussian() * 1e-15]);
    }
    let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
    let data = Matrix::from_rows(&refs);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, 3, 70, &mut dc);
    all_match(&data, &init_c, &KMeansParams::default());
}

#[test]
fn duplicated_initial_centers() {
    // k-means++ on duplicate-heavy data can emit coinciding centers; all
    // algorithms must agree on the tie-broken result.
    let data = synth::traffic(0.00003, 71);
    let init_c = Matrix::from_rows(&[data.row(0), data.row(0), data.row(1)]);
    all_match(&data, &init_c, &KMeansParams::default());
}

#[test]
fn minibatch_is_well_behaved_not_exact() {
    let data = synth::gaussian_blobs(500, 3, 4, 0.3, 72);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, 4, 73, &mut dc);
    let params = KMeansParams { algorithm: Algorithm::MiniBatch, ..KMeansParams::default() };
    let r = kmeans::run(&data, &init_c, &params, &mut Workspace::new());
    assert_eq!(r.labels.len(), 500);
    assert!(r.labels.iter().all(|&l| l < 4));
    assert!(!Algorithm::MiniBatch.is_exact());
    // SSE sane: within 2x of the exact result.
    let exact = kmeans::run(
        &data,
        &init_c,
        &KMeansParams::default(),
        &mut Workspace::new(),
    );
    assert!(r.sse(&data) <= 2.0 * exact.sse(&data) + 1e-12);
}
