//! Streaming-equivalence suite: the out-of-core contract of the
//! `DataSource` layer.
//!
//! For any source backend (in-RAM, mmap, chunk-streamed), any chunk size,
//! and any thread count, a fit must be **byte-identical** to the in-RAM
//! fit: same labels, same center bits, same iteration count, same counted
//! distances, and a bit-identical `.kmm` model. The suite pins that
//! contract three ways:
//!
//! 1. in-process, over an explicit backend × chunk × thread × algorithm
//!    matrix and a randomized property sweep;
//! 2. end-to-end, by spawning the real `covermeans` binary on a packed
//!    `.dmat` with `data_resident_mb` capped below the dataset size — the
//!    PR's acceptance criterion;
//! 3. across a crash: a fit checkpointed under one backend resumes under
//!    another and still reproduces the uninterrupted in-RAM run.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

use covermeans::data::{synth, write_dmat, DataSource, SourceBackend};
use covermeans::kmeans::{
    Algorithm, AlgorithmSpec, InitKind, KMeans, KMeansModel, KMeansParams,
};
use covermeans::metrics::RunResult;
use covermeans::testutil::{check, usize_in, Config};

const BIN: &str = env!("CARGO_BIN_EXE_covermeans");

/// The streaming-capable exact drivers plus MiniBatch: the matrix the
/// tentpole promises byte-identity for.
const ALGS: [Algorithm; 3] =
    [Algorithm::Standard, Algorithm::Hamerly, Algorithm::MiniBatch];

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "covermeans_stream_eq_{tag}_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// One fit with the init pinned to k-means|| — the Auto default differs
/// by backend (that is its job), so equivalence legs always pin it.
fn fit(source: &DataSource, alg: Algorithm, k: usize, threads: usize) -> RunResult {
    KMeans::new(k)
        .algorithm(AlgorithmSpec::from_params(alg, &KMeansParams::default()))
        .init(InitKind::Parallel)
        .seed(9)
        .threads(threads)
        .fit_source(source)
        .unwrap_or_else(|e| panic!("{} fit failed: {e}", alg.name()))
}

/// Everything the determinism contract covers, in comparable form: exact
/// label assignment, raw center bits, iteration count, counted distances,
/// and the serialized `.kmm` the run would persist.
fn signature(
    source: &DataSource,
    r: &RunResult,
    alg: Algorithm,
) -> (Vec<u32>, Vec<u64>, usize, u64, Vec<u8>) {
    let bits: Vec<u64> = r.centers.as_slice().iter().map(|v| v.to_bits()).collect();
    let kmm = KMeansModel::from_run_src(source.view(), r, alg, 9).to_bytes();
    (r.labels.clone(), bits, r.iterations, r.distances, kmm)
}

#[test]
fn every_backend_chunking_and_thread_count_is_byte_identical() {
    let dir = tmpdir("matrix");
    // Odd row count on purpose: no chunk size divides it evenly.
    let m = synth::gaussian_blobs(257, 3, 5, 0.7, 42);
    let path = dir.join("data.dmat");
    write_dmat(&path, &m).unwrap();
    let k = 6;
    let chunks = [1usize, 37, m.rows(), m.rows() * 3];

    for alg in ALGS {
        let ram = DataSource::from(m.clone());
        let run = fit(&ram, alg, k, 1);
        assert!(run.iterations > 0);
        let want = signature(&ram, &run, alg);
        for threads in [1usize, 4] {
            let r = fit(&ram, alg, k, threads);
            assert_eq!(
                signature(&ram, &r, alg),
                want,
                "{}: in-RAM fit diverged at {threads} threads",
                alg.name()
            );
            for backend in
                [SourceBackend::Ram, SourceBackend::Mmap, SourceBackend::Chunked]
            {
                for chunk in chunks {
                    let src = DataSource::open(&path, backend, chunk, 0).unwrap();
                    let r = fit(&src, alg, k, threads);
                    assert_eq!(
                        signature(&src, &r, alg),
                        want,
                        "{}: {} backend, chunk {chunk}, {threads} threads \
                         diverged from the in-RAM fit",
                        alg.name(),
                        backend.name()
                    );
                    if backend != SourceBackend::Chunked {
                        // Chunk size only means something when streaming.
                        break;
                    }
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn randomized_shapes_stream_identically() {
    let dir = tmpdir("prop");
    let mut case = 0u32;
    check(Config { cases: 6, seed: 0x57AE_A30 }, "stream-identity", |rng| {
        case += 1;
        let n = usize_in(rng, 20, 200);
        let d = usize_in(rng, 1, 5);
        let k = usize_in(rng, 2, 7).min(n);
        let chunk = usize_in(rng, 1, n + 7);
        let threads = if rng.below(2) == 0 { 1 } else { 4 };
        let m = synth::gaussian_blobs(n, d, k.min(4), 0.8, rng.next_u64());
        let path = dir.join(format!("case_{case}.dmat"));
        write_dmat(&path, &m).unwrap();
        for alg in [Algorithm::Standard, Algorithm::Hamerly] {
            let ram = DataSource::from(m.clone());
            let want = {
                let r = fit(&ram, alg, k, 1);
                signature(&ram, &r, alg)
            };
            for backend in [SourceBackend::Mmap, SourceBackend::Chunked] {
                let src = DataSource::open(&path, backend, chunk, 0).unwrap();
                let r = fit(&src, alg, k, threads);
                assert_eq!(
                    signature(&src, &r, alg),
                    want,
                    "{}: n={n} d={d} k={k} chunk={chunk} threads={threads} \
                     backend={}",
                    alg.name(),
                    backend.name()
                );
            }
        }
    });
    std::fs::remove_dir_all(&dir).ok();
}

// ----- spawned-CLI legs ---------------------------------------------------

fn covermeans(args: &[&str], envs: &[(&str, &str)]) -> Output {
    let mut c = Command::new(BIN);
    c.args(args);
    for (k, v) in envs {
        c.env(k, v);
    }
    c.output().expect("spawn covermeans")
}

fn stdout_line<'a>(out: &'a str, prefix: &str) -> &'a str {
    out.lines()
        .find(|l| l.starts_with(prefix))
        .unwrap_or_else(|| panic!("no {prefix:?} line in stdout:\n{out}"))
}

/// The result lines whose equality certifies streamed ≡ resident beyond
/// the byte-compare of the saved model.
const RESULT_LINES: [&str; 3] = ["iterations  :", "distances   :", "sse         :"];

fn assert_same_result(tag: &str, ref_out: &str, res_out: &str) {
    for prefix in RESULT_LINES {
        assert_eq!(
            stdout_line(ref_out, prefix),
            stdout_line(res_out, prefix),
            "{tag}: streamed run diverged on the {prefix:?} line"
        );
    }
}

fn assert_same_model(tag: &str, a: &Path, b: &Path) {
    let wa = std::fs::read(a).unwrap_or_else(|e| panic!("{tag}: read {a:?}: {e}"));
    let wb = std::fs::read(b).unwrap_or_else(|e| panic!("{tag}: read {b:?}: {e}"));
    assert!(!wa.is_empty(), "{tag}: empty reference model");
    assert_eq!(wa, wb, "{tag}: streamed model is not bit-identical");
}

/// The PR's acceptance criterion: a spawned `covermeans run` over a
/// chunk-streamed file with `data_resident_mb` capped below the dataset
/// size produces a `.kmm` byte-identical to the in-RAM fit, at 1 and 4
/// threads.
#[test]
fn cli_out_of_core_fit_is_bit_identical_to_resident() {
    let dir = tmpdir("cli");
    // 20000 rows x 8 cols x 8 bytes = 1.28 MB of payload, so a 1 MiB
    // resident budget genuinely cannot hold the dataset.
    const DATASET: &str = "blobs:20000:8:16";
    let dmat = dir.join("big.dmat");
    let p = covermeans(
        &["pack", "--dataset", DATASET, "--out", dmat.to_str().unwrap()],
        &[],
    );
    assert!(
        p.status.success(),
        "pack failed:\n{}",
        String::from_utf8_lossy(&p.stderr)
    );
    let bytes = std::fs::metadata(&dmat).unwrap().len();
    assert!(bytes > 1 << 20, "dataset must exceed the 1 MiB budget, got {bytes}");

    for threads in ["1", "4"] {
        let tag = format!("ooc@{threads}t");
        let fit_flags = [
            "--k", "16", "--seed", "5", "--algorithm", "standard",
            "--max_iter", "6", "--init", "kmeans||", "--fit_threads", threads,
        ];
        let ref_model = dir.join(format!("ref_{threads}.kmm"));
        let ooc_model = dir.join(format!("ooc_{threads}.kmm"));

        let mut args = vec!["run", "--dataset", DATASET];
        args.extend_from_slice(&fit_flags);
        args.extend_from_slice(&["--model_out", ref_model.to_str().unwrap()]);
        let r = covermeans(&args, &[]);
        assert!(
            r.status.success(),
            "{tag}: resident run failed:\n{}",
            String::from_utf8_lossy(&r.stderr)
        );
        let ref_out = String::from_utf8_lossy(&r.stdout).into_owned();

        let mut args = vec![
            "run", "--data_file", dmat.to_str().unwrap(),
            "--data_backend", "chunked", "--data_chunk_rows", "511",
            "--data_resident_mb", "1",
        ];
        args.extend_from_slice(&fit_flags);
        args.extend_from_slice(&["--model_out", ooc_model.to_str().unwrap()]);
        let o = covermeans(&args, &[]);
        assert!(
            o.status.success(),
            "{tag}: streamed run failed:\n{}",
            String::from_utf8_lossy(&o.stderr)
        );
        assert!(
            String::from_utf8_lossy(&o.stderr).contains("chunked"),
            "{tag}: streamed run did not announce its backend"
        );
        assert_same_result(&tag, &ref_out, &String::from_utf8_lossy(&o.stdout));
        assert_same_model(&tag, &ref_model, &ooc_model);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Checkpoint/resume keeps working across backends: a fit crashed under
/// the chunk-streamed backend resumes under mmap and still reproduces the
/// uninterrupted in-RAM run bit for bit.
#[test]
fn resume_mid_fit_crosses_backends_bit_identically() {
    let dir = tmpdir("resume");
    const DATASET: &str = "blobs:600:4:8";
    let dmat = dir.join("small.dmat");
    let p = covermeans(
        &["pack", "--dataset", DATASET, "--out", dmat.to_str().unwrap()],
        &[],
    );
    assert!(p.status.success(), "pack failed");

    let fit_flags = [
        "--k", "8", "--seed", "5", "--algorithm", "hamerly",
        "--init", "kmeans||", "--fit_threads", "2",
    ];
    let ref_model = dir.join("ref.kmm");
    let res_model = dir.join("res.kmm");
    let ck = dir.join("stream.kmc");

    let mut args = vec!["run", "--dataset", DATASET];
    args.extend_from_slice(&fit_flags);
    args.extend_from_slice(&["--model_out", ref_model.to_str().unwrap()]);
    let r = covermeans(&args, &[]);
    assert!(
        r.status.success(),
        "reference run failed:\n{}",
        String::from_utf8_lossy(&r.stderr)
    );
    let ref_out = String::from_utf8_lossy(&r.stdout).into_owned();
    // The crash is injected after iteration 1, so the reference must have
    // stepped further for the resume leg to mean anything.
    let iters = stdout_line(&ref_out, "iterations  :");
    assert!(
        !iters.contains(": 1 "),
        "fit converged too fast for a mid-fit crash: {iters}"
    );

    let mut args = vec![
        "run", "--data_file", dmat.to_str().unwrap(),
        "--data_backend", "chunked", "--data_chunk_rows", "23",
    ];
    args.extend_from_slice(&fit_flags);
    args.extend_from_slice(&[
        "--checkpoint_path", ck.to_str().unwrap(), "--checkpoint_every", "1",
    ]);
    let c = covermeans(&args, &[("COVERMEANS_CRASH_AFTER_ITER", "1")]);
    assert!(!c.status.success(), "injected crash did not kill the run");
    assert!(
        String::from_utf8_lossy(&c.stderr).contains("simulated crash"),
        "abort fired without the fault-injection banner:\n{}",
        String::from_utf8_lossy(&c.stderr)
    );
    assert!(ck.exists(), "no snapshot on disk after the crash");

    // Resume under a *different* backend.
    let mut args = vec![
        "run", "--data_file", dmat.to_str().unwrap(), "--data_backend", "mmap",
    ];
    args.extend_from_slice(&fit_flags);
    args.extend_from_slice(&[
        "--checkpoint_path", ck.to_str().unwrap(), "--resume", "1",
        "--model_out", res_model.to_str().unwrap(),
    ]);
    let r2 = covermeans(&args, &[]);
    let stderr = String::from_utf8_lossy(&r2.stderr);
    assert!(r2.status.success(), "cross-backend resume failed:\n{stderr}");
    assert!(stderr.contains("resuming"), "no resume banner:\n{stderr}");
    assert_same_result("resume", &ref_out, &String::from_utf8_lossy(&r2.stdout));
    assert_same_model("resume", &ref_model, &res_model);
    std::fs::remove_dir_all(&dir).ok();
}

/// Tree-based algorithms need a resident source: the CLI refuses streamed
/// input with exactly one diagnosable error line.
#[test]
fn streamed_cli_rejects_tree_algorithms_with_one_error_line() {
    let dir = tmpdir("reject");
    let dmat = dir.join("tiny.dmat");
    let p = covermeans(
        &["pack", "--dataset", "blobs:120:3:4", "--out", dmat.to_str().unwrap()],
        &[],
    );
    assert!(p.status.success(), "pack failed");
    let r = covermeans(
        &[
            "run", "--data_file", dmat.to_str().unwrap(),
            "--data_backend", "chunked", "--k", "4", "--algorithm", "cover",
        ],
        &[],
    );
    assert!(!r.status.success(), "tree algorithm accepted streamed input");
    let stderr = String::from_utf8_lossy(&r.stderr);
    assert!(
        stderr.contains("cannot fit a streamed data source"),
        "unhelpful refusal:\n{stderr}"
    );
    assert_eq!(
        stderr.matches("error: ").count(),
        1,
        "CLI error contract: exactly one error line, got:\n{stderr}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
