//! Integration tests for the PJRT runtime path: AOT artifacts (built by
//! `make artifacts`) loaded and executed from Rust, validated against the
//! native f64 implementation.
//!
//! These tests are skipped (with a loud warning) when `artifacts/` is
//! missing, so `cargo test` still works in a fresh checkout; `make test`
//! always builds artifacts first.

use covermeans::data::{synth, Matrix};
use covermeans::kmeans::{init, lloyd, Algorithm, KMeansParams};
use covermeans::metrics::DistCounter;
use covermeans::runtime::{artifacts_dir, lloyd_xla, AssignExecutor};

fn executor_or_skip() -> Option<AssignExecutor> {
    if !artifacts_dir().join("manifest.tsv").exists() {
        eprintln!(
            "WARNING: artifacts/manifest.tsv missing — run `make artifacts`; skipping XLA test"
        );
        return None;
    }
    Some(AssignExecutor::load_default().expect("load executor"))
}

fn native_assign(data: &Matrix, centers: &Matrix) -> (Vec<u32>, Vec<f64>, Vec<f64>) {
    let mut dist = DistCounter::new();
    let n = data.rows();
    let mut labels = Vec::with_capacity(n);
    let mut d1 = Vec::with_capacity(n);
    let mut d2 = Vec::with_capacity(n);
    for i in 0..n {
        let (c1, dd1, _c2, dd2) =
            covermeans::kmeans::bounds::nearest_two(data.row(i), centers, &mut dist);
        labels.push(c1);
        d1.push(dd1);
        d2.push(dd2);
    }
    (labels, d1, d2)
}

#[test]
fn xla_assign_matches_native() {
    let Some(mut exec) = executor_or_skip() else { return };
    // Odd sizes exercise all three padding axes (n % chunk, d pad, k pad).
    let data = synth::gaussian_blobs(1500, 5, 7, 1.0, 42);
    let mut dc = DistCounter::new();
    let centers = init::kmeans_plus_plus(&data, 7, 3, &mut dc);

    let out = exec.assign(&data, &centers).expect("assign");
    let (labels, d1, d2) = native_assign(&data, &centers);

    assert_eq!(out.labels.len(), 1500);
    let mut label_mismatch = 0;
    for i in 0..1500 {
        if out.labels[i] != labels[i] {
            label_mismatch += 1;
        }
        // The kernel uses the expanded form ||x||^2 + ||c||^2 - 2<x,c> in
        // f32 (the accelerator-native formulation): the absolute error of
        // a *distance* scales with ||x|| * sqrt(f32_eps), not with d1.
        let xnorm = covermeans::data::matrix::dist(
            data.row(i),
            &vec![0.0; data.cols()],
        );
        let tol = 2e-3 * (1.0 + xnorm + d1[i]);
        assert!(
            (out.d1[i] - d1[i]).abs() <= tol,
            "d1[{i}]: xla {} native {} (tol {tol})",
            out.d1[i],
            d1[i]
        );
        assert!(
            (out.d2[i] - d2[i]).abs() <= tol,
            "d2[{i}]: xla {} native {} (tol {tol})",
            out.d2[i],
            d2[i]
        );
    }
    // f32 vs f64 may flip near-equidistant points; must be very rare.
    assert!(label_mismatch <= 2, "{label_mismatch} label mismatches");

    // Partial sums/counts must aggregate to the native assignment.
    let total: f64 = out.counts.iter().sum();
    assert!((total - 1500.0).abs() < 1e-6);
    let mut native_counts = vec![0.0f64; 7];
    for &l in &labels {
        native_counts[l as usize] += 1.0;
    }
    for c in 0..7 {
        assert!(
            (out.counts[c] - native_counts[c]).abs() <= label_mismatch as f64,
            "count[{c}]: xla {} native {}",
            out.counts[c],
            native_counts[c]
        );
    }
}

#[test]
fn xla_weighted_assign_drops_zero_weight_rows() {
    let Some(mut exec) = executor_or_skip() else { return };
    let data = synth::gaussian_blobs(300, 3, 4, 0.5, 7);
    let mut dc = DistCounter::new();
    let centers = init::kmeans_plus_plus(&data, 4, 5, &mut dc);
    let mut weights = vec![1.0f64; 300];
    for w in weights.iter_mut().skip(150) {
        *w = 0.0;
    }
    let out = exec
        .assign_weighted(&data, Some(&weights), &centers)
        .expect("assign");
    let total: f64 = out.counts.iter().sum();
    assert!((total - 150.0).abs() < 1e-6, "total weight {total}");
    // labels still produced for all rows
    assert_eq!(out.labels.len(), 300);
}

#[test]
fn lloyd_xla_matches_native_lloyd() {
    let Some(mut exec) = executor_or_skip() else { return };
    let data = synth::gaussian_blobs(800, 6, 5, 0.4, 11);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, 5, 9, &mut dc);
    let params = KMeansParams::with_algorithm(Algorithm::Standard);

    let r_native = lloyd::run(&data, &init_c, &params);
    let r_xla = lloyd_xla(&data, &init_c, &params, &mut exec).expect("lloyd_xla");

    // Well-separated blobs: identical clustering and iteration count.
    assert_eq!(r_xla.labels, r_native.labels);
    assert_eq!(r_xla.iterations, r_native.iterations);
    assert_eq!(r_xla.distances, r_native.distances, "semantic counting");
    let sse_n = r_native.sse(&data);
    let sse_x = r_xla.sse(&data);
    assert!(
        (sse_n - sse_x).abs() <= 1e-3 * (1.0 + sse_n),
        "sse native {sse_n} vs xla {sse_x}"
    );
}

#[test]
fn manifest_shapes_cover_paper_datasets() {
    let Some(exec) = executor_or_skip() else { return };
    // Every paper dataset dimension and the k sweep range must be covered.
    for d in [2usize, 10, 27, 30, 50, 54, 64, 74] {
        for k in [10usize, 100, 400, 1000] {
            assert!(
                exec.manifest().pick(d, k).is_some(),
                "no artifact covers d={d} k={k}"
            );
        }
    }
}
