//! The central property of the paper's algorithm family: every accelerated
//! variant replicates the Standard algorithm **exactly** — same assignment
//! sequence, same iteration count, same final centers — on generic
//! (continuous) data. Randomized over datasets, dimensions, k, and seeds
//! via the in-tree property harness.

use covermeans::data::{synth, Matrix};
use covermeans::kmeans::{self, init, Algorithm, KMeansParams, Workspace};
use covermeans::metrics::DistCounter;
use covermeans::rng::Rng;
use covermeans::testutil::{check, usize_in, Config};
use covermeans::tree::CoverTreeParams;

fn random_dataset(rng: &mut Rng) -> Matrix {
    match rng.below(5) {
        0 => {
            let n = usize_in(rng, 100, 600);
            let d = usize_in(rng, 1, 16);
            let k = usize_in(rng, 2, 8);
            synth::gaussian_blobs(n, d, k, 0.1 + rng.f64() * 2.0, rng.next_u64())
        }
        1 => synth::istanbul(0.0005 + rng.f64() * 0.001, rng.next_u64()),
        2 => synth::mnist(usize_in(rng, 5, 20), 0.003, rng.next_u64()),
        3 => synth::kdd04(0.001, rng.next_u64()),
        _ => synth::traffic(0.00003, rng.next_u64()),
    }
}

fn check_all_match(data: &Matrix, k: usize, seed: u64, params: &KMeansParams) {
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(data, k, seed, &mut dc);
    let lloyd_params = KMeansParams { algorithm: Algorithm::Standard, ..*params };
    let reference = kmeans::run(data, &init_c, &lloyd_params, &mut Workspace::new());

    for alg in [
        Algorithm::Elkan,
        Algorithm::Hamerly,
        Algorithm::Exponion,
        Algorithm::Shallot,
        Algorithm::Kanungo,
        Algorithm::CoverMeans,
        Algorithm::Hybrid,
        Algorithm::DualTree,
    ] {
        let p = KMeansParams { algorithm: alg, ..*params };
        let r = kmeans::run(data, &init_c, &p, &mut Workspace::new());
        assert_eq!(
            r.labels,
            reference.labels,
            "{} diverged from Standard (n={}, d={}, k={k})",
            alg.name(),
            data.rows(),
            data.cols()
        );
        assert_eq!(r.iterations, reference.iterations, "{} iterations", alg.name());
        assert_eq!(r.converged, reference.converged, "{} convergence", alg.name());
        for (a, b) in r.centers.as_slice().iter().zip(reference.centers.as_slice()) {
            assert!(
                (a - b).abs() < 1e-9 * (1.0 + b.abs()),
                "{} centers differ",
                alg.name()
            );
        }
    }
}

#[test]
fn all_algorithms_replicate_lloyd() {
    check(Config { cases: 12, seed: 0xEAAC7 }, "exactness", |rng| {
        let data = random_dataset(rng);
        let k = usize_in(rng, 2, 40).min(data.rows() / 2);
        let params = KMeansParams {
            max_iter: 60,
            cover: CoverTreeParams {
                scale_factor: 1.1 + rng.f64() * 0.4,
                min_node_size: usize_in(rng, 1, 150),
            },
            switch_at: usize_in(rng, 1, 10),
            ..KMeansParams::default()
        };
        check_all_match(&data, k, rng.next_u64(), &params);
    });
}

#[test]
fn exactness_with_extreme_tree_params() {
    // Degenerate trees (leaf=1 splits everything; huge leaf = flat tree)
    // must not break exactness.
    for min_node_size in [1usize, 10_000] {
        let data = synth::istanbul(0.001, 99);
        let params = KMeansParams {
            cover: CoverTreeParams { scale_factor: 1.2, min_node_size },
            ..KMeansParams::default()
        };
        check_all_match(&data, 15, 5, &params);
    }
}

#[test]
fn exactness_with_large_scale_factor() {
    let data = synth::mnist(10, 0.004, 7);
    let params = KMeansParams {
        cover: CoverTreeParams { scale_factor: 3.0, min_node_size: 50 },
        ..KMeansParams::default()
    };
    check_all_match(&data, 25, 11, &params);
}

#[test]
fn exactness_k_larger_than_natural_clusters() {
    // k far above the generative cluster count stresses tie-ish regions.
    let data = synth::gaussian_blobs(400, 3, 4, 1.5, 13);
    let params = KMeansParams::default();
    check_all_match(&data, 60, 17, &params);
}

#[test]
fn distance_counts_ordering_holds_on_clustered_data() {
    // The qualitative ordering the paper reports (Table 2): Elkan fewest
    // among bounds algorithms; Shallot <= Exponion <= Hamerly.
    let data = synth::istanbul(0.004, 23);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, 50, 3, &mut dc);
    let mut counts = std::collections::HashMap::new();
    for alg in [
        Algorithm::Standard,
        Algorithm::Elkan,
        Algorithm::Hamerly,
        Algorithm::Exponion,
        Algorithm::Shallot,
    ] {
        let p = KMeansParams { algorithm: alg, ..KMeansParams::default() };
        let r = kmeans::run(&data, &init_c, &p, &mut Workspace::new());
        counts.insert(alg.name(), r.distances);
    }
    assert!(counts["Elkan"] < counts["Standard"]);
    assert!(counts["Shallot"] <= counts["Exponion"]);
    assert!(counts["Exponion"] <= counts["Hamerly"]);
    assert!(counts["Hamerly"] < counts["Standard"]);
}
