//! End-to-end coverage of the serving daemon: concurrent clients get
//! labels byte-identical to offline `model.predict` in every
//! `PredictMode` and over both wire framings; the bounded queue rejects
//! (rather than buffers) when full; hot-reload is swap-on-valid-parse —
//! corrupt and truncated files injected mid-serve never change served
//! output; graceful shutdown drains in-flight work; and the spawned
//! `covermeans serve` binary wires the same behavior through the CLI.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use covermeans::data::{synth, Matrix};
use covermeans::kmeans::{
    Algorithm, KMeans, KMeansModel, PredictMode, PredictOptions,
};
use covermeans::serve::{
    checksum_hex, counter, remote_error, ErrCode, ServeClient, ServeConfig,
    Server,
};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "covermeans_serve_test_{tag}_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A small clustered model plus a disjoint query set.
fn fixture(k: usize, seed: u64) -> (KMeansModel, Matrix) {
    let train = synth::gaussian_blobs(1500, 6, k, 0.8, seed);
    let queries = synth::gaussian_blobs(400, 6, k, 1.2, seed + 1);
    let model = KMeans::new(k)
        .algorithm(Algorithm::Elkan)
        .seed(seed)
        .fit_model(&train)
        .unwrap();
    (model, queries)
}

fn slice_rows(m: &Matrix, lo: usize, hi: usize) -> Matrix {
    let d = m.cols();
    Matrix::from_vec(m.as_slice()[lo * d..hi * d].to_vec(), hi - lo, d)
}

#[test]
fn served_labels_match_offline_in_every_mode() {
    let (model, queries) = fixture(32, 10);
    let dir = tmpdir("modes");
    let path = dir.join("modes.kmm");
    model.save(&path).unwrap();

    // (configured mode, auto cutoff, the mode that must actually answer)
    let cases = [
        (PredictMode::Tree, 64, PredictMode::Tree),
        (PredictMode::Scan, 64, PredictMode::Scan),
        (PredictMode::Auto, 1, PredictMode::Tree), // k=32 >= 1
        (PredictMode::Auto, 1000, PredictMode::Scan), // k=32 < 1000
    ];
    for (mode, auto_k, resolved) in cases {
        let offline = model.predict_opts(
            &queries,
            &PredictOptions { mode, auto_k, ..Default::default() },
        );
        assert_eq!(offline.mode, resolved);

        let cfg = ServeConfig {
            mode,
            auto_k,
            threads: 2,
            ..ServeConfig::for_tests(path.clone())
        };
        let mut server = Server::start(cfg).unwrap();
        let addr = server.addr().to_string();
        let want_hex = checksum_hex(model.checksum());

        // Four concurrent clients, each serving a disjoint query slice,
        // alternating framings. Batches may interleave rows from several
        // connections; per-row answers must not care.
        let mut handles = Vec::new();
        for t in 0..4 {
            let addr = addr.clone();
            let lo = t * 100;
            let q = slice_rows(&queries, lo, lo + 100);
            let want_labels = offline.labels[lo..lo + 100].to_vec();
            let want_dists = offline.distances[lo..lo + 100].to_vec();
            let want_hex = want_hex.clone();
            handles.push(thread::spawn(move || {
                let mut c = ServeClient::connect(&addr).unwrap();
                assert_eq!(c.k(), 32);
                assert_eq!(c.dim(), 6);
                for chunk in 0..4 {
                    let part = slice_rows(&q, chunk * 25, (chunk + 1) * 25);
                    let reply = if (t + chunk) % 2 == 0 {
                        c.predict_json(&part).unwrap()
                    } else {
                        c.predict_bin(&part).unwrap()
                    };
                    assert_eq!(
                        reply.labels,
                        want_labels[chunk * 25..(chunk + 1) * 25],
                        "mode {mode:?} auto_k {auto_k} client {t} chunk {chunk}"
                    );
                    for (a, b) in reply
                        .distances
                        .iter()
                        .zip(&want_dists[chunk * 25..(chunk + 1) * 25])
                    {
                        assert_eq!(a.to_bits(), b.to_bits(), "served distances must round-trip bit for bit");
                    }
                    assert_eq!(reply.model, want_hex);
                    if !reply.mode.is_empty() {
                        // BIN replies do not carry the mode string.
                        assert_eq!(reply.mode, resolved.name());
                    }
                }
                c.quit().unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = server.stats_json();
        assert_eq!(counter(&snap, "requests"), Some(16), "{snap}");
        assert_eq!(counter(&snap, "rows"), Some(400), "{snap}");
        assert!(counter(&snap, "batches").unwrap() >= 1);
        server.shutdown().unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn rejects_bad_requests_without_dying() {
    let (model, queries) = fixture(8, 20);
    let dir = tmpdir("badreq");
    let path = dir.join("badreq.kmm");
    model.save(&path).unwrap();
    let mut server = Server::start(ServeConfig::for_tests(path)).unwrap();
    let addr = server.addr().to_string();

    let mut c = ServeClient::connect(&addr).unwrap();

    // Wrong dimensionality → BADDIM, connection stays usable.
    let wrong = Matrix::from_vec(vec![0.0; 9], 3, 3);
    let err = c.predict_json(&wrong).unwrap_err();
    assert_eq!(remote_error(&err).unwrap().code, ErrCode::BadDim);

    // Malformed verb → BADREQ, connection stays usable.
    // (Exercised through a raw socket write below — the typed client
    // cannot emit garbage.)
    let ping = c.ping().unwrap();
    assert_eq!(ping, checksum_hex(model.checksum()));

    // And a real request still answers correctly afterwards.
    let q = slice_rows(&queries, 0, 10);
    let reply = c.predict_bin(&q).unwrap();
    let offline = model.predict_opts(&q, &PredictOptions::default());
    assert_eq!(reply.labels, offline.labels);
    c.quit().unwrap();

    // Raw garbage lines: unknown verb, broken JSON, bad BIN header.
    use std::io::{BufRead, BufReader, Write};
    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
    raw.write_all(b"CMSERVE 1\n").unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK covermeans-serve 1 "), "{line:?}");
    for bad in ["FROBNICATE\n", "{\"rows\":[[1,2],[3]]}\n", "BIN 0 6\n"] {
        raw.write_all(bad.as_bytes()).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR BADREQ "), "{bad:?} -> {line:?}");
    }
    // Version mismatch on a fresh connection → ERR PROTO.
    let mut raw2 = std::net::TcpStream::connect(&addr).unwrap();
    raw2.write_all(b"CMSERVE 99\n").unwrap();
    let mut reader2 = BufReader::new(raw2);
    line.clear();
    reader2.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR PROTO "), "{line:?}");

    server.shutdown().unwrap();
}

#[test]
fn full_queue_rejects_with_retryable_code() {
    let (model, queries) = fixture(16, 30);
    let dir = tmpdir("backpressure");
    let path = dir.join("bp.kmm");
    model.save(&path).unwrap();
    // Depth-1 queue, one job coalesced per pass: the batcher becomes the
    // bottleneck as soon as a handful of clients fire at once. A full
    // queue must answer `ERR RETRY`, never buffer without bound.
    let cfg = ServeConfig {
        queue_depth: 1,
        max_batch: 1,
        batch_wait_us: 0,
        mode: PredictMode::Scan,
        ..ServeConfig::for_tests(path)
    };
    let mut server = Server::start(cfg).unwrap();
    let addr = server.addr().to_string();

    // All clients send the same 200-row request; the expected labels are
    // one fixed vector. 200 rows per pass keeps the batcher busy long
    // enough for concurrent senders to collide with the depth-1 queue.
    let q = slice_rows(&queries, 0, 200);
    let offline = model.predict_opts(
        &q,
        &PredictOptions { mode: PredictMode::Scan, ..Default::default() },
    );
    let served = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));
    // Exact queue timing depends on the host, so hammer in bounded
    // rounds until a reject is observed; correctness of every served
    // reply is asserted unconditionally. Eight clients racing a depth-1
    // queue make a reject-free round vanishingly unlikely, and one round
    // is normally enough.
    for _round in 0..20 {
        let mut handles = Vec::new();
        for _t in 0..8 {
            let addr = addr.clone();
            let q = q.clone();
            let want = offline.labels.clone();
            let served = served.clone();
            let rejected = rejected.clone();
            handles.push(thread::spawn(move || {
                let mut c = ServeClient::connect(&addr).unwrap();
                for _i in 0..25 {
                    match c.predict_bin(&q) {
                        Ok(reply) => {
                            assert_eq!(reply.labels, want);
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => {
                            let remote = remote_error(&e).unwrap_or_else(|| {
                                panic!("non-protocol failure: {e:#}")
                            });
                            assert_eq!(remote.code, ErrCode::Retry, "{remote}");
                            assert!(remote.is_retryable());
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                c.quit().unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        if rejected.load(Ordering::Relaxed) > 0 {
            break;
        }
    }
    let ok = served.load(Ordering::Relaxed);
    let no = rejected.load(Ordering::Relaxed);
    assert!(ok > 0, "some requests must get through");
    assert!(
        no > 0,
        "clients hammering a depth-1 queue must trip backpressure"
    );
    let snap = server.stats_json();
    assert_eq!(counter(&snap, "queue_full_rejects"), Some(no as u64), "{snap}");
    assert_eq!(counter(&snap, "requests"), Some(ok as u64), "{snap}");
    server.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hot_reload_swaps_only_on_valid_parse() {
    let (model_a, queries) = fixture(16, 40);
    // Same dimensionality, different centers: labels will differ.
    let (model_b, _) = fixture(16, 41);
    let dir = tmpdir("reload");
    let path = dir.join("live.kmm");
    model_a.save(&path).unwrap();
    let good_a = std::fs::read(&path).unwrap();

    let mut server =
        Server::start(ServeConfig::for_tests(path.clone())).unwrap();
    let addr = server.addr().to_string();
    let hex_a = checksum_hex(model_a.checksum());
    let hex_b = checksum_hex(model_b.checksum());
    assert_ne!(hex_a, hex_b);

    let q = slice_rows(&queries, 0, 50);
    let offline_a = model_a.predict_opts(&q, &PredictOptions::default());
    let offline_b = model_b.predict_opts(&q, &PredictOptions::default());
    assert_ne!(
        offline_a.labels, offline_b.labels,
        "fixture models must disagree for the swap to be observable"
    );

    let mut c = ServeClient::connect(&addr).unwrap();
    assert_eq!(c.model(), hex_a);
    let reply = c.predict_json(&q).unwrap();
    assert_eq!(reply.labels, offline_a.labels);
    assert_eq!(reply.model, hex_a);

    // Inject the corrupt/truncated fixtures mid-serve: every reload
    // attempt must fail AND the daemon must keep answering from the old
    // model with the old version tag.
    let mut flipped = good_a.clone();
    flipped[good_a.len() / 2] ^= 0x01;
    let injections: Vec<(&str, Vec<u8>)> = vec![
        ("empty", Vec::new()),
        ("inside the magic", good_a[..2].to_vec()),
        ("half the file", good_a[..good_a.len() / 2].to_vec()),
        ("checksum clipped", good_a[..good_a.len() - 4].to_vec()),
        ("bit flip", flipped),
    ];
    for (what, bytes) in &injections {
        std::fs::write(&path, bytes).unwrap();
        let err = c.reload().unwrap_err();
        let remote = remote_error(&err)
            .unwrap_or_else(|| panic!("{what}: non-protocol failure: {err:#}"));
        assert_eq!(remote.code, ErrCode::Reload, "{what}: {remote}");

        let reply = c.predict_json(&q).unwrap();
        assert_eq!(reply.labels, offline_a.labels, "{what} changed served labels");
        assert_eq!(reply.model, hex_a, "{what} changed the version tag");
        assert_eq!(c.ping().unwrap(), hex_a);
    }

    // A valid file swaps cleanly and atomically.
    model_b.save(&path).unwrap();
    let new_tag = c.reload().unwrap();
    assert_eq!(new_tag, hex_b);
    let reply = c.predict_json(&q).unwrap();
    assert_eq!(reply.labels, offline_b.labels);
    for (a, b) in reply.distances.iter().zip(&offline_b.distances) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert_eq!(reply.model, hex_b);

    // Checkpoint-style generation fallback: save again so `.prev` holds a
    // good generation, then corrupt the primary in place. The reload
    // serves the retained generation instead of failing.
    model_b.save(&path).unwrap();
    std::fs::write(&path, &good_a[..good_a.len() / 3]).unwrap();
    let tag = c.reload().unwrap();
    assert_eq!(tag, hex_b, "fallback must serve the retained generation");
    let reply = c.predict_json(&q).unwrap();
    assert_eq!(reply.labels, offline_b.labels);
    assert_eq!(reply.model, hex_b);

    let snap = c.stats_json().unwrap();
    assert_eq!(counter(&snap, "reload_fail"), Some(injections.len() as u64));
    assert_eq!(counter(&snap, "reload_ok"), Some(1));
    assert_eq!(counter(&snap, "reload_fallback"), Some(1));
    c.quit().unwrap();
    server.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn graceful_shutdown_drains_and_stops_listening() {
    let (model, queries) = fixture(8, 50);
    let dir = tmpdir("shutdown");
    let path = dir.join("shutdown.kmm");
    model.save(&path).unwrap();
    let mut server = Server::start(ServeConfig::for_tests(path)).unwrap();
    let addr = server.addr().to_string();

    let q = slice_rows(&queries, 0, 20);
    let offline = model.predict_opts(&q, &PredictOptions::default());
    let mut c = ServeClient::connect(&addr).unwrap();
    let reply = c.predict_bin(&q).unwrap();
    assert_eq!(reply.labels, offline.labels);

    // The SHUTDOWN verb answers BYE, then the daemon drains and exits.
    let quitter = ServeClient::connect(&addr).unwrap();
    quitter.shutdown_server().unwrap();
    let start = Instant::now();
    server.wait().unwrap();
    assert!(
        start.elapsed() < Duration::from_secs(10),
        "drain must be bounded"
    );

    // The listener is gone: a fresh connection must fail (allow a beat
    // for the OS to tear the socket down).
    thread::sleep(Duration::from_millis(50));
    assert!(
        std::net::TcpStream::connect_timeout(
            &addr.parse().unwrap(),
            Duration::from_millis(500),
        )
        .is_err(),
        "daemon must stop accepting after shutdown"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The CLI path: spawn the real binary, parse its `listening` line, and
/// exercise predict + RELOAD + SHUTDOWN over the wire.
#[test]
fn spawned_binary_serves_reloads_and_shuts_down() {
    use std::io::{BufRead, BufReader};
    use std::process::{Command, Stdio};

    let (model, queries) = fixture(16, 60);
    let dir = tmpdir("spawn");
    let path = dir.join("spawn.kmm");
    model.save(&path).unwrap();

    let mut child = Command::new(env!("CARGO_BIN_EXE_covermeans"))
        .args([
            "serve",
            "--model",
            path.to_str().unwrap(),
            "--addr",
            "127.0.0.1:0",
            "--max_batch",
            "256",
            "--queue_depth",
            "32",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn covermeans serve");
    let stdout = child.stdout.take().unwrap();
    let mut lines = BufReader::new(stdout).lines();
    let listening = lines
        .next()
        .expect("daemon must announce its address")
        .unwrap();
    let addr = listening
        .strip_prefix("listening ")
        .unwrap_or_else(|| panic!("bad announce line {listening:?}"))
        .to_string();

    let q = slice_rows(&queries, 0, 30);
    let offline = model.predict_opts(&q, &PredictOptions::default());
    let mut c = ServeClient::connect(&addr).unwrap();
    let reply = c.predict_json(&q).unwrap();
    assert_eq!(reply.labels, offline.labels);
    assert_eq!(reply.model, checksum_hex(model.checksum()));
    let tag = c.reload().unwrap();
    assert_eq!(tag, checksum_hex(model.checksum()));
    c.quit().unwrap();

    let quitter = ServeClient::connect(&addr).unwrap();
    quitter.shutdown_server().unwrap();
    let status = child.wait().expect("daemon must exit after SHUTDOWN");
    assert!(status.success(), "graceful shutdown must exit 0: {status:?}");
    std::fs::remove_dir_all(&dir).ok();
}
