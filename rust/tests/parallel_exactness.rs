//! The determinism contract of the intra-fit parallel layer: for every
//! algorithm, a fit with `threads ∈ {2, 4}` must be **byte-identical** to
//! the same fit with `threads = 1` — same assignments, same iteration
//! count, same counted `distances`, same centers bit for bit, same
//! inertia. The reductions in `covermeans::parallel` are designed to make
//! this hold exactly (integer tallies, canonical-order center sums,
//! thread-count-independent tree task decomposition); these tests pin it
//! — now including the k-d-tree drivers (Kanungo, Pelleg-Moore), the
//! MiniBatch runner, k-means++ seeding, and pool reuse across fits. CI
//! additionally runs this suite in release mode so the contract is
//! verified under full optimization.

use covermeans::data::{synth, Matrix};
use covermeans::kmeans::{init, Algorithm, KMeans, KMeansParams, Workspace};
use covermeans::metrics::{DistCounter, RunResult};
use covermeans::parallel::Parallelism;
use covermeans::tree::covertree::Node;
use covermeans::tree::{CoverTree, CoverTreeParams};

fn fit_with_threads(
    data: &Matrix,
    init_c: &Matrix,
    alg: Algorithm,
    threads: usize,
) -> RunResult {
    KMeans::new(init_c.rows())
        .algorithm(alg)
        .threads(threads)
        .max_iter(60)
        .warm_start(init_c.clone())
        .fit(data)
        .unwrap()
}

fn assert_identical(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.labels, b.labels, "{what}: labels diverged");
    assert_eq!(a.iterations, b.iterations, "{what}: iterations");
    assert_eq!(a.converged, b.converged, "{what}: convergence");
    assert_eq!(a.distances, b.distances, "{what}: counted distances");
    assert_eq!(a.build_dist, b.build_dist, "{what}: build distances");
    let ca = a.centers.as_slice();
    let cb = b.centers.as_slice();
    assert_eq!(ca.len(), cb.len(), "{what}: center shape");
    for (i, (x, y)) in ca.iter().zip(cb).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: center value {i}");
    }
}

fn datasets() -> Vec<(Matrix, usize, u64)> {
    vec![
        // (data, k, init seed): clustered geo data, generic blobs, and
        // higher-dimensional digits — the synthetic families the
        // exactness suite uses.
        (synth::istanbul(0.001, 31), 20, 7),
        (synth::gaussian_blobs(700, 4, 6, 1.0, 32), 6, 8),
        (synth::mnist(10, 0.005, 33), 12, 9),
    ]
}

#[test]
fn every_exact_algorithm_is_thread_invariant() {
    for (data, k, seed) in datasets() {
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, k, seed, &mut dc);
        for alg in Algorithm::EXTENDED {
            if !alg.is_exact() {
                continue; // MiniBatch: covered separately below
            }
            let r1 = fit_with_threads(&data, &init_c, alg, 1);
            for threads in [2usize, 4] {
                let rt = fit_with_threads(&data, &init_c, alg, threads);
                assert_identical(
                    &rt,
                    &r1,
                    &format!("{} (threads={threads}, n={})", alg.name(), data.rows()),
                );
                assert_eq!(
                    rt.sse(&data).to_bits(),
                    r1.sse(&data).to_bits(),
                    "{}: inertia (threads={threads})",
                    alg.name()
                );
            }
        }
    }
}

#[test]
fn minibatch_is_thread_invariant() {
    // MiniBatch shards its per-step batch assignment over the pool; the
    // sampling stream is seed-driven and drawn up front, and the online
    // updates replay in batch order, so every thread count must reproduce
    // the sequential trajectory byte for byte.
    let data = synth::gaussian_blobs(500, 3, 4, 0.6, 40);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, 4, 11, &mut dc);
    let r1 = fit_with_threads(&data, &init_c, Algorithm::MiniBatch, 1);
    for threads in [2usize, 4] {
        let rt = fit_with_threads(&data, &init_c, Algorithm::MiniBatch, threads);
        assert_eq!(r1.labels, rt.labels, "threads={threads}");
        assert_eq!(r1.iterations, rt.iterations, "threads={threads}");
        assert_eq!(r1.distances, rt.distances, "threads={threads}");
        for (i, (a, b)) in r1
            .centers
            .as_slice()
            .iter()
            .zip(rt.centers.as_slice())
            .enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "center value {i} threads={threads}");
        }
    }
}

#[test]
fn kmeans_plus_plus_seeding_is_thread_invariant() {
    // Seeding shards its d2/near updates and prunes point-side distance
    // evaluations via the triangle inequality; both must leave the chosen
    // centers AND the counted init distances byte-identical at every
    // thread count.
    for (data, k, seed) in datasets() {
        let mut d1 = DistCounter::new();
        let seq = Parallelism::sequential();
        let c1 = init::kmeans_plus_plus_par(&data, k, seed, &mut d1, &seq);
        for threads in [2usize, 4] {
            let par = Parallelism::new(threads);
            let mut dt = DistCounter::new();
            let ct = init::kmeans_plus_plus_par(&data, k, seed, &mut dt, &par);
            assert_eq!(
                dt.count(),
                d1.count(),
                "init distances (threads={threads}, n={})",
                data.rows()
            );
            let a = c1.as_slice();
            let b = ct.as_slice();
            assert_eq!(a.len(), b.len());
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "seed center value {i} (threads={threads})"
                );
            }
        }
    }
}

#[test]
fn model_predict_is_thread_invariant() {
    // The serving pass shards query rows over the pool; labels, distances,
    // and counted evaluations must be byte-identical at every thread
    // count, in both query strategies, matching the contract of the fit
    // passes.
    use covermeans::kmeans::{PredictMode, PredictOptions};
    let train = synth::istanbul(0.002, 91);
    let queries = synth::istanbul(0.001, 92);
    let model = KMeans::new(64)
        .algorithm(Algorithm::Hybrid)
        .seed(17)
        .max_iter(40)
        .fit_model(&train)
        .unwrap();
    for mode in [PredictMode::Tree, PredictMode::Scan] {
        let p1 = model.predict_opts(
            &queries,
            &PredictOptions { mode, ..Default::default() },
        );
        assert_eq!(p1.mode, mode);
        for threads in [2usize, 4] {
            let pt = model.predict_opts(
                &queries,
                &PredictOptions { mode, threads, ..Default::default() },
            );
            assert_eq!(
                pt.labels, p1.labels,
                "{}: labels diverged (threads={threads})",
                mode.name()
            );
            assert_eq!(
                pt.query_evals, p1.query_evals,
                "{}: counted evaluations (threads={threads})",
                mode.name()
            );
            for (i, (a, b)) in pt.distances.iter().zip(&p1.distances).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{}: distance {i} (threads={threads})",
                    mode.name()
                );
            }
        }
    }
}

#[test]
fn pool_reuse_across_fits_matches_fresh_pools() {
    // Two sequential fits driven through one Workspace (one persistent
    // pool, trees cleared between runs) must equal two fits with fresh
    // pools — the pool carries no state between batches.
    let data = synth::istanbul(0.001, 90);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, 15, 5, &mut dc);
    for alg in [Algorithm::Kanungo, Algorithm::Hybrid, Algorithm::DualTree] {
        let fresh_a = fit_with_threads(&data, &init_c, alg, 4);
        let fresh_b = fit_with_threads(&data, &init_c, alg, 4);
        assert_identical(&fresh_b, &fresh_a, &format!("{} fresh/fresh", alg.name()));

        let mut ws = Workspace::new();
        let shared_a = KMeans::new(init_c.rows())
            .algorithm(alg)
            .threads(4)
            .max_iter(60)
            .warm_start(init_c.clone())
            .fit_with(&data, &mut ws)
            .unwrap();
        ws.clear_trees(); // rebuild the tree, keep the pool
        let shared_b = KMeans::new(init_c.rows())
            .algorithm(alg)
            .threads(4)
            .max_iter(60)
            .warm_start(init_c.clone())
            .fit_with(&data, &mut ws)
            .unwrap();
        assert_identical(
            &shared_a,
            &fresh_a,
            &format!("{} pooled fit 1", alg.name()),
        );
        assert_identical(
            &shared_b,
            &fresh_b,
            &format!("{} pooled fit 2 (reused pool)", alg.name()),
        );
    }
}

fn assert_same_tree(a: &Node, b: &Node) {
    assert_eq!(a.routing, b.routing);
    assert_eq!(a.weight, b.weight);
    assert_eq!(a.parent_dist.to_bits(), b.parent_dist.to_bits());
    assert_eq!(a.radius.to_bits(), b.radius.to_bits());
    assert_eq!(a.singletons.len(), b.singletons.len());
    for ((ia, da), (ib, db)) in a.singletons.iter().zip(&b.singletons) {
        assert_eq!(ia, ib);
        assert_eq!(da.to_bits(), db.to_bits());
    }
    assert_eq!(a.sum.len(), b.sum.len());
    for (x, y) in a.sum.iter().zip(&b.sum) {
        assert_eq!(x.to_bits(), y.to_bits(), "aggregate sums must match bitwise");
    }
    assert_eq!(a.children.len(), b.children.len());
    for (ca, cb) in a.children.iter().zip(&b.children) {
        assert_same_tree(ca, cb);
    }
}

#[test]
fn cover_tree_build_is_thread_invariant() {
    for (scale_factor, min_node_size) in [(1.2, 100), (1.3, 10)] {
        let data = synth::istanbul(0.003, 50);
        let params = CoverTreeParams { scale_factor, min_node_size };
        let t1 = CoverTree::build_with_threads(&data, params, 1);
        for threads in [2usize, 4] {
            let tn = CoverTree::build_with_threads(&data, params, threads);
            assert_eq!(tn.node_count, t1.node_count, "threads={threads}");
            assert_eq!(tn.singleton_count, t1.singleton_count, "threads={threads}");
            assert_eq!(
                tn.build_distances, t1.build_distances,
                "counted build distances must not depend on threads={threads}"
            );
            assert_same_tree(&tn.root, &t1.root);
        }
    }
}

#[test]
fn zero_threads_means_auto_and_stays_exact() {
    let data = synth::gaussian_blobs(400, 3, 5, 0.8, 60);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, 5, 13, &mut dc);
    let r1 = fit_with_threads(&data, &init_c, Algorithm::Hybrid, 1);
    let r_auto = fit_with_threads(&data, &init_c, Algorithm::Hybrid, 0);
    assert_identical(&r_auto, &r1, "Hybrid (threads=0 auto)");
}

#[test]
fn legacy_run_shim_routes_fit_threads() {
    // The flat-params path must honor `threads` too (config `fit_threads`).
    let data = synth::istanbul(0.0008, 70);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, 15, 3, &mut dc);
    let seq = KMeansParams {
        algorithm: Algorithm::CoverMeans,
        ..KMeansParams::default()
    };
    let par = KMeansParams { threads: 4, ..seq };
    let r_seq = covermeans::kmeans::run(
        &data,
        &init_c,
        &seq,
        &mut covermeans::kmeans::Workspace::new(),
    );
    let r_par = covermeans::kmeans::run(
        &data,
        &init_c,
        &par,
        &mut covermeans::kmeans::Workspace::new(),
    );
    assert_identical(&r_par, &r_seq, "CoverMeans via kmeans::run");
}
