//! Property suite for the distance-kernel layer (docs/GUIDE.md "Distance
//! kernels"): the dispatched SIMD kernels must match the scalar reference
//! **bit for bit** across dimensions and pathological values, the batched
//! scans must match their per-row references, and f32 serving must return
//! the same labels and distance bits as f64 serving. CI runs this binary
//! twice — once under the host's default dispatch and once with
//! `COVERMEANS_FORCE_SCALAR=1` — so both sides of every identity are
//! exercised on the same machine.

use std::time::Duration;

use covermeans::data::{synth, Matrix};
use covermeans::kernels::{self, scalar, Dispatch};
use covermeans::kmeans::{
    Algorithm, KMeans, KMeansModel, PredictMode, PredictOptions,
    PredictPrecision,
};
use covermeans::metrics::{IterationLog, RunResult};

/// Dependency-free xorshift64* — deterministic fixtures, no `rand`.
struct XorShift(u64);

impl XorShift {
    fn new(seed: u64) -> XorShift {
        XorShift(seed | 1)
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    /// Uniform in [0, 1).
    fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Signed value spanning ~24 decades of magnitude (squares and sums
    /// stay finite in f64).
    fn value(&mut self) -> f64 {
        let sign = if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
        let mag = 10f64.powf(self.uniform() * 24.0 - 12.0);
        sign * self.uniform() * mag
    }

    fn vector(&mut self, d: usize) -> Vec<f64> {
        (0..d).map(|_| self.value()).collect()
    }
}

// ----- SIMD == scalar, bit for bit --------------------------------------

#[test]
fn dispatched_sqdist_matches_scalar_bits_across_dims() {
    let mut rng = XorShift::new(0xC0FFEE);
    for d in 0..=67usize {
        for trial in 0..4 {
            let a = rng.vector(d);
            let b = rng.vector(d);
            assert_eq!(
                kernels::sqdist(&a, &b).to_bits(),
                scalar::sqdist(&a, &b).to_bits(),
                "sqdist d={d} trial={trial} dispatch={}",
                kernels::active_name()
            );
            assert_eq!(
                kernels::dist(&a, &b).to_bits(),
                scalar::sqdist(&a, &b).sqrt().to_bits(),
                "dist d={d} trial={trial}"
            );
            let af: Vec<f32> = a.iter().map(|&v| v as f32).collect();
            let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            assert_eq!(
                kernels::sqdist_f32(&af, &bf).to_bits(),
                scalar::sqdist_f32(&af, &bf).to_bits(),
                "sqdist_f32 d={d} trial={trial}"
            );
        }
    }
}

#[test]
fn dispatched_sqdist_matches_scalar_on_pathological_values() {
    // Subnormals, signed zeros, and magnitudes near the overflow edge of
    // the squared sum; every lane position gets every pathological value
    // as d ranges over lane offsets.
    let pool: [f64; 10] = [
        0.0,
        -0.0,
        f64::MIN_POSITIVE,        // smallest normal
        -f64::MIN_POSITIVE,
        f64::MIN_POSITIVE / 8.0,  // subnormal
        -f64::MIN_POSITIVE / 2.0, // subnormal
        1e150,
        -1e150,
        1.5e-300,
        1.0 + f64::EPSILON,
    ];
    for d in 0..=23usize {
        for shift in 0..pool.len() {
            let a: Vec<f64> =
                (0..d).map(|i| pool[(i + shift) % pool.len()]).collect();
            let b: Vec<f64> =
                (0..d).map(|i| pool[(i + shift + 3) % pool.len()]).collect();
            assert_eq!(
                kernels::sqdist(&a, &b).to_bits(),
                scalar::sqdist(&a, &b).to_bits(),
                "d={d} shift={shift}"
            );
        }
    }
    // Empty rows are a defined case: distance zero.
    assert_eq!(kernels::sqdist(&[], &[]).to_bits(), 0f64.to_bits());
    // f32 pathological pool, same idea (1e18 squares without overflow).
    let pool32: [f32; 8] = [
        0.0,
        -0.0,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE / 4.0, // subnormal
        1e18,
        -1e18,
        1.0 + f32::EPSILON,
        1.5e-42, // subnormal
    ];
    for d in 0..=19usize {
        for shift in 0..pool32.len() {
            let a: Vec<f32> =
                (0..d).map(|i| pool32[(i + shift) % pool32.len()]).collect();
            let b: Vec<f32> =
                (0..d).map(|i| pool32[(i + shift + 5) % pool32.len()]).collect();
            assert_eq!(
                kernels::sqdist_f32(&a, &b).to_bits(),
                scalar::sqdist_f32(&a, &b).to_bits(),
                "f32 d={d} shift={shift}"
            );
        }
    }
}

// ----- batched scans == per-row references ------------------------------

/// The historical per-row loop `argmin2` must reproduce exactly:
/// independent `sqrt(sqdist)` per row, strict `<` updates (lowest index
/// wins ties).
fn argmin2_reference(q: &[f64], centers: &Matrix) -> (u32, f64, u32, f64) {
    let (mut c1, mut d1, mut c2, mut d2) = (0u32, f64::INFINITY, 0u32, f64::INFINITY);
    for i in 0..centers.rows() {
        let dd = kernels::sqdist(q, centers.row(i)).sqrt();
        if dd < d1 {
            c2 = c1;
            d2 = d1;
            c1 = i as u32;
            d1 = dd;
        } else if dd < d2 {
            c2 = i as u32;
            d2 = dd;
        }
    }
    (c1, d1, c2, d2)
}

#[test]
fn argmin2_matches_per_row_reference() {
    let mut rng = XorShift::new(0xBADD_ECAF);
    for &k in &[1usize, 2, 3, 7, 8, 9, 64, 129] {
        for &d in &[1usize, 3, 8, 17] {
            let mut centers = Matrix::zeros(k, d);
            for i in 0..k {
                let row = rng.vector(d);
                centers.row_mut(i).copy_from_slice(&row);
            }
            for _ in 0..5 {
                let q = rng.vector(d);
                let got = kernels::argmin2(&q, &centers);
                let want = argmin2_reference(&q, &centers);
                assert_eq!(got.0, want.0, "c1 k={k} d={d}");
                assert_eq!(got.1.to_bits(), want.1.to_bits(), "d1 k={k} d={d}");
                assert_eq!(got.2, want.2, "c2 k={k} d={d}");
                assert_eq!(got.3.to_bits(), want.3.to_bits(), "d2 k={k} d={d}");
            }
        }
    }
}

#[test]
fn argmin2_breaks_ties_toward_lowest_index() {
    // Rows 2 and 5 are identical and nearest: c1 must be 2, c2 must be 5.
    let mut centers = Matrix::zeros(7, 3);
    for i in 0..7 {
        let v = 10.0 + i as f64;
        centers.row_mut(i).copy_from_slice(&[v, v, v]);
    }
    centers.row_mut(2).copy_from_slice(&[1.0, 2.0, 3.0]);
    centers.row_mut(5).copy_from_slice(&[1.0, 2.0, 3.0]);
    let (c1, d1, c2, d2) = kernels::argmin2(&[1.0, 2.0, 2.0], &centers);
    assert_eq!((c1, c2), (2, 5));
    assert_eq!(d1.to_bits(), d2.to_bits());

    // Same contract in f32 (squared distances).
    let flat: Vec<f32> = centers.as_slice().iter().map(|&v| v as f32).collect();
    let (c1, s1, c2, s2) = kernels::argmin2_f32(&[1.0, 2.0, 2.0], &flat, 3);
    assert_eq!((c1, c2), (2, 5));
    assert_eq!(s1.to_bits(), s2.to_bits());
}

#[test]
fn argmin2_f32_matches_scalar_reference() {
    let mut rng = XorShift::new(0xF00D);
    for &k in &[1usize, 5, 8, 33] {
        for &d in &[1usize, 4, 16, 30] {
            let centers: Vec<f32> =
                (0..k * d).map(|_| rng.value() as f32).collect();
            for _ in 0..4 {
                let q: Vec<f32> = (0..d).map(|_| rng.value() as f32).collect();
                let got = kernels::argmin2_f32(&q, &centers, d);
                let want = scalar::argmin2_f32(&q, &centers, d);
                assert_eq!(got.0, want.0, "k={k} d={d}");
                assert_eq!(got.1.to_bits(), want.1.to_bits(), "k={k} d={d}");
                assert_eq!(got.2, want.2, "k={k} d={d}");
                assert_eq!(got.3.to_bits(), want.3.to_bits(), "k={k} d={d}");
            }
        }
    }
}

#[test]
fn pairwise_upper_matches_rowwise_reference() {
    let mut rng = XorShift::new(0x9E37);
    for &k in &[0usize, 1, 2, 8, 9, 33, 100] {
        let d = 6;
        let mut centers = Matrix::zeros(k, d);
        for i in 0..k {
            let row = rng.vector(d);
            centers.row_mut(i).copy_from_slice(&row);
        }
        let mut got = vec![f64::NAN; k * k];
        let mut emitted = 0usize;
        kernels::pairwise_upper(&centers, |i, j, dd| {
            assert!(i < j && j < k, "pair ({i},{j}) out of range k={k}");
            assert!(got[i * k + j].is_nan(), "pair ({i},{j}) emitted twice");
            got[i * k + j] = dd;
            emitted += 1;
        });
        assert_eq!(emitted, k.saturating_sub(1) * k / 2, "k={k}");
        for i in 0..k {
            for j in (i + 1)..k {
                let want = kernels::sqdist(centers.row(i), centers.row(j)).sqrt();
                assert_eq!(
                    got[i * k + j].to_bits(),
                    want.to_bits(),
                    "pair ({i},{j}) k={k}"
                );
            }
        }
    }
}

// ----- f32 serving == f64 serving ---------------------------------------

fn opts(precision: PredictPrecision, threads: usize) -> PredictOptions {
    PredictOptions {
        mode: PredictMode::Scan,
        threads,
        precision,
        ..PredictOptions::default()
    }
}

#[test]
fn f32_predict_matches_f64_labels_and_distance_bits() {
    let train = synth::gaussian_blobs(1500, 8, 64, 1.0, 97);
    let model = KMeans::new(64).seed(7).fit_model(&train).unwrap();
    let queries = synth::gaussian_blobs(400, 8, 64, 1.0, 98);

    let p64 = model.predict_opts(&queries, &opts(PredictPrecision::F64, 1));
    let p32 = model.predict_opts(&queries, &opts(PredictPrecision::F32, 1));
    assert_eq!(p32.precision, PredictPrecision::F32);
    assert_eq!(p64.f32_fallbacks, 0);
    assert_eq!(p32.labels, p64.labels);
    for (i, (a, b)) in p32.distances.iter().zip(&p64.distances).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "distance bits differ at row {i}");
    }
    // On separated blobs the certificate must do real work: most queries
    // are answered without the exact rescan.
    assert!(
        (p32.f32_fallbacks as usize) < queries.rows() / 2,
        "fallbacks {} of {}",
        p32.f32_fallbacks,
        queries.rows()
    );

    // Thread-count invariance of the batched f32 path: results AND
    // counters are byte-identical at every worker count.
    for threads in [2usize, 4] {
        let pt = model.predict_opts(&queries, &opts(PredictPrecision::F32, threads));
        assert_eq!(pt.labels, p32.labels, "threads={threads}");
        for (a, b) in pt.distances.iter().zip(&p32.distances) {
            assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
        }
        assert_eq!(pt.query_evals, p32.query_evals, "threads={threads}");
        assert_eq!(pt.f32_fallbacks, p32.f32_fallbacks, "threads={threads}");
    }
}

/// A model with exactly the given centers (self-labeled one-point-per-
/// center run — the public `from_run` constructor validates shape only).
fn model_from_centers(centers: Matrix) -> KMeansModel {
    let k = centers.rows();
    let data = centers.clone();
    let run = RunResult {
        labels: (0..k as u32).collect(),
        centers,
        iterations: 1,
        distances: 0,
        build_dist: 0,
        time: Duration::ZERO,
        build_time: Duration::ZERO,
        log: IterationLog::new(),
        converged: true,
    };
    KMeansModel::from_run(&data, &run, Algorithm::Standard, 0)
}

#[test]
fn f32_near_ties_fall_back_and_stay_exact() {
    // Two centers 1e-12 apart: distinct in f64, the *same* point after
    // f32 quantization. The certificate can never separate them, so every
    // query must take the exact-fallback path — and still produce the f64
    // answer, including the lowest-index tie convention.
    let centers = Matrix::from_vec(vec![1.0, 0.0, 1.0 + 1e-12, 0.0], 2, 2);
    let model = model_from_centers(centers);
    let mut rng = XorShift::new(0xABCD);
    let n = 64usize;
    let rows: Vec<f64> = (0..n * 2)
        .map(|_| rng.uniform() * 4.0 - 2.0)
        .collect();
    let queries = Matrix::from_vec(rows, n, 2);

    let p64 = model.predict_opts(&queries, &opts(PredictPrecision::F64, 1));
    let p32 = model.predict_opts(&queries, &opts(PredictPrecision::F32, 1));
    assert_eq!(
        p32.f32_fallbacks, n as u64,
        "every near-tie query must fall back"
    );
    assert_eq!(p32.labels, p64.labels);
    for (a, b) in p32.distances.iter().zip(&p64.distances) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn f32_single_center_never_falls_back() {
    let model = model_from_centers(Matrix::from_vec(vec![0.5, -0.5, 2.0], 1, 3));
    let queries = Matrix::from_vec(vec![1.0, 1.0, 1.0, -3.0, 0.0, 4.0], 2, 3);
    let p32 = model.predict_opts(&queries, &opts(PredictPrecision::F32, 1));
    let p64 = model.predict_opts(&queries, &opts(PredictPrecision::F64, 1));
    assert_eq!(p32.f32_fallbacks, 0, "k=1 has no runner-up to confuse");
    assert_eq!(p32.labels, vec![0, 0]);
    for (a, b) in p32.distances.iter().zip(&p64.distances) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

// ----- dispatch provenance ----------------------------------------------

#[test]
fn dispatch_name_is_reportable_and_escape_hatch_wins() {
    let name = kernels::active_name();
    assert!(
        ["scalar", "avx", "neon"].contains(&name),
        "unknown dispatch name {name:?}"
    );
    if kernels::force_scalar() {
        // The CI forced-scalar leg runs this binary with
        // COVERMEANS_FORCE_SCALAR=1: the escape hatch must actually win.
        assert_eq!(kernels::active(), Dispatch::Scalar);
        assert_eq!(name, "scalar");
    }
}
