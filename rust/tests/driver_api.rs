//! Integration tests of the unified driver API: the fluent [`KMeans`]
//! builder, the stepwise [`Fit`] loop, observers, warm starts, and their
//! byte-for-byte agreement with the legacy free-function shims.

use covermeans::data::synth;
use covermeans::kmeans::{
    self, init, Algorithm, AlgorithmSpec, KMeans, KMeansError, KMeansParams,
    Signal, StepView, Workspace,
};
use covermeans::metrics::DistCounter;

/// The builder must replicate the legacy `kmeans::run` dispatch exactly —
/// same labels, iterations, distance counts — for every exact variant.
#[test]
fn builder_replicates_legacy_dispatch() {
    let data = synth::istanbul(0.0015, 3);
    let k = 15;
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, k, 7, &mut dc);
    for alg in Algorithm::EXTENDED {
        if !alg.is_exact() {
            continue;
        }
        let params = KMeansParams { algorithm: alg, ..KMeansParams::default() };
        let legacy = kmeans::run(&data, &init_c, &params, &mut Workspace::new());
        let fluent = KMeans::new(k)
            .algorithm(alg)
            .warm_start(init_c.clone())
            .fit(&data)
            .unwrap();
        assert_eq!(fluent.labels, legacy.labels, "{}", alg.name());
        assert_eq!(fluent.iterations, legacy.iterations, "{}", alg.name());
        assert_eq!(fluent.distances, legacy.distances, "{}", alg.name());
        assert_eq!(fluent.converged, legacy.converged, "{}", alg.name());
    }
}

/// Stepping by hand visits exactly the iterations `fit` runs, with
/// monotone cumulative distance counts and a consistent final snapshot.
#[test]
fn fit_step_exposes_every_iteration() {
    let data = synth::gaussian_blobs(400, 3, 5, 0.8, 11);
    let k = 5;
    let one_shot = KMeans::new(k)
        .algorithm(Algorithm::CoverMeans)
        .seed(2)
        .fit(&data)
        .unwrap();

    let mut fit = KMeans::new(k)
        .algorithm(Algorithm::CoverMeans)
        .seed(2)
        .fit_step(&data)
        .unwrap();
    let mut iters = 0usize;
    let mut last_dist = 0u64;
    while let Some(info) = fit.step() {
        iters += 1;
        assert_eq!(info.iter, iters);
        assert!(info.distances >= last_dist, "distance counts are cumulative");
        last_dist = info.distances;
        assert_eq!(fit.labels().len(), data.rows());
        assert_eq!(fit.centers().rows(), k);
    }
    assert!(fit.is_done());
    let stepped = fit.finish();
    assert_eq!(iters, one_shot.iterations);
    assert_eq!(stepped.labels, one_shot.labels);
    assert_eq!(stepped.distances, one_shot.distances);
    assert_eq!(stepped.converged, one_shot.converged);
}

/// An observer watching the inertia can stop the run early; the result is
/// a valid (if unconverged) clustering with fewer iterations.
#[test]
fn observer_early_stops_on_inertia_plateau() {
    let data = synth::kdd04(0.001, 9);
    let k = 12;
    let full = KMeans::new(k).algorithm(Algorithm::Shallot).seed(5).fit(&data).unwrap();
    assert!(full.iterations > 3, "need a long run for the plateau to bite");

    let obs_data = data.clone();
    let mut prev = f64::INFINITY;
    let early = KMeans::new(k)
        .algorithm(Algorithm::Shallot)
        .seed(5)
        .observer(move |view: &StepView<'_>| {
            let sse = view.sse(&obs_data);
            let flat = (prev - sse) / prev.max(f64::MIN_POSITIVE) < 1e-3;
            prev = sse;
            if flat && view.info.iter >= 2 { Signal::Stop } else { Signal::Continue }
        })
        .fit(&data)
        .unwrap();
    assert!(early.iterations <= full.iterations);
    assert_eq!(early.labels.len(), data.rows());
    // The early snapshot is a coherent assignment: every label in range.
    assert!(early.labels.iter().all(|&l| (l as usize) < k));
}

/// Warm-starting from a converged solution reconfirms the fixpoint in the
/// minimum number of iterations (1 to reassign, 1 to confirm).
#[test]
fn warm_start_resumes_from_prior_solution() {
    let data = synth::gaussian_blobs(500, 3, 6, 0.5, 21);
    let k = 6;
    let first = KMeans::new(k).algorithm(Algorithm::Hybrid).seed(4).fit(&data).unwrap();
    assert!(first.converged);
    let resumed = KMeans::new(k)
        .algorithm(Algorithm::Hybrid)
        .warm_start(first.centers.clone())
        .fit(&data)
        .unwrap();
    assert!(resumed.converged);
    assert_eq!(resumed.iterations, 2, "converged centers must be a fixpoint");
    assert_eq!(resumed.labels, first.labels);
}

/// Sweep-style center reuse: growing k from a smaller solution via
/// `extend_centers` keeps refining the inertia.
#[test]
fn extend_centers_sweep_monotone_sse() {
    let data = synth::istanbul(0.001, 31);
    let mut ws = Workspace::new();
    let mut prev: Option<covermeans::data::Matrix> = None;
    let mut last_sse = f64::INFINITY;
    for k in [5usize, 10, 20] {
        let mut dc = DistCounter::new();
        let init_c = match prev.as_ref() {
            Some(c) => init::extend_centers(&data, c, k, 17, &mut dc),
            None => init::kmeans_plus_plus(&data, k, 17, &mut dc),
        };
        let r = KMeans::new(k)
            .algorithm(Algorithm::Hybrid)
            .warm_start(init_c)
            .fit_with(&data, &mut ws)
            .unwrap();
        let sse = r.sse(&data);
        assert!(
            sse <= last_sse,
            "k={k}: warm-extended sweep must not regress (sse {sse} > {last_sse})"
        );
        last_sse = sse;
        prev = Some(r.centers.clone());
    }
}

/// Validation failures surface as typed errors, not panics.
#[test]
fn builder_validation_is_result_based() {
    let data = synth::gaussian_blobs(30, 2, 2, 0.5, 1);
    assert!(matches!(KMeans::new(0).fit(&data), Err(KMeansError::ZeroK)));
    assert!(matches!(
        KMeans::new(31).fit(&data),
        Err(KMeansError::KExceedsN { k: 31, n: 30 })
    ));
    let wrong_d = covermeans::data::Matrix::zeros(2, 7);
    assert!(matches!(
        KMeans::new(2).warm_start(wrong_d).fit(&data),
        Err(KMeansError::DimMismatch { expected: 2, got: 7 })
    ));
    let wrong_k = covermeans::data::Matrix::zeros(5, 2);
    assert!(matches!(
        KMeans::new(2).warm_start(wrong_k).fit(&data),
        Err(KMeansError::WarmStartK { expected: 2, got: 5 })
    ));
}

/// Typed per-algorithm knobs actually reach the run.
#[test]
fn algorithm_spec_carries_typed_knobs() {
    let data = synth::istanbul(0.001, 41);
    let k = 10;
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, k, 1, &mut dc);

    // A 1-point min_node_size builds a much deeper tree than the default
    // (100): the two configurations must count differently.
    let deep = KMeans::new(k)
        .algorithm(AlgorithmSpec::CoverMeans {
            cover: covermeans::tree::CoverTreeParams { scale_factor: 1.2, min_node_size: 1 },
        })
        .warm_start(init_c.clone())
        .fit(&data)
        .unwrap();
    let flat = KMeans::new(k)
        .algorithm(Algorithm::CoverMeans)
        .warm_start(init_c.clone())
        .fit(&data)
        .unwrap();
    assert_eq!(deep.labels, flat.labels, "both exact");
    assert_ne!(
        deep.total_distances(),
        flat.total_distances(),
        "tree knobs must change the cost profile"
    );

    // Hybrid switch_at = 1 vs default 7 changes the iteration cost series.
    let sw1 = KMeans::new(k)
        .algorithm(AlgorithmSpec::Hybrid {
            cover: Default::default(),
            switch_at: 1,
        })
        .warm_start(init_c.clone())
        .fit(&data)
        .unwrap();
    let sw7 = KMeans::new(k)
        .algorithm(Algorithm::Hybrid)
        .warm_start(init_c)
        .fit(&data)
        .unwrap();
    assert_eq!(sw1.labels, sw7.labels, "switch point never breaks exactness");
}
