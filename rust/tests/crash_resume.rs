//! Crash/resume fault-injection e2e.
//!
//! Spawns the real `covermeans` binary, kills it mid-fit — with a
//! deterministic abort (`COVERMEANS_CRASH_AFTER_ITER`), a true `kill -9`,
//! and SIGINT — and asserts the two contracts of the checkpoint
//! subsystem:
//!
//! 1. **Resume ≡ uninterrupted**: a crashed-then-resumed fit produces a
//!    bit-identical `.kmm` model and identical iteration/distance/SSE
//!    accounting to a run that was never interrupted, across algorithms
//!    and thread counts.
//! 2. **No torn state**: no injected fault — including a torn checkpoint
//!    write (`COVERMEANS_CRASH_TORN_WRITE`) — ever leaves the checkpoint
//!    path without a loadable generation.
//!
//! All datasets are `blobs:…` (synthesized in-process, no disk cache), so
//! the torn-write injection can only fire at checkpoint/model writes.

use std::path::{Path, PathBuf};
use std::process::{Command, Output, Stdio};
use std::time::{Duration, Instant};

const BIN: &str = env!("CARGO_BIN_EXE_covermeans");

/// Small fit used for the deterministic-abort matrix: big enough to take
/// several Lloyd iterations, small enough to keep an 8-cell matrix fast.
const SMALL: &str = "blobs:600:4:8";
/// Larger fit for the asynchronous kill/signal tests: enough work per
/// iteration that a poll-then-kill lands mid-run on any machine.
const BIG: &str = "blobs:8000:8:16";

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "covermeans_crash_resume_{tag}_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn covermeans(args: &[&str], envs: &[(&str, &str)]) -> Output {
    let mut c = Command::new(BIN);
    c.args(args);
    for (k, v) in envs {
        c.env(k, v);
    }
    c.output().expect("spawn covermeans")
}

/// Base `run` arguments for one fit configuration (no checkpoint flags).
fn fit_args(dataset: &str, k: &str, alg: &str, threads: &str) -> Vec<String> {
    ["run", "--dataset", dataset, "--k", k, "--seed", "5",
     "--algorithm", alg, "--fit_threads", threads]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

fn run_with(base: &[String], extra: &[&str], envs: &[(&str, &str)]) -> Output {
    let mut args: Vec<&str> = base.iter().map(|s| s.as_str()).collect();
    args.extend_from_slice(extra);
    covermeans(&args, envs)
}

fn stdout_line<'a>(out: &'a str, prefix: &str) -> &'a str {
    out.lines()
        .find(|l| l.starts_with(prefix))
        .unwrap_or_else(|| panic!("no {prefix:?} line in stdout:\n{out}"))
}

/// The result lines whose equality certifies "resume ≡ uninterrupted"
/// beyond the byte-compare of the saved model.
const RESULT_LINES: [&str; 3] = ["iterations  :", "distances   :", "sse         :"];

fn assert_same_result(tag: &str, ref_out: &str, res_out: &str) {
    for prefix in RESULT_LINES {
        assert_eq!(
            stdout_line(ref_out, prefix),
            stdout_line(res_out, prefix),
            "{tag}: resumed run diverged on the {prefix:?} line"
        );
    }
}

fn assert_same_model(tag: &str, a: &Path, b: &Path) {
    let wa = std::fs::read(a).unwrap_or_else(|e| panic!("{tag}: read {a:?}: {e}"));
    let wb = std::fs::read(b).unwrap_or_else(|e| panic!("{tag}: read {b:?}: {e}"));
    assert!(!wa.is_empty(), "{tag}: empty reference model");
    assert_eq!(wa, wb, "{tag}: resumed model is not bit-identical to the reference");
}

/// Deterministic crash + resume across the acceptance matrix: Lloyd,
/// Hamerly, CoverMeans, and DualTree, each at 1 and 4 fit threads.
#[test]
fn crash_and_resume_is_bit_identical_across_algorithms_and_threads() {
    let dir = tmpdir("matrix");
    for alg in ["standard", "hamerly", "cover", "dualtree"] {
        for threads in ["1", "4"] {
            let tag = format!("{alg}@{threads}t");
            let base = fit_args(SMALL, "8", alg, threads);
            let ref_model = dir.join(format!("ref_{alg}_{threads}.kmm"));
            let res_model = dir.join(format!("res_{alg}_{threads}.kmm"));
            let ck = dir.join(format!("{alg}_{threads}.kmc"));

            // Uninterrupted reference: no checkpointing involved at all.
            let r = run_with(&base, &["--model_out", ref_model.to_str().unwrap()], &[]);
            assert!(
                r.status.success(),
                "{tag}: reference run failed:\n{}",
                String::from_utf8_lossy(&r.stderr)
            );
            let ref_out = String::from_utf8_lossy(&r.stdout).into_owned();

            // Same fit with per-iteration snapshots, aborted mid-run.
            let c = run_with(
                &base,
                &["--checkpoint_path", ck.to_str().unwrap(), "--checkpoint_every", "1"],
                &[("COVERMEANS_CRASH_AFTER_ITER", "2")],
            );
            assert!(!c.status.success(), "{tag}: injected crash did not kill the run");
            assert!(
                String::from_utf8_lossy(&c.stderr).contains("simulated crash"),
                "{tag}: abort fired without the fault-injection banner"
            );
            assert!(ck.exists(), "{tag}: no snapshot on disk after the crash");

            // Resume and run to completion.
            let r2 = run_with(
                &base,
                &["--checkpoint_path", ck.to_str().unwrap(), "--resume", "1",
                  "--model_out", res_model.to_str().unwrap()],
                &[],
            );
            let stderr = String::from_utf8_lossy(&r2.stderr);
            assert!(r2.status.success(), "{tag}: resume failed:\n{stderr}");
            assert!(
                stderr.contains("resuming"),
                "{tag}: resume did not adopt the snapshot:\n{stderr}"
            );
            assert_same_result(&tag, &ref_out, &String::from_utf8_lossy(&r2.stdout));
            assert_same_model(&tag, &ref_model, &res_model);
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A snapshot taken at one thread count resumes at another with the same
/// bytes: the fingerprint deliberately excludes the thread topology
/// because intra-fit parallelism is exactness-preserving.
#[test]
fn resume_at_a_different_thread_count_stays_bit_identical() {
    let dir = tmpdir("xthread");
    let ref_model = dir.join("ref.kmm");
    let res_model = dir.join("res.kmm");
    let ck = dir.join("x.kmc");

    let r = run_with(
        &fit_args(SMALL, "8", "hamerly", "1"),
        &["--model_out", ref_model.to_str().unwrap()],
        &[],
    );
    assert!(r.status.success());
    let ref_out = String::from_utf8_lossy(&r.stdout).into_owned();

    let c = run_with(
        &fit_args(SMALL, "8", "hamerly", "1"),
        &["--checkpoint_path", ck.to_str().unwrap(), "--checkpoint_every", "1"],
        &[("COVERMEANS_CRASH_AFTER_ITER", "2")],
    );
    assert!(!c.status.success());

    let r2 = run_with(
        &fit_args(SMALL, "8", "hamerly", "4"),
        &["--checkpoint_path", ck.to_str().unwrap(), "--resume", "1",
          "--model_out", res_model.to_str().unwrap()],
        &[],
    );
    assert!(
        r2.status.success(),
        "cross-thread resume failed:\n{}",
        String::from_utf8_lossy(&r2.stderr)
    );
    let res_out = String::from_utf8_lossy(&r2.stdout).into_owned();
    for prefix in ["iterations  :", "sse         :"] {
        assert_eq!(stdout_line(&ref_out, prefix), stdout_line(&res_out, prefix));
    }
    assert_same_model("xthread", &ref_model, &res_model);
    std::fs::remove_dir_all(&dir).ok();
}

/// True `kill -9`: SIGKILL the child as soon as its first snapshot lands,
/// then resume. SIGKILL cannot be caught, so the kill may land anywhere —
/// including inside a later atomic write — and the resumed fit must still
/// find a loadable generation and reproduce the uninterrupted result.
#[test]
fn sigkill_mid_run_resumes_bit_identically() {
    let dir = tmpdir("sigkill");
    let ref_model = dir.join("ref.kmm");
    let res_model = dir.join("res.kmm");
    let ck = dir.join("kill.kmc");
    let base = fit_args(BIG, "32", "hamerly", "2");

    let r = run_with(&base, &["--model_out", ref_model.to_str().unwrap()], &[]);
    assert!(r.status.success());
    let ref_out = String::from_utf8_lossy(&r.stdout).into_owned();

    let mut child = Command::new(BIN)
        .args(base.iter().map(|s| s.as_str()))
        .args(["--checkpoint_path", ck.to_str().unwrap(), "--checkpoint_every", "1"])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn covermeans");
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        // `ck` only exists after a complete rename, so by the time we pull
        // the trigger at least one full generation is on disk.
        if ck.exists() {
            let _ = child.kill(); // SIGKILL
            break;
        }
        if child.try_wait().expect("try_wait").is_some() {
            break; // finished before we saw a snapshot; final snapshot exists
        }
        assert!(Instant::now() < deadline, "no snapshot appeared within 60s");
        std::thread::sleep(Duration::from_micros(200));
    }
    let _ = child.wait();

    let r2 = run_with(
        &base,
        &["--checkpoint_path", ck.to_str().unwrap(), "--resume", "1",
          "--model_out", res_model.to_str().unwrap()],
        &[],
    );
    assert!(
        r2.status.success(),
        "resume after SIGKILL failed (torn state left behind?):\n{}",
        String::from_utf8_lossy(&r2.stderr)
    );
    assert_same_result("sigkill", &ref_out, &String::from_utf8_lossy(&r2.stdout));
    assert_same_model("sigkill", &ref_model, &res_model);
    std::fs::remove_dir_all(&dir).ok();
}

/// SIGINT on a checkpointed run writes a final snapshot and exits 130;
/// `--resume 1` then completes the fit bit-identically. If the fit
/// finishes before the signal lands (fast machine), the child exits 0 and
/// the snapshot is the final one — resume still reproduces the reference.
#[test]
fn sigint_checkpoints_then_exits_130_and_resumes() {
    let dir = tmpdir("sigint");
    let ref_model = dir.join("ref.kmm");
    let res_model = dir.join("res.kmm");
    let ck = dir.join("int.kmc");
    let base = fit_args(BIG, "32", "standard", "2");

    let r = run_with(&base, &["--model_out", ref_model.to_str().unwrap()], &[]);
    assert!(r.status.success());
    let ref_out = String::from_utf8_lossy(&r.stdout).into_owned();

    let mut child = Command::new(BIN)
        .args(base.iter().map(|s| s.as_str()))
        .args(["--checkpoint_path", ck.to_str().unwrap(), "--checkpoint_every", "1"])
        .stdout(Stdio::null())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn covermeans");
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut finished_first = false;
    loop {
        if ck.exists() {
            // `kill` is a shell builtin everywhere; std has no SIGINT sender.
            let st = Command::new("sh")
                .args(["-c", &format!("kill -INT {}", child.id())])
                .status()
                .expect("spawn sh");
            assert!(st.success(), "could not deliver SIGINT");
            break;
        }
        if child.try_wait().expect("try_wait").is_some() {
            finished_first = true;
            break;
        }
        assert!(Instant::now() < deadline, "no snapshot appeared within 60s");
        std::thread::sleep(Duration::from_micros(200));
    }
    let out = child.wait_with_output().expect("wait");
    if !finished_first && !out.status.success() {
        // The interesting branch: the signal landed mid-fit.
        assert_eq!(
            out.status.code(),
            Some(130),
            "SIGINT on a checkpointed run must exit 130, got {:?}",
            out.status
        );
        assert!(
            String::from_utf8_lossy(&out.stderr).contains("interrupted"),
            "no interruption notice on stderr:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
    }

    let r2 = run_with(
        &base,
        &["--checkpoint_path", ck.to_str().unwrap(), "--resume", "1",
          "--model_out", res_model.to_str().unwrap()],
        &[],
    );
    assert!(
        r2.status.success(),
        "resume after SIGINT failed:\n{}",
        String::from_utf8_lossy(&r2.stderr)
    );
    assert_same_result("sigint", &ref_out, &String::from_utf8_lossy(&r2.stdout));
    assert_same_model("sigint", &ref_model, &res_model);
    std::fs::remove_dir_all(&dir).ok();
}

/// Torn-write injection: the writer corrupts its own temp file (truncate
/// or bitflip) and aborts *before* the rename, so the previously valid
/// generation must survive untouched and a clean resume must still match
/// the uninterrupted run.
#[test]
fn torn_write_injection_never_leaves_an_unloadable_state() {
    let dir = tmpdir("torn");
    for mode in ["truncate", "bitflip"] {
        let tag = format!("torn-{mode}");
        let base = fit_args(SMALL, "8", "cover", "1");
        let ref_model = dir.join(format!("ref_{mode}.kmm"));
        let res_model = dir.join(format!("res_{mode}.kmm"));
        let ck = dir.join(format!("{mode}.kmc"));

        let r = run_with(&base, &["--model_out", ref_model.to_str().unwrap()], &[]);
        assert!(r.status.success(), "{tag}: reference run failed");
        let ref_out = String::from_utf8_lossy(&r.stdout).into_owned();

        // Leave a valid snapshot on disk via a deterministic crash. Crash
        // at iteration 1 so the snapshot can never be a converged run:
        // the resumed fit must step, and the armed torn write must fire.
        let c = run_with(
            &base,
            &["--checkpoint_path", ck.to_str().unwrap(), "--checkpoint_every", "1"],
            &[("COVERMEANS_CRASH_AFTER_ITER", "1")],
        );
        assert!(!c.status.success(), "{tag}: injected crash did not kill the run");
        assert!(ck.exists(), "{tag}: no snapshot after the crash");
        let good = std::fs::read(&ck).unwrap();

        // Resume with the torn-write fault armed: the first checkpoint of
        // the resumed run corrupts its temp file and aborts pre-rename.
        let t = run_with(
            &base,
            &["--checkpoint_path", ck.to_str().unwrap(), "--resume", "1"],
            &[("COVERMEANS_CRASH_TORN_WRITE", mode)],
        );
        assert!(!t.status.success(), "{tag}: torn write did not abort the run");
        assert!(
            String::from_utf8_lossy(&t.stderr).contains("torn write"),
            "{tag}: abort fired without the torn-write banner:\n{}",
            String::from_utf8_lossy(&t.stderr)
        );
        // The good generation was never replaced by the torn temp.
        assert_eq!(
            std::fs::read(&ck).unwrap(),
            good,
            "{tag}: torn write clobbered the current generation"
        );

        // A clean resume rides over the corrupt leftover temp and still
        // reproduces the uninterrupted result.
        let r2 = run_with(
            &base,
            &["--checkpoint_path", ck.to_str().unwrap(), "--resume", "1",
              "--model_out", res_model.to_str().unwrap()],
            &[],
        );
        let stderr = String::from_utf8_lossy(&r2.stderr);
        assert!(r2.status.success(), "{tag}: clean resume failed:\n{stderr}");
        assert!(stderr.contains("resuming"), "{tag}: no resume banner:\n{stderr}");
        assert_same_result(&tag, &ref_out, &String::from_utf8_lossy(&r2.stdout));
        assert_same_model(&tag, &ref_model, &res_model);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Resuming under a different configuration is refused with a fingerprint
/// mismatch — a snapshot can only continue the exact fit that wrote it.
#[test]
fn resume_rejects_a_mismatched_configuration() {
    let dir = tmpdir("fingerprint");
    let ck = dir.join("fp.kmc");

    let c = run_with(
        &fit_args(SMALL, "8", "hamerly", "1"),
        &["--checkpoint_path", ck.to_str().unwrap(), "--checkpoint_every", "1"],
        &[("COVERMEANS_CRASH_AFTER_ITER", "2")],
    );
    assert!(!c.status.success());
    assert!(ck.exists());

    // Wrong algorithm and wrong k must both be refused.
    for (what, base) in [
        ("algorithm", fit_args(SMALL, "8", "cover", "1")),
        ("k", fit_args(SMALL, "9", "hamerly", "1")),
    ] {
        let r = run_with(
            &base,
            &["--checkpoint_path", ck.to_str().unwrap(), "--resume", "1"],
            &[],
        );
        assert!(!r.status.success(), "resume with a different {what} succeeded");
        let stderr = String::from_utf8_lossy(&r.stderr);
        assert!(
            stderr.contains("fingerprint mismatch"),
            "resume with a different {what} failed for the wrong reason:\n{stderr}"
        );
        assert_eq!(
            stderr.matches("error: ").count(),
            1,
            "CLI error contract: exactly one error line, got:\n{stderr}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
