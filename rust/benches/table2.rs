//! Bench: regenerate paper Table 2 — relative number of distance
//! computations vs the Standard algorithm, k = 100, all eight datasets.
//!
//!     cargo bench --bench table2
//!     REPRO_SCALE=0.2 REPRO_RESTARTS=10 cargo bench --bench table2
//!
//! Paper reference values (Table 2) are printed alongside so the measured
//! ratios can be shape-compared against the paper's.

use covermeans::benchutil::{bench_scale, CsvSink};
use covermeans::coordinator::{report, run_experiment, sweep};
use covermeans::kmeans::Algorithm;

/// Paper Table 2 rows, in dataset column order (covtype, istanbul, kdd04,
/// traffic, mnist10, mnist30, aloi27, aloi64).
const PAPER: &[(&str, [f64; 8])] = &[
    ("Kanungo", [0.006, 0.002, 1.450, 0.000, 0.149, 0.370, 0.036, 0.048]),
    ("Elkan", [0.004, 0.002, 0.025, 0.001, 0.007, 0.009, 0.005, 0.006]),
    ("Hamerly", [0.099, 0.078, 0.364, 0.090, 0.198, 0.213, 0.229, 0.253]),
    ("Exponion", [0.016, 0.010, 0.341, 0.009, 0.075, 0.130, 0.060, 0.075]),
    ("Shallot", [0.012, 0.006, 0.311, 0.006, 0.034, 0.061, 0.030, 0.043]),
    ("Cover-means", [0.012, 0.003, 0.807, 0.001, 0.097, 0.180, 0.044, 0.063]),
    ("Hybrid", [0.005, 0.003, 0.310, 0.003, 0.031, 0.057, 0.027, 0.038]),
];

fn main() {
    let scale = bench_scale();
    let restarts: usize = std::env::var("REPRO_RESTARTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let exp = sweep::tables23(scale, restarts);
    eprintln!(
        "table2: scale {scale}, {restarts} restarts, {} cells",
        exp.datasets.len() * exp.algorithms.len()
    );
    let t0 = std::time::Instant::now();
    let res = run_experiment(&exp, false).expect("experiment");
    eprintln!("completed in {:.1?}", t0.elapsed());

    println!(
        "{}",
        report::render_ratio_table(
            &exp,
            &res,
            report::Metric::Distances,
            &format!("Table 2 (measured, scale {scale}): relative distance computations, k=100"),
        )
    );
    println!("Table 2 (paper, scale 1.0, real datasets):");
    print!("{:<12}", "");
    for ds in &exp.datasets {
        print!(" {ds:>9}");
    }
    println!();
    for (name, vals) in PAPER {
        print!("{name:<12}");
        for v in vals {
            print!(" {v:>9.3}");
        }
        println!();
    }

    let mut sink = CsvSink::new("bench_table2.csv", "dataset,algorithm,ratio,paper_ratio");
    for (di, ds) in exp.datasets.iter().enumerate() {
        for &alg in &exp.algorithms {
            if alg == Algorithm::Standard {
                continue;
            }
            let measured = res
                .ratio_vs_standard(ds, alg, |c| c.total_distances() as f64)
                .unwrap_or(f64::NAN);
            let paper = PAPER
                .iter()
                .find(|(n, _)| *n == alg.name())
                .map(|(_, v)| v[di])
                .unwrap_or(f64::NAN);
            sink.row(format!("{ds},{},{measured:.6},{paper}", alg.name()));
        }
    }
    sink.flush();
}
