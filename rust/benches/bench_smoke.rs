//! Bench: CI perf-trajectory smoke gate.
//!
//! Runs the paper's eight-algorithm family at tiny scale (`REPRO_SCALE`,
//! default 0.05; CI uses 0.01) with 1 and 4 intra-fit threads, then:
//!
//!   * asserts the determinism contract end-to-end (threads=4 must
//!     reproduce threads=1 exactly: labels, iterations, distances);
//!   * measures the Lloyd assignment-phase speedup at 4 threads on a
//!     larger synthetic blob set;
//!   * measures the per-iteration **dispatch overhead** of the persistent
//!     worker pool against the old scoped-spawn baseline
//!     (`parallel::run_tasks_scoped`) — the pool must be cheaper;
//!   * measures the k-d-tree drivers (Kanungo, Pelleg-Moore) at 1 and 4
//!     threads over an amortized tree (the filtering pass is the object
//!     under test, not the sequential build);
//!   * measures pruned k-means++ seeding at 1 and 4 threads;
//!   * measures serving-layer batch predict (cover tree over the centers
//!     vs the Elkan-pruned scan, small vs large k, 1 vs 4 threads),
//!     asserts predict thread-invariance plus the tree's counted-work win
//!     over the naive n*k scan at k=64, and emits `BENCH_5.json`;
//!   * spins up the serving daemon on an ephemeral port and measures
//!     end-to-end served predict over the TCP wire (rows/s, p50/p99
//!     request latency at batch sizes 1/64/1024, server threads 1 vs 4),
//!     gates served labels against offline predict and across thread
//!     counts (deterministic, always enforced), and emits `BENCH_6.json`;
//!   * runs the dual-tree assignment pass head-to-head against the
//!     single-tree cover scan at k in {8, 64, 256} (wall time at 1 and 4
//!     threads plus counted per-iteration distances), gates exactness
//!     and thread invariance deterministically, and emits `BENCH_7.json`;
//!   * measures the distance-kernel layer (scalar vs dispatched SIMD
//!     ns/dist at d in {3, 30, 784}, tiled vs row-wise inter-center pass
//!     at k in {64, 256, 1000}, f32 vs f64 serving throughput at k=256),
//!     gates the bit-identities deterministically (SIMD ≡ scalar, tiled ≡
//!     row-wise, f32 labels/distances ≡ f64), and emits `BENCH_8.json`;
//!   * measures the checkpointed-fit overhead (snapshots off vs
//!     final-only vs every 10th iteration vs every iteration on the same
//!     fixed-seed fit), gates that checkpointing never perturbs the fit
//!     (deterministic, always enforced), and emits `BENCH_9.json`;
//!   * measures the out-of-core source layer (the same fixed-seed Lloyd
//!     fit over the in-RAM, mmap, and chunk-streamed backends at 1 and 4
//!     threads, with the streamed run's resident budget capped below the
//!     dataset size, plus k-means|| vs k-means++ seeding cost at large
//!     n), gates byte-identity across backends and thread counts
//!     (deterministic, always enforced), and emits `BENCH_10.json`;
//!   * emits `BENCH_4.json` (all of the above plus the per-algorithm
//!     table);
//!   * gates against the checked-in ceilings in `ci/bench_baseline.json`
//!     (override path via `BENCH_BASELINE`): any `dist_rel` / `time_rel`
//!     more than 25% above its baseline value fails the run.
//!
//! `BENCH_ENFORCE_SPEEDUP=1` additionally requires >= 1.5x Lloyd
//! assignment speedup at 4 threads, >= 1.5x on at least one k-d-tree
//! driver, the dual-tree pass to count strictly fewer assignment
//! distances than the single-tree scan at k = 256, the dispatched SIMD
//! kernel to beat the scalar loop at d=30 (skipped when the dispatch IS
//! scalar), f32 serving to beat f64 serving at k=256, and pool dispatch
//! below the scoped-spawn baseline, measured
//! best-of-N on both sides (set in CI, where 4 cores are guaranteed;
//! skipped by default so laptops with fewer cores don't fail spuriously).
//! `BENCH_GATE_WARN_ONLY=1` downgrades every gate failure to a warning
//! for noisy local machines.
//!
//!     REPRO_SCALE=0.01 cargo bench --bench bench_smoke

use std::time::{Duration, Instant};

use covermeans::benchutil::{bench_repeats, bench_scale, fmt_duration, measure, median};
use covermeans::data::{synth, write_dmat, DataSource, Matrix, SourceBackend};
use covermeans::kernels::{self, scalar as scalar_kernels};
use covermeans::kmeans::{
    init, Algorithm, CheckpointConfig, KMeans, PredictMode, PredictOptions,
    PredictPrecision, Workspace,
};
use covermeans::metrics::{DistCounter, RunResult};
use covermeans::parallel::{run_tasks_scoped, Parallelism};
use covermeans::serve::{ServeClient, ServeConfig, Server};
use covermeans::tree::KdTreeParams;

/// Regression threshold vs the baseline ceilings: fail above 125%.
const REGRESSION_FACTOR: f64 = 1.25;

struct AlgRow {
    name: &'static str,
    time_ms_t1: f64,
    time_ms_t4: f64,
    distances: u64,
    dist_rel: f64,
    time_rel: f64,
}

struct KdRow {
    name: &'static str,
    time_ms_t1: f64,
    time_ms_t4: f64,
    speedup: f64,
}

/// Returns the sorted per-repeat wall times and the last run's result.
fn timed_fit(
    repeats: usize,
    data: &Matrix,
    init_c: &Matrix,
    alg: Algorithm,
    threads: usize,
    max_iter: usize,
) -> (Vec<Duration>, RunResult) {
    let mut last: Option<RunResult> = None;
    let times = measure(repeats, || {
        let r = KMeans::new(init_c.rows())
            .algorithm(alg)
            .threads(threads)
            .max_iter(max_iter)
            .warm_start(init_c.clone())
            .fit(data)
            .expect("valid bench configuration");
        last = Some(r);
    });
    (times, last.expect("at least one measured run"))
}

/// Minimal flat-JSON number extractor for the baseline file. The file is
/// written one `"key": value` pair per line; lines whose value is not a
/// bare number (schema/comment strings, braces) are skipped.
fn parse_flat_json(text: &str) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        let Some(rest) = line.strip_prefix('"') else { continue };
        let Some((key, after)) = rest.split_once('"') else { continue };
        let Some((_, val)) = after.split_once(':') else { continue };
        if let Ok(v) = val.trim().trim_end_matches('}').trim().parse::<f64>() {
            out.push((key.to_string(), v));
        }
    }
    out
}

struct Extras {
    dispatch_us_pool: f64,
    dispatch_us_scoped: f64,
    kd: Vec<KdRow>,
    seed_ms_t1: f64,
    seed_ms_t4: f64,
}

/// One (server threads, request batch size) cell of the daemon
/// measurement.
struct ServeRow {
    threads: usize,
    batch: usize,
    requests: usize,
    rows_per_s: f64,
    p50_us: f64,
    p99_us: f64,
}

/// Sorted-latency percentile (nearest-rank).
fn percentile_us(sorted: &[Duration], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 * p / 100.0).ceil() as usize)
        .clamp(1, sorted.len())
        - 1;
    sorted[idx].as_secs_f64() * 1e6
}

/// Emit `BENCH_6.json`: end-to-end daemon throughput (rows/s) and
/// request latency (p50/p99) per batch size and server thread count,
/// over the TCP wire with coalescing on.
fn write_serve_json(path: &str, scale: f64, q_n: usize, k: usize, rows: &[ServeRow]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"bench-smoke-serve-v1\",\n");
    s.push_str(&format!("  \"scale\": {scale},\n"));
    s.push_str(&format!("  \"queries\": {q_n},\n"));
    s.push_str(&format!("  \"model_k\": {k},\n"));
    s.push_str("  \"batch_sizes\": [1, 64, 1024],\n");
    s.push_str("  \"threads_compared\": [1, 4],\n");
    s.push_str("  \"serve\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"threads\": {}, \"batch\": {}, \"requests\": {}, \
             \"rows_per_s\": {:.0}, \"p50_us\": {:.1}, \"p99_us\": {:.1}}}{comma}\n",
            r.threads, r.batch, r.requests, r.rows_per_s, r.p50_us, r.p99_us,
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("[json] wrote {path}"),
        Err(e) => eprintln!("[json] failed to write {path}: {e}"),
    }
}

/// One (k, strategy) cell of the serving-layer predict measurement.
struct PredictRow {
    k: usize,
    mode: &'static str,
    ms_t1: f64,
    ms_t4: f64,
    pps_t1: f64,
    pps_t4: f64,
    query_evals: u64,
    prep_evals: u64,
    naive_evals: u64,
}

/// Emit `BENCH_5.json`: predict throughput (points/s at 1 and 4 threads)
/// and counted evaluations for the cover-tree and pruned-scan strategies
/// at small and large k, so the crossover is visible from the artifact.
fn write_predict_json(path: &str, scale: f64, q_n: usize, rows: &[PredictRow]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"bench-smoke-predict-v1\",\n");
    s.push_str(&format!("  \"scale\": {scale},\n"));
    s.push_str(&format!("  \"queries\": {q_n},\n"));
    s.push_str("  \"threads_compared\": [1, 4],\n");
    s.push_str("  \"predict\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"k\": {}, \"mode\": \"{}\", \"ms_t1\": {:.3}, \"ms_t4\": {:.3}, \
             \"points_per_s_t1\": {:.0}, \"points_per_s_t4\": {:.0}, \
             \"query_evals\": {}, \"prep_evals\": {}, \"naive_evals\": {}}}{comma}\n",
            r.k,
            r.mode,
            r.ms_t1,
            r.ms_t4,
            r.pps_t1,
            r.pps_t4,
            r.query_evals,
            r.prep_evals,
            r.naive_evals,
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("[json] wrote {path}"),
        Err(e) => eprintln!("[json] failed to write {path}: {e}"),
    }
}

/// One k of the dual-tree vs single-tree cover head-to-head.
struct DualRow {
    k: usize,
    cover_ms_t1: f64,
    cover_ms_t4: f64,
    dual_ms_t1: f64,
    dual_ms_t4: f64,
    cover_dists: u64,
    dual_dists: u64,
}

/// Emit `BENCH_7.json`: wall time (1 vs 4 threads) and counted
/// per-iteration distances for the single-tree Cover-means scan vs the
/// dual-tree node-pair traversal at small, medium, and large k, so the
/// crossover where the dual pass starts winning is visible from the
/// artifact.
fn write_dual_json(path: &str, scale: f64, n: usize, rows: &[DualRow]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"bench-smoke-dual-v1\",\n");
    s.push_str(&format!("  \"scale\": {scale},\n"));
    s.push_str(&format!("  \"rows\": {n},\n"));
    s.push_str("  \"threads_compared\": [1, 4],\n");
    s.push_str("  \"dual_tree\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"k\": {}, \"cover_ms_t1\": {:.3}, \"cover_ms_t4\": {:.3}, \
             \"dual_ms_t1\": {:.3}, \"dual_ms_t4\": {:.3}, \
             \"cover_dists\": {}, \"dual_dists\": {}, \"dist_ratio\": {:.4}}}{comma}\n",
            r.k,
            r.cover_ms_t1,
            r.cover_ms_t4,
            r.dual_ms_t1,
            r.dual_ms_t4,
            r.cover_dists,
            r.dual_dists,
            r.dual_dists as f64 / r.cover_dists.max(1) as f64,
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("[json] wrote {path}"),
        Err(e) => eprintln!("[json] failed to write {path}: {e}"),
    }
}

/// One dimensionality of the scalar-vs-dispatched sqdist measurement.
struct KernelDimRow {
    d: usize,
    scalar_ns: f64,
    dispatched_ns: f64,
}

/// One k of the row-wise vs cache-tiled inter-center pass.
struct KernelPairRow {
    k: usize,
    rowwise_ms: f64,
    tiled_ms: f64,
}

/// One cadence of the checkpointed-fit overhead measurement.
struct CkptRow {
    cadence: &'static str,
    ms: f64,
    overhead: f64,
}

/// Emit `BENCH_9.json`: wall time of the same fixed-seed fit with
/// snapshots off, final-only, every 10th iteration, and every iteration,
/// plus the on-disk snapshot size — the cost of crash safety as a ratio
/// over the uncheckpointed baseline.
fn write_ckpt_json(
    path: &str,
    scale: f64,
    n: usize,
    k: usize,
    baseline_ms: f64,
    snapshot_bytes: u64,
    rows: &[CkptRow],
) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"bench-smoke-checkpoint-v1\",\n");
    s.push_str(&format!("  \"scale\": {scale},\n"));
    s.push_str(&format!("  \"rows\": {n},\n"));
    s.push_str(&format!("  \"k\": {k},\n"));
    s.push_str(&format!("  \"baseline_ms\": {baseline_ms:.3},\n"));
    s.push_str(&format!("  \"snapshot_bytes\": {snapshot_bytes},\n"));
    s.push_str("  \"checkpointed\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"cadence\": \"{}\", \"ms\": {:.3}, \"overhead\": {:.4}}}{comma}\n",
            r.cadence, r.ms, r.overhead,
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("[json] wrote {path}"),
        Err(e) => eprintln!("[json] failed to write {path}: {e}"),
    }
}

/// One (backend, threads) cell of the out-of-core fit measurement.
struct OocRow {
    backend: &'static str,
    threads: usize,
    ms: f64,
    rows_per_s: f64,
}

/// Shape of the out-of-core fixture (dataset dims plus streaming knobs).
struct OocSetup {
    n: usize,
    d: usize,
    k: usize,
    chunk_rows: usize,
    resident_mb: usize,
}

/// The seeding head-to-head at large n: wall time and counted distances
/// for triangle-pruned k-means++ vs k-means||.
struct OocInit {
    pp_ms: f64,
    pp_dists: u64,
    par_ms: f64,
    par_dists: u64,
}

/// Emit `BENCH_10.json`: the out-of-core source layer — wall time and
/// rows/s of the same fixed-seed Lloyd fit over the in-RAM, mmap, and
/// chunk-streamed backends at 1 and 4 threads, plus the k-means|| vs
/// k-means++ seeding cost at large n.
fn write_ooc_json(
    path: &str,
    scale: f64,
    setup: &OocSetup,
    fits: &[OocRow],
    init: &OocInit,
) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"bench-smoke-ooc-v1\",\n");
    s.push_str(&format!("  \"scale\": {scale},\n"));
    s.push_str(&format!("  \"rows\": {},\n", setup.n));
    s.push_str(&format!("  \"cols\": {},\n", setup.d));
    s.push_str(&format!("  \"k\": {},\n", setup.k));
    s.push_str(&format!("  \"chunk_rows\": {},\n", setup.chunk_rows));
    s.push_str(&format!("  \"resident_mb\": {},\n", setup.resident_mb));
    s.push_str("  \"fits\": [\n");
    for (i, r) in fits.iter().enumerate() {
        let comma = if i + 1 < fits.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"backend\": \"{}\", \"threads\": {}, \"ms\": {:.3}, \
             \"rows_per_s\": {:.0}}}{comma}\n",
            r.backend, r.threads, r.ms, r.rows_per_s,
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"init\": {{\"plusplus_ms\": {:.3}, \"plusplus_distances\": {}, \
         \"parallel_ms\": {:.3}, \"parallel_distances\": {}}}\n",
        init.pp_ms, init.pp_dists, init.par_ms, init.par_dists,
    ));
    s.push_str("}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("[json] wrote {path}"),
        Err(e) => eprintln!("[json] failed to write {path}: {e}"),
    }
}

/// The f64-vs-f32 serving throughput head-to-head at one k.
struct KernelPredictRow {
    k: usize,
    rows_per_s_f64: f64,
    rows_per_s_f32: f64,
    fallbacks: u64,
}

/// Emit `BENCH_8.json`: the distance-kernel layer — per-distance cost of
/// the scalar vs dispatched kernels across dimensionalities, the tiled
/// inter-center pass vs the historical row-wise loop across k, and f32 vs
/// f64 serving throughput, all attributed to the selected dispatch.
fn write_kernel_json(
    path: &str,
    scale: f64,
    dims: &[KernelDimRow],
    pairs: &[KernelPairRow],
    pred: &KernelPredictRow,
) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"bench-smoke-kernels-v1\",\n");
    s.push_str(&format!("  \"dispatch\": \"{}\",\n", kernels::active_name()));
    s.push_str(&format!("  \"scale\": {scale},\n"));
    s.push_str("  \"sqdist\": [\n");
    for (i, r) in dims.iter().enumerate() {
        let comma = if i + 1 < dims.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"d\": {}, \"scalar_ns\": {:.3}, \"dispatched_ns\": {:.3}, \
             \"speedup\": {:.3}}}{comma}\n",
            r.d,
            r.scalar_ns,
            r.dispatched_ns,
            r.scalar_ns / r.dispatched_ns.max(1e-12),
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"intercenter\": [\n");
    for (i, r) in pairs.iter().enumerate() {
        let comma = if i + 1 < pairs.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"k\": {}, \"rowwise_ms\": {:.3}, \"tiled_ms\": {:.3}, \
             \"speedup\": {:.3}}}{comma}\n",
            r.k,
            r.rowwise_ms,
            r.tiled_ms,
            r.rowwise_ms / r.tiled_ms.max(1e-12),
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"predict_f32\": {{\"k\": {}, \"rows_per_s_f64\": {:.0}, \
         \"rows_per_s_f32\": {:.0}, \"speedup\": {:.3}, \"fallbacks\": {}}}\n",
        pred.k,
        pred.rows_per_s_f64,
        pred.rows_per_s_f32,
        pred.rows_per_s_f32 / pred.rows_per_s_f64.max(1e-12),
        pred.fallbacks,
    ));
    s.push_str("}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("[json] wrote {path}"),
        Err(e) => eprintln!("[json] failed to write {path}: {e}"),
    }
}

#[allow(clippy::too_many_arguments)]
fn write_bench_json(
    path: &str,
    scale: f64,
    speedup: f64,
    rows: &[AlgRow],
    extras: &Extras,
) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"bench-smoke-v2\",\n");
    s.push_str(&format!("  \"scale\": {scale},\n"));
    s.push_str("  \"threads_compared\": [1, 4],\n");
    s.push_str(&format!(
        "  \"lloyd_assignment_speedup_4t\": {speedup:.3},\n"
    ));
    s.push_str(&format!(
        "  \"dispatch_us_pool\": {:.3},\n  \"dispatch_us_scoped\": {:.3},\n",
        extras.dispatch_us_pool, extras.dispatch_us_scoped,
    ));
    s.push_str("  \"kd_drivers\": {\n");
    for (i, row) in extras.kd.iter().enumerate() {
        let comma = if i + 1 < extras.kd.len() { "," } else { "" };
        s.push_str(&format!(
            "    \"{}\": {{\"time_ms_t1\": {:.3}, \"time_ms_t4\": {:.3}, \
             \"speedup_4t\": {:.3}}}{comma}\n",
            row.name, row.time_ms_t1, row.time_ms_t4, row.speedup,
        ));
    }
    s.push_str("  },\n");
    s.push_str(&format!(
        "  \"seeding\": {{\"time_ms_t1\": {:.3}, \"time_ms_t4\": {:.3}}},\n",
        extras.seed_ms_t1, extras.seed_ms_t4,
    ));
    s.push_str("  \"algorithms\": {\n");
    for (i, row) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        s.push_str(&format!(
            "    \"{}\": {{\"time_ms_t1\": {:.3}, \"time_ms_t4\": {:.3}, \
             \"distances\": {}, \"dist_rel\": {:.6}, \"time_rel\": {:.6}}}{comma}\n",
            row.name, row.time_ms_t1, row.time_ms_t4, row.distances, row.dist_rel,
            row.time_rel,
        ));
    }
    s.push_str("  }\n}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("[json] wrote {path}"),
        Err(e) => eprintln!("[json] failed to write {path}: {e}"),
    }
}

fn main() {
    let scale = bench_scale();
    let repeats = bench_repeats();
    let enforce = std::env::var_os("BENCH_ENFORCE_SPEEDUP").is_some();
    let mut failures: Vec<String> = Vec::new();

    // --- per-algorithm smoke at 1 vs 4 threads (scaled istanbul analog).
    let data = synth::istanbul(scale.max(0.002), 11);
    let k = 50usize.clamp(2, data.rows() / 4);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, k, 7, &mut dc);
    println!(
        "bench-smoke: istanbul n={} d={} k={k} (scale {scale}), {repeats} repeats",
        data.rows(),
        data.cols()
    );

    let mut rows: Vec<AlgRow> = Vec::new();
    let mut std_time = f64::NAN;
    let mut std_dist = 0u64;
    for alg in Algorithm::ALL {
        let (times1, r1) = timed_fit(repeats, &data, &init_c, alg, 1, 40);
        let (times4, r4) = timed_fit(repeats, &data, &init_c, alg, 4, 40);
        let (t1, t4) = (median(&times1), median(&times4));
        if r1.labels != r4.labels
            || r1.iterations != r4.iterations
            || r1.distances != r4.distances
            || r1.build_dist != r4.build_dist
        {
            failures.push(format!(
                "{}: threads=4 diverged from threads=1 (iters {} vs {}, dists {} vs {})",
                alg.name(),
                r4.iterations,
                r1.iterations,
                r4.distances,
                r1.distances,
            ));
        }
        // Measured wall time of the whole fit; construction is included
        // because every run starts from a fresh workspace (the Tables 3-4
        // convention).
        let secs1 = t1.as_secs_f64();
        let dists = r1.total_distances();
        if alg == Algorithm::Standard {
            std_time = secs1;
            std_dist = dists;
        }
        // Algorithm::ALL lists Standard first; the ratios below rely on it.
        assert!(
            std_time.is_finite() && std_dist > 0,
            "Standard must be measured before any ratio is computed"
        );
        let dist_rel = dists as f64 / std_dist as f64;
        let time_rel = secs1 / std_time;
        println!(
            "  {:<12} t1 {:>9} | t4 {:>9} | dists {:>10} | dist_rel {:.3} | time_rel {:.3}",
            alg.name(),
            fmt_duration(t1),
            fmt_duration(t4),
            dists,
            dist_rel,
            time_rel,
        );
        rows.push(AlgRow {
            name: alg.name(),
            time_ms_t1: secs1 * 1e3,
            time_ms_t4: t4.as_secs_f64() * 1e3,
            distances: dists,
            dist_rel,
            time_rel,
        });
    }

    // --- Lloyd assignment-phase speedup at 4 threads. Fixed-size blobs
    // (clamped so even CI's 0.01 scale measures real parallel work).
    let n_speed = ((400_000.0 * scale) as usize).clamp(20_000, 200_000);
    let big = synth::gaussian_blobs(n_speed, 8, 16, 1.0, 5);
    let mut dc = DistCounter::new();
    let big_init = init::kmeans_plus_plus(&big, 64, 3, &mut dc);
    let (times_s1, rs1) = timed_fit(repeats, &big, &big_init, Algorithm::Standard, 1, 3);
    let (times_s4, rs4) = timed_fit(repeats, &big, &big_init, Algorithm::Standard, 4, 3);
    if rs1.labels != rs4.labels || rs1.distances != rs4.distances {
        failures.push("Lloyd speedup fixture: threads=4 diverged".to_string());
    }
    // Best-of-N on both sides: minimum wall time is the standard
    // noise-robust estimator for speedup ratios on shared runners.
    let (ts1, ts4) = (times_s1[0], times_s4[0]);
    let speedup = ts1.as_secs_f64() / ts4.as_secs_f64().max(1e-12);
    println!(
        "lloyd assignment phase (n={n_speed}, k=64, 3 iters): t1 {} | t4 {} | speedup {speedup:.2}x",
        fmt_duration(ts1),
        fmt_duration(ts4),
    );
    if enforce && speedup < 1.5 {
        failures.push(format!(
            "Lloyd 4-thread assignment speedup {speedup:.2}x below the 1.5x floor"
        ));
    }

    // --- per-iteration dispatch overhead: persistent pool vs the old
    // scoped-spawn design, on a small-fit-shaped batch (a handful of
    // trivial chunk tasks per dispatch).
    const DISPATCHES: usize = 200;
    const TASKS_PER_DISPATCH: usize = 16;
    let pool4 = Parallelism::new(4);
    let tiny = |i: usize| i.wrapping_mul(2_654_435_761);
    let pool_times = measure(repeats, || {
        for _ in 0..DISPATCHES {
            let out =
                pool4.run_tasks((0..TASKS_PER_DISPATCH).collect::<Vec<_>>(), tiny);
            std::hint::black_box(out);
        }
    });
    let scoped_times = measure(repeats, || {
        for _ in 0..DISPATCHES {
            let out =
                run_tasks_scoped(4, (0..TASKS_PER_DISPATCH).collect::<Vec<_>>(), tiny);
            std::hint::black_box(out);
        }
    });
    let dispatch_us_pool = pool_times[0].as_secs_f64() * 1e6 / DISPATCHES as f64;
    let dispatch_us_scoped = scoped_times[0].as_secs_f64() * 1e6 / DISPATCHES as f64;
    println!(
        "dispatch overhead ({TASKS_PER_DISPATCH} trivial tasks, 4 threads): \
         pool {dispatch_us_pool:.1}us | scoped-spawn {dispatch_us_scoped:.1}us"
    );
    if enforce && dispatch_us_pool >= dispatch_us_scoped {
        failures.push(format!(
            "pool dispatch {dispatch_us_pool:.1}us not below the scoped-spawn \
             baseline {dispatch_us_scoped:.1}us"
        ));
    }

    // --- k-d-tree driver speedup at 4 threads over an amortized tree
    // (k-d construction is sequential and identical on both sides; the
    // parallel filtering pass is what this fixture isolates).
    let kd_data = synth::istanbul(scale.max(0.08), 12);
    let kd_k = 50usize.clamp(2, kd_data.rows() / 4);
    let mut dc = DistCounter::new();
    let kd_init = init::kmeans_plus_plus(&kd_data, kd_k, 9, &mut dc);
    let mut kd_rows: Vec<KdRow> = Vec::new();
    for alg in [Algorithm::Kanungo, Algorithm::PellegMoore] {
        let mut t_ms = [0.0f64; 2];
        let mut results: Vec<RunResult> = Vec::new();
        for (slot, threads) in [1usize, 4].into_iter().enumerate() {
            let mut ws = Workspace::new();
            ws.kd_tree_arc(&kd_data, KdTreeParams::default()); // warm build
            let mut last: Option<RunResult> = None;
            let times = measure(repeats, || {
                let r = KMeans::new(kd_k)
                    .algorithm(alg)
                    .threads(threads)
                    .max_iter(15)
                    .warm_start(kd_init.clone())
                    .fit_with(&kd_data, &mut ws)
                    .expect("valid kd bench configuration");
                last = Some(r);
            });
            t_ms[slot] = times[0].as_secs_f64() * 1e3;
            results.push(last.expect("at least one measured run"));
        }
        if results[0].labels != results[1].labels
            || results[0].iterations != results[1].iterations
            || results[0].distances != results[1].distances
        {
            failures.push(format!(
                "{}: kd speedup fixture diverged across thread counts",
                alg.name()
            ));
        }
        let sp = t_ms[0] / t_ms[1].max(1e-9);
        println!(
            "{} filtering (n={}, k={kd_k}, 15 iters, warm tree): t1 {:.2}ms | t4 {:.2}ms | speedup {sp:.2}x",
            alg.name(),
            kd_data.rows(),
            t_ms[0],
            t_ms[1],
        );
        kd_rows.push(KdRow {
            name: alg.name(),
            time_ms_t1: t_ms[0],
            time_ms_t4: t_ms[1],
            speedup: sp,
        });
    }
    let best_kd = kd_rows.iter().map(|r| r.speedup).fold(0.0f64, f64::max);
    if enforce && best_kd < 1.5 {
        failures.push(format!(
            "no kd-tree driver reached the 1.5x 4-thread floor (best {best_kd:.2}x)"
        ));
    }

    // --- pruned k-means++ seeding at 1 vs 4 threads (reuses the blob
    // fixture; the weighted draws stay sequential, so this reports the
    // end-to-end seeding wall time, not a pure map speedup).
    let par1 = Parallelism::new(1);
    let par4 = Parallelism::new(4);
    let mut seed_ms = [0.0f64; 2];
    let mut seed_out: Vec<(Matrix, u64)> = Vec::new();
    for (slot, par) in [&par1, &par4].into_iter().enumerate() {
        let mut last: Option<(Matrix, u64)> = None;
        let times = measure(repeats, || {
            let mut dc = DistCounter::new();
            let c = init::kmeans_plus_plus_par(&big, 64, 3, &mut dc, par);
            last = Some((c, dc.count()));
        });
        seed_ms[slot] = times[0].as_secs_f64() * 1e3;
        seed_out.push(last.expect("at least one measured run"));
    }
    if seed_out[0] != seed_out[1] {
        failures.push("seeding fixture: threads=4 diverged from threads=1".to_string());
    }
    println!(
        "k-means++ seeding (n={n_speed}, k=64, pruned): t1 {:.2}ms | t4 {:.2}ms | speedup {:.2}x",
        seed_ms[0],
        seed_ms[1],
        seed_ms[0] / seed_ms[1].max(1e-9),
    );

    // --- serving-layer predict throughput (BENCH_5.json): tree vs
    // Elkan-pruned scan at small and large k, 1 vs 4 threads, over warm
    // model indexes (the first call pays index prep, the timed calls
    // measure steady-state serving).
    let q_n = (n_speed / 4).clamp(5_000, 50_000);
    let queries = synth::gaussian_blobs(q_n, 8, 16, 1.3, 77);
    // Long-lived pools: the timed calls must measure serving, not
    // per-call pool spawn/teardown (the dispatch benchmark above is where
    // that cost is tracked).
    let serve_pools = [Parallelism::new(1), Parallelism::new(4)];
    let mut predict_rows: Vec<PredictRow> = Vec::new();
    for pk in [8usize, 64] {
        let mut dc = DistCounter::new();
        let p_init = init::kmeans_plus_plus(&big, pk, 13, &mut dc);
        let model = KMeans::new(pk)
            .algorithm(Algorithm::Standard)
            .threads(4)
            .max_iter(5)
            .warm_start(p_init)
            .fit_model(&big)
            .expect("valid predict-bench configuration");
        let naive = q_n as u64 * pk as u64;
        for mode in [PredictMode::Tree, PredictMode::Scan] {
            // Cold call: charges index prep, and is the reference for the
            // thread-invariance check.
            let cold = model.predict_par(&queries, mode, &serve_pools[0]);
            let p4 = model.predict_par(&queries, mode, &serve_pools[1]);
            if cold.labels != p4.labels || cold.query_evals != p4.query_evals {
                failures.push(format!(
                    "predict k={pk} {}: threads=4 diverged from threads=1",
                    mode.name()
                ));
            }
            let mut ms = [0.0f64; 2];
            for (slot, par) in serve_pools.iter().enumerate() {
                let times = measure(repeats, || {
                    let p = model.predict_par(&queries, mode, par);
                    std::hint::black_box(p.labels.len());
                });
                ms[slot] = times[0].as_secs_f64() * 1e3;
            }
            println!(
                "predict k={pk:<3} {:<5} (n={q_n}): t1 {:>8.2}ms | t4 {:>8.2}ms | \
                 {:>9.0} pts/s t4 | evals {} (naive {naive})",
                mode.name(),
                ms[0],
                ms[1],
                q_n as f64 / (ms[1] / 1e3).max(1e-12),
                cold.query_evals,
            );
            predict_rows.push(PredictRow {
                k: pk,
                mode: mode.name(),
                ms_t1: ms[0],
                ms_t4: ms[1],
                pps_t1: q_n as f64 / (ms[0] / 1e3).max(1e-12),
                pps_t4: q_n as f64 / (ms[1] / 1e3).max(1e-12),
                query_evals: cold.query_evals,
                prep_evals: cold.prep_evals,
                naive_evals: naive,
            });
        }
        // Counted-work gate (deterministic, so always enforced): at large
        // k the tree must answer with strictly fewer evaluations than the
        // naive n*k scan — the serving layer's acceptance bar.
        if pk >= 64 {
            let tree_row = predict_rows
                .iter()
                .rfind(|r| r.k == pk && r.mode == "tree")
                .expect("tree row recorded");
            if tree_row.query_evals >= naive {
                failures.push(format!(
                    "predict k={pk}: tree spent {} evals, not below naive {naive}",
                    tree_row.query_evals
                ));
            }
        }
    }
    write_predict_json("BENCH_5.json", scale, q_n, &predict_rows);

    // --- serving daemon end-to-end (BENCH_6.json): the same k=64 model
    // behind `covermeans serve`, measured over the TCP wire with request
    // coalescing on, at batch sizes 1/64/1024 and 1 vs 4 server threads.
    // Labels must be byte-identical to offline predict and invariant to
    // the server's thread count — a deterministic gate, always enforced.
    let serve_k = 64usize;
    let mut dc = DistCounter::new();
    let s_init = init::kmeans_plus_plus(&big, serve_k, 13, &mut dc);
    let serve_model = KMeans::new(serve_k)
        .algorithm(Algorithm::Standard)
        .threads(4)
        .max_iter(5)
        .warm_start(s_init)
        .fit_model(&big)
        .expect("valid serve-bench configuration");
    let model_path = std::env::temp_dir().join(format!(
        "covermeans_bench_serve_{}.kmm",
        std::process::id()
    ));
    serve_model
        .save(&model_path)
        .expect("write serve-bench model");
    let check_rows = 512.min(q_n);
    let check = Matrix::from_vec(
        queries.as_slice()[..check_rows * queries.cols()].to_vec(),
        check_rows,
        queries.cols(),
    );
    let offline = serve_model.predict_par(&check, PredictMode::Auto, &serve_pools[0]);
    let mut serve_rows: Vec<ServeRow> = Vec::new();
    for threads in [1usize, 4] {
        let cfg = ServeConfig {
            threads,
            batch_wait_us: 100,
            max_batch: 1024,
            queue_depth: 256,
            ..ServeConfig::for_tests(model_path.clone())
        };
        let mut server = Server::start(cfg).expect("start serve-bench daemon");
        let addr = server.addr().to_string();
        let mut client = ServeClient::connect(&addr).expect("connect serve bench");

        // The determinism/identity gate rides on a verification request.
        let served = client.predict_bin(&check).expect("serve-bench check");
        if served.labels != offline.labels {
            failures.push(format!(
                "serve threads={threads}: served labels diverged from offline predict"
            ));
        }
        for (a, b) in served.distances.iter().zip(&offline.distances) {
            if a.to_bits() != b.to_bits() {
                failures.push(format!(
                    "serve threads={threads}: served distances not bit-identical"
                ));
                break;
            }
        }

        for (batch, requests) in [(1usize, 300usize), (64, 100), (1024, 20)] {
            let span = q_n.saturating_sub(batch).max(1);
            let mut lat: Vec<Duration> = Vec::with_capacity(requests);
            let wall = Instant::now();
            for i in 0..requests {
                let lo = (i * batch) % span;
                let part = Matrix::from_vec(
                    queries.as_slice()[lo * queries.cols()..(lo + batch) * queries.cols()]
                        .to_vec(),
                    batch,
                    queries.cols(),
                );
                let t = Instant::now();
                let reply = client.predict_bin(&part).expect("serve-bench request");
                lat.push(t.elapsed());
                std::hint::black_box(reply.labels.len());
            }
            let total = wall.elapsed().as_secs_f64().max(1e-12);
            lat.sort();
            let row = ServeRow {
                threads,
                batch,
                requests,
                rows_per_s: (requests * batch) as f64 / total,
                p50_us: percentile_us(&lat, 50.0),
                p99_us: percentile_us(&lat, 99.0),
            };
            println!(
                "serve t{threads} batch {batch:<4} ({requests} reqs): \
                 {:>9.0} rows/s | p50 {:>8.1}us | p99 {:>8.1}us",
                row.rows_per_s, row.p50_us, row.p99_us,
            );
            serve_rows.push(row);
        }
        client.quit().expect("close serve-bench client");
        server.shutdown().expect("stop serve-bench daemon");
    }
    std::fs::remove_file(&model_path).ok();
    write_serve_json("BENCH_6.json", scale, q_n, serve_k, &serve_rows);

    // --- dual-tree vs single-tree cover assignment (BENCH_7.json). Same
    // warm start and point-tree parameters on both sides; at k in
    // {8, 64, 256} measure full-fit wall time (1 vs 4 threads) and
    // counted per-iteration distances. Both passes are exact, so equal
    // labels and thread invariance are deterministic gates, always
    // enforced. The dual pass exists for large k — the single-tree scan
    // pays ~k candidate distances at the root, where Eq. 9 cannot prune
    // — so under BENCH_ENFORCE_SPEEDUP it must count strictly fewer
    // assignment distances than the scan at k = 256.
    let dual_data = synth::istanbul(scale.max(0.02), 14);
    let mut dual_rows: Vec<DualRow> = Vec::new();
    for dk in [8usize, 64, 256] {
        let dk = dk.min(dual_data.rows() / 4);
        let mut dc = DistCounter::new();
        let d_init = init::kmeans_plus_plus(&dual_data, dk, 21, &mut dc);
        let (tc1, rc1) =
            timed_fit(repeats, &dual_data, &d_init, Algorithm::CoverMeans, 1, 8);
        let (tc4, rc4) =
            timed_fit(repeats, &dual_data, &d_init, Algorithm::CoverMeans, 4, 8);
        let (td1, rd1) =
            timed_fit(repeats, &dual_data, &d_init, Algorithm::DualTree, 1, 8);
        let (td4, rd4) =
            timed_fit(repeats, &dual_data, &d_init, Algorithm::DualTree, 4, 8);
        for (name, r1, r4) in
            [("Cover-means", &rc1, &rc4), ("Dual-tree", &rd1, &rd4)]
        {
            if r1.labels != r4.labels || r1.distances != r4.distances {
                failures.push(format!(
                    "dual-tree fixture k={dk}: {name} threads=4 diverged from threads=1"
                ));
            }
        }
        if rd1.labels != rc1.labels || rd1.iterations != rc1.iterations {
            failures.push(format!(
                "dual-tree fixture k={dk}: Dual-tree labels diverged from Cover-means"
            ));
        }
        let row = DualRow {
            k: dk,
            cover_ms_t1: median(&tc1).as_secs_f64() * 1e3,
            cover_ms_t4: median(&tc4).as_secs_f64() * 1e3,
            dual_ms_t1: median(&td1).as_secs_f64() * 1e3,
            dual_ms_t4: median(&td4).as_secs_f64() * 1e3,
            cover_dists: rc1.distances,
            dual_dists: rd1.distances,
        };
        println!(
            "dual-tree k={dk:<3}: cover t1 {:>9} dists {:>10} | \
             dual t1 {:>9} dists {:>10} ({:.2}x fewer)",
            fmt_duration(median(&tc1)),
            row.cover_dists,
            fmt_duration(median(&td1)),
            row.dual_dists,
            row.cover_dists as f64 / row.dual_dists.max(1) as f64,
        );
        if enforce && dk == 256 && row.dual_dists >= row.cover_dists {
            failures.push(format!(
                "dual-tree at k=256 counted {} assignment distances, not below \
                 the single-tree scan's {}",
                row.dual_dists, row.cover_dists,
            ));
        }
        dual_rows.push(row);
    }
    write_dual_json("BENCH_7.json", scale, dual_data.rows(), &dual_rows);

    // --- distance-kernel layer (BENCH_8.json): scalar vs dispatched
    // sqdist per-distance cost, tiled vs row-wise inter-center pass, and
    // f32 vs f64 serving throughput. The bit-identity gates are
    // deterministic and always enforced; the speedup gates (SIMD at d=30,
    // f32 serving at k=256) run under BENCH_ENFORCE_SPEEDUP.
    println!("kernel dispatch: {}", kernels::active_name());

    /// Best-of-N ns per distance for one sqdist implementation over a
    /// fixed pool of vector pairs.
    fn ns_per_dist(
        repeats: usize,
        iters: usize,
        pairs: usize,
        d: usize,
        va: &[f64],
        vb: &[f64],
        f: impl Fn(&[f64], &[f64]) -> f64,
    ) -> f64 {
        let times = measure(repeats, || {
            let mut acc = 0.0;
            for _ in 0..iters {
                for p in 0..pairs {
                    acc += f(&va[p * d..(p + 1) * d], &vb[p * d..(p + 1) * d]);
                }
            }
            std::hint::black_box(acc);
        });
        times[0].as_secs_f64() * 1e9 / (iters * pairs) as f64
    }

    let mut dim_rows: Vec<KernelDimRow> = Vec::new();
    for d in [3usize, 30, 784] {
        const PAIRS: usize = 32;
        let va: Vec<f64> = (0..PAIRS * d)
            .map(|i| ((i * 37 + 11) % 101) as f64 * 0.173 - 8.0)
            .collect();
        let vb: Vec<f64> = (0..PAIRS * d)
            .map(|i| ((i * 53 + 29) % 97) as f64 * 0.211 - 10.0)
            .collect();
        // Identity gate (always enforced): dispatched ≡ scalar, bit for
        // bit, on every pair of the timing pool.
        for p in 0..PAIRS {
            let (a, b) = (&va[p * d..(p + 1) * d], &vb[p * d..(p + 1) * d]);
            if kernels::sqdist(a, b).to_bits()
                != scalar_kernels::sqdist(a, b).to_bits()
            {
                failures.push(format!(
                    "kernel identity broken at d={d} (dispatch {})",
                    kernels::active_name()
                ));
                break;
            }
        }
        let iters = (2_000_000 / (d * PAIRS).max(1)).max(20);
        let scalar_ns =
            ns_per_dist(repeats, iters, PAIRS, d, &va, &vb, scalar_kernels::sqdist);
        let dispatched_ns =
            ns_per_dist(repeats, iters, PAIRS, d, &va, &vb, kernels::sqdist);
        println!(
            "sqdist d={d:<4}: scalar {scalar_ns:>7.2} ns | {} {dispatched_ns:>7.2} ns | {:.2}x",
            kernels::active_name(),
            scalar_ns / dispatched_ns.max(1e-12),
        );
        if enforce
            && d == 30
            && kernels::active() != kernels::Dispatch::Scalar
            && dispatched_ns >= scalar_ns
        {
            failures.push(format!(
                "dispatched sqdist ({}) {dispatched_ns:.2} ns/dist not below the \
                 scalar loop's {scalar_ns:.2} at d=30",
                kernels::active_name()
            ));
        }
        dim_rows.push(KernelDimRow { d, scalar_ns, dispatched_ns });
    }

    // Tiled vs row-wise inter-center pass: same per-pair arithmetic,
    // cache-blocked loop order. Identity over the full upper triangle is
    // a deterministic gate; the timing rows show the cache win growing
    // with k.
    let mut pair_rows: Vec<KernelPairRow> = Vec::new();
    for ck in [64usize, 256, 1000] {
        let centers = synth::gaussian_blobs(ck, 30, 16, 1.0, 300 + ck as u64);
        let mut grid = vec![f64::NAN; ck * ck];
        kernels::pairwise_upper(&centers, |i, j, dd| grid[i * ck + j] = dd);
        let mut identical = true;
        'pairs: for i in 0..ck {
            for j in (i + 1)..ck {
                let want = kernels::sqdist(centers.row(i), centers.row(j)).sqrt();
                if grid[i * ck + j].to_bits() != want.to_bits() {
                    identical = false;
                    break 'pairs;
                }
            }
        }
        if !identical {
            failures.push(format!(
                "tiled inter-center pass not bit-identical to row-wise at k={ck}"
            ));
        }
        let rowwise_times = measure(repeats, || {
            let mut acc = 0.0f64;
            for i in 0..ck {
                let ci = centers.row(i);
                for j in (i + 1)..ck {
                    acc += kernels::sqdist(ci, centers.row(j)).sqrt();
                }
            }
            std::hint::black_box(acc);
        });
        let tiled_times = measure(repeats, || {
            let mut acc = 0.0f64;
            kernels::pairwise_upper(&centers, |_, _, dd| acc += dd);
            std::hint::black_box(acc);
        });
        let rowwise_ms = rowwise_times[0].as_secs_f64() * 1e3;
        let tiled_ms = tiled_times[0].as_secs_f64() * 1e3;
        println!(
            "inter-center k={ck:<4} (d=30): row-wise {rowwise_ms:>8.3}ms | \
             tiled {tiled_ms:>8.3}ms | {:.2}x",
            rowwise_ms / tiled_ms.max(1e-12),
        );
        pair_rows.push(KernelPairRow { k: ck, rowwise_ms, tiled_ms });
    }

    // f32 vs f64 serving at k=256: identical labels and distance bits
    // (deterministic gate), higher throughput (BENCH_ENFORCE_SPEEDUP).
    let f32_k = 256usize;
    let mut dc = DistCounter::new();
    let f_init = init::kmeans_plus_plus(&big, f32_k, 31, &mut dc);
    let f_model = KMeans::new(f32_k)
        .algorithm(Algorithm::Standard)
        .threads(4)
        .max_iter(3)
        .warm_start(f_init)
        .fit_model(&big)
        .expect("valid kernel-bench configuration");
    let opts64 = PredictOptions {
        mode: PredictMode::Scan,
        threads: 4,
        precision: PredictPrecision::F64,
        ..PredictOptions::default()
    };
    let opts32 = PredictOptions { precision: PredictPrecision::F32, ..opts64 };
    // Cold calls charge index prep and feed the identity gate.
    let pk64 = f_model.predict_opts_par(&queries, &opts64, &serve_pools[1]);
    let pk32 = f_model.predict_opts_par(&queries, &opts32, &serve_pools[1]);
    if pk32.labels != pk64.labels {
        failures.push("f32 serving labels diverged from f64 at k=256".to_string());
    } else if pk32
        .distances
        .iter()
        .zip(&pk64.distances)
        .any(|(a, b)| a.to_bits() != b.to_bits())
    {
        failures.push("f32 serving distances not bit-identical to f64".to_string());
    }
    let mut rps = [0.0f64; 2];
    for (slot, o) in [&opts64, &opts32].into_iter().enumerate() {
        let times = measure(repeats, || {
            let p = f_model.predict_opts_par(&queries, o, &serve_pools[1]);
            std::hint::black_box(p.labels.len());
        });
        rps[slot] = q_n as f64 / times[0].as_secs_f64().max(1e-12);
    }
    println!(
        "predict k={f32_k} scan (n={q_n}): f64 {:>9.0} rows/s | f32 {:>9.0} rows/s \
         | {:.2}x | {} fallbacks",
        rps[0],
        rps[1],
        rps[1] / rps[0].max(1e-12),
        pk32.f32_fallbacks,
    );
    if enforce && rps[1] <= rps[0] {
        failures.push(format!(
            "f32 serving at k={f32_k} ({:.0} rows/s) not above f64 ({:.0} rows/s)",
            rps[1], rps[0],
        ));
    }
    let kernel_pred = KernelPredictRow {
        k: f32_k,
        rows_per_s_f64: rps[0],
        rows_per_s_f32: rps[1],
        fallbacks: pk32.f32_fallbacks,
    };
    write_kernel_json("BENCH_8.json", scale, &dim_rows, &pair_rows, &kernel_pred);

    // --- checkpointed-fit overhead (BENCH_9.json): the same fixed-seed
    // Lloyd fit with snapshots off, final-only (every=0), every 10th
    // iteration, and every iteration, on the blob fixture. Checkpointing
    // must not perturb the fit — identical labels, distances, and
    // iteration count to the uncheckpointed run is a deterministic gate,
    // always enforced. Under BENCH_ENFORCE_SPEEDUP the every=10 cadence
    // must stay under 1.5x the baseline wall time (every=1 pays an fsync
    // per iteration by design and is reported, not gated).
    let ck_path = std::env::temp_dir().join(format!(
        "covermeans_bench_ckpt_{}.kmc",
        std::process::id()
    ));
    let ckpt_fit = |every: Option<usize>| -> (f64, RunResult) {
        let mut last: Option<RunResult> = None;
        let times = measure(repeats, || {
            let mut b = KMeans::new(big_init.rows())
                .algorithm(Algorithm::Standard)
                .threads(1)
                .max_iter(8)
                .warm_start(big_init.clone());
            if let Some(every) = every {
                b = b.checkpoint(CheckpointConfig {
                    path: ck_path.clone(),
                    every,
                    secs: 0,
                });
            }
            let r = b.fit(&big).expect("valid checkpoint bench configuration");
            last = Some(r);
        });
        (
            times[0].as_secs_f64() * 1e3,
            last.expect("at least one measured run"),
        )
    };
    let (base_ms, r_base) = ckpt_fit(None);
    let cells = [
        ("final-only", ckpt_fit(Some(0))),
        ("every-10", ckpt_fit(Some(10))),
        ("every-1", ckpt_fit(Some(1))),
    ];
    let snapshot_bytes = std::fs::metadata(&ck_path).map(|m| m.len()).unwrap_or(0);
    let mut ckpt_rows: Vec<CkptRow> = Vec::new();
    for (cadence, (ms, r)) in cells {
        if r.labels != r_base.labels
            || r.distances != r_base.distances
            || r.iterations != r_base.iterations
        {
            failures.push(format!(
                "checkpointing ({cadence}) perturbed the fit it was snapshotting"
            ));
        }
        let overhead = ms / base_ms.max(1e-9);
        println!(
            "checkpoint {cadence:<10} (n={n_speed}, k=64, 8 iters): \
             {ms:>8.2}ms | {overhead:.2}x baseline {base_ms:.2}ms"
        );
        if enforce && cadence == "every-10" && overhead > 1.5 {
            failures.push(format!(
                "every-10 checkpointing cost {overhead:.2}x the uncheckpointed \
                 baseline, above the 1.5x ceiling"
            ));
        }
        ckpt_rows.push(CkptRow { cadence, ms, overhead });
    }
    for suffix in ["", ".prev", ".tmp"] {
        let mut name = ck_path.as_os_str().to_os_string();
        name.push(suffix);
        std::fs::remove_file(std::path::PathBuf::from(name)).ok();
    }
    write_ckpt_json(
        "BENCH_9.json",
        scale,
        big.rows(),
        big_init.rows(),
        base_ms,
        snapshot_bytes,
        &ckpt_rows,
    );

    // --- out-of-core source layer (BENCH_10.json): the same fixed-seed
    // Lloyd fit over the in-RAM, mmap, and chunk-streamed backends at 1
    // and 4 threads — wall time and rows/s — plus k-means|| vs k-means++
    // seeding cost at the same large n. The chunked cells hold a resident
    // budget below the dataset size, so they genuinely stream from disk.
    // Byte-identity of labels, centers, iteration count, and counted
    // distances across backends and thread counts is the source-layer
    // contract: a deterministic gate, always enforced.
    let ooc_path = std::env::temp_dir().join(format!(
        "covermeans_bench_ooc_{}.dmat",
        std::process::id()
    ));
    write_dmat(&ooc_path, &big).expect("write bench .dmat");
    let ooc_chunk = 1024usize;
    let ooc_resident_mb = 1usize;
    let ooc_budget_bytes = ooc_resident_mb << 20;
    assert!(
        big.rows() * big.cols() * 8 > ooc_budget_bytes,
        "out-of-core fixture must exceed its resident budget"
    );
    let ooc_iters = 3usize;
    let ooc_fit = |source: &DataSource, threads: usize| -> (f64, RunResult) {
        let mut last: Option<RunResult> = None;
        let times = measure(repeats, || {
            let r = KMeans::new(big_init.rows())
                .algorithm(Algorithm::Standard)
                .threads(threads)
                .max_iter(ooc_iters)
                .warm_start(big_init.clone())
                .fit_source(source)
                .expect("valid out-of-core bench configuration");
            last = Some(r);
        });
        (
            times[0].as_secs_f64() * 1e3,
            last.expect("at least one measured run"),
        )
    };
    let ooc_sources = [
        ("ram", DataSource::from(big.clone())),
        (
            "mmap",
            DataSource::open(&ooc_path, SourceBackend::Mmap, ooc_chunk, 0)
                .expect("mmap-open bench .dmat"),
        ),
        (
            "chunked",
            DataSource::open(&ooc_path, SourceBackend::Chunked, ooc_chunk, ooc_resident_mb)
                .expect("chunk-open bench .dmat"),
        ),
    ];
    let mut ooc_rows: Vec<OocRow> = Vec::new();
    let mut ooc_want = None;
    for &(backend, ref source) in &ooc_sources {
        for threads in [1usize, 4] {
            let (ms, r) = ooc_fit(source, threads);
            let rows_per_s = (big.rows() * r.iterations) as f64 * 1e3 / ms.max(1e-9);
            let sig = (
                r.labels,
                r.centers
                    .as_slice()
                    .iter()
                    .map(|v| v.to_bits())
                    .collect::<Vec<_>>(),
                r.iterations,
                r.distances,
            );
            match &ooc_want {
                None => ooc_want = Some(sig),
                Some(want) => {
                    if sig != *want {
                        failures.push(format!(
                            "out-of-core fixture: {backend} at {threads} threads \
                             diverged from the in-RAM single-thread fit"
                        ));
                    }
                }
            }
            println!(
                "ooc {backend:<7} t{threads} (n={n_speed}, k=64, {ooc_iters} iters): \
                 {ms:>8.2}ms | {rows_per_s:>10.0} rows/s"
            );
            ooc_rows.push(OocRow { backend, threads, ms, rows_per_s });
        }
    }

    // Seeding head-to-head (k=64, 4 threads, both triangle-pruned).
    // k-means|| must additionally be backend-invariant: seeding over the
    // chunk-streamed file is bit-identical to the resident matrix.
    let mut ooc_init_ms = [0.0f64; 2];
    let mut ooc_init_out: Vec<(Matrix, u64)> = Vec::new();
    for (slot, parallel) in [false, true].into_iter().enumerate() {
        let mut last: Option<(Matrix, u64)> = None;
        let times = measure(repeats, || {
            let mut dc = DistCounter::new();
            let c = if parallel {
                init::init_kmeanspar_par(&big, 64, 3, 5, 2.0, &mut dc, &par4)
            } else {
                init::kmeans_plus_plus_par(&big, 64, 3, &mut dc, &par4)
            };
            last = Some((c, dc.count()));
        });
        ooc_init_ms[slot] = times[0].as_secs_f64() * 1e3;
        ooc_init_out.push(last.expect("at least one measured run"));
    }
    {
        let mut dc = DistCounter::new();
        let streamed =
            init::init_kmeanspar_src(ooc_sources[2].1.view(), 64, 3, 5, 2.0, &mut dc, &par4);
        if (streamed, dc.count()) != ooc_init_out[1] {
            failures.push(
                "k-means|| seeding over the chunk-streamed file diverged from \
                 the resident matrix"
                    .to_string(),
            );
        }
    }
    println!(
        "ooc seeding (n={n_speed}, k=64, t4): k-means++ {:.2}ms ({} dists) | \
         k-means|| {:.2}ms ({} dists)",
        ooc_init_ms[0], ooc_init_out[0].1, ooc_init_ms[1], ooc_init_out[1].1,
    );
    std::fs::remove_file(&ooc_path).ok();
    write_ooc_json(
        "BENCH_10.json",
        scale,
        &OocSetup {
            n: big.rows(),
            d: big.cols(),
            k: big_init.rows(),
            chunk_rows: ooc_chunk,
            resident_mb: ooc_resident_mb,
        },
        &ooc_rows,
        &OocInit {
            pp_ms: ooc_init_ms[0],
            pp_dists: ooc_init_out[0].1,
            par_ms: ooc_init_ms[1],
            par_dists: ooc_init_out[1].1,
        },
    );

    // --- emit the artifact.
    let extras = Extras {
        dispatch_us_pool,
        dispatch_us_scoped,
        kd: kd_rows,
        seed_ms_t1: seed_ms[0],
        seed_ms_t4: seed_ms[1],
    };
    write_bench_json("BENCH_4.json", scale, speedup, &rows, &extras);

    // --- perf-trajectory gate vs the checked-in ceilings.
    let baseline_path = std::env::var("BENCH_BASELINE")
        .unwrap_or_else(|_| "ci/bench_baseline.json".to_string());
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => {
            println!("[gate] baseline {baseline_path} (fail above {REGRESSION_FACTOR}x)");
            for (key, ceiling) in parse_flat_json(&text) {
                let Some((alg_name, metric)) = key.rsplit_once('.') else {
                    continue;
                };
                let Some(row) = rows.iter().find(|r| r.name == alg_name) else {
                    continue;
                };
                let current = match metric {
                    "dist_rel" => row.dist_rel,
                    "time_rel" => row.time_rel,
                    _ => continue,
                };
                if current > ceiling * REGRESSION_FACTOR {
                    failures.push(format!(
                        "{key}: {current:.3} exceeds baseline {ceiling:.3} x {REGRESSION_FACTOR}"
                    ));
                } else {
                    println!("  ok {key}: {current:.3} <= {ceiling:.3} x {REGRESSION_FACTOR}");
                }
            }
        }
        Err(e) => {
            println!("[gate] no baseline at {baseline_path} ({e}); gate skipped");
        }
    }

    if failures.is_empty() {
        println!("bench-smoke: PASS");
    } else {
        eprintln!("bench-smoke: FAIL");
        for f in &failures {
            eprintln!("  - {f}");
        }
        eprintln!(
            "(to refresh ceilings after an intentional change, copy the \
             dist_rel/time_rel values from BENCH_4.json into {baseline_path})"
        );
        // Escape hatch for noisy local machines: report but don't fail.
        if std::env::var_os("BENCH_GATE_WARN_ONLY").is_some() {
            eprintln!("BENCH_GATE_WARN_ONLY set: exiting 0 despite failures");
        } else {
            std::process::exit(1);
        }
    }
}
