//! Bench: CI perf-trajectory smoke gate.
//!
//! Runs the paper's eight-algorithm family at tiny scale (`REPRO_SCALE`,
//! default 0.05; CI uses 0.01) with 1 and 4 intra-fit threads, then:
//!
//!   * asserts the determinism contract end-to-end (threads=4 must
//!     reproduce threads=1 exactly: labels, iterations, distances);
//!   * measures the Lloyd assignment-phase speedup at 4 threads on a
//!     larger synthetic blob set;
//!   * emits `BENCH_2.json` (per-algorithm wall time at both thread
//!     counts, counted distances, and ratios vs the Standard run);
//!   * gates against the checked-in ceilings in `ci/bench_baseline.json`
//!     (override path via `BENCH_BASELINE`): any `dist_rel` / `time_rel`
//!     more than 25% above its baseline value fails the run.
//!
//! `BENCH_ENFORCE_SPEEDUP=1` additionally requires >= 1.5x Lloyd
//! assignment speedup at 4 threads, measured best-of-N on both sides (set
//! in CI, where 4 cores are guaranteed; skipped by default so laptops
//! with fewer cores don't fail spuriously). `BENCH_GATE_WARN_ONLY=1`
//! downgrades every gate failure to a warning for noisy local machines.
//!
//!     REPRO_SCALE=0.01 cargo bench --bench bench_smoke

use std::time::Duration;

use covermeans::benchutil::{bench_repeats, bench_scale, fmt_duration, measure, median};
use covermeans::data::{synth, Matrix};
use covermeans::kmeans::{init, Algorithm, KMeans};
use covermeans::metrics::{DistCounter, RunResult};

/// Regression threshold vs the baseline ceilings: fail above 125%.
const REGRESSION_FACTOR: f64 = 1.25;

struct AlgRow {
    name: &'static str,
    time_ms_t1: f64,
    time_ms_t4: f64,
    distances: u64,
    dist_rel: f64,
    time_rel: f64,
}

/// Returns the sorted per-repeat wall times and the last run's result.
fn timed_fit(
    repeats: usize,
    data: &Matrix,
    init_c: &Matrix,
    alg: Algorithm,
    threads: usize,
    max_iter: usize,
) -> (Vec<Duration>, RunResult) {
    let mut last: Option<RunResult> = None;
    let times = measure(repeats, || {
        let r = KMeans::new(init_c.rows())
            .algorithm(alg)
            .threads(threads)
            .max_iter(max_iter)
            .warm_start(init_c.clone())
            .fit(data)
            .expect("valid bench configuration");
        last = Some(r);
    });
    (times, last.expect("at least one measured run"))
}

/// Minimal flat-JSON number extractor for the baseline file. The file is
/// written one `"key": value` pair per line; lines whose value is not a
/// bare number (schema/comment strings, braces) are skipped.
fn parse_flat_json(text: &str) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        let Some(rest) = line.strip_prefix('"') else { continue };
        let Some((key, after)) = rest.split_once('"') else { continue };
        let Some((_, val)) = after.split_once(':') else { continue };
        if let Ok(v) = val.trim().trim_end_matches('}').trim().parse::<f64>() {
            out.push((key.to_string(), v));
        }
    }
    out
}

fn write_bench_json(path: &str, scale: f64, speedup: f64, rows: &[AlgRow]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"bench-smoke-v1\",\n");
    s.push_str(&format!("  \"scale\": {scale},\n"));
    s.push_str("  \"threads_compared\": [1, 4],\n");
    s.push_str(&format!(
        "  \"lloyd_assignment_speedup_4t\": {speedup:.3},\n"
    ));
    s.push_str("  \"algorithms\": {\n");
    for (i, row) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        s.push_str(&format!(
            "    \"{}\": {{\"time_ms_t1\": {:.3}, \"time_ms_t4\": {:.3}, \
             \"distances\": {}, \"dist_rel\": {:.6}, \"time_rel\": {:.6}}}{comma}\n",
            row.name, row.time_ms_t1, row.time_ms_t4, row.distances, row.dist_rel,
            row.time_rel,
        ));
    }
    s.push_str("  }\n}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("[json] wrote {path}"),
        Err(e) => eprintln!("[json] failed to write {path}: {e}"),
    }
}

fn main() {
    let scale = bench_scale();
    let repeats = bench_repeats();
    let mut failures: Vec<String> = Vec::new();

    // --- per-algorithm smoke at 1 vs 4 threads (scaled istanbul analog).
    let data = synth::istanbul(scale.max(0.002), 11);
    let k = 50usize.clamp(2, data.rows() / 4);
    let mut dc = DistCounter::new();
    let init_c = init::kmeans_plus_plus(&data, k, 7, &mut dc);
    println!(
        "bench-smoke: istanbul n={} d={} k={k} (scale {scale}), {repeats} repeats",
        data.rows(),
        data.cols()
    );

    let mut rows: Vec<AlgRow> = Vec::new();
    let mut std_time = f64::NAN;
    let mut std_dist = 0u64;
    for alg in Algorithm::ALL {
        let (times1, r1) = timed_fit(repeats, &data, &init_c, alg, 1, 40);
        let (times4, r4) = timed_fit(repeats, &data, &init_c, alg, 4, 40);
        let (t1, t4) = (median(&times1), median(&times4));
        if r1.labels != r4.labels
            || r1.iterations != r4.iterations
            || r1.distances != r4.distances
            || r1.build_dist != r4.build_dist
        {
            failures.push(format!(
                "{}: threads=4 diverged from threads=1 (iters {} vs {}, dists {} vs {})",
                alg.name(),
                r4.iterations,
                r1.iterations,
                r4.distances,
                r1.distances,
            ));
        }
        // Measured wall time of the whole fit; construction is included
        // because every run starts from a fresh workspace (the Tables 3-4
        // convention).
        let secs1 = t1.as_secs_f64();
        let dists = r1.total_distances();
        if alg == Algorithm::Standard {
            std_time = secs1;
            std_dist = dists;
        }
        // Algorithm::ALL lists Standard first; the ratios below rely on it.
        assert!(
            std_time.is_finite() && std_dist > 0,
            "Standard must be measured before any ratio is computed"
        );
        let dist_rel = dists as f64 / std_dist as f64;
        let time_rel = secs1 / std_time;
        println!(
            "  {:<12} t1 {:>9} | t4 {:>9} | dists {:>10} | dist_rel {:.3} | time_rel {:.3}",
            alg.name(),
            fmt_duration(t1),
            fmt_duration(t4),
            dists,
            dist_rel,
            time_rel,
        );
        rows.push(AlgRow {
            name: alg.name(),
            time_ms_t1: secs1 * 1e3,
            time_ms_t4: t4.as_secs_f64() * 1e3,
            distances: dists,
            dist_rel,
            time_rel,
        });
    }

    // --- Lloyd assignment-phase speedup at 4 threads. Fixed-size blobs
    // (clamped so even CI's 0.01 scale measures real parallel work).
    let n_speed = ((400_000.0 * scale) as usize).clamp(20_000, 200_000);
    let big = synth::gaussian_blobs(n_speed, 8, 16, 1.0, 5);
    let mut dc = DistCounter::new();
    let big_init = init::kmeans_plus_plus(&big, 64, 3, &mut dc);
    let (times_s1, rs1) = timed_fit(repeats, &big, &big_init, Algorithm::Standard, 1, 3);
    let (times_s4, rs4) = timed_fit(repeats, &big, &big_init, Algorithm::Standard, 4, 3);
    if rs1.labels != rs4.labels || rs1.distances != rs4.distances {
        failures.push("Lloyd speedup fixture: threads=4 diverged".to_string());
    }
    // Best-of-N on both sides: minimum wall time is the standard
    // noise-robust estimator for speedup ratios on shared runners.
    let (ts1, ts4) = (times_s1[0], times_s4[0]);
    let speedup = ts1.as_secs_f64() / ts4.as_secs_f64().max(1e-12);
    println!(
        "lloyd assignment phase (n={n_speed}, k=64, 3 iters): t1 {} | t4 {} | speedup {speedup:.2}x",
        fmt_duration(ts1),
        fmt_duration(ts4),
    );
    if std::env::var_os("BENCH_ENFORCE_SPEEDUP").is_some() && speedup < 1.5 {
        failures.push(format!(
            "Lloyd 4-thread assignment speedup {speedup:.2}x below the 1.5x floor"
        ));
    }

    // --- emit the artifact.
    write_bench_json("BENCH_2.json", scale, speedup, &rows);

    // --- perf-trajectory gate vs the checked-in ceilings.
    let baseline_path = std::env::var("BENCH_BASELINE")
        .unwrap_or_else(|_| "ci/bench_baseline.json".to_string());
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => {
            println!("[gate] baseline {baseline_path} (fail above {REGRESSION_FACTOR}x)");
            for (key, ceiling) in parse_flat_json(&text) {
                let Some((alg_name, metric)) = key.rsplit_once('.') else {
                    continue;
                };
                let Some(row) = rows.iter().find(|r| r.name == alg_name) else {
                    continue;
                };
                let current = match metric {
                    "dist_rel" => row.dist_rel,
                    "time_rel" => row.time_rel,
                    _ => continue,
                };
                if current > ceiling * REGRESSION_FACTOR {
                    failures.push(format!(
                        "{key}: {current:.3} exceeds baseline {ceiling:.3} x {REGRESSION_FACTOR}"
                    ));
                } else {
                    println!("  ok {key}: {current:.3} <= {ceiling:.3} x {REGRESSION_FACTOR}");
                }
            }
        }
        Err(e) => {
            println!("[gate] no baseline at {baseline_path} ({e}); gate skipped");
        }
    }

    if failures.is_empty() {
        println!("bench-smoke: PASS");
    } else {
        eprintln!("bench-smoke: FAIL");
        for f in &failures {
            eprintln!("  - {f}");
        }
        eprintln!(
            "(to refresh ceilings after an intentional change, copy the \
             dist_rel/time_rel values from BENCH_2.json into {baseline_path})"
        );
        // Escape hatch for noisy local machines: report but don't fail.
        if std::env::var_os("BENCH_GATE_WARN_ONLY").is_some() {
            eprintln!("BENCH_GATE_WARN_ONLY set: exiting 0 despite failures");
        } else {
            std::process::exit(1);
        }
    }
}
