//! Bench: regenerate paper Fig. 2 — run time relative to the Standard
//! algorithm (a) vs dimensionality d in {10..50} on the MNIST analogs at
//! k = 100, and (b) vs k on MNIST-10.
//!
//!     cargo bench --bench fig2

use covermeans::benchutil::{bench_scale, CsvSink};
use covermeans::coordinator::{report, run_experiment, sweep};

fn main() {
    let scale = bench_scale();
    let restarts: usize = std::env::var("REPRO_RESTARTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);

    // --- Fig 2a: vs dimensionality.
    let exp_a = sweep::fig2a(scale, restarts);
    eprintln!("fig2a: scale {scale}, {restarts} restarts, 5 dims");
    let res_a = run_experiment(&exp_a, false).expect("fig2a");
    let rows_a = report::fig2_series_csv(&exp_a, &res_a, false);
    println!("Fig 2a (time rel. Standard vs d, k=100, scale {scale}):");
    for r in &rows_a {
        println!("  {r}");
    }
    let mut sink = CsvSink::new("bench_fig2a.csv", "dataset,algorithm,time_rel");
    for r in rows_a.iter().skip(1) {
        sink.row(r.clone());
    }
    sink.flush();

    // --- Fig 2b: vs k (grid scaled to dataset size).
    let mut exp_b = sweep::fig2b(scale, restarts);
    let n_est = (covermeans::data::synth::MNIST_N as f64 * scale) as usize;
    exp_b.ks.retain(|&k| k <= n_est / 10);
    if exp_b.ks.is_empty() {
        exp_b.ks = vec![10];
    }
    eprintln!("fig2b: k grid {:?}", exp_b.ks);
    let res_b = run_experiment(&exp_b, false).expect("fig2b");
    let rows_b = report::fig2_series_csv(&exp_b, &res_b, true);
    println!("\nFig 2b (time rel. Standard vs k, mnist10, scale {scale}):");
    for r in &rows_b {
        println!("  {r}");
    }
    let mut sink = CsvSink::new("bench_fig2b.csv", "k,algorithm,time_rel");
    for r in rows_b.iter().skip(1) {
        sink.row(r.clone());
    }
    sink.flush();
}
