//! Bench: design-choice ablations — the cover tree scaling
//! factor, the minimum node size, and the hybrid switch iteration, each
//! varied alone on a tree-friendly (istanbul) and a tree-hostile (kdd04)
//! dataset.
//!
//!     cargo bench --bench ablation

use covermeans::benchutil::{bench_scale, CsvSink};
use covermeans::coordinator::{run_experiment, sweep};
use covermeans::kmeans::Algorithm;

fn main() {
    let scale = bench_scale();
    let restarts: usize = std::env::var("REPRO_RESTARTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let mut sink = CsvSink::new(
        "bench_ablation.csv",
        "knob,dataset,algorithm,dist_rel,time_rel",
    );
    println!("ablations (scale {scale}, {restarts} restarts):");
    println!(
        "{:<22} {:<10} {:<12} {:>9} {:>9}",
        "knob", "dataset", "algorithm", "dist rel", "time rel"
    );
    for (label, exp) in sweep::ablations(scale, restarts) {
        let res = run_experiment(&exp, false).expect("ablation");
        for ds in &exp.datasets {
            for &alg in &exp.algorithms {
                if alg == Algorithm::Standard {
                    continue;
                }
                let dr = res
                    .ratio_vs_standard(ds, alg, |c| c.total_distances() as f64)
                    .unwrap_or(f64::NAN);
                let tr = res
                    .ratio_vs_standard(ds, alg, |c| c.total_time().as_secs_f64())
                    .unwrap_or(f64::NAN);
                println!(
                    "{label:<22} {ds:<10} {:<12} {dr:>9.3} {tr:>9.3}",
                    alg.name()
                );
                sink.row(format!("{label},{ds},{},{dr:.6},{tr:.6}", alg.name()));
            }
        }
    }
    sink.flush();
}
