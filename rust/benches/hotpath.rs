//! Bench: hot-path microbenchmarks for the §Perf optimization pass.
//!
//! Measures, in isolation:
//!   * dense distance-evaluation throughput (the L3 roofline reference),
//!   * cover tree construction throughput,
//!   * one Cover-means assignment pass (the paper-critical inner loop),
//!   * one Shallot iteration at converged bounds (the hybrid tail),
//!   * the XLA dense assign step (runtime path), when artifacts exist.
//!
//!     cargo bench --bench hotpath

use covermeans::benchutil::{bench_repeats, fmt_duration, measure, median, CsvSink};
use covermeans::data::synth;
use covermeans::kmeans::bounds::InterCenter;
use covermeans::kmeans::{self, Algorithm, KMeansParams, Workspace};
use covermeans::metrics::DistCounter;
use covermeans::tree::{CoverTree, CoverTreeParams};

fn main() {
    let repeats = bench_repeats();
    let mut sink = CsvSink::new("bench_hotpath.csv", "section,metric,value");

    // --- dense distance throughput (f64 native).
    let data = synth::mnist(30, 0.05, 1); // 3500 x 30
    let centers_m = {
        let mut dc = DistCounter::new();
        kmeans::init::kmeans_plus_plus(&data, 128, 1, &mut dc)
    };
    let n = data.rows();
    let k = centers_m.rows();
    let times = measure(repeats, || {
        let mut dc = DistCounter::new();
        let mut acc = 0.0f64;
        for i in 0..n {
            for c in 0..k {
                acc += dc.sq(data.row(i), centers_m.row(c));
            }
        }
        std::hint::black_box(acc);
    });
    let med = median(&times);
    let evals_per_s = (n * k) as f64 / med.as_secs_f64();
    println!(
        "dense sqdist (d=30): {} for {}x{} -> {:.1} M evals/s ({:.2} GFLOP/s)",
        fmt_duration(med),
        n,
        k,
        evals_per_s / 1e6,
        evals_per_s * (3.0 * 30.0) / 1e9
    );
    sink.row(format!("dense_sqdist_d30,Mevals_per_s,{:.3}", evals_per_s / 1e6));

    // --- cover tree construction.
    let geo = synth::istanbul(0.02, 2); // ~6900 x 2
    let times = measure(repeats, || {
        let t = CoverTree::build(&geo, CoverTreeParams::default());
        std::hint::black_box(t.node_count);
    });
    let med = median(&times);
    println!(
        "cover tree build (istanbul n={}): {} ({:.0} pts/ms)",
        geo.rows(),
        fmt_duration(med),
        geo.rows() as f64 / med.as_secs_f64() / 1e3
    );
    sink.row(format!(
        "covertree_build,points_per_ms,{:.3}",
        geo.rows() as f64 / med.as_secs_f64() / 1e3
    ));

    // --- one Cover-means assignment pass (iteration 1 conditions). The
    // workspace is pre-warmed so the measured pass excludes construction.
    let k2 = 100;
    let init = {
        let mut dc = DistCounter::new();
        kmeans::init::kmeans_plus_plus(&geo, k2, 3, &mut dc)
    };
    let params = KMeansParams {
        algorithm: Algorithm::CoverMeans,
        max_iter: 1,
        ..KMeansParams::default()
    };
    let mut ws = Workspace::new();
    ws.cover_tree(&geo, params.cover);
    let times = measure(repeats, || {
        let r = kmeans::run(&geo, &init, &params, &mut ws);
        std::hint::black_box(r.distances);
    });
    let med = median(&times);
    println!(
        "cover-means pass (n={}, k={k2}): {} / iter",
        geo.rows(),
        fmt_duration(med)
    );
    sink.row(format!("cover_pass,ms,{:.3}", med.as_secs_f64() * 1e3));

    // --- Shallot tail iteration (bounds warm, centers converged).
    let full = kmeans::run(
        &geo,
        &init,
        &KMeansParams { algorithm: Algorithm::Standard, ..KMeansParams::default() },
        &mut Workspace::new(),
    );
    let params_s = KMeansParams {
        algorithm: Algorithm::Shallot,
        max_iter: 2,
        ..KMeansParams::default()
    };
    let times = measure(repeats, || {
        // From converged centers: iteration 2 is the "stable tail" cost.
        let r = kmeans::run(&geo, &full.centers, &params_s, &mut Workspace::new());
        std::hint::black_box(r.distances);
    });
    let med = median(&times);
    println!("shallot tail (2 iters from converged): {}", fmt_duration(med));
    sink.row(format!("shallot_tail,ms,{:.3}", med.as_secs_f64() * 1e3));

    // --- inter-center matrix (per-iteration fixed cost at k=1000).
    let big_init = {
        let mut dc = DistCounter::new();
        let big = synth::mnist(10, 0.03, 4);
        kmeans::init::kmeans_plus_plus(&big, 1000, 5, &mut dc)
    };
    let times = measure(repeats, || {
        let mut dc = DistCounter::new();
        let ic = InterCenter::compute(&big_init, &mut dc);
        std::hint::black_box(ic.s[0]);
    });
    let med = median(&times);
    println!("inter-center matrix (k=1000, d=10): {}", fmt_duration(med));
    sink.row(format!("intercenter_k1000,ms,{:.3}", med.as_secs_f64() * 1e3));

    // --- XLA dense assign (runtime path; needs the `xla` feature).
    #[cfg(feature = "xla")]
    match covermeans::runtime::AssignExecutor::load_default() {
        Ok(mut exec) => {
            let times = measure(repeats, || {
                let out = exec.assign(&data, &centers_m).expect("assign");
                std::hint::black_box(out.labels.len());
            });
            let med = median(&times);
            let evals = (n * k) as f64;
            println!(
                "xla assign (n={n}, d=30->64, k=128): {} ({:.1} M evals/s)",
                fmt_duration(med),
                evals / med.as_secs_f64() / 1e6
            );
            sink.row(format!(
                "xla_assign,Mevals_per_s,{:.3}",
                evals / med.as_secs_f64() / 1e6
            ));
        }
        Err(e) => eprintln!("xla assign skipped: {e}"),
    }
    #[cfg(not(feature = "xla"))]
    eprintln!("xla assign skipped: built without the `xla` feature");

    sink.flush();
}
