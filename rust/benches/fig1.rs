//! Bench: regenerate paper Fig. 1 — cumulative distance computations (1a)
//! and cumulative time (1b) per iteration on the ALOI-64 analog, k = 400,
//! normalized by the full Standard run; tree construction excluded.
//!
//!     cargo bench --bench fig1
//!
//! Writes results/bench_fig1.csv and prints the three behavioural groups
//! the paper describes (constant tree cost, decaying stored-bounds cost,
//! hybrid switching between them).

use covermeans::benchutil::{bench_scale, CsvSink};
use covermeans::coordinator::{report, run_experiment, sweep};
use covermeans::kmeans::Algorithm;

fn main() {
    let scale = bench_scale();
    // k scales with the dataset so cluster structure stays comparable at
    // small scales (paper: k=400 at n=110k; keep k <= n/40).
    let mut exp = sweep::fig1(scale);
    let n_est = (covermeans::data::synth::ALOI_N as f64 * scale) as usize;
    if 400 > n_est / 40 {
        exp.ks = vec![(n_est / 40).max(10)];
        eprintln!("fig1: scaled k down to {} for n~{n_est}", exp.ks[0]);
    }
    let res = run_experiment(&exp, true).expect("experiment");
    let rows = report::fig1_series_csv(&exp, &res);

    // Per-iteration marginal cost of the last iteration, by algorithm —
    // the paper's "three groups" signature.
    println!("Fig 1 (scale {scale}, k={}):", exp.ks[0]);
    println!(
        "{:<12} {:>6} {:>16} {:>16}",
        "algorithm", "iters", "final dist rel", "final time rel"
    );
    for alg in Algorithm::ALL {
        let series: Vec<&String> =
            rows.iter().filter(|r| r.starts_with(alg.name())).collect();
        if let Some(last) = series.last() {
            let cols: Vec<&str> = last.split(',').collect();
            println!(
                "{:<12} {:>6} {:>16} {:>16}",
                alg.name(),
                series.len(),
                cols[2],
                cols[3]
            );
        }
    }

    let mut sink = CsvSink::new(
        "bench_fig1.csv",
        "algorithm,iter,dist_cum_rel,time_cum_rel",
    );
    for r in rows.iter().skip(1) {
        sink.row(r.clone());
    }
    sink.flush();
}
