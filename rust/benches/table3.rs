//! Bench: regenerate paper Table 3 — relative run time (including tree
//! construction) vs the Standard algorithm, k = 100, all eight datasets.
//!
//!     cargo bench --bench table3

use covermeans::benchutil::{bench_scale, CsvSink};
use covermeans::coordinator::{report, run_experiment, sweep};
use covermeans::kmeans::Algorithm;

const PAPER: &[(&str, [f64; 8])] = &[
    ("Kanungo", [0.068, 0.123, 4.035, 0.182, 0.470, 0.798, 0.133, 0.130]),
    ("Elkan", [0.114, 0.520, 0.193, 0.652, 0.454, 0.226, 0.180, 0.104]),
    ("Hamerly", [0.139, 0.171, 0.383, 0.173, 0.262, 0.238, 0.262, 0.278]),
    ("Exponion", [0.064, 0.132, 0.369, 0.142, 0.150, 0.161, 0.107, 0.109]),
    ("Shallot", [0.062, 0.134, 0.346, 0.145, 0.120, 0.098, 0.084, 0.080]),
    ("Cover-means", [0.072, 0.092, 1.121, 0.135, 0.352, 0.313, 0.138, 0.123]),
    ("Hybrid", [0.051, 0.084, 0.457, 0.130, 0.133, 0.102, 0.082, 0.076]),
];

fn main() {
    let scale = bench_scale();
    let restarts: usize = std::env::var("REPRO_RESTARTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let exp = sweep::tables23(scale, restarts);
    eprintln!("table3: scale {scale}, {restarts} restarts");
    let res = run_experiment(&exp, false).expect("experiment");

    println!(
        "{}",
        report::render_ratio_table(
            &exp,
            &res,
            report::Metric::Time,
            &format!("Table 3 (measured, scale {scale}): relative run time incl. construction, k=100"),
        )
    );
    println!("Table 3 (paper):");
    print!("{:<12}", "");
    for ds in &exp.datasets {
        print!(" {ds:>9}");
    }
    println!();
    for (name, vals) in PAPER {
        print!("{name:<12}");
        for v in vals {
            print!(" {v:>9.3}");
        }
        println!();
    }

    let mut sink = CsvSink::new("bench_table3.csv", "dataset,algorithm,ratio,paper_ratio");
    for (di, ds) in exp.datasets.iter().enumerate() {
        for &alg in &exp.algorithms {
            if alg == Algorithm::Standard {
                continue;
            }
            let measured = res
                .ratio_vs_standard(ds, alg, |c| c.total_time().as_secs_f64())
                .unwrap_or(f64::NAN);
            let paper = PAPER
                .iter()
                .find(|(n, _)| *n == alg.name())
                .map(|(_, v)| v[di])
                .unwrap_or(f64::NAN);
            sink.row(format!("{ds},{},{measured:.6},{paper}", alg.name()));
        }
    }
    sink.flush();
}
