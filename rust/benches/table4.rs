//! Bench: regenerate paper Table 4 — relative run time of the full
//! parameter sweep (16 values of k x restarts), cover/k-d trees amortized
//! across the sweep.
//!
//!     cargo bench --bench table4
//!
//! The k grid follows the paper's protocol scaled down by default
//! (REPRO_SWEEP_KS to override, e.g. REPRO_SWEEP_KS=full).

use covermeans::benchutil::{bench_scale, CsvSink};
use covermeans::coordinator::{report, run_experiment, sweep};
use covermeans::kmeans::Algorithm;

const PAPER: &[(&str, [f64; 8])] = &[
    ("Kanungo", [0.040, 0.112, 5.090, 0.162, 0.409, 0.903, 0.114, 0.116]),
    ("Elkan", [0.093, 0.609, 0.171, f64::NAN, 0.351, 0.187, 0.121, 0.065]),
    ("Hamerly", [0.211, 0.208, 0.453, 0.238, 0.338, 0.347, 0.284, 0.304]),
    ("Exponion", [0.040, 0.145, 0.492, 0.162, 0.154, 0.172, 0.077, 0.077]),
    ("Shallot", [0.037, 0.145, 0.414, 0.154, 0.121, 0.100, 0.059, 0.050]),
    ("Cover-means", [0.028, 0.059, 1.015, 0.093, 0.272, 0.248, 0.086, 0.077]),
    ("Hybrid", [0.020, 0.056, 0.463, 0.089, 0.122, 0.095, 0.055, 0.047]),
];

fn main() {
    let scale = bench_scale();
    let restarts: usize = std::env::var("REPRO_RESTARTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let mut exp = sweep::table4(scale, restarts);
    // The full 16-point grid up to k=1000 is heavy at bench scales; use an
    // 8-point grid by default, the paper's full grid with REPRO_SWEEP_KS=full.
    if std::env::var("REPRO_SWEEP_KS").as_deref() != Ok("full") {
        exp.ks = vec![10, 20, 40, 70, 100, 140, 200, 280];
    }
    eprintln!(
        "table4: scale {scale}, {restarts} restarts, {} k values (amortized trees)",
        exp.ks.len()
    );
    let res = run_experiment(&exp, false).expect("experiment");

    println!(
        "{}",
        report::render_ratio_table(
            &exp,
            &res,
            report::Metric::Time,
            &format!(
                "Table 4 (measured, scale {scale}): relative sweep run time, {} ks x {restarts} restarts",
                exp.ks.len()
            ),
        )
    );
    println!("Table 4 (paper; '-' = out of memory for Elkan on Traffic):");
    print!("{:<12}", "");
    for ds in &exp.datasets {
        print!(" {ds:>9}");
    }
    println!();
    for (name, vals) in PAPER {
        print!("{name:<12}");
        for v in vals {
            if v.is_nan() {
                print!(" {:>9}", "-");
            } else {
                print!(" {v:>9.3}");
            }
        }
        println!();
    }

    let mut sink = CsvSink::new("bench_table4.csv", "dataset,algorithm,ratio,paper_ratio");
    for (di, ds) in exp.datasets.iter().enumerate() {
        for &alg in &exp.algorithms {
            if alg == Algorithm::Standard {
                continue;
            }
            let measured = res
                .ratio_vs_standard(ds, alg, |c| c.total_time().as_secs_f64())
                .unwrap_or(f64::NAN);
            let paper = PAPER
                .iter()
                .find(|(n, _)| *n == alg.name())
                .map(|(_, v)| v[di])
                .unwrap_or(f64::NAN);
            sink.row(format!("{ds},{},{measured:.6},{paper}", alg.name()));
        }
    }
    sink.flush();
}
