//! Intra-fit data parallelism with exactness-preserving reductions.
//!
//! The paper's entire algorithm family has embarrassingly parallel
//! assignment phases: each point's (or subtree's) new assignment depends
//! only on its own stored state, the current centers, and the inter-center
//! matrix — never on another point's in-flight update. This module
//! exploits that with plain `std::thread::scope` workers (no external
//! dependencies) while keeping the repo's central invariant intact:
//!
//! **Determinism contract.** A fit with `threads = N` produces *byte
//! identical* results to `threads = 1` — same assignments, same iteration
//! count, same counted `distances`, same centers bit for bit. Three rules
//! enforce it:
//!
//! 1. **Per-point passes** ([`Parallelism::map_chunks`]) shard the point
//!    range into disjoint chunks. Chunk workers only write point-local
//!    state (labels, stored bounds) through [`SharedSlices`]; the integer
//!    reductions (changed counts, distance tallies) are order-free sums,
//!    and the floating-point center sums are *not* reduced per chunk at
//!    all — every driver accumulates them sequentially in canonical point
//!    order after the parallel pass, so the sums match the sequential
//!    implementation bit for bit at any thread count.
//! 2. **Tree passes** (Cover-means assignment, cover tree construction)
//!    are decomposed into a task list by a *thread-count-independent*
//!    expansion policy; per-task partial accumulators are merged in task
//!    order. Thread count only affects scheduling, never the task list or
//!    the merge order.
//! 3. Every distance computation a worker performs goes into a private
//!    [`crate::metrics::DistCounter`] whose total is folded back with
//!    integer addition, so counted distances stay exact.
//!
//! `rust/tests/parallel_exactness.rs` asserts the contract for every
//! algorithm on the synthetic datasets.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Thread budget for one fit (or one tree build).
///
/// `Parallelism::new(0)` resolves to the machine's available parallelism;
/// any other value is used as-is. The default is sequential execution,
/// which keeps the paper-replication protocols single-threaded unless a
/// caller opts in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    threads: usize,
}

impl Parallelism {
    /// A budget of `threads` workers; 0 means "all available cores".
    pub fn new(threads: usize) -> Parallelism {
        Parallelism { threads: resolve_threads(threads) }
    }

    /// Strictly sequential execution.
    pub fn sequential() -> Parallelism {
        Parallelism { threads: 1 }
    }

    /// The resolved worker count (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every task, returning the results **in task order**. Tasks are
    /// claimed work-stealing style by up to `threads` scoped workers; with
    /// one thread (or one task) everything runs inline on the caller.
    ///
    /// The closure must be deterministic per task: result `i` may be
    /// computed by any worker, but the returned vector is always ordered
    /// by task index, so order-sensitive reductions stay reproducible.
    pub fn run_tasks<T, R, F>(&self, tasks: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        if self.threads <= 1 || tasks.len() <= 1 {
            return tasks.into_iter().map(f).collect();
        }
        let n = tasks.len();
        let slots: Vec<Mutex<Option<T>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let workers = self.threads.min(n);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let task = slots[i]
                        .lock()
                        .unwrap()
                        .take()
                        .expect("task claimed twice");
                    let r = f(task);
                    *results[i].lock().unwrap() = Some(r);
                });
            }
        });
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker dropped a result"))
            .collect()
    }

    /// The chunk layout for a per-point pass over `0..n`: one chunk when
    /// sequential, otherwise `threads * 4` roughly equal chunks (bounded
    /// below so tiny inputs are not shredded). Per-point passes are
    /// invariant to the layout — each point's outcome depends only on its
    /// own prior state — so the layout may (and does) depend on the thread
    /// count without breaking the determinism contract.
    pub fn chunk_ranges(&self, n: usize) -> Vec<Range<usize>> {
        if n == 0 {
            return Vec::new();
        }
        if self.threads <= 1 {
            return vec![0..n];
        }
        const MIN_CHUNK: usize = 256;
        let target = self.threads * 4;
        let size = n.div_ceil(target).max(MIN_CHUNK);
        let mut out = Vec::with_capacity(n.div_ceil(size));
        let mut start = 0;
        while start < n {
            let end = (start + size).min(n);
            out.push(start..end);
            start = end;
        }
        out
    }

    /// Shard `0..n` with [`Parallelism::chunk_ranges`] and run `f` on every
    /// chunk, returning per-chunk results in chunk order.
    pub fn map_chunks<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(Range<usize>) -> R + Sync,
    {
        self.run_tasks(self.chunk_ranges(n), f)
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism::sequential()
    }
}

/// Resolve a configured thread count: 0 = all available cores, otherwise
/// the value itself (minimum 1).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// Hands out disjoint mutable subranges of one slice to chunk workers.
///
/// The borrow checker cannot see that chunk ranges are disjoint across
/// worker closures, so the split goes through a raw pointer. All uses in
/// this crate derive the ranges from [`Parallelism::chunk_ranges`] (or a
/// spatial-tree partition), which never overlap.
pub struct SharedSlices<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for SharedSlices<'_, T> {}
unsafe impl<T: Send> Sync for SharedSlices<'_, T> {}

impl<'a, T> SharedSlices<'a, T> {
    pub fn new(slice: &'a mut [T]) -> SharedSlices<'a, T> {
        SharedSlices {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Mutable view of `range`.
    ///
    /// # Safety
    /// Concurrent callers must use pairwise-disjoint ranges; the range
    /// must lie within the original slice.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range(&self, range: Range<usize>) -> &mut [T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        std::slice::from_raw_parts_mut(
            self.ptr.add(range.start),
            range.end - range.start,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_zero_is_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(Parallelism::new(1).threads(), 1);
        assert_eq!(Parallelism::sequential().threads(), 1);
    }

    #[test]
    fn run_tasks_preserves_order() {
        for t in [1usize, 2, 4] {
            let par = Parallelism::new(t);
            let tasks: Vec<usize> = (0..37).collect();
            let out = par.run_tasks(tasks, |i| i * 10);
            assert_eq!(out.len(), 37);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * 10, "threads={t}");
            }
        }
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for t in [1usize, 2, 4, 8] {
            let par = Parallelism::new(t);
            for n in [0usize, 1, 255, 256, 1000, 4097] {
                let ranges = par.chunk_ranges(n);
                let mut next = 0usize;
                for r in &ranges {
                    assert_eq!(r.start, next, "threads={t} n={n}");
                    assert!(r.end > r.start);
                    next = r.end;
                }
                assert_eq!(next, n, "threads={t} n={n}");
            }
        }
    }

    #[test]
    fn sequential_is_single_chunk() {
        assert_eq!(Parallelism::sequential().chunk_ranges(10_000), vec![0..10_000]);
    }

    #[test]
    fn map_chunks_results_in_chunk_order() {
        let par = Parallelism::new(4);
        let sums = par.map_chunks(10_000, |r| r.sum::<usize>());
        let total: usize = sums.into_iter().sum();
        assert_eq!(total, (0..10_000).sum::<usize>());
    }

    #[test]
    fn shared_slices_disjoint_writes() {
        let mut v = vec![0u32; 1000];
        let par = Parallelism::new(4);
        {
            let sh = SharedSlices::new(&mut v);
            par.map_chunks(1000, |r| {
                let s = unsafe { sh.range(r.clone()) };
                for (off, i) in r.enumerate() {
                    s[off] = i as u32 + 1;
                }
            });
        }
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u32 + 1);
        }
    }
}
