//! Intra-fit data parallelism with exactness-preserving reductions over a
//! **persistent worker pool**.
//!
//! The paper's entire algorithm family has embarrassingly parallel
//! assignment phases: each point's (or subtree's) new assignment depends
//! only on its own stored state, the current centers, and the inter-center
//! matrix — never on another point's in-flight update. This module
//! exploits that with plain `std` threads (no external dependencies) while
//! keeping the repo's central invariant intact:
//!
//! **Determinism contract.** A fit with `threads = N` produces *byte
//! identical* results to `threads = 1` — same assignments, same iteration
//! count, same counted `distances`, same centers bit for bit. Three rules
//! enforce it:
//!
//! 1. **Per-point passes** ([`Parallelism::map_chunks`]) shard the point
//!    range into disjoint chunks. Chunk workers only write point-local
//!    state (labels, stored bounds) through [`SharedSlices`]; the integer
//!    reductions (changed counts, distance tallies) are order-free sums,
//!    and the floating-point center sums are *not* reduced per chunk at
//!    all — every driver accumulates them sequentially in canonical point
//!    order after the parallel pass, so the sums match the sequential
//!    implementation bit for bit at any thread count.
//! 2. **Tree passes** (Cover-means assignment, cover tree construction,
//!    and the k-d-tree filtering recursions of Kanungo and Pelleg-Moore)
//!    are decomposed into a task list by a *thread-count-independent*
//!    expansion policy; per-task partial accumulators are merged in task
//!    order. Thread count only affects scheduling, never the task list or
//!    the merge order.
//! 3. Every distance computation a worker performs goes into a private
//!    [`crate::metrics::DistCounter`] whose total is folded back with
//!    integer addition, so counted distances stay exact.
//!
//! # Pool architecture
//!
//! A [`Parallelism`] with a budget of `N > 1` threads owns `N - 1`
//! long-lived OS workers (the caller is the N-th executor), created once
//! when the budget is constructed — by [`crate::kmeans::Workspace`] once
//! per fit, and shared across fits when the workspace is reused (the
//! coordinator keeps one per cell). The serving daemon
//! ([`crate::serve`]) stretches the same reuse to a process lifetime:
//! its batcher thread owns one `Parallelism` from startup to drain, so
//! every coalesced predict batch reuses the same parked workers and no
//! request ever pays a thread spawn. Each [`Parallelism::run_tasks`] call
//! publishes a single *batch job* — the work-stealing claim loop over the
//! task list — to the pool through a condvar-guarded slot; workers and the
//! caller race to claim task indices and the caller blocks until every
//! participant has finished before returning. Dispatch is therefore two
//! mutex/condvar handshakes instead of `N - 1` thread spawns+joins per
//! pass, which is what used to dominate small fits (PR 2 spawned scoped
//! threads in every iteration; `bench_smoke` tracks the per-dispatch cost
//! of both designs).
//!
//! Scheduling is still work-stealing and nondeterministic — determinism
//! comes solely from rules 1-3 above, which make the *results* independent
//! of which worker computed what. Cloning a `Parallelism` shares the same
//! pool (the handle is an `Arc`); the workers exit when the last handle
//! drops. A pool handle must only be dispatched from one thread at a time
//! (every use in this crate dispatches from the thread driving the fit),
//! and task closures must never dispatch on their own pool — both are
//! debug-asserted.
//!
//! The same machinery serves reads as well as fits: the batch-predict
//! pass of [`crate::kmeans::KMeansModel`] shards query rows over
//! [`Parallelism::map_chunks`] (labels and distances through
//! [`SharedSlices`], per-chunk distance tallies as integer sums), so
//! serving inherits the contract unchanged — predict at `threads = N` is
//! byte-identical to `threads = 1`.
//!
//! `rust/tests/parallel_exactness.rs` asserts the contract for every
//! algorithm — including the k-d-tree drivers, MiniBatch, k-means++
//! seeding, and model predict — on the synthetic datasets, in debug and
//! (via CI) release builds.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Best-effort worker-core pinning (config key `pin_workers`; Linux only).
///
/// On Linux this calls `sched_setaffinity(2)` directly (the crate has no
/// libc dependency; the serving daemon's signal handling sets the same
/// precedent for raw FFI). Failure is silently ignored — restricted
/// cpusets in containers make pinning a hint, never a correctness matter.
/// Everywhere else it is a no-op, so `pin_workers = 1` is portable
/// configuration. Pinning only affects *where* threads run; the
/// determinism contract (rules 1-3 above) never depends on placement.
#[cfg(target_os = "linux")]
mod affinity {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }

    /// Pin the calling thread to `core` (wrapped into the 1024-bit
    /// `cpu_set_t` a default kernel supports).
    pub fn pin_current_thread(core: usize) {
        let mut mask = [0u64; 16];
        let bit = core % (mask.len() * 64);
        mask[bit / 64] |= 1u64 << (bit % 64);
        // pid 0 = the calling thread; errors (EPERM under restricted
        // cpusets, EINVAL for offline cores) are deliberately ignored.
        unsafe {
            sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr());
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod affinity {
    pub fn pin_current_thread(_core: usize) {}
}

pub use affinity::pin_current_thread;

/// What the pool's job slot holds: the current batch's claim loop with its
/// lifetime erased. Soundness: the dispatching thread blocks until
/// `running == 0` and the slot is cleared before the pointee's stack frame
/// unwinds, so no worker can observe a dangling reference.
type ErasedJob = &'static (dyn Fn() + Sync);

struct PoolState {
    /// Current batch job, if a dispatch is in flight.
    job: Option<ErasedJob>,
    /// Batch sequence number; workers remember the last one they joined so
    /// a still-published batch is never re-entered by the same worker.
    seq: u64,
    /// Workers currently executing the batch job.
    running: usize,
    /// A worker task panicked during the current batch (re-raised on the
    /// dispatching thread once the batch drains).
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers wait here for a new batch (or shutdown).
    work_cv: Condvar,
    /// The dispatcher waits here for `running` to reach zero.
    done_cv: Condvar,
}

fn worker_loop(shared: &PoolShared) {
    let mut last_seq = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(f) = st.job {
                    if st.seq != last_seq {
                        last_seq = st.seq;
                        st.running += 1;
                        break f;
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // Run the claim loop; a panicking task must not wedge the pool, so
        // catch it and re-raise on the dispatcher.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        let mut st = shared.state.lock().unwrap();
        st.running -= 1;
        if result.is_err() {
            st.panicked = true;
        }
        if st.running == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// The long-lived worker set behind a multi-threaded [`Parallelism`].
struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` pool threads. With `pin`, worker `i` pins itself to
    /// core `(i + 1) % cores` — the dispatching thread (the pool's N-th
    /// executor) is *not* pinned, since it is the caller's thread and may
    /// be a short-lived batcher or test thread; leaving core 0 to it is
    /// why the workers start at core 1.
    fn new(workers: usize, pin: bool) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                job: None,
                seq: 0,
                running: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let cores = resolve_threads(0);
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("covermeans-pool-{i}"))
                    .spawn(move || {
                        if pin {
                            affinity::pin_current_thread((i + 1) % cores);
                        }
                        worker_loop(&sh)
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Publish `f` to every worker and run it on the calling thread too;
    /// returns once all participants finished. Panics raised by worker
    /// tasks are re-raised here after the batch drains.
    fn dispatch(&self, f: &(dyn Fn() + Sync)) {
        // Clears the job slot and waits out in-flight workers even when
        // the caller's own inline run unwinds, so the erased reference
        // never outlives its frame.
        struct Finish<'p>(&'p PoolShared);
        impl Drop for Finish<'_> {
            fn drop(&mut self) {
                let mut st = self.0.state.lock().unwrap();
                st.job = None;
                while st.running > 0 {
                    st = self.0.done_cv.wait(st).unwrap();
                }
            }
        }

        // Safety: see `ErasedJob` — the guard below blocks until no worker
        // holds the reference before this frame can unwind or return.
        let erased = unsafe {
            std::mem::transmute::<&(dyn Fn() + Sync), ErasedJob>(f)
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(
                st.job.is_none(),
                "nested or concurrent dispatch on one worker pool"
            );
            st.seq = st.seq.wrapping_add(1);
            st.panicked = false;
            st.job = Some(erased);
            self.shared.work_cv.notify_all();
        }
        let guard = Finish(&self.shared);
        f(); // the caller is a participant, not an idle waiter
        drop(guard);
        let panicked = {
            let mut st = self.shared.state.lock().unwrap();
            std::mem::take(&mut st.panicked)
        };
        if panicked {
            panic!("a worker task panicked during a parallel pass");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Thread budget for one fit (or one tree build), backed by a persistent
/// worker pool when the budget exceeds one.
///
/// `Parallelism::new(0)` resolves to the machine's available parallelism;
/// any other value is used as-is. The default is sequential execution,
/// which keeps the paper-replication protocols single-threaded unless a
/// caller opts in. Construction spawns the pool workers (`threads - 1`
/// of them); [`Clone`] shares the same pool, so one budget can serve a
/// whole sweep of fits without respawning (see
/// [`crate::kmeans::Workspace::parallelism`]).
#[derive(Clone)]
pub struct Parallelism {
    threads: usize,
    pinned: bool,
    pool: Option<Arc<WorkerPool>>,
}

impl std::fmt::Debug for Parallelism {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Parallelism")
            .field("threads", &self.threads)
            .field("pinned", &self.pinned)
            .field("pooled", &self.pool.is_some())
            .finish()
    }
}

impl Parallelism {
    /// A budget of `threads` workers; 0 means "all available cores".
    /// Spawns the persistent pool when the resolved budget exceeds one.
    pub fn new(threads: usize) -> Parallelism {
        Parallelism::new_opts(threads, false)
    }

    /// [`Parallelism::new`] with opt-in worker-core pinning (see
    /// [`pin_current_thread`]): each pool worker is pinned to its own core
    /// at spawn, which steadies tail latency for long-lived pools (the
    /// serving daemon) on multi-socket or busy hosts. No effect on
    /// results — only on placement — and a no-op outside Linux.
    pub fn new_opts(threads: usize, pin: bool) -> Parallelism {
        let threads = resolve_threads(threads);
        let pool =
            (threads > 1).then(|| Arc::new(WorkerPool::new(threads - 1, pin)));
        Parallelism { threads, pinned: pin, pool }
    }

    /// Strictly sequential execution (no pool).
    pub fn sequential() -> Parallelism {
        Parallelism { threads: 1, pinned: false, pool: None }
    }

    /// The resolved worker count (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Whether the pool workers were pinned at spawn.
    pub fn pinned(&self) -> bool {
        self.pinned
    }

    /// Run every task, returning the results **in task order**. Tasks are
    /// claimed work-stealing style by the pool workers plus the caller;
    /// with one thread (or one task) everything runs inline on the caller.
    ///
    /// The closure must be deterministic per task: result `i` may be
    /// computed by any worker, but the returned vector is always ordered
    /// by task index, so order-sensitive reductions stay reproducible.
    pub fn run_tasks<T, R, F>(&self, tasks: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = tasks.len();
        let Some(pool) = self.pool.as_ref().filter(|_| n > 1) else {
            return tasks.into_iter().map(f).collect();
        };
        let slots: Vec<Mutex<Option<T>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let claim_loop = || loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let task = slots[i]
                .lock()
                .unwrap()
                .take()
                .expect("task claimed twice");
            let r = f(task);
            *results[i].lock().unwrap() = Some(r);
        };
        pool.dispatch(&claim_loop);
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker dropped a result"))
            .collect()
    }

    /// The chunk layout for a per-point pass over `0..n`: one chunk when
    /// sequential, otherwise `threads * 4` roughly equal chunks (bounded
    /// below so tiny inputs are not shredded). Per-point passes are
    /// invariant to the layout — each point's outcome depends only on its
    /// own prior state — so the layout may (and does) depend on the thread
    /// count without breaking the determinism contract.
    pub fn chunk_ranges(&self, n: usize) -> Vec<Range<usize>> {
        if n == 0 {
            return Vec::new();
        }
        if self.threads <= 1 {
            return vec![0..n];
        }
        const MIN_CHUNK: usize = 256;
        let target = self.threads * 4;
        let size = n.div_ceil(target).max(MIN_CHUNK);
        let mut out = Vec::with_capacity(n.div_ceil(size));
        let mut start = 0;
        while start < n {
            let end = (start + size).min(n);
            out.push(start..end);
            start = end;
        }
        out
    }

    /// Shard `0..n` with [`Parallelism::chunk_ranges`] and run `f` on every
    /// chunk, returning per-chunk results in chunk order.
    pub fn map_chunks<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(Range<usize>) -> R + Sync,
    {
        self.run_tasks(self.chunk_ranges(n), f)
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism::sequential()
    }
}

/// Thread-count-independent task expansion shared by the tree passes
/// (cover, dual-tree, and the k-d filtering engine): repeatedly pick the
/// **first strictly-heaviest** splittable task and let `visit` replace it
/// with its children, until `target` tasks exist or nothing splits.
///
/// `weight` returns `None` for tasks that must not be split further
/// (leaves, subtrees below the pass's minimum weight). Determinism
/// contract rule 2 lives here: `target` is a fixed constant at every call
/// site — never derived from the thread count — and the selection policy
/// (first index wins ties, strict `>` comparison) is a pure function of
/// the task list, so the resulting task order (and therefore every
/// order-sensitive accumulator merge downstream) depends on the data
/// alone.
pub fn expand_tasks<T>(
    tasks: &mut Vec<T>,
    target: usize,
    weight: impl Fn(&T) -> Option<u32>,
    mut visit: impl FnMut(T, &mut Vec<T>),
) {
    while tasks.len() < target {
        let mut best: Option<(usize, u32)> = None;
        for (i, t) in tasks.iter().enumerate() {
            if let Some(w) = weight(t) {
                let heavier = match best {
                    None => true,
                    Some((_, bw)) => w > bw,
                };
                if heavier {
                    best = Some((i, w));
                }
            }
        }
        let Some((idx, _)) = best else { break };
        let t = tasks.remove(idx);
        visit(t, tasks);
    }
}

/// Resolve a configured thread count: 0 = all available cores, otherwise
/// the value itself (minimum 1).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// The pre-pool dispatcher: run every task on up to `threads` *freshly
/// spawned* scoped workers, results in task order. Kept only as the
/// spawn-overhead baseline for `bench_smoke` (the pool must beat this on
/// per-iteration dispatch cost); library code always goes through
/// [`Parallelism::run_tasks`].
#[doc(hidden)]
pub fn run_tasks_scoped<T, R, F>(threads: usize, tasks: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    if threads <= 1 || tasks.len() <= 1 {
        return tasks.into_iter().map(f).collect();
    }
    let n = tasks.len();
    let slots: Vec<Mutex<Option<T>>> =
        tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = threads.min(n);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let task = slots[i]
                    .lock()
                    .unwrap()
                    .take()
                    .expect("task claimed twice");
                let r = f(task);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker dropped a result"))
        .collect()
}

/// Hands out disjoint mutable subranges of one slice to chunk workers.
///
/// The borrow checker cannot see that chunk ranges are disjoint across
/// worker closures, so the split goes through a raw pointer. All uses in
/// this crate derive the ranges from [`Parallelism::chunk_ranges`] (or a
/// spatial-tree partition), which never overlap.
pub struct SharedSlices<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for SharedSlices<'_, T> {}
unsafe impl<T: Send> Sync for SharedSlices<'_, T> {}

impl<'a, T> SharedSlices<'a, T> {
    pub fn new(slice: &'a mut [T]) -> SharedSlices<'a, T> {
        SharedSlices {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Mutable view of `range`.
    ///
    /// # Safety
    /// Concurrent callers must use pairwise-disjoint ranges; the range
    /// must lie within the original slice.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range(&self, range: Range<usize>) -> &mut [T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        std::slice::from_raw_parts_mut(
            self.ptr.add(range.start),
            range.end - range.start,
        )
    }
}

/// Raw-pointer view of one slice for *scattered* disjoint-index writes —
/// the tree passes' per-subtree label updates (a spatial tree partitions
/// point indices across subtrees, but not into contiguous ranges) and the
/// inter-center matrix's mirrored pair writes. Unlike [`SharedSlices`],
/// ownership is per index: concurrent users must touch pairwise-disjoint
/// index sets.
pub struct ScatterSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for ScatterSlice<'_, T> {}
unsafe impl<T: Send> Sync for ScatterSlice<'_, T> {}

impl<T> Clone for ScatterSlice<'_, T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for ScatterSlice<'_, T> {}

impl<'a, T> ScatterSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> ScatterSlice<'a, T> {
        ScatterSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// # Safety
    /// `i` must be in bounds and owned by the calling task (no concurrent
    /// reader or writer of the same index).
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }

    /// # Safety
    /// `i` must be in bounds and owned by the calling task (no concurrent
    /// writer of the same index).
    #[inline]
    pub unsafe fn read(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        *self.ptr.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_zero_is_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(Parallelism::new(1).threads(), 1);
        assert_eq!(Parallelism::sequential().threads(), 1);
    }

    #[test]
    fn run_tasks_preserves_order() {
        for t in [1usize, 2, 4] {
            let par = Parallelism::new(t);
            let tasks: Vec<usize> = (0..37).collect();
            let out = par.run_tasks(tasks, |i| i * 10);
            assert_eq!(out.len(), 37);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * 10, "threads={t}");
            }
        }
    }

    #[test]
    fn pool_survives_many_dispatches() {
        // The point of the persistent pool: one Parallelism, many batches
        // (one per iteration in a fit), no respawn. Also exercises reuse
        // after empty and single-task batches, which bypass the pool.
        let par = Parallelism::new(4);
        for round in 0..100usize {
            let tasks: Vec<usize> = (0..round % 7).collect();
            let out = par.run_tasks(tasks, |i| i + round);
            assert_eq!(out.len(), round % 7);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i + round, "round={round}");
            }
        }
    }

    #[test]
    fn cloned_handles_share_one_pool() {
        let a = Parallelism::new(3);
        let b = a.clone();
        drop(a); // workers must stay alive for the surviving handle
        let out = b.run_tasks((0..10).collect::<Vec<usize>>(), |i| i * 2);
        assert_eq!(out, (0..10).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_baseline_matches_pool() {
        let par = Parallelism::new(4);
        let a = par.run_tasks((0..23).collect::<Vec<usize>>(), |i| i * i);
        let b = run_tasks_scoped(4, (0..23).collect::<Vec<usize>>(), |i| i * i);
        assert_eq!(a, b);
    }

    #[test]
    fn worker_panic_propagates_and_pool_recovers() {
        let par = Parallelism::new(4);
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            par.run_tasks((0..64).collect::<Vec<usize>>(), |i| {
                assert!(i != 13, "injected failure");
                i
            })
        }));
        assert!(boom.is_err(), "task panic must surface to the dispatcher");
        // The pool must stay usable after a failed batch.
        let out = par.run_tasks((0..8).collect::<Vec<usize>>(), |i| i + 1);
        assert_eq!(out, (1..9).collect::<Vec<_>>());
    }

    #[test]
    fn pinned_pool_matches_unpinned() {
        let pinned = Parallelism::new_opts(4, true);
        assert!(pinned.pinned());
        let plain = Parallelism::new(4);
        assert!(!plain.pinned());
        let a = pinned.run_tasks((0..100).collect::<Vec<usize>>(), |i| i * 3);
        let b = plain.run_tasks((0..100).collect::<Vec<usize>>(), |i| i * 3);
        assert_eq!(a, b, "pinning must only move threads, never results");
        // Direct pinning of the calling thread is also safe (and a no-op
        // off Linux); out-of-range cores wrap instead of erroring.
        pin_current_thread(0);
        pin_current_thread(100_000);
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for t in [1usize, 2, 4, 8] {
            let par = Parallelism::new(t);
            for n in [0usize, 1, 255, 256, 1000, 4097] {
                let ranges = par.chunk_ranges(n);
                let mut next = 0usize;
                for r in &ranges {
                    assert_eq!(r.start, next, "threads={t} n={n}");
                    assert!(r.end > r.start);
                    next = r.end;
                }
                assert_eq!(next, n, "threads={t} n={n}");
            }
        }
    }

    #[test]
    fn sequential_is_single_chunk() {
        assert_eq!(Parallelism::sequential().chunk_ranges(10_000), vec![0..10_000]);
    }

    #[test]
    fn map_chunks_results_in_chunk_order() {
        let par = Parallelism::new(4);
        let sums = par.map_chunks(10_000, |r| r.sum::<usize>());
        let total: usize = sums.into_iter().sum();
        assert_eq!(total, (0..10_000).sum::<usize>());
    }

    #[test]
    fn expand_tasks_first_heaviest_and_target() {
        // Tasks are (weight, id); splitting halves the weight into two
        // children. The policy must pick the first strictly-heaviest task
        // each round and stop exactly at the target.
        let mut tasks: Vec<(u32, u32)> = vec![(8, 0), (8, 1), (2, 2)];
        let mut visited = Vec::new();
        expand_tasks(
            &mut tasks,
            5,
            |t| (t.0 >= 4).then_some(t.0),
            |t, out| {
                visited.push(t.1);
                out.push((t.0 / 2, t.1 * 10 + 1));
                out.push((t.0 / 2, t.1 * 10 + 2));
            },
        );
        // First round splits id 0 (first of the two weight-8 ties), second
        // splits id 1; then 5 tasks exist and expansion stops.
        assert_eq!(visited, vec![0, 1]);
        assert_eq!(tasks.len(), 5);
        // Unsplittable everything: expansion is a no-op.
        let mut flat: Vec<(u32, u32)> = vec![(1, 0), (1, 1)];
        expand_tasks(&mut flat, 10, |_| None, |_, _| panic!("no split"));
        assert_eq!(flat.len(), 2);
    }

    #[test]
    fn shared_slices_disjoint_writes() {
        let mut v = vec![0u32; 1000];
        let par = Parallelism::new(4);
        {
            let sh = SharedSlices::new(&mut v);
            par.map_chunks(1000, |r| {
                let s = unsafe { sh.range(r.clone()) };
                for (off, i) in r.enumerate() {
                    s[off] = i as u32 + 1;
                }
            });
        }
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u32 + 1);
        }
    }

    #[test]
    fn scatter_slice_disjoint_indices() {
        let mut v = vec![0u32; 512];
        let par = Parallelism::new(4);
        {
            let sc = ScatterSlice::new(&mut v);
            // Strided index sets: task t owns indices i with i % 4 == t.
            par.run_tasks((0..4usize).collect(), |t| {
                let mut i = t;
                while i < 512 {
                    unsafe {
                        sc.write(i, i as u32 + 1);
                        assert_eq!(sc.read(i), i as u32 + 1);
                    }
                    i += 4;
                }
            });
        }
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u32 + 1);
        }
    }
}
