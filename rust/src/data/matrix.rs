//! Dense row-major matrix of `f64` — the dataset container.
//!
//! The L3 algorithms run in `f64` (matching the paper's ELKI/Java doubles:
//! the stored-bounds algorithms rely on bound arithmetic that must never be
//! *optimistically* wrong, which f32 rounding could make it). The XLA path
//! converts chunks to `f32` at the runtime boundary.

/// Row-major `rows x cols` matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Wrap an existing buffer (must have exactly `rows * cols` items).
    pub fn from_vec(data: Vec<f64>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { data, rows, cols }
    }

    /// Build from row slices (all the same length).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { data, rows: r, cols: c }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Flat read-only view of the backing buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Iterator over row slices.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[f64]> {
        self.data.chunks_exact(self.cols.max(1))
    }

    /// Copy the given rows into a new matrix (e.g. sampled initial centers).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }

    /// Per-column min/max over all rows (used by k-d tree bounding boxes
    /// and dataset sanity checks). Returns `(mins, maxs)`.
    pub fn column_bounds(&self) -> (Vec<f64>, Vec<f64>) {
        let mut mins = vec![f64::INFINITY; self.cols];
        let mut maxs = vec![f64::NEG_INFINITY; self.cols];
        for row in self.iter_rows() {
            for (j, &v) in row.iter().enumerate() {
                if v < mins[j] {
                    mins[j] = v;
                }
                if v > maxs[j] {
                    maxs[j] = v;
                }
            }
        }
        (mins, maxs)
    }

    /// Convert a set of rows to a packed f32 buffer (XLA boundary).
    pub fn rows_to_f32(&self, idx: &[usize], out: &mut Vec<f32>) {
        out.clear();
        out.reserve(idx.len() * self.cols);
        for &i in idx {
            for &v in self.row(i) {
                out.push(v as f32);
            }
        }
    }
}

/// Squared Euclidean distance between two equal-length slices.
///
/// This is the *uncounted* primitive; algorithm code must go through
/// [`crate::metrics::DistCounter`] so the paper's "number of distance
/// computations" metric is tracked. Since the kernels refactor this is a
/// shim over [`crate::kernels::sqdist`] — the runtime-dispatched SIMD
/// kernel, bit-identical to the historical 4-accumulator scalar loop
/// (which now lives in [`crate::kernels::scalar`]).
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    crate::kernels::sqdist(a, b)
}

/// Euclidean distance (shim over [`crate::kernels::dist`]).
#[inline]
pub fn dist(a: &[f64], b: &[f64]) -> f64 {
    crate::kernels::dist(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_accessors() {
        let mut m = Matrix::zeros(3, 2);
        m.set(1, 1, 5.0);
        assert_eq!(m.get(1, 1), 5.0);
        assert_eq!(m.row(1), &[0.0, 5.0]);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 2);
    }

    #[test]
    fn from_rows_and_select() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.row(0), &[5.0, 6.0]);
        assert_eq!(s.row(1), &[1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "buffer size mismatch")]
    fn from_vec_checks_size() {
        Matrix::from_vec(vec![1.0; 5], 2, 3);
    }

    #[test]
    fn column_bounds() {
        let m = Matrix::from_rows(&[&[1.0, -2.0], &[3.0, 0.0]]);
        let (mins, maxs) = m.column_bounds();
        assert_eq!(mins, vec![1.0, -2.0]);
        assert_eq!(maxs, vec![3.0, 0.0]);
    }

    #[test]
    fn distances() {
        assert_eq!(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(dist(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
        // odd length exercising the tail loop
        assert_eq!(sqdist(&[1.0; 7], &[2.0; 7]), 7.0);
    }

    #[test]
    fn rows_to_f32_packs() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let mut out = Vec::new();
        m.rows_to_f32(&[1], &mut out);
        assert_eq!(out, vec![3.0f32, 4.0]);
    }
}
