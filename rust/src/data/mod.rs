//! Dataset substrate: matrix container, synthetic generators, registry, I/O.

pub mod io;
pub mod matrix;
pub mod registry;
pub mod source;
pub mod synth;

pub use matrix::{dist, sqdist, Matrix};
pub use source::{read_dmat, write_dmat, DataSource, SourceBackend, SourceView};
