//! Synthetic analogs of the paper's evaluation datasets (Table 1).
//!
//! The real datasets (ALOI, autoencoded MNIST, CovType, Istanbul tweets,
//! UK traffic accidents, KDD04-bio) are not available in this environment,
//! so each generator reproduces the *statistical character that drives the
//! relative algorithm performance* the paper reports:
//!
//! * `aloi`     — many tight micro-clusters (object views): tree-friendly,
//!               moderate dimension, non-negative normalized histograms.
//! * `mnist`    — few broad clusters with low intrinsic dimension embedded
//!               in `d` ambient dims (the autoencoder bottleneck sweep).
//! * `covtype`  — large N, skewed component sizes, quantized attributes.
//! * `istanbul` — 2-d urban hotspot mixture (heavy spatial clustering).
//! * `traffic`  — 2-d, extreme near-duplicates from a Zipf-weighted set of
//!               discrete locations (the tree best case of the paper).
//! * `kdd04`    — 74-d heavily overlapping anisotropic mixture + outliers
//!               (the tree worst case: Kanungo > 1.0x distances).
//!
//! All generators are deterministic in `(seed, scale)` and sized as
//! `ceil(N_paper * scale)`.

use crate::data::matrix::Matrix;
use crate::rng::{Rng, Zipf};

/// Paper sizes (Table 1).
pub const ALOI_N: usize = 110_250;
pub const MNIST_N: usize = 70_000;
pub const COVTYPE_N: usize = 581_012;
pub const ISTANBUL_N: usize = 346_463;
pub const TRAFFIC_N: usize = 6_200_000;
pub const KDD04_N: usize = 145_751;

fn scaled(n: usize, scale: f64) -> usize {
    ((n as f64 * scale).ceil() as usize).max(64)
}

/// ALOI analog: `n_objects` tight view-clusters of sparse non-negative
/// L1-normalized "color histograms" in `d` dims (paper: d in {27, 64}).
pub fn aloi(d: usize, scale: f64, seed: u64) -> Matrix {
    let n = scaled(ALOI_N, scale);
    let mut rng = Rng::derive(seed, "datasets/aloi");
    // 1000 physical objects, ~110 views each at scale 1.0. Keep the number
    // of micro-clusters proportional to N so views-per-object stays ~110.
    let n_objects = (n / 110).max(8);
    let mut proto = Matrix::zeros(n_objects, d);
    for o in 0..n_objects {
        let row = proto.row_mut(o);
        // Sparse exponential histogram: ~40% active bins.
        let mut total = 0.0;
        for v in row.iter_mut() {
            if rng.f64() < 0.4 {
                *v = rng.exp();
                total += *v;
            }
        }
        if total <= 0.0 {
            row[rng.below(d)] = 1.0;
            total = 1.0;
        }
        for v in row.iter_mut() {
            *v /= total;
        }
    }
    let mut out = Matrix::zeros(n, d);
    for i in 0..n {
        let o = i % n_objects; // balanced views per object
        let row = out.row_mut(i);
        row.copy_from_slice(proto.row(o));
        // Small view-to-view variation (illumination/angle), keep >= 0 and
        // re-normalize so rows stay on the simplex like histograms.
        let mut total = 0.0;
        for v in row.iter_mut() {
            *v = (*v + 0.01 * rng.gaussian() * (*v).max(0.02)).max(0.0);
            total += *v;
        }
        if total > 0.0 {
            for v in row.iter_mut() {
                *v /= total;
            }
        }
    }
    out
}

/// MNIST-autoencoder analog: 10 broad anisotropic clusters living on an
/// 8-dim manifold, embedded linearly into `d` ambient dims plus noise
/// (paper: d in {10, 20, 30, 40, 50}).
pub fn mnist(d: usize, scale: f64, seed: u64) -> Matrix {
    let n = scaled(MNIST_N, scale);
    let mut rng = Rng::derive(seed, "datasets/mnist");
    let intrinsic = 8.min(d);
    let classes = 10;
    // Class means and per-class axis scales in intrinsic space.
    let mut means = Matrix::zeros(classes, intrinsic);
    let mut scales = Matrix::zeros(classes, intrinsic);
    for c in 0..classes {
        for j in 0..intrinsic {
            means.set(c, j, 4.0 * rng.gaussian());
            scales.set(c, j, 0.4 + rng.f64() * 1.2);
        }
    }
    // Shared random embedding R^intrinsic -> R^d.
    let mut embed = Matrix::zeros(intrinsic, d);
    for j in 0..intrinsic {
        for a in 0..d {
            embed.set(j, a, rng.gaussian() / (intrinsic as f64).sqrt());
        }
    }
    let mut out = Matrix::zeros(n, d);
    let mut z = vec![0.0; intrinsic];
    for i in 0..n {
        let c = i % classes;
        for (j, zj) in z.iter_mut().enumerate() {
            *zj = means.get(c, j) + scales.get(c, j) * rng.gaussian();
        }
        let row = out.row_mut(i);
        for a in 0..d {
            let mut acc = 0.0;
            for (j, zj) in z.iter().enumerate() {
                acc += zj * embed.get(j, a);
            }
            row[a] = acc + 0.05 * rng.gaussian(); // ambient noise
        }
    }
    out
}

/// CovType analog: 54 attributes, 7 components with strongly skewed sizes
/// (two dominate, like Spruce-Fir/Lodgepole in the real data), elongated
/// covariances, and most attributes quantized to integer grids.
pub fn covtype(scale: f64, seed: u64) -> Matrix {
    let n = scaled(COVTYPE_N, scale);
    let d = 54;
    let mut rng = Rng::derive(seed, "datasets/covtype");
    let comps = 7;
    let weights = [0.365, 0.488, 0.062, 0.005, 0.016, 0.030, 0.035];
    let mut means = Matrix::zeros(comps, d);
    let mut sds = Matrix::zeros(comps, d);
    for c in 0..comps {
        for j in 0..d {
            means.set(c, j, 100.0 * rng.gaussian());
            // Elongated but well-separated: per-axis sds spanning two
            // orders of magnitude, small against the +-100 mean spread
            // (the real cartographic classes are tight integer blocks).
            sds.set(c, j, 10.0_f64.powf(rng.range(-0.5, 1.5)));
        }
    }
    let mut out = Matrix::zeros(n, d);
    for i in 0..n {
        let c = rng.choose_weighted(&weights).unwrap();
        let row = out.row_mut(i);
        for j in 0..d {
            let v = means.get(c, j) + sds.get(c, j) * rng.gaussian();
            // First 10 attrs continuous-ish; the rest quantized (the real
            // data is full of integer and one-hot-ish columns).
            row[j] = if j < 10 { v } else { v.round() };
        }
    }
    out
}

/// Istanbul-tweets analog: 2-d mixture of ~200 urban hotspots with
/// log-normal sizes and spreads, plus 4% diffuse background.
pub fn istanbul(scale: f64, seed: u64) -> Matrix {
    let n = scaled(ISTANBUL_N, scale);
    let mut rng = Rng::derive(seed, "datasets/istanbul");
    let hotspots = 200;
    let mut cx = vec![0.0; hotspots];
    let mut cy = vec![0.0; hotspots];
    let mut sp = vec![0.0; hotspots];
    let mut w = vec![0.0; hotspots];
    for h in 0..hotspots {
        cx[h] = rng.range(28.5, 29.5); // lon-ish
        cy[h] = rng.range(40.8, 41.4); // lat-ish
        sp[h] = 0.002 * rng.lognormal(0.0, 1.0);
        w[h] = rng.lognormal(0.0, 1.5);
    }
    let mut out = Matrix::zeros(n, 2);
    for i in 0..n {
        let row = out.row_mut(i);
        if rng.f64() < 0.04 {
            row[0] = rng.range(28.5, 29.5);
            row[1] = rng.range(40.8, 41.4);
        } else {
            let h = rng.choose_weighted(&w).unwrap();
            row[0] = cx[h] + sp[h] * rng.gaussian();
            row[1] = cy[h] + sp[h] * rng.gaussian();
        }
    }
    out
}

/// Traffic-accidents analog: draws from a finite set of "intersections"
/// with Zipf-distributed frequency and metre-scale jitter — the extreme
/// near-duplicate regime in which the paper's tree methods assign
/// thousands of points at once (Table 2 column `Traffic`: ~0.000-0.001).
///
/// `n` defaults to 1/6.2 of the paper's 6.2M via `scale`; pass
/// `scale = 1.0` for the full-size set (fits in ~100 MB).
pub fn traffic(scale: f64, seed: u64) -> Matrix {
    let n = scaled(TRAFFIC_N, scale);
    let mut rng = Rng::derive(seed, "datasets/traffic");
    // Intersection grid follows the same hotspot process as istanbul but
    // over a country-sized box; the number of distinct sites scales
    // sub-linearly so duplicates stay dominant at every scale.
    let sites = ((n as f64).sqrt() as usize * 20).clamp(1000, 50_000);
    let mut sx = vec![0.0; sites];
    let mut sy = vec![0.0; sites];
    for s in 0..sites {
        sx[s] = rng.range(-6.0, 2.0); // UK-ish lon span
        sy[s] = rng.range(50.0, 58.0); // lat span
    }
    let zipf = Zipf::new(sites, 1.05);
    let mut out = Matrix::zeros(n, 2);
    for i in 0..n {
        let s = zipf.sample(&mut rng);
        let row = out.row_mut(i);
        // ~10 m jitter (1e-4 degrees) — near-duplicates, not exact ones.
        row[0] = sx[s] + 1e-4 * rng.gaussian();
        row[1] = sy[s] + 1e-4 * rng.gaussian();
    }
    out
}

/// KDD04-bio analog: 74-d, 50 heavily overlapping anisotropic components
/// plus 5% wide-box outliers. High dimension + overlap defeats geometric
/// pruning (the paper's Kanungo row exceeds the Standard algorithm here).
pub fn kdd04(scale: f64, seed: u64) -> Matrix {
    let n = scaled(KDD04_N, scale);
    let d = 74;
    let mut rng = Rng::derive(seed, "datasets/kdd04");
    let comps = 50;
    let mut means = Matrix::zeros(comps, d);
    let mut sds = Matrix::zeros(comps, d);
    for c in 0..comps {
        for j in 0..d {
            // Means packed close together relative to the spreads => heavy
            // overlap; sds heavy-tailed across axes.
            means.set(c, j, 1.5 * rng.gaussian());
            sds.set(c, j, rng.lognormal(0.0, 0.75));
        }
    }
    let mut out = Matrix::zeros(n, d);
    for i in 0..n {
        let row = out.row_mut(i);
        if rng.f64() < 0.05 {
            for v in row.iter_mut() {
                *v = rng.range(-20.0, 20.0);
            }
        } else {
            let c = rng.below(comps);
            for j in 0..d {
                row[j] = means.get(c, j) + sds.get(c, j) * rng.gaussian();
            }
        }
    }
    out
}

/// Simple isotropic Gaussian-mixture generator for tests and examples.
pub fn gaussian_blobs(
    n: usize,
    d: usize,
    k: usize,
    spread: f64,
    seed: u64,
) -> Matrix {
    let mut rng = Rng::derive(seed, "datasets/blobs");
    let mut centers = Matrix::zeros(k, d);
    for c in 0..k {
        for j in 0..d {
            centers.set(c, j, 10.0 * rng.gaussian());
        }
    }
    let mut out = Matrix::zeros(n, d);
    for i in 0..n {
        let c = i % k;
        let row = out.row_mut(i);
        for j in 0..d {
            row[j] = centers.get(c, j) + spread * rng.gaussian();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dist;

    #[test]
    fn sizes_scale() {
        let m = istanbul(0.001, 1);
        assert_eq!(m.rows(), (ISTANBUL_N as f64 * 0.001).ceil() as usize);
        assert_eq!(m.cols(), 2);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = mnist(10, 0.001, 9);
        let b = mnist(10, 0.001, 9);
        let c = mnist(10, 0.001, 10);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn aloi_rows_are_normalized_histograms() {
        let m = aloi(27, 0.001, 2);
        for i in 0..m.rows() {
            let row = m.row(i);
            assert!(row.iter().all(|&v| v >= 0.0));
            let sum: f64 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "row {i} sums to {sum}");
        }
    }

    #[test]
    fn aloi_views_form_tight_clusters() {
        let m = aloi(27, 0.002, 3);
        let n_objects = (m.rows() / 110).max(8);
        // Same-object views must be far closer than cross-object pairs.
        let same = dist(m.row(0), m.row(n_objects));
        let cross = dist(m.row(0), m.row(1));
        assert!(same * 5.0 < cross, "same {same} cross {cross}");
    }

    #[test]
    fn traffic_has_near_duplicates() {
        let m = traffic(0.0002, 4);
        // Nearest-neighbour distance of point 0 must be metre-scale for
        // most points: count pairs within 1e-3 of point 0's site.
        let mut close = 0;
        for i in 1..m.rows() {
            if dist(m.row(0), m.row(i)) < 1e-3 {
                close += 1;
            }
        }
        assert!(close >= 1, "expected duplicate sites (zipf head)");
    }

    #[test]
    fn covtype_quantized_tail_attrs() {
        let m = covtype(0.0001, 5);
        for i in 0..m.rows().min(50) {
            for j in 10..54 {
                let v = m.get(i, j);
                assert_eq!(v, v.round());
            }
        }
    }

    #[test]
    fn kdd04_shape_and_outliers() {
        let m = kdd04(0.001, 6);
        assert_eq!(m.cols(), 74);
        let (mins, maxs) = m.column_bounds();
        // Outlier box is wide.
        assert!(mins.iter().any(|&v| v < -10.0));
        assert!(maxs.iter().any(|&v| v > 10.0));
    }

    #[test]
    fn blobs_cluster_structure() {
        let m = gaussian_blobs(300, 4, 3, 0.1, 7);
        // points 0 and 3 share a blob; 0 and 1 do not
        assert!(dist(m.row(0), m.row(3)) < dist(m.row(0), m.row(1)));
    }
}
