//! Dataset and result I/O: CSV matrices and small binary formats.
//!
//! CSV is used for interchange (results/, external data); the binary `.fmat`
//! format caches generated datasets between benchmark runs (a header
//! `FMAT1\n<rows> <cols>\n` followed by little-endian f64 rows). The
//! little-endian primitives in [`bin`] are shared with the trained-model
//! format of [`crate::kmeans::KMeansModel`] (`.kmm` files).

use std::fs::File;
use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::matrix::Matrix;

/// Little-endian binary primitives shared by the `.fmat` dataset cache and
/// the `.kmm` trained-model format: append-style writers over a `Vec<u8>`
/// and a bounds-checked [`bin::Reader`] whose every read fails cleanly on
/// truncated input instead of panicking.
pub mod bin {
    use anyhow::{bail, Result};

    pub fn put_u32(out: &mut Vec<u8>, v: u32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    /// Writes the exact bit pattern (`to_bits`), so round-trips are
    /// bit-identical for every value including -0.0 and NaNs.
    pub fn put_f64(out: &mut Vec<u8>, v: f64) {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// Forward-only bounds-checked reader over a byte slice.
    pub struct Reader<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl<'a> Reader<'a> {
        pub fn new(buf: &'a [u8]) -> Reader<'a> {
            Reader { buf, pos: 0 }
        }

        /// Bytes not yet consumed.
        pub fn remaining(&self) -> usize {
            self.buf.len() - self.pos
        }

        /// Consume exactly `n` bytes.
        pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
            if self.remaining() < n {
                bail!(
                    "truncated input: wanted {n} bytes at offset {}, {} left",
                    self.pos,
                    self.remaining()
                );
            }
            let s = &self.buf[self.pos..self.pos + n];
            self.pos += n;
            Ok(s)
        }

        pub fn u32(&mut self) -> Result<u32> {
            Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
        }

        pub fn u64(&mut self) -> Result<u64> {
            Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }

        pub fn f64(&mut self) -> Result<f64> {
            Ok(f64::from_bits(self.u64()?))
        }
    }
}

/// FNV-1a over a byte buffer — the crate's one string/byte hash: the
/// `.kmm` model checksum, the RNG stream-label derivation, and the
/// coordinator's per-cell init seeds all use it. (The workspace cache
/// fingerprint keeps a private running-hash variant: it samples
/// non-contiguous matrix elements, so a buffer-at-once helper doesn't
/// fit.)
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

// ----- atomic writes ----------------------------------------------------

/// Companion path of an artifact: its in-flight temp file (`.tmp`) or its
/// retained previous generation (`.prev`). The suffix is appended to the
/// full file name so `model.kmm` pairs with `model.kmm.tmp`, not
/// `model.tmp`.
pub fn sibling_path(path: &Path, suffix: &str) -> std::path::PathBuf {
    let mut name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_default();
    name.push(suffix);
    path.with_file_name(name)
}

/// Crash-safe artifact write: `<path>.tmp` → `sync_all` → rename over
/// `path`, with the previous generation rotated to `<path>.prev` first.
///
/// At every instant one of `path` / `<path>.prev` holds a complete prior
/// byte-for-byte artifact: a crash before the final rename leaves `path`
/// untouched, a crash between the rotate and the rename leaves
/// `<path>.prev` intact. Readers that must survive torn writes try the
/// generations in order (see `KMeansCheckpoint::load_any`).
///
/// Fault injection: when `COVERMEANS_CRASH_TORN_WRITE` is set to
/// `truncate` or `bitflip`, the temp file is corrupted accordingly and
/// the process aborts *before* the rename — simulating a torn write that
/// must never replace a good generation.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = sibling_path(path, ".tmp");
    {
        let mut f = File::create(&tmp)
            .with_context(|| format!("create temp file {tmp:?}"))?;
        f.write_all(bytes)
            .with_context(|| format!("write temp file {tmp:?}"))?;
        f.sync_all()
            .with_context(|| format!("sync temp file {tmp:?}"))?;
    }
    maybe_inject_torn_write(&tmp, bytes.len());
    if path.exists() {
        let prev = sibling_path(path, ".prev");
        std::fs::rename(path, &prev)
            .with_context(|| format!("rotate {path:?} -> {prev:?}"))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    // Make the rename itself durable where the platform allows it; the
    // data blocks are already synced, so this is best-effort.
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

/// The torn-write crash point of [`atomic_write`]: corrupt the temp file,
/// then die before the rename. Gated behind an env var so only the
/// fault-injection harness ever reaches it.
fn maybe_inject_torn_write(tmp: &Path, len: usize) {
    let Ok(mode) = std::env::var("COVERMEANS_CRASH_TORN_WRITE") else {
        return;
    };
    match mode.as_str() {
        "truncate" => {
            let keep = (len / 2) as u64;
            if let Ok(f) = std::fs::OpenOptions::new().write(true).open(tmp) {
                let _ = f.set_len(keep);
                let _ = f.sync_all();
            }
        }
        "bitflip" => {
            if let Ok(mut bytes) = std::fs::read(tmp) {
                if !bytes.is_empty() {
                    let at = bytes.len() / 2;
                    bytes[at] ^= 0x40;
                    let _ = std::fs::write(tmp, &bytes);
                }
            }
        }
        _ => return,
    }
    eprintln!("fault injection: torn write ({mode}) at {tmp:?}, aborting");
    std::process::abort();
}

/// Write a matrix as CSV (no header), atomically (see [`atomic_write`]).
pub fn write_csv(path: &Path, m: &Matrix) -> Result<()> {
    let mut out = Vec::new();
    for row in m.iter_rows() {
        let mut first = true;
        for v in row {
            if !first {
                out.push(b',');
            }
            write!(out, "{v}")?;
            first = false;
        }
        out.push(b'\n');
    }
    atomic_write(path, &out)
}

/// Read a CSV of floats (no header; `,`, `;` or whitespace separated).
pub fn read_csv(path: &Path) -> Result<Matrix> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut data = Vec::new();
    let mut cols = 0usize;
    let mut rows = 0usize;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let vals: Vec<f64> = t
            .split(|c: char| c == ',' || c == ';' || c.is_whitespace())
            .filter(|s| !s.is_empty())
            .map(|s| s.parse::<f64>().with_context(|| format!("line {}: {s:?}", lineno + 1)))
            .collect::<Result<_>>()?;
        if vals.is_empty() {
            continue;
        }
        if cols == 0 {
            cols = vals.len();
        } else if vals.len() != cols {
            bail!("ragged CSV at line {}: {} vs {} cols", lineno + 1, vals.len(), cols);
        }
        data.extend(vals);
        rows += 1;
    }
    Ok(Matrix::from_vec(data, rows, cols))
}

/// Write the binary cache format, atomically (see [`atomic_write`]).
pub fn write_fmat(path: &Path, m: &Matrix) -> Result<()> {
    let mut out = Vec::with_capacity(32 + m.rows() * m.cols() * 8);
    write!(out, "FMAT1\n{} {}\n", m.rows(), m.cols())?;
    for &v in m.as_slice() {
        out.extend_from_slice(&v.to_le_bytes());
    }
    atomic_write(path, &out)
}

/// Read the binary cache format.
pub fn read_fmat(path: &Path) -> Result<Matrix> {
    let mut r = BufReader::new(File::open(path)?);
    let mut header = Vec::new();
    // Read two newline-terminated header lines byte-wise.
    for _ in 0..2 {
        let mut line = Vec::new();
        loop {
            let mut b = [0u8; 1];
            r.read_exact(&mut b)?;
            if b[0] == b'\n' {
                break;
            }
            line.push(b[0]);
        }
        header.push(String::from_utf8(line)?);
    }
    if header[0] != "FMAT1" {
        bail!("bad magic {:?}", header[0]);
    }
    let dims: Vec<usize> = header[1]
        .split_whitespace()
        .map(|s| s.parse().context("bad dims"))
        .collect::<Result<_>>()?;
    if dims.len() != 2 {
        bail!("bad dims line {:?}", header[1]);
    }
    let (rows, cols) = (dims[0], dims[1]);
    let mut buf = vec![0u8; rows * cols * 8];
    r.read_exact(&mut buf)?;
    let data: Vec<f64> = buf
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Matrix::from_vec(data, rows, cols))
}

/// Load a named dataset through a binary cache directory: generate it on a
/// miss, reuse the cached bytes on a hit. Used by benches so the (large)
/// Table-4 sweeps don't regenerate data per algorithm.
pub fn load_cached(
    cache_dir: &Path,
    name: &str,
    scale: f64,
    seed: u64,
) -> Result<Matrix> {
    std::fs::create_dir_all(cache_dir)?;
    let fname = format!("{name}_s{scale}_r{seed}.fmat");
    let path = cache_dir.join(fname);
    if path.exists() {
        if let Ok(m) = read_fmat(&path) {
            return Ok(m);
        }
    }
    let m = crate::data::registry::load(name, scale, seed)
        .with_context(|| format!("unknown dataset {name:?}"))?;
    write_fmat(&path, &m)?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "covermeans_io_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn csv_roundtrip() {
        let m = Matrix::from_rows(&[&[1.5, -2.0], &[0.0, 3.25]]);
        let p = tmpdir().join("t.csv");
        write_csv(&p, &m).unwrap();
        let m2 = read_csv(&p).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmpdir().join("ragged.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(read_csv(&p).is_err());
    }

    #[test]
    fn csv_skips_comments_and_blanks() {
        let p = tmpdir().join("c.csv");
        std::fs::write(&p, "# header\n\n1,2\n").unwrap();
        let m = read_csv(&p).unwrap();
        assert_eq!((m.rows(), m.cols()), (1, 2));
    }

    #[test]
    fn fmat_roundtrip() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[-4.0, 5.5, 6.0]]);
        let p = tmpdir().join("t.fmat");
        write_fmat(&p, &m).unwrap();
        assert_eq!(read_fmat(&p).unwrap(), m);
    }

    #[test]
    fn bin_roundtrip_and_truncation() {
        let mut buf = Vec::new();
        bin::put_u32(&mut buf, 7);
        bin::put_u64(&mut buf, u64::MAX - 3);
        bin::put_f64(&mut buf, -0.0);
        bin::put_f64(&mut buf, f64::NAN);
        let mut r = bin::Reader::new(&buf);
        assert_eq!(r.u32().unwrap(), 7);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.f64().unwrap().is_nan());
        assert_eq!(r.remaining(), 0);
        assert!(r.u32().is_err(), "reads past the end must fail, not panic");
        // Truncated mid-field.
        let mut r = bin::Reader::new(&buf[..6]);
        assert_eq!(r.u32().unwrap(), 7);
        assert!(r.u64().is_err());
    }

    #[test]
    fn atomic_write_keeps_previous_generation() {
        let p = tmpdir().join("gen.bin");
        atomic_write(&p, b"one").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"one");
        assert!(!sibling_path(&p, ".prev").exists());
        atomic_write(&p, b"two").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"two");
        assert_eq!(std::fs::read(sibling_path(&p, ".prev")).unwrap(), b"one");
        assert!(!sibling_path(&p, ".tmp").exists(), "temp must be renamed away");
    }

    #[test]
    fn sibling_path_appends_to_full_name() {
        let p = Path::new("/a/b/model.kmm");
        assert_eq!(sibling_path(p, ".tmp"), Path::new("/a/b/model.kmm.tmp"));
        assert_eq!(sibling_path(p, ".prev"), Path::new("/a/b/model.kmm.prev"));
    }

    #[test]
    fn fnv1a_discriminates() {
        assert_ne!(fnv1a(b"abc"), fnv1a(b"abd"));
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn cached_load_hits() {
        let dir = tmpdir().join("cache");
        let a = load_cached(&dir, "blobs:100:2:3", 1.0, 7).unwrap();
        let b = load_cached(&dir, "blobs:100:2:3", 1.0, 7).unwrap();
        assert_eq!(a, b);
    }
}
