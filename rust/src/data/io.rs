//! Dataset and result I/O: CSV matrices and a small binary format.
//!
//! CSV is used for interchange (results/, external data); the binary `.fmat`
//! format caches generated datasets between benchmark runs (a header
//! `FMAT1\n<rows> <cols>\n` followed by little-endian f64 rows).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::matrix::Matrix;

/// Write a matrix as CSV (no header).
pub fn write_csv(path: &Path, m: &Matrix) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for row in m.iter_rows() {
        let mut first = true;
        for v in row {
            if !first {
                w.write_all(b",")?;
            }
            write!(w, "{v}")?;
            first = false;
        }
        w.write_all(b"\n")?;
    }
    Ok(())
}

/// Read a CSV of floats (no header; `,`, `;` or whitespace separated).
pub fn read_csv(path: &Path) -> Result<Matrix> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut data = Vec::new();
    let mut cols = 0usize;
    let mut rows = 0usize;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let vals: Vec<f64> = t
            .split(|c: char| c == ',' || c == ';' || c.is_whitespace())
            .filter(|s| !s.is_empty())
            .map(|s| s.parse::<f64>().with_context(|| format!("line {}: {s:?}", lineno + 1)))
            .collect::<Result<_>>()?;
        if vals.is_empty() {
            continue;
        }
        if cols == 0 {
            cols = vals.len();
        } else if vals.len() != cols {
            bail!("ragged CSV at line {}: {} vs {} cols", lineno + 1, vals.len(), cols);
        }
        data.extend(vals);
        rows += 1;
    }
    Ok(Matrix::from_vec(data, rows, cols))
}

/// Write the binary cache format.
pub fn write_fmat(path: &Path, m: &Matrix) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    write!(w, "FMAT1\n{} {}\n", m.rows(), m.cols())?;
    for &v in m.as_slice() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read the binary cache format.
pub fn read_fmat(path: &Path) -> Result<Matrix> {
    let mut r = BufReader::new(File::open(path)?);
    let mut header = Vec::new();
    // Read two newline-terminated header lines byte-wise.
    for _ in 0..2 {
        let mut line = Vec::new();
        loop {
            let mut b = [0u8; 1];
            r.read_exact(&mut b)?;
            if b[0] == b'\n' {
                break;
            }
            line.push(b[0]);
        }
        header.push(String::from_utf8(line)?);
    }
    if header[0] != "FMAT1" {
        bail!("bad magic {:?}", header[0]);
    }
    let dims: Vec<usize> = header[1]
        .split_whitespace()
        .map(|s| s.parse().context("bad dims"))
        .collect::<Result<_>>()?;
    if dims.len() != 2 {
        bail!("bad dims line {:?}", header[1]);
    }
    let (rows, cols) = (dims[0], dims[1]);
    let mut buf = vec![0u8; rows * cols * 8];
    r.read_exact(&mut buf)?;
    let data: Vec<f64> = buf
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Matrix::from_vec(data, rows, cols))
}

/// Load a named dataset through a binary cache directory: generate it on a
/// miss, reuse the cached bytes on a hit. Used by benches so the (large)
/// Table-4 sweeps don't regenerate data per algorithm.
pub fn load_cached(
    cache_dir: &Path,
    name: &str,
    scale: f64,
    seed: u64,
) -> Result<Matrix> {
    std::fs::create_dir_all(cache_dir)?;
    let fname = format!("{name}_s{scale}_r{seed}.fmat");
    let path = cache_dir.join(fname);
    if path.exists() {
        if let Ok(m) = read_fmat(&path) {
            return Ok(m);
        }
    }
    let m = crate::data::registry::load(name, scale, seed)
        .with_context(|| format!("unknown dataset {name:?}"))?;
    write_fmat(&path, &m)?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "covermeans_io_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn csv_roundtrip() {
        let m = Matrix::from_rows(&[&[1.5, -2.0], &[0.0, 3.25]]);
        let p = tmpdir().join("t.csv");
        write_csv(&p, &m).unwrap();
        let m2 = read_csv(&p).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmpdir().join("ragged.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(read_csv(&p).is_err());
    }

    #[test]
    fn csv_skips_comments_and_blanks() {
        let p = tmpdir().join("c.csv");
        std::fs::write(&p, "# header\n\n1,2\n").unwrap();
        let m = read_csv(&p).unwrap();
        assert_eq!((m.rows(), m.cols()), (1, 2));
    }

    #[test]
    fn fmat_roundtrip() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[-4.0, 5.5, 6.0]]);
        let p = tmpdir().join("t.fmat");
        write_fmat(&p, &m).unwrap();
        assert_eq!(read_fmat(&p).unwrap(), m);
    }

    #[test]
    fn cached_load_hits() {
        let dir = tmpdir().join("cache");
        let a = load_cached(&dir, "blobs:100:2:3", 1.0, 7).unwrap();
        let b = load_cached(&dir, "blobs:100:2:3", 1.0, 7).unwrap();
        assert_eq!(a, b);
    }
}
