//! Dataset and result I/O: CSV matrices and small binary formats.
//!
//! CSV is used for interchange (results/, external data); the binary `.fmat`
//! format caches generated datasets between benchmark runs (a header
//! `FMAT1\n<rows> <cols>\n` followed by little-endian f64 rows). The
//! little-endian primitives in [`bin`] are shared with the trained-model
//! format of [`crate::kmeans::KMeansModel`] (`.kmm` files).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::matrix::Matrix;

/// Little-endian binary primitives shared by the `.fmat` dataset cache and
/// the `.kmm` trained-model format: append-style writers over a `Vec<u8>`
/// and a bounds-checked [`bin::Reader`] whose every read fails cleanly on
/// truncated input instead of panicking.
pub mod bin {
    use anyhow::{bail, Result};

    pub fn put_u32(out: &mut Vec<u8>, v: u32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    /// Writes the exact bit pattern (`to_bits`), so round-trips are
    /// bit-identical for every value including -0.0 and NaNs.
    pub fn put_f64(out: &mut Vec<u8>, v: f64) {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// Forward-only bounds-checked reader over a byte slice.
    pub struct Reader<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl<'a> Reader<'a> {
        pub fn new(buf: &'a [u8]) -> Reader<'a> {
            Reader { buf, pos: 0 }
        }

        /// Bytes not yet consumed.
        pub fn remaining(&self) -> usize {
            self.buf.len() - self.pos
        }

        /// Consume exactly `n` bytes.
        pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
            if self.remaining() < n {
                bail!(
                    "truncated input: wanted {n} bytes at offset {}, {} left",
                    self.pos,
                    self.remaining()
                );
            }
            let s = &self.buf[self.pos..self.pos + n];
            self.pos += n;
            Ok(s)
        }

        pub fn u32(&mut self) -> Result<u32> {
            Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
        }

        pub fn u64(&mut self) -> Result<u64> {
            Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }

        pub fn f64(&mut self) -> Result<f64> {
            Ok(f64::from_bits(self.u64()?))
        }
    }
}

/// FNV-1a over a byte buffer — the crate's one string/byte hash: the
/// `.kmm` model checksum, the RNG stream-label derivation, and the
/// coordinator's per-cell init seeds all use it. (The workspace cache
/// fingerprint keeps a private running-hash variant: it samples
/// non-contiguous matrix elements, so a buffer-at-once helper doesn't
/// fit.)
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Write a matrix as CSV (no header).
pub fn write_csv(path: &Path, m: &Matrix) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for row in m.iter_rows() {
        let mut first = true;
        for v in row {
            if !first {
                w.write_all(b",")?;
            }
            write!(w, "{v}")?;
            first = false;
        }
        w.write_all(b"\n")?;
    }
    Ok(())
}

/// Read a CSV of floats (no header; `,`, `;` or whitespace separated).
pub fn read_csv(path: &Path) -> Result<Matrix> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut data = Vec::new();
    let mut cols = 0usize;
    let mut rows = 0usize;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let vals: Vec<f64> = t
            .split(|c: char| c == ',' || c == ';' || c.is_whitespace())
            .filter(|s| !s.is_empty())
            .map(|s| s.parse::<f64>().with_context(|| format!("line {}: {s:?}", lineno + 1)))
            .collect::<Result<_>>()?;
        if vals.is_empty() {
            continue;
        }
        if cols == 0 {
            cols = vals.len();
        } else if vals.len() != cols {
            bail!("ragged CSV at line {}: {} vs {} cols", lineno + 1, vals.len(), cols);
        }
        data.extend(vals);
        rows += 1;
    }
    Ok(Matrix::from_vec(data, rows, cols))
}

/// Write the binary cache format.
pub fn write_fmat(path: &Path, m: &Matrix) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    write!(w, "FMAT1\n{} {}\n", m.rows(), m.cols())?;
    for &v in m.as_slice() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read the binary cache format.
pub fn read_fmat(path: &Path) -> Result<Matrix> {
    let mut r = BufReader::new(File::open(path)?);
    let mut header = Vec::new();
    // Read two newline-terminated header lines byte-wise.
    for _ in 0..2 {
        let mut line = Vec::new();
        loop {
            let mut b = [0u8; 1];
            r.read_exact(&mut b)?;
            if b[0] == b'\n' {
                break;
            }
            line.push(b[0]);
        }
        header.push(String::from_utf8(line)?);
    }
    if header[0] != "FMAT1" {
        bail!("bad magic {:?}", header[0]);
    }
    let dims: Vec<usize> = header[1]
        .split_whitespace()
        .map(|s| s.parse().context("bad dims"))
        .collect::<Result<_>>()?;
    if dims.len() != 2 {
        bail!("bad dims line {:?}", header[1]);
    }
    let (rows, cols) = (dims[0], dims[1]);
    let mut buf = vec![0u8; rows * cols * 8];
    r.read_exact(&mut buf)?;
    let data: Vec<f64> = buf
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Matrix::from_vec(data, rows, cols))
}

/// Load a named dataset through a binary cache directory: generate it on a
/// miss, reuse the cached bytes on a hit. Used by benches so the (large)
/// Table-4 sweeps don't regenerate data per algorithm.
pub fn load_cached(
    cache_dir: &Path,
    name: &str,
    scale: f64,
    seed: u64,
) -> Result<Matrix> {
    std::fs::create_dir_all(cache_dir)?;
    let fname = format!("{name}_s{scale}_r{seed}.fmat");
    let path = cache_dir.join(fname);
    if path.exists() {
        if let Ok(m) = read_fmat(&path) {
            return Ok(m);
        }
    }
    let m = crate::data::registry::load(name, scale, seed)
        .with_context(|| format!("unknown dataset {name:?}"))?;
    write_fmat(&path, &m)?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "covermeans_io_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn csv_roundtrip() {
        let m = Matrix::from_rows(&[&[1.5, -2.0], &[0.0, 3.25]]);
        let p = tmpdir().join("t.csv");
        write_csv(&p, &m).unwrap();
        let m2 = read_csv(&p).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmpdir().join("ragged.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(read_csv(&p).is_err());
    }

    #[test]
    fn csv_skips_comments_and_blanks() {
        let p = tmpdir().join("c.csv");
        std::fs::write(&p, "# header\n\n1,2\n").unwrap();
        let m = read_csv(&p).unwrap();
        assert_eq!((m.rows(), m.cols()), (1, 2));
    }

    #[test]
    fn fmat_roundtrip() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[-4.0, 5.5, 6.0]]);
        let p = tmpdir().join("t.fmat");
        write_fmat(&p, &m).unwrap();
        assert_eq!(read_fmat(&p).unwrap(), m);
    }

    #[test]
    fn bin_roundtrip_and_truncation() {
        let mut buf = Vec::new();
        bin::put_u32(&mut buf, 7);
        bin::put_u64(&mut buf, u64::MAX - 3);
        bin::put_f64(&mut buf, -0.0);
        bin::put_f64(&mut buf, f64::NAN);
        let mut r = bin::Reader::new(&buf);
        assert_eq!(r.u32().unwrap(), 7);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.f64().unwrap().is_nan());
        assert_eq!(r.remaining(), 0);
        assert!(r.u32().is_err(), "reads past the end must fail, not panic");
        // Truncated mid-field.
        let mut r = bin::Reader::new(&buf[..6]);
        assert_eq!(r.u32().unwrap(), 7);
        assert!(r.u64().is_err());
    }

    #[test]
    fn fnv1a_discriminates() {
        assert_ne!(fnv1a(b"abc"), fnv1a(b"abd"));
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn cached_load_hits() {
        let dir = tmpdir().join("cache");
        let a = load_cached(&dir, "blobs:100:2:3", 1.0, 7).unwrap();
        let b = load_cached(&dir, "blobs:100:2:3", 1.0, 7).unwrap();
        assert_eq!(a, b);
    }
}
