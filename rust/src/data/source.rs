//! Out-of-core data sources: one point set, three residency strategies.
//!
//! A [`DataSource`] owns a dataset in one of three backends and hands out
//! cheap [`SourceView`] handles that the streaming-capable drivers
//! (Lloyd, Elkan, Hamerly, MiniBatch) and the seeding passes iterate:
//!
//! * **`InRam`** — the existing [`Matrix`]; `visit` hands back slices of
//!   the resident buffer. The only backend the tree-based drivers accept
//!   (they build spatial indexes over the whole point set).
//! * **`Mmap`** — a read-only memory map of a `.dmat` file. The kernel
//!   pages rows in and out on demand, so the fit's address space covers
//!   the file without the process owning the bytes.
//! * **`Chunked`** — an explicit streaming reader with a bounded
//!   resident-chunk budget (`data_chunk_rows` / `data_resident_mb`
//!   config keys): workers block until the bytes they want to read fit
//!   under the budget, so peak resident data memory stays capped no
//!   matter how many threads scan at once.
//!
//! The contract that makes the backends interchangeable is the same
//! byte-identity contract the parallel layer honors: every backend
//! serves the **exact f64 bit patterns** of the same point set, and the
//! per-point iteration order inside a worker's chunk range is ascending
//! row index regardless of how `visit` blocks the range. Labels,
//! centers, iteration counts and counted distances of a fit are
//! therefore identical across backends (`rust/tests/
//! streaming_equivalence.rs`).
//!
//! The on-disk `.dmat` format is a 64-byte header — magic, `rows` /
//! `cols` as `u64`, reserved zeros, and an FNV-1a checksum over the
//! first 56 bytes — followed by exactly `rows * cols` little-endian f64
//! values. The 64-byte header keeps the payload 8-byte aligned under
//! `mmap` (the mapping base is page-aligned). The header is checksummed
//! and the total file length is enforced exactly, so truncation,
//! bit-flips in the header, and trailing garbage all fail loudly at
//! open time; the payload itself is *not* checksummed — it may be far
//! larger than RAM, which is the point of this module.

use std::fs::File;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::{Condvar, Mutex};

use anyhow::{bail, Context, Result};

use crate::data::io::{atomic_write, fnv1a};
use crate::data::matrix::Matrix;

/// `.dmat` magic: 8 bytes so the header stays trivially 8-aligned.
const DMAT_MAGIC: &[u8; 8] = b"CMDMAT1\0";
/// Fixed header length; the payload starts here, 8-byte aligned.
pub const DMAT_HEADER_LEN: usize = 64;

/// Default streaming chunk granularity (`data_chunk_rows` config key).
pub const DEFAULT_CHUNK_ROWS: usize = 4096;

// ----- .dmat header ------------------------------------------------------

/// Parse and validate a `.dmat` header (the first [`DMAT_HEADER_LEN`]
/// bytes of the file). Returns `(rows, cols)`. Every corruption mode is
/// diagnosed: short input, bad magic, a flipped header bit (checksum),
/// zero or overflowing dimensions.
pub fn parse_dmat_header(buf: &[u8]) -> Result<(usize, usize)> {
    if buf.len() < DMAT_HEADER_LEN {
        bail!(
            "truncated .dmat header: {} bytes, need {DMAT_HEADER_LEN}",
            buf.len()
        );
    }
    let header = &buf[..DMAT_HEADER_LEN];
    if &header[..8] != DMAT_MAGIC {
        bail!("not a covermeans .dmat file: bad magic {:?}", &header[..8]);
    }
    let stored = u64::from_le_bytes(header[56..64].try_into().unwrap());
    let actual = fnv1a(&header[..56]);
    if stored != actual {
        bail!(
            "corrupt .dmat header: checksum mismatch (stored {stored:#018x}, \
             computed {actual:#018x})"
        );
    }
    let rows = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
    let cols = u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
    if rows == 0 || cols == 0 {
        bail!("corrupt .dmat header: rows={rows}, cols={cols}");
    }
    rows.checked_mul(cols)
        .and_then(|e| e.checked_mul(8))
        .context(".dmat dimensions overflow")?;
    Ok((rows, cols))
}

/// The exact byte length a well-formed `.dmat` with these dimensions has.
fn dmat_file_len(rows: usize, cols: usize) -> u64 {
    DMAT_HEADER_LEN as u64 + (rows * cols * 8) as u64
}

/// Open a `.dmat` file and validate its header *and* exact length —
/// a truncated payload or trailing garbage is rejected here, before any
/// fit starts consuming rows.
fn open_dmat(path: &Path) -> Result<(File, usize, usize)> {
    let file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let flen = file
        .metadata()
        .with_context(|| format!("stat {path:?}"))?
        .len();
    if flen < DMAT_HEADER_LEN as u64 {
        bail!("truncated .dmat file {path:?}: {flen} bytes, the header alone is {DMAT_HEADER_LEN}");
    }
    let mut header = [0u8; DMAT_HEADER_LEN];
    read_exact_at(&file, &mut header, 0)
        .with_context(|| format!("read {path:?} header"))?;
    let (rows, cols) =
        parse_dmat_header(&header).with_context(|| format!("parse {path:?}"))?;
    let want = dmat_file_len(rows, cols);
    if flen < want {
        bail!(
            "truncated .dmat payload in {path:?}: file is {flen} bytes, \
             header promises {want} ({rows} x {cols} f64)"
        );
    }
    if flen > want {
        bail!(
            "trailing bytes after the .dmat payload in {path:?}: file is \
             {flen} bytes, header promises {want} ({} extra)",
            flen - want
        );
    }
    Ok((file, rows, cols))
}

/// Serialize a matrix to the `.dmat` byte format (header + payload).
pub fn dmat_bytes(m: &Matrix) -> Vec<u8> {
    let mut out = Vec::with_capacity(DMAT_HEADER_LEN + m.rows() * m.cols() * 8);
    out.extend_from_slice(DMAT_MAGIC);
    out.extend_from_slice(&(m.rows() as u64).to_le_bytes());
    out.extend_from_slice(&(m.cols() as u64).to_le_bytes());
    out.resize(56, 0);
    let sum = fnv1a(&out[..56]);
    out.extend_from_slice(&sum.to_le_bytes());
    for &v in m.as_slice() {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    out
}

/// Write a matrix as a `.dmat` file, atomically (see
/// [`crate::data::io::atomic_write`]). Round-trips bit-identically,
/// including NaN and -0.0 payloads.
pub fn write_dmat(path: &Path, m: &Matrix) -> Result<()> {
    if m.rows() == 0 || m.cols() == 0 {
        bail!("refusing to write an empty .dmat ({} x {})", m.rows(), m.cols());
    }
    atomic_write(path, &dmat_bytes(m)).with_context(|| format!("write {path:?}"))
}

/// Read a `.dmat` file fully into RAM (the `ram` backend of
/// [`DataSource::open`]).
pub fn read_dmat(path: &Path) -> Result<Matrix> {
    let (file, rows, cols) = open_dmat(path)?;
    let mut data = vec![0f64; rows * cols];
    read_f64_at(&file, &mut data, DMAT_HEADER_LEN as u64)
        .with_context(|| format!("read {path:?} payload"))?;
    Ok(Matrix::from_vec(data, rows, cols))
}

// ----- positioned reads --------------------------------------------------

/// Positioned read: thread-safe on unix (`pread`), serialized through a
/// process-wide seek lock elsewhere.
fn read_exact_at(file: &File, buf: &mut [u8], off: u64) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        file.read_exact_at(buf, off)
    }
    #[cfg(not(unix))]
    {
        use std::io::{Read, Seek, SeekFrom};
        static SEEK_LOCK: Mutex<()> = Mutex::new(());
        let _g = SEEK_LOCK.lock().unwrap();
        let mut f = file.try_clone()?;
        f.seek(SeekFrom::Start(off))?;
        f.read_exact(buf)
    }
}

/// Positioned read of little-endian f64s straight into an f64 buffer.
/// The bytes are read in place and byte-swapped only on big-endian
/// hosts, so the little-endian fast path is a single read.
fn read_f64_at(file: &File, out: &mut [f64], off: u64) -> std::io::Result<()> {
    {
        // An f64 slice is always validly viewable as bytes (no invalid
        // bit patterns, alignment 8 >= 1).
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, out.len() * 8)
        };
        read_exact_at(file, bytes, off)?;
    }
    #[cfg(target_endian = "big")]
    for v in out.iter_mut() {
        *v = f64::from_bits(v.to_bits().swap_bytes());
    }
    Ok(())
}

// ----- mmap backend ------------------------------------------------------

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
}

/// Owner of a read-only file mapping; unmaps on drop.
#[cfg(unix)]
struct MapHandle {
    ptr: *mut std::os::raw::c_void,
    len: usize,
}

// The mapping is read-only and never remapped after construction, so
// sharing the raw pointer across threads is sound.
#[cfg(unix)]
unsafe impl Send for MapHandle {}
#[cfg(unix)]
unsafe impl Sync for MapHandle {}

#[cfg(unix)]
impl Drop for MapHandle {
    fn drop(&mut self) {
        unsafe {
            sys::munmap(self.ptr, self.len);
        }
    }
}

/// A `.dmat` file served through a read-only memory map: the payload is
/// addressable as one `&[f64]` without the process owning the bytes.
/// On non-unix hosts this falls back to reading the file into the heap
/// (same bits, no paging benefit).
pub struct MmapSource {
    rows: usize,
    cols: usize,
    #[cfg(unix)]
    map: MapHandle,
    #[cfg(not(unix))]
    buf: Vec<f64>,
}

impl MmapSource {
    pub fn open(path: &Path) -> Result<MmapSource> {
        let (file, rows, cols) = open_dmat(path)?;
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let len = dmat_file_len(rows, cols) as usize;
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize == -1 {
                bail!(
                    "mmap {path:?} ({len} bytes) failed: {}",
                    std::io::Error::last_os_error()
                );
            }
            // The base is page-aligned and the header is 64 bytes, so
            // the payload view below is 8-byte aligned.
            assert_eq!(
                (ptr as usize + DMAT_HEADER_LEN) % std::mem::align_of::<f64>(),
                0,
                "mmap base must leave the payload f64-aligned"
            );
            Ok(MmapSource { rows, cols, map: MapHandle { ptr, len } })
        }
        #[cfg(not(unix))]
        {
            let mut buf = vec![0f64; rows * cols];
            read_f64_at(&file, &mut buf, DMAT_HEADER_LEN as u64)
                .with_context(|| format!("read {path:?} payload"))?;
            Ok(MmapSource { rows, cols, buf })
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The full payload as one flat row-major slice.
    ///
    /// Endianness note: the mapped bytes are little-endian by format.
    /// On a big-endian host the mapped view would be wrong, so the
    /// constructor path is the heap fallback there (`#[cfg]` above is
    /// unix vs not; unix big-endian hosts are out of scope for this
    /// reproduction and would fail the roundtrip tests immediately).
    pub fn data(&self) -> &[f64] {
        #[cfg(unix)]
        unsafe {
            let base = (self.map.ptr as *const u8).add(DMAT_HEADER_LEN);
            std::slice::from_raw_parts(base as *const f64, self.rows * self.cols)
        }
        #[cfg(not(unix))]
        {
            &self.buf
        }
    }
}

// ----- chunked backend ---------------------------------------------------

/// Resident-byte accounting shared by every thread scanning a
/// [`ChunkedSource`].
struct ResidentGauge {
    resident: usize,
    peak: usize,
}

/// A `.dmat` file read in bounded chunks: `visit` materializes at most
/// `chunk_rows` rows at a time per caller, and the total bytes resident
/// across *all* concurrent callers is capped by the budget — a thread
/// whose read would overflow it blocks until another thread releases
/// its chunk. The effective chunk size is clamped so a single chunk
/// always fits the budget (no self-deadlock), and a thread holding
/// nothing is always allowed to proceed (no collective deadlock).
pub struct ChunkedSource {
    file: File,
    path: PathBuf,
    rows: usize,
    cols: usize,
    chunk_rows: usize,
    /// 0 = unlimited (chunking still applies, the gate never blocks).
    budget_bytes: usize,
    gate: Mutex<ResidentGauge>,
    cv: Condvar,
}

impl ChunkedSource {
    /// `chunk_rows` 0 falls back to [`DEFAULT_CHUNK_ROWS`];
    /// `resident_mb` 0 means no budget.
    pub fn open(path: &Path, chunk_rows: usize, resident_mb: usize) -> Result<ChunkedSource> {
        let (file, rows, cols) = open_dmat(path)?;
        let row_bytes = cols * 8;
        let budget_bytes = resident_mb.saturating_mul(1 << 20);
        let mut eff = if chunk_rows == 0 { DEFAULT_CHUNK_ROWS } else { chunk_rows };
        if budget_bytes > 0 {
            eff = eff.min((budget_bytes / row_bytes).max(1));
        }
        Ok(ChunkedSource {
            file,
            path: path.to_path_buf(),
            rows,
            cols,
            chunk_rows: eff,
            budget_bytes,
            gate: Mutex::new(ResidentGauge { resident: 0, peak: 0 }),
            cv: Condvar::new(),
        })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The clamped per-visit chunk granularity actually in effect.
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// High-water mark of concurrently resident chunk bytes so far.
    pub fn peak_resident_bytes(&self) -> usize {
        self.gate.lock().unwrap().peak
    }

    fn acquire(&self, bytes: usize) {
        let mut g = self.gate.lock().unwrap();
        if self.budget_bytes > 0 {
            // A caller holding nothing always proceeds, so the clamp on
            // chunk_rows plus this wait condition cannot deadlock.
            while g.resident > 0 && g.resident + bytes > self.budget_bytes {
                g = self.cv.wait(g).unwrap();
            }
        }
        g.resident += bytes;
        g.peak = g.peak.max(g.resident);
    }

    fn release(&self, bytes: usize) {
        let mut g = self.gate.lock().unwrap();
        g.resident -= bytes;
        drop(g);
        self.cv.notify_all();
    }

    /// Read rows `[start, end)` into a fresh buffer under the budget.
    fn read_block(&self, start: usize, end: usize) -> Vec<f64> {
        let mut block = vec![0f64; (end - start) * self.cols];
        let off = DMAT_HEADER_LEN as u64 + (start * self.cols * 8) as u64;
        if let Err(e) = read_f64_at(&self.file, &mut block, off) {
            // Reads were validated at open; a failure here is the
            // environment yanking the file mid-fit — no sane resume.
            panic!("read rows {start}..{end} of {:?}: {e}", self.path);
        }
        block
    }
}

// ----- the source and its view ------------------------------------------

/// Streaming backend selector (`data_backend` config key).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SourceBackend {
    Ram,
    Mmap,
    Chunked,
}

impl SourceBackend {
    pub fn name(&self) -> &'static str {
        match self {
            SourceBackend::Ram => "ram",
            SourceBackend::Mmap => "mmap",
            SourceBackend::Chunked => "chunked",
        }
    }

    pub fn parse(s: &str) -> Result<SourceBackend> {
        Ok(match s {
            "ram" | "in-ram" | "inram" => SourceBackend::Ram,
            "mmap" => SourceBackend::Mmap,
            "chunked" | "stream" | "streamed" => SourceBackend::Chunked,
            other => bail!(
                "unknown data backend {other:?} (expected ram, mmap, or chunked)"
            ),
        })
    }
}

/// One dataset behind one of the three residency strategies. Fits
/// borrow it through [`DataSource::view`].
pub enum DataSource {
    InRam(Matrix),
    Mmap(MmapSource),
    Chunked(ChunkedSource),
}

impl DataSource {
    /// Open a `.dmat` file under the chosen backend. `chunk_rows` and
    /// `resident_mb` only apply to [`SourceBackend::Chunked`].
    pub fn open(
        path: &Path,
        backend: SourceBackend,
        chunk_rows: usize,
        resident_mb: usize,
    ) -> Result<DataSource> {
        Ok(match backend {
            SourceBackend::Ram => DataSource::InRam(read_dmat(path)?),
            SourceBackend::Mmap => DataSource::Mmap(MmapSource::open(path)?),
            SourceBackend::Chunked => {
                DataSource::Chunked(ChunkedSource::open(path, chunk_rows, resident_mb)?)
            }
        })
    }

    pub fn view(&self) -> SourceView<'_> {
        match self {
            DataSource::InRam(m) => SourceView::Ram(m),
            DataSource::Mmap(m) => SourceView::Mmap(m),
            DataSource::Chunked(c) => SourceView::Chunked(c),
        }
    }

    pub fn rows(&self) -> usize {
        self.view().rows()
    }

    pub fn cols(&self) -> usize {
        self.view().cols()
    }
}

impl From<Matrix> for DataSource {
    fn from(m: Matrix) -> DataSource {
        DataSource::InRam(m)
    }
}

/// A borrowed, `Copy` handle on a [`DataSource`] — what the drivers and
/// seeding passes actually iterate. Cloning it into per-worker closures
/// is free; the chunked backend's budget gate lives behind the shared
/// reference.
#[derive(Clone, Copy)]
pub enum SourceView<'a> {
    Ram(&'a Matrix),
    Mmap(&'a MmapSource),
    Chunked(&'a ChunkedSource),
}

impl<'a> From<&'a Matrix> for SourceView<'a> {
    fn from(m: &'a Matrix) -> SourceView<'a> {
        SourceView::Ram(m)
    }
}

impl<'a> SourceView<'a> {
    pub fn rows(&self) -> usize {
        match self {
            SourceView::Ram(m) => m.rows(),
            SourceView::Mmap(m) => m.rows(),
            SourceView::Chunked(c) => c.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            SourceView::Ram(m) => m.cols(),
            SourceView::Mmap(m) => m.cols(),
            SourceView::Chunked(c) => c.cols(),
        }
    }

    pub fn backend(&self) -> SourceBackend {
        match self {
            SourceView::Ram(_) => SourceBackend::Ram,
            SourceView::Mmap(_) => SourceBackend::Mmap,
            SourceView::Chunked(_) => SourceBackend::Chunked,
        }
    }

    /// The resident matrix, if this backend has one. The tree-based
    /// drivers require it (they index the whole point set); `mmap`
    /// deliberately returns `None` — the workspace tree caches key on
    /// the matrix allocation, which a mapping is not.
    pub fn as_matrix(&self) -> Option<&'a Matrix> {
        match self {
            SourceView::Ram(m) => Some(m),
            _ => None,
        }
    }

    /// Walk rows `range` in ascending order, handing `f` row-major
    /// blocks as `(first_row_index, values)`. Resident backends hand
    /// the whole range as one block; the chunked backend splits it at
    /// its chunk granularity under the resident-byte budget. Block
    /// boundaries carry no semantic weight — callers must produce
    /// identical results for any blocking of the same range (that is
    /// the backend byte-identity contract).
    pub fn visit<F: FnMut(usize, &[f64])>(&self, range: Range<usize>, mut f: F) {
        match self {
            SourceView::Ram(m) => {
                if !range.is_empty() {
                    let c = m.cols();
                    f(range.start, &m.as_slice()[range.start * c..range.end * c]);
                }
            }
            SourceView::Mmap(m) => {
                if !range.is_empty() {
                    let c = m.cols();
                    f(range.start, &m.data()[range.start * c..range.end * c]);
                }
            }
            SourceView::Chunked(c) => {
                let mut start = range.start;
                while start < range.end {
                    let end = (start + c.chunk_rows).min(range.end);
                    let bytes = (end - start) * c.cols * 8;
                    c.acquire(bytes);
                    let block = c.read_block(start, end);
                    f(start, &block);
                    drop(block);
                    c.release(bytes);
                    start = end;
                }
            }
        }
    }

    /// Gather arbitrary rows into a fresh resident matrix (mini-batch
    /// draws, seeding candidates). The gathered rows are the caller's
    /// working set — like the centers, they are not charged against the
    /// chunked budget.
    pub fn read_rows(&self, idx: &[usize]) -> Matrix {
        let cols = self.cols();
        let mut out = Vec::with_capacity(idx.len() * cols);
        match self {
            SourceView::Ram(m) => {
                for &i in idx {
                    out.extend_from_slice(m.row(i));
                }
            }
            SourceView::Mmap(m) => {
                let d = m.data();
                for &i in idx {
                    out.extend_from_slice(&d[i * cols..(i + 1) * cols]);
                }
            }
            SourceView::Chunked(c) => {
                let mut row = vec![0f64; cols];
                for &i in idx {
                    assert!(i < c.rows, "row {i} out of range ({} rows)", c.rows);
                    let off = DMAT_HEADER_LEN as u64 + (i * cols * 8) as u64;
                    if let Err(e) = read_f64_at(&c.file, &mut row, off) {
                        panic!("read row {i} of {:?}: {e}", c.path);
                    }
                    out.extend_from_slice(&row);
                }
            }
        }
        Matrix::from_vec(out, idx.len(), cols)
    }

    /// One element of the flat row-major payload — the sampled-content
    /// accessor the checkpoint fingerprint uses. All backends return
    /// the same bits for the same index, so fingerprints (and therefore
    /// `.kmc` snapshots) are interchangeable across backends.
    pub fn flat_element(&self, i: usize) -> f64 {
        match self {
            SourceView::Ram(m) => m.as_slice()[i],
            SourceView::Mmap(m) => m.data()[i],
            SourceView::Chunked(c) => {
                let mut one = [0f64; 1];
                let off = DMAT_HEADER_LEN as u64 + (i * 8) as u64;
                if let Err(e) = read_f64_at(&c.file, &mut one, off) {
                    panic!("read element {i} of {:?}: {e}", c.path);
                }
                one[0]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "covermeans_source_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample() -> Matrix {
        let mut m = synth::gaussian_blobs(37, 3, 4, 0.5, 77);
        // Exercise the bit-exactness corners explicitly.
        m.set(0, 0, -0.0);
        m.set(1, 1, f64::NAN);
        m
    }

    fn bits(s: &[f64]) -> Vec<u64> {
        s.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn dmat_roundtrips_bit_identically() {
        let m = sample();
        let p = tmpdir().join("rt.dmat");
        write_dmat(&p, &m).unwrap();
        let back = read_dmat(&p).unwrap();
        assert_eq!((back.rows(), back.cols()), (m.rows(), m.cols()));
        assert_eq!(bits(back.as_slice()), bits(m.as_slice()));
    }

    #[test]
    fn every_backend_serves_the_same_bits() {
        let m = sample();
        let p = tmpdir().join("backends.dmat");
        write_dmat(&p, &m).unwrap();
        let want = bits(m.as_slice());
        for backend in [SourceBackend::Ram, SourceBackend::Mmap, SourceBackend::Chunked] {
            let src = DataSource::open(&p, backend, 5, 1).unwrap();
            let v = src.view();
            assert_eq!((v.rows(), v.cols()), (m.rows(), m.cols()));
            let mut got = vec![0u64; want.len()];
            v.visit(0..v.rows(), |start, block| {
                let at = start * v.cols();
                for (i, x) in block.iter().enumerate() {
                    got[at + i] = x.to_bits();
                }
            });
            assert_eq!(got, want, "{}", backend.name());
        }
    }

    #[test]
    fn chunked_visit_blocks_cover_any_range_once() {
        let m = sample();
        let p = tmpdir().join("blocks.dmat");
        write_dmat(&p, &m).unwrap();
        for chunk in [1usize, 3, 7, m.rows(), m.rows() * 2] {
            let src = ChunkedSource::open(&p, chunk, 0).unwrap();
            let v = SourceView::Chunked(&src);
            for range in [0..m.rows(), 5..m.rows() - 3, 11..12, 4..4] {
                let mut seen = Vec::new();
                v.visit(range.clone(), |start, block| {
                    assert_eq!(block.len() % m.cols(), 0);
                    for r in 0..block.len() / m.cols() {
                        seen.push(start + r);
                        assert_eq!(
                            bits(&block[r * m.cols()..(r + 1) * m.cols()]),
                            bits(m.row(start + r)),
                        );
                    }
                });
                assert_eq!(seen, range.collect::<Vec<_>>(), "chunk {chunk}");
            }
        }
    }

    #[test]
    fn chunked_budget_caps_peak_and_clamps_chunk() {
        let m = synth::gaussian_blobs(64, 8, 2, 0.5, 3);
        let p = tmpdir().join("budget.dmat");
        write_dmat(&p, &m).unwrap();
        // 1 MiB budget, absurd chunk request: the chunk clamps to what
        // fits (here the budget exceeds a row, so the clamp is the
        // budget in rows).
        let src = ChunkedSource::open(&p, usize::MAX, 1).unwrap();
        assert_eq!(src.chunk_rows(), (1 << 20) / (8 * 8));
        let v = SourceView::Chunked(&src);
        v.visit(0..m.rows(), |_, _| {});
        assert!(src.peak_resident_bytes() <= 1 << 20);
        assert!(src.peak_resident_bytes() > 0);
        // Concurrent scans stay under the budget too.
        let tiny = ChunkedSource::open(&p, 4, 1).unwrap();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    SourceView::Chunked(&tiny).visit(0..m.rows(), |_, block| {
                        std::hint::black_box(block.len());
                    });
                });
            }
        });
        assert!(tiny.peak_resident_bytes() <= 1 << 20);
    }

    #[test]
    fn read_rows_gathers_exact_bits() {
        let m = sample();
        let p = tmpdir().join("gather.dmat");
        write_dmat(&p, &m).unwrap();
        let idx = [0usize, 36, 5, 5, 17];
        let want = m.select_rows(&idx);
        for backend in [SourceBackend::Ram, SourceBackend::Mmap, SourceBackend::Chunked] {
            let src = DataSource::open(&p, backend, 3, 0).unwrap();
            let got = src.view().read_rows(&idx);
            assert_eq!(bits(got.as_slice()), bits(want.as_slice()), "{}", backend.name());
        }
    }

    #[test]
    fn flat_element_matches_across_backends() {
        let m = sample();
        let p = tmpdir().join("flat.dmat");
        write_dmat(&p, &m).unwrap();
        let flat = m.as_slice();
        for backend in [SourceBackend::Ram, SourceBackend::Mmap, SourceBackend::Chunked] {
            let src = DataSource::open(&p, backend, 3, 0).unwrap();
            let v = src.view();
            for i in [0usize, 1, flat.len() / 2, flat.len() - 1] {
                assert_eq!(v.flat_element(i).to_bits(), flat[i].to_bits());
            }
        }
    }

    #[test]
    fn header_corruption_is_diagnosed() {
        let m = sample();
        let p = tmpdir().join("corrupt.dmat");
        write_dmat(&p, &m).unwrap();
        let good = std::fs::read(&p).unwrap();
        let reopen = |bytes: &[u8]| {
            let q = tmpdir().join("corrupt_case.dmat");
            std::fs::write(&q, bytes).unwrap();
            read_dmat(&q)
        };
        // Shared fault battery: only the header is checksummed; the
        // payload is guarded by the exact-length contract, so the checked
        // prefix is the header alone.
        crate::testutil::corruption::assert_rejects_faults(
            ".dmat",
            &good,
            DMAT_HEADER_LEN,
            reopen,
        );
        // Format-specific faults the battery cannot know about follow.
        // Payload truncation and trailing payload bytes are length
        // violations, not checksum failures.
        for cut in [DMAT_HEADER_LEN - 1, DMAT_HEADER_LEN + 9, good.len() - 1] {
            let msg = format!("{:#}", reopen(&good[..cut]).unwrap_err());
            assert!(msg.contains("truncated"), "cut {cut}: {msg}");
        }
        let mut bad = good.clone();
        bad.extend_from_slice(&[0u8; 16]);
        let msg = format!("{:#}", reopen(&bad).unwrap_err());
        assert!(msg.contains("trailing bytes"), "{msg}");
        // Zero dims (rewrite header checksum so only the dims are bad).
        let mut bad = good.clone();
        bad[8..16].copy_from_slice(&0u64.to_le_bytes());
        let sum = fnv1a(&bad[..56]);
        bad[56..64].copy_from_slice(&sum.to_le_bytes());
        let msg = format!("{:#}", reopen(&bad[..DMAT_HEADER_LEN]).unwrap_err());
        assert!(msg.contains("rows=0"), "{msg}");
    }
}
