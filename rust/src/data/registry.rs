//! Named dataset registry mirroring the paper's Table 1.
//!
//! `load("mnist30", scale, seed)` returns the synthetic analog of the named
//! paper dataset (see `synth`). Names accepted (case-insensitive):
//! `covtype, istanbul, kdd04, traffic, aloi27, aloi64, mnist10, mnist20,
//! mnist30, mnist40, mnist50`, plus `blobs:<n>:<d>:<k>` for ad-hoc data.

use crate::data::matrix::Matrix;
use crate::data::synth;

/// Descriptor for one registered dataset.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DatasetInfo {
    pub name: &'static str,
    /// Paper-size N (scale 1.0).
    pub n: usize,
    pub d: usize,
    pub domain: &'static str,
}

/// The eight datasets of the paper's Tables 2-4, in table column order.
pub const TABLE_DATASETS: [DatasetInfo; 8] = [
    DatasetInfo { name: "covtype", n: synth::COVTYPE_N, d: 54, domain: "remote sensing" },
    DatasetInfo { name: "istanbul", n: synth::ISTANBUL_N, d: 2, domain: "tweet locations" },
    DatasetInfo { name: "kdd04", n: synth::KDD04_N, d: 74, domain: "biology" },
    DatasetInfo { name: "traffic", n: synth::TRAFFIC_N, d: 2, domain: "accident locations" },
    DatasetInfo { name: "mnist10", n: synth::MNIST_N, d: 10, domain: "autoencoder" },
    DatasetInfo { name: "mnist30", n: synth::MNIST_N, d: 30, domain: "autoencoder" },
    DatasetInfo { name: "aloi27", n: synth::ALOI_N, d: 27, domain: "color histograms" },
    DatasetInfo { name: "aloi64", n: synth::ALOI_N, d: 64, domain: "color histograms" },
];

/// Look up a dataset descriptor by name.
pub fn info(name: &str) -> Option<DatasetInfo> {
    let lname = name.to_ascii_lowercase();
    if let Some(i) = TABLE_DATASETS.iter().find(|i| i.name == lname) {
        return Some(i.clone());
    }
    match lname.as_str() {
        "mnist20" => Some(DatasetInfo { name: "mnist20", n: synth::MNIST_N, d: 20, domain: "autoencoder" }),
        "mnist40" => Some(DatasetInfo { name: "mnist40", n: synth::MNIST_N, d: 40, domain: "autoencoder" }),
        "mnist50" => Some(DatasetInfo { name: "mnist50", n: synth::MNIST_N, d: 50, domain: "autoencoder" }),
        _ => None,
    }
}

/// Generate the named dataset at the given scale and seed.
pub fn load(name: &str, scale: f64, seed: u64) -> Option<Matrix> {
    let lname = name.to_ascii_lowercase();
    if let Some(rest) = lname.strip_prefix("blobs:") {
        let parts: Vec<usize> =
            rest.split(':').filter_map(|p| p.parse().ok()).collect();
        if parts.len() == 3 {
            return Some(synth::gaussian_blobs(
                parts[0], parts[1], parts[2], 0.5, seed,
            ));
        }
        return None;
    }
    if let Some(dstr) = lname.strip_prefix("mnist") {
        if let Ok(d) = dstr.parse::<usize>() {
            return Some(synth::mnist(d, scale, seed));
        }
    }
    if let Some(dstr) = lname.strip_prefix("aloi") {
        if let Ok(d) = dstr.parse::<usize>() {
            return Some(synth::aloi(d, scale, seed));
        }
    }
    match lname.as_str() {
        "covtype" => Some(synth::covtype(scale, seed)),
        "istanbul" => Some(synth::istanbul(scale, seed)),
        "traffic" => Some(synth::traffic(scale, seed)),
        "kdd04" => Some(synth::kdd04(scale, seed)),
        _ => None,
    }
}

/// Names of all paper-table datasets, in column order.
pub fn table_names() -> Vec<&'static str> {
    TABLE_DATASETS.iter().map(|i| i.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn info_known_and_unknown() {
        assert_eq!(info("ALOI64").unwrap().d, 64);
        assert_eq!(info("mnist40").unwrap().d, 40);
        assert!(info("nope").is_none());
    }

    #[test]
    fn load_all_table_datasets_tiny() {
        for ds in TABLE_DATASETS.iter() {
            let m = load(ds.name, 0.0005, 1).unwrap();
            assert_eq!(m.cols(), ds.d, "{}", ds.name);
            assert!(m.rows() >= 64);
        }
    }

    #[test]
    fn load_blobs_spec() {
        let m = load("blobs:200:3:4", 1.0, 2).unwrap();
        assert_eq!((m.rows(), m.cols()), (200, 3));
        assert!(load("blobs:bad", 1.0, 2).is_none());
    }

    #[test]
    fn load_arbitrary_mnist_dim() {
        let m = load("mnist50", 0.001, 3).unwrap();
        assert_eq!(m.cols(), 50);
    }
}
