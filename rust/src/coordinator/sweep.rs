//! The paper's experiment protocols (§4), one constructor per table/figure.
//!
//! Each function returns an [`Experiment`] sized by a `scale` knob (1.0 =
//! the paper's dataset sizes); the CLI and benches pass smaller scales so
//! the full matrix completes in minutes. See docs/GUIDE.md §7 for the
//! CLI commands that drive each protocol.

use crate::coordinator::Experiment;
use crate::kmeans::Algorithm;

/// Tables 2 & 3: all eight datasets, k = 100, 10 k-means++ restarts.
/// (Table 2 reads the distance metric off the result, Table 3 the time.)
pub fn tables23(scale: f64, restarts: usize) -> Experiment {
    Experiment {
        restarts,
        scale,
        ..Experiment::new("tables23")
    }
}

/// Table 4: the parameter sweep — 16 values of k, 10 restarts each, tree
/// construction amortized across the whole sweep.
pub fn table4(scale: f64, restarts: usize) -> Experiment {
    Experiment {
        ks: ks_sweep16(),
        restarts,
        scale,
        amortize_tree: true,
        ..Experiment::new("table4")
    }
}

/// Table 4 variant with warm-started restarts: each restart of the next
/// larger k continues from its previous-k solution, extended by D²
/// sampling (`kmeans::init::extend_centers`). Faster sweeps at the cost
/// of a different optimization trajectory than the paper's protocol —
/// use for production k-selection, not table replication.
pub fn table4_warm(scale: f64, restarts: usize) -> Experiment {
    Experiment {
        warm_restarts: true,
        ..table4(scale, restarts)
    }
}

/// The 16-point k grid of the Table 4 sweep (the paper chooses k by a
/// quality heuristic afterwards; the grid spans the "medium to large
/// k = 10..1000" range of §4).
pub fn ks_sweep16() -> Vec<usize> {
    vec![10, 20, 30, 40, 50, 70, 100, 140, 200, 280, 400, 500, 600, 700, 850, 1000]
}

/// Fig. 1: ALOI-64 analog, k = 400, per-iteration cumulative series
/// (tree construction excluded from the series; one restart).
pub fn fig1(scale: f64) -> Experiment {
    Experiment {
        datasets: vec!["aloi64".into()],
        ks: vec![400],
        restarts: 1,
        scale,
        ..Experiment::new("fig1")
    }
}

/// Fig. 2a: runtime vs dimensionality on the MNIST analogs, k = 100.
pub fn fig2a(scale: f64, restarts: usize) -> Experiment {
    Experiment {
        datasets: vec![
            "mnist10".into(),
            "mnist20".into(),
            "mnist30".into(),
            "mnist40".into(),
            "mnist50".into(),
        ],
        ks: vec![100],
        restarts,
        scale,
        ..Experiment::new("fig2a")
    }
}

/// Fig. 2b: runtime vs k on MNIST-10.
pub fn fig2b(scale: f64, restarts: usize) -> Experiment {
    Experiment {
        datasets: vec!["mnist10".into()],
        ks: vec![10, 20, 50, 100, 200, 400, 700, 1000],
        restarts,
        scale,
        ..Experiment::new("fig2b")
    }
}

/// Large-k head-to-head of the two cover-tree assignment passes: the
/// single-tree Cover-means scan vs the dual-tree node-pair traversal,
/// over the top of the k grid where the single-tree per-node candidate
/// scan dominates (Standard rides along as the distance baseline).
pub fn large_k(scale: f64, restarts: usize) -> Experiment {
    Experiment {
        datasets: vec!["istanbul".into(), "mnist10".into()],
        algorithms: vec![
            Algorithm::Standard,
            Algorithm::CoverMeans,
            Algorithm::DualTree,
        ],
        ks: vec![100, 200, 400, 700, 1000],
        restarts,
        scale,
        amortize_tree: true,
        ..Experiment::new("large_k")
    }
}

/// E8 ablations: one knob varied at a time on two contrasting datasets
/// (tree-friendly istanbul, tree-hostile kdd04). Returns labelled
/// experiments; the bench/CLI runs each and reports Cover-means/Hybrid.
pub fn ablations(scale: f64, restarts: usize) -> Vec<(String, Experiment)> {
    let datasets: Vec<String> = vec!["istanbul".into(), "kdd04".into()];
    let mut out = Vec::new();
    for sf in [1.1, 1.2, 1.3, 2.0] {
        let mut e = Experiment {
            datasets: datasets.clone(),
            algorithms: vec![Algorithm::Standard, Algorithm::CoverMeans, Algorithm::Hybrid],
            ks: vec![100],
            restarts,
            scale,
            ..Experiment::new(&format!("ablate_scale_factor_{sf}"))
        };
        e.params.cover.scale_factor = sf;
        out.push((format!("scale_factor={sf}"), e));
    }
    for leaf in [1usize, 10, 100, 1000] {
        let mut e = Experiment {
            datasets: datasets.clone(),
            algorithms: vec![Algorithm::Standard, Algorithm::CoverMeans, Algorithm::Hybrid],
            ks: vec![100],
            restarts,
            scale,
            ..Experiment::new(&format!("ablate_min_node_{leaf}"))
        };
        e.params.cover.min_node_size = leaf;
        out.push((format!("min_node_size={leaf}"), e));
    }
    for sw in [1usize, 3, 7, 15] {
        let mut e = Experiment {
            datasets: datasets.clone(),
            algorithms: vec![Algorithm::Standard, Algorithm::Shallot, Algorithm::Hybrid],
            ks: vec![100],
            restarts,
            scale,
            ..Experiment::new(&format!("ablate_switch_{sw}"))
        };
        e.params.switch_at = sw;
        out.push((format!("switch_at={sw}"), e));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn protocols_match_paper_shapes() {
        let t23 = tables23(0.01, 10);
        assert_eq!(t23.datasets.len(), 8);
        assert_eq!(t23.ks, vec![100]);
        assert!(!t23.amortize_tree);

        let t4 = table4(0.01, 10);
        assert_eq!(t4.ks.len(), 16);
        assert!(t4.amortize_tree);
        assert!(!t4.warm_restarts, "paper protocol stays cold-started");

        let t4w = table4_warm(0.01, 10);
        assert!(t4w.warm_restarts);
        assert!(t4w.amortize_tree);

        let f1 = fig1(0.01);
        assert_eq!(f1.ks, vec![400]);
        assert_eq!(f1.datasets, vec!["aloi64"]);

        assert_eq!(fig2a(0.01, 3).datasets.len(), 5);
        assert_eq!(fig2b(0.01, 3).ks.len(), 8);

        let lk = large_k(0.01, 3);
        assert!(lk.algorithms.contains(&Algorithm::DualTree));
        assert!(lk.amortize_tree, "trees amortize across the k sweep");
        assert_eq!(lk.ks.last(), Some(&1000));
    }

    #[test]
    fn ablations_cover_three_knobs() {
        let abl = ablations(0.01, 2);
        assert_eq!(abl.len(), 12);
        assert!(abl.iter().any(|(n, _)| n == "scale_factor=1.2"));
        assert!(abl.iter().any(|(n, _)| n == "min_node_size=1000"));
        assert!(abl.iter().any(|(n, _)| n == "switch_at=15"));
    }
}
