//! Report renderers: print the paper's tables/figures from an
//! [`ExperimentResult`] and write the raw series as CSV.

use std::fmt::Write as _;

use crate::coordinator::{CellResult, Experiment, ExperimentResult};
use crate::kmeans::Algorithm;

/// Provenance comment rows for CSV outputs: the thread topology a result
/// was produced under. Earlier revisions implicitly reported every run as
/// single-threaded; now the *actual* cell-level worker count and intra-fit
/// thread count are routed through from the experiment. (Thanks to the
/// exactness-preserving reductions, the counted metrics are identical at
/// any `fit_threads`; the wall-clock columns are what the topology
/// contextualizes.)
pub fn provenance_rows(exp: &Experiment) -> Vec<String> {
    provenance_rows_for(exp.cell_workers(), exp.fit_threads())
}

/// [`provenance_rows`] from bare counts — the single source of the header
/// format (`write_csv` in the CLI routes through this with the thread
/// split derived from the run config).
pub fn provenance_rows_for(cell_threads: usize, fit_threads: usize) -> Vec<String> {
    vec![
        format!("# cell_threads = {cell_threads}"),
        format!("# fit_threads = {fit_threads}"),
        format!("# kernel = {}", crate::kernels::active_name()),
    ]
}

/// Which metric a table reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// Relative number of distance computations (Table 2).
    Distances,
    /// Relative run time including index construction (Tables 3-4).
    Time,
}

impl Metric {
    fn extract(&self, c: &CellResult) -> f64 {
        match self {
            Metric::Distances => c.total_distances() as f64,
            Metric::Time => c.total_time().as_secs_f64(),
        }
    }
}

/// Render a paper-style table: algorithms as rows, datasets as columns,
/// each value the ratio vs the Standard algorithm on that dataset.
pub fn render_ratio_table(
    exp: &Experiment,
    res: &ExperimentResult,
    metric: Metric,
    title: &str,
) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{title}");
    let _ = write!(s, "{:<12}", "");
    for ds in &exp.datasets {
        let _ = write!(s, " {ds:>9}");
    }
    let _ = writeln!(s);
    for &alg in &exp.algorithms {
        if alg == Algorithm::Standard {
            continue; // the baseline row is 1.000 by construction
        }
        let _ = write!(s, "{:<12}", alg.name());
        for ds in &exp.datasets {
            match res.ratio_vs_standard(ds, alg, |c| metric.extract(c)) {
                Some(r) => {
                    let _ = write!(s, " {r:>9.3}");
                }
                None => {
                    let _ = write!(s, " {:>9}", "-");
                }
            }
        }
        let _ = writeln!(s);
    }
    s
}

/// CSV rows for a ratio table: `dataset,algorithm,ratio`.
pub fn ratio_table_csv(
    exp: &Experiment,
    res: &ExperimentResult,
    metric: Metric,
) -> Vec<String> {
    let mut rows = vec!["dataset,algorithm,ratio".to_string()];
    for ds in &exp.datasets {
        for &alg in &exp.algorithms {
            if let Some(r) = res.ratio_vs_standard(ds, alg, |c| metric.extract(c)) {
                rows.push(format!("{ds},{},{r:.6}", alg.name()));
            }
        }
    }
    rows
}

/// Fig. 1 series: cumulative distance computations and time per iteration,
/// normalized by the *full* Standard run (the paper's normalization).
/// Returns CSV rows `algorithm,iter,dist_cum_rel,time_cum_rel`.
pub fn fig1_series_csv(exp: &Experiment, res: &ExperimentResult) -> Vec<String> {
    let mut rows = vec!["algorithm,iter,dist_cum_rel,time_cum_rel".to_string()];
    let ds = &exp.datasets[0];
    let Some(std_cell) = res.cell(ds, Algorithm::Standard) else {
        return rows;
    };
    let Some(std_log) = std_cell.runs[0].log.as_ref() else {
        return rows;
    };
    let Some(std_last) = std_log.stats.last() else {
        return rows;
    };
    let std_dist = std_last.dist_cum as f64;
    let std_time = std_last.time_cum.as_secs_f64();
    for &alg in &exp.algorithms {
        let Some(cell) = res.cell(ds, alg) else { continue };
        let Some(log) = cell.runs[0].log.as_ref() else { continue };
        for st in &log.stats {
            rows.push(format!(
                "{},{},{:.6},{:.6}",
                alg.name(),
                st.iter,
                st.dist_cum as f64 / std_dist,
                st.time_cum.as_secs_f64() / std_time,
            ));
        }
    }
    rows
}

/// Fig. 2 series: one ratio per (x, algorithm) where x is the dataset
/// (Fig. 2a, d on the x-axis) or k (Fig. 2b).
pub fn fig2_series_csv(
    exp: &Experiment,
    res: &ExperimentResult,
    by_k: bool,
) -> Vec<String> {
    let mut rows = vec![format!(
        "{},algorithm,time_rel",
        if by_k { "k" } else { "dataset" }
    )];
    if by_k {
        let ds = &exp.datasets[0];
        for &k in &exp.ks {
            for &alg in &exp.algorithms {
                let (Some(cell), Some(std_cell)) =
                    (res.cell(ds, alg), res.cell(ds, Algorithm::Standard))
                else {
                    continue;
                };
                let t = per_k_time(cell, k);
                let ts = per_k_time(std_cell, k);
                if ts > 0.0 {
                    rows.push(format!("{k},{},{:.6}", alg.name(), t / ts));
                }
            }
        }
    } else {
        for ds in &exp.datasets {
            for &alg in &exp.algorithms {
                if let Some(r) =
                    res.ratio_vs_standard(ds, alg, |c| c.total_time().as_secs_f64())
                {
                    rows.push(format!("{ds},{},{r:.6}", alg.name()));
                }
            }
        }
    }
    rows
}

fn per_k_time(cell: &CellResult, k: usize) -> f64 {
    let mut t = 0.0;
    for r in &cell.runs {
        if r.k == k {
            t += (r.time + r.build_time).as_secs_f64();
        }
    }
    t
}

/// Quick ASCII bar chart of a ratio series (terminal figure rendering).
pub fn ascii_chart(rows: &[(String, f64)], width: usize) -> String {
    let max = rows.iter().map(|r| r.1).fold(0.0f64, f64::max).max(1e-12);
    let mut s = String::new();
    for (label, v) in rows {
        let bar = ((v / max) * width as f64).round() as usize;
        let _ = writeln!(s, "{label:<22} {:<width$} {v:.3}", "#".repeat(bar.max(1)));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_experiment;

    fn tiny() -> (Experiment, ExperimentResult) {
        let exp = Experiment {
            datasets: vec!["blobs:150:2:3".into()],
            algorithms: vec![Algorithm::Standard, Algorithm::Hamerly],
            ks: vec![3],
            restarts: 1,
            scale: 1.0,
            threads: 1,
            ..Experiment::new("t")
        };
        let res = run_experiment(&exp, true).unwrap();
        (exp, res)
    }

    #[test]
    fn provenance_reports_actual_thread_split() {
        let mut exp = Experiment::new("prov");
        exp.threads = 8;
        exp.params.threads = 2;
        let rows = provenance_rows(&exp);
        assert_eq!(rows[0], "# cell_threads = 4");
        assert_eq!(rows[1], "# fit_threads = 2");
        assert_eq!(
            rows[2],
            format!("# kernel = {}", crate::kernels::active_name())
        );
    }

    #[test]
    fn ratio_table_renders() {
        let (exp, res) = tiny();
        let t = render_ratio_table(&exp, &res, Metric::Distances, "Table X");
        assert!(t.contains("Hamerly"));
        assert!(!t.contains("Standard  ")); // baseline row omitted
        let csv = ratio_table_csv(&exp, &res, Metric::Distances);
        assert_eq!(csv[0], "dataset,algorithm,ratio");
        assert!(csv.len() >= 3); // header + standard + hamerly
    }

    #[test]
    fn fig1_series_normalized_to_standard_total() {
        let (exp, res) = tiny();
        let rows = fig1_series_csv(&exp, &res);
        assert!(rows.len() > 1);
        // The Standard algorithm's last row must be ~1.0 in both metrics.
        let std_rows: Vec<&String> =
            rows.iter().filter(|r| r.starts_with("Standard")).collect();
        let last = std_rows.last().unwrap();
        let cols: Vec<&str> = last.split(',').collect();
        let dist_rel: f64 = cols[2].parse().unwrap();
        assert!((dist_rel - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ascii_chart_draws_bars() {
        let chart = ascii_chart(
            &[("a".into(), 1.0), ("b".into(), 0.5)],
            20,
        );
        assert!(chart.contains("####"));
    }
}
