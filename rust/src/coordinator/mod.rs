//! L3 coordination: the experiment scheduler that reproduces the paper's
//! evaluation protocol.
//!
//! The unit of scheduling is a **cell** — one `(dataset, algorithm)` pair
//! covering all `(k, restart)` combinations of an experiment. Cells run in
//! parallel on a work-stealing queue of OS threads. The total thread
//! budget ([`Experiment::threads`]) is split between cell-level workers
//! and intra-fit threads ([`KMeansParams::threads`], config key
//! `fit_threads`): the coordinator spawns `threads / fit_threads` cell
//! workers, each fit sharding its assignment phase over `fit_threads`
//! workers drawn from **one persistent pool per cell** (spawned once,
//! reused by every fit, tree build, and seeding pass of the cell). With
//! `fit_threads = 1` (the default) everything inside a cell
//! is strictly single-threaded, matching the paper's single-core runs —
//! and because the intra-fit reductions are exactness-preserving, raising
//! `fit_threads` changes wall time only, never a counted metric. Initial
//! centers are derived from `(dataset, k, restart)` only, so every
//! algorithm sees byte-identical k-means++ seeds — the paper's "same 10
//! random initializations for each algorithm".
//!
//! Tree amortization: with [`Experiment::amortize_tree`] (the Table 4
//! parameter-sweep protocol) a cell keeps one [`Workspace`] across all its
//! runs, so the cover/k-d tree is built once per dataset and its build
//! cost is charged exactly once; otherwise every run rebuilds (Tables 2-3
//! include construction per run).

pub mod report;
pub mod sweep;

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::data::{registry, DataSource, Matrix, SourceBackend};
use crate::kmeans::{
    self, Algorithm, AlgorithmSpec, KMeans, KMeansModel, KMeansParams, Workspace,
};
use crate::metrics::{DistCounter, IterationLog};

/// One experiment specification.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub name: String,
    pub datasets: Vec<String>,
    pub algorithms: Vec<Algorithm>,
    pub ks: Vec<usize>,
    pub restarts: usize,
    /// Dataset scale relative to the paper's sizes.
    pub scale: f64,
    pub data_seed: u64,
    pub params: KMeansParams,
    /// Reuse one workspace (tree) across all runs of a cell (Table 4).
    pub amortize_tree: bool,
    /// Warm-started sweep restarts: with `ks` ascending, each restart of a
    /// larger k starts from the same restart's previous-k solution,
    /// extended to k centers by D² sampling
    /// ([`kmeans::init::extend_centers`]), instead of a cold k-means++
    /// seed. Off by default — it changes the optimization trajectory, so
    /// the paper-replication protocols never enable it.
    pub warm_restarts: bool,
    /// Total worker-thread budget, split between cell-level workers and
    /// the intra-fit threads configured in `params.threads` (see
    /// [`Experiment::cell_workers`]).
    pub threads: usize,
    /// When set, each cell persists its best run (lowest SSE across every
    /// `(k, restart)`) as a servable [`KMeansModel`] at
    /// `<model_dir>/<dataset>_<algorithm>.kmm` — the train-once /
    /// serve-many hand-off from a sweep. `None` (the default) keeps the
    /// paper-replication protocols free of I/O.
    pub model_dir: Option<std::path::PathBuf>,
    /// Completion manifest for interrupted-sweep resume. When set, every
    /// finished `(dataset, algorithm)` cell is recorded here (atomic
    /// rewrite after each cell), and a rerun of the *same* experiment —
    /// guarded by a fingerprint over the cell grid and run parameters —
    /// adopts the recorded cells instead of recomputing them. The file is
    /// removed once every cell is complete, so a finished sweep always
    /// starts fresh. Adopted cells carry no per-iteration logs.
    pub manifest_path: Option<std::path::PathBuf>,
}

impl Experiment {
    pub fn new(name: &str) -> Experiment {
        Experiment {
            name: name.to_string(),
            datasets: registry::table_names().iter().map(|s| s.to_string()).collect(),
            algorithms: Algorithm::ALL.to_vec(),
            ks: vec![100],
            restarts: 10,
            scale: 0.05,
            data_seed: 1,
            params: KMeansParams::default(),
            amortize_tree: false,
            warm_restarts: false,
            threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            model_dir: None,
            manifest_path: None,
        }
    }

    /// Intra-fit threads each run uses (`params.threads`; 0 = all cores).
    pub fn fit_threads(&self) -> usize {
        thread_split(self.threads, self.params.threads).1
    }

    /// Cell-level workers after splitting the total budget with the
    /// intra-fit threads: `threads / fit_threads`, at least 1.
    pub fn cell_workers(&self) -> usize {
        thread_split(self.threads, self.params.threads).0
    }
}

/// Split a total thread budget into `(cell_workers, fit_threads)`:
/// `fit_threads` resolves 0 to all cores, and the cell level gets
/// `total / fit_threads` workers (each side at least 1).
pub fn thread_split(total: usize, fit_threads: usize) -> (usize, usize) {
    let fit = crate::parallel::resolve_threads(fit_threads);
    ((total.max(1) / fit).max(1), fit)
}

/// Summary of a single run within a cell.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub k: usize,
    pub restart: usize,
    pub iterations: usize,
    pub distances: u64,
    pub build_dist: u64,
    pub time: Duration,
    pub build_time: Duration,
    pub sse: f64,
    pub converged: bool,
    /// Per-iteration series (kept only when the experiment asks for it).
    pub log: Option<IterationLog>,
}

/// Aggregated result of one `(dataset, algorithm)` cell.
#[derive(Debug, Clone, Default)]
pub struct CellResult {
    pub distances: u64,
    pub build_dist: u64,
    pub time: Duration,
    pub build_time: Duration,
    pub runs: Vec<RunSummary>,
}

impl CellResult {
    /// Total distance computations including index construction.
    pub fn total_distances(&self) -> u64 {
        self.distances + self.build_dist
    }

    pub fn total_time(&self) -> Duration {
        self.time + self.build_time
    }
}

/// All cells of an experiment, keyed `(dataset, algorithm)`.
#[derive(Debug, Clone, Default)]
pub struct ExperimentResult {
    pub cells: BTreeMap<(String, &'static str), CellResult>,
}

impl ExperimentResult {
    pub fn cell(&self, dataset: &str, alg: Algorithm) -> Option<&CellResult> {
        self.cells.get(&(dataset.to_string(), alg.name()))
    }

    /// Ratio of a metric vs the Standard algorithm on the same dataset.
    pub fn ratio_vs_standard<F: Fn(&CellResult) -> f64>(
        &self,
        dataset: &str,
        alg: Algorithm,
        f: F,
    ) -> Option<f64> {
        let cell = self.cell(dataset, alg)?;
        let std_cell = self.cell(dataset, Algorithm::Standard)?;
        let denom = f(std_cell);
        if denom <= 0.0 {
            return None;
        }
        Some(f(cell) / denom)
    }
}

/// Deterministic init seed shared by all algorithms for a
/// `(dataset, k, restart)` triple.
pub fn init_seed(dataset: &str, k: usize, restart: usize) -> u64 {
    let mut h = crate::data::io::fnv1a(dataset.as_bytes());
    h ^= (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h ^= (restart as u64).wrapping_mul(0xD1B5_4A32_D192_ED03);
    h
}

/// Fingerprint of everything that determines a sweep's cell grid and the
/// work inside each cell, binding a completion manifest to its experiment.
/// Thread topology is deliberately excluded: intra-fit parallelism is
/// exactness-preserving, so a sweep may resume at a different thread count.
fn experiment_fingerprint(exp: &Experiment) -> u64 {
    let mut buf = Vec::new();
    buf.extend_from_slice(exp.name.as_bytes());
    for d in &exp.datasets {
        buf.push(0);
        buf.extend_from_slice(d.as_bytes());
    }
    for a in &exp.algorithms {
        buf.push(1);
        buf.extend_from_slice(a.name().as_bytes());
    }
    for &k in &exp.ks {
        buf.extend_from_slice(&(k as u64).to_le_bytes());
    }
    buf.extend_from_slice(&(exp.restarts as u64).to_le_bytes());
    buf.extend_from_slice(&exp.scale.to_bits().to_le_bytes());
    buf.extend_from_slice(&exp.data_seed.to_le_bytes());
    buf.extend_from_slice(&(exp.params.max_iter as u64).to_le_bytes());
    buf.extend_from_slice(&exp.params.tol.to_bits().to_le_bytes());
    buf.extend_from_slice(&exp.params.cover.scale_factor.to_bits().to_le_bytes());
    buf.extend_from_slice(&(exp.params.cover.min_node_size as u64).to_le_bytes());
    buf.extend_from_slice(&(exp.params.kd.leaf_size as u64).to_le_bytes());
    buf.extend_from_slice(&(exp.params.switch_at as u64).to_le_bytes());
    buf.push(exp.amortize_tree as u8);
    buf.push(exp.warm_restarts as u8);
    crate::data::io::fnv1a(&buf)
}

/// Serialize the completed cells: one `cell` line per `(dataset,
/// algorithm)` pair, one `run` line per `(k, restart)` with SSE as raw
/// f64 bits so an adopted cell reproduces the original byte for byte.
fn render_manifest(fingerprint: u64, res: &ExperimentResult) -> String {
    let mut s = format!("covermeans-sweep-manifest v1 {fingerprint:#018x}\n");
    for ((dataset, alg), cell) in &res.cells {
        s.push_str(&format!("cell {dataset} {}\n", alg.to_ascii_lowercase()));
        for r in &cell.runs {
            s.push_str(&format!(
                "run {} {} {} {} {} {} {} {:016x} {}\n",
                r.k,
                r.restart,
                r.iterations,
                r.distances,
                r.build_dist,
                r.time.as_nanos(),
                r.build_time.as_nanos(),
                r.sse.to_bits(),
                r.converged as u8,
            ));
        }
    }
    s
}

/// Parse a completion manifest back into results. `None` on any mismatch —
/// wrong fingerprint, unknown line, short field list — in which case the
/// sweep starts from scratch (a stale manifest must never inject cells
/// from a different experiment).
fn parse_manifest(text: &str, fingerprint: u64) -> Option<ExperimentResult> {
    let mut lines = text.lines();
    let mut header = lines.next()?.split_whitespace();
    if header.next()? != "covermeans-sweep-manifest" || header.next()? != "v1" {
        return None;
    }
    let fp =
        u64::from_str_radix(header.next()?.trim_start_matches("0x"), 16).ok()?;
    if fp != fingerprint {
        return None;
    }
    let mut res = ExperimentResult::default();
    let mut current: Option<(String, &'static str)> = None;
    for line in lines {
        if line.trim().is_empty() {
            continue;
        }
        let mut f = line.split_whitespace();
        match f.next()? {
            "cell" => {
                let dataset = f.next()?.to_string();
                let alg = Algorithm::parse(f.next()?)?;
                let key = (dataset, alg.name());
                res.cells.insert(key.clone(), CellResult::default());
                current = Some(key);
            }
            "run" => {
                let cell = res.cells.get_mut(current.as_ref()?)?;
                let k: usize = f.next()?.parse().ok()?;
                let restart: usize = f.next()?.parse().ok()?;
                let iterations: usize = f.next()?.parse().ok()?;
                let distances: u64 = f.next()?.parse().ok()?;
                let build_dist: u64 = f.next()?.parse().ok()?;
                let time = Duration::from_nanos(f.next()?.parse().ok()?);
                let build_time = Duration::from_nanos(f.next()?.parse().ok()?);
                let sse =
                    f64::from_bits(u64::from_str_radix(f.next()?, 16).ok()?);
                let converged = f.next()? == "1";
                cell.distances += distances;
                cell.build_dist += build_dist;
                cell.time += time;
                cell.build_time += build_time;
                cell.runs.push(RunSummary {
                    k,
                    restart,
                    iterations,
                    distances,
                    build_dist,
                    time,
                    build_time,
                    sse,
                    converged,
                    log: None,
                });
            }
            _ => return None,
        }
    }
    Some(res)
}

/// Atomically persist the manifest (previous generation retained by
/// [`crate::data::io::atomic_write`], like every other artifact).
fn write_manifest(
    path: &std::path::Path,
    fingerprint: u64,
    res: &ExperimentResult,
) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    crate::data::io::atomic_write(path, render_manifest(fingerprint, res).as_bytes())
}

/// Open one experiment dataset. Names prefixed `dmat:` open the packed
/// file behind them (`covermeans pack` writes these) as a chunk-streamed
/// out-of-core source; every other name is generated resident through the
/// registry, exactly as before.
fn load_source(name: &str, scale: f64, data_seed: u64) -> Result<DataSource> {
    if let Some(path) = name.strip_prefix("dmat:") {
        return DataSource::open(
            std::path::Path::new(path),
            SourceBackend::Chunked,
            crate::data::source::DEFAULT_CHUNK_ROWS,
            0,
        )
        .with_context(|| format!("dataset {name:?}"));
    }
    let m = registry::load(name, scale, data_seed)
        .with_context(|| format!("unknown dataset {name:?}"))?;
    Ok(DataSource::from(m))
}

/// Run every `(dataset, algorithm)` cell of the experiment on a thread
/// pool. `keep_logs` retains per-iteration series (Fig. 1).
pub fn run_experiment(exp: &Experiment, keep_logs: bool) -> Result<ExperimentResult> {
    // Open all datasets up front (deterministic, shared read-only).
    // Streamed (`dmat:`) sources are validated against the cell grid here
    // so an impossible sweep fails with one clear message instead of a
    // mid-sweep panic from a worker thread.
    let mut datasets: BTreeMap<String, Arc<DataSource>> = BTreeMap::new();
    for name in &exp.datasets {
        let src = load_source(name, exp.scale, exp.data_seed)?;
        if src.view().as_matrix().is_none() {
            if let Some(alg) = exp.algorithms.iter().find(|a| !a.streams()) {
                anyhow::bail!(
                    "dataset {name:?} is streamed, but {} needs a resident \
                     data source; drop the algorithm from the experiment or \
                     load the data resident (a non-dmat dataset name)",
                    alg.name()
                );
            }
            if exp.warm_restarts {
                anyhow::bail!(
                    "warm_restarts extends centers over a resident matrix \
                     and cannot run on streamed dataset {name:?}"
                );
            }
        }
        datasets.insert(name.clone(), Arc::new(src));
    }

    // Interrupted-sweep resume: adopt cells a previous invocation of the
    // *same* experiment (fingerprint-guarded) already completed.
    let total = exp.datasets.len() * exp.algorithms.len();
    let fingerprint = experiment_fingerprint(exp);
    let mut done = ExperimentResult::default();
    if let Some(mpath) = &exp.manifest_path {
        if let Ok(text) = std::fs::read_to_string(mpath) {
            match parse_manifest(&text, fingerprint) {
                Some(prev) => {
                    eprintln!(
                        "resuming sweep: {} of {total} cells already complete \
                         (manifest {})",
                        prev.cells.len(),
                        mpath.display()
                    );
                    done = prev;
                }
                None => eprintln!(
                    "ignoring stale sweep manifest {} (written by a different \
                     experiment); starting fresh",
                    mpath.display()
                ),
            }
        }
    }

    // Cell queue.
    struct Cell {
        dataset: String,
        alg: Algorithm,
    }
    let queue: Mutex<Vec<Cell>> = Mutex::new(
        exp.datasets
            .iter()
            .flat_map(|d| {
                exp.algorithms.iter().map(move |&alg| Cell { dataset: d.clone(), alg })
            })
            .filter(|c| !done.cells.contains_key(&(c.dataset.clone(), c.alg.name())))
            .collect(),
    );
    let results: Mutex<ExperimentResult> = Mutex::new(done);
    // Cell-level × intra-fit budget split: fits that shard internally get
    // proportionally fewer concurrent cells.
    let threads = exp.cell_workers();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let cell = { queue.lock().unwrap().pop() };
                let Some(cell) = cell else { break };
                let data = datasets.get(&cell.dataset).unwrap().clone();
                let res = run_cell(exp, &cell.dataset, cell.alg, &data, keep_logs);
                let mut guard = results.lock().unwrap();
                guard.cells.insert((cell.dataset, cell.alg.name()), res);
                if let Some(mpath) = &exp.manifest_path {
                    // A manifest write failure degrades resume, not the
                    // sweep itself: report and carry on.
                    if let Err(e) = write_manifest(mpath, fingerprint, &guard) {
                        eprintln!(
                            "warning: could not write sweep manifest {}: {e:#}",
                            mpath.display()
                        );
                    }
                }
            });
        }
    });

    let results = results.into_inner().unwrap();
    if let Some(mpath) = &exp.manifest_path {
        if results.cells.len() == total {
            // The sweep is complete: a manifest left behind would make the
            // next invocation a silent no-op serving stale cells.
            std::fs::remove_file(mpath).ok();
            std::fs::remove_file(crate::data::io::sibling_path(mpath, ".prev")).ok();
            std::fs::remove_file(crate::data::io::sibling_path(mpath, ".tmp")).ok();
        }
    }
    Ok(results)
}

/// Execute one cell: all `(k, restart)` runs of one algorithm on one
/// dataset, sequential and single-threaded.
fn run_cell(
    exp: &Experiment,
    dataset: &str,
    alg: Algorithm,
    data: &DataSource,
    keep_logs: bool,
) -> CellResult {
    let src = data.view();
    let mut out = CellResult::default();
    let mut ws = Workspace::new();
    // One persistent worker pool per cell, shared by every fit, tree
    // build, and seeding pass the cell runs (fit_threads > 1 only pays
    // the spawn cost once, not per run).
    let fit_par =
        ws.parallelism_opts(exp.params.threads, exp.params.pin_workers);
    let spec = AlgorithmSpec::from_params(alg, &exp.params);
    // Previous-k solution per restart, for the warm-started sweep.
    let mut prev_centers: Vec<Option<Matrix>> = vec![None; exp.restarts];
    // Best run of the cell so far (lowest SSE), kept only when the
    // experiment persists models.
    let mut best: Option<(f64, KMeansModel)> = None;

    for &k in &exp.ks {
        let k = k.min(src.rows());
        for restart in 0..exp.restarts {
            if !exp.amortize_tree {
                // Fresh tree per run (Tables 2-3 charge construction per
                // run); the pool survives.
                ws.clear_trees();
            }
            // Init distances are charged to a separate counter (the paper
            // generates each seed once, outside the per-algorithm cost).
            let mut init_counter = DistCounter::new();
            let seed = init_seed(dataset, k, restart);
            let init = match src.as_matrix() {
                Some(m) => match &prev_centers[restart] {
                    Some(prev) if exp.warm_restarts && prev.rows() <= k => {
                        kmeans::init::extend_centers_par(
                            m,
                            prev,
                            k,
                            seed,
                            &mut init_counter,
                            &fit_par,
                        )
                    }
                    _ => kmeans::init::kmeans_plus_plus_par(
                        m,
                        k,
                        seed,
                        &mut init_counter,
                        &fit_par,
                    ),
                },
                // Streamed cells seed with k-means|| — a bounded number of
                // full passes instead of k sequential ones (rounds and
                // oversampling match the builder's defaults).
                None => kmeans::init::init_kmeanspar_src(
                    src,
                    k,
                    seed,
                    5,
                    2.0,
                    &mut init_counter,
                    &fit_par,
                ),
            };
            let builder = KMeans::new(k)
                .algorithm(spec)
                .max_iter(exp.params.max_iter)
                .tol(exp.params.tol)
                .threads(exp.params.threads)
                .warm_start(init);
            // fit_source_with routes MiniBatch to its own runner and drives
            // the exact algorithms through the stepwise fit_step_src loop.
            // Streamed input was validated against the algorithm list up
            // front, so the only failure mode left is a shape bug.
            let r = builder.fit_source_with(data, &mut ws).expect("validated shapes");
            if exp.warm_restarts {
                prev_centers[restart] = Some(r.centers.clone());
            }
            let sse = crate::metrics::sse_src(src, &r.labels, &r.centers);
            let improves = match &best {
                Some((b, _)) => sse < *b,
                None => true,
            };
            if exp.model_dir.is_some() && improves {
                best = Some((sse, KMeansModel::from_run_src(src, &r, alg, seed)));
            }
            out.distances += r.distances;
            out.build_dist += r.build_dist;
            out.time += r.time;
            out.build_time += r.build_time;
            out.runs.push(RunSummary {
                k,
                restart,
                iterations: r.iterations,
                distances: r.distances,
                build_dist: r.build_dist,
                time: r.time,
                build_time: r.build_time,
                sse,
                converged: r.converged,
                log: keep_logs.then(|| r.log.clone()),
            });
        }
    }
    if let (Some(dir), Some((_, model))) = (&exp.model_dir, &best) {
        // `dmat:` dataset names carry a file path; flatten separators so
        // the model lands inside `dir` instead of a phantom subtree.
        let stem = dataset.replace(['/', '\\'], "_");
        let path = dir.join(format!("{stem}_{}.kmm", alg.name()));
        // A failed save must not poison the sweep results; report and
        // carry on (the CSV/Table outputs are the primary artifact).
        if let Err(e) = std::fs::create_dir_all(dir)
            .map_err(anyhow::Error::from)
            .and_then(|()| model.save(&path))
        {
            eprintln!("warning: could not persist cell model {path:?}: {e:#}");
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_experiment() -> Experiment {
        Experiment {
            datasets: vec!["blobs:200:3:4".into()],
            algorithms: vec![Algorithm::Standard, Algorithm::Shallot, Algorithm::Hybrid],
            ks: vec![4],
            restarts: 2,
            scale: 1.0,
            threads: 2,
            ..Experiment::new("tiny")
        }
    }

    #[test]
    fn experiment_runs_all_cells_and_is_exact() {
        let exp = tiny_experiment();
        let res = run_experiment(&exp, false).unwrap();
        assert_eq!(res.cells.len(), 3);
        // Same SSE per (k, restart) across algorithms (exactness).
        let std_runs = &res.cell("blobs:200:3:4", Algorithm::Standard).unwrap().runs;
        for alg in [Algorithm::Shallot, Algorithm::Hybrid] {
            let runs = &res.cell("blobs:200:3:4", alg).unwrap().runs;
            assert_eq!(runs.len(), std_runs.len());
            for (a, b) in runs.iter().zip(std_runs) {
                assert_eq!(a.iterations, b.iterations, "{}", alg.name());
                assert!(
                    (a.sse - b.sse).abs() < 1e-6 * (1.0 + b.sse),
                    "{}: sse {} vs {}",
                    alg.name(),
                    a.sse,
                    b.sse
                );
            }
        }
    }

    #[test]
    fn ratio_vs_standard_is_one_for_standard() {
        let exp = tiny_experiment();
        let res = run_experiment(&exp, false).unwrap();
        let r = res
            .ratio_vs_standard("blobs:200:3:4", Algorithm::Standard, |c| {
                c.total_distances() as f64
            })
            .unwrap();
        assert!((r - 1.0).abs() < 1e-12);
    }

    #[test]
    fn thread_budget_splits_between_cells_and_fits() {
        let mut exp = tiny_experiment();
        exp.threads = 8;
        exp.params.threads = 4;
        assert_eq!(exp.fit_threads(), 4);
        assert_eq!(exp.cell_workers(), 2);
        exp.params.threads = 16;
        assert_eq!(exp.cell_workers(), 1, "fit threads exhaust the budget");
        exp.params.threads = 1;
        assert_eq!(exp.cell_workers(), 8);
    }

    #[test]
    fn intra_fit_threads_reproduce_sequential_results() {
        let mut exp_seq = tiny_experiment();
        exp_seq.params.threads = 1;
        let res_seq = run_experiment(&exp_seq, false).unwrap();

        let mut exp_par = tiny_experiment();
        exp_par.threads = 4;
        exp_par.params.threads = 4;
        let res_par = run_experiment(&exp_par, false).unwrap();

        assert_eq!(res_par.cells.len(), res_seq.cells.len());
        for (key, cell) in &res_par.cells {
            let cell_seq = res_seq.cells.get(key).unwrap();
            assert_eq!(cell.distances, cell_seq.distances, "{key:?}");
            assert_eq!(cell.build_dist, cell_seq.build_dist, "{key:?}");
            for (a, b) in cell.runs.iter().zip(&cell_seq.runs) {
                assert_eq!(a.iterations, b.iterations, "{key:?}");
                assert_eq!(a.distances, b.distances, "{key:?}");
                assert_eq!(a.sse.to_bits(), b.sse.to_bits(), "{key:?}");
            }
        }
    }

    #[test]
    fn init_seed_depends_on_all_inputs() {
        let a = init_seed("x", 10, 0);
        assert_ne!(a, init_seed("y", 10, 0));
        assert_ne!(a, init_seed("x", 11, 0));
        assert_ne!(a, init_seed("x", 10, 1));
        assert_eq!(a, init_seed("x", 10, 0));
    }

    #[test]
    fn amortized_tree_charges_build_once() {
        let mut exp = tiny_experiment();
        exp.algorithms = vec![Algorithm::CoverMeans];
        exp.amortize_tree = true;
        exp.restarts = 3;
        let res = run_experiment(&exp, false).unwrap();
        let cell = res.cell("blobs:200:3:4", Algorithm::CoverMeans).unwrap();
        let builds: usize = cell
            .runs
            .iter()
            .filter(|r| r.build_time > Duration::ZERO || r.build_dist > 0)
            .count();
        assert_eq!(builds, 1, "tree must be built exactly once");
    }

    #[test]
    fn warm_restarts_reuse_previous_k() {
        let mut exp = tiny_experiment();
        exp.algorithms = vec![Algorithm::Hybrid];
        exp.ks = vec![2, 4];
        exp.restarts = 2;
        exp.amortize_tree = true;
        exp.warm_restarts = true;
        let res = run_experiment(&exp, false).unwrap();
        let cell = res.cell("blobs:200:3:4", Algorithm::Hybrid).unwrap();
        assert_eq!(cell.runs.len(), 4);
        for r in &cell.runs {
            assert!(r.converged, "k={} restart={}", r.k, r.restart);
            assert!(r.sse.is_finite() && r.sse >= 0.0);
        }
        // Warm-started k=4 refines the k=2 solutions: SSE must drop.
        let sse2: f64 = cell.runs.iter().filter(|r| r.k == 2).map(|r| r.sse).sum();
        let sse4: f64 = cell.runs.iter().filter(|r| r.k == 4).map(|r| r.sse).sum();
        assert!(sse4 < sse2, "k=4 warm sse {sse4} vs k=2 sse {sse2}");
    }

    #[test]
    fn model_dir_persists_best_cell_models() {
        let dir = std::env::temp_dir().join(format!(
            "covermeans_cell_models_{}",
            std::process::id()
        ));
        let mut exp = tiny_experiment();
        exp.algorithms = vec![Algorithm::Standard, Algorithm::Hybrid];
        exp.model_dir = Some(dir.clone());
        let res = run_experiment(&exp, false).unwrap();
        for alg in [Algorithm::Standard, Algorithm::Hybrid] {
            let path = dir.join(format!("blobs:200:3:4_{}.kmm", alg.name()));
            let model = KMeansModel::load(&path)
                .unwrap_or_else(|e| panic!("missing cell model {path:?}: {e:#}"));
            assert_eq!(model.k(), 4);
            assert_eq!(model.dim(), 3);
            assert_eq!(model.algorithm(), alg);
            // The persisted model is the best run: its inertia matches
            // the cell's minimum recorded SSE.
            let cell = res.cell("blobs:200:3:4", alg).unwrap();
            let best = cell.runs.iter().map(|r| r.sse).fold(f64::INFINITY, f64::min);
            assert!(
                (model.inertia() - best).abs() < 1e-9 * (1.0 + best),
                "{}: persisted inertia {} vs best sse {best}",
                alg.name(),
                model.inertia()
            );
            std::fs::remove_file(&path).ok();
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streamed_dmat_cells_run_and_reject_tree_algorithms() {
        let dir = std::env::temp_dir()
            .join(format!("covermeans_coord_dmat_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blobs.dmat");
        let data = registry::load("blobs:200:3:4", 1.0, 1).unwrap();
        crate::data::write_dmat(&path, &data).unwrap();
        let name = format!("dmat:{}", path.display());

        // Streamed cells must be thread-invariant: the coordinator's
        // determinism contract does not stop at resident sources.
        let mut exp = tiny_experiment();
        exp.datasets = vec![name.clone()];
        exp.algorithms = vec![Algorithm::Standard];
        let res_seq = run_experiment(&exp, false).unwrap();
        let mut exp_par = exp.clone();
        exp_par.threads = 4;
        exp_par.params.threads = 4;
        let res_par = run_experiment(&exp_par, false).unwrap();
        let a = res_seq.cell(&name, Algorithm::Standard).unwrap();
        let b = res_par.cell(&name, Algorithm::Standard).unwrap();
        assert_eq!(a.distances, b.distances);
        assert_eq!(a.runs.len(), b.runs.len());
        for (x, y) in a.runs.iter().zip(&b.runs) {
            assert_eq!(x.iterations, y.iterations);
            assert_eq!(x.distances, y.distances);
            assert_eq!(x.sse.to_bits(), y.sse.to_bits());
        }

        // Tree algorithms need a resident source: one clear error before
        // any cell runs, naming the offending algorithm.
        let mut bad = exp.clone();
        bad.algorithms = vec![Algorithm::CoverMeans];
        let err = run_experiment(&bad, false).unwrap_err().to_string();
        assert!(err.contains("streamed"), "unhelpful error: {err}");
        assert!(
            err.contains(Algorithm::CoverMeans.name()),
            "unhelpful error: {err}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_roundtrips_and_rejects_other_experiments() {
        let res = run_experiment(&tiny_experiment(), false).unwrap();
        let text = render_manifest(7, &res);
        let back = parse_manifest(&text, 7).unwrap();
        assert_eq!(back.cells.len(), res.cells.len());
        for (key, cell) in &res.cells {
            let b = back.cells.get(key).unwrap();
            assert_eq!(b.distances, cell.distances, "{key:?}");
            assert_eq!(b.build_dist, cell.build_dist, "{key:?}");
            assert_eq!(b.total_time(), cell.total_time(), "{key:?}");
            assert_eq!(b.runs.len(), cell.runs.len());
            for (x, y) in b.runs.iter().zip(&cell.runs) {
                assert_eq!(x.k, y.k);
                assert_eq!(x.restart, y.restart);
                assert_eq!(x.iterations, y.iterations);
                assert_eq!(x.distances, y.distances);
                assert_eq!(x.sse.to_bits(), y.sse.to_bits());
                assert_eq!(x.converged, y.converged);
            }
        }
        // Wrong fingerprint or garbage: discarded, never half-parsed.
        assert!(parse_manifest(&text, 8).is_none());
        assert!(parse_manifest("garbage", 7).is_none());
        assert!(parse_manifest("", 7).is_none());
        // The fingerprint tracks the work grid, not the thread topology
        // (sweeps may resume at a different thread count).
        let a = experiment_fingerprint(&tiny_experiment());
        let mut same = tiny_experiment();
        same.threads = 16;
        same.params.threads = 4;
        assert_eq!(a, experiment_fingerprint(&same));
        let mut other = tiny_experiment();
        other.restarts = 5;
        assert_ne!(a, experiment_fingerprint(&other));
        let mut other = tiny_experiment();
        other.ks = vec![5];
        assert_ne!(a, experiment_fingerprint(&other));
    }

    #[test]
    fn sweep_resumes_from_manifest_and_cleans_up() {
        let dir = std::env::temp_dir().join(format!(
            "covermeans_sweep_manifest_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let mpath = dir.join("tiny.manifest");
        let reference = run_experiment(&tiny_experiment(), false).unwrap();

        // Simulate an interrupted sweep: one cell recorded, two to go —
        // with a poisoned summary so adoption (vs recomputation) is
        // observable.
        let mut exp = tiny_experiment();
        exp.manifest_path = Some(mpath.clone());
        let key = ("blobs:200:3:4".to_string(), Algorithm::Standard.name());
        let mut partial = ExperimentResult::default();
        let mut marked = reference.cells.get(&key).unwrap().clone();
        // Aggregates are rebuilt from the run lines on parse, so the
        // marker goes on a run.
        marked.runs[0].distances += 1_000_000;
        partial.cells.insert(key.clone(), marked);
        write_manifest(&mpath, experiment_fingerprint(&exp), &partial).unwrap();

        let resumed = run_experiment(&exp, false).unwrap();
        assert_eq!(resumed.cells.len(), reference.cells.len());
        let adopted = resumed.cells.get(&key).unwrap();
        assert_eq!(
            adopted.distances,
            reference.cells.get(&key).unwrap().distances + 1_000_000,
            "the recorded cell must be adopted, not recomputed"
        );
        for (k, cell) in &reference.cells {
            if *k == key {
                continue;
            }
            let r = resumed.cells.get(k).unwrap();
            assert_eq!(r.distances, cell.distances, "{k:?}");
            for (a, b) in r.runs.iter().zip(&cell.runs) {
                assert_eq!(a.sse.to_bits(), b.sse.to_bits(), "{k:?}");
            }
        }
        // A completed sweep removes its manifest: the next invocation
        // starts fresh instead of serving stale cells.
        assert!(!mpath.exists(), "manifest must be cleaned up when done");

        // A stale manifest (different experiment) is ignored entirely.
        let mut other = tiny_experiment();
        other.restarts = 1;
        other.manifest_path = Some(mpath.clone());
        write_manifest(&mpath, experiment_fingerprint(&exp), &partial).unwrap();
        let fresh = run_experiment(&other, false).unwrap();
        let cell = fresh.cells.get(&key).unwrap();
        assert_eq!(cell.runs.len(), 1, "stale manifest must not inject cells");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn keep_logs_retains_series() {
        let mut exp = tiny_experiment();
        exp.algorithms = vec![Algorithm::Standard];
        exp.restarts = 1;
        let res = run_experiment(&exp, true).unwrap();
        let cell = res.cell("blobs:200:3:4", Algorithm::Standard).unwrap();
        let log = cell.runs[0].log.as_ref().unwrap();
        assert_eq!(log.len(), cell.runs[0].iterations);
    }
}
