//! Deterministic pseudo-random numbers (xoshiro256++ seeded by splitmix64).
//!
//! The offline vendored crate set has no `rand`, so the library carries its
//! own small PRNG. Every experiment in the repo derives its stream from a
//! `(name, seed)` pair so that datasets, initializations, and property tests
//! are bit-reproducible across runs and machines.

/// xoshiro256++ generator (Blackman & Vigna). Period 2^256 - 1.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    gauss_spare: Option<f64>,
}

/// The seeding mixer, also used directly for counter-based draws (the
/// `k-means||` selection step hashes `(seed, round, point)` through it so
/// per-point Bernoulli decisions are independent of scan order).
#[inline]
pub(crate) fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a 64-bit value.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive a deterministic stream for a named sub-purpose
    /// (FNV-1a over the label, mixed into the seed).
    pub fn derive(seed: u64, label: &str) -> Self {
        Rng::new(seed ^ crate::data::io::fnv1a(label.as_bytes()))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Lemire's multiply-shift with rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (polar form, pair-cached).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Exponential with rate 1.
    #[inline]
    pub fn exp(&mut self) -> f64 {
        -(1.0 - self.f64()).ln()
    }

    /// Log-normal with the given mu/sigma of the underlying normal.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.gaussian()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index proportionally to the (non-negative) weights.
    /// Returns `None` when all weights are zero (or the slice is empty).
    pub fn choose_weighted(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) {
            return None;
        }
        let mut target = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target < 0.0 {
                return Some(i);
            }
        }
        // Floating-point slack: fall back to the last positive weight.
        weights.iter().rposition(|&w| w > 0.0)
    }
}

/// Precomputed Zipf sampler over `{0, .., n-1}` with exponent `s`
/// (inverse-CDF over the cumulative weights; O(log n) per draw).
///
/// Used by the Traffic dataset analog: accident locations follow a heavily
/// skewed frequency distribution over a finite set of intersections.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 1..=n {
            acc += 1.0 / (i as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn derive_differs_by_label() {
        let mut a = Rng::derive(1, "datasets/aloi");
        let mut b = Rng::derive(1, "datasets/mnist");
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let i = rng.below(10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.gaussian();
            sum += g;
            sumsq += g * g;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn choose_weighted_respects_weights() {
        let mut rng = Rng::new(5);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.choose_weighted(&w).unwrap()] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn choose_weighted_all_zero() {
        let mut rng = Rng::new(5);
        assert_eq!(rng.choose_weighted(&[0.0, 0.0]), None);
        assert_eq!(rng.choose_weighted(&[]), None);
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Rng::new(13);
        let z = Zipf::new(1000, 1.1);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[100] && counts[0] > 1000);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
