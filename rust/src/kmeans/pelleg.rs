//! Pelleg & Moore's "blacklisting" k-means [14] — the first k-d-tree
//! acceleration (paper §1): candidates are pruned per node using the
//! *minimum/maximum distances to the node's bounding box* rather than the
//! hyperplane dominance test Kanungo et al. later introduced.
//!
//! Pruning rule (sound, box-based): let `h* = min_z max_dist(z, box)` over
//! the candidate set. Any candidate `z` with `min_dist(z, box) > h*`
//! cannot be nearest for any point of the box and is blacklisted for the
//! subtree. A single survivor owns the node and is assigned via the
//! aggregates. Each candidate's min/max box distance costs one
//! d-dimensional pass, counted as one distance computation each.

use std::sync::Arc;
use std::time::Duration;

use crate::data::Matrix;
use crate::kmeans::bounds::CentroidAccum;
use crate::kmeans::driver::{Fit, KMeansDriver};
use crate::kmeans::{Algorithm, KMeansParams, Workspace};
use crate::metrics::{DistCounter, RunResult};
use crate::tree::kdtree::KdNode;
use crate::tree::KdTree;

/// Squared min and max distance from `z` to the box `[lo, hi]`.
fn box_dist_sq(z: &[f64], lo: &[f64], hi: &[f64]) -> (f64, f64) {
    let mut dmin = 0.0;
    let mut dmax = 0.0;
    for j in 0..z.len() {
        let below = lo[j] - z[j];
        let above = z[j] - hi[j];
        let out = below.max(above).max(0.0);
        dmin += out * out;
        // farthest corner coordinate-wise
        let far = (z[j] - lo[j]).abs().max((hi[j] - z[j]).abs());
        dmax += far * far;
    }
    (dmin, dmax)
}

/// The blacklisting driver: the k-d tree plus the labels.
pub(crate) struct PellegDriver<'a> {
    data: &'a Matrix,
    tree: Arc<KdTree>,
    labels: Vec<u32>,
}

impl<'a> PellegDriver<'a> {
    pub(crate) fn new(data: &'a Matrix, tree: Arc<KdTree>) -> PellegDriver<'a> {
        PellegDriver { data, tree, labels: vec![u32::MAX; data.rows()] }
    }

    fn pass(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let mut changed = 0usize;
        let all: Vec<u32> = (0..centers.rows() as u32).collect();
        descend(
            self.data,
            &self.tree.root,
            centers,
            &all,
            &mut self.labels,
            acc,
            dist,
            &mut changed,
        );
        changed
    }
}

impl KMeansDriver for PellegDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::PellegMoore
    }

    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(centers, acc, dist)
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(centers, acc, dist)
    }

    fn labels(&self) -> &[u32] {
        &self.labels
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.labels
    }
}

/// Legacy shim: drive blacklisting through the shared loop, reusing (or
/// building) the workspace's k-d tree.
pub fn run(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> RunResult {
    let (tree, fresh) = ws.kd_tree_arc(data, params.kd);
    let build_time = if fresh { tree.build_time } else { Duration::ZERO };
    Fit::from_driver(
        data,
        Box::new(PellegDriver::new(data, tree)),
        init,
        params.max_iter,
        params.tol,
    )
    .with_build_cost(0, build_time)
    .run()
}

#[allow(clippy::too_many_arguments)]
fn descend(
    data: &Matrix,
    node: &KdNode,
    centers: &Matrix,
    candidates: &[u32],
    labels: &mut [u32],
    acc: &mut CentroidAccum,
    dist: &mut DistCounter,
    changed: &mut usize,
) {
    if node.is_leaf() {
        for &pi in &node.points {
            let p = data.row(pi as usize);
            let mut best = candidates[0];
            let mut best_d = f64::INFINITY;
            for &z in candidates {
                let dd = dist.d(p, centers.row(z as usize));
                if dd < best_d || (dd == best_d && z < best) {
                    best_d = dd;
                    best = z;
                }
            }
            if labels[pi as usize] != best {
                labels[pi as usize] = best;
                *changed += 1;
            }
            acc.add_point(best as usize, p);
        }
        return;
    }

    // Blacklist: min/max box distances per candidate (one counted pass
    // each, analogous to a distance computation over d dims).
    let mut h_star = f64::INFINITY;
    let mut mins: Vec<f64> = Vec::with_capacity(candidates.len());
    for &z in candidates {
        dist.add_bulk(1);
        let (dmin, dmax) = box_dist_sq(
            centers.row(z as usize),
            &node.bbox_min,
            &node.bbox_max,
        );
        mins.push(dmin);
        if dmax < h_star {
            h_star = dmax;
        }
    }
    let remaining: Vec<u32> = candidates
        .iter()
        .zip(&mins)
        .filter(|&(_, &dmin)| dmin <= h_star)
        .map(|(&z, _)| z)
        .collect();

    if remaining.len() == 1 {
        let z = remaining[0] as usize;
        acc.add_aggregate(z, &node.sum, node.weight as f64);
        node.for_each_point(&mut |pi| {
            if labels[pi as usize] != z as u32 {
                labels[pi as usize] = z as u32;
                *changed += 1;
            }
        });
        return;
    }

    descend(data, node.left.as_ref().unwrap(), centers, &remaining, labels, acc, dist, changed);
    descend(data, node.right.as_ref().unwrap(), centers, &remaining, labels, acc, dist, changed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn box_dist_inside_and_outside() {
        let lo = [0.0, 0.0];
        let hi = [2.0, 2.0];
        let (dmin, dmax) = box_dist_sq(&[1.0, 1.0], &lo, &hi);
        assert_eq!(dmin, 0.0); // inside
        assert_eq!(dmax, 2.0); // to a corner
        let (dmin, _) = box_dist_sq(&[4.0, 1.0], &lo, &hi);
        assert_eq!(dmin, 4.0);
    }

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(500, 3, 5, 1.0, 34);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 5, 27, &mut dc);
        let params = KMeansParams::default();
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_p = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_p.labels, r_l.labels);
        assert_eq!(r_p.iterations, r_l.iterations);
    }

    #[test]
    fn matches_lloyd_geo() {
        let data = synth::istanbul(0.0015, 35);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 20, 28, &mut dc);
        let params = KMeansParams {
            kd: crate::tree::KdTreeParams { leaf_size: 20, max_depth: 64 },
            ..KMeansParams::default()
        };
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_p = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_p.labels, r_l.labels);
        assert!(r_p.distances < r_l.distances);
    }

    #[test]
    fn kanungo_prunes_no_worse_than_pelleg() {
        // The hyperplane dominance test dominates the box min/max test on
        // most data (that is why Kanungo superseded it).
        let data = synth::istanbul(0.002, 36);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 30, 29, &mut dc);
        let params = KMeansParams {
            kd: crate::tree::KdTreeParams { leaf_size: 50, max_depth: 64 },
            ..KMeansParams::default()
        };
        let mut ws1 = Workspace::new();
        let mut ws2 = Workspace::new();
        let r_p = run(&data, &init_c, &params, &mut ws1);
        let r_k = crate::kmeans::kanungo::run(&data, &init_c, &params, &mut ws2);
        assert_eq!(r_p.labels, r_k.labels);
        assert!(
            (r_k.distances as f64) < 1.3 * r_p.distances as f64,
            "kanungo {} vs pelleg {}",
            r_k.distances,
            r_p.distances
        );
    }
}
