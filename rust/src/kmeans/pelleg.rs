//! Pelleg & Moore's "blacklisting" k-means [14] — the first k-d-tree
//! acceleration (paper §1): candidates are pruned per node using the
//! *minimum/maximum distances to the node's bounding box* rather than the
//! hyperplane dominance test Kanungo et al. later introduced.
//!
//! Pruning rule (sound, box-based): let `h* = min_z max_dist(z, box)` over
//! the candidate set. Any candidate `z` with `min_dist(z, box) > h*`
//! cannot be nearest for any point of the box and is blacklisted for the
//! subtree. A single survivor owns the node and is assigned via the
//! aggregates. Each candidate's min/max box distance costs one
//! d-dimensional pass, counted as one distance computation each.
//!
//! The traversal — task decomposition, leaf scans, whole-subtree
//! settlement, and the parallel execution with its determinism contract —
//! lives in [`crate::kmeans::kdfilter`]; this module contributes only the
//! blacklist prune rule.

use std::sync::Arc;
use std::time::Duration;

use crate::data::Matrix;
use crate::kmeans::bounds::CentroidAccum;
use crate::kmeans::driver::{DriverState, Fit, KMeansDriver};
use crate::kmeans::kdfilter::{self, PruneRule};
use crate::kmeans::{Algorithm, KMeansParams, Workspace};
use crate::metrics::{DistCounter, RunResult};
use crate::parallel::Parallelism;
use crate::tree::kdtree::KdNode;
use crate::tree::KdTree;

/// Squared min and max distance from `z` to the box `[lo, hi]`.
fn box_dist_sq(z: &[f64], lo: &[f64], hi: &[f64]) -> (f64, f64) {
    let mut dmin = 0.0;
    let mut dmax = 0.0;
    for j in 0..z.len() {
        let below = lo[j] - z[j];
        let above = z[j] - hi[j];
        let out = below.max(above).max(0.0);
        dmin += out * out;
        // farthest corner coordinate-wise
        let far = (z[j] - lo[j]).abs().max((hi[j] - z[j]).abs());
        dmax += far * far;
    }
    (dmin, dmax)
}

/// The box min/max blacklist prune: candidates whose minimum box distance
/// exceeds the best maximum cannot win anywhere in the cell.
pub(crate) struct BlacklistPrune;

impl PruneRule for BlacklistPrune {
    fn prune(
        &self,
        node: &KdNode,
        candidates: &[u32],
        centers: &Matrix,
        dist: &mut DistCounter,
        _scratch: &mut [f64],
    ) -> Vec<u32> {
        // Blacklist: min/max box distances per candidate (one counted pass
        // each, analogous to a distance computation over d dims).
        let mut h_star = f64::INFINITY;
        let mut mins: Vec<f64> = Vec::with_capacity(candidates.len());
        for &z in candidates {
            dist.add_bulk(1);
            let (dmin, dmax) = box_dist_sq(
                centers.row(z as usize),
                &node.bbox_min,
                &node.bbox_max,
            );
            mins.push(dmin);
            if dmax < h_star {
                h_star = dmax;
            }
        }
        candidates
            .iter()
            .zip(&mins)
            .filter(|&(_, &dmin)| dmin <= h_star)
            .map(|(&z, _)| z)
            .collect()
    }
}

/// The blacklisting driver: the k-d tree plus the labels.
pub(crate) struct PellegDriver<'a> {
    data: &'a Matrix,
    tree: Arc<KdTree>,
    labels: Vec<u32>,
    par: Parallelism,
}

impl<'a> PellegDriver<'a> {
    pub(crate) fn new(
        data: &'a Matrix,
        tree: Arc<KdTree>,
        par: Parallelism,
    ) -> PellegDriver<'a> {
        PellegDriver {
            data,
            tree,
            labels: vec![u32::MAX; data.rows()],
            par,
        }
    }

    fn pass(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        kdfilter::filter_pass(
            &BlacklistPrune,
            self.data,
            &self.tree,
            centers,
            &mut self.labels,
            acc,
            dist,
            &self.par,
        )
    }
}

impl KMeansDriver for PellegDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::PellegMoore
    }

    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(centers, acc, dist)
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(centers, acc, dist)
    }

    fn labels(&self) -> &[u32] {
        &self.labels
    }

    fn save_state(&self) -> Option<DriverState> {
        Some(DriverState::new(self.labels.clone()))
    }

    fn load_state(&mut self, state: &DriverState) -> anyhow::Result<()> {
        self.labels = state.labels_checked(self.data.rows())?.to_vec();
        Ok(())
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.labels
    }
}

/// Legacy shim: drive blacklisting through the shared loop, reusing (or
/// building) the workspace's k-d tree.
pub fn run(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> RunResult {
    let (tree, fresh) = ws.kd_tree_arc(data, params.kd);
    let build_time = if fresh { tree.build_time } else { Duration::ZERO };
    let par = ws.parallelism_opts(params.threads, params.pin_workers);
    Fit::from_driver(
        data,
        Box::new(PellegDriver::new(data, tree, par)),
        init,
        params.max_iter,
        params.tol,
    )
    .with_build_cost(0, build_time)
    .run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn box_dist_inside_and_outside() {
        let lo = [0.0, 0.0];
        let hi = [2.0, 2.0];
        let (dmin, dmax) = box_dist_sq(&[1.0, 1.0], &lo, &hi);
        assert_eq!(dmin, 0.0); // inside
        assert_eq!(dmax, 2.0); // to a corner
        let (dmin, _) = box_dist_sq(&[4.0, 1.0], &lo, &hi);
        assert_eq!(dmin, 4.0);
    }

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(500, 3, 5, 1.0, 34);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 5, 27, &mut dc);
        let params = KMeansParams::default();
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_p = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_p.labels, r_l.labels);
        assert_eq!(r_p.iterations, r_l.iterations);
    }

    #[test]
    fn matches_lloyd_geo() {
        let data = synth::istanbul(0.0015, 35);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 20, 28, &mut dc);
        let params = KMeansParams {
            kd: crate::tree::KdTreeParams { leaf_size: 20, max_depth: 64 },
            ..KMeansParams::default()
        };
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_p = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_p.labels, r_l.labels);
        assert!(r_p.distances < r_l.distances);
    }

    #[test]
    fn kanungo_prunes_no_worse_than_pelleg() {
        // The hyperplane dominance test dominates the box min/max test on
        // most data (that is why Kanungo superseded it).
        let data = synth::istanbul(0.002, 36);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 30, 29, &mut dc);
        let params = KMeansParams {
            kd: crate::tree::KdTreeParams { leaf_size: 50, max_depth: 64 },
            ..KMeansParams::default()
        };
        let mut ws1 = Workspace::new();
        let mut ws2 = Workspace::new();
        let r_p = run(&data, &init_c, &params, &mut ws1);
        let r_k = crate::kmeans::kanungo::run(&data, &init_c, &params, &mut ws2);
        assert_eq!(r_p.labels, r_k.labels);
        assert!(
            (r_k.distances as f64) < 1.3 * r_p.distances as f64,
            "kanungo {} vs pelleg {}",
            r_k.distances,
            r_p.distances
        );
    }
}
