//! The fluent front door: [`KMeans`] configures a run, validates it, and
//! hands back either a finished [`RunResult`] (`fit`) or a stepwise
//! [`Fit`] handle (`fit_step`).
//!
//! ```
//! use covermeans::data::synth;
//! use covermeans::kmeans::{Algorithm, KMeans};
//!
//! let data = synth::istanbul(0.002, 42);
//! let result = KMeans::new(20)
//!     .algorithm(Algorithm::Hybrid)
//!     .tol(1e-6)
//!     .max_iter(200)
//!     .seed(7)
//!     .fit(&data)
//!     .unwrap();
//! assert!(result.converged);
//! ```
//!
//! Per-algorithm knobs are typed: [`AlgorithmSpec`] carries exactly the
//! parameters its variant consumes (cover tree construction for
//! Cover-means, `switch_at` for Hybrid, batch/tol/seed for MiniBatch),
//! replacing the flat [`KMeansParams`] bag and the bolted-on
//! `MiniBatchParams` side channel.
//!
//! A single fit can use the whole machine: `KMeans::new(k).threads(n)`
//! shards the assignment phase (and cover tree construction) over `n`
//! workers with exactness-preserving reductions — any thread count
//! reproduces the sequential fit byte for byte, so the counted distance
//! metrics of the paper's evaluation are unaffected.

use std::fmt;

use crate::data::{DataSource, Matrix, SourceView};
use crate::kmeans::checkpoint::{self, CheckpointConfig};
use crate::kmeans::driver::{Fit, Observer, Signal, StepView};
use crate::kmeans::minibatch::MiniBatchParams;
use crate::kmeans::model::KMeansModel;
use crate::kmeans::{driver, init, minibatch, Algorithm, KMeansParams, Workspace};
use crate::metrics::{DistCounter, RunResult};
use crate::tree::{CoverTreeParams, KdTreeParams};

/// An algorithm plus the knobs *that algorithm* actually consumes.
///
/// `Algorithm` (the bare enum) converts into the spec with paper-default
/// knobs, so `.algorithm(Algorithm::Hybrid)` and
/// `.algorithm(AlgorithmSpec::Hybrid { cover, switch_at })` both work.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AlgorithmSpec {
    Standard,
    Elkan,
    Hamerly,
    Exponion,
    Shallot,
    Phillips,
    Kanungo { kd: KdTreeParams },
    PellegMoore { kd: KdTreeParams },
    CoverMeans { cover: CoverTreeParams },
    Hybrid { cover: CoverTreeParams, switch_at: usize },
    DualTree { cover: CoverTreeParams },
    MiniBatch { batch: usize, tol: f64, seed: u64 },
}

impl AlgorithmSpec {
    /// The algorithm this spec configures.
    pub fn kind(&self) -> Algorithm {
        match self {
            AlgorithmSpec::Standard => Algorithm::Standard,
            AlgorithmSpec::Elkan => Algorithm::Elkan,
            AlgorithmSpec::Hamerly => Algorithm::Hamerly,
            AlgorithmSpec::Exponion => Algorithm::Exponion,
            AlgorithmSpec::Shallot => Algorithm::Shallot,
            AlgorithmSpec::Phillips => Algorithm::Phillips,
            AlgorithmSpec::Kanungo { .. } => Algorithm::Kanungo,
            AlgorithmSpec::PellegMoore { .. } => Algorithm::PellegMoore,
            AlgorithmSpec::CoverMeans { .. } => Algorithm::CoverMeans,
            AlgorithmSpec::Hybrid { .. } => Algorithm::Hybrid,
            AlgorithmSpec::DualTree { .. } => Algorithm::DualTree,
            AlgorithmSpec::MiniBatch { .. } => Algorithm::MiniBatch,
        }
    }

    /// Typed spec for `algorithm` with the knobs lifted out of a flat
    /// parameter struct (migration path for config files / the CLI).
    pub fn from_params(algorithm: Algorithm, p: &KMeansParams) -> AlgorithmSpec {
        match algorithm {
            Algorithm::Standard => AlgorithmSpec::Standard,
            Algorithm::Elkan => AlgorithmSpec::Elkan,
            Algorithm::Hamerly => AlgorithmSpec::Hamerly,
            Algorithm::Exponion => AlgorithmSpec::Exponion,
            Algorithm::Shallot => AlgorithmSpec::Shallot,
            Algorithm::Phillips => AlgorithmSpec::Phillips,
            Algorithm::Kanungo => AlgorithmSpec::Kanungo { kd: p.kd },
            Algorithm::PellegMoore => AlgorithmSpec::PellegMoore { kd: p.kd },
            Algorithm::CoverMeans => AlgorithmSpec::CoverMeans { cover: p.cover },
            Algorithm::Hybrid => {
                AlgorithmSpec::Hybrid { cover: p.cover, switch_at: p.switch_at }
            }
            Algorithm::DualTree => AlgorithmSpec::DualTree { cover: p.cover },
            Algorithm::MiniBatch => AlgorithmSpec::MiniBatch {
                batch: p.minibatch.batch,
                tol: p.minibatch.tol,
                seed: p.minibatch.seed,
            },
        }
    }

    /// Fold the typed knobs into the flat legacy parameter struct.
    pub(crate) fn apply(&self, p: &mut KMeansParams) {
        p.algorithm = self.kind();
        match *self {
            AlgorithmSpec::Kanungo { kd } | AlgorithmSpec::PellegMoore { kd } => p.kd = kd,
            AlgorithmSpec::CoverMeans { cover } | AlgorithmSpec::DualTree { cover } => {
                p.cover = cover
            }
            AlgorithmSpec::Hybrid { cover, switch_at } => {
                p.cover = cover;
                p.switch_at = switch_at;
            }
            AlgorithmSpec::MiniBatch { batch, tol, seed } => {
                p.minibatch = MiniBatchParams { batch, tol, seed };
            }
            _ => {}
        }
    }
}

impl From<Algorithm> for AlgorithmSpec {
    fn from(a: Algorithm) -> AlgorithmSpec {
        AlgorithmSpec::from_params(a, &KMeansParams::default())
    }
}

/// Seeding strategy for the initial centers (config key `init`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum InitKind {
    /// Resolve by data source: k-means++ for resident (in-RAM) data,
    /// k-means|| for file-backed (mmap/chunked) sources, where the
    /// handful of sequential full passes of `||` beat the k dependent
    /// passes of `++`.
    #[default]
    Auto,
    /// Classic k-means++ (triangle-pruned; [`init::kmeans_plus_plus`]).
    PlusPlus,
    /// k-means|| oversampling + weighted recluster
    /// ([`init::init_kmeanspar`]); rounds and oversampling factor come
    /// from [`KMeans::init_rounds`] / [`KMeans::init_oversample`].
    Parallel,
}

impl InitKind {
    pub fn name(&self) -> &'static str {
        match self {
            InitKind::Auto => "auto",
            InitKind::PlusPlus => "kmeans++",
            InitKind::Parallel => "kmeans||",
        }
    }

    pub fn parse(s: &str) -> Option<InitKind> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(InitKind::Auto),
            "kmeans++" | "plusplus" | "++" => Some(InitKind::PlusPlus),
            "kmeans||" | "parallel" | "||" => Some(InitKind::Parallel),
            _ => None,
        }
    }
}

/// Validation failures of a [`KMeans`] configuration, surfaced as values
/// instead of the panics of the legacy `kmeans::run` asserts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KMeansError {
    /// `k == 0`: no centers to fit.
    ZeroK,
    /// More centers than points.
    KExceedsN { k: usize, n: usize },
    /// Warm-start centers whose dimensionality differs from the data.
    DimMismatch { expected: usize, got: usize },
    /// Warm-start center count differs from the configured `k`.
    WarmStartK { expected: usize, got: usize },
    /// `fit_step` on an algorithm without exact stepwise semantics
    /// (MiniBatch moves centers online inside its batch loop).
    NotStepwise(Algorithm),
    /// A checkpoint write failed mid-fit; the run stopped at that
    /// iteration boundary instead of continuing uncheckpointed.
    Checkpoint(String),
    /// A non-resident (mmap/chunked) data source routed to an algorithm
    /// that needs the whole matrix resident (the tree variants build a
    /// spatial index over every point up front).
    StreamedUnsupported { algorithm: Algorithm, backend: &'static str },
}

impl fmt::Display for KMeansError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KMeansError::ZeroK => write!(f, "k must be at least 1"),
            KMeansError::KExceedsN { k, n } => {
                write!(f, "more centers than points (k={k}, n={n})")
            }
            KMeansError::DimMismatch { expected, got } => {
                write!(f, "center/data dimension mismatch (data d={expected}, centers d={got})")
            }
            KMeansError::WarmStartK { expected, got } => {
                write!(f, "warm-start centers disagree with k (k={expected}, centers={got})")
            }
            KMeansError::NotStepwise(a) => {
                write!(f, "{} has no exact stepwise iteration", a.name())
            }
            KMeansError::Checkpoint(e) => {
                write!(f, "checkpoint write failed: {e}")
            }
            KMeansError::StreamedUnsupported { algorithm, backend } => write!(
                f,
                "{} cannot fit a streamed data source (backend: {backend}); \
                 load the data resident (data_backend=ram) or pick a \
                 streaming-capable algorithm (standard, elkan, hamerly, minibatch)",
                algorithm.name()
            ),
        }
    }
}

impl std::error::Error for KMeansError {}

/// Fluent k-means configuration. See the [module docs](self) for the
/// canonical chain; every setter returns `self`.
pub struct KMeans {
    k: usize,
    spec: AlgorithmSpec,
    max_iter: usize,
    tol: f64,
    seed: u64,
    init: InitKind,
    init_rounds: usize,
    init_oversample: f64,
    threads: usize,
    pin_workers: bool,
    warm: Option<Matrix>,
    observer: Option<Observer>,
    checkpoint: Option<CheckpointConfig>,
}

impl KMeans {
    /// Start configuring a fit with `k` clusters. Defaults: Standard
    /// algorithm, `max_iter` 200, exact convergence (`tol` 0), seed 0,
    /// single-threaded.
    pub fn new(k: usize) -> KMeans {
        let d = KMeansParams::default();
        KMeans {
            k,
            spec: AlgorithmSpec::Standard,
            max_iter: d.max_iter,
            tol: d.tol,
            seed: 0,
            init: InitKind::Auto,
            init_rounds: 5,
            init_oversample: 2.0,
            threads: d.threads,
            pin_workers: d.pin_workers,
            warm: None,
            observer: None,
            checkpoint: None,
        }
    }

    /// Select the algorithm — a bare [`Algorithm`] for paper defaults, or
    /// an [`AlgorithmSpec`] carrying tuned per-algorithm knobs.
    pub fn algorithm(mut self, spec: impl Into<AlgorithmSpec>) -> Self {
        self.spec = spec.into();
        self
    }

    /// Iteration cap (the paper runs to convergence; this is a guard).
    pub fn max_iter(mut self, max_iter: usize) -> Self {
        self.max_iter = max_iter;
        self
    }

    /// Convergence tolerance on the largest center movement. 0 (default)
    /// keeps the paper's exact assignment-fixpoint criterion.
    pub fn tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    /// Seed for the k-means++ initialization (ignored under warm start).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Seeding strategy (config key `init`; default [`InitKind::Auto`]:
    /// k-means++ for resident data, k-means|| for file-backed sources).
    /// Both strategies are source-generic and deterministic, so pinning
    /// one explicitly makes in-RAM and streamed fits byte-identical.
    /// Ignored under warm start.
    pub fn init(mut self, init: InitKind) -> Self {
        self.init = init;
        self
    }

    /// k-means|| sampling rounds (config key `init_rounds`; default 5).
    /// Consumed only when the resolved init is [`InitKind::Parallel`].
    pub fn init_rounds(mut self, rounds: usize) -> Self {
        self.init_rounds = rounds;
        self
    }

    /// k-means|| oversampling factor: each round samples points with
    /// expectation `oversample * k` (config key `init_oversample`;
    /// default 2.0). Consumed only under [`InitKind::Parallel`].
    pub fn init_oversample(mut self, oversample: f64) -> Self {
        self.init_oversample = oversample;
        self
    }

    /// Intra-fit worker threads (0 = all cores; default 1), served by one
    /// persistent worker pool per fit (shared across fits when the
    /// workspace is reused via [`KMeans::fit_with`]). Covers every phase:
    /// the assignment passes of all drivers — including the k-d-tree
    /// variants (Kanungo, Pelleg-Moore) and MiniBatch — plus cover tree
    /// construction and the k-means++ seeding.
    ///
    /// **Determinism guarantee:** the parallel reductions are
    /// exactness-preserving, so any thread count produces byte-identical
    /// results — same assignments, same iteration count, same counted
    /// `distances`, same centers — as the sequential fit
    /// (`rust/tests/parallel_exactness.rs`).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Pin each pool worker to its own core at spawn (config key
    /// `pin_workers`; Linux `sched_setaffinity(2)`, a no-op elsewhere).
    /// Placement only — results are byte-identical either way.
    pub fn pin_workers(mut self, pin: bool) -> Self {
        self.pin_workers = pin;
        self
    }

    /// Start from these centers instead of k-means++ — prior results,
    /// sweep reuse, or an explicit shared init for cross-algorithm
    /// comparisons. Must be `k x d`.
    pub fn warm_start(mut self, centers: Matrix) -> Self {
        self.warm = Some(centers);
        self
    }

    /// Crash-safe checkpointing: snapshot the fit to `cfg.path` per the
    /// `cfg` triggers (plus once at completion), through atomic writes
    /// that retain the previous generation. A failed write stops the fit
    /// with [`KMeansError::Checkpoint`] instead of running on
    /// uncheckpointed. Resume via [`crate::kmeans::KMeansCheckpoint`] and
    /// [`Fit::restore`]. Only the exact algorithms checkpoint; MiniBatch
    /// has no iteration boundary to snapshot.
    pub fn checkpoint(mut self, cfg: CheckpointConfig) -> Self {
        self.checkpoint = Some(cfg);
        self
    }

    /// Register a per-iteration observer (early stopping, telemetry).
    /// Only exact algorithms have iteration boundaries to observe;
    /// fitting MiniBatch with an observer returns
    /// [`KMeansError::NotStepwise`].
    pub fn observer<F>(mut self, f: F) -> Self
    where
        F: FnMut(&StepView<'_>) -> Signal + 'static,
    {
        self.observer = Some(Box::new(f));
        self
    }

    /// The flat parameter struct this configuration folds down to.
    pub fn params(&self) -> KMeansParams {
        let mut p = KMeansParams {
            max_iter: self.max_iter,
            tol: self.tol,
            threads: self.threads,
            pin_workers: self.pin_workers,
            ..KMeansParams::default()
        };
        self.spec.apply(&mut p);
        p
    }

    /// Validate against the data source and produce the initial centers
    /// (seeding shards over `par`; byte-identical at every thread count
    /// and on every source backend).
    fn make_init(
        &mut self,
        src: SourceView<'_>,
        par: &crate::parallel::Parallelism,
    ) -> Result<Matrix, KMeansError> {
        if self.k == 0 {
            return Err(KMeansError::ZeroK);
        }
        if self.k > src.rows() {
            return Err(KMeansError::KExceedsN { k: self.k, n: src.rows() });
        }
        if let Some(warm) = self.warm.take() {
            if warm.cols() != src.cols() {
                return Err(KMeansError::DimMismatch {
                    expected: src.cols(),
                    got: warm.cols(),
                });
            }
            if warm.rows() != self.k {
                return Err(KMeansError::WarmStartK {
                    expected: self.k,
                    got: warm.rows(),
                });
            }
            return Ok(warm);
        }
        let parallel = match self.init {
            InitKind::PlusPlus => false,
            InitKind::Parallel => true,
            // Auto: `++` makes k+1 passes over the data — fine resident,
            // painful from a file; `||` needs ~init_rounds passes.
            InitKind::Auto => src.as_matrix().is_none(),
        };
        // Init distances stay outside the run counters (paper protocol:
        // identical seeds are generated once, not charged per algorithm).
        let mut counter = DistCounter::new();
        Ok(if parallel {
            init::init_kmeanspar_src(
                src,
                self.k,
                self.seed,
                self.init_rounds,
                self.init_oversample,
                &mut counter,
                par,
            )
        } else {
            init::kmeans_plus_plus_src(src, self.k, self.seed, &mut counter, par)
        })
    }

    /// Fit to completion with a fresh workspace.
    pub fn fit(self, data: &Matrix) -> Result<RunResult, KMeansError> {
        let mut ws = Workspace::new();
        self.fit_with(data, &mut ws)
    }

    /// Fit to completion, reusing `ws`'s cached spatial indexes (the
    /// Table 4 amortization protocol).
    pub fn fit_with(self, data: &Matrix, ws: &mut Workspace) -> Result<RunResult, KMeansError> {
        self.fit_src_with(data.into(), ws)
    }

    /// Fit to completion over any [`DataSource`] backend with a fresh
    /// workspace — the out-of-core entry point. For every backend, chunk
    /// size, and thread count the result is byte-identical to the in-RAM
    /// fit of the same data (given the same resolved init; see
    /// [`KMeans::init`]). Streamed sources are accepted only by the
    /// streaming-capable algorithms ([`Algorithm::streams`]); the tree
    /// variants return [`KMeansError::StreamedUnsupported`].
    pub fn fit_source(self, source: &DataSource) -> Result<RunResult, KMeansError> {
        let mut ws = Workspace::new();
        self.fit_source_with(source, &mut ws)
    }

    /// [`KMeans::fit_source`] against a caller-owned workspace.
    pub fn fit_source_with(
        self,
        source: &DataSource,
        ws: &mut Workspace,
    ) -> Result<RunResult, KMeansError> {
        self.fit_src_with(source.view(), ws)
    }

    fn fit_src_with(
        mut self,
        src: SourceView<'_>,
        ws: &mut Workspace,
    ) -> Result<RunResult, KMeansError> {
        if let AlgorithmSpec::MiniBatch { .. } = self.spec {
            if self.observer.is_some() || self.checkpoint.is_some() {
                // Mini-batch moves centers online inside its batch loop;
                // there is no exact iteration boundary to observe or to
                // checkpoint. Error instead of silently never firing.
                return Err(KMeansError::NotStepwise(Algorithm::MiniBatch));
            }
            let params = self.params();
            let par = ws.parallelism_opts(params.threads, params.pin_workers);
            let init_c = self.make_init(src, &par)?;
            return Ok(minibatch::run_par_src(
                src,
                &init_c,
                &params,
                &params.minibatch,
                &par,
            ));
        }
        let mut fit = self.fit_step_src(src, ws)?;
        while fit.step().is_some() {}
        if let Some(e) = fit.take_checkpoint_error() {
            return Err(KMeansError::Checkpoint(format!("{e:#}")));
        }
        Ok(fit.finish())
    }

    /// Fit to completion and capture the result as a servable, persistable
    /// [`KMeansModel`] (centers, per-cluster counts/inertia, and the
    /// builder's algorithm/seed as provenance) — the train-once /
    /// serve-many entry point.
    ///
    /// ```
    /// use covermeans::data::synth;
    /// use covermeans::kmeans::{Algorithm, KMeans};
    ///
    /// let train = synth::gaussian_blobs(300, 3, 4, 0.5, 1);
    /// let fresh = synth::gaussian_blobs(50, 3, 4, 0.5, 2);
    /// let model = KMeans::new(4)
    ///     .algorithm(Algorithm::Elkan)
    ///     .seed(9)
    ///     .fit_model(&train)
    ///     .unwrap();
    /// let labels = model.predict(&fresh); // out-of-sample assignment
    /// assert_eq!(labels.len(), 50);
    /// ```
    pub fn fit_model(self, data: &Matrix) -> Result<KMeansModel, KMeansError> {
        let mut ws = Workspace::new();
        self.fit_model_with(data, &mut ws)
    }

    /// [`KMeans::fit_model`] against a caller-owned workspace (tree and
    /// worker-pool reuse across fits).
    pub fn fit_model_with(
        self,
        data: &Matrix,
        ws: &mut Workspace,
    ) -> Result<KMeansModel, KMeansError> {
        let algorithm = self.spec.kind();
        let seed = self.seed;
        let run = self.fit_with(data, ws)?;
        Ok(KMeansModel::from_run(data, &run, algorithm, seed))
    }

    /// [`KMeans::fit_model`] over any [`DataSource`] backend. The model
    /// statistics are computed in one sequential canonical-order pass, so
    /// the persisted `.kmm` bytes are identical across backends.
    pub fn fit_model_src(self, source: &DataSource) -> Result<KMeansModel, KMeansError> {
        let mut ws = Workspace::new();
        self.fit_model_src_with(source, &mut ws)
    }

    /// [`KMeans::fit_model_src`] against a caller-owned workspace.
    pub fn fit_model_src_with(
        self,
        source: &DataSource,
        ws: &mut Workspace,
    ) -> Result<KMeansModel, KMeansError> {
        let algorithm = self.spec.kind();
        let seed = self.seed;
        let src = source.view();
        let run = self.fit_src_with(src, ws)?;
        Ok(KMeansModel::from_run_src(src, &run, algorithm, seed))
    }

    /// Begin a stepwise fit with a fresh workspace: returns a [`Fit`]
    /// whose `step()` exposes every iteration boundary.
    pub fn fit_step(self, data: &Matrix) -> Result<Fit<'_>, KMeansError> {
        let mut ws = Workspace::new();
        self.fit_step_with(data, &mut ws)
    }

    /// Begin a stepwise fit against a caller-owned workspace. The returned
    /// handle borrows only `data`; the spatial index is shared out of the
    /// workspace cache, so `ws` is free for the next run immediately.
    pub fn fit_step_with<'a>(
        self,
        data: &'a Matrix,
        ws: &mut Workspace,
    ) -> Result<Fit<'a>, KMeansError> {
        self.fit_step_src(data.into(), ws)
    }

    /// Begin a stepwise fit over any source backend (the checkpointed
    /// out-of-core path: [`Fit::checkpoint_now`] and [`Fit::restore`]
    /// work unchanged, and the config fingerprint samples the source so a
    /// resume can cross backends). Streamed (non-RAM) sources are
    /// accepted only by streaming-capable algorithms; the tree variants
    /// return [`KMeansError::StreamedUnsupported`] before any driver
    /// state is built.
    pub fn fit_step_src<'a>(
        mut self,
        src: SourceView<'a>,
        ws: &mut Workspace,
    ) -> Result<Fit<'a>, KMeansError> {
        if let AlgorithmSpec::MiniBatch { .. } = self.spec {
            return Err(KMeansError::NotStepwise(Algorithm::MiniBatch));
        }
        let algorithm = self.spec.kind();
        if src.as_matrix().is_none() && !algorithm.streams() {
            return Err(KMeansError::StreamedUnsupported {
                algorithm,
                backend: src.backend().name(),
            });
        }
        let params = self.params();
        let par = ws.parallelism_opts(params.threads, params.pin_workers);
        let init_c = self.make_init(src, &par)?;
        let (drv, build_dist, build_time) =
            driver::new_driver_src(src, init_c.rows(), &params, ws);
        let mut fit = Fit::from_driver_src(src, drv, &init_c, params.max_iter, params.tol)
            .with_build_cost(build_dist, build_time)
            .with_observer(self.observer.take());
        if let Some(cfg) = self.checkpoint.take() {
            let fp = checkpoint::config_fingerprint_src(&params, src, init_c.rows());
            fit = fit.with_checkpoints(cfg, fp, self.seed);
        }
        Ok(fit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn builder_validates_before_running() {
        let data = synth::gaussian_blobs(50, 2, 2, 0.5, 1);
        assert_eq!(KMeans::new(0).fit(&data).unwrap_err(), KMeansError::ZeroK);
        assert_eq!(
            KMeans::new(51).fit(&data).unwrap_err(),
            KMeansError::KExceedsN { k: 51, n: 50 }
        );
        let bad_dim = Matrix::zeros(3, 5);
        assert_eq!(
            KMeans::new(3).warm_start(bad_dim).fit(&data).unwrap_err(),
            KMeansError::DimMismatch { expected: 2, got: 5 }
        );
        let bad_k = Matrix::zeros(4, 2);
        assert_eq!(
            KMeans::new(3).warm_start(bad_k).fit(&data).unwrap_err(),
            KMeansError::WarmStartK { expected: 3, got: 4 }
        );
        // Errors render human-readable messages.
        assert!(KMeansError::ZeroK.to_string().contains("k"));
    }

    #[test]
    fn fit_model_propagates_validation_errors() {
        let data = synth::gaussian_blobs(40, 2, 2, 0.5, 9);
        assert_eq!(
            KMeans::new(0).fit_model(&data).unwrap_err(),
            KMeansError::ZeroK
        );
        let m = KMeans::new(3).seed(5).fit_model(&data).unwrap();
        assert_eq!(m.k(), 3);
        assert_eq!(m.seed(), 5);
        assert_eq!(m.algorithm(), Algorithm::Standard);
        assert_eq!(m.counts().iter().sum::<u64>(), 40);
    }

    #[test]
    fn spec_round_trips_algorithm_kind() {
        for a in Algorithm::EXTENDED {
            assert_eq!(AlgorithmSpec::from(a).kind(), a, "{}", a.name());
        }
    }

    #[test]
    fn builder_matches_legacy_run() {
        let data = synth::istanbul(0.001, 5);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 12, 3, &mut dc);
        for alg in [Algorithm::Standard, Algorithm::Elkan, Algorithm::CoverMeans] {
            let params = KMeansParams { algorithm: alg, ..KMeansParams::default() };
            let legacy =
                crate::kmeans::run(&data, &init_c, &params, &mut Workspace::new());
            let new = KMeans::new(12)
                .algorithm(alg)
                .warm_start(init_c.clone())
                .fit(&data)
                .unwrap();
            assert_eq!(new.labels, legacy.labels, "{}", alg.name());
            assert_eq!(new.iterations, legacy.iterations, "{}", alg.name());
            assert_eq!(new.distances, legacy.distances, "{}", alg.name());
        }
    }

    #[test]
    fn minibatch_routes_tuned_config() {
        let data = synth::gaussian_blobs(400, 3, 4, 0.4, 6);
        // A 1-point batch with a huge tol converges almost immediately;
        // the default (1024-point batch) runs far more distance evals. If
        // the tuned config were dropped (the old side-channel bug), both
        // runs would count the same.
        let tiny = KMeans::new(4)
            .algorithm(AlgorithmSpec::MiniBatch { batch: 1, tol: 1e-4, seed: 1 })
            .max_iter(20)
            .seed(2)
            .fit(&data)
            .unwrap();
        let default = KMeans::new(4)
            .algorithm(Algorithm::MiniBatch)
            .max_iter(20)
            .seed(2)
            .fit(&data)
            .unwrap();
        assert!(
            tiny.distances < default.distances,
            "tuned batch size ignored: {} vs {}",
            tiny.distances,
            default.distances
        );
    }

    #[test]
    fn checkpointed_fit_writes_final_snapshot() {
        let data = synth::gaussian_blobs(200, 2, 3, 0.5, 8);
        let dir = std::env::temp_dir().join(format!(
            "covermeans_builder_ckpt_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("final.kmc");
        let r = KMeans::new(3)
            .algorithm(Algorithm::Hamerly)
            .seed(4)
            .checkpoint(CheckpointConfig::new(path.clone()))
            .fit(&data)
            .unwrap();
        assert!(r.converged);
        let snap = crate::kmeans::KMeansCheckpoint::load(&path).unwrap();
        assert_eq!(snap.iter as usize, r.iterations);
        assert!(snap.converged);
        assert_eq!(snap.seed, 4);
        assert_eq!(snap.algorithm, Algorithm::Hamerly);
        assert_eq!(snap.distances, r.distances);
        // MiniBatch cannot checkpoint: no exact iteration boundary.
        let err = KMeans::new(3)
            .algorithm(Algorithm::MiniBatch)
            .checkpoint(CheckpointConfig::new(dir.join("mb.kmc")))
            .fit(&data)
            .unwrap_err();
        assert_eq!(err, KMeansError::NotStepwise(Algorithm::MiniBatch));
        // A doomed path surfaces as KMeansError::Checkpoint.
        let err = KMeans::new(3)
            .checkpoint(CheckpointConfig::new(
                dir.join("no_such_subdir").join("x.kmc"),
            ))
            .fit(&data)
            .unwrap_err();
        assert!(matches!(err, KMeansError::Checkpoint(_)), "{err}");
    }

    #[test]
    fn init_kind_parses_and_roundtrips() {
        assert_eq!(InitKind::parse("auto"), Some(InitKind::Auto));
        assert_eq!(InitKind::parse("KMEANS++"), Some(InitKind::PlusPlus));
        assert_eq!(InitKind::parse("plusplus"), Some(InitKind::PlusPlus));
        assert_eq!(InitKind::parse("kmeans||"), Some(InitKind::Parallel));
        assert_eq!(InitKind::parse("parallel"), Some(InitKind::Parallel));
        assert!(InitKind::parse("bogus").is_none());
        for k in [InitKind::Auto, InitKind::PlusPlus, InitKind::Parallel] {
            assert_eq!(InitKind::parse(k.name()), Some(k), "{}", k.name());
        }
        assert_eq!(InitKind::default(), InitKind::Auto);
    }

    #[test]
    fn streamed_source_rejects_tree_algorithms_and_streams_the_rest() {
        let data = synth::gaussian_blobs(120, 3, 3, 0.5, 11);
        let dir = std::env::temp_dir()
            .join(format!("covermeans_builder_stream_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blobs.dmat");
        crate::data::write_dmat(&path, &data).unwrap();
        let src =
            DataSource::open(&path, crate::data::SourceBackend::Chunked, 16, 0).unwrap();

        // Tree variants must reject streamed input with a diagnosable
        // error, not panic inside the driver factory.
        for alg in [
            Algorithm::CoverMeans,
            Algorithm::Hybrid,
            Algorithm::Kanungo,
            Algorithm::PellegMoore,
            Algorithm::DualTree,
            Algorithm::Exponion,
        ] {
            let err = KMeans::new(3).algorithm(alg).fit_source(&src).unwrap_err();
            assert_eq!(
                err,
                KMeansError::StreamedUnsupported { algorithm: alg, backend: "chunked" },
                "{}",
                alg.name()
            );
            assert!(err.to_string().contains("streamed"), "{err}");
        }

        // Streaming-capable algorithms accept the same source and match
        // the in-RAM fit bit for bit (init pinned: Auto resolves to ++
        // resident and || streamed, so defaults would legitimately
        // differ).
        for alg in [Algorithm::Standard, Algorithm::Hamerly, Algorithm::MiniBatch] {
            assert!(alg.streams());
            let streamed = KMeans::new(3)
                .algorithm(alg)
                .init(InitKind::Parallel)
                .seed(5)
                .fit_source(&src)
                .unwrap();
            let resident = KMeans::new(3)
                .algorithm(alg)
                .init(InitKind::Parallel)
                .seed(5)
                .fit(&data)
                .unwrap();
            assert_eq!(streamed.labels, resident.labels, "{}", alg.name());
            assert_eq!(streamed.iterations, resident.iterations, "{}", alg.name());
            assert_eq!(streamed.distances, resident.distances, "{}", alg.name());
            for (a, b) in streamed
                .centers
                .as_slice()
                .iter()
                .zip(resident.centers.as_slice())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", alg.name());
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn auto_init_resolves_by_source_backend() {
        let data = synth::gaussian_blobs(150, 2, 3, 0.5, 12);
        let dir = std::env::temp_dir()
            .join(format!("covermeans_builder_auto_init_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blobs.dmat");
        crate::data::write_dmat(&path, &data).unwrap();
        let src =
            DataSource::open(&path, crate::data::SourceBackend::Chunked, 32, 0).unwrap();

        // Streamed + Auto must equal streamed + explicit k-means||...
        let auto = KMeans::new(4).seed(3).fit_source(&src).unwrap();
        let par = KMeans::new(4)
            .seed(3)
            .init(InitKind::Parallel)
            .fit_source(&src)
            .unwrap();
        assert_eq!(auto.labels, par.labels);
        assert_eq!(auto.distances, par.distances);

        // ...and resident + Auto must equal resident + explicit k-means++.
        let auto_r = KMeans::new(4).seed(3).fit(&data).unwrap();
        let pp = KMeans::new(4)
            .seed(3)
            .init(InitKind::PlusPlus)
            .fit(&data)
            .unwrap();
        assert_eq!(auto_r.labels, pp.labels);
        assert_eq!(auto_r.distances, pp.distances);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn minibatch_has_no_stepwise_fit() {
        let data = synth::gaussian_blobs(100, 2, 2, 0.5, 7);
        let err = KMeans::new(2)
            .algorithm(Algorithm::MiniBatch)
            .fit_step(&data)
            .unwrap_err();
        assert_eq!(err, KMeansError::NotStepwise(Algorithm::MiniBatch));
        // An observer on the mini-batch fit errors too, instead of being
        // silently dropped.
        let err = KMeans::new(2)
            .algorithm(Algorithm::MiniBatch)
            .observer(|_| crate::kmeans::Signal::Continue)
            .fit(&data)
            .unwrap_err();
        assert_eq!(err, KMeansError::NotStepwise(Algorithm::MiniBatch));
    }
}
