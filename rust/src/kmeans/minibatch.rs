//! Mini-batch k-means (Sculley [22]) — the *approximate* aggregation
//! family the paper positions itself against in §1: instead of exact
//! assignment over all points, each step samples a batch, assigns it, and
//! moves centers with a per-center learning rate `1 / count`. Included so
//! the evaluation can quantify the exactness/SSE trade-off the "exact"
//! algorithms avoid (the paper: "the expected values of the results are
//! very similar ... because the means used in k-means are statistical
//! summaries, too").
//!
//! Not exact: the convergence criterion is center movement below `tol`
//! rather than an assignment fixpoint.
//!
//! The runner follows Sculley's two-phase formulation: each step first
//! caches the nearest center of every batch sample against the centers
//! *as they stood at the start of the step*, then applies the online
//! per-sample updates. The cached-assignment phase is a pure map over the
//! batch, so it shards over the worker pool — disjoint per-sample result
//! slots, private integer distance tallies — and the update phase replays
//! in canonical batch order, making `threads = N` byte-identical to
//! `threads = 1` (the sampling stream is seed-driven and drawn up front,
//! so it never depends on scheduling).

use crate::data::{Matrix, SourceView};
use crate::kmeans::KMeansParams;
use crate::metrics::{DistCounter, IterationLog, RunResult, Stopwatch};
use crate::parallel::{Parallelism, SharedSlices};
use crate::rng::Rng;

/// Mini-batch specific knobs. Reaches the runner through
/// `KMeansParams::minibatch` (or the builder's
/// `AlgorithmSpec::MiniBatch`); `kmeans::run` honors caller-tuned values
/// instead of silently substituting the defaults.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MiniBatchParams {
    pub batch: usize,
    /// Stop when the max center movement in a step falls below this.
    pub tol: f64,
    pub seed: u64,
}

impl Default for MiniBatchParams {
    fn default() -> Self {
        MiniBatchParams { batch: 1024, tol: 1e-4, seed: 0xB47C4 }
    }
}

pub fn run(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    mb: &MiniBatchParams,
) -> RunResult {
    run_par(data, init, params, mb, &Parallelism::new(params.threads))
}

/// Pool-sharing variant of [`run`] (the builder and `kmeans::run` route
/// their workspace-cached pool here).
pub(crate) fn run_par(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    mb: &MiniBatchParams,
    par: &Parallelism,
) -> RunResult {
    run_par_src(data.into(), init, params, mb, par)
}

/// [`run_par`] over any data source backend. Each step gathers its batch
/// rows into a small resident matrix ([`SourceView::read_rows`] — exact
/// bits, random access without paging the whole file), so the per-sample
/// arithmetic and RNG stream match the in-RAM runner exactly; the final
/// full labeling streams through [`SourceView::visit`].
pub(crate) fn run_par_src(
    src: SourceView<'_>,
    init: &Matrix,
    params: &KMeansParams,
    mb: &MiniBatchParams,
    par: &Parallelism,
) -> RunResult {
    let n = src.rows();
    let cols = src.cols();
    let k = init.rows();
    let sw = Stopwatch::start();
    let mut dist = DistCounter::new();
    let mut rng = Rng::derive(mb.seed, "minibatch");

    let mut centers = init.clone();
    let mut counts = vec![0.0f64; k];
    let mut log = IterationLog::new();
    let mut converged = false;
    let mut iterations = 0;
    let batch = mb.batch.min(n);

    let mut batch_idx = vec![0usize; batch];
    let mut batch_best = vec![0u32; batch];
    for iter in 1..=params.max_iter {
        iterations = iter;
        // Draw the whole batch up front (consumes the RNG stream in the
        // same per-sample order at every thread count).
        for s in batch_idx.iter_mut() {
            *s = rng.below(n);
        }
        // Gather the batch rows resident (exact bits from any backend).
        let batch_m = src.read_rows(&batch_idx);
        // Assignment phase: nearest center per sample (k counted
        // distances each) against the start-of-step snapshot, sharded
        // over batch positions.
        {
            let snapshot = &centers;
            let batch_m = &batch_m;
            let best_sh = SharedSlices::new(&mut batch_best);
            let tallies = par.map_chunks(batch, |r| {
                let best = unsafe { best_sh.range(r.clone()) };
                let mut dc = DistCounter::new();
                for (j, s) in r.clone().enumerate() {
                    let p = batch_m.row(s);
                    let mut b = 0u32;
                    let mut best_d = f64::INFINITY;
                    for c in 0..k {
                        let dd = dc.d(p, snapshot.row(c));
                        if dd < best_d {
                            best_d = dd;
                            b = c as u32;
                        }
                    }
                    best[j] = b;
                }
                dc.count()
            });
            for t in tallies {
                dist.add_bulk(t);
            }
        }
        // Update phase: online moves with decaying rate (Sculley's
        // update), replayed sequentially in batch order.
        let mut max_move_sq = 0.0f64;
        for pos in 0..batch {
            let best = batch_best[pos] as usize;
            let p = batch_m.row(pos);
            counts[best] += 1.0;
            let eta = 1.0 / counts[best];
            let row = centers.row_mut(best);
            let mut move_sq = 0.0;
            for (cj, &pj) in row.iter_mut().zip(p) {
                let delta = eta * (pj - *cj);
                *cj += delta;
                move_sq += delta * delta;
            }
            max_move_sq = max_move_sq.max(move_sq);
        }
        log.push(iter, dist.count(), sw.elapsed(), batch);
        if max_move_sq.sqrt() < mb.tol {
            converged = true;
            break;
        }
    }

    // Final full assignment for reporting (counted: it is real work a user
    // needs to obtain labels), sharded over point chunks.
    let mut labels = vec![0u32; n];
    {
        let snapshot = &centers;
        let labels_sh = SharedSlices::new(&mut labels);
        let tallies = par.map_chunks(n, |r| {
            let l = unsafe { labels_sh.range(r.clone()) };
            let mut dc = DistCounter::new();
            src.visit(r.clone(), |start, block| {
                for (off, p) in block.chunks_exact(cols).enumerate() {
                    let j = start + off - r.start;
                    let mut best = 0u32;
                    let mut best_d = f64::INFINITY;
                    for c in 0..k {
                        let dd = dc.d(p, snapshot.row(c));
                        if dd < best_d {
                            best_d = dd;
                            best = c as u32;
                        }
                    }
                    l[j] = best;
                }
            });
            dc.count()
        });
        for t in tallies {
            dist.add_bulk(t);
        }
    }

    RunResult {
        labels,
        centers,
        iterations,
        distances: dist.count(),
        build_dist: 0,
        time: sw.elapsed(),
        build_time: std::time::Duration::ZERO,
        log,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn sse_close_to_lloyd_on_blobs() {
        let data = synth::gaussian_blobs(2000, 4, 5, 0.3, 37);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 5, 30, &mut dc);
        let params = KMeansParams { max_iter: 100, ..KMeansParams::default() };
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_m = run(&data, &init_c, &params, &MiniBatchParams::default());
        let sse_l = r_l.sse(&data);
        let sse_m = r_m.sse(&data);
        assert!(
            sse_m <= 1.25 * sse_l,
            "minibatch sse {sse_m} vs lloyd {sse_l}"
        );
    }

    #[test]
    fn cheaper_than_lloyd_on_large_n() {
        let data = synth::istanbul(0.02, 38);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 50, 31, &mut dc);
        // Lloyd runs to convergence; mini-batch is capped at a fixed
        // budget of batches (its normal usage mode).
        let params_l = KMeansParams { max_iter: 200, ..KMeansParams::default() };
        let params_m = KMeansParams { max_iter: 30, ..KMeansParams::default() };
        let r_l = lloyd::run(&data, &init_c, &params_l);
        let r_m = run(&data, &init_c, &params_m, &MiniBatchParams::default());
        assert!(
            r_m.distances < r_l.distances,
            "minibatch {} vs lloyd {}",
            r_m.distances,
            r_l.distances
        );
    }

    #[test]
    fn deterministic_in_seed() {
        let data = synth::gaussian_blobs(300, 2, 3, 0.5, 39);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 3, 32, &mut dc);
        let params = KMeansParams::default();
        let a = run(&data, &init_c, &params, &MiniBatchParams::default());
        let b = run(&data, &init_c, &params, &MiniBatchParams::default());
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn snapshot_assignment_is_thread_invariant() {
        // The two-phase step must make any thread count replay the
        // sequential trajectory bit for bit.
        let data = synth::gaussian_blobs(1500, 3, 4, 0.5, 41);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 4, 33, &mut dc);
        let params = KMeansParams { max_iter: 25, ..KMeansParams::default() };
        let mb = MiniBatchParams { batch: 600, ..MiniBatchParams::default() };
        let r1 = run_par(&data, &init_c, &params, &mb, &Parallelism::sequential());
        let r4 = run_par(&data, &init_c, &params, &mb, &Parallelism::new(4));
        assert_eq!(r1.labels, r4.labels);
        assert_eq!(r1.iterations, r4.iterations);
        assert_eq!(r1.distances, r4.distances);
        for (a, b) in r1.centers.as_slice().iter().zip(r4.centers.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
