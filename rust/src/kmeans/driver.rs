//! The unified per-iteration driver behind every exact algorithm.
//!
//! The paper's family (§2-3) shares one outer loop — assign, recompute the
//! means (Eq. 2), check the assignment fixpoint — and differs only in *how*
//! each assignment pass prunes distance computations. This module makes
//! that structure literal:
//!
//! * [`KMeansDriver`] — the per-iteration strategy: `init_state` seeds the
//!   per-point state (iteration 1, conventionally a full scan or a tree
//!   pass), `iterate` runs one pruned assignment pass, `post_update` is the
//!   bound-maintenance hook after the centers moved, `finish` yields the
//!   final labels.
//! * [`Fit`] — the shared outer loop as a stepwise handle: it owns the
//!   centers, the [`CentroidAccum`], the [`DistCounter`], convergence
//!   checking (fixpoint, optional movement tolerance, iteration cap) and
//!   the per-iteration log. `step()` advances one iteration and returns a
//!   [`StepInfo`]; `run()` drives to completion, consulting the registered
//!   [`Observer`] after every iteration (early stopping, telemetry).
//!
//! Exactness invariant: driving any exact algorithm through this loop
//! replicates the pre-refactor per-algorithm loops byte-for-byte — same
//! assignment sequence, same distance counts (`rust/tests/exactness.rs`).

use std::time::Duration;

use crate::data::Matrix;
use crate::kmeans::bounds::CentroidAccum;
use crate::kmeans::{
    cover, dualtree, elkan, exponion, hamerly, hybrid, kanungo, lloyd, pelleg,
    phillips, shallot, Algorithm, KMeansParams, Workspace,
};
use crate::metrics::{DistCounter, IterationLog, RunResult, Stopwatch};

/// Per-iteration strategy of one exact k-means variant.
///
/// The shared outer loop ([`Fit`]) owns the centers, the accumulator,
/// convergence checking and iteration logging; a driver owns the per-point
/// state (labels, stored bounds, spatial index) and implements the
/// assignment passes. Implementations must uphold the exactness contract:
/// every pass assigns each point to its true nearest center (ties to the
/// lowest index).
pub trait KMeansDriver {
    /// Which algorithm this driver implements (display / reporting).
    fn algorithm(&self) -> Algorithm;

    /// Iteration 1: seed the per-point state with a first assignment pass
    /// against `centers`, filling `acc`. Returns the number of points
    /// whose assignment changed (conventionally `n`).
    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize;

    /// Iterations 2..: one pruned assignment pass. Same contract as
    /// [`KMeansDriver::init_state`].
    fn iterate(
        &mut self,
        iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize;

    /// Bound maintenance after the outer loop recomputed the centers;
    /// `movement` holds the per-center movement distances (§2.2). Default:
    /// no stored bounds, nothing to maintain.
    fn post_update(&mut self, _iter: usize, _movement: &[f64]) {}

    /// Current assignment (valid after `init_state`).
    fn labels(&self) -> &[u32];

    /// Consume the driver, yielding the final labels without cloning.
    fn finish(self: Box<Self>) -> Vec<u32>;
}

/// Observer verdict after an iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Signal {
    Continue,
    /// Halt after this iteration; the run keeps whatever `converged`
    /// status the loop itself established.
    Stop,
}

/// The numbers of one completed iteration, returned by [`Fit::step`] and
/// embedded in the observer's [`StepView`].
#[derive(Debug, Clone, Copy)]
pub struct StepInfo {
    /// 1-based iteration index.
    pub iter: usize,
    /// Points whose assignment changed this iteration.
    pub changed: usize,
    /// Cumulative counted distance computations (excludes tree build).
    pub distances: u64,
    /// Largest per-center movement of this iteration's recomputation.
    pub max_movement: f64,
    /// Assignment fixpoint (or movement tolerance) reached.
    pub converged: bool,
    /// No further iterations will run (fixpoint, tolerance, or cap).
    pub done: bool,
}

/// What an observer sees after each iteration: the numbers plus the state
/// needed for early-stopping decisions and sweep-time center reuse.
pub struct StepView<'v> {
    pub info: StepInfo,
    /// Centers *after* this iteration's recomputation.
    pub centers: &'v Matrix,
    /// Assignment produced by this iteration.
    pub labels: &'v [u32],
}

impl StepView<'_> {
    /// SSE of this snapshot against `data` (uncounted evaluation work;
    /// labels predate the center recomputation, so this is the standard
    /// post-assignment inertia practitioners plot per iteration).
    pub fn sse(&self, data: &Matrix) -> f64 {
        crate::metrics::sse(data, self.labels, self.centers)
    }
}

/// Per-iteration callback; return [`Signal::Stop`] to end the run early.
pub type Observer = Box<dyn FnMut(&StepView<'_>) -> Signal>;

/// A stepwise k-means run: the shared outer loop with the iteration
/// boundary exposed. Construct via [`crate::kmeans::KMeans::fit_step`] (or
/// [`Fit::from_driver`] for a custom [`KMeansDriver`]), then either call
/// [`Fit::step`] yourself or [`Fit::run`] to completion.
pub struct Fit<'a> {
    data: &'a Matrix,
    driver: Box<dyn KMeansDriver + 'a>,
    centers: Matrix,
    acc: CentroidAccum,
    movement: Vec<f64>,
    dist: DistCounter,
    log: IterationLog,
    sw: Stopwatch,
    iter: usize,
    max_iter: usize,
    tol: f64,
    converged: bool,
    done: bool,
    build_dist: u64,
    build_time: Duration,
    observer: Option<Observer>,
}

impl<'a> Fit<'a> {
    /// Assemble a stepwise run from an explicit driver. Exposed so custom
    /// `KMeansDriver` implementations can reuse the shared outer loop.
    pub fn from_driver(
        data: &'a Matrix,
        driver: Box<dyn KMeansDriver + 'a>,
        init: &Matrix,
        max_iter: usize,
        tol: f64,
    ) -> Fit<'a> {
        let k = init.rows();
        Fit {
            data,
            driver,
            centers: init.clone(),
            acc: CentroidAccum::new(k, init.cols()),
            movement: Vec::with_capacity(k),
            dist: DistCounter::new(),
            log: IterationLog::new(),
            sw: Stopwatch::start(),
            iter: 0,
            max_iter,
            tol,
            converged: false,
            done: max_iter == 0,
            build_dist: 0,
            build_time: Duration::ZERO,
            observer: None,
        }
    }

    pub(crate) fn with_build_cost(mut self, build_dist: u64, build_time: Duration) -> Self {
        self.build_dist = build_dist;
        self.build_time = build_time;
        self
    }

    pub(crate) fn with_observer(mut self, observer: Option<Observer>) -> Self {
        self.observer = observer;
        self
    }

    /// Advance one iteration: assignment pass, center recomputation, bound
    /// maintenance, convergence check, observer consultation. Returns
    /// `None` once the run is done (fixpoint, tolerance, cap, or observer
    /// stop) — so a manual `while fit.step().is_some() {}` drive honors
    /// the registered observer exactly like [`Fit::run`] does.
    pub fn step(&mut self) -> Option<StepInfo> {
        if self.done {
            return None;
        }
        self.iter += 1;
        self.acc.clear();
        let changed = if self.iter == 1 {
            self.driver.init_state(&self.centers, &mut self.acc, &mut self.dist)
        } else {
            self.driver.iterate(self.iter, &self.centers, &mut self.acc, &mut self.dist)
        };
        self.acc.update_centers(&mut self.centers, &mut self.dist, &mut self.movement);
        self.driver.post_update(self.iter, &self.movement);
        self.log.push(self.iter, self.dist.count(), self.sw.elapsed(), changed);
        let max_movement = self.movement.iter().fold(0.0f64, |a, &b| a.max(b));
        // Fixpoint is the paper's criterion; the movement tolerance is an
        // opt-in addition (tol = 0 preserves exact replication).
        if changed == 0 || (self.tol > 0.0 && max_movement <= self.tol) {
            self.converged = true;
        }
        if self.converged || self.iter >= self.max_iter {
            self.done = true;
        }
        let mut info = StepInfo {
            iter: self.iter,
            changed,
            distances: self.dist.count(),
            max_movement,
            converged: self.converged,
            done: self.done,
        };
        if let Some(mut obs) = self.observer.take() {
            let view = StepView {
                info,
                centers: &self.centers,
                labels: self.driver.labels(),
            };
            let signal = obs(&view);
            self.observer = Some(obs);
            if signal == Signal::Stop {
                self.done = true;
                info.done = true;
            }
        }
        Some(info)
    }

    /// Drive to completion (the observer, if any, is consulted inside
    /// every [`Fit::step`]).
    pub fn run(mut self) -> RunResult {
        while self.step().is_some() {}
        self.finish()
    }

    /// Seal the run into a [`RunResult`] (callable at any iteration
    /// boundary after the first step — iteration 1 produces the first
    /// valid assignment; before it, labels are the unassigned sentinel).
    pub fn finish(self) -> RunResult {
        RunResult {
            labels: self.driver.finish(),
            centers: self.centers,
            iterations: self.iter,
            distances: self.dist.count(),
            build_dist: self.build_dist,
            time: self.sw.elapsed(),
            build_time: self.build_time,
            log: self.log,
            converged: self.converged,
        }
    }

    /// The algorithm being driven.
    pub fn algorithm(&self) -> Algorithm {
        self.driver.algorithm()
    }

    /// Centers after the last completed iteration.
    pub fn centers(&self) -> &Matrix {
        &self.centers
    }

    /// Assignment after the last completed iteration. Valid once the
    /// first step ran; before that, tree-based drivers report the
    /// `u32::MAX` unassigned sentinel.
    pub fn labels(&self) -> &[u32] {
        self.driver.labels()
    }

    /// Completed iterations so far.
    pub fn iterations(&self) -> usize {
        self.iter
    }

    pub fn converged(&self) -> bool {
        self.converged
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Cumulative counted distances (excludes tree construction).
    pub fn distances(&self) -> u64 {
        self.dist.count()
    }

    /// Current inertia (SSE) of the snapshot, or `f64::INFINITY` before
    /// the first step produced an assignment.
    pub fn sse(&self) -> f64 {
        if self.iter == 0 {
            return f64::INFINITY;
        }
        crate::metrics::sse(self.data, self.driver.labels(), &self.centers)
    }
}

/// Construct the driver for `params.algorithm`, charging a fresh tree
/// build (when the workspace misses) to the returned build cost pair.
/// `params.threads` selects the intra-fit thread budget; the pool behind
/// it comes from the workspace ([`Workspace::parallelism`]), so repeated
/// fits against one workspace reuse the same long-lived workers for the
/// assignment passes, tree construction, and the k-d-tree filtering
/// recursions alike. Panics on [`Algorithm::MiniBatch`], which is
/// approximate and does not run the exact outer loop.
pub(crate) fn new_driver<'a>(
    data: &'a Matrix,
    k: usize,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> (Box<dyn KMeansDriver + 'a>, u64, Duration) {
    let par = ws.parallelism_opts(params.threads, params.pin_workers);
    match params.algorithm {
        Algorithm::Standard => {
            (Box::new(lloyd::LloydDriver::new(data, par)), 0, Duration::ZERO)
        }
        Algorithm::Elkan => {
            (Box::new(elkan::ElkanDriver::new(data, k, par)), 0, Duration::ZERO)
        }
        Algorithm::Hamerly => {
            (Box::new(hamerly::HamerlyDriver::new(data, par)), 0, Duration::ZERO)
        }
        Algorithm::Exponion => {
            (Box::new(exponion::ExponionDriver::new(data, par)), 0, Duration::ZERO)
        }
        Algorithm::Shallot => {
            (Box::new(shallot::ShallotDriver::new(data, par)), 0, Duration::ZERO)
        }
        Algorithm::Phillips => {
            (Box::new(phillips::PhillipsDriver::new(data, par)), 0, Duration::ZERO)
        }
        Algorithm::Kanungo => {
            let (tree, fresh) = ws.kd_tree_arc(data, params.kd);
            let bt = if fresh { tree.build_time } else { Duration::ZERO };
            (Box::new(kanungo::KanungoDriver::new(data, tree, par)), 0, bt)
        }
        Algorithm::PellegMoore => {
            let (tree, fresh) = ws.kd_tree_arc(data, params.kd);
            let bt = if fresh { tree.build_time } else { Duration::ZERO };
            (Box::new(pelleg::PellegDriver::new(data, tree, par)), 0, bt)
        }
        Algorithm::CoverMeans => {
            let (tree, fresh) = ws.cover_tree_arc_par(data, params.cover, &par);
            let (bd, bt) = if fresh {
                (tree.build_distances, tree.build_time)
            } else {
                (0, Duration::ZERO)
            };
            (Box::new(cover::CoverDriver::new(data, tree, par)), bd, bt)
        }
        Algorithm::DualTree => {
            let (tree, fresh) = ws.cover_tree_arc_par(data, params.cover, &par);
            let (bd, bt) = if fresh {
                (tree.build_distances, tree.build_time)
            } else {
                (0, Duration::ZERO)
            };
            (Box::new(dualtree::DualDriver::new(data, tree, par)), bd, bt)
        }
        Algorithm::Hybrid => {
            let (tree, fresh) = ws.cover_tree_arc_par(data, params.cover, &par);
            let (bd, bt) = if fresh {
                (tree.build_distances, tree.build_time)
            } else {
                (0, Duration::ZERO)
            };
            (
                Box::new(hybrid::HybridDriver::new(data, tree, params.switch_at, par)),
                bd,
                bt,
            )
        }
        Algorithm::MiniBatch => {
            unreachable!("mini-batch is approximate; it does not use the exact driver loop")
        }
    }
}

/// One-shot runner over the shared loop — the engine behind the legacy
/// free-function shims (`kmeans::run` and the per-module `run`s).
pub(crate) fn run_exact(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> RunResult {
    let (driver, build_dist, build_time) = new_driver(data, init.rows(), params, ws);
    Fit::from_driver(data, driver, init, params.max_iter, params.tol)
        .with_build_cost(build_dist, build_time)
        .run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, KMeans};
    use crate::metrics::DistCounter;

    fn blobs_and_init() -> (Matrix, Matrix) {
        let data = synth::gaussian_blobs(300, 3, 4, 0.6, 41);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 4, 9, &mut dc);
        (data, init_c)
    }

    #[test]
    fn stepwise_equals_one_shot() {
        let (data, init_c) = blobs_and_init();
        for alg in [Algorithm::Standard, Algorithm::Shallot, Algorithm::Hybrid] {
            let params = KMeansParams { algorithm: alg, ..KMeansParams::default() };
            let one = run_exact(&data, &init_c, &params, &mut Workspace::new());
            let (driver, bd, bt) =
                new_driver(&data, init_c.rows(), &params, &mut Workspace::new());
            let mut fit = Fit::from_driver(&data, driver, &init_c, params.max_iter, 0.0)
                .with_build_cost(bd, bt);
            while fit.step().is_some() {}
            let stepped = fit.finish();
            assert_eq!(stepped.labels, one.labels, "{}", alg.name());
            assert_eq!(stepped.iterations, one.iterations, "{}", alg.name());
            assert_eq!(stepped.distances, one.distances, "{}", alg.name());
            assert_eq!(stepped.converged, one.converged, "{}", alg.name());
        }
    }

    #[test]
    fn observer_sees_every_iteration_and_can_stop() {
        let (data, init_c) = blobs_and_init();
        let baseline = run_exact(
            &data,
            &init_c,
            &KMeansParams::default(),
            &mut Workspace::new(),
        );
        assert!(baseline.iterations > 2, "need a multi-iteration run");

        let seen = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let seen2 = seen.clone();
        let r = KMeans::new(4)
            .warm_start(init_c.clone())
            .observer(move |view: &StepView<'_>| {
                seen2.borrow_mut().push(view.info.iter);
                if view.info.iter == 2 { Signal::Stop } else { Signal::Continue }
            })
            .fit(&data)
            .unwrap();
        assert_eq!(r.iterations, 2, "observer stop must halt the loop");
        assert!(!r.converged);
        assert_eq!(*seen.borrow(), vec![1, 2]);
    }

    #[test]
    fn tol_stops_before_fixpoint() {
        let (data, init_c) = blobs_and_init();
        let exact = run_exact(
            &data,
            &init_c,
            &KMeansParams::default(),
            &mut Workspace::new(),
        );
        let loose = run_exact(
            &data,
            &init_c,
            &KMeansParams { tol: 1e9, ..KMeansParams::default() },
            &mut Workspace::new(),
        );
        assert!(loose.converged);
        assert!(loose.iterations <= exact.iterations);
        assert_eq!(loose.iterations, 1, "huge tol stops after one iteration");
    }

    #[test]
    fn max_iter_zero_runs_nothing() {
        let (data, init_c) = blobs_and_init();
        let params = KMeansParams { max_iter: 0, ..KMeansParams::default() };
        let r = run_exact(&data, &init_c, &params, &mut Workspace::new());
        assert_eq!(r.iterations, 0);
        assert_eq!(r.distances, 0);
        assert!(!r.converged);
        assert!(r.log.is_empty());
    }
}
