//! The unified per-iteration driver behind every exact algorithm.
//!
//! The paper's family (§2-3) shares one outer loop — assign, recompute the
//! means (Eq. 2), check the assignment fixpoint — and differs only in *how*
//! each assignment pass prunes distance computations. This module makes
//! that structure literal:
//!
//! * [`KMeansDriver`] — the per-iteration strategy: `init_state` seeds the
//!   per-point state (iteration 1, conventionally a full scan or a tree
//!   pass), `iterate` runs one pruned assignment pass, `post_update` is the
//!   bound-maintenance hook after the centers moved, `finish` yields the
//!   final labels.
//! * [`Fit`] — the shared outer loop as a stepwise handle: it owns the
//!   centers, the [`CentroidAccum`], the [`DistCounter`], convergence
//!   checking (fixpoint, optional movement tolerance, iteration cap) and
//!   the per-iteration log. `step()` advances one iteration and returns a
//!   [`StepInfo`]; `run()` drives to completion, consulting the registered
//!   [`Observer`] after every iteration (early stopping, telemetry).
//!
//! Exactness invariant: driving any exact algorithm through this loop
//! replicates the pre-refactor per-algorithm loops byte-for-byte — same
//! assignment sequence, same distance counts (`rust/tests/exactness.rs`).

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::data::{Matrix, SourceView};
use crate::kmeans::bounds::CentroidAccum;
use crate::kmeans::checkpoint::{CheckpointConfig, KMeansCheckpoint};
use crate::kmeans::{
    cover, dualtree, elkan, exponion, hamerly, hybrid, kanungo, lloyd, pelleg,
    phillips, shallot, Algorithm, KMeansParams, Workspace,
};
use crate::metrics::{DistCounter, IterationLog, RunResult, Stopwatch};

/// The serializable cross-iteration state of a [`KMeansDriver`], as the
/// checkpoint subsystem sees it: the labels every driver keeps, plus
/// driver-defined `f64` / `u32` vectors (stored bounds, second-nearest
/// indices) in a slot order each driver fixes for itself. Spatial indexes
/// (cover / k-d trees) are *not* state — their builds are deterministic
/// and thread-count invariant, so resume rebuilds them.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DriverState {
    /// Current assignment (may be the `u32::MAX` unassigned sentinel
    /// when a checkpoint landed before iteration 1 — it never does today,
    /// but the format allows it).
    pub labels: Vec<u32>,
    /// Driver-defined `f64` vectors (e.g. Hamerly's upper/lower bounds),
    /// in the driver's own slot order.
    pub f64_slots: Vec<Vec<f64>>,
    /// Driver-defined `u32` vectors (e.g. Shallot's second-nearest
    /// center indices), in the driver's own slot order.
    pub u32_slots: Vec<Vec<u32>>,
}

impl DriverState {
    pub fn new(labels: Vec<u32>) -> DriverState {
        DriverState { labels, f64_slots: Vec::new(), u32_slots: Vec::new() }
    }

    pub fn with_f64(mut self, v: Vec<f64>) -> DriverState {
        self.f64_slots.push(v);
        self
    }

    pub fn with_u32(mut self, v: Vec<u32>) -> DriverState {
        self.u32_slots.push(v);
        self
    }

    /// The labels, validated against the expected point count.
    pub fn labels_checked(&self, n: usize) -> Result<&[u32]> {
        if self.labels.len() != n {
            bail!(
                "checkpointed labels have {} entries, expected {n}",
                self.labels.len()
            );
        }
        Ok(&self.labels)
    }

    /// Slot `i` of the `f64` state, validated against an expected length.
    pub fn f64_slot(&self, i: usize, len: usize, what: &str) -> Result<&[f64]> {
        match self.f64_slots.get(i) {
            Some(v) if v.len() == len => Ok(v),
            Some(v) => bail!(
                "checkpointed {what} has {} entries, expected {len}",
                v.len()
            ),
            None => bail!("checkpoint is missing driver state slot {i} ({what})"),
        }
    }

    /// Slot `i` of the `u32` state, validated against an expected length.
    pub fn u32_slot(&self, i: usize, len: usize, what: &str) -> Result<&[u32]> {
        match self.u32_slots.get(i) {
            Some(v) if v.len() == len => Ok(v),
            Some(v) => bail!(
                "checkpointed {what} has {} entries, expected {len}",
                v.len()
            ),
            None => bail!("checkpoint is missing driver state slot {i} ({what})"),
        }
    }
}

/// Per-iteration strategy of one exact k-means variant.
///
/// The shared outer loop ([`Fit`]) owns the centers, the accumulator,
/// convergence checking and iteration logging; a driver owns the per-point
/// state (labels, stored bounds, spatial index) and implements the
/// assignment passes. Implementations must uphold the exactness contract:
/// every pass assigns each point to its true nearest center (ties to the
/// lowest index).
pub trait KMeansDriver {
    /// Which algorithm this driver implements (display / reporting).
    fn algorithm(&self) -> Algorithm;

    /// Iteration 1: seed the per-point state with a first assignment pass
    /// against `centers`, filling `acc`. Returns the number of points
    /// whose assignment changed (conventionally `n`).
    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize;

    /// Iterations 2..: one pruned assignment pass. Same contract as
    /// [`KMeansDriver::init_state`].
    fn iterate(
        &mut self,
        iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize;

    /// Bound maintenance after the outer loop recomputed the centers;
    /// `movement` holds the per-center movement distances (§2.2). Default:
    /// no stored bounds, nothing to maintain.
    fn post_update(&mut self, _iter: usize, _movement: &[f64]) {}

    /// Current assignment (valid after `init_state`).
    fn labels(&self) -> &[u32];

    /// Snapshot the cross-iteration state for a checkpoint. `None` (the
    /// default) marks the driver as not checkpointable — the fit then
    /// refuses to write snapshots instead of writing unresumable ones.
    fn save_state(&self) -> Option<DriverState> {
        None
    }

    /// Restore a snapshot produced by [`KMeansDriver::save_state`].
    /// Implementations must validate lengths: a state that does not fit
    /// this driver/dataset is an error, never a panic. The default
    /// (paired with the `save_state` default) rejects restoration.
    fn load_state(&mut self, _state: &DriverState) -> Result<()> {
        bail!(
            "{} does not support checkpoint resume",
            self.algorithm().name()
        )
    }

    /// Consume the driver, yielding the final labels without cloning.
    fn finish(self: Box<Self>) -> Vec<u32>;
}

/// Observer verdict after an iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Signal {
    Continue,
    /// Halt after this iteration; the run keeps whatever `converged`
    /// status the loop itself established.
    Stop,
}

/// The numbers of one completed iteration, returned by [`Fit::step`] and
/// embedded in the observer's [`StepView`].
#[derive(Debug, Clone, Copy)]
pub struct StepInfo {
    /// 1-based iteration index.
    pub iter: usize,
    /// Points whose assignment changed this iteration.
    pub changed: usize,
    /// Cumulative counted distance computations (excludes tree build).
    pub distances: u64,
    /// Largest per-center movement of this iteration's recomputation.
    pub max_movement: f64,
    /// Assignment fixpoint (or movement tolerance) reached.
    pub converged: bool,
    /// No further iterations will run (fixpoint, tolerance, or cap).
    pub done: bool,
}

/// What an observer sees after each iteration: the numbers plus the state
/// needed for early-stopping decisions and sweep-time center reuse.
pub struct StepView<'v> {
    pub info: StepInfo,
    /// Centers *after* this iteration's recomputation.
    pub centers: &'v Matrix,
    /// Assignment produced by this iteration.
    pub labels: &'v [u32],
}

impl StepView<'_> {
    /// SSE of this snapshot against `data` (uncounted evaluation work;
    /// labels predate the center recomputation, so this is the standard
    /// post-assignment inertia practitioners plot per iteration).
    pub fn sse(&self, data: &Matrix) -> f64 {
        crate::metrics::sse(data, self.labels, self.centers)
    }
}

/// Per-iteration callback; return [`Signal::Stop`] to end the run early.
pub type Observer = Box<dyn FnMut(&StepView<'_>) -> Signal>;

/// The attached checkpoint destination of a [`Fit`]: the config (path +
/// triggers), the run identity recorded into every snapshot, the time
/// trigger's clock, and the sticky error of a failed write.
struct CheckpointSink {
    cfg: CheckpointConfig,
    fingerprint: u64,
    seed: u64,
    last_write: Instant,
    err: Option<anyhow::Error>,
}

/// Fault injection: `COVERMEANS_CRASH_AFTER_ITER=N` aborts the process
/// right after the first checkpoint written at iteration >= N — the
/// deterministic "power loss mid-run" the crash-resume harness replays
/// (`rust/tests/crash_resume.rs`).
fn maybe_crash_after_iter(iter: usize) {
    let Ok(v) = std::env::var("COVERMEANS_CRASH_AFTER_ITER") else {
        return;
    };
    let Ok(n) = v.parse::<usize>() else { return };
    if iter >= n {
        eprintln!("fault injection: simulated crash after iteration {iter}");
        std::process::abort();
    }
}

/// A stepwise k-means run: the shared outer loop with the iteration
/// boundary exposed. Construct via [`crate::kmeans::KMeans::fit_step`] (or
/// [`Fit::from_driver`] for a custom [`KMeansDriver`]), then either call
/// [`Fit::step`] yourself or [`Fit::run`] to completion.
pub struct Fit<'a> {
    src: SourceView<'a>,
    driver: Box<dyn KMeansDriver + 'a>,
    centers: Matrix,
    acc: CentroidAccum,
    movement: Vec<f64>,
    dist: DistCounter,
    log: IterationLog,
    sw: Stopwatch,
    iter: usize,
    max_iter: usize,
    tol: f64,
    converged: bool,
    done: bool,
    build_dist: u64,
    build_time: Duration,
    observer: Option<Observer>,
    ckpt: Option<CheckpointSink>,
}

impl<'a> Fit<'a> {
    /// Assemble a stepwise run from an explicit driver. Exposed so custom
    /// `KMeansDriver` implementations can reuse the shared outer loop.
    pub fn from_driver(
        data: &'a Matrix,
        driver: Box<dyn KMeansDriver + 'a>,
        init: &Matrix,
        max_iter: usize,
        tol: f64,
    ) -> Fit<'a> {
        Fit::from_driver_src(data.into(), driver, init, max_iter, tol)
    }

    /// [`Fit::from_driver`] over any data source backend. The loop itself
    /// touches the data only for checkpoint metadata and SSE evaluation;
    /// whether iterations stream is the driver's business.
    pub(crate) fn from_driver_src(
        src: SourceView<'a>,
        driver: Box<dyn KMeansDriver + 'a>,
        init: &Matrix,
        max_iter: usize,
        tol: f64,
    ) -> Fit<'a> {
        let k = init.rows();
        Fit {
            src,
            driver,
            centers: init.clone(),
            acc: CentroidAccum::new(k, init.cols()),
            movement: Vec::with_capacity(k),
            dist: DistCounter::new(),
            log: IterationLog::new(),
            sw: Stopwatch::start(),
            iter: 0,
            max_iter,
            tol,
            converged: false,
            done: max_iter == 0,
            build_dist: 0,
            build_time: Duration::ZERO,
            observer: None,
            ckpt: None,
        }
    }

    pub(crate) fn with_build_cost(mut self, build_dist: u64, build_time: Duration) -> Self {
        self.build_dist = build_dist;
        self.build_time = build_time;
        self
    }

    pub(crate) fn with_observer(mut self, observer: Option<Observer>) -> Self {
        self.observer = observer;
        self
    }

    /// Attach crash-safe checkpointing: snapshots go to `cfg.path` per the
    /// `cfg` triggers, plus one when the run completes. `fingerprint` is
    /// this run's [`crate::kmeans::checkpoint::config_fingerprint`]
    /// (resume rejects any other); `seed` is recorded as provenance. A
    /// failed write stops the run at that iteration boundary and surfaces
    /// through [`Fit::checkpoint_error`].
    pub fn with_checkpoints(
        mut self,
        cfg: CheckpointConfig,
        fingerprint: u64,
        seed: u64,
    ) -> Self {
        self.ckpt = Some(CheckpointSink {
            cfg,
            fingerprint,
            seed,
            last_write: Instant::now(),
            err: None,
        });
        self
    }

    /// Advance one iteration: assignment pass, center recomputation, bound
    /// maintenance, convergence check, observer consultation. Returns
    /// `None` once the run is done (fixpoint, tolerance, cap, or observer
    /// stop) — so a manual `while fit.step().is_some() {}` drive honors
    /// the registered observer exactly like [`Fit::run`] does.
    pub fn step(&mut self) -> Option<StepInfo> {
        if self.done {
            return None;
        }
        self.iter += 1;
        self.acc.clear();
        let changed = if self.iter == 1 {
            self.driver.init_state(&self.centers, &mut self.acc, &mut self.dist)
        } else {
            self.driver.iterate(self.iter, &self.centers, &mut self.acc, &mut self.dist)
        };
        self.acc.update_centers(&mut self.centers, &mut self.dist, &mut self.movement);
        self.driver.post_update(self.iter, &self.movement);
        self.log.push(self.iter, self.dist.count(), self.sw.elapsed(), changed);
        let max_movement = self.movement.iter().fold(0.0f64, |a, &b| a.max(b));
        // Fixpoint is the paper's criterion; the movement tolerance is an
        // opt-in addition (tol = 0 preserves exact replication).
        if changed == 0 || (self.tol > 0.0 && max_movement <= self.tol) {
            self.converged = true;
        }
        if self.converged || self.iter >= self.max_iter {
            self.done = true;
        }
        let mut info = StepInfo {
            iter: self.iter,
            changed,
            distances: self.dist.count(),
            max_movement,
            converged: self.converged,
            done: self.done,
        };
        if let Some(mut obs) = self.observer.take() {
            let view = StepView {
                info,
                centers: &self.centers,
                labels: self.driver.labels(),
            };
            let signal = obs(&view);
            self.observer = Some(obs);
            if signal == Signal::Stop {
                self.done = true;
                info.done = true;
            }
        }
        self.maybe_checkpoint();
        info.done = self.done;
        Some(info)
    }

    /// Write a snapshot if one is due: the run just finished, the every-N
    /// trigger fired, or the time trigger elapsed. A write failure is
    /// sticky ([`Fit::checkpoint_error`]) and ends the run at this
    /// boundary — continuing past it would break the crash-safety the
    /// caller asked for.
    fn maybe_checkpoint(&mut self) {
        let Some(ck) = &self.ckpt else { return };
        if ck.err.is_some() {
            return;
        }
        let due = self.done
            || (ck.cfg.every > 0 && self.iter % ck.cfg.every == 0)
            || (ck.cfg.secs > 0 && ck.last_write.elapsed().as_secs() >= ck.cfg.secs);
        if !due {
            return;
        }
        if let Err(e) = self.checkpoint_now() {
            self.done = true;
            if let Some(ck) = &mut self.ckpt {
                ck.err = Some(e);
            }
        }
    }

    /// Snapshot the fit to the configured checkpoint path right now,
    /// whatever the triggers say — the signal-driven checkpoint-then-exit
    /// path of `covermeans run`. Also resets the time trigger.
    pub fn checkpoint_now(&mut self) -> Result<()> {
        let Some(ck) = &self.ckpt else {
            bail!("no checkpoint path configured for this fit");
        };
        let Some(state) = self.driver.save_state() else {
            bail!(
                "{} does not support checkpointing",
                self.driver.algorithm().name()
            );
        };
        let snap = KMeansCheckpoint {
            fingerprint: ck.fingerprint,
            algorithm: self.driver.algorithm(),
            k: self.centers.rows(),
            dim: self.centers.cols(),
            n: self.src.rows(),
            seed: ck.seed,
            iter: self.iter as u64,
            converged: self.converged,
            distances: self.dist.count(),
            build_dist: self.build_dist,
            build_time: self.build_time,
            centers: self.centers.clone(),
            log: self.log.stats.clone(),
            state,
        };
        snap.save(&ck.cfg.path)?;
        maybe_crash_after_iter(self.iter);
        if let Some(ck) = &mut self.ckpt {
            ck.last_write = Instant::now();
        }
        Ok(())
    }

    /// The sticky error of a failed checkpoint write, if any (the run
    /// stopped at the iteration boundary where the write failed).
    pub fn checkpoint_error(&self) -> Option<&anyhow::Error> {
        self.ckpt.as_ref().and_then(|c| c.err.as_ref())
    }

    /// Take ownership of the sticky checkpoint error for propagation.
    pub fn take_checkpoint_error(&mut self) -> Option<anyhow::Error> {
        self.ckpt.as_mut().and_then(|c| c.err.take())
    }

    /// Rewind this freshly constructed (never stepped) fit to a
    /// checkpointed state. The caller validates the config fingerprint
    /// first ([`KMeansCheckpoint::validate`]); this checks the structural
    /// fit and restores the centers, driver state, counters and log. The
    /// stopwatch restarts — wall-clock time sits outside the identity
    /// contract; everything else resumes bit-identically.
    pub fn restore(&mut self, snap: &KMeansCheckpoint) -> Result<()> {
        if self.iter != 0 {
            bail!("restore must happen before the first step");
        }
        if snap.algorithm != self.driver.algorithm() {
            bail!(
                "checkpoint is for {}, this fit drives {}",
                snap.algorithm.name(),
                self.driver.algorithm().name()
            );
        }
        if snap.n != self.src.rows()
            || snap.dim != self.src.cols()
            || snap.k != self.centers.rows()
        {
            bail!(
                "checkpoint shape (n={}, d={}, k={}) does not match this \
                 fit (n={}, d={}, k={})",
                snap.n,
                snap.dim,
                snap.k,
                self.src.rows(),
                self.src.cols(),
                self.centers.rows()
            );
        }
        self.driver.load_state(&snap.state)?;
        self.centers = snap.centers.clone();
        self.iter = snap.iter as usize;
        self.converged = snap.converged;
        self.done = self.converged || self.iter >= self.max_iter;
        self.dist = DistCounter::new();
        self.dist.add_bulk(snap.distances);
        self.log = IterationLog { stats: snap.log.clone() };
        // The snapshot's build cost replaces any re-charged tree build of
        // this construction, so resumed totals match the uninterrupted
        // run exactly.
        self.build_dist = snap.build_dist;
        self.build_time = snap.build_time;
        self.sw = Stopwatch::start();
        Ok(())
    }

    /// Drive to completion (the observer, if any, is consulted inside
    /// every [`Fit::step`]).
    pub fn run(mut self) -> RunResult {
        while self.step().is_some() {}
        self.finish()
    }

    /// Seal the run into a [`RunResult`] (callable at any iteration
    /// boundary after the first step — iteration 1 produces the first
    /// valid assignment; before it, labels are the unassigned sentinel).
    pub fn finish(self) -> RunResult {
        RunResult {
            labels: self.driver.finish(),
            centers: self.centers,
            iterations: self.iter,
            distances: self.dist.count(),
            build_dist: self.build_dist,
            time: self.sw.elapsed(),
            build_time: self.build_time,
            log: self.log,
            converged: self.converged,
        }
    }

    /// The algorithm being driven.
    pub fn algorithm(&self) -> Algorithm {
        self.driver.algorithm()
    }

    /// Centers after the last completed iteration.
    pub fn centers(&self) -> &Matrix {
        &self.centers
    }

    /// Assignment after the last completed iteration. Valid once the
    /// first step ran; before that, tree-based drivers report the
    /// `u32::MAX` unassigned sentinel.
    pub fn labels(&self) -> &[u32] {
        self.driver.labels()
    }

    /// Completed iterations so far.
    pub fn iterations(&self) -> usize {
        self.iter
    }

    pub fn converged(&self) -> bool {
        self.converged
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Cumulative counted distances (excludes tree construction).
    pub fn distances(&self) -> u64 {
        self.dist.count()
    }

    /// Current inertia (SSE) of the snapshot, or `f64::INFINITY` before
    /// the first step produced an assignment.
    pub fn sse(&self) -> f64 {
        if self.iter == 0 {
            return f64::INFINITY;
        }
        crate::metrics::sse_src(self.src, self.driver.labels(), &self.centers)
    }
}

/// Construct the driver for `params.algorithm`, charging a fresh tree
/// build (when the workspace misses) to the returned build cost pair.
/// `params.threads` selects the intra-fit thread budget; the pool behind
/// it comes from the workspace ([`Workspace::parallelism`]), so repeated
/// fits against one workspace reuse the same long-lived workers for the
/// assignment passes, tree construction, and the k-d-tree filtering
/// recursions alike. Panics on [`Algorithm::MiniBatch`], which is
/// approximate and does not run the exact outer loop.
pub(crate) fn new_driver<'a>(
    data: &'a Matrix,
    k: usize,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> (Box<dyn KMeansDriver + 'a>, u64, Duration) {
    let par = ws.parallelism_opts(params.threads, params.pin_workers);
    match params.algorithm {
        Algorithm::Standard => {
            (Box::new(lloyd::LloydDriver::new(data, par)), 0, Duration::ZERO)
        }
        Algorithm::Elkan => {
            (Box::new(elkan::ElkanDriver::new(data, k, par)), 0, Duration::ZERO)
        }
        Algorithm::Hamerly => {
            (Box::new(hamerly::HamerlyDriver::new(data, par)), 0, Duration::ZERO)
        }
        Algorithm::Exponion => {
            (Box::new(exponion::ExponionDriver::new(data, par)), 0, Duration::ZERO)
        }
        Algorithm::Shallot => {
            (Box::new(shallot::ShallotDriver::new(data, par)), 0, Duration::ZERO)
        }
        Algorithm::Phillips => {
            (Box::new(phillips::PhillipsDriver::new(data, par)), 0, Duration::ZERO)
        }
        Algorithm::Kanungo => {
            let (tree, fresh) = ws.kd_tree_arc(data, params.kd);
            let bt = if fresh { tree.build_time } else { Duration::ZERO };
            (Box::new(kanungo::KanungoDriver::new(data, tree, par)), 0, bt)
        }
        Algorithm::PellegMoore => {
            let (tree, fresh) = ws.kd_tree_arc(data, params.kd);
            let bt = if fresh { tree.build_time } else { Duration::ZERO };
            (Box::new(pelleg::PellegDriver::new(data, tree, par)), 0, bt)
        }
        Algorithm::CoverMeans => {
            let (tree, fresh) = ws.cover_tree_arc_par(data, params.cover, &par);
            let (bd, bt) = if fresh {
                (tree.build_distances, tree.build_time)
            } else {
                (0, Duration::ZERO)
            };
            (Box::new(cover::CoverDriver::new(data, tree, par)), bd, bt)
        }
        Algorithm::DualTree => {
            let (tree, fresh) = ws.cover_tree_arc_par(data, params.cover, &par);
            let (bd, bt) = if fresh {
                (tree.build_distances, tree.build_time)
            } else {
                (0, Duration::ZERO)
            };
            (Box::new(dualtree::DualDriver::new(data, tree, par)), bd, bt)
        }
        Algorithm::Hybrid => {
            let (tree, fresh) = ws.cover_tree_arc_par(data, params.cover, &par);
            let (bd, bt) = if fresh {
                (tree.build_distances, tree.build_time)
            } else {
                (0, Duration::ZERO)
            };
            (
                Box::new(hybrid::HybridDriver::new(data, tree, params.switch_at, par)),
                bd,
                bt,
            )
        }
        Algorithm::MiniBatch => {
            unreachable!("mini-batch is approximate; it does not use the exact driver loop")
        }
    }
}

/// [`new_driver`] over any data source backend. In-RAM sources delegate to
/// [`new_driver`] (all algorithms, workspace tree caching intact); streamed
/// sources construct the streaming-capable drivers directly. The builder
/// rejects streamed input for non-streaming algorithms with a typed error
/// *before* reaching this point, so the panic here is a programming-error
/// backstop, not a user-facing diagnostic.
pub(crate) fn new_driver_src<'a>(
    src: SourceView<'a>,
    k: usize,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> (Box<dyn KMeansDriver + 'a>, u64, Duration) {
    if let Some(data) = src.as_matrix() {
        return new_driver(data, k, params, ws);
    }
    let par = ws.parallelism_opts(params.threads, params.pin_workers);
    match params.algorithm {
        Algorithm::Standard => {
            (Box::new(lloyd::LloydDriver::from_source(src, par)), 0, Duration::ZERO)
        }
        Algorithm::Elkan => {
            (Box::new(elkan::ElkanDriver::from_source(src, k, par)), 0, Duration::ZERO)
        }
        Algorithm::Hamerly => {
            (Box::new(hamerly::HamerlyDriver::from_source(src, par)), 0, Duration::ZERO)
        }
        other => panic!(
            "{} requires a resident data source (the builder should have \
             rejected streamed input)",
            other.name()
        ),
    }
}

/// One-shot runner over the shared loop — the engine behind the legacy
/// free-function shims (`kmeans::run` and the per-module `run`s).
pub(crate) fn run_exact(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> RunResult {
    let (driver, build_dist, build_time) = new_driver(data, init.rows(), params, ws);
    Fit::from_driver(data, driver, init, params.max_iter, params.tol)
        .with_build_cost(build_dist, build_time)
        .run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, KMeans};
    use crate::metrics::DistCounter;

    fn blobs_and_init() -> (Matrix, Matrix) {
        let data = synth::gaussian_blobs(300, 3, 4, 0.6, 41);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 4, 9, &mut dc);
        (data, init_c)
    }

    #[test]
    fn stepwise_equals_one_shot() {
        let (data, init_c) = blobs_and_init();
        for alg in [Algorithm::Standard, Algorithm::Shallot, Algorithm::Hybrid] {
            let params = KMeansParams { algorithm: alg, ..KMeansParams::default() };
            let one = run_exact(&data, &init_c, &params, &mut Workspace::new());
            let (driver, bd, bt) =
                new_driver(&data, init_c.rows(), &params, &mut Workspace::new());
            let mut fit = Fit::from_driver(&data, driver, &init_c, params.max_iter, 0.0)
                .with_build_cost(bd, bt);
            while fit.step().is_some() {}
            let stepped = fit.finish();
            assert_eq!(stepped.labels, one.labels, "{}", alg.name());
            assert_eq!(stepped.iterations, one.iterations, "{}", alg.name());
            assert_eq!(stepped.distances, one.distances, "{}", alg.name());
            assert_eq!(stepped.converged, one.converged, "{}", alg.name());
        }
    }

    #[test]
    fn observer_sees_every_iteration_and_can_stop() {
        let (data, init_c) = blobs_and_init();
        let baseline = run_exact(
            &data,
            &init_c,
            &KMeansParams::default(),
            &mut Workspace::new(),
        );
        assert!(baseline.iterations > 2, "need a multi-iteration run");

        let seen = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let seen2 = seen.clone();
        let r = KMeans::new(4)
            .warm_start(init_c.clone())
            .observer(move |view: &StepView<'_>| {
                seen2.borrow_mut().push(view.info.iter);
                if view.info.iter == 2 { Signal::Stop } else { Signal::Continue }
            })
            .fit(&data)
            .unwrap();
        assert_eq!(r.iterations, 2, "observer stop must halt the loop");
        assert!(!r.converged);
        assert_eq!(*seen.borrow(), vec![1, 2]);
    }

    #[test]
    fn tol_stops_before_fixpoint() {
        let (data, init_c) = blobs_and_init();
        let exact = run_exact(
            &data,
            &init_c,
            &KMeansParams::default(),
            &mut Workspace::new(),
        );
        let loose = run_exact(
            &data,
            &init_c,
            &KMeansParams { tol: 1e9, ..KMeansParams::default() },
            &mut Workspace::new(),
        );
        assert!(loose.converged);
        assert!(loose.iterations <= exact.iterations);
        assert_eq!(loose.iterations, 1, "huge tol stops after one iteration");
    }

    #[test]
    fn checkpoint_resume_is_bit_identical() {
        let (data, init_c) = blobs_and_init();
        let dir = std::env::temp_dir().join(format!(
            "covermeans_driver_ckpt_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        for alg in [
            Algorithm::Standard,
            Algorithm::Hamerly,
            Algorithm::Elkan,
            Algorithm::CoverMeans,
            Algorithm::DualTree,
            Algorithm::Hybrid,
        ] {
            let params = KMeansParams { algorithm: alg, ..KMeansParams::default() };
            let full = run_exact(&data, &init_c, &params, &mut Workspace::new());
            assert!(full.iterations > 2, "{} converged too fast", alg.name());
            let fp = crate::kmeans::checkpoint::config_fingerprint(
                &params,
                &data,
                init_c.rows(),
            );
            let path = dir.join(format!("{}.kmc", alg.name()));
            let cfg = CheckpointConfig { path: path.clone(), every: 1, secs: 0 };
            // Interrupted run: two iterations, then the fit is dropped —
            // only the on-disk snapshot survives.
            let (driver, bd, bt) =
                new_driver(&data, init_c.rows(), &params, &mut Workspace::new());
            let mut fit = Fit::from_driver(&data, driver, &init_c, params.max_iter, 0.0)
                .with_build_cost(bd, bt)
                .with_checkpoints(cfg, fp, 9);
            fit.step().unwrap();
            fit.step().unwrap();
            assert!(fit.checkpoint_error().is_none());
            drop(fit);
            // Resume from disk and run to completion.
            let (snap, gen) = KMeansCheckpoint::load_any(&path).unwrap();
            assert_eq!(gen, crate::kmeans::checkpoint::Generation::Current);
            snap.validate(&params, &data, init_c.rows()).unwrap();
            assert_eq!(snap.iter, 2, "{}", alg.name());
            let (driver, bd, bt) =
                new_driver(&data, init_c.rows(), &params, &mut Workspace::new());
            let mut fit = Fit::from_driver(&data, driver, &init_c, params.max_iter, 0.0)
                .with_build_cost(bd, bt);
            fit.restore(&snap).unwrap();
            while fit.step().is_some() {}
            let resumed = fit.finish();
            assert_eq!(resumed.labels, full.labels, "{}", alg.name());
            assert_eq!(resumed.iterations, full.iterations, "{}", alg.name());
            assert_eq!(resumed.distances, full.distances, "{}", alg.name());
            assert_eq!(resumed.converged, full.converged, "{}", alg.name());
            let bits = |m: &Matrix| {
                m.as_slice().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            };
            assert_eq!(bits(&resumed.centers), bits(&full.centers), "{}", alg.name());
        }
    }

    #[test]
    fn restore_rejects_wrong_algorithm_and_shape() {
        let (data, init_c) = blobs_and_init();
        let params = KMeansParams::default();
        let fp = crate::kmeans::checkpoint::config_fingerprint(
            &params,
            &data,
            init_c.rows(),
        );
        let dir = std::env::temp_dir().join(format!(
            "covermeans_driver_ckpt_neg_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("neg.kmc");
        let (driver, bd, bt) =
            new_driver(&data, init_c.rows(), &params, &mut Workspace::new());
        let mut fit = Fit::from_driver(&data, driver, &init_c, params.max_iter, 0.0)
            .with_build_cost(bd, bt)
            .with_checkpoints(
                CheckpointConfig { path: path.clone(), every: 1, secs: 0 },
                fp,
                0,
            );
        fit.step().unwrap();
        drop(fit);
        let (snap, _) = KMeansCheckpoint::load_any(&path).unwrap();
        // Wrong algorithm: the driver refuses.
        let hp = KMeansParams::with_algorithm(Algorithm::Hamerly);
        let (driver, _, _) =
            new_driver(&data, init_c.rows(), &hp, &mut Workspace::new());
        let mut fit = Fit::from_driver(&data, driver, &init_c, hp.max_iter, 0.0);
        let err = fit.restore(&snap).unwrap_err();
        assert!(format!("{err:#}").contains("this fit drives"), "{err:#}");
        // Fingerprint validation also rejects the cross-algorithm resume.
        assert!(snap.validate(&hp, &data, init_c.rows()).is_err());
        // Restore after stepping is refused.
        let (driver, _, _) =
            new_driver(&data, init_c.rows(), &params, &mut Workspace::new());
        let mut fit = Fit::from_driver(&data, driver, &init_c, params.max_iter, 0.0);
        fit.step().unwrap();
        assert!(fit.restore(&snap).is_err());
    }

    #[test]
    fn checkpoint_write_failure_is_sticky_and_stops_the_run() {
        let (data, init_c) = blobs_and_init();
        let params = KMeansParams::default();
        let fp = crate::kmeans::checkpoint::config_fingerprint(
            &params,
            &data,
            init_c.rows(),
        );
        // A directory that does not exist: every write fails.
        let path = std::env::temp_dir()
            .join(format!("covermeans_no_such_dir_{}", std::process::id()))
            .join("nested")
            .join("x.kmc");
        let (driver, bd, bt) =
            new_driver(&data, init_c.rows(), &params, &mut Workspace::new());
        let mut fit = Fit::from_driver(&data, driver, &init_c, params.max_iter, 0.0)
            .with_build_cost(bd, bt)
            .with_checkpoints(
                CheckpointConfig { path, every: 1, secs: 0 },
                fp,
                0,
            );
        let info = fit.step().unwrap();
        assert!(info.done, "failed write must end the run at this boundary");
        assert!(fit.step().is_none());
        let err = fit.take_checkpoint_error().expect("sticky error");
        assert!(format!("{err:#}").contains("checkpoint"), "{err:#}");
    }

    #[test]
    fn max_iter_zero_runs_nothing() {
        let (data, init_c) = blobs_and_init();
        let params = KMeansParams { max_iter: 0, ..KMeansParams::default() };
        let r = run_exact(&data, &init_c, &params, &mut Workspace::new());
        assert_eq!(r.iterations, 0);
        assert_eq!(r.distances, 0);
        assert!(!r.converged);
        assert!(r.log.is_empty());
    }
}
