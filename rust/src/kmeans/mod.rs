//! The k-means algorithm family of the paper's evaluation (§4).
//!
//! All algorithms are **exact**: given the same initial centers they
//! replicate the Standard algorithm's assignment sequence (ties broken by
//! the lowest center index), differing only in how many distance
//! computations they spend. That invariant is enforced by the property
//! tests in `rust/tests/exactness.rs`.
//!
//! | variant      | module      | paper ref |
//! |--------------|-------------|-----------|
//! | Standard     | `lloyd`     | Lloyd [11] / Steinhaus [23] |
//! | Elkan        | `elkan`     | [5] |
//! | Hamerly      | `hamerly`   | [7] |
//! | Exponion     | `exponion`  | Newling & Fleuret [13] |
//! | Shallot      | `shallot`   | Borgelt [3] |
//! | Kanungo      | `kanungo`   | k-d-tree filtering [8] |
//! | Cover-means  | `cover`     | **this paper §3.1-3.3** |
//! | Hybrid       | `hybrid`    | **this paper §3.4** |

pub mod bounds;
pub mod cover;
pub mod elkan;
pub mod exponion;
pub mod hamerly;
pub mod hybrid;
pub mod init;
pub mod kanungo;
pub mod lloyd;
pub mod minibatch;
pub mod pelleg;
pub mod phillips;
pub mod shallot;

use std::time::Duration;

use crate::data::Matrix;
use crate::metrics::RunResult;
use crate::tree::{CoverTree, CoverTreeParams, KdTree, KdTreeParams};

/// Which algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    Standard,
    Elkan,
    Hamerly,
    Exponion,
    Shallot,
    Kanungo,
    CoverMeans,
    Hybrid,
    /// Phillips' compare-means [15] (related work; exact).
    Phillips,
    /// Pelleg & Moore's box-blacklisting k-d tree k-means [14] (exact).
    PellegMoore,
    /// Sculley's mini-batch k-means [22] (approximate; §1 contrast).
    MiniBatch,
}

impl Algorithm {
    /// The paper's evaluated algorithms, in the row order of Tables 2-4.
    pub const ALL: [Algorithm; 8] = [
        Algorithm::Standard,
        Algorithm::Kanungo,
        Algorithm::Elkan,
        Algorithm::Hamerly,
        Algorithm::Exponion,
        Algorithm::Shallot,
        Algorithm::CoverMeans,
        Algorithm::Hybrid,
    ];

    /// Extended family: the paper's table plus the related-work methods
    /// it discusses (§1-2) that this repo also implements.
    pub const EXTENDED: [Algorithm; 11] = [
        Algorithm::Standard,
        Algorithm::Kanungo,
        Algorithm::PellegMoore,
        Algorithm::Phillips,
        Algorithm::Elkan,
        Algorithm::Hamerly,
        Algorithm::Exponion,
        Algorithm::Shallot,
        Algorithm::CoverMeans,
        Algorithm::Hybrid,
        Algorithm::MiniBatch,
    ];

    /// Is the variant exact (replicates the Standard algorithm)?
    pub fn is_exact(&self) -> bool {
        !matches!(self, Algorithm::MiniBatch)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Standard => "Standard",
            Algorithm::Elkan => "Elkan",
            Algorithm::Hamerly => "Hamerly",
            Algorithm::Exponion => "Exponion",
            Algorithm::Shallot => "Shallot",
            Algorithm::Kanungo => "Kanungo",
            Algorithm::CoverMeans => "Cover-means",
            Algorithm::Hybrid => "Hybrid",
            Algorithm::Phillips => "Phillips",
            Algorithm::PellegMoore => "Pelleg-Moore",
            Algorithm::MiniBatch => "MiniBatch",
        }
    }

    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_ascii_lowercase().as_str() {
            "standard" | "lloyd" => Some(Algorithm::Standard),
            "elkan" => Some(Algorithm::Elkan),
            "hamerly" => Some(Algorithm::Hamerly),
            "exponion" => Some(Algorithm::Exponion),
            "shallot" => Some(Algorithm::Shallot),
            "kanungo" | "kdtree" => Some(Algorithm::Kanungo),
            "cover" | "covermeans" | "cover-means" => Some(Algorithm::CoverMeans),
            "hybrid" => Some(Algorithm::Hybrid),
            "phillips" | "compare-means" => Some(Algorithm::Phillips),
            "pelleg" | "pelleg-moore" | "pellegmoore" => Some(Algorithm::PellegMoore),
            "minibatch" | "mini-batch" => Some(Algorithm::MiniBatch),
            _ => None,
        }
    }

    /// Does this algorithm use a spatial index?
    pub fn uses_tree(&self) -> bool {
        matches!(
            self,
            Algorithm::Kanungo
                | Algorithm::CoverMeans
                | Algorithm::Hybrid
                | Algorithm::PellegMoore
        )
    }
}

/// Parameters shared by every run (paper §4 "Parameterization" defaults).
#[derive(Debug, Clone, Copy)]
pub struct KMeansParams {
    pub algorithm: Algorithm,
    /// Iteration cap (the paper runs to convergence; the cap is a guard).
    pub max_iter: usize,
    /// Cover tree construction parameters (scale 1.2, min node 100).
    pub cover: CoverTreeParams,
    /// k-d tree construction parameters for Kanungo.
    pub kd: KdTreeParams,
    /// Hybrid: switch from Cover-means to Shallot after this many
    /// iterations (paper default: 7).
    pub switch_at: usize,
}

impl Default for KMeansParams {
    fn default() -> Self {
        KMeansParams {
            algorithm: Algorithm::Standard,
            max_iter: 200,
            cover: CoverTreeParams::default(),
            kd: KdTreeParams::default(),
            switch_at: 7,
        }
    }
}

impl KMeansParams {
    pub fn with_algorithm(algorithm: Algorithm) -> Self {
        KMeansParams { algorithm, ..Default::default() }
    }
}

/// Reusable per-dataset state: the spatial indexes. The parameter-sweep
/// protocol of Table 4 amortizes tree construction across 10 restarts x 16
/// values of k by reusing one `Workspace`; Tables 3 and E6 build fresh
/// trees per run (construction cost included in the reported time).
#[derive(Default)]
pub struct Workspace {
    pub cover: Option<CoverTree>,
    pub kd: Option<KdTree>,
}

impl Workspace {
    pub fn new() -> Self {
        Workspace::default()
    }

    /// Get or build the cover tree (build cost charged only on the miss).
    pub fn cover_tree(&mut self, data: &Matrix, params: CoverTreeParams) -> &CoverTree {
        if self.cover.as_ref().map(|t| t.params != params).unwrap_or(true) {
            self.cover = Some(CoverTree::build(data, params));
        }
        self.cover.as_ref().unwrap()
    }

    /// Get or build the k-d tree.
    pub fn kd_tree(&mut self, data: &Matrix, params: KdTreeParams) -> &KdTree {
        if self.kd.as_ref().map(|t| t.params != params).unwrap_or(true) {
            self.kd = Some(KdTree::build(data, params));
        }
        self.kd.as_ref().unwrap()
    }
}

/// Run the configured algorithm from the given initial centers.
///
/// `init` must be a `k x d` matrix (use [`init::kmeans_plus_plus`]). Tree
/// construction, when required and not cached in `ws`, is charged to the
/// result's `build_time`/`build_dist`.
pub fn run(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> RunResult {
    assert!(init.rows() > 0, "need at least one initial center");
    assert_eq!(init.cols(), data.cols(), "center/data dimension mismatch");
    assert!(
        init.rows() <= data.rows(),
        "more centers than points"
    );
    match params.algorithm {
        Algorithm::Standard => lloyd::run(data, init, params),
        Algorithm::Elkan => elkan::run(data, init, params),
        Algorithm::Hamerly => hamerly::run(data, init, params),
        Algorithm::Exponion => exponion::run(data, init, params),
        Algorithm::Shallot => shallot::run(data, init, params),
        Algorithm::Kanungo => kanungo::run(data, init, params, ws),
        Algorithm::CoverMeans => cover::run(data, init, params, ws),
        Algorithm::Hybrid => hybrid::run(data, init, params, ws),
        Algorithm::Phillips => phillips::run(data, init, params),
        Algorithm::PellegMoore => pelleg::run(data, init, params, ws),
        Algorithm::MiniBatch => {
            minibatch::run(data, init, params, &minibatch::MiniBatchParams::default())
        }
    }
}

/// Convenience wrapper: k-means++ init + run, fresh workspace.
pub fn cluster(
    data: &Matrix,
    k: usize,
    seed: u64,
    params: &KMeansParams,
) -> RunResult {
    let mut counter = crate::metrics::DistCounter::new();
    let init = init::kmeans_plus_plus(data, k, seed, &mut counter);
    let mut ws = Workspace::new();
    run(data, &init, params, &mut ws)
}

/// Outcome fields shared by the per-algorithm run loops.
pub(crate) struct LoopState {
    pub labels: Vec<u32>,
    pub iterations: usize,
    pub converged: bool,
    pub log: crate::metrics::IterationLog,
    pub time: Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm_parse_roundtrip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::parse(a.name()), Some(a));
        }
        assert_eq!(Algorithm::parse("lloyd"), Some(Algorithm::Standard));
        assert!(Algorithm::parse("foo").is_none());
    }

    #[test]
    fn workspace_caches_trees() {
        let data = crate::data::synth::gaussian_blobs(200, 3, 3, 0.5, 1);
        let mut ws = Workspace::new();
        let p = CoverTreeParams::default();
        let t1 = ws.cover_tree(&data, p) as *const _;
        let t2 = ws.cover_tree(&data, p) as *const _;
        assert_eq!(t1, t2, "second call must reuse the cached tree");
        // Different params force a rebuild.
        let p2 = CoverTreeParams { scale_factor: 1.5, ..p };
        ws.cover_tree(&data, p2);
        assert_eq!(ws.cover.as_ref().unwrap().params, p2);
    }
}
