//! The k-means algorithm family of the paper's evaluation (§4), served by
//! one unified driver API.
//!
//! All exact algorithms are interchangeable per-iteration strategies under
//! a single outer loop: each implements [`KMeansDriver`]
//! (`init_state` / `iterate` / `post_update` / `finish`) and is driven by
//! the shared [`Fit`] loop, which owns convergence checking, iteration
//! logging, and center recomputation. Configure and launch runs through
//! the fluent [`KMeans`] builder:
//!
//! ```
//! # use covermeans::data::synth;
//! # use covermeans::kmeans::{Algorithm, KMeans};
//! # let data = synth::istanbul(0.002, 1);
//! let r = KMeans::new(20).algorithm(Algorithm::Hybrid).seed(7).fit(&data).unwrap();
//! ```
//!
//! Given the same initial centers every exact variant replicates the
//! Standard algorithm's assignment sequence (ties broken by the lowest
//! center index), differing only in how many distance computations it
//! spends. That invariant is enforced by the property tests in
//! `rust/tests/exactness.rs`. A second invariant rides on top: with
//! `.threads(n)` the assignment phase shards over `n` workers using
//! exactness-preserving reductions, and any thread count reproduces the
//! sequential fit byte for byte (`rust/tests/parallel_exactness.rs`).
//!
//! | variant      | driver in   | paper ref |
//! |--------------|-------------|-----------|
//! | Standard     | `lloyd`     | Lloyd [11] / Steinhaus [23] |
//! | Elkan        | `elkan`     | [5] |
//! | Hamerly      | `hamerly`   | [7] |
//! | Exponion     | `exponion`  | Newling & Fleuret [13] |
//! | Shallot      | `shallot`   | Borgelt [3] |
//! | Kanungo      | `kanungo`   | k-d-tree filtering [8] |
//! | Pelleg-Moore | `pelleg`    | blacklisting k-d tree [14] |
//! | Phillips     | `phillips`  | compare-means [15] |
//! | Cover-means  | `cover`     | **this paper §3.1-3.3** |
//! | Hybrid       | `hybrid`    | **this paper §3.4** |
//! | Dual-tree    | `dualtree`  | Curtin's dual-tree k-means (arXiv:1601.03754) |
//! | MiniBatch    | `minibatch` | Sculley [22] (approximate; no driver) |
//!
//! The free functions [`run`] and [`cluster`] and the flat
//! [`KMeansParams`] struct are kept as thin shims over the driver loop so
//! existing callers and the exactness suite pin behavior across the
//! refactor; new code should prefer the builder.
//!
//! A fit no longer dead-ends at [`RunResult`]: [`KMeans::fit_model`]
//! captures the trained centers (plus per-cluster stats and provenance)
//! as a [`KMeansModel`] — persistable via a versioned binary format and
//! able to answer batch out-of-sample `predict` queries through a cover
//! tree built over the centers (see the [`model`] module).

pub mod bounds;
pub mod builder;
pub mod checkpoint;
pub mod cover;
pub mod driver;
pub mod dualtree;
pub mod elkan;
pub mod exponion;
pub mod hamerly;
pub mod hybrid;
pub mod init;
pub mod kanungo;
pub(crate) mod kdfilter;
pub mod lloyd;
pub mod minibatch;
pub mod model;
pub mod pelleg;
pub mod phillips;
pub mod shallot;

use std::sync::Arc;

use crate::data::Matrix;
use crate::metrics::RunResult;
use crate::parallel::Parallelism;
use crate::tree::{CoverTree, CoverTreeParams, KdTree, KdTreeParams};

pub use builder::{AlgorithmSpec, InitKind, KMeans, KMeansError};
pub use checkpoint::{CheckpointConfig, Generation, KMeansCheckpoint};
pub use driver::{DriverState, Fit, KMeansDriver, Observer, Signal, StepInfo, StepView};
pub use minibatch::MiniBatchParams;
pub use model::{
    KMeansModel, PredictMode, PredictOptions, PredictPrecision, Prediction,
    DEFAULT_PREDICT_AUTO_K,
};

/// Which algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    Standard,
    Elkan,
    Hamerly,
    Exponion,
    Shallot,
    Kanungo,
    CoverMeans,
    Hybrid,
    /// Phillips' compare-means [15] (related work; exact).
    Phillips,
    /// Pelleg & Moore's box-blacklisting k-d tree k-means [14] (exact).
    PellegMoore,
    /// Dual-tree k-means after Curtin (arXiv:1601.03754): simultaneous
    /// traversal of the point cover tree and a per-iteration cover tree
    /// over the centers, pruning per node *pair* (exact).
    DualTree,
    /// Sculley's mini-batch k-means [22] (approximate; §1 contrast).
    MiniBatch,
}

impl Algorithm {
    /// The paper's evaluated algorithms, in the row order of Tables 2-4.
    pub const ALL: [Algorithm; 8] = [
        Algorithm::Standard,
        Algorithm::Kanungo,
        Algorithm::Elkan,
        Algorithm::Hamerly,
        Algorithm::Exponion,
        Algorithm::Shallot,
        Algorithm::CoverMeans,
        Algorithm::Hybrid,
    ];

    /// Extended family: the paper's table plus the related-work methods
    /// it discusses (§1-2) that this repo also implements.
    pub const EXTENDED: [Algorithm; 12] = [
        Algorithm::Standard,
        Algorithm::Kanungo,
        Algorithm::PellegMoore,
        Algorithm::Phillips,
        Algorithm::Elkan,
        Algorithm::Hamerly,
        Algorithm::Exponion,
        Algorithm::Shallot,
        Algorithm::CoverMeans,
        Algorithm::Hybrid,
        Algorithm::DualTree,
        Algorithm::MiniBatch,
    ];

    /// Is the variant exact (replicates the Standard algorithm)?
    pub fn is_exact(&self) -> bool {
        !matches!(self, Algorithm::MiniBatch)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Standard => "Standard",
            Algorithm::Elkan => "Elkan",
            Algorithm::Hamerly => "Hamerly",
            Algorithm::Exponion => "Exponion",
            Algorithm::Shallot => "Shallot",
            Algorithm::Kanungo => "Kanungo",
            Algorithm::CoverMeans => "Cover-means",
            Algorithm::Hybrid => "Hybrid",
            Algorithm::Phillips => "Phillips",
            Algorithm::PellegMoore => "Pelleg-Moore",
            Algorithm::DualTree => "Dual-tree",
            Algorithm::MiniBatch => "MiniBatch",
        }
    }

    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_ascii_lowercase().as_str() {
            "standard" | "lloyd" => Some(Algorithm::Standard),
            "elkan" => Some(Algorithm::Elkan),
            "hamerly" => Some(Algorithm::Hamerly),
            "exponion" => Some(Algorithm::Exponion),
            "shallot" => Some(Algorithm::Shallot),
            "kanungo" | "kdtree" => Some(Algorithm::Kanungo),
            "cover" | "covermeans" | "cover-means" => Some(Algorithm::CoverMeans),
            "hybrid" => Some(Algorithm::Hybrid),
            "phillips" | "compare-means" => Some(Algorithm::Phillips),
            "pelleg" | "pelleg-moore" | "pellegmoore" => Some(Algorithm::PellegMoore),
            "dual-tree" | "dualtree" | "dual" => Some(Algorithm::DualTree),
            "minibatch" | "mini-batch" => Some(Algorithm::MiniBatch),
            _ => None,
        }
    }

    /// Can the variant fit a non-resident (mmap/chunked) data source?
    /// The per-point streaming drivers visit the data block by block;
    /// the tree family (and the per-point variants that keep whole-matrix
    /// random access) need the data resident to build or probe their
    /// state, and the builder rejects streamed input for them with
    /// [`KMeansError::StreamedUnsupported`].
    pub fn streams(&self) -> bool {
        matches!(
            self,
            Algorithm::Standard
                | Algorithm::Elkan
                | Algorithm::Hamerly
                | Algorithm::MiniBatch
        )
    }

    /// Does this algorithm use a spatial index?
    pub fn uses_tree(&self) -> bool {
        matches!(
            self,
            Algorithm::Kanungo
                | Algorithm::CoverMeans
                | Algorithm::Hybrid
                | Algorithm::PellegMoore
                | Algorithm::DualTree
        )
    }
}

/// Flat run parameters (paper §4 "Parameterization" defaults) — the legacy
/// configuration surface, kept for the shims and the coordinator's config
/// files. New code should configure through [`KMeans`] / [`AlgorithmSpec`],
/// which fold down to this struct internally.
#[derive(Debug, Clone, Copy)]
pub struct KMeansParams {
    pub algorithm: Algorithm,
    /// Iteration cap (the paper runs to convergence; the cap is a guard).
    pub max_iter: usize,
    /// Convergence tolerance on the largest per-center movement. 0 keeps
    /// the paper's exact assignment-fixpoint criterion (the default).
    pub tol: f64,
    /// Cover tree construction parameters (scale 1.2, min node 100).
    pub cover: CoverTreeParams,
    /// k-d tree construction parameters for Kanungo.
    pub kd: KdTreeParams,
    /// Hybrid: switch from Cover-means to Shallot after this many
    /// iterations (paper default: 7).
    pub switch_at: usize,
    /// Mini-batch knobs (consumed only by [`Algorithm::MiniBatch`]).
    pub minibatch: MiniBatchParams,
    /// Intra-fit worker threads for the assignment phase and tree
    /// construction (config key `fit_threads`; 0 = all cores), served by
    /// one persistent worker pool per fit (shared across fits when a
    /// [`Workspace`] is reused). The reductions are exactness-preserving —
    /// any thread count reproduces the sequential run byte for byte (same
    /// assignments, same counted distances) — so 1 (the default) keeps the
    /// paper's single-core measurement protocol without changing any
    /// result. Every runner honors the knob: the per-point drivers, the
    /// tree drivers (Cover-means, Hybrid, Kanungo, Pelleg-Moore),
    /// MiniBatch, and k-means++ seeding.
    pub threads: usize,
    /// Pin each pool worker to its own core at spawn (config key
    /// `pin_workers`; Linux `sched_setaffinity`, a no-op elsewhere).
    /// Placement only — results are byte-identical either way; see
    /// [`crate::parallel::pin_current_thread`].
    pub pin_workers: bool,
    /// Write a crash-safe checkpoint every N iterations (config key
    /// `checkpoint_every`; 0 = no periodic trigger). Requires a
    /// checkpoint path (config key `checkpoint_path`, routed separately —
    /// this struct stays `Copy`).
    pub checkpoint_every: usize,
    /// Also checkpoint when this many seconds elapsed since the last
    /// snapshot (config key `checkpoint_secs`; 0 = no time trigger).
    pub checkpoint_secs: u64,
}

impl Default for KMeansParams {
    fn default() -> Self {
        KMeansParams {
            algorithm: Algorithm::Standard,
            max_iter: 200,
            tol: 0.0,
            cover: CoverTreeParams::default(),
            kd: KdTreeParams::default(),
            switch_at: 7,
            minibatch: MiniBatchParams::default(),
            threads: 1,
            pin_workers: false,
            checkpoint_every: 0,
            checkpoint_secs: 0,
        }
    }
}

impl KMeansParams {
    pub fn with_algorithm(algorithm: Algorithm) -> Self {
        KMeansParams { algorithm, ..Default::default() }
    }
}

/// Identity of the matrix a cached tree was built over: buffer address,
/// shape, and a sampled content fingerprint. The fingerprint closes the
/// allocator-reuse (ABA) hole: a same-shape matrix built after the cached
/// one was dropped can land on the same address, but its values hash
/// differently, so the cache rebuilds instead of serving a stale tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct DataKey {
    ptr: usize,
    rows: usize,
    cols: usize,
    fingerprint: u64,
}

impl DataKey {
    fn of(data: &Matrix) -> DataKey {
        let buf = data.as_slice();
        // FNV-1a over up to 1024 evenly-spaced elements: small matrices
        // are hashed in full; large ones are sampled across the whole
        // buffer (~8 KiB of hashing, negligible next to one assignment
        // pass). A stale hit then needs allocator address reuse AND the
        // same shape AND the same params AND agreement at every sampled
        // position — a full-buffer hash would close even that sliver but
        // costs O(nd) per cache probe, defeating the amortization the
        // workspace exists for.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let step = (buf.len() / 1024).max(1);
        for &v in buf.iter().step_by(step) {
            for b in v.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        }
        DataKey {
            ptr: buf.as_ptr() as usize,
            rows: data.rows(),
            cols: data.cols(),
            fingerprint: h,
        }
    }
}

/// Reusable per-dataset state: the spatial indexes and the worker pool.
/// The parameter-sweep protocol of Table 4 amortizes tree construction
/// across 10 restarts x 16 values of k by reusing one `Workspace`; Tables
/// 3 and E6 build fresh trees per run (construction cost included in the
/// reported time).
///
/// The tree cache is keyed on *(data identity, construction params)*:
/// calling with a different matrix — or the same matrix after reallocation
/// — or different params rebuilds instead of silently serving a stale
/// tree. Trees are stored behind [`Arc`] so stepwise [`Fit`] handles can
/// hold the index while the workspace moves on to the next run.
///
/// The pool cache ([`Workspace::parallelism`]) is keyed on the resolved
/// thread count only — the pool carries no per-fit state, so one pool
/// serves every fit a workspace drives (the coordinator keeps one per
/// cell via [`Workspace::clear_trees`]). Thread count is not part of any
/// result: the parallel reductions are exactness-preserving.
#[derive(Default)]
pub struct Workspace {
    cover: Option<(DataKey, Arc<CoverTree>)>,
    kd: Option<(DataKey, Arc<KdTree>)>,
    par: Option<Parallelism>,
}

impl Workspace {
    pub fn new() -> Self {
        Workspace::default()
    }

    /// The workspace's persistent worker pool for `threads` (0 = all
    /// cores), created on first use and reused across fits. Requesting a
    /// different resolved thread count replaces the pool.
    pub fn parallelism(&mut self, threads: usize) -> Parallelism {
        self.parallelism_opts(threads, false)
    }

    /// [`Workspace::parallelism`] with opt-in worker-core pinning
    /// ([`KMeansParams::pin_workers`]). Pinning is part of the cache key:
    /// asking for a pinned pool after an unpinned one (or vice versa)
    /// respawns the workers with the new placement.
    pub fn parallelism_opts(&mut self, threads: usize, pin: bool) -> Parallelism {
        let resolved = crate::parallel::resolve_threads(threads);
        if let Some(p) = &self.par {
            if p.threads() == resolved && p.pinned() == pin {
                return p.clone();
            }
        }
        let p = Parallelism::new_opts(threads, pin);
        self.par = Some(p.clone());
        p
    }

    /// Drop the cached spatial indexes but keep the worker pool — the
    /// fresh-tree-per-run protocol of Tables 2-3 under a per-cell pool.
    pub fn clear_trees(&mut self) {
        self.cover = None;
        self.kd = None;
    }

    /// Get or build the cover tree (build cost charged only on the miss).
    pub fn cover_tree(&mut self, data: &Matrix, params: CoverTreeParams) -> &CoverTree {
        self.cover_tree_arc(data, params);
        &self.cover.as_ref().unwrap().1
    }

    /// Get or build the k-d tree.
    pub fn kd_tree(&mut self, data: &Matrix, params: KdTreeParams) -> &KdTree {
        self.kd_tree_arc(data, params);
        &self.kd.as_ref().unwrap().1
    }

    /// Shared-ownership variant; the `bool` reports whether this call
    /// built the tree (`true` = fresh, charge the build cost).
    pub fn cover_tree_arc(
        &mut self,
        data: &Matrix,
        params: CoverTreeParams,
    ) -> (Arc<CoverTree>, bool) {
        self.cover_tree_arc_threads(data, params, 1)
    }

    /// Like [`Workspace::cover_tree_arc`], building any fresh tree with
    /// `threads` workers (drawn from the workspace's pool). The thread
    /// count is *not* part of the cache key: parallel construction yields
    /// a byte-identical tree (structure, aggregates, and counted build
    /// distances), so a tree built with any thread count serves every
    /// caller.
    pub fn cover_tree_arc_threads(
        &mut self,
        data: &Matrix,
        params: CoverTreeParams,
        threads: usize,
    ) -> (Arc<CoverTree>, bool) {
        let par = self.parallelism(threads);
        self.cover_tree_arc_par(data, params, &par)
    }

    /// [`Workspace::cover_tree_arc_threads`] with an explicit (pooled)
    /// thread budget.
    pub fn cover_tree_arc_par(
        &mut self,
        data: &Matrix,
        params: CoverTreeParams,
        par: &Parallelism,
    ) -> (Arc<CoverTree>, bool) {
        let key = DataKey::of(data);
        let stale = match &self.cover {
            Some((k, t)) => *k != key || t.params != params,
            None => true,
        };
        if stale {
            self.cover = Some((
                key,
                Arc::new(CoverTree::build_with_parallelism(data, params, par)),
            ));
        }
        (self.cover.as_ref().unwrap().1.clone(), stale)
    }

    pub fn kd_tree_arc(
        &mut self,
        data: &Matrix,
        params: KdTreeParams,
    ) -> (Arc<KdTree>, bool) {
        let key = DataKey::of(data);
        let stale = match &self.kd {
            Some((k, t)) => *k != key || t.params != params,
            None => true,
        };
        if stale {
            self.kd = Some((key, Arc::new(KdTree::build(data, params))));
        }
        (self.kd.as_ref().unwrap().1.clone(), stale)
    }

    /// The cached cover tree, if any (inspection/tests).
    pub fn cached_cover(&self) -> Option<&CoverTree> {
        self.cover.as_ref().map(|(_, t)| t.as_ref())
    }

    /// The cached k-d tree, if any (inspection/tests).
    pub fn cached_kd(&self) -> Option<&KdTree> {
        self.kd.as_ref().map(|(_, t)| t.as_ref())
    }
}

/// Run the configured algorithm from the given initial centers.
///
/// Legacy shim over the [`KMeansDriver`] loop (and the mini-batch runner
/// for [`Algorithm::MiniBatch`], honoring `params.minibatch`). `init` must
/// be a `k x d` matrix (use [`init::kmeans_plus_plus`]). Tree
/// construction, when required and not cached in `ws`, is charged to the
/// result's `build_time`/`build_dist`. New code should prefer [`KMeans`].
pub fn run(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> RunResult {
    assert!(init.rows() > 0, "need at least one initial center");
    assert_eq!(init.cols(), data.cols(), "center/data dimension mismatch");
    assert!(
        init.rows() <= data.rows(),
        "more centers than points"
    );
    if params.algorithm == Algorithm::MiniBatch {
        let par = ws.parallelism_opts(params.threads, params.pin_workers);
        return minibatch::run_par(data, init, params, &params.minibatch, &par);
    }
    driver::run_exact(data, init, params, ws)
}

/// Convenience wrapper: k-means++ init + run, fresh workspace. Legacy
/// shim; equivalent to `KMeans::new(k).algorithm(...).seed(seed).fit(data)`.
pub fn cluster(
    data: &Matrix,
    k: usize,
    seed: u64,
    params: &KMeansParams,
) -> RunResult {
    let mut counter = crate::metrics::DistCounter::new();
    let init = init::kmeans_plus_plus(data, k, seed, &mut counter);
    let mut ws = Workspace::new();
    run(data, &init, params, &mut ws)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm_parse_roundtrip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::parse(a.name()), Some(a));
        }
        assert_eq!(Algorithm::parse("lloyd"), Some(Algorithm::Standard));
        assert!(Algorithm::parse("foo").is_none());
    }

    #[test]
    fn algorithm_parse_roundtrip_extended() {
        // Display names must parse back for the whole extended family —
        // including the hyphenated "Pelleg-Moore" and camel "MiniBatch".
        for a in Algorithm::EXTENDED {
            assert_eq!(Algorithm::parse(a.name()), Some(a), "{}", a.name());
            assert_eq!(
                Algorithm::parse(&a.name().to_ascii_uppercase()),
                Some(a),
                "case-insensitive {}",
                a.name()
            );
        }
    }

    #[test]
    fn workspace_caches_trees() {
        let data = crate::data::synth::gaussian_blobs(200, 3, 3, 0.5, 1);
        let mut ws = Workspace::new();
        let p = CoverTreeParams::default();
        let t1 = ws.cover_tree(&data, p) as *const _;
        let t2 = ws.cover_tree(&data, p) as *const _;
        assert_eq!(t1, t2, "second call must reuse the cached tree");
        // Different params force a rebuild.
        let p2 = CoverTreeParams { scale_factor: 1.5, ..p };
        ws.cover_tree(&data, p2);
        assert_eq!(ws.cached_cover().unwrap().params, p2);
    }

    #[test]
    fn workspace_rebuilds_for_different_data() {
        // Regression: the cache used to be keyed on params only, so a
        // second dataset silently got the first dataset's tree.
        let data1 = crate::data::synth::gaussian_blobs(200, 3, 3, 0.5, 1);
        let data2 = crate::data::synth::gaussian_blobs(300, 3, 3, 0.5, 2);
        let mut ws = Workspace::new();
        let p = CoverTreeParams::default();
        let (t1, fresh1) = ws.cover_tree_arc(&data1, p);
        assert!(fresh1);
        let (t2, fresh2) = ws.cover_tree_arc(&data2, p);
        assert!(fresh2, "same params, different data must rebuild");
        assert!(!Arc::ptr_eq(&t1, &t2));
        assert_eq!(t2.root.weight as usize, data2.rows());

        let (k1, fresh_k1) = ws.kd_tree_arc(&data1, KdTreeParams::default());
        assert!(fresh_k1);
        let (k2, fresh_k2) = ws.kd_tree_arc(&data2, KdTreeParams::default());
        assert!(fresh_k2, "kd cache must also key on data");
        assert!(!Arc::ptr_eq(&k1, &k2));

        // And a run on the second dataset after caching the first must be
        // exact (this panicked on out-of-range point ids before the fix).
        let mut dc = crate::metrics::DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data2, 3, 4, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::CoverMeans);
        let r_cover = run(&data2, &init_c, &params, &mut ws);
        let r_std = run(
            &data2,
            &init_c,
            &KMeansParams::default(),
            &mut Workspace::new(),
        );
        assert_eq!(r_cover.labels, r_std.labels);
    }

    #[test]
    fn run_routes_minibatch_params() {
        let data = crate::data::synth::gaussian_blobs(300, 2, 3, 0.4, 3);
        let mut dc = crate::metrics::DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 3, 5, &mut dc);
        let mut params = KMeansParams::with_algorithm(Algorithm::MiniBatch);
        params.max_iter = 10;
        params.minibatch = MiniBatchParams { batch: 2, tol: 1e-12, seed: 1 };
        let tiny = run(&data, &init_c, &params, &mut Workspace::new());
        params.minibatch = MiniBatchParams::default();
        let dflt = run(&data, &init_c, &params, &mut Workspace::new());
        assert!(
            tiny.distances < dflt.distances,
            "caller-tuned mini-batch settings must reach the runner"
        );
    }
}
