//! The serving layer: a persistable trained model with batch prediction.
//!
//! A fit used to dead-end at [`RunResult`] — labels and centers for the
//! training set, nothing for out-of-sample points and nothing to put on
//! disk. [`KMeansModel`] closes that gap: it captures everything a serving
//! process needs (centers, per-cluster sizes and inertia, algorithm/seed
//! provenance), round-trips through a small self-describing binary format
//! (`.kmm`), and answers batch nearest-center queries through the paper's
//! own index — a cover tree built **over the centers** — with an
//! Elkan-style pruned scan as the small-`k` fallback where tree overhead
//! loses (see [`PredictMode`]).
//!
//! ```
//! use covermeans::data::synth;
//! use covermeans::kmeans::{Algorithm, KMeans, KMeansModel};
//!
//! let data = synth::gaussian_blobs(200, 3, 4, 0.5, 1);
//! let model = KMeans::new(4)
//!     .algorithm(Algorithm::Hybrid)
//!     .seed(7)
//!     .fit_model(&data)
//!     .unwrap();
//! let labels = model.predict(&data);
//!
//! let path = std::env::temp_dir().join("covermeans_model_doc.kmm");
//! model.save(&path).unwrap();
//! let served = KMeansModel::load(&path).unwrap();
//! assert_eq!(served.predict(&data), labels);
//! # std::fs::remove_file(&path).ok();
//! ```
//!
//! **Determinism.** Prediction shards query rows over the same persistent
//! worker pool the fit uses ([`crate::parallel::Parallelism`]); each query
//! is independent, per-chunk distance tallies fold back as integer sums,
//! and the serving indexes are built sequentially once — so `threads = N`
//! reproduces `threads = 1` byte for byte, the same contract every other
//! parallel pass in this crate honors. Labels are additionally guaranteed
//! to match a naive lowest-index nearest-center scan label for label, at
//! every thread count and in every [`PredictMode`]
//! (`rust/tests/model.rs`, `rust/tests/parallel_exactness.rs`).
//!
//! **f32 serving.** [`PredictPrecision::F32`] (config key
//! `predict_precision`) scans a quantized single-precision copy of the
//! centers with the f32 SIMD kernel and *certifies* each answer against a
//! rigorous error bound, falling back to the f64 scan for the (rare)
//! queries the bound cannot separate — so even the fast path returns
//! labels and distances bit-identical to f64 mode (see the `F32Index`
//! internals for the proof sketch and `rust/tests/kernels.rs` for the
//! property tests).

use std::path::Path;
use std::sync::{Arc, OnceLock};

use anyhow::{bail, Context, Result};

use crate::data::io::{bin, fnv1a};
use crate::data::Matrix;
use crate::kmeans::bounds::{nearest_two, InterCenter};
use crate::kmeans::Algorithm;
use crate::metrics::{DistCounter, RunResult};
use crate::parallel::{Parallelism, SharedSlices};
use crate::tree::{search, CoverTree, CoverTreeParams};

/// `.kmm` file magic.
const MAGIC: &[u8; 4] = b"CMKM";
/// Current `.kmm` format version.
const FORMAT_VERSION: u32 = 1;

/// Default `k` at or above which [`PredictMode::Auto`] resolves to the
/// cover tree: the center tree's per-query descent overhead (child
/// ordering, recursion) only pays off once the scan's `O(k)` per query
/// dominates. The `bench_smoke` harness measures the actual crossover
/// (`BENCH_5.json`); callers whose hardware crosses elsewhere override it
/// per call ([`PredictOptions::auto_k`],
/// [`KMeansModel::predict_par_with`]) or via the `predict_auto_k` config
/// key (`covermeans predict` / `covermeans serve`).
pub const DEFAULT_PREDICT_AUTO_K: usize = 64;

/// Cover tree construction parameters for the *centers* index. Centers
/// matrices are tiny next to datasets, so the node floor is far below the
/// paper's data-side default of 100 — with that default, any `k < 100`
/// would collapse into one leaf and degenerate to a linear scan.
const CENTER_TREE_PARAMS: CoverTreeParams =
    CoverTreeParams { scale_factor: 1.2, min_node_size: 8 };

/// How [`KMeansModel::predict_opts`] answers nearest-center queries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictMode {
    /// Pick per model: the cover tree for `k >= auto_k` (default
    /// [`DEFAULT_PREDICT_AUTO_K`]), the pruned scan below (the small-`k`
    /// regime where tree overhead loses).
    Auto,
    /// 1-NN descent of a cover tree built over the centers
    /// ([`crate::tree::nearest`]), reusing the node radii and parent
    /// distances for pruning.
    Tree,
    /// Elkan-style pruned linear scan: center `j` is skipped whenever
    /// `d(c_best, c_j) >= 2 * d(x, c_best)` (triangle inequality over the
    /// cached inter-center matrix), so it cannot strictly beat the
    /// incumbent.
    Scan,
}

impl PredictMode {
    pub fn name(&self) -> &'static str {
        match self {
            PredictMode::Auto => "auto",
            PredictMode::Tree => "tree",
            PredictMode::Scan => "scan",
        }
    }

    pub fn parse(s: &str) -> Option<PredictMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(PredictMode::Auto),
            "tree" | "cover" => Some(PredictMode::Tree),
            "scan" | "pruned" | "elkan" => Some(PredictMode::Scan),
            _ => None,
        }
    }
}

/// Arithmetic the serving scan runs in (config key `predict_precision`).
///
/// [`PredictPrecision::F64`] is the default: every distance in full
/// doubles, the same arithmetic the fit used. [`PredictPrecision::F32`]
/// keeps a quantized single-precision copy of the centers and scans it
/// with the f32 SIMD kernel (twice the lanes per vector register, half
/// the memory traffic) — but never at the cost of the answer: a query is
/// accepted from the f32 scan only when a rigorous error bound proves the
/// f32 winner is the true f64 nearest center, and falls back to the full
/// f64 scan otherwise (see [`KMeansModel`]'s f32 quality contract). The
/// reported labels and distances are therefore **identical** to f64 mode
/// at every thread count; only throughput and [`Prediction::f32_fallbacks`]
/// differ.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictPrecision {
    /// Full double-precision scan (default).
    F64,
    /// Quantized single-precision scan with certified f64 fallback.
    F32,
}

impl PredictPrecision {
    pub fn name(&self) -> &'static str {
        match self {
            PredictPrecision::F64 => "f64",
            PredictPrecision::F32 => "f32",
        }
    }

    pub fn parse(s: &str) -> Option<PredictPrecision> {
        match s.to_ascii_lowercase().as_str() {
            "f64" | "double" => Some(PredictPrecision::F64),
            "f32" | "single" | "float" => Some(PredictPrecision::F32),
            _ => None,
        }
    }
}

/// Batch-predict configuration: the query-answering strategy, the
/// [`PredictMode::Auto`] tree/scan cutoff, and the worker-thread budget
/// (0 = all cores; any value reproduces the single-threaded labels byte
/// for byte).
#[derive(Debug, Clone, Copy)]
pub struct PredictOptions {
    pub mode: PredictMode,
    /// `k` at or above which [`PredictMode::Auto`] picks the cover tree
    /// (config key `predict_auto_k`; default [`DEFAULT_PREDICT_AUTO_K`]).
    pub auto_k: usize,
    pub threads: usize,
    /// Scan arithmetic (config key `predict_precision`; default f64).
    pub precision: PredictPrecision,
}

impl Default for PredictOptions {
    fn default() -> Self {
        PredictOptions {
            mode: PredictMode::Auto,
            auto_k: DEFAULT_PREDICT_AUTO_K,
            threads: 1,
            precision: PredictPrecision::F64,
        }
    }
}

/// Outcome of one batch predict, with the counted-distance accounting the
/// repo's evaluation protocol uses everywhere else: `query_evals` is what
/// the strategy spent answering, `prep_evals` what this call spent
/// building a serving index (0 once the model's lazy index cache is warm),
/// mirroring the `distances` / `build_dist` split of [`RunResult`].
#[derive(Debug, Clone)]
pub struct Prediction {
    /// Nearest-center index per query row.
    pub labels: Vec<u32>,
    /// Distance to that center per query row.
    pub distances: Vec<f64>,
    /// Distance evaluations spent answering the queries (a naive scan
    /// spends exactly `n * k`).
    pub query_evals: u64,
    /// Distance evaluations spent building the serving index in this call.
    pub prep_evals: u64,
    /// The strategy that actually ran ([`PredictMode::Auto`] resolved).
    /// Under [`PredictPrecision::F32`] this is always [`PredictMode::Scan`]:
    /// the f32 path scans the flat quantized buffer regardless of the
    /// requested mode (a tree over rounded centers would need its own
    /// radii-correctness argument for no measured win).
    pub mode: PredictMode,
    /// The arithmetic that ran the scan.
    pub precision: PredictPrecision,
    /// Queries the f32 scan could not certify and re-answered with the
    /// full f64 scan (always 0 under [`PredictPrecision::F64`]).
    pub f32_fallbacks: u64,
}

/// A trained k-means model: the artifact `fit` hands to serving.
///
/// Produced by [`crate::kmeans::KMeans::fit_model`] (or
/// [`KMeansModel::from_run`] for an existing [`RunResult`]); persisted
/// with [`KMeansModel::save`] / [`KMeansModel::load`]; queried with
/// [`KMeansModel::predict`] and friends. The serving indexes (center
/// cover tree, inter-center matrix) are built lazily on first use and
/// cached — they are *not* persisted, so a loaded model rebuilds them on
/// its first predict (charged to [`Prediction::prep_evals`]).
#[derive(Debug, Clone)]
pub struct KMeansModel {
    centers: Matrix,
    counts: Vec<u64>,
    cluster_sse: Vec<f64>,
    algorithm: Algorithm,
    seed: u64,
    iterations: u64,
    converged: bool,
    center_tree: OnceLock<Arc<CoverTree>>,
    inter_center: OnceLock<Arc<InterCenter>>,
    f32_index: OnceLock<Arc<F32Index>>,
    /// Lazily computed `.kmm` checksum (the serving layer's model version
    /// tag); [`KMeansModel::from_bytes`] seeds it with the verified value.
    checksum: OnceLock<u64>,
}

/// The f32 serving index: a quantized copy of the centers plus the two
/// constants the acceptance test needs.
///
/// **Quality contract.** Let `c32_j` be center `j` rounded to f32 (read
/// back as f64), `r_j` the f32-computed distance from the quantized query
/// `q32` to `c32_j` (lifted to f64), `qx = d(q, q32)` the query's own
/// quantization displacement, and `qmax = max_j d(c_j, c32_j)` the worst
/// center displacement. The f32 accumulation's relative error is bounded
/// by `gamma = (d + 8) * eps_f32` (a standard forward bound: `d - 1`
/// additions plus the subtract/multiply rounding per lane, with slack for
/// the reduction tree and the final sqrt), so with `m = qx + qmax` the
/// true distance satisfies, by the triangle inequality,
///
/// ```text
/// r_j * (1 - gamma) - m  <=  d(q, c_j)  <=  r_j * (1 + gamma) + m
/// ```
///
/// If the f32 runner-up's lower bound strictly exceeds the f32 winner's
/// upper bound, every other center's true distance strictly exceeds the
/// winner's (the runner-up has the second-smallest `r_j`), so the winner
/// is the unique true nearest — the f64 scan, lowest-index ties and all,
/// would return exactly it. Otherwise the query falls back to the full
/// f64 scan. Accepted winners get their reported distance recomputed in
/// f64, so outputs are bit-identical to f64 mode either way.
#[derive(Debug)]
struct F32Index {
    /// Quantized centers, row-major `k x d`.
    centers: Vec<f32>,
    /// `max_j d(c_j, c32_j)`: worst-case center quantization displacement.
    qmax: f64,
    /// Relative error bound of one f32 squared-distance accumulation.
    gamma: f64,
}

impl F32Index {
    fn build(centers: &Matrix) -> F32Index {
        let (k, d) = (centers.rows(), centers.cols());
        let mut c32 = Vec::with_capacity(k * d);
        for &v in centers.as_slice() {
            c32.push(v as f32);
        }
        let mut qmax = 0.0f64;
        let mut back = vec![0.0f64; d];
        for j in 0..k {
            for (t, &v) in back.iter_mut().zip(&c32[j * d..(j + 1) * d]) {
                *t = v as f64;
            }
            qmax = qmax.max(crate::kernels::dist(centers.row(j), &back));
        }
        let gamma = (d as f64 + 8.0) * (f32::EPSILON as f64);
        F32Index { centers: c32, qmax, gamma }
    }
}

impl KMeansModel {
    /// Capture a finished run as a servable model. `data` must be the
    /// matrix the run was fit on (per-cluster counts and inertia are
    /// derived from its labels); `algorithm` and `seed` record provenance.
    pub fn from_run(
        data: &Matrix,
        run: &RunResult,
        algorithm: Algorithm,
        seed: u64,
    ) -> KMeansModel {
        KMeansModel::from_run_src(data.into(), run, algorithm, seed)
    }

    /// [`KMeansModel::from_run`] over any data source backend. The
    /// per-cluster statistics accumulate in one sequential canonical-order
    /// pass, so the model — and its persisted `.kmm` bytes — is identical
    /// whether the fit's data was in RAM, mmapped, or chunk-streamed.
    pub fn from_run_src(
        src: crate::data::SourceView<'_>,
        run: &RunResult,
        algorithm: Algorithm,
        seed: u64,
    ) -> KMeansModel {
        assert_eq!(
            src.rows(),
            run.labels.len(),
            "data/labels length mismatch: the run was not fit on this matrix"
        );
        assert_eq!(src.cols(), run.centers.cols(), "data/centers dimension mismatch");
        let k = run.centers.rows();
        let cols = src.cols();
        let mut counts = vec![0u64; k];
        let mut cluster_sse = vec![0.0f64; k];
        src.visit(0..run.labels.len(), |start, block| {
            for (off, p) in block.chunks_exact(cols).enumerate() {
                let l = run.labels[start + off] as usize;
                counts[l] += 1;
                cluster_sse[l] += crate::kernels::sqdist(p, run.centers.row(l));
            }
        });
        KMeansModel {
            centers: run.centers.clone(),
            counts,
            cluster_sse,
            algorithm,
            seed,
            iterations: run.iterations as u64,
            converged: run.converged,
            center_tree: OnceLock::new(),
            inter_center: OnceLock::new(),
            f32_index: OnceLock::new(),
            checksum: OnceLock::new(),
        }
    }

    /// Number of clusters.
    pub fn k(&self) -> usize {
        self.centers.rows()
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.centers.cols()
    }

    /// The cluster centers (`k x d`).
    pub fn centers(&self) -> &Matrix {
        &self.centers
    }

    /// Training-set points per cluster.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Training-set sum of squared errors per cluster.
    pub fn cluster_sse(&self) -> &[f64] {
        &self.cluster_sse
    }

    /// Total training-set inertia (sum of [`KMeansModel::cluster_sse`]).
    pub fn inertia(&self) -> f64 {
        self.cluster_sse.iter().sum()
    }

    /// The algorithm that produced the model.
    pub fn algorithm(&self) -> Algorithm {
        self.algorithm
    }

    /// The seeding seed the fit was configured with.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Iterations the fit ran.
    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    /// Whether the fit reached its convergence criterion.
    pub fn converged(&self) -> bool {
        self.converged
    }

    /// The FNV-1a checksum of the model's `.kmm` serialization — the same
    /// value [`KMeansModel::to_bytes`] appends as the trailing 8 bytes and
    /// [`KMeansModel::from_bytes`] verifies. Two models with the same
    /// checksum serve identical predictions, so the serving daemon uses it
    /// as the model **version tag** carried on every reply. Computed once
    /// and cached (loaded models reuse the verified on-disk value).
    pub fn checksum(&self) -> u64 {
        *self.checksum.get_or_init(|| {
            let bytes = self.to_bytes();
            u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap())
        })
    }

    // ----- prediction ---------------------------------------------------

    /// Nearest-center label per query row (defaults: [`PredictMode::Auto`],
    /// single-threaded). Panics if `data`'s dimensionality differs from
    /// the model's.
    pub fn predict(&self, data: &Matrix) -> Vec<u32> {
        self.predict_opts(data, &PredictOptions::default()).labels
    }

    /// Labels plus the distance to the assigned center per query row.
    pub fn predict_with_distances(&self, data: &Matrix) -> (Vec<u32>, Vec<f64>) {
        let p = self.predict_opts(data, &PredictOptions::default());
        (p.labels, p.distances)
    }

    /// Batch predict with explicit strategy and thread budget, spawning a
    /// fresh pool when `opts.threads > 1`. Callers holding a long-lived
    /// pool (sweeps, serving loops) should prefer
    /// [`KMeansModel::predict_par`].
    pub fn predict_opts(&self, data: &Matrix, opts: &PredictOptions) -> Prediction {
        self.predict_opts_par(data, opts, &Parallelism::new(opts.threads))
    }

    /// What [`PredictMode::Auto`] resolves to for this model under the
    /// given tree/scan cutoff (`Tree` at `k >= auto_k`); explicit modes
    /// pass through unchanged.
    pub fn resolve_mode(&self, mode: PredictMode, auto_k: usize) -> PredictMode {
        match mode {
            PredictMode::Auto if self.k() >= auto_k => PredictMode::Tree,
            PredictMode::Auto => PredictMode::Scan,
            m => m,
        }
    }

    /// Eagerly build the serving index the given mode needs (the cover
    /// tree over the centers, or the inter-center matrix for the pruned
    /// scan), so later predict calls run against a warm cache. Returns the
    /// distance evaluations this call spent (0 when already warm) — the
    /// serving daemon charges them to its prep counter at startup and on
    /// every hot-reload, keeping query-time accounting clean.
    pub fn prewarm(&self, mode: PredictMode, auto_k: usize) -> u64 {
        let mut prep = 0u64;
        match self.resolve_mode(mode, auto_k) {
            PredictMode::Tree => {
                self.center_tree.get_or_init(|| {
                    let t = CoverTree::build(&self.centers, CENTER_TREE_PARAMS);
                    prep = t.build_distances;
                    Arc::new(t)
                });
            }
            _ => {
                self.inter_center.get_or_init(|| {
                    let mut dc = DistCounter::new();
                    let ic = InterCenter::compute(&self.centers, &mut dc);
                    prep = dc.count();
                    Arc::new(ic)
                });
            }
        }
        prep
    }

    /// [`KMeansModel::prewarm`] for a full option set: additionally builds
    /// the quantized f32 index when `opts.precision` asks for it. Building
    /// the f32 index charges no distance evaluations — quantizing centers
    /// and measuring their rounding displacement is conversion accounting,
    /// not query or inter-center work (and the f64 fallback index is warmed
    /// too, so an ambiguous query never pays prep at query time).
    pub fn prewarm_opts(&self, opts: &PredictOptions) -> u64 {
        match opts.precision {
            PredictPrecision::F64 => self.prewarm(opts.mode, opts.auto_k),
            PredictPrecision::F32 => {
                self.f32_index
                    .get_or_init(|| Arc::new(F32Index::build(&self.centers)));
                0
            }
        }
    }

    /// Batch predict over an existing worker pool with the default
    /// [`PredictMode::Auto`] cutoff ([`DEFAULT_PREDICT_AUTO_K`]); see
    /// [`KMeansModel::predict_par_with`].
    pub fn predict_par(
        &self,
        data: &Matrix,
        mode: PredictMode,
        par: &Parallelism,
    ) -> Prediction {
        self.predict_par_with(data, mode, DEFAULT_PREDICT_AUTO_K, par)
    }

    /// Batch predict over an existing worker pool with the full option
    /// set (strategy, Auto cutoff, scan precision; `opts.threads` is
    /// ignored — the pool decides). The one entry point the serving
    /// daemon and CLI use.
    pub fn predict_opts_par(
        &self,
        data: &Matrix,
        opts: &PredictOptions,
        par: &Parallelism,
    ) -> Prediction {
        match opts.precision {
            PredictPrecision::F64 => {
                self.predict_par_with(data, opts.mode, opts.auto_k, par)
            }
            PredictPrecision::F32 => self.predict_f32(data, par),
        }
    }

    /// Batch predict over an existing worker pool, with an explicit
    /// [`PredictMode::Auto`] tree/scan cutoff, in f64. Every query row is
    /// independent and the per-chunk distance tallies are integer sums, so
    /// any thread count produces byte-identical labels, distances, and
    /// counted evaluations.
    pub fn predict_par_with(
        &self,
        data: &Matrix,
        mode: PredictMode,
        auto_k: usize,
        par: &Parallelism,
    ) -> Prediction {
        assert_eq!(
            data.cols(),
            self.dim(),
            "query dimension {} does not match model dimension {}",
            data.cols(),
            self.dim()
        );
        let n = data.rows();
        let mode = self.resolve_mode(mode, auto_k);

        // Serving indexes are built once, sequentially, on the dispatching
        // thread — never under the pool — so their bits (and the charged
        // prep evaluations) cannot depend on the thread count.
        let mut prep_evals = 0u64;
        #[derive(Clone, Copy)]
        enum Index<'m> {
            Tree(&'m CoverTree),
            Scan(&'m InterCenter),
        }
        let index = match mode {
            PredictMode::Tree => {
                let tree = self.center_tree.get_or_init(|| {
                    let t = CoverTree::build(&self.centers, CENTER_TREE_PARAMS);
                    prep_evals = t.build_distances;
                    Arc::new(t)
                });
                Index::Tree(tree.as_ref())
            }
            _ => {
                let ic = self.inter_center.get_or_init(|| {
                    let mut dc = DistCounter::new();
                    let ic = InterCenter::compute(&self.centers, &mut dc);
                    prep_evals = dc.count();
                    Arc::new(ic)
                });
                Index::Scan(ic.as_ref())
            }
        };

        let mut labels = vec![0u32; n];
        let mut dists = vec![0.0f64; n];
        let query_evals: u64 = {
            let lab = SharedSlices::new(&mut labels);
            let dst = SharedSlices::new(&mut dists);
            par.map_chunks(n, |range| {
                // Safety: `map_chunks` hands out pairwise-disjoint ranges.
                let l = unsafe { lab.range(range.clone()) };
                let d = unsafe { dst.range(range.clone()) };
                let mut dc = DistCounter::new();
                for (off, i) in range.enumerate() {
                    let q = data.row(i);
                    let (label, dist) = match index {
                        Index::Tree(tree) => {
                            let nb = search::nearest(tree, &self.centers, q, &mut dc);
                            (nb.index, nb.dist)
                        }
                        Index::Scan(ic) => scan_one(q, &self.centers, ic, &mut dc),
                    };
                    l[off] = label;
                    d[off] = dist;
                }
                dc.count()
            })
            .into_iter()
            .sum()
        };

        Prediction {
            labels,
            distances: dists,
            query_evals,
            prep_evals,
            mode,
            precision: PredictPrecision::F64,
            f32_fallbacks: 0,
        }
    }

    /// The f32 serving scan (see [`F32Index`] for the quality contract):
    /// quantize the query, run the batched f32 argmin over the flat
    /// quantized centers, and accept the winner only when the certified
    /// error bound proves it is the true f64 nearest; otherwise fall back
    /// to the full f64 scan for that query. Accounting: the f32 scan is
    /// charged `k` evaluations per query (same O(d) passes, half-width
    /// lanes), an accepted winner one more for its f64 distance, and a
    /// fallback the `k` of its rescan.
    fn predict_f32(&self, data: &Matrix, par: &Parallelism) -> Prediction {
        assert_eq!(
            data.cols(),
            self.dim(),
            "query dimension {} does not match model dimension {}",
            data.cols(),
            self.dim()
        );
        let n = data.rows();
        let (k, d) = (self.k(), self.dim());
        let idx = self
            .f32_index
            .get_or_init(|| Arc::new(F32Index::build(&self.centers)))
            .as_ref();

        let mut labels = vec![0u32; n];
        let mut dists = vec![0.0f64; n];
        let (query_evals, f32_fallbacks) = {
            let lab = SharedSlices::new(&mut labels);
            let dst = SharedSlices::new(&mut dists);
            let per_chunk = par.map_chunks(n, |range| {
                // Safety: `map_chunks` hands out pairwise-disjoint ranges.
                let l = unsafe { lab.range(range.clone()) };
                let dv = unsafe { dst.range(range.clone()) };
                let mut dc = DistCounter::new();
                let mut fallbacks = 0u64;
                let mut q32 = vec![0.0f32; d];
                for (off, i) in range.enumerate() {
                    let q = data.row(i);
                    let mut qx = 0.0f64;
                    for (t, &v) in q32.iter_mut().zip(q) {
                        *t = v as f32;
                        let diff = v - *t as f64;
                        qx += diff * diff;
                    }
                    let qx = qx.sqrt();
                    dc.add_bulk(k as u64);
                    let (c1, s1, _, s2) =
                        crate::kernels::argmin2_f32(&q32, &idx.centers, d);
                    let r1 = (s1 as f64).sqrt();
                    let r2 = (s2 as f64).sqrt();
                    let m = qx + idx.qmax;
                    // NaN anywhere makes the comparison false => fallback;
                    // k = 1 makes r2 infinite => always accepted.
                    if r2 * (1.0 - idx.gamma) - m > r1 * (1.0 + idx.gamma) + m {
                        l[off] = c1;
                        dv[off] = dc.d(q, self.centers.row(c1 as usize));
                    } else {
                        fallbacks += 1;
                        let (c, dd, _, _) = nearest_two(q, &self.centers, &mut dc);
                        l[off] = c;
                        dv[off] = dd;
                    }
                }
                (dc.count(), fallbacks)
            });
            per_chunk
                .into_iter()
                .fold((0u64, 0u64), |(e, f), (ce, cf)| (e + ce, f + cf))
        };

        Prediction {
            labels,
            distances: dists,
            query_evals,
            prep_evals: 0,
            mode: PredictMode::Scan,
            precision: PredictPrecision::F32,
            f32_fallbacks,
        }
    }

    // ----- persistence --------------------------------------------------

    /// Serialize to the `.kmm` byte format: a `CMKM` magic, a format
    /// version, the model header (k, dim, algorithm name, seed,
    /// iterations, convergence flag), per-cluster counts and inertia, the
    /// centers' exact f64 bit patterns, and a trailing FNV-1a checksum
    /// over everything before it. Round-trips bit-identically.
    pub fn to_bytes(&self) -> Vec<u8> {
        let k = self.k();
        let name = self.algorithm.name().as_bytes();
        let mut out = Vec::with_capacity(64 + name.len() + k * 16 + k * self.dim() * 8);
        out.extend_from_slice(MAGIC);
        bin::put_u32(&mut out, FORMAT_VERSION);
        bin::put_u32(&mut out, k as u32);
        bin::put_u32(&mut out, self.dim() as u32);
        bin::put_u32(&mut out, name.len() as u32);
        out.extend_from_slice(name);
        bin::put_u64(&mut out, self.seed);
        bin::put_u64(&mut out, self.iterations);
        out.push(self.converged as u8);
        for &c in &self.counts {
            bin::put_u64(&mut out, c);
        }
        for &s in &self.cluster_sse {
            bin::put_f64(&mut out, s);
        }
        for &v in self.centers.as_slice() {
            bin::put_f64(&mut out, v);
        }
        let sum = fnv1a(&out);
        bin::put_u64(&mut out, sum);
        out
    }

    /// Parse the `.kmm` byte format, verifying the magic, version,
    /// structural length, and checksum — a truncated or bit-flipped file
    /// fails with a diagnosable error instead of yielding a silently
    /// corrupt model.
    pub fn from_bytes(buf: &[u8]) -> Result<KMeansModel> {
        if buf.len() < MAGIC.len() + 4 {
            bail!("not a covermeans model: {} bytes is too short", buf.len());
        }
        if &buf[..MAGIC.len()] != MAGIC {
            bail!("not a covermeans model: bad magic {:?}", &buf[..MAGIC.len()]);
        }
        if buf.len() < 8 + MAGIC.len() {
            bail!("truncated model file: no room for a checksum");
        }
        let (body, tail) = buf.split_at(buf.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        let actual = fnv1a(body);
        if stored != actual {
            bail!(
                "model checksum mismatch (stored {stored:#018x}, computed \
                 {actual:#018x}): the file is truncated or corrupt"
            );
        }
        let mut r = bin::Reader::new(&body[MAGIC.len()..]);
        let version = r.u32()?;
        if version != FORMAT_VERSION {
            bail!("unsupported model format version {version} (this build reads {FORMAT_VERSION})");
        }
        let k = r.u32()? as usize;
        let dim = r.u32()? as usize;
        if k == 0 || dim == 0 {
            bail!("corrupt model header: k={k}, dim={dim}");
        }
        let name_len = r.u32()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)
            .context("algorithm name is not UTF-8")?;
        let algorithm = Algorithm::parse(name)
            .with_context(|| format!("unknown algorithm {name:?} in model header"))?;
        let seed = r.u64()?;
        let iterations = r.u64()?;
        let converged = match r.take(1)?[0] {
            0 => false,
            1 => true,
            other => bail!("corrupt convergence flag {other}"),
        };
        // Structural check before any k-sized allocation: the payload must
        // hold exactly k counts + k SSEs + k*dim center coordinates.
        let need = k
            .checked_mul(16)
            .and_then(|a| a.checked_add(k.checked_mul(dim)?.checked_mul(8)?))
            .context("model dimensions overflow")?;
        if r.remaining() != need {
            bail!(
                "model payload is {} bytes, expected {need} for k={k} dim={dim}",
                r.remaining()
            );
        }
        let mut counts = Vec::with_capacity(k);
        for _ in 0..k {
            counts.push(r.u64()?);
        }
        let mut cluster_sse = Vec::with_capacity(k);
        for _ in 0..k {
            cluster_sse.push(r.f64()?);
        }
        let mut centers = Vec::with_capacity(k * dim);
        for _ in 0..k * dim {
            centers.push(r.f64()?);
        }
        if r.remaining() != 0 {
            bail!("{} trailing bytes after the centers block", r.remaining());
        }
        let checksum = OnceLock::new();
        checksum.set(stored).ok();
        Ok(KMeansModel {
            centers: Matrix::from_vec(centers, k, dim),
            counts,
            cluster_sse,
            algorithm,
            seed,
            iterations,
            converged,
            center_tree: OnceLock::new(),
            inter_center: OnceLock::new(),
            f32_index: OnceLock::new(),
            checksum,
        })
    }

    /// Write the `.kmm` format to `path` — atomically (temp + fsync +
    /// rename, previous generation kept as `.prev`), so a crash or a
    /// concurrent reader never sees a half-written model.
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::data::io::atomic_write(path, &self.to_bytes())
            .with_context(|| format!("write model {path:?}"))
    }

    /// Read a `.kmm` file back. The result predicts (and re-serializes)
    /// bit-identically to the saved model.
    pub fn load(path: &Path) -> Result<KMeansModel> {
        let buf =
            std::fs::read(path).with_context(|| format!("read model {path:?}"))?;
        KMeansModel::from_bytes(&buf)
            .with_context(|| format!("parse model {path:?}"))
    }

    /// Export the centers as a plain CSV (`k` rows x `d` columns) for
    /// interchange with external tooling. Rust's shortest-round-trip float
    /// formatting means re-reading the CSV reproduces the exact values.
    pub fn export_centers_csv(&self, path: &Path) -> Result<()> {
        crate::data::io::write_csv(path, &self.centers)
    }

    /// Export the whole model as a single self-describing JSON object
    /// (header fields, per-cluster stats, centers as nested arrays). For
    /// inspection and interchange; the `.kmm` binary remains the
    /// round-trip format.
    pub fn export_json(&self, path: &Path) -> Result<()> {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"format\": \"covermeans-kmeans-model\",\n");
        s.push_str(&format!("  \"version\": {FORMAT_VERSION},\n"));
        s.push_str(&format!("  \"k\": {},\n", self.k()));
        s.push_str(&format!("  \"dim\": {},\n", self.dim()));
        s.push_str(&format!("  \"algorithm\": \"{}\",\n", self.algorithm.name()));
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"iterations\": {},\n", self.iterations));
        s.push_str(&format!("  \"converged\": {},\n", self.converged));
        s.push_str(&format!("  \"inertia\": {},\n", self.inertia()));
        let counts: Vec<String> = self.counts.iter().map(|c| c.to_string()).collect();
        s.push_str(&format!("  \"counts\": [{}],\n", counts.join(", ")));
        let sses: Vec<String> =
            self.cluster_sse.iter().map(|v| v.to_string()).collect();
        s.push_str(&format!("  \"cluster_sse\": [{}],\n", sses.join(", ")));
        s.push_str("  \"centers\": [\n");
        for i in 0..self.k() {
            let row: Vec<String> =
                self.centers.row(i).iter().map(|v| v.to_string()).collect();
            let comma = if i + 1 < self.k() { "," } else { "" };
            s.push_str(&format!("    [{}]{comma}\n", row.join(", ")));
        }
        s.push_str("  ]\n}\n");
        crate::data::io::atomic_write(path, s.as_bytes())
            .with_context(|| format!("write model json {path:?}"))
    }
}

/// One pruned-scan query: index-order scan with the Elkan center-center
/// prune. A skipped center satisfies `d(c_best, c_j) >= 2 d(x, c_best)`,
/// hence by the triangle inequality `d(x, c_j) >= d(x, c_best)` — it can
/// tie but never strictly beat the incumbent, and a tie at a *later* index
/// never wins under the lowest-index convention, so the result is
/// label-identical to the naive full scan.
#[inline]
fn scan_one(
    q: &[f64],
    centers: &Matrix,
    ic: &InterCenter,
    dc: &mut DistCounter,
) -> (u32, f64) {
    let k = centers.rows();
    let mut best = 0usize;
    let mut d_best = dc.d(q, centers.row(0));
    for j in 1..k {
        if ic.d(best, j) >= 2.0 * d_best {
            continue;
        }
        let dd = dc.d(q, centers.row(j));
        if dd < d_best {
            best = j;
            d_best = dd;
        }
    }
    (best as u32, d_best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::bounds::nearest_two;
    use crate::kmeans::KMeans;

    fn naive_labels(data: &Matrix, centers: &Matrix) -> (Vec<u32>, Vec<f64>) {
        let mut dc = DistCounter::new();
        let mut labels = Vec::with_capacity(data.rows());
        let mut dists = Vec::with_capacity(data.rows());
        for i in 0..data.rows() {
            let (c1, d1, _, _) = nearest_two(data.row(i), centers, &mut dc);
            labels.push(c1);
            dists.push(d1);
        }
        (labels, dists)
    }

    fn fit_model(data: &Matrix, k: usize, seed: u64) -> KMeansModel {
        KMeans::new(k)
            .algorithm(Algorithm::Hamerly)
            .seed(seed)
            .max_iter(30)
            .fit_model(data)
            .unwrap()
    }

    #[test]
    fn from_run_aggregates_counts_and_inertia() {
        let data = synth::gaussian_blobs(300, 3, 5, 0.4, 2);
        let model = fit_model(&data, 5, 3);
        assert_eq!(model.k(), 5);
        assert_eq!(model.dim(), 3);
        assert_eq!(model.counts().iter().sum::<u64>(), 300);
        assert_eq!(model.algorithm(), Algorithm::Hamerly);
        assert_eq!(model.seed(), 3);
        assert!(model.iterations() >= 1);
        // Inertia equals the run's SSE (same labels, same centers).
        let r = KMeans::new(5)
            .algorithm(Algorithm::Hamerly)
            .seed(3)
            .max_iter(30)
            .fit(&data)
            .unwrap();
        assert!((model.inertia() - r.sse(&data)).abs() < 1e-9 * (1.0 + model.inertia()));
    }

    #[test]
    fn predict_matches_naive_scan_in_every_mode() {
        let train = synth::gaussian_blobs(400, 4, 10, 0.6, 5);
        let queries = synth::gaussian_blobs(150, 4, 10, 1.2, 6);
        let model = fit_model(&train, 10, 7);
        let (want_labels, want_dists) = naive_labels(&queries, model.centers());
        for mode in [PredictMode::Auto, PredictMode::Tree, PredictMode::Scan] {
            let p = model.predict_opts(
                &queries,
                &PredictOptions { mode, ..Default::default() },
            );
            assert_eq!(p.labels, want_labels, "{}", mode.name());
            for (i, (a, b)) in p.distances.iter().zip(&want_dists).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{}: distance {i}",
                    mode.name()
                );
            }
        }
    }

    #[test]
    fn auto_mode_resolves_by_k() {
        let train = synth::gaussian_blobs(600, 3, 4, 0.5, 8);
        let small = fit_model(&train, 4, 1);
        let p = small.predict_opts(&train, &PredictOptions::default());
        assert_eq!(p.mode, PredictMode::Scan);
        let big = fit_model(&train, DEFAULT_PREDICT_AUTO_K, 1);
        let p = big.predict_opts(&train, &PredictOptions::default());
        assert_eq!(p.mode, PredictMode::Tree);
    }

    #[test]
    fn auto_k_cutoff_is_configurable() {
        let train = synth::gaussian_blobs(600, 3, 4, 0.5, 8);
        let model = fit_model(&train, 4, 1);
        // Default cutoff: k=4 resolves to the scan.
        assert_eq!(model.resolve_mode(PredictMode::Auto, DEFAULT_PREDICT_AUTO_K), PredictMode::Scan);
        // Lowering the cutoff to k flips Auto to the tree — and the labels
        // must not care which strategy answered.
        assert_eq!(model.resolve_mode(PredictMode::Auto, 4), PredictMode::Tree);
        let scan = model.predict_opts(&train, &PredictOptions::default());
        let tree = model.predict_opts(
            &train,
            &PredictOptions { auto_k: 4, ..Default::default() },
        );
        assert_eq!(scan.mode, PredictMode::Scan);
        assert_eq!(tree.mode, PredictMode::Tree);
        assert_eq!(scan.labels, tree.labels);
        // Explicit modes ignore the cutoff entirely.
        assert_eq!(model.resolve_mode(PredictMode::Scan, 1), PredictMode::Scan);
        assert_eq!(
            model.resolve_mode(PredictMode::Tree, usize::MAX),
            PredictMode::Tree
        );
    }

    #[test]
    fn checksum_matches_serialization_and_survives_roundtrip() {
        let train = synth::gaussian_blobs(200, 3, 5, 0.5, 21);
        let model = fit_model(&train, 5, 22);
        let bytes = model.to_bytes();
        let tail = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        assert_eq!(model.checksum(), tail);
        let loaded = KMeansModel::from_bytes(&bytes).unwrap();
        assert_eq!(loaded.checksum(), model.checksum());
        // A different model versions differently.
        let other = fit_model(&train, 4, 23);
        assert_ne!(other.checksum(), model.checksum());
    }

    #[test]
    fn prewarm_charges_prep_exactly_once() {
        let train = synth::gaussian_blobs(300, 3, 6, 0.5, 9);
        let model = fit_model(&train, 6, 2);
        let prep = model.prewarm(PredictMode::Scan, DEFAULT_PREDICT_AUTO_K);
        assert_eq!(prep, (6 * 5 / 2) as u64, "k(k-1)/2 inter-center");
        assert_eq!(model.prewarm(PredictMode::Scan, DEFAULT_PREDICT_AUTO_K), 0);
        // A prewarmed model's first predict charges no prep.
        let p = model.predict_opts(
            &train,
            &PredictOptions { mode: PredictMode::Scan, ..Default::default() },
        );
        assert_eq!(p.prep_evals, 0);
        // The tree index is independent and charges on its own first build.
        assert!(model.prewarm(PredictMode::Tree, DEFAULT_PREDICT_AUTO_K) > 0);
        assert_eq!(model.prewarm(PredictMode::Tree, DEFAULT_PREDICT_AUTO_K), 0);
    }

    #[test]
    fn prep_evals_charged_once() {
        let train = synth::gaussian_blobs(300, 3, 6, 0.5, 9);
        let model = fit_model(&train, 6, 2);
        let p1 = model.predict_opts(
            &train,
            &PredictOptions { mode: PredictMode::Scan, ..Default::default() },
        );
        assert_eq!(p1.prep_evals, (6 * 5 / 2) as u64, "k(k-1)/2 inter-center");
        let p2 = model.predict_opts(
            &train,
            &PredictOptions { mode: PredictMode::Scan, ..Default::default() },
        );
        assert_eq!(p2.prep_evals, 0, "cached index must not be re-charged");
        assert_eq!(p1.labels, p2.labels);
    }

    #[test]
    fn bytes_roundtrip_is_bit_identical() {
        let train = synth::gaussian_blobs(250, 5, 7, 0.5, 10);
        let model = fit_model(&train, 7, 11);
        let bytes = model.to_bytes();
        let back = KMeansModel::from_bytes(&bytes).unwrap();
        assert_eq!(back.k(), model.k());
        assert_eq!(back.dim(), model.dim());
        assert_eq!(back.counts(), model.counts());
        assert_eq!(back.algorithm(), model.algorithm());
        assert_eq!(back.seed(), model.seed());
        assert_eq!(back.iterations(), model.iterations());
        assert_eq!(back.converged(), model.converged());
        for (a, b) in back
            .centers()
            .as_slice()
            .iter()
            .zip(model.centers().as_slice())
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in back.cluster_sse().iter().zip(model.cluster_sse()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Re-serialization is byte-identical (stable format).
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn corrupt_and_truncated_files_fail_loudly() {
        let train = synth::gaussian_blobs(120, 2, 3, 0.5, 12);
        let model = fit_model(&train, 3, 13);
        let bytes = model.to_bytes();
        // The whole container is checksummed, so every fault in the
        // shared battery must land on the checksum or the magic.
        crate::testutil::corruption::assert_rejects_faults(
            ".kmm model",
            &bytes,
            bytes.len(),
            KMeansModel::from_bytes,
        );
    }

    fn model_from_centers(centers: Matrix) -> KMeansModel {
        let data = centers.clone();
        let labels: Vec<u32> = (0..centers.rows() as u32).collect();
        let run = RunResult {
            labels,
            centers,
            iterations: 1,
            distances: 0,
            build_dist: 0,
            time: std::time::Duration::ZERO,
            build_time: std::time::Duration::ZERO,
            log: crate::metrics::IterationLog::new(),
            converged: true,
        };
        KMeansModel::from_run(&data, &run, Algorithm::Standard, 0)
    }

    #[test]
    fn f32_precision_is_output_identical_to_f64() {
        let train = synth::gaussian_blobs(400, 4, 10, 0.6, 5);
        let queries = synth::gaussian_blobs(150, 4, 10, 1.2, 6);
        let model = fit_model(&train, 10, 7);
        let base = model.predict_opts(&queries, &PredictOptions::default());
        assert_eq!(base.precision, PredictPrecision::F64);
        assert_eq!(base.f32_fallbacks, 0);
        let fast = model.predict_opts(
            &queries,
            &PredictOptions {
                precision: PredictPrecision::F32,
                ..Default::default()
            },
        );
        assert_eq!(fast.precision, PredictPrecision::F32);
        assert_eq!(fast.mode, PredictMode::Scan);
        assert_eq!(fast.labels, base.labels);
        for (i, (a, b)) in fast.distances.iter().zip(&base.distances).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "distance {i}");
        }
        // Well-separated blobs: the certificate should accept nearly all
        // queries (the point of the fast path).
        assert!(
            fast.f32_fallbacks < queries.rows() as u64 / 2,
            "{} of {} queries fell back",
            fast.f32_fallbacks,
            queries.rows()
        );
    }

    #[test]
    fn f32_near_ties_fall_back_and_stay_exact() {
        // Two centers separated by less than f32 resolution around 1.0:
        // they quantize to the same f32 row, the f32 margin is ~0, the
        // certificate must refuse, and the f64 fallback must keep the
        // lowest-index-wins answer exact.
        let centers = Matrix::from_rows(&[&[1.0, 0.0], &[1.0 + 1e-12, 0.0]]);
        let model = model_from_centers(centers);
        let queries = Matrix::from_rows(&[&[1.0, 0.5], &[1.0 + 1e-12, -0.5]]);
        let (want_labels, want_dists) = naive_labels(&queries, model.centers());
        let p = model.predict_opts(
            &queries,
            &PredictOptions {
                precision: PredictPrecision::F32,
                ..Default::default()
            },
        );
        assert_eq!(p.labels, want_labels);
        for (a, b) in p.distances.iter().zip(&want_dists) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(
            p.f32_fallbacks,
            queries.rows() as u64,
            "indistinguishable-in-f32 centers must always fall back"
        );
    }

    #[test]
    fn f32_single_center_always_certifies() {
        let model = model_from_centers(Matrix::from_rows(&[&[0.5, -0.25, 3.0]]));
        let queries = synth::gaussian_blobs(50, 3, 2, 1.0, 33);
        let p = model.predict_opts(
            &queries,
            &PredictOptions {
                precision: PredictPrecision::F32,
                ..Default::default()
            },
        );
        assert!(p.labels.iter().all(|&l| l == 0));
        assert_eq!(p.f32_fallbacks, 0, "k = 1 has an infinite margin");
    }

    #[test]
    fn f32_predict_is_thread_count_invariant() {
        let train = synth::gaussian_blobs(500, 3, 8, 0.5, 17);
        let model = fit_model(&train, 8, 18);
        let opts = PredictOptions {
            precision: PredictPrecision::F32,
            ..Default::default()
        };
        let base = model.predict_opts_par(&train, &opts, &Parallelism::new(1));
        for t in [2usize, 4] {
            let p = model.predict_opts_par(&train, &opts, &Parallelism::new(t));
            assert_eq!(p.labels, base.labels, "threads={t}");
            assert_eq!(p.query_evals, base.query_evals, "threads={t}");
            assert_eq!(p.f32_fallbacks, base.f32_fallbacks, "threads={t}");
            for (a, b) in p.distances.iter().zip(&base.distances) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={t}");
            }
        }
    }

    #[test]
    fn prewarm_opts_covers_both_precisions() {
        let train = synth::gaussian_blobs(300, 3, 6, 0.5, 9);
        let model = fit_model(&train, 6, 2);
        let opts = PredictOptions {
            precision: PredictPrecision::F32,
            ..Default::default()
        };
        assert_eq!(model.prewarm_opts(&opts), 0, "f32 index is uncounted");
        let p = model.predict_opts(&train, &opts);
        assert_eq!(p.prep_evals, 0);
        // The f64 default routes through the mode-based prewarm.
        let def = PredictOptions::default();
        assert_eq!(model.prewarm_opts(&def), (6 * 5 / 2) as u64);
        assert_eq!(model.prewarm_opts(&def), 0);
    }

    #[test]
    fn predict_precision_parse_roundtrip() {
        for p in [PredictPrecision::F64, PredictPrecision::F32] {
            assert_eq!(PredictPrecision::parse(p.name()), Some(p));
        }
        assert_eq!(PredictPrecision::parse("single"), Some(PredictPrecision::F32));
        assert_eq!(PredictPrecision::parse("double"), Some(PredictPrecision::F64));
        assert!(PredictPrecision::parse("f16").is_none());
    }

    #[test]
    fn predict_mode_parse_roundtrip() {
        for m in [PredictMode::Auto, PredictMode::Tree, PredictMode::Scan] {
            assert_eq!(PredictMode::parse(m.name()), Some(m));
        }
        assert_eq!(PredictMode::parse("elkan"), Some(PredictMode::Scan));
        assert!(PredictMode::parse("quantum").is_none());
    }

    #[test]
    #[should_panic(expected = "query dimension")]
    fn predict_rejects_dimension_mismatch() {
        let train = synth::gaussian_blobs(100, 3, 2, 0.5, 14);
        let model = fit_model(&train, 2, 15);
        let wrong = Matrix::zeros(5, 4);
        model.predict(&wrong);
    }
}
