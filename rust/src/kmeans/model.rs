//! The serving layer: a persistable trained model with batch prediction.
//!
//! A fit used to dead-end at [`RunResult`] — labels and centers for the
//! training set, nothing for out-of-sample points and nothing to put on
//! disk. [`KMeansModel`] closes that gap: it captures everything a serving
//! process needs (centers, per-cluster sizes and inertia, algorithm/seed
//! provenance), round-trips through a small self-describing binary format
//! (`.kmm`), and answers batch nearest-center queries through the paper's
//! own index — a cover tree built **over the centers** — with an
//! Elkan-style pruned scan as the small-`k` fallback where tree overhead
//! loses (see [`PredictMode`]).
//!
//! ```
//! use covermeans::data::synth;
//! use covermeans::kmeans::{Algorithm, KMeans, KMeansModel};
//!
//! let data = synth::gaussian_blobs(200, 3, 4, 0.5, 1);
//! let model = KMeans::new(4)
//!     .algorithm(Algorithm::Hybrid)
//!     .seed(7)
//!     .fit_model(&data)
//!     .unwrap();
//! let labels = model.predict(&data);
//!
//! let path = std::env::temp_dir().join("covermeans_model_doc.kmm");
//! model.save(&path).unwrap();
//! let served = KMeansModel::load(&path).unwrap();
//! assert_eq!(served.predict(&data), labels);
//! # std::fs::remove_file(&path).ok();
//! ```
//!
//! **Determinism.** Prediction shards query rows over the same persistent
//! worker pool the fit uses ([`crate::parallel::Parallelism`]); each query
//! is independent, per-chunk distance tallies fold back as integer sums,
//! and the serving indexes are built sequentially once — so `threads = N`
//! reproduces `threads = 1` byte for byte, the same contract every other
//! parallel pass in this crate honors. Labels are additionally guaranteed
//! to match a naive lowest-index nearest-center scan label for label, at
//! every thread count and in every [`PredictMode`]
//! (`rust/tests/model.rs`, `rust/tests/parallel_exactness.rs`).

use std::path::Path;
use std::sync::{Arc, OnceLock};

use anyhow::{bail, Context, Result};

use crate::data::io::{bin, fnv1a};
use crate::data::{matrix, Matrix};
use crate::kmeans::bounds::InterCenter;
use crate::kmeans::Algorithm;
use crate::metrics::{DistCounter, RunResult};
use crate::parallel::{Parallelism, SharedSlices};
use crate::tree::{search, CoverTree, CoverTreeParams};

/// `.kmm` file magic.
const MAGIC: &[u8; 4] = b"CMKM";
/// Current `.kmm` format version.
const FORMAT_VERSION: u32 = 1;

/// Default `k` at or above which [`PredictMode::Auto`] resolves to the
/// cover tree: the center tree's per-query descent overhead (child
/// ordering, recursion) only pays off once the scan's `O(k)` per query
/// dominates. The `bench_smoke` harness measures the actual crossover
/// (`BENCH_5.json`); callers whose hardware crosses elsewhere override it
/// per call ([`PredictOptions::auto_k`],
/// [`KMeansModel::predict_par_with`]) or via the `predict_auto_k` config
/// key (`covermeans predict` / `covermeans serve`).
pub const DEFAULT_PREDICT_AUTO_K: usize = 64;

/// Cover tree construction parameters for the *centers* index. Centers
/// matrices are tiny next to datasets, so the node floor is far below the
/// paper's data-side default of 100 — with that default, any `k < 100`
/// would collapse into one leaf and degenerate to a linear scan.
const CENTER_TREE_PARAMS: CoverTreeParams =
    CoverTreeParams { scale_factor: 1.2, min_node_size: 8 };

/// How [`KMeansModel::predict_opts`] answers nearest-center queries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictMode {
    /// Pick per model: the cover tree for `k >= auto_k` (default
    /// [`DEFAULT_PREDICT_AUTO_K`]), the pruned scan below (the small-`k`
    /// regime where tree overhead loses).
    Auto,
    /// 1-NN descent of a cover tree built over the centers
    /// ([`crate::tree::nearest`]), reusing the node radii and parent
    /// distances for pruning.
    Tree,
    /// Elkan-style pruned linear scan: center `j` is skipped whenever
    /// `d(c_best, c_j) >= 2 * d(x, c_best)` (triangle inequality over the
    /// cached inter-center matrix), so it cannot strictly beat the
    /// incumbent.
    Scan,
}

impl PredictMode {
    pub fn name(&self) -> &'static str {
        match self {
            PredictMode::Auto => "auto",
            PredictMode::Tree => "tree",
            PredictMode::Scan => "scan",
        }
    }

    pub fn parse(s: &str) -> Option<PredictMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(PredictMode::Auto),
            "tree" | "cover" => Some(PredictMode::Tree),
            "scan" | "pruned" | "elkan" => Some(PredictMode::Scan),
            _ => None,
        }
    }
}

/// Batch-predict configuration: the query-answering strategy, the
/// [`PredictMode::Auto`] tree/scan cutoff, and the worker-thread budget
/// (0 = all cores; any value reproduces the single-threaded labels byte
/// for byte).
#[derive(Debug, Clone, Copy)]
pub struct PredictOptions {
    pub mode: PredictMode,
    /// `k` at or above which [`PredictMode::Auto`] picks the cover tree
    /// (config key `predict_auto_k`; default [`DEFAULT_PREDICT_AUTO_K`]).
    pub auto_k: usize,
    pub threads: usize,
}

impl Default for PredictOptions {
    fn default() -> Self {
        PredictOptions {
            mode: PredictMode::Auto,
            auto_k: DEFAULT_PREDICT_AUTO_K,
            threads: 1,
        }
    }
}

/// Outcome of one batch predict, with the counted-distance accounting the
/// repo's evaluation protocol uses everywhere else: `query_evals` is what
/// the strategy spent answering, `prep_evals` what this call spent
/// building a serving index (0 once the model's lazy index cache is warm),
/// mirroring the `distances` / `build_dist` split of [`RunResult`].
#[derive(Debug, Clone)]
pub struct Prediction {
    /// Nearest-center index per query row.
    pub labels: Vec<u32>,
    /// Distance to that center per query row.
    pub distances: Vec<f64>,
    /// Distance evaluations spent answering the queries (a naive scan
    /// spends exactly `n * k`).
    pub query_evals: u64,
    /// Distance evaluations spent building the serving index in this call.
    pub prep_evals: u64,
    /// The strategy that actually ran ([`PredictMode::Auto`] resolved).
    pub mode: PredictMode,
}

/// A trained k-means model: the artifact `fit` hands to serving.
///
/// Produced by [`crate::kmeans::KMeans::fit_model`] (or
/// [`KMeansModel::from_run`] for an existing [`RunResult`]); persisted
/// with [`KMeansModel::save`] / [`KMeansModel::load`]; queried with
/// [`KMeansModel::predict`] and friends. The serving indexes (center
/// cover tree, inter-center matrix) are built lazily on first use and
/// cached — they are *not* persisted, so a loaded model rebuilds them on
/// its first predict (charged to [`Prediction::prep_evals`]).
#[derive(Debug, Clone)]
pub struct KMeansModel {
    centers: Matrix,
    counts: Vec<u64>,
    cluster_sse: Vec<f64>,
    algorithm: Algorithm,
    seed: u64,
    iterations: u64,
    converged: bool,
    center_tree: OnceLock<Arc<CoverTree>>,
    inter_center: OnceLock<Arc<InterCenter>>,
    /// Lazily computed `.kmm` checksum (the serving layer's model version
    /// tag); [`KMeansModel::from_bytes`] seeds it with the verified value.
    checksum: OnceLock<u64>,
}

impl KMeansModel {
    /// Capture a finished run as a servable model. `data` must be the
    /// matrix the run was fit on (per-cluster counts and inertia are
    /// derived from its labels); `algorithm` and `seed` record provenance.
    pub fn from_run(
        data: &Matrix,
        run: &RunResult,
        algorithm: Algorithm,
        seed: u64,
    ) -> KMeansModel {
        assert_eq!(
            data.rows(),
            run.labels.len(),
            "data/labels length mismatch: the run was not fit on this matrix"
        );
        assert_eq!(data.cols(), run.centers.cols(), "data/centers dimension mismatch");
        let k = run.centers.rows();
        let mut counts = vec![0u64; k];
        let mut cluster_sse = vec![0.0f64; k];
        for (i, &l) in run.labels.iter().enumerate() {
            counts[l as usize] += 1;
            cluster_sse[l as usize] +=
                matrix::sqdist(data.row(i), run.centers.row(l as usize));
        }
        KMeansModel {
            centers: run.centers.clone(),
            counts,
            cluster_sse,
            algorithm,
            seed,
            iterations: run.iterations as u64,
            converged: run.converged,
            center_tree: OnceLock::new(),
            inter_center: OnceLock::new(),
            checksum: OnceLock::new(),
        }
    }

    /// Number of clusters.
    pub fn k(&self) -> usize {
        self.centers.rows()
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.centers.cols()
    }

    /// The cluster centers (`k x d`).
    pub fn centers(&self) -> &Matrix {
        &self.centers
    }

    /// Training-set points per cluster.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Training-set sum of squared errors per cluster.
    pub fn cluster_sse(&self) -> &[f64] {
        &self.cluster_sse
    }

    /// Total training-set inertia (sum of [`KMeansModel::cluster_sse`]).
    pub fn inertia(&self) -> f64 {
        self.cluster_sse.iter().sum()
    }

    /// The algorithm that produced the model.
    pub fn algorithm(&self) -> Algorithm {
        self.algorithm
    }

    /// The seeding seed the fit was configured with.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Iterations the fit ran.
    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    /// Whether the fit reached its convergence criterion.
    pub fn converged(&self) -> bool {
        self.converged
    }

    /// The FNV-1a checksum of the model's `.kmm` serialization — the same
    /// value [`KMeansModel::to_bytes`] appends as the trailing 8 bytes and
    /// [`KMeansModel::from_bytes`] verifies. Two models with the same
    /// checksum serve identical predictions, so the serving daemon uses it
    /// as the model **version tag** carried on every reply. Computed once
    /// and cached (loaded models reuse the verified on-disk value).
    pub fn checksum(&self) -> u64 {
        *self.checksum.get_or_init(|| {
            let bytes = self.to_bytes();
            u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap())
        })
    }

    // ----- prediction ---------------------------------------------------

    /// Nearest-center label per query row (defaults: [`PredictMode::Auto`],
    /// single-threaded). Panics if `data`'s dimensionality differs from
    /// the model's.
    pub fn predict(&self, data: &Matrix) -> Vec<u32> {
        self.predict_opts(data, &PredictOptions::default()).labels
    }

    /// Labels plus the distance to the assigned center per query row.
    pub fn predict_with_distances(&self, data: &Matrix) -> (Vec<u32>, Vec<f64>) {
        let p = self.predict_opts(data, &PredictOptions::default());
        (p.labels, p.distances)
    }

    /// Batch predict with explicit strategy and thread budget, spawning a
    /// fresh pool when `opts.threads > 1`. Callers holding a long-lived
    /// pool (sweeps, serving loops) should prefer
    /// [`KMeansModel::predict_par`].
    pub fn predict_opts(&self, data: &Matrix, opts: &PredictOptions) -> Prediction {
        self.predict_par_with(
            data,
            opts.mode,
            opts.auto_k,
            &Parallelism::new(opts.threads),
        )
    }

    /// What [`PredictMode::Auto`] resolves to for this model under the
    /// given tree/scan cutoff (`Tree` at `k >= auto_k`); explicit modes
    /// pass through unchanged.
    pub fn resolve_mode(&self, mode: PredictMode, auto_k: usize) -> PredictMode {
        match mode {
            PredictMode::Auto if self.k() >= auto_k => PredictMode::Tree,
            PredictMode::Auto => PredictMode::Scan,
            m => m,
        }
    }

    /// Eagerly build the serving index the given mode needs (the cover
    /// tree over the centers, or the inter-center matrix for the pruned
    /// scan), so later predict calls run against a warm cache. Returns the
    /// distance evaluations this call spent (0 when already warm) — the
    /// serving daemon charges them to its prep counter at startup and on
    /// every hot-reload, keeping query-time accounting clean.
    pub fn prewarm(&self, mode: PredictMode, auto_k: usize) -> u64 {
        let mut prep = 0u64;
        match self.resolve_mode(mode, auto_k) {
            PredictMode::Tree => {
                self.center_tree.get_or_init(|| {
                    let t = CoverTree::build(&self.centers, CENTER_TREE_PARAMS);
                    prep = t.build_distances;
                    Arc::new(t)
                });
            }
            _ => {
                self.inter_center.get_or_init(|| {
                    let mut dc = DistCounter::new();
                    let ic = InterCenter::compute(&self.centers, &mut dc);
                    prep = dc.count();
                    Arc::new(ic)
                });
            }
        }
        prep
    }

    /// Batch predict over an existing worker pool with the default
    /// [`PredictMode::Auto`] cutoff ([`DEFAULT_PREDICT_AUTO_K`]); see
    /// [`KMeansModel::predict_par_with`].
    pub fn predict_par(
        &self,
        data: &Matrix,
        mode: PredictMode,
        par: &Parallelism,
    ) -> Prediction {
        self.predict_par_with(data, mode, DEFAULT_PREDICT_AUTO_K, par)
    }

    /// Batch predict over an existing worker pool, with an explicit
    /// [`PredictMode::Auto`] tree/scan cutoff. Every query row is
    /// independent and the per-chunk distance tallies are integer sums, so
    /// any thread count produces byte-identical labels, distances, and
    /// counted evaluations.
    pub fn predict_par_with(
        &self,
        data: &Matrix,
        mode: PredictMode,
        auto_k: usize,
        par: &Parallelism,
    ) -> Prediction {
        assert_eq!(
            data.cols(),
            self.dim(),
            "query dimension {} does not match model dimension {}",
            data.cols(),
            self.dim()
        );
        let n = data.rows();
        let mode = self.resolve_mode(mode, auto_k);

        // Serving indexes are built once, sequentially, on the dispatching
        // thread — never under the pool — so their bits (and the charged
        // prep evaluations) cannot depend on the thread count.
        let mut prep_evals = 0u64;
        #[derive(Clone, Copy)]
        enum Index<'m> {
            Tree(&'m CoverTree),
            Scan(&'m InterCenter),
        }
        let index = match mode {
            PredictMode::Tree => {
                let tree = self.center_tree.get_or_init(|| {
                    let t = CoverTree::build(&self.centers, CENTER_TREE_PARAMS);
                    prep_evals = t.build_distances;
                    Arc::new(t)
                });
                Index::Tree(tree.as_ref())
            }
            _ => {
                let ic = self.inter_center.get_or_init(|| {
                    let mut dc = DistCounter::new();
                    let ic = InterCenter::compute(&self.centers, &mut dc);
                    prep_evals = dc.count();
                    Arc::new(ic)
                });
                Index::Scan(ic.as_ref())
            }
        };

        let mut labels = vec![0u32; n];
        let mut dists = vec![0.0f64; n];
        let query_evals: u64 = {
            let lab = SharedSlices::new(&mut labels);
            let dst = SharedSlices::new(&mut dists);
            par.map_chunks(n, |range| {
                // Safety: `map_chunks` hands out pairwise-disjoint ranges.
                let l = unsafe { lab.range(range.clone()) };
                let d = unsafe { dst.range(range.clone()) };
                let mut dc = DistCounter::new();
                for (off, i) in range.enumerate() {
                    let q = data.row(i);
                    let (label, dist) = match index {
                        Index::Tree(tree) => {
                            let nb = search::nearest(tree, &self.centers, q, &mut dc);
                            (nb.index, nb.dist)
                        }
                        Index::Scan(ic) => scan_one(q, &self.centers, ic, &mut dc),
                    };
                    l[off] = label;
                    d[off] = dist;
                }
                dc.count()
            })
            .into_iter()
            .sum()
        };

        Prediction { labels, distances: dists, query_evals, prep_evals, mode }
    }

    // ----- persistence --------------------------------------------------

    /// Serialize to the `.kmm` byte format: a `CMKM` magic, a format
    /// version, the model header (k, dim, algorithm name, seed,
    /// iterations, convergence flag), per-cluster counts and inertia, the
    /// centers' exact f64 bit patterns, and a trailing FNV-1a checksum
    /// over everything before it. Round-trips bit-identically.
    pub fn to_bytes(&self) -> Vec<u8> {
        let k = self.k();
        let name = self.algorithm.name().as_bytes();
        let mut out = Vec::with_capacity(64 + name.len() + k * 16 + k * self.dim() * 8);
        out.extend_from_slice(MAGIC);
        bin::put_u32(&mut out, FORMAT_VERSION);
        bin::put_u32(&mut out, k as u32);
        bin::put_u32(&mut out, self.dim() as u32);
        bin::put_u32(&mut out, name.len() as u32);
        out.extend_from_slice(name);
        bin::put_u64(&mut out, self.seed);
        bin::put_u64(&mut out, self.iterations);
        out.push(self.converged as u8);
        for &c in &self.counts {
            bin::put_u64(&mut out, c);
        }
        for &s in &self.cluster_sse {
            bin::put_f64(&mut out, s);
        }
        for &v in self.centers.as_slice() {
            bin::put_f64(&mut out, v);
        }
        let sum = fnv1a(&out);
        bin::put_u64(&mut out, sum);
        out
    }

    /// Parse the `.kmm` byte format, verifying the magic, version,
    /// structural length, and checksum — a truncated or bit-flipped file
    /// fails with a diagnosable error instead of yielding a silently
    /// corrupt model.
    pub fn from_bytes(buf: &[u8]) -> Result<KMeansModel> {
        if buf.len() < MAGIC.len() + 4 {
            bail!("not a covermeans model: {} bytes is too short", buf.len());
        }
        if &buf[..MAGIC.len()] != MAGIC {
            bail!("not a covermeans model: bad magic {:?}", &buf[..MAGIC.len()]);
        }
        if buf.len() < 8 + MAGIC.len() {
            bail!("truncated model file: no room for a checksum");
        }
        let (body, tail) = buf.split_at(buf.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        let actual = fnv1a(body);
        if stored != actual {
            bail!(
                "model checksum mismatch (stored {stored:#018x}, computed \
                 {actual:#018x}): the file is truncated or corrupt"
            );
        }
        let mut r = bin::Reader::new(&body[MAGIC.len()..]);
        let version = r.u32()?;
        if version != FORMAT_VERSION {
            bail!("unsupported model format version {version} (this build reads {FORMAT_VERSION})");
        }
        let k = r.u32()? as usize;
        let dim = r.u32()? as usize;
        if k == 0 || dim == 0 {
            bail!("corrupt model header: k={k}, dim={dim}");
        }
        let name_len = r.u32()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)
            .context("algorithm name is not UTF-8")?;
        let algorithm = Algorithm::parse(name)
            .with_context(|| format!("unknown algorithm {name:?} in model header"))?;
        let seed = r.u64()?;
        let iterations = r.u64()?;
        let converged = match r.take(1)?[0] {
            0 => false,
            1 => true,
            other => bail!("corrupt convergence flag {other}"),
        };
        // Structural check before any k-sized allocation: the payload must
        // hold exactly k counts + k SSEs + k*dim center coordinates.
        let need = k
            .checked_mul(16)
            .and_then(|a| a.checked_add(k.checked_mul(dim)?.checked_mul(8)?))
            .context("model dimensions overflow")?;
        if r.remaining() != need {
            bail!(
                "model payload is {} bytes, expected {need} for k={k} dim={dim}",
                r.remaining()
            );
        }
        let mut counts = Vec::with_capacity(k);
        for _ in 0..k {
            counts.push(r.u64()?);
        }
        let mut cluster_sse = Vec::with_capacity(k);
        for _ in 0..k {
            cluster_sse.push(r.f64()?);
        }
        let mut centers = Vec::with_capacity(k * dim);
        for _ in 0..k * dim {
            centers.push(r.f64()?);
        }
        if r.remaining() != 0 {
            bail!("{} trailing bytes after the centers block", r.remaining());
        }
        let checksum = OnceLock::new();
        checksum.set(stored).ok();
        Ok(KMeansModel {
            centers: Matrix::from_vec(centers, k, dim),
            counts,
            cluster_sse,
            algorithm,
            seed,
            iterations,
            converged,
            center_tree: OnceLock::new(),
            inter_center: OnceLock::new(),
            checksum,
        })
    }

    /// Write the `.kmm` format to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("write model {path:?}"))
    }

    /// Read a `.kmm` file back. The result predicts (and re-serializes)
    /// bit-identically to the saved model.
    pub fn load(path: &Path) -> Result<KMeansModel> {
        let buf =
            std::fs::read(path).with_context(|| format!("read model {path:?}"))?;
        KMeansModel::from_bytes(&buf)
            .with_context(|| format!("parse model {path:?}"))
    }

    /// Export the centers as a plain CSV (`k` rows x `d` columns) for
    /// interchange with external tooling. Rust's shortest-round-trip float
    /// formatting means re-reading the CSV reproduces the exact values.
    pub fn export_centers_csv(&self, path: &Path) -> Result<()> {
        crate::data::io::write_csv(path, &self.centers)
    }

    /// Export the whole model as a single self-describing JSON object
    /// (header fields, per-cluster stats, centers as nested arrays). For
    /// inspection and interchange; the `.kmm` binary remains the
    /// round-trip format.
    pub fn export_json(&self, path: &Path) -> Result<()> {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"format\": \"covermeans-kmeans-model\",\n");
        s.push_str(&format!("  \"version\": {FORMAT_VERSION},\n"));
        s.push_str(&format!("  \"k\": {},\n", self.k()));
        s.push_str(&format!("  \"dim\": {},\n", self.dim()));
        s.push_str(&format!("  \"algorithm\": \"{}\",\n", self.algorithm.name()));
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"iterations\": {},\n", self.iterations));
        s.push_str(&format!("  \"converged\": {},\n", self.converged));
        s.push_str(&format!("  \"inertia\": {},\n", self.inertia()));
        let counts: Vec<String> = self.counts.iter().map(|c| c.to_string()).collect();
        s.push_str(&format!("  \"counts\": [{}],\n", counts.join(", ")));
        let sses: Vec<String> =
            self.cluster_sse.iter().map(|v| v.to_string()).collect();
        s.push_str(&format!("  \"cluster_sse\": [{}],\n", sses.join(", ")));
        s.push_str("  \"centers\": [\n");
        for i in 0..self.k() {
            let row: Vec<String> =
                self.centers.row(i).iter().map(|v| v.to_string()).collect();
            let comma = if i + 1 < self.k() { "," } else { "" };
            s.push_str(&format!("    [{}]{comma}\n", row.join(", ")));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(path, s).with_context(|| format!("write model json {path:?}"))
    }
}

/// One pruned-scan query: index-order scan with the Elkan center-center
/// prune. A skipped center satisfies `d(c_best, c_j) >= 2 d(x, c_best)`,
/// hence by the triangle inequality `d(x, c_j) >= d(x, c_best)` — it can
/// tie but never strictly beat the incumbent, and a tie at a *later* index
/// never wins under the lowest-index convention, so the result is
/// label-identical to the naive full scan.
#[inline]
fn scan_one(
    q: &[f64],
    centers: &Matrix,
    ic: &InterCenter,
    dc: &mut DistCounter,
) -> (u32, f64) {
    let k = centers.rows();
    let mut best = 0usize;
    let mut d_best = dc.d(q, centers.row(0));
    for j in 1..k {
        if ic.d(best, j) >= 2.0 * d_best {
            continue;
        }
        let dd = dc.d(q, centers.row(j));
        if dd < d_best {
            best = j;
            d_best = dd;
        }
    }
    (best as u32, d_best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::bounds::nearest_two;
    use crate::kmeans::KMeans;

    fn naive_labels(data: &Matrix, centers: &Matrix) -> (Vec<u32>, Vec<f64>) {
        let mut dc = DistCounter::new();
        let mut labels = Vec::with_capacity(data.rows());
        let mut dists = Vec::with_capacity(data.rows());
        for i in 0..data.rows() {
            let (c1, d1, _, _) = nearest_two(data.row(i), centers, &mut dc);
            labels.push(c1);
            dists.push(d1);
        }
        (labels, dists)
    }

    fn fit_model(data: &Matrix, k: usize, seed: u64) -> KMeansModel {
        KMeans::new(k)
            .algorithm(Algorithm::Hamerly)
            .seed(seed)
            .max_iter(30)
            .fit_model(data)
            .unwrap()
    }

    #[test]
    fn from_run_aggregates_counts_and_inertia() {
        let data = synth::gaussian_blobs(300, 3, 5, 0.4, 2);
        let model = fit_model(&data, 5, 3);
        assert_eq!(model.k(), 5);
        assert_eq!(model.dim(), 3);
        assert_eq!(model.counts().iter().sum::<u64>(), 300);
        assert_eq!(model.algorithm(), Algorithm::Hamerly);
        assert_eq!(model.seed(), 3);
        assert!(model.iterations() >= 1);
        // Inertia equals the run's SSE (same labels, same centers).
        let r = KMeans::new(5)
            .algorithm(Algorithm::Hamerly)
            .seed(3)
            .max_iter(30)
            .fit(&data)
            .unwrap();
        assert!((model.inertia() - r.sse(&data)).abs() < 1e-9 * (1.0 + model.inertia()));
    }

    #[test]
    fn predict_matches_naive_scan_in_every_mode() {
        let train = synth::gaussian_blobs(400, 4, 10, 0.6, 5);
        let queries = synth::gaussian_blobs(150, 4, 10, 1.2, 6);
        let model = fit_model(&train, 10, 7);
        let (want_labels, want_dists) = naive_labels(&queries, model.centers());
        for mode in [PredictMode::Auto, PredictMode::Tree, PredictMode::Scan] {
            let p = model.predict_opts(
                &queries,
                &PredictOptions { mode, ..Default::default() },
            );
            assert_eq!(p.labels, want_labels, "{}", mode.name());
            for (i, (a, b)) in p.distances.iter().zip(&want_dists).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{}: distance {i}",
                    mode.name()
                );
            }
        }
    }

    #[test]
    fn auto_mode_resolves_by_k() {
        let train = synth::gaussian_blobs(600, 3, 4, 0.5, 8);
        let small = fit_model(&train, 4, 1);
        let p = small.predict_opts(&train, &PredictOptions::default());
        assert_eq!(p.mode, PredictMode::Scan);
        let big = fit_model(&train, DEFAULT_PREDICT_AUTO_K, 1);
        let p = big.predict_opts(&train, &PredictOptions::default());
        assert_eq!(p.mode, PredictMode::Tree);
    }

    #[test]
    fn auto_k_cutoff_is_configurable() {
        let train = synth::gaussian_blobs(600, 3, 4, 0.5, 8);
        let model = fit_model(&train, 4, 1);
        // Default cutoff: k=4 resolves to the scan.
        assert_eq!(model.resolve_mode(PredictMode::Auto, DEFAULT_PREDICT_AUTO_K), PredictMode::Scan);
        // Lowering the cutoff to k flips Auto to the tree — and the labels
        // must not care which strategy answered.
        assert_eq!(model.resolve_mode(PredictMode::Auto, 4), PredictMode::Tree);
        let scan = model.predict_opts(&train, &PredictOptions::default());
        let tree = model.predict_opts(
            &train,
            &PredictOptions { auto_k: 4, ..Default::default() },
        );
        assert_eq!(scan.mode, PredictMode::Scan);
        assert_eq!(tree.mode, PredictMode::Tree);
        assert_eq!(scan.labels, tree.labels);
        // Explicit modes ignore the cutoff entirely.
        assert_eq!(model.resolve_mode(PredictMode::Scan, 1), PredictMode::Scan);
        assert_eq!(
            model.resolve_mode(PredictMode::Tree, usize::MAX),
            PredictMode::Tree
        );
    }

    #[test]
    fn checksum_matches_serialization_and_survives_roundtrip() {
        let train = synth::gaussian_blobs(200, 3, 5, 0.5, 21);
        let model = fit_model(&train, 5, 22);
        let bytes = model.to_bytes();
        let tail = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        assert_eq!(model.checksum(), tail);
        let loaded = KMeansModel::from_bytes(&bytes).unwrap();
        assert_eq!(loaded.checksum(), model.checksum());
        // A different model versions differently.
        let other = fit_model(&train, 4, 23);
        assert_ne!(other.checksum(), model.checksum());
    }

    #[test]
    fn prewarm_charges_prep_exactly_once() {
        let train = synth::gaussian_blobs(300, 3, 6, 0.5, 9);
        let model = fit_model(&train, 6, 2);
        let prep = model.prewarm(PredictMode::Scan, DEFAULT_PREDICT_AUTO_K);
        assert_eq!(prep, (6 * 5 / 2) as u64, "k(k-1)/2 inter-center");
        assert_eq!(model.prewarm(PredictMode::Scan, DEFAULT_PREDICT_AUTO_K), 0);
        // A prewarmed model's first predict charges no prep.
        let p = model.predict_opts(
            &train,
            &PredictOptions { mode: PredictMode::Scan, ..Default::default() },
        );
        assert_eq!(p.prep_evals, 0);
        // The tree index is independent and charges on its own first build.
        assert!(model.prewarm(PredictMode::Tree, DEFAULT_PREDICT_AUTO_K) > 0);
        assert_eq!(model.prewarm(PredictMode::Tree, DEFAULT_PREDICT_AUTO_K), 0);
    }

    #[test]
    fn prep_evals_charged_once() {
        let train = synth::gaussian_blobs(300, 3, 6, 0.5, 9);
        let model = fit_model(&train, 6, 2);
        let p1 = model.predict_opts(
            &train,
            &PredictOptions { mode: PredictMode::Scan, ..Default::default() },
        );
        assert_eq!(p1.prep_evals, (6 * 5 / 2) as u64, "k(k-1)/2 inter-center");
        let p2 = model.predict_opts(
            &train,
            &PredictOptions { mode: PredictMode::Scan, ..Default::default() },
        );
        assert_eq!(p2.prep_evals, 0, "cached index must not be re-charged");
        assert_eq!(p1.labels, p2.labels);
    }

    #[test]
    fn bytes_roundtrip_is_bit_identical() {
        let train = synth::gaussian_blobs(250, 5, 7, 0.5, 10);
        let model = fit_model(&train, 7, 11);
        let bytes = model.to_bytes();
        let back = KMeansModel::from_bytes(&bytes).unwrap();
        assert_eq!(back.k(), model.k());
        assert_eq!(back.dim(), model.dim());
        assert_eq!(back.counts(), model.counts());
        assert_eq!(back.algorithm(), model.algorithm());
        assert_eq!(back.seed(), model.seed());
        assert_eq!(back.iterations(), model.iterations());
        assert_eq!(back.converged(), model.converged());
        for (a, b) in back
            .centers()
            .as_slice()
            .iter()
            .zip(model.centers().as_slice())
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in back.cluster_sse().iter().zip(model.cluster_sse()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Re-serialization is byte-identical (stable format).
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn corrupt_and_truncated_files_fail_loudly() {
        let train = synth::gaussian_blobs(120, 2, 3, 0.5, 12);
        let model = fit_model(&train, 3, 13);
        let bytes = model.to_bytes();
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(KMeansModel::from_bytes(&bad).is_err());
        // Any single bit flip in the body trips the checksum.
        let mut flipped = bytes.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x40;
        let err = KMeansModel::from_bytes(&flipped).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // Truncation at every prefix length fails (never panics).
        for len in [0, 3, 4, 11, 20, bytes.len() - 9, bytes.len() - 1] {
            assert!(
                KMeansModel::from_bytes(&bytes[..len]).is_err(),
                "prefix of {len} bytes must not parse"
            );
        }
        // Trailing garbage fails too.
        let mut long = bytes.clone();
        long.extend_from_slice(&[0u8; 16]);
        assert!(KMeansModel::from_bytes(&long).is_err());
    }

    #[test]
    fn predict_mode_parse_roundtrip() {
        for m in [PredictMode::Auto, PredictMode::Tree, PredictMode::Scan] {
            assert_eq!(PredictMode::parse(m.name()), Some(m));
        }
        assert_eq!(PredictMode::parse("elkan"), Some(PredictMode::Scan));
        assert!(PredictMode::parse("quantum").is_none());
    }

    #[test]
    #[should_panic(expected = "query dimension")]
    fn predict_rejects_dimension_mismatch() {
        let train = synth::gaussian_blobs(100, 3, 2, 0.5, 14);
        let model = fit_model(&train, 2, 15);
        let wrong = Matrix::zeros(5, 4);
        model.predict(&wrong);
    }
}
