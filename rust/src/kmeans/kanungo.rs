//! Kanungo et al.'s filtering algorithm [8] — the k-d-tree baseline the
//! paper compares its cover tree approach against.
//!
//! Each iteration traverses the k-d tree top-down with a candidate center
//! set `Z`. At a node, the candidate closest to the cell midpoint (`z*`)
//! is found, then every other candidate `z` is pruned if the bisecting
//! hyperplane test shows the whole bounding box is closer to `z*`
//! (geometric pruning with the box corner extremal in direction `z - z*`;
//! see [`crate::tree::kdtree::is_farther`]). When one candidate remains,
//! the whole subtree is assigned at once using the node aggregates. The
//! dominance test costs two d-dimensional distance evaluations, which we
//! count — this is why Kanungo can exceed the Standard algorithm's count
//! on overlap-heavy data (the paper's KDD04 column: 1.450).
//!
//! The traversal itself — task decomposition, leaf scans, whole-subtree
//! settlement, and the parallel execution with its determinism contract —
//! lives in [`crate::kmeans::kdfilter`]; this module contributes only the
//! dominance prune rule.

use std::sync::Arc;
use std::time::Duration;

use crate::data::Matrix;
use crate::kmeans::bounds::CentroidAccum;
use crate::kmeans::driver::{DriverState, Fit, KMeansDriver};
use crate::kmeans::kdfilter::{self, PruneRule};
use crate::kmeans::{Algorithm, KMeansParams, Workspace};
use crate::metrics::{DistCounter, RunResult};
use crate::parallel::Parallelism;
use crate::tree::kdtree::{is_farther, KdNode};
use crate::tree::KdTree;

/// The hyperplane dominance prune of Kanungo et al.: find the candidate
/// closest to the cell midpoint, then drop every candidate the midpoint
/// winner dominates over the whole box.
pub(crate) struct DominancePrune;

impl PruneRule for DominancePrune {
    fn prune(
        &self,
        node: &KdNode,
        candidates: &[u32],
        centers: &Matrix,
        dist: &mut DistCounter,
        scratch: &mut [f64],
    ) -> Vec<u32> {
        // z* = candidate closest to the cell midpoint (ties: lowest index,
        // which the scan order provides).
        for (j, m) in scratch.iter_mut().enumerate() {
            *m = 0.5 * (node.bbox_min[j] + node.bbox_max[j]);
        }
        let mut z_star = candidates[0];
        let mut z_star_d = f64::INFINITY;
        for &z in candidates {
            let dd = dist.d(scratch, centers.row(z as usize));
            if dd < z_star_d {
                z_star_d = dd;
                z_star = z;
            }
        }

        // Prune candidates dominated by z* over the whole box. The corner
        // test evaluates two d-dim squared distances; count both.
        let mut remaining: Vec<u32> = Vec::with_capacity(candidates.len());
        for &z in candidates {
            if z == z_star {
                remaining.push(z);
                continue;
            }
            dist.add_bulk(2);
            if !is_farther(
                centers.row(z as usize),
                centers.row(z_star as usize),
                &node.bbox_min,
                &node.bbox_max,
            ) {
                remaining.push(z);
            }
        }
        remaining
    }
}

/// The filtering driver: the k-d tree plus the labels. The tree is shared
/// out of the [`Workspace`] cache, so sweeps amortize construction.
pub(crate) struct KanungoDriver<'a> {
    data: &'a Matrix,
    tree: Arc<KdTree>,
    labels: Vec<u32>,
    par: Parallelism,
}

impl<'a> KanungoDriver<'a> {
    pub(crate) fn new(
        data: &'a Matrix,
        tree: Arc<KdTree>,
        par: Parallelism,
    ) -> KanungoDriver<'a> {
        KanungoDriver {
            data,
            tree,
            labels: vec![u32::MAX; data.rows()],
            par,
        }
    }

    fn pass(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        kdfilter::filter_pass(
            &DominancePrune,
            self.data,
            &self.tree,
            centers,
            &mut self.labels,
            acc,
            dist,
            &self.par,
        )
    }
}

impl KMeansDriver for KanungoDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Kanungo
    }

    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(centers, acc, dist)
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(centers, acc, dist)
    }

    fn labels(&self) -> &[u32] {
        &self.labels
    }

    fn save_state(&self) -> Option<DriverState> {
        Some(DriverState::new(self.labels.clone()))
    }

    fn load_state(&mut self, state: &DriverState) -> anyhow::Result<()> {
        self.labels = state.labels_checked(self.data.rows())?.to_vec();
        Ok(())
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.labels
    }
}

/// Legacy shim: drive the filtering algorithm through the shared loop,
/// reusing (or building) the workspace's k-d tree.
pub fn run(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> RunResult {
    let (tree, fresh) = ws.kd_tree_arc(data, params.kd);
    // k-d construction computes no distances; only the time is charged.
    let build_time = if fresh { tree.build_time } else { Duration::ZERO };
    let par = ws.parallelism_opts(params.threads, params.pin_workers);
    Fit::from_driver(
        data,
        Box::new(KanungoDriver::new(data, tree, par)),
        init,
        params.max_iter,
        params.tol,
    )
    .with_build_cost(0, build_time)
    .run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(500, 3, 5, 1.0, 16);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 5, 10, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Kanungo);
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_k = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_k.labels, r_l.labels);
        assert_eq!(r_k.iterations, r_l.iterations);
    }

    #[test]
    fn saves_distances_on_low_dim_clustered_data() {
        let data = synth::istanbul(0.002, 17);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 20, 11, &mut dc);
        let params = KMeansParams {
            kd: crate::tree::KdTreeParams { leaf_size: 20, max_depth: 64 },
            ..KMeansParams::with_algorithm(Algorithm::Kanungo)
        };
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_k = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_k.labels, r_l.labels);
        assert!(
            r_k.distances < r_l.distances / 2,
            "kanungo {} vs lloyd {}",
            r_k.distances,
            r_l.distances
        );
    }

    #[test]
    fn workspace_reuse_skips_build_time() {
        let data = synth::gaussian_blobs(300, 3, 4, 0.5, 18);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 4, 12, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Kanungo);
        let mut ws = Workspace::new();
        let r1 = run(&data, &init_c, &params, &mut ws);
        let r2 = run(&data, &init_c, &params, &mut ws);
        assert!(r1.build_time >= r2.build_time);
        assert_eq!(r2.build_time, std::time::Duration::ZERO);
        assert_eq!(r1.labels, r2.labels);
    }
}
