//! Kanungo et al.'s filtering algorithm [8] — the k-d-tree baseline the
//! paper compares its cover tree approach against.
//!
//! Each iteration traverses the k-d tree top-down with a candidate center
//! set `Z`. At a node, the candidate closest to the cell midpoint (`z*`)
//! is found, then every other candidate `z` is pruned if the bisecting
//! hyperplane test shows the whole bounding box is closer to `z*`
//! (geometric pruning with the box corner extremal in direction `z - z*`;
//! see [`crate::tree::kdtree::is_farther`]). When one candidate remains,
//! the whole subtree is assigned at once using the node aggregates. The
//! dominance test costs two d-dimensional distance evaluations, which we
//! count — this is why Kanungo can exceed the Standard algorithm's count
//! on overlap-heavy data (the paper's KDD04 column: 1.450).

use std::sync::Arc;
use std::time::Duration;

use crate::data::Matrix;
use crate::kmeans::bounds::CentroidAccum;
use crate::kmeans::driver::{Fit, KMeansDriver};
use crate::kmeans::{Algorithm, KMeansParams, Workspace};
use crate::metrics::{DistCounter, RunResult};
use crate::tree::kdtree::{is_farther, KdNode};
use crate::tree::KdTree;

/// The filtering driver: the k-d tree plus the labels. The tree is shared
/// out of the [`Workspace`] cache, so sweeps amortize construction.
pub(crate) struct KanungoDriver<'a> {
    data: &'a Matrix,
    tree: Arc<KdTree>,
    labels: Vec<u32>,
    scratch_mid: Vec<f64>,
}

impl<'a> KanungoDriver<'a> {
    pub(crate) fn new(data: &'a Matrix, tree: Arc<KdTree>) -> KanungoDriver<'a> {
        KanungoDriver {
            data,
            tree,
            labels: vec![u32::MAX; data.rows()],
            scratch_mid: vec![0.0; data.cols()],
        }
    }

    fn pass(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let mut changed = 0usize;
        let all: Vec<u32> = (0..centers.rows() as u32).collect();
        filter(
            self.data,
            &self.tree.root,
            centers,
            &all,
            &mut self.labels,
            acc,
            dist,
            &mut changed,
            &mut self.scratch_mid,
        );
        changed
    }
}

impl KMeansDriver for KanungoDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Kanungo
    }

    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(centers, acc, dist)
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(centers, acc, dist)
    }

    fn labels(&self) -> &[u32] {
        &self.labels
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.labels
    }
}

/// Legacy shim: drive the filtering algorithm through the shared loop,
/// reusing (or building) the workspace's k-d tree.
pub fn run(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> RunResult {
    let (tree, fresh) = ws.kd_tree_arc(data, params.kd);
    // k-d construction computes no distances; only the time is charged.
    let build_time = if fresh { tree.build_time } else { Duration::ZERO };
    Fit::from_driver(
        data,
        Box::new(KanungoDriver::new(data, tree)),
        init,
        params.max_iter,
        params.tol,
    )
    .with_build_cost(0, build_time)
    .run()
}

/// Recursive filtering step.
#[allow(clippy::too_many_arguments)]
fn filter(
    data: &Matrix,
    node: &KdNode,
    centers: &Matrix,
    candidates: &[u32],
    labels: &mut [u32],
    acc: &mut CentroidAccum,
    dist: &mut DistCounter,
    changed: &mut usize,
    scratch_mid: &mut [f64],
) {
    if node.is_leaf() {
        // Scan the remaining candidates per point.
        for &pi in &node.points {
            let p = data.row(pi as usize);
            let mut best = candidates[0];
            let mut best_d = f64::INFINITY;
            for &z in candidates {
                let dd = dist.d(p, centers.row(z as usize));
                if dd < best_d || (dd == best_d && z < best) {
                    best_d = dd;
                    best = z;
                }
            }
            if labels[pi as usize] != best {
                labels[pi as usize] = best;
                *changed += 1;
            }
            acc.add_point(best as usize, p);
        }
        return;
    }

    // z* = candidate closest to the cell midpoint (ties: lowest index,
    // which the scan order provides).
    for (j, m) in scratch_mid.iter_mut().enumerate() {
        *m = 0.5 * (node.bbox_min[j] + node.bbox_max[j]);
    }
    let mut z_star = candidates[0];
    let mut z_star_d = f64::INFINITY;
    for &z in candidates {
        let dd = dist.d(scratch_mid, centers.row(z as usize));
        if dd < z_star_d {
            z_star_d = dd;
            z_star = z;
        }
    }

    // Prune candidates dominated by z* over the whole box. The corner
    // test evaluates two d-dim squared distances; count both.
    let mut remaining: Vec<u32> = Vec::with_capacity(candidates.len());
    for &z in candidates {
        if z == z_star {
            remaining.push(z);
            continue;
        }
        dist.add_bulk(2);
        if !is_farther(
            centers.row(z as usize),
            centers.row(z_star as usize),
            &node.bbox_min,
            &node.bbox_max,
        ) {
            remaining.push(z);
        }
    }

    if remaining.len() == 1 {
        // Assign the whole subtree to z* using the aggregates.
        let z = remaining[0] as usize;
        acc.add_aggregate(z, &node.sum, node.weight as f64);
        node.for_each_point(&mut |pi| {
            if labels[pi as usize] != z as u32 {
                labels[pi as usize] = z as u32;
                *changed += 1;
            }
        });
        return;
    }

    filter(
        data,
        node.left.as_ref().unwrap(),
        centers,
        &remaining,
        labels,
        acc,
        dist,
        changed,
        scratch_mid,
    );
    filter(
        data,
        node.right.as_ref().unwrap(),
        centers,
        &remaining,
        labels,
        acc,
        dist,
        changed,
        scratch_mid,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(500, 3, 5, 1.0, 16);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 5, 10, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Kanungo);
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_k = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_k.labels, r_l.labels);
        assert_eq!(r_k.iterations, r_l.iterations);
    }

    #[test]
    fn saves_distances_on_low_dim_clustered_data() {
        let data = synth::istanbul(0.002, 17);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 20, 11, &mut dc);
        let params = KMeansParams {
            kd: crate::tree::KdTreeParams { leaf_size: 20, max_depth: 64 },
            ..KMeansParams::with_algorithm(Algorithm::Kanungo)
        };
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_k = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_k.labels, r_l.labels);
        assert!(
            r_k.distances < r_l.distances / 2,
            "kanungo {} vs lloyd {}",
            r_k.distances,
            r_l.distances
        );
    }

    #[test]
    fn workspace_reuse_skips_build_time() {
        let data = synth::gaussian_blobs(300, 3, 4, 0.5, 18);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 4, 12, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Kanungo);
        let mut ws = Workspace::new();
        let r1 = run(&data, &init_c, &params, &mut ws);
        let r2 = run(&data, &init_c, &params, &mut ws);
        assert!(r1.build_time >= r2.build_time);
        assert_eq!(r2.build_time, std::time::Duration::ZERO);
        assert_eq!(r1.labels, r2.labels);
    }
}
