//! Kanungo et al.'s filtering algorithm [8] — the k-d-tree baseline the
//! paper compares its cover tree approach against.
//!
//! Each iteration traverses the k-d tree top-down with a candidate center
//! set `Z`. At a node, the candidate closest to the cell midpoint (`z*`)
//! is found, then every other candidate `z` is pruned if the bisecting
//! hyperplane test shows the whole bounding box is closer to `z*`
//! (geometric pruning with the box corner extremal in direction `z - z*`;
//! see [`crate::tree::kdtree::is_farther`]). When one candidate remains,
//! the whole subtree is assigned at once using the node aggregates. The
//! dominance test costs two d-dimensional distance evaluations, which we
//! count — this is why Kanungo can exceed the Standard algorithm's count
//! on overlap-heavy data (the paper's KDD04 column: 1.450).

use crate::data::Matrix;
use crate::kmeans::bounds::CentroidAccum;
use crate::kmeans::{KMeansParams, Workspace};
use crate::metrics::{DistCounter, IterationLog, RunResult, Stopwatch};
use crate::tree::kdtree::{is_farther, KdNode};

pub fn run(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> RunResult {
    let d = data.cols();
    let k = init.rows();

    // Build (or reuse) the index; fresh builds are charged to the result.
    let fresh = ws
        .kd
        .as_ref()
        .map(|t| t.params != params.kd)
        .unwrap_or(true);
    let tree = ws.kd_tree(data, params.kd);
    let (build_dist, build_time) = if fresh {
        (0, tree.build_time) // k-d construction computes no distances
    } else {
        (0, std::time::Duration::ZERO)
    };

    let sw = Stopwatch::start();
    let mut dist = DistCounter::new();
    let mut centers = init.clone();
    let mut labels = vec![u32::MAX; data.rows()];
    let mut acc = CentroidAccum::new(k, d);
    let mut movement: Vec<f64> = Vec::with_capacity(k);
    let mut log = IterationLog::new();
    let mut converged = false;
    let mut iterations = 0;

    let mut scratch_mid = vec![0.0; d];

    for iter in 1..=params.max_iter {
        iterations = iter;
        acc.clear();
        let mut changed = 0usize;
        let all: Vec<u32> = (0..k as u32).collect();
        filter(
            data,
            &tree.root,
            &centers,
            &all,
            &mut labels,
            &mut acc,
            &mut dist,
            &mut changed,
            &mut scratch_mid,
        );
        acc.update_centers(&mut centers, &mut dist, &mut movement);
        log.push(iter, dist.count(), sw.elapsed(), changed);
        if changed == 0 {
            converged = true;
            break;
        }
    }

    RunResult {
        labels,
        centers,
        iterations,
        distances: dist.count(),
        build_dist,
        time: sw.elapsed(),
        build_time,
        log,
        converged,
    }
}

/// Recursive filtering step.
#[allow(clippy::too_many_arguments)]
fn filter(
    data: &Matrix,
    node: &KdNode,
    centers: &Matrix,
    candidates: &[u32],
    labels: &mut [u32],
    acc: &mut CentroidAccum,
    dist: &mut DistCounter,
    changed: &mut usize,
    scratch_mid: &mut [f64],
) {
    if node.is_leaf() {
        // Scan the remaining candidates per point.
        for &pi in &node.points {
            let p = data.row(pi as usize);
            let mut best = candidates[0];
            let mut best_d = f64::INFINITY;
            for &z in candidates {
                let dd = dist.d(p, centers.row(z as usize));
                if dd < best_d || (dd == best_d && z < best) {
                    best_d = dd;
                    best = z;
                }
            }
            if labels[pi as usize] != best {
                labels[pi as usize] = best;
                *changed += 1;
            }
            acc.add_point(best as usize, p);
        }
        return;
    }

    // z* = candidate closest to the cell midpoint (ties: lowest index,
    // which the scan order provides).
    for (j, m) in scratch_mid.iter_mut().enumerate() {
        *m = 0.5 * (node.bbox_min[j] + node.bbox_max[j]);
    }
    let mut z_star = candidates[0];
    let mut z_star_d = f64::INFINITY;
    for &z in candidates {
        let dd = dist.d(scratch_mid, centers.row(z as usize));
        if dd < z_star_d {
            z_star_d = dd;
            z_star = z;
        }
    }

    // Prune candidates dominated by z* over the whole box. The corner
    // test evaluates two d-dim squared distances; count both.
    let mut remaining: Vec<u32> = Vec::with_capacity(candidates.len());
    for &z in candidates {
        if z == z_star {
            remaining.push(z);
            continue;
        }
        dist.add_bulk(2);
        if !is_farther(
            centers.row(z as usize),
            centers.row(z_star as usize),
            &node.bbox_min,
            &node.bbox_max,
        ) {
            remaining.push(z);
        }
    }

    if remaining.len() == 1 {
        // Assign the whole subtree to z* using the aggregates.
        let z = remaining[0] as usize;
        acc.add_aggregate(z, &node.sum, node.weight as f64);
        node.for_each_point(&mut |pi| {
            if labels[pi as usize] != z as u32 {
                labels[pi as usize] = z as u32;
                *changed += 1;
            }
        });
        return;
    }

    filter(
        data,
        node.left.as_ref().unwrap(),
        centers,
        &remaining,
        labels,
        acc,
        dist,
        changed,
        scratch_mid,
    );
    filter(
        data,
        node.right.as_ref().unwrap(),
        centers,
        &remaining,
        labels,
        acc,
        dist,
        changed,
        scratch_mid,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(500, 3, 5, 1.0, 16);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 5, 10, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Kanungo);
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_k = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_k.labels, r_l.labels);
        assert_eq!(r_k.iterations, r_l.iterations);
    }

    #[test]
    fn saves_distances_on_low_dim_clustered_data() {
        let data = synth::istanbul(0.002, 17);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 20, 11, &mut dc);
        let params = KMeansParams {
            kd: crate::tree::KdTreeParams { leaf_size: 20, max_depth: 64 },
            ..KMeansParams::with_algorithm(Algorithm::Kanungo)
        };
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_k = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_k.labels, r_l.labels);
        assert!(
            r_k.distances < r_l.distances / 2,
            "kanungo {} vs lloyd {}",
            r_k.distances,
            r_l.distances
        );
    }

    #[test]
    fn workspace_reuse_skips_build_time() {
        let data = synth::gaussian_blobs(300, 3, 4, 0.5, 18);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 4, 12, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Kanungo);
        let mut ws = Workspace::new();
        let r1 = run(&data, &init_c, &params, &mut ws);
        let r2 = run(&data, &init_c, &params, &mut ws);
        assert!(r1.build_time >= r2.build_time);
        assert_eq!(r2.build_time, std::time::Duration::ZERO);
        assert_eq!(r1.labels, r2.labels);
    }
}
