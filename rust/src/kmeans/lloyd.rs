//! The Standard k-means algorithm (Lloyd [11] / Steinhaus [23], paper §2.1)
//! — the baseline every metric in the evaluation is normalized against.
//!
//! Per iteration it computes all `n * k` point-center distances (Eq. 1);
//! the shared [`crate::kmeans::Fit`] loop then computes the means (Eq. 2)
//! and stops at the assignment fixpoint. The XLA backend variant, which
//! runs the same assign step through the AOT-compiled Pallas kernel, lives
//! in `crate::runtime::lloyd_xla` (behind the `xla` feature).

use crate::data::{Matrix, SourceView};
use crate::kmeans::bounds::{accumulate_in_order_src, CentroidAccum};
use crate::kmeans::driver::{DriverState, Fit, KMeansDriver};
use crate::kmeans::{Algorithm, KMeansParams};
use crate::metrics::{DistCounter, RunResult};
use crate::parallel::{Parallelism, SharedSlices};

/// The dense full-scan driver: no state beyond the labels. Streams: the
/// scan visits each worker's chunk range through the data source, so any
/// backend (in-RAM, mmap, chunked) drives it — with identical bits, since
/// the per-point work and its ascending order don't depend on how the
/// source blocks the range.
pub(crate) struct LloydDriver<'a> {
    src: SourceView<'a>,
    labels: Vec<u32>,
    par: Parallelism,
}

impl<'a> LloydDriver<'a> {
    pub(crate) fn new(data: &'a Matrix, par: Parallelism) -> LloydDriver<'a> {
        LloydDriver::from_source(data.into(), par)
    }

    pub(crate) fn from_source(src: SourceView<'a>, par: Parallelism) -> LloydDriver<'a> {
        LloydDriver { src, labels: vec![u32::MAX; src.rows()], par }
    }

    fn scan(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let src = self.src;
        let n = src.rows();
        let cols = src.cols();
        let k = centers.rows();
        let mut changed = 0usize;
        {
            // Parallel label pass: chunk workers write disjoint label
            // ranges; each point's result depends only on its own prior
            // label, so any chunk layout reproduces the sequential scan.
            let labels_sh = SharedSlices::new(&mut self.labels);
            let results = self.par.map_chunks(n, |r| {
                let labels = unsafe { labels_sh.range(r.clone()) };
                let mut dc = DistCounter::new();
                let mut changed = 0usize;
                src.visit(r.clone(), |start, block| {
                    for (off, p) in block.chunks_exact(cols).enumerate() {
                        let j = start + off - r.start;
                        // Nearest center, ties to the lowest index
                        // (strict <).
                        let mut best = 0u32;
                        let mut best_d = f64::INFINITY;
                        for c in 0..k {
                            let dd = dc.d(p, centers.row(c));
                            if dd < best_d {
                                best_d = dd;
                                best = c as u32;
                            }
                        }
                        if labels[j] != best {
                            labels[j] = best;
                            changed += 1;
                        }
                    }
                });
                (changed, dc.count())
            });
            for (ch, count) in results {
                changed += ch;
                dist.add_bulk(count);
            }
        }
        // Center sums in canonical point order: bit-identical to the
        // sequential accumulation at every thread count.
        accumulate_in_order_src(src, &self.labels, acc);
        changed
    }
}

impl KMeansDriver for LloydDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Standard
    }

    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.scan(centers, acc, dist)
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.scan(centers, acc, dist)
    }

    fn labels(&self) -> &[u32] {
        &self.labels
    }

    fn save_state(&self) -> Option<DriverState> {
        Some(DriverState::new(self.labels.clone()))
    }

    fn load_state(&mut self, state: &DriverState) -> anyhow::Result<()> {
        self.labels = state.labels_checked(self.src.rows())?.to_vec();
        Ok(())
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.labels
    }
}

/// Legacy shim: drive the Standard algorithm through the shared loop.
pub fn run(data: &Matrix, init: &Matrix, params: &KMeansParams) -> RunResult {
    Fit::from_driver(
        data,
        Box::new(LloydDriver::new(data, Parallelism::new(params.threads))),
        init,
        params.max_iter,
        params.tol,
    )
    .run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn separates_clean_blobs() {
        let data = synth::gaussian_blobs(300, 2, 3, 0.05, 1);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 3, 1, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Standard);
        let r = run(&data, &init_c, &params);
        assert!(r.converged);
        // blobs are generated round-robin: points i, i+3, i+6 share a blob
        for i in 0..3 {
            for j in (i..300).step_by(3).take(20) {
                assert_eq!(r.labels[j], r.labels[i]);
            }
        }
    }

    #[test]
    fn counts_nk_distances_per_iteration() {
        let data = synth::gaussian_blobs(100, 2, 2, 0.3, 2);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 2, 1, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Standard);
        let r = run(&data, &init_c, &params);
        // n*k assignment distances + <= k movement distances per iteration
        let per_iter_min = (100 * 2) as u64;
        let per_iter_max = (100 * 2 + 2) as u64;
        let iters = r.iterations as u64;
        assert!(r.distances >= per_iter_min * iters);
        assert!(r.distances <= per_iter_max * iters);
    }

    #[test]
    fn fixpoint_means_stable_sse() {
        let data = synth::gaussian_blobs(200, 3, 4, 0.5, 3);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 4, 2, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Standard);
        let r = run(&data, &init_c, &params);
        assert!(r.converged);
        // Re-running from the final centers must not change anything
        // (iteration 1 populates labels, iteration 2 confirms fixpoint).
        let r2 = run(&data, &r.centers, &params);
        assert_eq!(r2.iterations, 2);
        assert_eq!(r2.labels, r.labels);
    }

    #[test]
    fn k_equals_one() {
        let data = synth::gaussian_blobs(50, 2, 2, 0.5, 4);
        let init_c = data.select_rows(&[0]);
        let params = KMeansParams::with_algorithm(Algorithm::Standard);
        let r = run(&data, &init_c, &params);
        assert!(r.converged);
        assert!(r.labels.iter().all(|&l| l == 0));
        // center is the global mean
        let mut mean = vec![0.0; 2];
        for row in data.iter_rows() {
            mean[0] += row[0];
            mean[1] += row[1];
        }
        mean[0] /= 50.0;
        mean[1] /= 50.0;
        assert!((r.centers.get(0, 0) - mean[0]).abs() < 1e-9);
    }

    #[test]
    fn respects_max_iter() {
        let data = synth::kdd04(0.0008, 5);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 10, 1, &mut dc);
        let params = KMeansParams {
            max_iter: 2,
            ..KMeansParams::with_algorithm(Algorithm::Standard)
        };
        let r = run(&data, &init_c, &params);
        assert_eq!(r.iterations, 2);
        assert_eq!(r.log.len(), 2);
    }
}
