//! The Standard k-means algorithm (Lloyd [11] / Steinhaus [23], paper §2.1)
//! — the baseline every metric in the evaluation is normalized against.
//!
//! Per iteration it computes all `n * k` point-center distances (Eq. 1),
//! then the means (Eq. 2), and stops at the assignment fixpoint. The XLA
//! backend variant, which runs the same assign step through the AOT-
//! compiled Pallas kernel, lives in [`crate::runtime::lloyd_xla`].

use crate::data::Matrix;
use crate::kmeans::bounds::CentroidAccum;
use crate::kmeans::KMeansParams;
use crate::metrics::{DistCounter, IterationLog, RunResult, Stopwatch};

pub fn run(data: &Matrix, init: &Matrix, params: &KMeansParams) -> RunResult {
    let n = data.rows();
    let d = data.cols();
    let k = init.rows();
    let sw = Stopwatch::start();
    let mut dist = DistCounter::new();

    let mut centers = init.clone();
    let mut labels = vec![u32::MAX; n];
    let mut acc = CentroidAccum::new(k, d);
    let mut movement: Vec<f64> = Vec::with_capacity(k);
    let mut log = IterationLog::new();
    let mut converged = false;
    let mut iterations = 0;

    for iter in 1..=params.max_iter {
        iterations = iter;
        acc.clear();
        let mut changed = 0usize;

        for i in 0..n {
            let p = data.row(i);
            // Nearest center, ties to the lowest index (strict <).
            let mut best = 0u32;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let dd = dist.d(p, centers.row(c));
                if dd < best_d {
                    best_d = dd;
                    best = c as u32;
                }
            }
            if labels[i] != best {
                labels[i] = best;
                changed += 1;
            }
            acc.add_point(best as usize, p);
        }

        acc.update_centers(&mut centers, &mut dist, &mut movement);
        log.push(iter, dist.count(), sw.elapsed(), changed);
        if changed == 0 {
            converged = true;
            break;
        }
    }

    RunResult {
        labels,
        centers,
        iterations,
        distances: dist.count(),
        build_dist: 0,
        time: sw.elapsed(),
        build_time: std::time::Duration::ZERO,
        log,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn separates_clean_blobs() {
        let data = synth::gaussian_blobs(300, 2, 3, 0.05, 1);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 3, 1, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Standard);
        let r = run(&data, &init_c, &params);
        assert!(r.converged);
        // blobs are generated round-robin: points i, i+3, i+6 share a blob
        for i in 0..3 {
            for j in (i..300).step_by(3).take(20) {
                assert_eq!(r.labels[j], r.labels[i]);
            }
        }
    }

    #[test]
    fn counts_nk_distances_per_iteration() {
        let data = synth::gaussian_blobs(100, 2, 2, 0.3, 2);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 2, 1, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Standard);
        let r = run(&data, &init_c, &params);
        // n*k assignment distances + <= k movement distances per iteration
        let per_iter_min = (100 * 2) as u64;
        let per_iter_max = (100 * 2 + 2) as u64;
        let iters = r.iterations as u64;
        assert!(r.distances >= per_iter_min * iters);
        assert!(r.distances <= per_iter_max * iters);
    }

    #[test]
    fn fixpoint_means_stable_sse() {
        let data = synth::gaussian_blobs(200, 3, 4, 0.5, 3);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 4, 2, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Standard);
        let r = run(&data, &init_c, &params);
        assert!(r.converged);
        // Re-running from the final centers must not change anything
        // (iteration 1 populates labels, iteration 2 confirms fixpoint).
        let r2 = run(&data, &r.centers, &params);
        assert_eq!(r2.iterations, 2);
        assert_eq!(r2.labels, r.labels);
    }

    #[test]
    fn k_equals_one() {
        let data = synth::gaussian_blobs(50, 2, 2, 0.5, 4);
        let init_c = data.select_rows(&[0]);
        let params = KMeansParams::with_algorithm(Algorithm::Standard);
        let r = run(&data, &init_c, &params);
        assert!(r.converged);
        assert!(r.labels.iter().all(|&l| l == 0));
        // center is the global mean
        let mut mean = vec![0.0; 2];
        for row in data.iter_rows() {
            mean[0] += row[0];
            mean[1] += row[1];
        }
        mean[0] /= 50.0;
        mean[1] /= 50.0;
        assert!((r.centers.get(0, 0) - mean[0]).abs() < 1e-9);
    }

    #[test]
    fn respects_max_iter() {
        let data = synth::kdd04(0.0008, 5);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 10, 1, &mut dc);
        let params = KMeansParams {
            max_iter: 2,
            ..KMeansParams::with_algorithm(Algorithm::Standard)
        };
        let r = run(&data, &init_c, &params);
        assert_eq!(r.iterations, 2);
        assert_eq!(r.log.len(), 2);
    }
}
