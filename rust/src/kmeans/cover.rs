//! Cover-means (paper §3): k-means assignment over the cover tree,
//! assigning whole subtrees at once and pruning candidate centers with the
//! triangle inequality.
//!
//! Per iteration the tree is traversed from the root with a shrinking
//! candidate set `A_x`:
//!
//! * **Eq. 9** — while computing the distances from a routing object `p_x`
//!   to the candidates, a candidate `c_j` is dropped without computing its
//!   distance when `d(c_best, c_j) >= 2 d(p_x, c_best) + 2 r_x` (the
//!   Phillips filter lifted to a ball of radius `r_x`);
//! * **Eq. 10** — the whole subtree is assigned to `c_1` when
//!   `d(p_x,c_1) + r_x <= d(p_x,c_2) - r_x`;
//! * **Eq. 11** — otherwise candidates with
//!   `d(p_x,c_i) - r_x > d(p_x,c_1) + r_x` are pruned;
//! * **Eqs. 12-14** — child nodes first try to inherit the parent's
//!   assignment using only the stored parent distance `d(p_x,p_y)` and the
//!   child radius (Eq. 12), then with one fresh distance `d(p_y,c_1)`
//!   (Eq. 13), pruning the candidate set with Eq. 14 before recursing.
//!
//! Reassigned subtrees move their stored aggregates `(S_x, w_x)` between
//! cluster accumulators in O(d) (§3.2). Every assignment also records the
//! upper/lower bounds and second-nearest identity of Eqs. 15-18, which is
//! what the Hybrid algorithm (§3.4) hands to Shallot.

use std::sync::Arc;

use crate::data::Matrix;
use crate::kmeans::bounds::{CentroidAccum, InterCenter};
use crate::kmeans::driver::{DriverState, Fit, KMeansDriver};
use crate::kmeans::{Algorithm, KMeansParams, Workspace};
use crate::metrics::{DistCounter, RunResult};
use crate::parallel::Parallelism;
use crate::tree::covertree::{CoverTree, Node};

/// Raw-pointer view of the per-point outputs (labels and the Eqs. 15-18
/// hand-off bounds). The cover tree partitions the point set across
/// subtrees, so concurrent tasks write disjoint indices; the borrow
/// checker cannot see that, hence the unsafe accessors.
#[derive(Clone, Copy)]
struct PointSink {
    labels: *mut u32,
    upper: *mut f64,
    lower: *mut f64,
    second: *mut u32,
}

unsafe impl Send for PointSink {}
unsafe impl Sync for PointSink {}

impl PointSink {
    fn new(
        labels: &mut [u32],
        upper: &mut [f64],
        lower: &mut [f64],
        second: &mut [u32],
    ) -> PointSink {
        PointSink {
            labels: labels.as_mut_ptr(),
            upper: upper.as_mut_ptr(),
            lower: lower.as_mut_ptr(),
            second: second.as_mut_ptr(),
        }
    }

    /// # Safety: `i` must be owned by the calling task (disjoint subtrees).
    #[inline]
    unsafe fn label(&self, i: usize) -> u32 {
        *self.labels.add(i)
    }

    /// # Safety: `i` must be owned by the calling task (disjoint subtrees).
    #[inline]
    unsafe fn set(&self, i: usize, label: u32, u: f64, l: f64, sec: u32) {
        *self.labels.add(i) = label;
        *self.upper.add(i) = u;
        *self.lower.add(i) = l;
        *self.second.add(i) = sec;
    }
}

/// Mutable per-iteration view shared by the traversal. Each task of the
/// parallel decomposition owns one `Ctx` with its own accumulator and
/// distance counter; the per-point writes go through the shared
/// [`PointSink`].
struct Ctx<'a> {
    data: &'a Matrix,
    centers: &'a Matrix,
    ic: &'a InterCenter,
    sink: PointSink,
    acc: &'a mut CentroidAccum,
    dist: &'a mut DistCounter,
    changed: usize,
    /// Scratch buffers recycled across nodes (§Perf: the traversal is
    /// allocation-free in steady state; buffers grow to the candidate-set
    /// high-water mark and are reused down the recursion).
    cand_pool: Vec<Vec<Cand>>,
    id_pool: Vec<Vec<u32>>,
}

/// Perf A/B switch: `COVERMEANS_NO_POOL=1` disables scratch recycling so
/// the allocation cost of the naive traversal can be measured against the
/// pooled default.
fn pool_disabled() -> bool {
    static DISABLED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *DISABLED.get_or_init(|| std::env::var_os("COVERMEANS_NO_POOL").is_some())
}

impl Ctx<'_> {
    #[inline]
    fn take_cands(&mut self) -> Vec<Cand> {
        if pool_disabled() {
            return Vec::new();
        }
        self.cand_pool.pop().unwrap_or_default()
    }

    #[inline]
    fn put_cands(&mut self, mut v: Vec<Cand>) {
        v.clear();
        self.cand_pool.push(v);
    }

    #[inline]
    fn take_ids(&mut self) -> Vec<u32> {
        if pool_disabled() {
            return Vec::new();
        }
        self.id_pool.pop().unwrap_or_default()
    }

    #[inline]
    fn put_ids(&mut self, mut v: Vec<u32>) {
        v.clear();
        self.id_pool.push(v);
    }
}

/// A candidate center with its computed distance to the current routing
/// object.
#[derive(Clone, Copy, Debug)]
struct Cand {
    c: u32,
    d: f64,
}

/// One unit of the parallel decomposition: a subtree visit with its
/// already-computed candidate set and inherited lower bound.
struct Task<'t> {
    node: &'t Node,
    cands: Vec<Cand>,
    lb: f64,
}

/// The expansion stops splitting once this many tasks exist. Fixed (never
/// derived from the thread count) so the task list — and therefore the
/// accumulator merge order — is a function of the tree and centers only.
const TASK_TARGET: usize = 64;
/// Subtrees lighter than this are not worth splitting further.
const MIN_TASK_WEIGHT: u32 = 256;

/// Run one full assignment pass over the tree. Returns the number of
/// points whose assignment changed. Exposed for the Hybrid algorithm.
///
/// The pass always runs the same two phases regardless of thread count:
/// a sequential expansion that peels the top of the tree into at most
/// ~[`TASK_TARGET`] subtree tasks (charging its distances to the caller's
/// counter), then the tasks themselves — concurrently when `par` has the
/// budget, inline otherwise — each filling a private accumulator that is
/// merged back in task order. `threads = N` is therefore byte-identical
/// to `threads = 1`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn assign_pass(
    data: &Matrix,
    tree: &CoverTree,
    centers: &Matrix,
    ic: &InterCenter,
    labels: &mut [u32],
    upper: &mut [f64],
    lower: &mut [f64],
    second: &mut [u32],
    acc: &mut CentroidAccum,
    dist: &mut DistCounter,
    par: &Parallelism,
) -> usize {
    let k = centers.rows();
    let d = data.cols();
    let sink = PointSink::new(labels, upper, lower, second);
    let root = &tree.root;
    let mut changed;
    let tasks = {
        let mut ctx = Ctx {
            data,
            centers,
            ic,
            sink,
            acc,
            dist,
            changed: 0,
            cand_pool: Vec::new(),
            id_pool: Vec::new(),
        };
        // Root candidates: compute distances with the Eq. 9 running
        // filter.
        let all: Vec<u32> = (0..k as u32).collect();
        let p = data.row(root.routing as usize);
        let mut lb = f64::INFINITY;
        let mut cands = Vec::new();
        compute_candidates(&mut ctx, p, root.radius, &all, None, &mut lb, &mut cands);
        // Expansion: repeatedly visit the heaviest splittable task's node
        // (assigning what Eqs. 10-13 settle outright) and spill the
        // children that still need a recursive visit back into the list.
        let mut tasks: Vec<Task> = vec![Task { node: root, cands, lb }];
        crate::parallel::expand_tasks(
            &mut tasks,
            TASK_TARGET,
            |t| {
                (!t.node.children.is_empty() && t.node.weight >= MIN_TASK_WEIGHT)
                    .then_some(t.node.weight)
            },
            |t, out| assign_node(&mut ctx, t.node, &t.cands, t.lb, Some(out)),
        );
        changed = ctx.changed;
        tasks
    };
    // Task phase: private accumulators, merged in task order below.
    let results = par.run_tasks(tasks, |task| {
        let mut task_acc = CentroidAccum::new(k, d);
        let mut dc = DistCounter::new();
        let mut ctx = Ctx {
            data,
            centers,
            ic,
            sink,
            acc: &mut task_acc,
            dist: &mut dc,
            changed: 0,
            cand_pool: Vec::new(),
            id_pool: Vec::new(),
        };
        assign_node(&mut ctx, task.node, &task.cands, task.lb, None);
        let task_changed = ctx.changed;
        (task_acc, dc.count(), task_changed)
    });
    for (task_acc, count, task_changed) in results {
        acc.merge(&task_acc);
        dist.add_bulk(count);
        changed += task_changed;
    }
    changed
}

/// Compute distances from routing object `p` to the given candidate ids,
/// dropping candidates via Eq. 9 as the running best improves. `warm`
/// optionally seeds the running best with an already-computed candidate
/// (the parent's nearest, Eq. 13's tightening). Pruned candidates lower
/// `lb` (a valid lower bound on their distance to any point in the ball).
#[allow(clippy::too_many_arguments)]
fn compute_candidates(
    ctx: &mut Ctx,
    p: &[f64],
    radius: f64,
    ids: &[u32],
    warm: Option<Cand>,
    lb: &mut f64,
    out: &mut Vec<Cand>,
) {
    out.clear();
    out.reserve(ids.len() + warm.is_some() as usize);
    let (mut best_c, mut best_d) = match warm {
        Some(w) => {
            out.push(w);
            (w.c, w.d)
        }
        None => (u32::MAX, f64::INFINITY),
    };
    for &j in ids {
        if j == best_c {
            continue;
        }
        if best_c != u32::MAX {
            // Eq. 9: c_j cannot be nearest for any q in the ball.
            let cc = ctx.ic.d(best_c as usize, j as usize);
            if cc >= 2.0 * (best_d + radius) {
                // d(q, c_j) >= cc - d(q, c_best) >= cc - best_d - radius.
                *lb = lb.min(cc - best_d - radius);
                continue;
            }
        }
        let dj = ctx.dist.d(p, ctx.centers.row(j as usize));
        out.push(Cand { c: j, d: dj });
        if dj < best_d || (dj == best_d && j < best_c) {
            best_d = dj;
            best_c = j;
        }
    }
}

/// Best and second-best candidates (by distance; ties to lowest id).
fn top2(cands: &[Cand]) -> (Cand, Option<Cand>) {
    debug_assert!(!cands.is_empty());
    let mut c1 = cands[0];
    let mut c2: Option<Cand> = None;
    for &cand in &cands[1..] {
        if cand.d < c1.d || (cand.d == c1.d && cand.c < c1.c) {
            c2 = Some(c1);
            c1 = cand;
        } else if c2.map(|s| cand.d < s.d).unwrap_or(true) {
            c2 = Some(cand);
        }
    }
    (c1, c2)
}

/// Assign the whole subtree under `node` to center `c1`, moving aggregates
/// and recording the hand-off bounds (u, l, second) for every point.
fn assign_subtree(ctx: &mut Ctx, node: &Node, c1: u32, u: f64, l: f64, sec: u32) {
    ctx.acc.add_aggregate(c1 as usize, &node.sum, node.weight as f64);
    let sink = ctx.sink;
    let mut changed = 0usize;
    node.for_each_point(&mut |pi| {
        let i = pi as usize;
        // Safety: every point index occurs in exactly one subtree, and
        // tasks are disjoint subtrees.
        unsafe {
            if sink.label(i) != c1 {
                changed += 1;
            }
            sink.set(i, c1, u, l, sec);
        }
    });
    ctx.changed += changed;
}

/// Assign a single point.
fn assign_point(ctx: &mut Ctx, pi: u32, c1: u32, u: f64, l: f64, sec: u32) {
    let i = pi as usize;
    ctx.acc.add_point(c1 as usize, ctx.data.row(i));
    // Safety: singletons belong to exactly one node; tasks are disjoint.
    unsafe {
        if ctx.sink.label(i) != c1 {
            ctx.changed += 1;
        }
        ctx.sink.set(i, c1, u, l, sec);
    }
}

/// Recursive node assignment. `cands` are the computed (and Eq. 9
/// filtered) candidate distances at this node's routing object;
/// `inherited_lb` is a valid lower bound on the distance from any point in
/// this subtree to every candidate dropped along the path from the root.
///
/// With `spill == None` children are visited by direct recursion. During
/// the expansion phase `spill` collects the children that would recurse
/// as [`Task`]s instead — the node's own work (Eqs. 10-13 settlements and
/// singleton assignment) happens identically either way.
fn assign_node<'t>(
    ctx: &mut Ctx,
    node: &'t Node,
    cands: &[Cand],
    inherited_lb: f64,
    mut spill: Option<&mut Vec<Task<'t>>>,
) {
    let (c1, c2) = top2(cands);
    let r = node.radius;
    let (d2, sec) = match c2 {
        Some(s) => (s.d, s.c),
        None => (f64::INFINITY, c1.c),
    };

    // Eq. 10: the whole subtree is closest to c1.
    if cands.len() == 1 || c1.d + r <= d2 - r {
        let l = (d2 - r).min(inherited_lb);
        assign_subtree(ctx, node, c1.c, c1.d + r, l, sec);
        return;
    }

    // Eq. 11: prune candidates that cannot be nearest anywhere in the ball.
    let mut pruned = ctx.take_cands();
    let mut lb = inherited_lb;
    for &cand in cands {
        if cand.d - r > c1.d + r {
            lb = lb.min(cand.d - r);
        } else {
            pruned.push(cand);
        }
    }

    // Singletons: children of radius 0 at stored distance dq.
    for &(pi, dq) in &node.singletons {
        assign_singleton(ctx, pi, dq, &pruned, c1, d2, sec, lb);
    }

    // Child nodes.
    for child in &node.children {
        let dxy = child.parent_dist;
        let ry = child.radius;

        if child.routing == node.routing {
            // Self-child: identical routing object, distances carry over;
            // only the radius shrank. Re-run the tests on the same cands.
            match spill.as_deref_mut() {
                Some(out) => out.push(Task { node: child, cands: pruned.clone(), lb }),
                None => assign_node(ctx, child, &pruned, lb, None),
            }
            continue;
        }

        // Eq. 12: assign the child using only stored tree distances.
        if c1.d + dxy + ry <= d2 - dxy - ry {
            let l = (d2 - dxy - ry).min(lb);
            assign_subtree(ctx, child, c1.c, c1.d + dxy + ry, l, sec);
            continue;
        }

        // Eq. 13: one fresh distance to the parent's nearest.
        let py = ctx.data.row(child.routing as usize);
        let dy1 = ctx.dist.d(py, ctx.centers.row(c1.c as usize));
        if dy1 + ry <= d2 - dxy - ry {
            let l = (d2 - dxy - ry).min(lb);
            assign_subtree(ctx, child, c1.c, dy1 + ry, l, sec);
            continue;
        }

        // Eq. 14: prune candidates for the child, then recompute the
        // survivors' distances at p_y (Eq. 9 filter, warm-started at c1).
        let mut child_lb = lb;
        let mut survivor_ids = ctx.take_ids();
        for &cand in &pruned {
            if cand.c == c1.c {
                continue; // warm start carries it
            }
            if cand.d - dxy - ry > dy1 + ry {
                child_lb = child_lb.min(cand.d - dxy - ry);
            } else {
                survivor_ids.push(cand.c);
            }
        }
        let warm = Cand { c: c1.c, d: dy1 };
        let mut child_cands = ctx.take_cands();
        compute_candidates(
            ctx,
            py,
            ry,
            &survivor_ids,
            Some(warm),
            &mut child_lb,
            &mut child_cands,
        );
        ctx.put_ids(survivor_ids);
        match spill.as_deref_mut() {
            Some(out) => out.push(Task { node: child, cands: child_cands, lb: child_lb }),
            None => {
                assign_node(ctx, child, &child_cands, child_lb, None);
                ctx.put_cands(child_cands);
            }
        }
    }
    ctx.put_cands(pruned);
}

/// A singleton is a radius-0 child at stored distance `dq` from the
/// routing object: Eqs. 12-14 with `r_y = 0`, then an exact scan.
#[allow(clippy::too_many_arguments)]
fn assign_singleton(
    ctx: &mut Ctx,
    pi: u32,
    dq: f64,
    cands: &[Cand],
    c1: Cand,
    d2: f64,
    sec: u32,
    inherited_lb: f64,
) {
    // Eq. 12 (r_y = 0): no computation at all.
    if c1.d + dq <= d2 - dq {
        let l = (d2 - dq).min(inherited_lb);
        assign_point(ctx, pi, c1.c, c1.d + dq, l, sec);
        return;
    }
    let q = ctx.data.row(pi as usize);
    // Eq. 13: exact distance to the inherited nearest only.
    let dq1 = ctx.dist.d(q, ctx.centers.row(c1.c as usize));
    if dq1 <= d2 - dq {
        let l = (d2 - dq).min(inherited_lb);
        assign_point(ctx, pi, c1.c, dq1, l, sec);
        return;
    }
    // Eq. 14 prune + Eq. 9 running filter, then exact top-2.
    let mut best = Cand { c: c1.c, d: dq1 };
    let mut second_d = f64::INFINITY;
    let mut second_c = sec;
    let mut lb = inherited_lb;
    for &cand in cands {
        if cand.c == c1.c {
            continue;
        }
        // Eq. 14 with r_y = 0: skip without computing.
        if cand.d - dq > dq1 {
            lb = lb.min(cand.d - dq);
            continue;
        }
        // Eq. 9 with r = 0 against the running best.
        let cc = ctx.ic.d(best.c as usize, cand.c as usize);
        if cc >= 2.0 * best.d {
            lb = lb.min(cc - best.d);
            continue;
        }
        let dj = ctx.dist.d(q, ctx.centers.row(cand.c as usize));
        if dj < best.d || (dj == best.d && cand.c < best.c) {
            second_d = best.d;
            second_c = best.c;
            best = Cand { c: cand.c, d: dj };
        } else if dj < second_d {
            second_d = dj;
            second_c = cand.c;
        }
    }
    let l = second_d.min(lb);
    assign_point(ctx, pi, best.c, best.d, l, second_c);
}

/// One full Cover-means iteration: inter-center distances (sharded over
/// the pool at large k), then the tree assignment pass. Shared with the
/// Hybrid driver's tree phase.
#[allow(clippy::too_many_arguments)]
pub(crate) fn iterate_pass(
    data: &Matrix,
    tree: &CoverTree,
    centers: &Matrix,
    labels: &mut [u32],
    upper: &mut [f64],
    lower: &mut [f64],
    second: &mut [u32],
    acc: &mut CentroidAccum,
    dist: &mut DistCounter,
    par: &Parallelism,
) -> usize {
    let ic = InterCenter::compute_par(centers, dist, par);
    assign_pass(
        data, tree, centers, &ic, labels, upper, lower, second, acc, dist, par,
    )
}

/// The tree-at-once driver: the cover tree plus per-point labels and the
/// Eqs. 15-18 hand-off bounds (kept fresh every pass as a by-product).
pub(crate) struct CoverDriver<'a> {
    data: &'a Matrix,
    tree: Arc<CoverTree>,
    labels: Vec<u32>,
    upper: Vec<f64>,
    lower: Vec<f64>,
    second: Vec<u32>,
    par: Parallelism,
}

impl<'a> CoverDriver<'a> {
    pub(crate) fn new(
        data: &'a Matrix,
        tree: Arc<CoverTree>,
        par: Parallelism,
    ) -> CoverDriver<'a> {
        let n = data.rows();
        CoverDriver {
            data,
            tree,
            labels: vec![u32::MAX; n],
            upper: vec![0.0f64; n],
            lower: vec![0.0f64; n],
            second: vec![0u32; n],
            par,
        }
    }

    fn pass(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        iterate_pass(
            self.data,
            &self.tree,
            centers,
            &mut self.labels,
            &mut self.upper,
            &mut self.lower,
            &mut self.second,
            acc,
            dist,
            &self.par,
        )
    }
}

impl KMeansDriver for CoverDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::CoverMeans
    }

    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(centers, acc, dist)
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(centers, acc, dist)
    }

    fn labels(&self) -> &[u32] {
        &self.labels
    }

    fn save_state(&self) -> Option<DriverState> {
        // Bounds are refreshed by every tree pass, but the vectors are
        // saved anyway: the snapshot then matches the Shallot layout the
        // Hybrid hand-off produces, and costs nothing extra on resume.
        Some(
            DriverState::new(self.labels.clone())
                .with_f64(self.upper.clone())
                .with_f64(self.lower.clone())
                .with_u32(self.second.clone()),
        )
    }

    fn load_state(&mut self, state: &DriverState) -> anyhow::Result<()> {
        let n = self.data.rows();
        self.labels = state.labels_checked(n)?.to_vec();
        self.upper = state.f64_slot(0, n, "upper bounds")?.to_vec();
        self.lower = state.f64_slot(1, n, "lower bounds")?.to_vec();
        self.second = state.u32_slot(0, n, "second-nearest indices")?.to_vec();
        Ok(())
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.labels
    }
}

/// Legacy shim: drive Cover-means through the shared loop, reusing (or
/// building) the workspace's cover tree.
pub fn run(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> RunResult {
    let par = ws.parallelism(params.threads);
    let (tree, fresh) = ws.cover_tree_arc_par(data, params.cover, &par);
    let (build_dist, build_time) = if fresh {
        (tree.build_distances, tree.build_time)
    } else {
        (0, std::time::Duration::ZERO)
    };
    Fit::from_driver(
        data,
        Box::new(CoverDriver::new(data, tree, par)),
        init,
        params.max_iter,
        params.tol,
    )
    .with_build_cost(build_dist, build_time)
    .run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;
    use crate::tree::CoverTreeParams;

    fn params_small_leaf() -> KMeansParams {
        KMeansParams {
            cover: CoverTreeParams { scale_factor: 1.2, min_node_size: 10 },
            ..KMeansParams::with_algorithm(Algorithm::CoverMeans)
        }
    }

    #[test]
    fn matches_lloyd_exactly_blobs() {
        let data = synth::gaussian_blobs(500, 3, 5, 1.0, 19);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 5, 13, &mut dc);
        let params = params_small_leaf();
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_c = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_c.labels, r_l.labels);
        assert_eq!(r_c.iterations, r_l.iterations);
    }

    #[test]
    fn matches_lloyd_exactly_geo() {
        let data = synth::istanbul(0.002, 20);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 25, 14, &mut dc);
        let params = params_small_leaf();
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_c = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_c.labels, r_l.labels);
        assert_eq!(r_c.iterations, r_l.iterations);
    }

    #[test]
    fn saves_distances_first_iteration() {
        // The tree method must beat n*k already in iteration 1 on
        // clustered low-dim data (the paper's early-iteration advantage).
        let data = synth::istanbul(0.003, 21);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 50, 15, &mut dc);
        let params = KMeansParams {
            max_iter: 1,
            ..params_small_leaf()
        };
        let mut ws = Workspace::new();
        let r_c = run(&data, &init_c, &params, &mut ws);
        let full = (data.rows() * 50) as u64;
        assert!(
            r_c.distances < full / 2,
            "cover {} vs full {}",
            r_c.distances,
            full
        );
    }

    #[test]
    fn handoff_bounds_are_valid() {
        // After a full run, u >= d(x, c_a) and l <= d(x, c_j) for all
        // j != a must hold for every point (Eqs. 15-18 soundness).
        let data = synth::gaussian_blobs(400, 3, 6, 1.0, 22);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 6, 16, &mut dc);
        let params = KMeansParams {
            max_iter: 3,
            ..params_small_leaf()
        };

        // Re-run the final pass manually to capture bounds pre-movement.
        let tree = crate::tree::CoverTree::build(&data, params.cover);
        let mut dist = DistCounter::new();
        let mut centers = init_c.clone();
        let n = data.rows();
        let mut labels = vec![u32::MAX; n];
        let mut upper = vec![0.0; n];
        let mut lower = vec![0.0; n];
        let mut second = vec![0u32; n];
        let mut acc = CentroidAccum::new(6, 3);
        for _ in 0..2 {
            let ic = InterCenter::compute(&centers, &mut dist);
            acc.clear();
            assign_pass(
                &data,
                &tree,
                &centers,
                &ic,
                &mut labels,
                &mut upper,
                &mut lower,
                &mut second,
                &mut acc,
                &mut dist,
                &Parallelism::sequential(),
            );
            // Validate against the *current* centers (before movement).
            for i in 0..n {
                let a = labels[i] as usize;
                let da = crate::kernels::dist(data.row(i), centers.row(a));
                assert!(
                    upper[i] >= da - 1e-9,
                    "u[{i}]={} < d={da}",
                    upper[i]
                );
                for j in 0..6 {
                    if j != a {
                        let dj =
                            crate::kernels::dist(data.row(i), centers.row(j));
                        assert!(
                            lower[i] <= dj + 1e-9,
                            "l[{i}]={} > d_{j}={dj}",
                            lower[i]
                        );
                    }
                }
                // NOTE: second[i] may equal labels[i] when the candidate
                // set collapsed to one center (Shallot's search handles
                // that degenerate memory explicitly).
            }
            let mut movement = Vec::new();
            acc.update_centers(&mut centers, &mut dist, &mut movement);
        }
    }

    #[test]
    fn near_duplicates_assign_cheaply() {
        let data = synth::traffic(0.00005, 23);
        let k = 10;
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, k, 17, &mut dc);
        let params = params_small_leaf();
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_c = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_c.labels, r_l.labels, "exactness on duplicate-heavy data");
        assert!(
            (r_c.distances as f64) < 0.5 * r_l.distances as f64,
            "cover {} vs lloyd {}",
            r_c.distances,
            r_l.distances
        );
    }

    #[test]
    fn default_leaf_size_matches_too() {
        let data = synth::mnist(10, 0.005, 24);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 15, 18, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::CoverMeans);
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_c = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_c.labels, r_l.labels);
    }
}
