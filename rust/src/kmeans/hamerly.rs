//! Hamerly's k-means [7] (paper §2.2): one upper bound `u` and a *single*
//! merged lower bound `l` per point. Less memory and cheaper bound updates
//! than Elkan, at the price of looser bounds — one fast-moving center
//! forces full rescans of many points (the effect visible in the paper's
//! Fig. 1a, where Hamerly computes the most distances of the bounds family).

use crate::data::Matrix;
use crate::kmeans::bounds::{nearest_two, CentroidAccum, InterCenter};
use crate::kmeans::KMeansParams;
use crate::metrics::{DistCounter, IterationLog, RunResult, Stopwatch};

pub fn run(data: &Matrix, init: &Matrix, params: &KMeansParams) -> RunResult {
    let n = data.rows();
    let d = data.cols();
    let k = init.rows();
    let sw = Stopwatch::start();
    let mut dist = DistCounter::new();

    let mut centers = init.clone();
    let mut labels = vec![0u32; n];
    let mut upper = vec![0.0f64; n];
    let mut lower = vec![0.0f64; n];
    let mut acc = CentroidAccum::new(k, d);
    let mut movement: Vec<f64> = Vec::with_capacity(k);
    let mut log = IterationLog::new();
    let mut converged = false;
    let mut iterations = 0;

    // Iteration 1: full scan seeds u = d1, l = d2.
    {
        acc.clear();
        for i in 0..n {
            let p = data.row(i);
            let (c1, d1, _c2, d2) = nearest_two(p, &centers, &mut dist);
            labels[i] = c1;
            upper[i] = d1;
            lower[i] = d2;
            acc.add_point(c1 as usize, p);
        }
        acc.update_centers(&mut centers, &mut dist, &mut movement);
        update_bounds(&mut upper, &mut lower, &labels, &movement);
        iterations = 1;
        log.push(1, dist.count(), sw.elapsed(), n);
    }

    for iter in 2..=params.max_iter {
        iterations = iter;
        let ic = InterCenter::compute(&centers, &mut dist);
        acc.clear();
        let mut changed = 0usize;

        for i in 0..n {
            let p = data.row(i);
            let a = labels[i] as usize;
            let m = ic.s[a].max(lower[i]);
            if upper[i] > m {
                // Tighten u to the true distance and re-test.
                upper[i] = dist.d(p, centers.row(a));
                if upper[i] > m {
                    // Full rescan: recompute the two nearest centers.
                    let (c1, d1, _c2, d2) = nearest_two(p, &centers, &mut dist);
                    if c1 != labels[i] {
                        labels[i] = c1;
                        changed += 1;
                    }
                    upper[i] = d1;
                    lower[i] = d2;
                }
            }
            acc.add_point(labels[i] as usize, p);
        }

        acc.update_centers(&mut centers, &mut dist, &mut movement);
        update_bounds(&mut upper, &mut lower, &labels, &movement);
        log.push(iter, dist.count(), sw.elapsed(), changed);
        if changed == 0 {
            converged = true;
            break;
        }
    }

    RunResult {
        labels,
        centers,
        iterations,
        distances: dist.count(),
        build_dist: 0,
        time: sw.elapsed(),
        build_time: std::time::Duration::ZERO,
        log,
        converged,
    }
}

/// u grows by the own-center movement; l shrinks by the largest movement
/// of any *other* center (tracked via max and second-max so the own center
/// can be excluded in O(1)).
pub(crate) fn update_bounds(
    upper: &mut [f64],
    lower: &mut [f64],
    labels: &[u32],
    movement: &[f64],
) {
    let (mut max1, mut arg1, mut max2) = (0.0f64, usize::MAX, 0.0f64);
    for (j, &mv) in movement.iter().enumerate() {
        if mv > max1 {
            max2 = max1;
            max1 = mv;
            arg1 = j;
        } else if mv > max2 {
            max2 = mv;
        }
    }
    for i in 0..upper.len() {
        let a = labels[i] as usize;
        upper[i] += movement[a];
        lower[i] -= if a == arg1 { max2 } else { max1 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(400, 4, 6, 1.0, 8);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 6, 4, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Hamerly);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_h = run(&data, &init_c, &params);
        assert_eq!(r_h.labels, r_l.labels);
        assert_eq!(r_h.iterations, r_l.iterations);
    }

    #[test]
    fn saves_distances_on_easy_data() {
        let data = synth::gaussian_blobs(500, 2, 5, 0.2, 9);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 5, 5, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Hamerly);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_h = run(&data, &init_c, &params);
        assert_eq!(r_h.labels, r_l.labels);
        assert!(r_h.distances < r_l.distances);
    }

    #[test]
    fn bound_update_excludes_own_center() {
        let mut upper = vec![1.0, 1.0];
        let mut lower = vec![5.0, 5.0];
        let labels = vec![0u32, 1u32];
        let movement = vec![3.0, 1.0];
        update_bounds(&mut upper, &mut lower, &labels, &movement);
        // point 0: own center moved 3 -> u += 3; other max movement is 1.
        assert_eq!(upper[0], 4.0);
        assert_eq!(lower[0], 4.0);
        // point 1: own center moved 1 -> u += 1; other max movement is 3.
        assert_eq!(upper[1], 2.0);
        assert_eq!(lower[1], 2.0);
    }
}
