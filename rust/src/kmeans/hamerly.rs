//! Hamerly's k-means [7] (paper §2.2): one upper bound `u` and a *single*
//! merged lower bound `l` per point. Less memory and cheaper bound updates
//! than Elkan, at the price of looser bounds — one fast-moving center
//! forces full rescans of many points (the effect visible in the paper's
//! Fig. 1a, where Hamerly computes the most distances of the bounds family).

use crate::data::{Matrix, SourceView};
use crate::kmeans::bounds::{
    accumulate_in_order_src, nearest_two, CentroidAccum, InterCenter,
};
use crate::kmeans::driver::{DriverState, Fit, KMeansDriver};
use crate::kmeans::{Algorithm, KMeansParams};
use crate::metrics::{DistCounter, RunResult};
use crate::parallel::{Parallelism, SharedSlices};

/// Merged-bounds driver: `(u, l)` per point. Streams: both passes visit
/// each worker's chunk range through the data source; the bounds live in
/// RAM (O(n), not O(n·d)), only the points themselves stream.
pub(crate) struct HamerlyDriver<'a> {
    src: SourceView<'a>,
    labels: Vec<u32>,
    upper: Vec<f64>,
    lower: Vec<f64>,
    par: Parallelism,
}

impl<'a> HamerlyDriver<'a> {
    pub(crate) fn new(data: &'a Matrix, par: Parallelism) -> HamerlyDriver<'a> {
        HamerlyDriver::from_source(data.into(), par)
    }

    pub(crate) fn from_source(src: SourceView<'a>, par: Parallelism) -> HamerlyDriver<'a> {
        let n = src.rows();
        HamerlyDriver {
            src,
            labels: vec![0u32; n],
            upper: vec![0.0f64; n],
            lower: vec![0.0f64; n],
            par,
        }
    }
}

impl KMeansDriver for HamerlyDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Hamerly
    }

    /// Iteration 1: full scan seeds u = d1, l = d2.
    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let src = self.src;
        let n = src.rows();
        let cols = src.cols();
        {
            let labels_sh = SharedSlices::new(&mut self.labels);
            let upper_sh = SharedSlices::new(&mut self.upper);
            let lower_sh = SharedSlices::new(&mut self.lower);
            let counts = self.par.map_chunks(n, |r| {
                let labels = unsafe { labels_sh.range(r.clone()) };
                let upper = unsafe { upper_sh.range(r.clone()) };
                let lower = unsafe { lower_sh.range(r.clone()) };
                let mut dc = DistCounter::new();
                src.visit(r.clone(), |start, block| {
                    for (off, p) in block.chunks_exact(cols).enumerate() {
                        let j = start + off - r.start;
                        let (c1, d1, _c2, d2) = nearest_two(p, centers, &mut dc);
                        labels[j] = c1;
                        upper[j] = d1;
                        lower[j] = d2;
                    }
                });
                dc.count()
            });
            for count in counts {
                dist.add_bulk(count);
            }
        }
        accumulate_in_order_src(src, &self.labels, acc);
        n
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let ic = InterCenter::compute_par(centers, dist, &self.par);
        let src = self.src;
        let n = src.rows();
        let cols = src.cols();
        let mut changed = 0usize;
        {
            let labels_sh = SharedSlices::new(&mut self.labels);
            let upper_sh = SharedSlices::new(&mut self.upper);
            let lower_sh = SharedSlices::new(&mut self.lower);
            let ic = &ic;
            let results = self.par.map_chunks(n, |r| {
                let labels = unsafe { labels_sh.range(r.clone()) };
                let upper = unsafe { upper_sh.range(r.clone()) };
                let lower = unsafe { lower_sh.range(r.clone()) };
                let mut dc = DistCounter::new();
                let mut changed = 0usize;
                src.visit(r.clone(), |start, block| {
                    for (off, p) in block.chunks_exact(cols).enumerate() {
                        let j = start + off - r.start;
                        let a = labels[j] as usize;
                        let m = ic.s[a].max(lower[j]);
                        if upper[j] > m {
                            // Tighten u to the true distance and re-test.
                            upper[j] = dc.d(p, centers.row(a));
                            if upper[j] > m {
                                // Full rescan: recompute the two nearest.
                                let (c1, d1, _c2, d2) = nearest_two(p, centers, &mut dc);
                                if c1 != labels[j] {
                                    labels[j] = c1;
                                    changed += 1;
                                }
                                upper[j] = d1;
                                lower[j] = d2;
                            }
                        }
                    }
                });
                (changed, dc.count())
            });
            for (ch, count) in results {
                changed += ch;
                dist.add_bulk(count);
            }
        }
        accumulate_in_order_src(src, &self.labels, acc);
        changed
    }

    fn post_update(&mut self, _iter: usize, movement: &[f64]) {
        update_bounds(&mut self.upper, &mut self.lower, &self.labels, movement);
    }

    fn labels(&self) -> &[u32] {
        &self.labels
    }

    fn save_state(&self) -> Option<DriverState> {
        Some(
            DriverState::new(self.labels.clone())
                .with_f64(self.upper.clone())
                .with_f64(self.lower.clone()),
        )
    }

    fn load_state(&mut self, state: &DriverState) -> anyhow::Result<()> {
        let n = self.src.rows();
        self.labels = state.labels_checked(n)?.to_vec();
        self.upper = state.f64_slot(0, n, "upper bounds")?.to_vec();
        self.lower = state.f64_slot(1, n, "lower bounds")?.to_vec();
        Ok(())
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.labels
    }
}

/// Legacy shim: drive Hamerly through the shared loop.
pub fn run(data: &Matrix, init: &Matrix, params: &KMeansParams) -> RunResult {
    Fit::from_driver(
        data,
        Box::new(HamerlyDriver::new(data, Parallelism::new(params.threads))),
        init,
        params.max_iter,
        params.tol,
    )
    .run()
}

/// u grows by the own-center movement; l shrinks by the largest movement
/// of any *other* center (tracked via max and second-max so the own center
/// can be excluded in O(1)).
pub(crate) fn update_bounds(
    upper: &mut [f64],
    lower: &mut [f64],
    labels: &[u32],
    movement: &[f64],
) {
    let (mut max1, mut arg1, mut max2) = (0.0f64, usize::MAX, 0.0f64);
    for (j, &mv) in movement.iter().enumerate() {
        if mv > max1 {
            max2 = max1;
            max1 = mv;
            arg1 = j;
        } else if mv > max2 {
            max2 = mv;
        }
    }
    for i in 0..upper.len() {
        let a = labels[i] as usize;
        upper[i] += movement[a];
        lower[i] -= if a == arg1 { max2 } else { max1 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(400, 4, 6, 1.0, 8);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 6, 4, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Hamerly);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_h = run(&data, &init_c, &params);
        assert_eq!(r_h.labels, r_l.labels);
        assert_eq!(r_h.iterations, r_l.iterations);
    }

    #[test]
    fn saves_distances_on_easy_data() {
        let data = synth::gaussian_blobs(500, 2, 5, 0.2, 9);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 5, 5, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Hamerly);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_h = run(&data, &init_c, &params);
        assert_eq!(r_h.labels, r_l.labels);
        assert!(r_h.distances < r_l.distances);
    }

    #[test]
    fn bound_update_excludes_own_center() {
        let mut upper = vec![1.0, 1.0];
        let mut lower = vec![5.0, 5.0];
        let labels = vec![0u32, 1u32];
        let movement = vec![3.0, 1.0];
        update_bounds(&mut upper, &mut lower, &labels, &movement);
        // point 0: own center moved 3 -> u += 3; other max movement is 1.
        assert_eq!(upper[0], 4.0);
        assert_eq!(lower[0], 4.0);
        // point 1: own center moved 1 -> u += 1; other max movement is 3.
        assert_eq!(upper[1], 2.0);
        assert_eq!(lower[1], 2.0);
    }
}
