//! Exponion (Newling & Fleuret [13], paper §2.2): Hamerly's bounds, but
//! when they fail the rescan is restricted to the centers inside a ball
//! around the assigned center instead of all k.
//!
//! After tightening `u = d(x, c_a)`, every center that could be nearer
//! than `c_a` satisfies `d(c_a, c_j) <= 2u`; to also refresh the merged
//! lower bound we search the slightly larger radius `R = 2u + delta_a`
//! (`delta_a` = distance from `c_a` to its nearest other center), walking
//! the centers in increasing distance from `c_a` via per-center sorted
//! neighbor lists (built lazily once per iteration, shared across chunk
//! workers — they are a pure function of the inter-center matrix, so
//! sharding changes no outcome). Centers outside the ball are at distance
//! > R - u from the point, which caps the new lower bound for them.

use std::sync::OnceLock;

use crate::data::Matrix;
use crate::kmeans::bounds::{accumulate_in_order, nearest_two, CentroidAccum, InterCenter};
use crate::kmeans::driver::{DriverState, Fit, KMeansDriver};
use crate::kmeans::hamerly::update_bounds;
use crate::kmeans::{Algorithm, KMeansParams};
use crate::metrics::{DistCounter, RunResult};
use crate::parallel::{Parallelism, SharedSlices};

/// Hamerly bounds; the sorted neighbor lists live in a per-iteration
/// cache shared across chunk workers (they are a pure function of the
/// inter-center matrix, so who initializes one changes no outcome).
pub(crate) struct ExponionDriver<'a> {
    data: &'a Matrix,
    labels: Vec<u32>,
    upper: Vec<f64>,
    lower: Vec<f64>,
    par: Parallelism,
}

impl<'a> ExponionDriver<'a> {
    pub(crate) fn new(data: &'a Matrix, par: Parallelism) -> ExponionDriver<'a> {
        let n = data.rows();
        ExponionDriver {
            data,
            labels: vec![0u32; n],
            upper: vec![0.0f64; n],
            lower: vec![0.0f64; n],
            par,
        }
    }

}

impl KMeansDriver for ExponionDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Exponion
    }

    /// Iteration 1: full scan (identical to Hamerly).
    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let data = self.data;
        let n = data.rows();
        {
            let labels_sh = SharedSlices::new(&mut self.labels);
            let upper_sh = SharedSlices::new(&mut self.upper);
            let lower_sh = SharedSlices::new(&mut self.lower);
            let counts = self.par.map_chunks(n, |r| {
                let labels = unsafe { labels_sh.range(r.clone()) };
                let upper = unsafe { upper_sh.range(r.clone()) };
                let lower = unsafe { lower_sh.range(r.clone()) };
                let mut dc = DistCounter::new();
                for (j, i) in r.clone().enumerate() {
                    let p = data.row(i);
                    let (c1, d1, _c2, d2) = nearest_two(p, centers, &mut dc);
                    labels[j] = c1;
                    upper[j] = d1;
                    lower[j] = d2;
                }
                dc.count()
            });
            for count in counts {
                dist.add_bulk(count);
            }
        }
        accumulate_in_order(data, &self.labels, acc);
        n
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let ic = InterCenter::compute_par(centers, dist, &self.par);
        let data = self.data;
        let n = data.rows();
        let k = centers.rows();
        let mut changed = 0usize;
        {
            let ic = &ic;
            // Sorted neighbor lists, built lazily once per iteration and
            // shared across chunks (pure functions of the inter-center
            // matrix, so which worker initializes one is immaterial).
            let neighbors: Vec<OnceLock<Vec<(f64, u32)>>> =
                (0..k).map(|_| OnceLock::new()).collect();
            let neighbors = &neighbors;
            let labels_sh = SharedSlices::new(&mut self.labels);
            let upper_sh = SharedSlices::new(&mut self.upper);
            let lower_sh = SharedSlices::new(&mut self.lower);
            let results = self.par.map_chunks(n, |r| {
                let labels = unsafe { labels_sh.range(r.clone()) };
                let upper = unsafe { upper_sh.range(r.clone()) };
                let lower = unsafe { lower_sh.range(r.clone()) };
                let mut dc = DistCounter::new();
                let mut changed = 0usize;
                for (jj, i) in r.clone().enumerate() {
                    let p = data.row(i);
                    let a = labels[jj] as usize;
                    let m = ic.s[a].max(lower[jj]);
                    if upper[jj] > m {
                        upper[jj] = dc.d(p, centers.row(a));
                        if upper[jj] > m {
                            // Annulus search around c_a.
                            let u = upper[jj];
                            let delta = 2.0 * ic.s[a]; // d(c_a, nearest other)
                            let radius = 2.0 * u + delta;
                            let nb =
                                neighbors[a].get_or_init(|| ic.sorted_neighbors(a));

                            let mut c1 = a as u32;
                            let mut d1 = u;
                            let mut c2 = c1;
                            let mut d2 = f64::INFINITY;
                            for &(cc_dist, j) in nb.iter() {
                                if cc_dist > radius {
                                    break;
                                }
                                let dj = dc.d(p, centers.row(j as usize));
                                if dj < d1 || (dj == d1 && j < c1) {
                                    c2 = c1;
                                    d2 = d1;
                                    c1 = j;
                                    d1 = dj;
                                } else if dj < d2 {
                                    c2 = j;
                                    d2 = dj;
                                }
                            }
                            let _ = c2;
                            // Excluded centers are farther than radius - u.
                            let excluded_lb = radius - u;
                            if c1 != labels[jj] {
                                labels[jj] = c1;
                                changed += 1;
                            }
                            upper[jj] = d1;
                            lower[jj] = d2.min(excluded_lb);
                        }
                    }
                }
                (changed, dc.count())
            });
            for (ch, count) in results {
                changed += ch;
                dist.add_bulk(count);
            }
        }
        accumulate_in_order(data, &self.labels, acc);
        changed
    }

    fn post_update(&mut self, _iter: usize, movement: &[f64]) {
        update_bounds(&mut self.upper, &mut self.lower, &self.labels, movement);
    }

    fn labels(&self) -> &[u32] {
        &self.labels
    }

    fn save_state(&self) -> Option<DriverState> {
        Some(
            DriverState::new(self.labels.clone())
                .with_f64(self.upper.clone())
                .with_f64(self.lower.clone()),
        )
    }

    fn load_state(&mut self, state: &DriverState) -> anyhow::Result<()> {
        let n = self.data.rows();
        self.labels = state.labels_checked(n)?.to_vec();
        self.upper = state.f64_slot(0, n, "upper bounds")?.to_vec();
        self.lower = state.f64_slot(1, n, "lower bounds")?.to_vec();
        Ok(())
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.labels
    }
}

/// Legacy shim: drive Exponion through the shared loop.
pub fn run(data: &Matrix, init: &Matrix, params: &KMeansParams) -> RunResult {
    Fit::from_driver(
        data,
        Box::new(ExponionDriver::new(data, Parallelism::new(params.threads))),
        init,
        params.max_iter,
        params.tol,
    )
    .run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(400, 4, 8, 1.0, 10);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 8, 5, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Exponion);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_e = run(&data, &init_c, &params);
        assert_eq!(r_e.labels, r_l.labels);
        assert_eq!(r_e.iterations, r_l.iterations);
    }

    #[test]
    fn beats_hamerly_on_distance_count() {
        // Medium k, clustered data: the annulus should restrict rescans.
        let data = synth::istanbul(0.003, 11);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 30, 6, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Exponion);
        let r_h = crate::kmeans::hamerly::run(&data, &init_c, &params);
        let r_e = run(&data, &init_c, &params);
        assert_eq!(r_e.labels, r_h.labels);
        assert!(
            r_e.distances <= r_h.distances,
            "exponion {} vs hamerly {}",
            r_e.distances,
            r_h.distances
        );
    }

    #[test]
    fn matches_lloyd_on_overlapping_data() {
        let data = synth::kdd04(0.0015, 12);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 12, 7, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Exponion);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_e = run(&data, &init_c, &params);
        assert_eq!(r_e.labels, r_l.labels);
    }
}
