//! Exponion (Newling & Fleuret [13], paper §2.2): Hamerly's bounds, but
//! when they fail the rescan is restricted to the centers inside a ball
//! around the assigned center instead of all k.
//!
//! After tightening `u = d(x, c_a)`, every center that could be nearer
//! than `c_a` satisfies `d(c_a, c_j) <= 2u`; to also refresh the merged
//! lower bound we search the slightly larger radius `R = 2u + delta_a`
//! (`delta_a` = distance from `c_a` to its nearest other center), walking
//! the centers in increasing distance from `c_a` via per-center sorted
//! neighbor lists (built lazily once per iteration). Centers outside the
//! ball are at distance > R - u from the point, which caps the new lower
//! bound for them.

use crate::data::Matrix;
use crate::kmeans::bounds::{CentroidAccum, InterCenter};
use crate::kmeans::hamerly::update_bounds;
use crate::kmeans::KMeansParams;
use crate::metrics::{DistCounter, IterationLog, RunResult, Stopwatch};

pub fn run(data: &Matrix, init: &Matrix, params: &KMeansParams) -> RunResult {
    let n = data.rows();
    let d = data.cols();
    let k = init.rows();
    let sw = Stopwatch::start();
    let mut dist = DistCounter::new();

    let mut centers = init.clone();
    let mut labels = vec![0u32; n];
    let mut upper = vec![0.0f64; n];
    let mut lower = vec![0.0f64; n];
    let mut acc = CentroidAccum::new(k, d);
    let mut movement: Vec<f64> = Vec::with_capacity(k);
    let mut log = IterationLog::new();
    let mut converged = false;
    let mut iterations = 0;

    // Iteration 1: full scan (identical to Hamerly).
    {
        acc.clear();
        for i in 0..n {
            let p = data.row(i);
            let (c1, d1, _c2, d2) =
                crate::kmeans::bounds::nearest_two(p, &centers, &mut dist);
            labels[i] = c1;
            upper[i] = d1;
            lower[i] = d2;
            acc.add_point(c1 as usize, p);
        }
        acc.update_centers(&mut centers, &mut dist, &mut movement);
        update_bounds(&mut upper, &mut lower, &labels, &movement);
        iterations = 1;
        log.push(1, dist.count(), sw.elapsed(), n);
    }

    // Lazily-built per-center sorted neighbor lists, valid one iteration.
    let mut neighbors: Vec<Option<Vec<(f64, u32)>>> = vec![None; k];

    for iter in 2..=params.max_iter {
        iterations = iter;
        let ic = InterCenter::compute(&centers, &mut dist);
        for nb in neighbors.iter_mut() {
            *nb = None;
        }
        acc.clear();
        let mut changed = 0usize;

        for i in 0..n {
            let p = data.row(i);
            let a = labels[i] as usize;
            let m = ic.s[a].max(lower[i]);
            if upper[i] > m {
                upper[i] = dist.d(p, centers.row(a));
                if upper[i] > m {
                    // Annulus search around c_a.
                    let u = upper[i];
                    let delta = 2.0 * ic.s[a]; // d(c_a, nearest other)
                    let radius = 2.0 * u + delta;
                    let nb = neighbors[a]
                        .get_or_insert_with(|| ic.sorted_neighbors(a));

                    let mut c1 = a as u32;
                    let mut d1 = u;
                    let mut c2 = c1;
                    let mut d2 = f64::INFINITY;
                    for &(cc_dist, j) in nb.iter() {
                        if cc_dist > radius {
                            break;
                        }
                        let dj = dist.d(p, centers.row(j as usize));
                        if dj < d1 || (dj == d1 && j < c1) {
                            c2 = c1;
                            d2 = d1;
                            c1 = j;
                            d1 = dj;
                        } else if dj < d2 {
                            c2 = j;
                            d2 = dj;
                        }
                    }
                    let _ = c2;
                    // Excluded centers are farther than radius - u.
                    let excluded_lb = radius - u;
                    if c1 != labels[i] {
                        labels[i] = c1;
                        changed += 1;
                    }
                    upper[i] = d1;
                    lower[i] = d2.min(excluded_lb);
                }
            }
            acc.add_point(labels[i] as usize, p);
        }

        acc.update_centers(&mut centers, &mut dist, &mut movement);
        update_bounds(&mut upper, &mut lower, &labels, &movement);
        log.push(iter, dist.count(), sw.elapsed(), changed);
        if changed == 0 {
            converged = true;
            break;
        }
    }

    RunResult {
        labels,
        centers,
        iterations,
        distances: dist.count(),
        build_dist: 0,
        time: sw.elapsed(),
        build_time: std::time::Duration::ZERO,
        log,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(400, 4, 8, 1.0, 10);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 8, 5, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Exponion);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_e = run(&data, &init_c, &params);
        assert_eq!(r_e.labels, r_l.labels);
        assert_eq!(r_e.iterations, r_l.iterations);
    }

    #[test]
    fn beats_hamerly_on_distance_count() {
        // Medium k, clustered data: the annulus should restrict rescans.
        let data = synth::istanbul(0.003, 11);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 30, 6, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Exponion);
        let r_h = crate::kmeans::hamerly::run(&data, &init_c, &params);
        let r_e = run(&data, &init_c, &params);
        assert_eq!(r_e.labels, r_h.labels);
        assert!(
            r_e.distances <= r_h.distances,
            "exponion {} vs hamerly {}",
            r_e.distances,
            r_h.distances
        );
    }

    #[test]
    fn matches_lloyd_on_overlapping_data() {
        let data = synth::kdd04(0.0015, 12);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 12, 7, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Exponion);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_e = run(&data, &init_c, &params);
        assert_eq!(r_e.labels, r_l.labels);
    }
}
