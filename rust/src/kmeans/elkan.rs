//! Elkan's k-means [5] (paper §2.2): per-point upper bound `u` plus `k`
//! per-center lower bounds `l[i][j]`, pruned with the inter-center
//! distances. Fewest distance computations of the stored-bounds family,
//! but O(n·k) bound memory and per-iteration update cost — the overhead
//! the paper's Fig. 1b/Table 3 shows dominating on low-d data.

use crate::data::Matrix;
use crate::kmeans::bounds::{CentroidAccum, InterCenter};
use crate::kmeans::KMeansParams;
use crate::metrics::{DistCounter, IterationLog, RunResult, Stopwatch};

pub fn run(data: &Matrix, init: &Matrix, params: &KMeansParams) -> RunResult {
    let n = data.rows();
    let d = data.cols();
    let k = init.rows();
    let sw = Stopwatch::start();
    let mut dist = DistCounter::new();

    let mut centers = init.clone();
    let mut labels = vec![0u32; n];
    let mut upper = vec![0.0f64; n];
    // Row-major n x k lower bounds.
    let mut lower = vec![0.0f64; n * k];
    let mut acc = CentroidAccum::new(k, d);
    let mut movement: Vec<f64> = Vec::with_capacity(k);
    let mut log = IterationLog::new();
    let mut converged = false;
    let mut iterations = 0;

    // --- Iteration 1: full scan, seed all bounds (paper §2.2: the first
    // iteration is as expensive as the Standard algorithm).
    {
        acc.clear();
        for i in 0..n {
            let p = data.row(i);
            let lrow = &mut lower[i * k..(i + 1) * k];
            let mut best = 0u32;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let dd = dist.d(p, centers.row(c));
                lrow[c] = dd;
                if dd < best_d {
                    best_d = dd;
                    best = c as u32;
                }
            }
            labels[i] = best;
            upper[i] = best_d;
            acc.add_point(best as usize, p);
        }
        acc.update_centers(&mut centers, &mut dist, &mut movement);
        update_bounds(&mut upper, &mut lower, &labels, &movement, k);
        iterations = 1;
        log.push(1, dist.count(), sw.elapsed(), n);
    }

    for iter in 2..=params.max_iter {
        iterations = iter;
        let ic = InterCenter::compute(&centers, &mut dist);
        acc.clear();
        let mut changed = 0usize;

        for i in 0..n {
            let p = data.row(i);
            let mut a = labels[i] as usize;
            // Global filter: u <= s(a) means no other center can win.
            if upper[i] > ic.s[a] {
                let lrow = &mut lower[i * k..(i + 1) * k];
                let mut tight = false;
                for j in 0..k {
                    if j == a {
                        continue;
                    }
                    // Elkan's two per-center filters (Eqs. 4-5).
                    if upper[i] <= lrow[j] || upper[i] <= 0.5 * ic.d(a, j) {
                        continue;
                    }
                    if !tight {
                        // Tighten the upper bound to the true distance.
                        upper[i] = dist.d(p, centers.row(a));
                        lrow[a] = upper[i];
                        tight = true;
                        if upper[i] <= lrow[j] || upper[i] <= 0.5 * ic.d(a, j) {
                            continue;
                        }
                    }
                    let dj = dist.d(p, centers.row(j));
                    lrow[j] = dj;
                    if dj < upper[i] {
                        a = j;
                        upper[i] = dj;
                    }
                }
            }
            if labels[i] != a as u32 {
                labels[i] = a as u32;
                changed += 1;
            }
            acc.add_point(a, p);
        }

        acc.update_centers(&mut centers, &mut dist, &mut movement);
        update_bounds(&mut upper, &mut lower, &labels, &movement, k);
        log.push(iter, dist.count(), sw.elapsed(), changed);
        if changed == 0 {
            converged = true;
            break;
        }
    }

    RunResult {
        labels,
        centers,
        iterations,
        distances: dist.count(),
        build_dist: 0,
        time: sw.elapsed(),
        build_time: std::time::Duration::ZERO,
        log,
        converged,
    }
}

/// Bound maintenance after the means moved (paper §2.2): the upper bound
/// grows by the assigned center's movement, every lower bound shrinks by
/// that center's movement. This is the O(n·k) cost that makes Elkan slow
/// per iteration even when it computes almost no distances.
fn update_bounds(
    upper: &mut [f64],
    lower: &mut [f64],
    labels: &[u32],
    movement: &[f64],
    k: usize,
) {
    for i in 0..upper.len() {
        upper[i] += movement[labels[i] as usize];
        let lrow = &mut lower[i * k..(i + 1) * k];
        for (l, &mv) in lrow.iter_mut().zip(movement) {
            *l -= mv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    /// Elkan must replicate Lloyd exactly (assignments and iterations).
    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(400, 4, 6, 1.0, 7);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 6, 3, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Elkan);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_e = run(&data, &init_c, &params);
        assert_eq!(r_e.labels, r_l.labels);
        assert_eq!(r_e.iterations, r_l.iterations);
        assert_eq!(r_e.converged, r_l.converged);
        for (a, b) in r_e.centers.as_slice().iter().zip(r_l.centers.as_slice()) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn saves_distances_vs_lloyd() {
        let data = synth::mnist(10, 0.01, 1);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 20, 1, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Elkan);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_e = run(&data, &init_c, &params);
        assert_eq!(r_e.labels, r_l.labels);
        assert!(
            r_e.distances < r_l.distances / 2,
            "elkan {} vs lloyd {}",
            r_e.distances,
            r_l.distances
        );
    }

    #[test]
    fn first_iteration_costs_full_scan() {
        let data = synth::gaussian_blobs(100, 3, 4, 0.5, 2);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 4, 1, &mut dc);
        let params = KMeansParams {
            max_iter: 1,
            ..KMeansParams::with_algorithm(Algorithm::Elkan)
        };
        let r = run(&data, &init_c, &params);
        assert!(r.distances >= 400, "first round must pay n*k");
    }
}
