//! Elkan's k-means [5] (paper §2.2): per-point upper bound `u` plus `k`
//! per-center lower bounds `l[i][j]`, pruned with the inter-center
//! distances. Fewest distance computations of the stored-bounds family,
//! but O(n·k) bound memory and per-iteration update cost — the overhead
//! the paper's Fig. 1b/Table 3 shows dominating on low-d data.

use crate::data::Matrix;
use crate::kmeans::bounds::{CentroidAccum, InterCenter};
use crate::kmeans::driver::{Fit, KMeansDriver};
use crate::kmeans::{Algorithm, KMeansParams};
use crate::metrics::{DistCounter, RunResult};

/// Stored-bounds driver: `u` per point, `l` per (point, center).
pub(crate) struct ElkanDriver<'a> {
    data: &'a Matrix,
    k: usize,
    labels: Vec<u32>,
    upper: Vec<f64>,
    /// Row-major n x k lower bounds.
    lower: Vec<f64>,
}

impl<'a> ElkanDriver<'a> {
    pub(crate) fn new(data: &'a Matrix, k: usize) -> ElkanDriver<'a> {
        let n = data.rows();
        ElkanDriver {
            data,
            k,
            labels: vec![0u32; n],
            upper: vec![0.0f64; n],
            lower: vec![0.0f64; n * k],
        }
    }
}

impl KMeansDriver for ElkanDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Elkan
    }

    /// Iteration 1: full scan, seed all bounds (paper §2.2: the first
    /// iteration is as expensive as the Standard algorithm).
    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let n = self.data.rows();
        let k = self.k;
        for i in 0..n {
            let p = self.data.row(i);
            let lrow = &mut self.lower[i * k..(i + 1) * k];
            let mut best = 0u32;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let dd = dist.d(p, centers.row(c));
                lrow[c] = dd;
                if dd < best_d {
                    best_d = dd;
                    best = c as u32;
                }
            }
            self.labels[i] = best;
            self.upper[i] = best_d;
            acc.add_point(best as usize, p);
        }
        n
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let n = self.data.rows();
        let k = self.k;
        let ic = InterCenter::compute(centers, dist);
        let mut changed = 0usize;

        for i in 0..n {
            let p = self.data.row(i);
            let mut a = self.labels[i] as usize;
            // Global filter: u <= s(a) means no other center can win.
            if self.upper[i] > ic.s[a] {
                let lrow = &mut self.lower[i * k..(i + 1) * k];
                let mut tight = false;
                for j in 0..k {
                    if j == a {
                        continue;
                    }
                    // Elkan's two per-center filters (Eqs. 4-5).
                    if self.upper[i] <= lrow[j] || self.upper[i] <= 0.5 * ic.d(a, j) {
                        continue;
                    }
                    if !tight {
                        // Tighten the upper bound to the true distance.
                        self.upper[i] = dist.d(p, centers.row(a));
                        lrow[a] = self.upper[i];
                        tight = true;
                        if self.upper[i] <= lrow[j] || self.upper[i] <= 0.5 * ic.d(a, j)
                        {
                            continue;
                        }
                    }
                    let dj = dist.d(p, centers.row(j));
                    lrow[j] = dj;
                    if dj < self.upper[i] {
                        a = j;
                        self.upper[i] = dj;
                    }
                }
            }
            if self.labels[i] != a as u32 {
                self.labels[i] = a as u32;
                changed += 1;
            }
            acc.add_point(a, p);
        }
        changed
    }

    fn post_update(&mut self, _iter: usize, movement: &[f64]) {
        update_bounds(&mut self.upper, &mut self.lower, &self.labels, movement, self.k);
    }

    fn labels(&self) -> &[u32] {
        &self.labels
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.labels
    }
}

/// Legacy shim: drive Elkan through the shared loop.
pub fn run(data: &Matrix, init: &Matrix, params: &KMeansParams) -> RunResult {
    Fit::from_driver(
        data,
        Box::new(ElkanDriver::new(data, init.rows())),
        init,
        params.max_iter,
        params.tol,
    )
    .run()
}

/// Bound maintenance after the means moved (paper §2.2): the upper bound
/// grows by the assigned center's movement, every lower bound shrinks by
/// that center's movement. This is the O(n·k) cost that makes Elkan slow
/// per iteration even when it computes almost no distances.
fn update_bounds(
    upper: &mut [f64],
    lower: &mut [f64],
    labels: &[u32],
    movement: &[f64],
    k: usize,
) {
    for i in 0..upper.len() {
        upper[i] += movement[labels[i] as usize];
        let lrow = &mut lower[i * k..(i + 1) * k];
        for (l, &mv) in lrow.iter_mut().zip(movement) {
            *l -= mv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    /// Elkan must replicate Lloyd exactly (assignments and iterations).
    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(400, 4, 6, 1.0, 7);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 6, 3, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Elkan);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_e = run(&data, &init_c, &params);
        assert_eq!(r_e.labels, r_l.labels);
        assert_eq!(r_e.iterations, r_l.iterations);
        assert_eq!(r_e.converged, r_l.converged);
        for (a, b) in r_e.centers.as_slice().iter().zip(r_l.centers.as_slice()) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn saves_distances_vs_lloyd() {
        let data = synth::mnist(10, 0.01, 1);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 20, 1, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Elkan);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_e = run(&data, &init_c, &params);
        assert_eq!(r_e.labels, r_l.labels);
        assert!(
            r_e.distances < r_l.distances / 2,
            "elkan {} vs lloyd {}",
            r_e.distances,
            r_l.distances
        );
    }

    #[test]
    fn first_iteration_costs_full_scan() {
        let data = synth::gaussian_blobs(100, 3, 4, 0.5, 2);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 4, 1, &mut dc);
        let params = KMeansParams {
            max_iter: 1,
            ..KMeansParams::with_algorithm(Algorithm::Elkan)
        };
        let r = run(&data, &init_c, &params);
        assert!(r.distances >= 400, "first round must pay n*k");
    }
}
