//! Elkan's k-means [5] (paper §2.2): per-point upper bound `u` plus `k`
//! per-center lower bounds `l[i][j]`, pruned with the inter-center
//! distances. Fewest distance computations of the stored-bounds family,
//! but O(n·k) bound memory and per-iteration update cost — the overhead
//! the paper's Fig. 1b/Table 3 shows dominating on low-d data.

use crate::data::{Matrix, SourceView};
use crate::kmeans::bounds::{accumulate_in_order_src, CentroidAccum, InterCenter};
use crate::kmeans::driver::{DriverState, Fit, KMeansDriver};
use crate::kmeans::{Algorithm, KMeansParams};
use crate::metrics::{DistCounter, RunResult};
use crate::parallel::{Parallelism, SharedSlices};

/// Stored-bounds driver: `u` per point, `l` per (point, center). Streams:
/// the bounds stay resident (O(n·k) — streaming Elkan only pays off when
/// d ≫ k), only the points themselves come through the source.
pub(crate) struct ElkanDriver<'a> {
    src: SourceView<'a>,
    k: usize,
    labels: Vec<u32>,
    upper: Vec<f64>,
    /// Row-major n x k lower bounds.
    lower: Vec<f64>,
    par: Parallelism,
}

impl<'a> ElkanDriver<'a> {
    pub(crate) fn new(data: &'a Matrix, k: usize, par: Parallelism) -> ElkanDriver<'a> {
        ElkanDriver::from_source(data.into(), k, par)
    }

    pub(crate) fn from_source(
        src: SourceView<'a>,
        k: usize,
        par: Parallelism,
    ) -> ElkanDriver<'a> {
        let n = src.rows();
        ElkanDriver {
            src,
            k,
            labels: vec![0u32; n],
            upper: vec![0.0f64; n],
            lower: vec![0.0f64; n * k],
            par,
        }
    }
}

impl KMeansDriver for ElkanDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Elkan
    }

    /// Iteration 1: full scan, seed all bounds (paper §2.2: the first
    /// iteration is as expensive as the Standard algorithm).
    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let src = self.src;
        let n = src.rows();
        let cols = src.cols();
        let k = self.k;
        {
            let labels_sh = SharedSlices::new(&mut self.labels);
            let upper_sh = SharedSlices::new(&mut self.upper);
            let lower_sh = SharedSlices::new(&mut self.lower);
            let counts = self.par.map_chunks(n, |r| {
                let labels = unsafe { labels_sh.range(r.clone()) };
                let upper = unsafe { upper_sh.range(r.clone()) };
                let lower = unsafe { lower_sh.range(r.start * k..r.end * k) };
                let mut dc = DistCounter::new();
                src.visit(r.clone(), |start, block| {
                    for (off, p) in block.chunks_exact(cols).enumerate() {
                        let j = start + off - r.start;
                        let lrow = &mut lower[j * k..(j + 1) * k];
                        let mut best = 0u32;
                        let mut best_d = f64::INFINITY;
                        for c in 0..k {
                            let dd = dc.d(p, centers.row(c));
                            lrow[c] = dd;
                            if dd < best_d {
                                best_d = dd;
                                best = c as u32;
                            }
                        }
                        labels[j] = best;
                        upper[j] = best_d;
                    }
                });
                dc.count()
            });
            for count in counts {
                dist.add_bulk(count);
            }
        }
        accumulate_in_order_src(src, &self.labels, acc);
        n
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let src = self.src;
        let n = src.rows();
        let cols = src.cols();
        let k = self.k;
        let ic = InterCenter::compute_par(centers, dist, &self.par);
        let mut changed = 0usize;
        {
            let ic = &ic;
            let labels_sh = SharedSlices::new(&mut self.labels);
            let upper_sh = SharedSlices::new(&mut self.upper);
            let lower_sh = SharedSlices::new(&mut self.lower);
            let results = self.par.map_chunks(n, |r| {
                let labels = unsafe { labels_sh.range(r.clone()) };
                let upper = unsafe { upper_sh.range(r.clone()) };
                let lower = unsafe { lower_sh.range(r.start * k..r.end * k) };
                let mut dc = DistCounter::new();
                let mut changed = 0usize;
                src.visit(r.clone(), |start, block| {
                    for (off, p) in block.chunks_exact(cols).enumerate() {
                        let jj = start + off - r.start;
                        let mut a = labels[jj] as usize;
                        // Global filter: u <= s(a) means no other center
                        // wins.
                        if upper[jj] > ic.s[a] {
                            let lrow = &mut lower[jj * k..(jj + 1) * k];
                            let mut tight = false;
                            for j in 0..k {
                                if j == a {
                                    continue;
                                }
                                // Elkan's two per-center filters (Eqs. 4-5).
                                if upper[jj] <= lrow[j]
                                    || upper[jj] <= 0.5 * ic.d(a, j)
                                {
                                    continue;
                                }
                                if !tight {
                                    // Tighten the upper bound to the truth.
                                    upper[jj] = dc.d(p, centers.row(a));
                                    lrow[a] = upper[jj];
                                    tight = true;
                                    if upper[jj] <= lrow[j]
                                        || upper[jj] <= 0.5 * ic.d(a, j)
                                    {
                                        continue;
                                    }
                                }
                                let dj = dc.d(p, centers.row(j));
                                lrow[j] = dj;
                                if dj < upper[jj] {
                                    a = j;
                                    upper[jj] = dj;
                                }
                            }
                        }
                        if labels[jj] != a as u32 {
                            labels[jj] = a as u32;
                            changed += 1;
                        }
                    }
                });
                (changed, dc.count())
            });
            for (ch, count) in results {
                changed += ch;
                dist.add_bulk(count);
            }
        }
        accumulate_in_order_src(src, &self.labels, acc);
        changed
    }

    fn post_update(&mut self, _iter: usize, movement: &[f64]) {
        update_bounds(&mut self.upper, &mut self.lower, &self.labels, movement, self.k);
    }

    fn labels(&self) -> &[u32] {
        &self.labels
    }

    fn save_state(&self) -> Option<DriverState> {
        Some(
            DriverState::new(self.labels.clone())
                .with_f64(self.upper.clone())
                .with_f64(self.lower.clone()),
        )
    }

    fn load_state(&mut self, state: &DriverState) -> anyhow::Result<()> {
        let n = self.src.rows();
        self.labels = state.labels_checked(n)?.to_vec();
        self.upper = state.f64_slot(0, n, "upper bounds")?.to_vec();
        self.lower = state
            .f64_slot(1, n * self.k, "per-center lower bounds")?
            .to_vec();
        Ok(())
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.labels
    }
}

/// Legacy shim: drive Elkan through the shared loop.
pub fn run(data: &Matrix, init: &Matrix, params: &KMeansParams) -> RunResult {
    Fit::from_driver(
        data,
        Box::new(ElkanDriver::new(
            data,
            init.rows(),
            Parallelism::new(params.threads),
        )),
        init,
        params.max_iter,
        params.tol,
    )
    .run()
}

/// Bound maintenance after the means moved (paper §2.2): the upper bound
/// grows by the assigned center's movement, every lower bound shrinks by
/// that center's movement. This is the O(n·k) cost that makes Elkan slow
/// per iteration even when it computes almost no distances.
fn update_bounds(
    upper: &mut [f64],
    lower: &mut [f64],
    labels: &[u32],
    movement: &[f64],
    k: usize,
) {
    for i in 0..upper.len() {
        upper[i] += movement[labels[i] as usize];
        let lrow = &mut lower[i * k..(i + 1) * k];
        for (l, &mv) in lrow.iter_mut().zip(movement) {
            *l -= mv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    /// Elkan must replicate Lloyd exactly (assignments and iterations).
    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(400, 4, 6, 1.0, 7);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 6, 3, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Elkan);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_e = run(&data, &init_c, &params);
        assert_eq!(r_e.labels, r_l.labels);
        assert_eq!(r_e.iterations, r_l.iterations);
        assert_eq!(r_e.converged, r_l.converged);
        for (a, b) in r_e.centers.as_slice().iter().zip(r_l.centers.as_slice()) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn saves_distances_vs_lloyd() {
        let data = synth::mnist(10, 0.01, 1);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 20, 1, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Elkan);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_e = run(&data, &init_c, &params);
        assert_eq!(r_e.labels, r_l.labels);
        assert!(
            r_e.distances < r_l.distances / 2,
            "elkan {} vs lloyd {}",
            r_e.distances,
            r_l.distances
        );
    }

    #[test]
    fn first_iteration_costs_full_scan() {
        let data = synth::gaussian_blobs(100, 3, 4, 0.5, 2);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 4, 1, &mut dc);
        let params = KMeansParams {
            max_iter: 1,
            ..KMeansParams::with_algorithm(Algorithm::Elkan)
        };
        let r = run(&data, &init_c, &params);
        assert!(r.distances >= 400, "first round must pay n*k");
    }
}
