//! Shallot (Borgelt [3], paper §2.2): the state-of-the-art stored-bounds
//! algorithm the paper's Hybrid switches to.
//!
//! Like Exponion it keeps Hamerly's `(u, l)` pair, but additionally
//! remembers the *identity* of the (assumed) second-nearest center. On a
//! bound failure it first probes that remembered center — often already
//! the new winner — and then walks the sorted neighbors of the best center
//! inside a ball whose radius `d1 + d2` *shrinks* as better candidates are
//! found (the onion layers that give the algorithm its name). The search
//! radius starts from `u + d(x, c_second)`, which is typically much
//! tighter than Exponion's `2u + delta`.
//!
//! As the paper notes (§3.4), the remembered second-nearest identity may
//! go stale; correctness only needs `l` to lower-bound every non-assigned
//! center, which the shrinking-ball argument preserves.

use crate::data::Matrix;
use crate::kmeans::bounds::{CentroidAccum, InterCenter};
use crate::kmeans::driver::{Fit, KMeansDriver};
use crate::kmeans::hamerly::update_bounds;
use crate::kmeans::{Algorithm, KMeansParams};
use crate::metrics::{DistCounter, RunResult};

/// Per-point stored state seeded either by the first full scan or by the
/// cover tree hand-off (paper Eqs. 15-18).
#[derive(Debug, Clone)]
pub struct ShallotState {
    pub labels: Vec<u32>,
    /// Assumed second-nearest center identity.
    pub second: Vec<u32>,
    pub upper: Vec<f64>,
    pub lower: Vec<f64>,
}

impl ShallotState {
    /// Zeroed state for a cold start (labels 0, bounds 0).
    pub fn zeroed(n: usize) -> ShallotState {
        ShallotState {
            labels: vec![0u32; n],
            second: vec![0u32; n],
            upper: vec![0.0f64; n],
            lower: vec![0.0f64; n],
        }
    }

    /// Unassigned state for a tree-seeded start (labels `u32::MAX`, to be
    /// overwritten by the first cover pass).
    pub fn unassigned(n: usize) -> ShallotState {
        ShallotState { labels: vec![u32::MAX; n], ..ShallotState::zeroed(n) }
    }
}

/// One Shallot iteration over an existing bounded state: inter-center
/// distances, the `(u, l)` filter per point, shrinking-ball searches on
/// failure. Shared between [`ShallotDriver`] and the Hybrid driver, which
/// seeds `state` from the cover tree instead of a full first scan.
pub(crate) fn iterate_pass(
    data: &Matrix,
    centers: &Matrix,
    state: &mut ShallotState,
    neighbors: &mut [Option<Vec<(f64, u32)>>],
    acc: &mut CentroidAccum,
    dist: &mut DistCounter,
) -> usize {
    let ic = InterCenter::compute(centers, dist);
    for nb in neighbors.iter_mut() {
        *nb = None;
    }
    let mut changed = 0usize;

    for i in 0..data.rows() {
        let p = data.row(i);
        let a = state.labels[i] as usize;
        let m = ic.s[a].max(state.lower[i]);
        if state.upper[i] > m {
            // Tighten u.
            state.upper[i] = dist.d(p, centers.row(a));
            if state.upper[i] > m {
                search(p, i, centers, &ic, neighbors, state, dist, &mut changed);
            }
        }
        acc.add_point(state.labels[i] as usize, p);
    }
    changed
}

/// Stored-bounds driver with second-nearest identity memory.
pub(crate) struct ShallotDriver<'a> {
    data: &'a Matrix,
    state: ShallotState,
    neighbors: Vec<Option<Vec<(f64, u32)>>>,
}

impl<'a> ShallotDriver<'a> {
    pub(crate) fn new(data: &'a Matrix, k: usize) -> ShallotDriver<'a> {
        ShallotDriver {
            data,
            state: ShallotState::zeroed(data.rows()),
            neighbors: vec![None; k],
        }
    }
}

impl KMeansDriver for ShallotDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Shallot
    }

    /// Iteration 1: full scan.
    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let n = self.data.rows();
        for i in 0..n {
            let p = self.data.row(i);
            let (c1, d1, c2, d2) =
                crate::kmeans::bounds::nearest_two(p, centers, dist);
            self.state.labels[i] = c1;
            self.state.second[i] = c2;
            self.state.upper[i] = d1;
            self.state.lower[i] = d2;
            acc.add_point(c1 as usize, p);
        }
        n
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        iterate_pass(
            self.data,
            centers,
            &mut self.state,
            &mut self.neighbors,
            acc,
            dist,
        )
    }

    fn post_update(&mut self, _iter: usize, movement: &[f64]) {
        update_bounds(
            &mut self.state.upper,
            &mut self.state.lower,
            &self.state.labels,
            movement,
        );
    }

    fn labels(&self) -> &[u32] {
        &self.state.labels
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.state.labels
    }
}

/// Legacy shim: drive Shallot through the shared loop.
pub fn run(data: &Matrix, init: &Matrix, params: &KMeansParams) -> RunResult {
    Fit::from_driver(
        data,
        Box::new(ShallotDriver::new(data, init.rows())),
        init,
        params.max_iter,
        params.tol,
    )
    .run()
}

/// The shrinking-ball search for one point whose bounds failed.
#[allow(clippy::too_many_arguments)]
#[inline]
fn search(
    p: &[f64],
    i: usize,
    centers: &Matrix,
    ic: &InterCenter,
    neighbors: &mut [Option<Vec<(f64, u32)>>],
    state: &mut ShallotState,
    dist: &mut DistCounter,
    changed: &mut usize,
) {
    let a_orig = state.labels[i];
    let u_orig = state.upper[i];

    // Probe the remembered second-nearest first.
    let mut c1 = a_orig;
    let mut d1 = u_orig;
    let mut b = state.second[i];
    if b == c1 {
        // Degenerate memory (k == 1 hand-off); pick any other center.
        b = if c1 == 0 { (centers.rows() - 1) as u32 } else { 0 };
    }
    let mut d2 = dist.d(p, centers.row(b as usize));
    let mut c2 = b;
    if d2 < d1 || (d2 == d1 && c2 < c1) {
        std::mem::swap(&mut c1, &mut c2);
        std::mem::swap(&mut d1, &mut d2);
    }

    // Walk neighbors of the original assigned center (the annulus anchor)
    // while the shrinking radius allows.
    let anchor = a_orig as usize;
    let nb = neighbors[anchor].get_or_insert_with(|| ic.sorted_neighbors(anchor));
    for &(cc_dist, j) in nb.iter() {
        // Shrinking ball: any center with d(x, c_j) < d2 must satisfy
        // d(c_anchor, c_j) <= d(x, c_anchor) + d(x, c_j) < u_orig + d2.
        if cc_dist > u_orig + d2 {
            break;
        }
        if j == b || j == a_orig {
            continue; // already probed
        }
        let dj = dist.d(p, centers.row(j as usize));
        if dj < d1 || (dj == d1 && j < c1) {
            c2 = c1;
            d2 = d1;
            c1 = j;
            d1 = dj;
        } else if dj < d2 {
            c2 = j;
            d2 = dj;
        }
    }

    // Centers never probed satisfy d(x,c_j) >= cc(anchor, j) - u_orig >
    // (u_orig + d2) - u_orig = d2 at the moment the walk stopped, so `d2`
    // is a valid merged lower bound.
    if c1 != state.labels[i] {
        state.labels[i] = c1;
        *changed += 1;
    }
    state.second[i] = c2;
    state.upper[i] = d1;
    state.lower[i] = d2;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(400, 4, 8, 1.0, 13);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 8, 6, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Shallot);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_s = run(&data, &init_c, &params);
        assert_eq!(r_s.labels, r_l.labels);
        assert_eq!(r_s.iterations, r_l.iterations);
    }

    #[test]
    fn no_worse_than_exponion() {
        let data = synth::istanbul(0.003, 14);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 30, 8, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Shallot);
        let r_e = crate::kmeans::exponion::run(&data, &init_c, &params);
        let r_s = run(&data, &init_c, &params);
        assert_eq!(r_s.labels, r_e.labels);
        assert!(
            (r_s.distances as f64) <= 1.05 * r_e.distances as f64,
            "shallot {} vs exponion {}",
            r_s.distances,
            r_e.distances
        );
    }

    #[test]
    fn matches_lloyd_high_dim_overlap() {
        let data = synth::kdd04(0.0015, 15);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 12, 9, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Shallot);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_s = run(&data, &init_c, &params);
        assert_eq!(r_s.labels, r_l.labels);
    }
}
