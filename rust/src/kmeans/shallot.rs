//! Shallot (Borgelt [3], paper §2.2): the state-of-the-art stored-bounds
//! algorithm the paper's Hybrid switches to.
//!
//! Like Exponion it keeps Hamerly's `(u, l)` pair, but additionally
//! remembers the *identity* of the (assumed) second-nearest center. On a
//! bound failure it first probes that remembered center — often already
//! the new winner — and then walks the sorted neighbors of the best center
//! inside a ball whose radius `d1 + d2` *shrinks* as better candidates are
//! found (the onion layers that give the algorithm its name). The search
//! radius starts from `u + d(x, c_second)`, which is typically much
//! tighter than Exponion's `2u + delta`.
//!
//! As the paper notes (§3.4), the remembered second-nearest identity may
//! go stale; correctness only needs `l` to lower-bound every non-assigned
//! center, which the shrinking-ball argument preserves.

use std::sync::OnceLock;

use crate::data::Matrix;
use crate::kmeans::bounds::{accumulate_in_order, CentroidAccum, InterCenter};
use crate::kmeans::driver::{DriverState, Fit, KMeansDriver};
use crate::kmeans::hamerly::update_bounds;
use crate::kmeans::{Algorithm, KMeansParams};
use crate::metrics::{DistCounter, RunResult};
use crate::parallel::{Parallelism, SharedSlices};

/// Per-point stored state seeded either by the first full scan or by the
/// cover tree hand-off (paper Eqs. 15-18).
#[derive(Debug, Clone)]
pub struct ShallotState {
    pub labels: Vec<u32>,
    /// Assumed second-nearest center identity.
    pub second: Vec<u32>,
    pub upper: Vec<f64>,
    pub lower: Vec<f64>,
}

impl ShallotState {
    /// Zeroed state for a cold start (labels 0, bounds 0).
    pub fn zeroed(n: usize) -> ShallotState {
        ShallotState {
            labels: vec![0u32; n],
            second: vec![0u32; n],
            upper: vec![0.0f64; n],
            lower: vec![0.0f64; n],
        }
    }

    /// Unassigned state for a tree-seeded start (labels `u32::MAX`, to be
    /// overwritten by the first cover pass).
    pub fn unassigned(n: usize) -> ShallotState {
        ShallotState { labels: vec![u32::MAX; n], ..ShallotState::zeroed(n) }
    }

    /// Checkpoint snapshot (slot order: upper, lower, second). Shared by
    /// the Shallot and Hybrid drivers.
    pub(crate) fn to_driver_state(&self) -> DriverState {
        DriverState::new(self.labels.clone())
            .with_f64(self.upper.clone())
            .with_f64(self.lower.clone())
            .with_u32(self.second.clone())
    }

    /// Rebuild from a [`ShallotState::to_driver_state`] snapshot,
    /// validating every vector against the point count.
    pub(crate) fn from_driver_state(
        state: &DriverState,
        n: usize,
    ) -> anyhow::Result<ShallotState> {
        Ok(ShallotState {
            labels: state.labels_checked(n)?.to_vec(),
            second: state.u32_slot(0, n, "second-nearest indices")?.to_vec(),
            upper: state.f64_slot(0, n, "upper bounds")?.to_vec(),
            lower: state.f64_slot(1, n, "lower bounds")?.to_vec(),
        })
    }
}

/// One Shallot iteration over an existing bounded state: inter-center
/// distances, the `(u, l)` filter per point, shrinking-ball searches on
/// failure. Shared between [`ShallotDriver`] and the Hybrid driver, which
/// seeds `state` from the cover tree instead of a full first scan.
/// Sharded over point chunks; the sorted-neighbor cache is built lazily
/// once per iteration and shared across chunk workers (pure functions of
/// the inter-center matrix), so any thread count reproduces the
/// sequential pass exactly.
pub(crate) fn iterate_pass(
    data: &Matrix,
    centers: &Matrix,
    state: &mut ShallotState,
    acc: &mut CentroidAccum,
    dist: &mut DistCounter,
    par: &Parallelism,
) -> usize {
    let ic = InterCenter::compute_par(centers, dist, par);
    let n = data.rows();
    let k = centers.rows();
    let mut changed = 0usize;
    {
        let ic = &ic;
        let neighbors: Vec<OnceLock<Vec<(f64, u32)>>> =
            (0..k).map(|_| OnceLock::new()).collect();
        let neighbors = &neighbors;
        let labels_sh = SharedSlices::new(&mut state.labels);
        let second_sh = SharedSlices::new(&mut state.second);
        let upper_sh = SharedSlices::new(&mut state.upper);
        let lower_sh = SharedSlices::new(&mut state.lower);
        let results = par.map_chunks(n, |r| {
            let labels = unsafe { labels_sh.range(r.clone()) };
            let second = unsafe { second_sh.range(r.clone()) };
            let upper = unsafe { upper_sh.range(r.clone()) };
            let lower = unsafe { lower_sh.range(r.clone()) };
            let mut dc = DistCounter::new();
            let mut changed = 0usize;
            for (j, i) in r.clone().enumerate() {
                let p = data.row(i);
                let a = labels[j] as usize;
                let m = ic.s[a].max(lower[j]);
                if upper[j] > m {
                    // Tighten u.
                    upper[j] = dc.d(p, centers.row(a));
                    if upper[j] > m
                        && search(
                            p,
                            centers,
                            ic,
                            neighbors,
                            &mut labels[j],
                            &mut second[j],
                            &mut upper[j],
                            &mut lower[j],
                            &mut dc,
                        )
                    {
                        changed += 1;
                    }
                }
            }
            (changed, dc.count())
        });
        for (ch, count) in results {
            changed += ch;
            dist.add_bulk(count);
        }
    }
    // Center sums in canonical point order (bit-identical at every
    // thread count).
    accumulate_in_order(data, &state.labels, acc);
    changed
}

/// Stored-bounds driver with second-nearest identity memory.
pub(crate) struct ShallotDriver<'a> {
    data: &'a Matrix,
    state: ShallotState,
    par: Parallelism,
}

impl<'a> ShallotDriver<'a> {
    pub(crate) fn new(data: &'a Matrix, par: Parallelism) -> ShallotDriver<'a> {
        ShallotDriver {
            data,
            state: ShallotState::zeroed(data.rows()),
            par,
        }
    }
}

impl KMeansDriver for ShallotDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Shallot
    }

    /// Iteration 1: full scan.
    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let data = self.data;
        let n = data.rows();
        {
            let labels_sh = SharedSlices::new(&mut self.state.labels);
            let second_sh = SharedSlices::new(&mut self.state.second);
            let upper_sh = SharedSlices::new(&mut self.state.upper);
            let lower_sh = SharedSlices::new(&mut self.state.lower);
            let counts = self.par.map_chunks(n, |r| {
                let labels = unsafe { labels_sh.range(r.clone()) };
                let second = unsafe { second_sh.range(r.clone()) };
                let upper = unsafe { upper_sh.range(r.clone()) };
                let lower = unsafe { lower_sh.range(r.clone()) };
                let mut dc = DistCounter::new();
                for (j, i) in r.clone().enumerate() {
                    let p = data.row(i);
                    let (c1, d1, c2, d2) =
                        crate::kmeans::bounds::nearest_two(p, centers, &mut dc);
                    labels[j] = c1;
                    second[j] = c2;
                    upper[j] = d1;
                    lower[j] = d2;
                }
                dc.count()
            });
            for count in counts {
                dist.add_bulk(count);
            }
        }
        accumulate_in_order(data, &self.state.labels, acc);
        n
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        iterate_pass(self.data, centers, &mut self.state, acc, dist, &self.par)
    }

    fn post_update(&mut self, _iter: usize, movement: &[f64]) {
        update_bounds(
            &mut self.state.upper,
            &mut self.state.lower,
            &self.state.labels,
            movement,
        );
    }

    fn labels(&self) -> &[u32] {
        &self.state.labels
    }

    fn save_state(&self) -> Option<DriverState> {
        Some(self.state.to_driver_state())
    }

    fn load_state(&mut self, state: &DriverState) -> anyhow::Result<()> {
        self.state = ShallotState::from_driver_state(state, self.data.rows())?;
        Ok(())
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.state.labels
    }
}

/// Legacy shim: drive Shallot through the shared loop.
pub fn run(data: &Matrix, init: &Matrix, params: &KMeansParams) -> RunResult {
    Fit::from_driver(
        data,
        Box::new(ShallotDriver::new(data, Parallelism::new(params.threads))),
        init,
        params.max_iter,
        params.tol,
    )
    .run()
}

/// The shrinking-ball search for one point whose bounds failed. Operates
/// on the point's own stored state (`label`/`second`/`upper`/`lower`), so
/// chunk workers can run it concurrently on disjoint points. Returns
/// whether the assignment changed.
#[allow(clippy::too_many_arguments)]
#[inline]
fn search(
    p: &[f64],
    centers: &Matrix,
    ic: &InterCenter,
    neighbors: &[OnceLock<Vec<(f64, u32)>>],
    label: &mut u32,
    second: &mut u32,
    upper: &mut f64,
    lower: &mut f64,
    dist: &mut DistCounter,
) -> bool {
    let a_orig = *label;
    let u_orig = *upper;

    // Probe the remembered second-nearest first.
    let mut c1 = a_orig;
    let mut d1 = u_orig;
    let mut b = *second;
    if b == c1 {
        // Degenerate memory (k == 1 hand-off); pick any other center.
        b = if c1 == 0 { (centers.rows() - 1) as u32 } else { 0 };
    }
    let mut d2 = dist.d(p, centers.row(b as usize));
    let mut c2 = b;
    if d2 < d1 || (d2 == d1 && c2 < c1) {
        std::mem::swap(&mut c1, &mut c2);
        std::mem::swap(&mut d1, &mut d2);
    }

    // Walk neighbors of the original assigned center (the annulus anchor)
    // while the shrinking radius allows.
    let anchor = a_orig as usize;
    let nb = neighbors[anchor].get_or_init(|| ic.sorted_neighbors(anchor));
    for &(cc_dist, j) in nb.iter() {
        // Shrinking ball: any center with d(x, c_j) < d2 must satisfy
        // d(c_anchor, c_j) <= d(x, c_anchor) + d(x, c_j) < u_orig + d2.
        if cc_dist > u_orig + d2 {
            break;
        }
        if j == b || j == a_orig {
            continue; // already probed
        }
        let dj = dist.d(p, centers.row(j as usize));
        if dj < d1 || (dj == d1 && j < c1) {
            c2 = c1;
            d2 = d1;
            c1 = j;
            d1 = dj;
        } else if dj < d2 {
            c2 = j;
            d2 = dj;
        }
    }

    // Centers never probed satisfy d(x,c_j) >= cc(anchor, j) - u_orig >
    // (u_orig + d2) - u_orig = d2 at the moment the walk stopped, so `d2`
    // is a valid merged lower bound.
    let changed = c1 != *label;
    *label = c1;
    *second = c2;
    *upper = d1;
    *lower = d2;
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(400, 4, 8, 1.0, 13);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 8, 6, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Shallot);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_s = run(&data, &init_c, &params);
        assert_eq!(r_s.labels, r_l.labels);
        assert_eq!(r_s.iterations, r_l.iterations);
    }

    #[test]
    fn no_worse_than_exponion() {
        let data = synth::istanbul(0.003, 14);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 30, 8, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Shallot);
        let r_e = crate::kmeans::exponion::run(&data, &init_c, &params);
        let r_s = run(&data, &init_c, &params);
        assert_eq!(r_s.labels, r_e.labels);
        assert!(
            (r_s.distances as f64) <= 1.05 * r_e.distances as f64,
            "shallot {} vs exponion {}",
            r_s.distances,
            r_e.distances
        );
    }

    #[test]
    fn matches_lloyd_high_dim_overlap() {
        let data = synth::kdd04(0.0015, 15);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 12, 9, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::Shallot);
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_s = run(&data, &init_c, &params);
        assert_eq!(r_s.labels, r_l.labels);
    }
}
