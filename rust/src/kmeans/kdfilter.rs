//! Shared parallel engine of the two k-d-tree drivers — Kanungo et al.'s
//! filtering [8] and Pelleg & Moore's blacklisting [14].
//!
//! Both algorithms are the same top-down candidate-narrowing recursion and
//! differ only in the geometric test that prunes candidates at an internal
//! node (the [`PruneRule`]): the hyperplane dominance test for Kanungo,
//! the box min/max blacklist for Pelleg-Moore. Leaves scan the surviving
//! candidates per point; a node whose candidate set collapses to one
//! center assigns its whole subtree at once via the stored aggregates.
//!
//! # Parallel decomposition
//!
//! The recursion decomposes into independent subtree tasks exactly like
//! the cover tree pass (`kmeans::cover`): a **sequential expansion** peels
//! the top of the tree into at most ~[`TASK_TARGET`] subtree tasks by
//! repeatedly visiting the heaviest splittable task's node — running its
//! prune test (charged to the caller's counter in a fixed order), settling
//! single-survivor subtrees outright, and spilling the two children as new
//! tasks. The expansion policy depends only on the tree and the centers,
//! never on the thread count, so the task list — and therefore the
//! accumulator merge order — is a function of the data alone. The **task
//! phase** then runs each task's recursion with a private
//! [`CentroidAccum`] and [`crate::metrics::DistCounter`]; labels are
//! written through a [`ScatterSlice`] (a k-d tree partitions the point
//! indices across subtrees, so concurrent tasks touch disjoint indices),
//! and the per-task accumulators/tallies fold back **in task order**.
//! `threads = N` is therefore byte-identical to `threads = 1`.
//!
//! Like the cover pass (PR 2), running the task decomposition at every
//! thread count means the center-sum association differs from the old
//! depth-first recursion by low-order bits; counted distances and (with
//! assignment margins dwarfing ulps) labels are unaffected.

use crate::data::Matrix;
use crate::kmeans::bounds::CentroidAccum;
use crate::metrics::DistCounter;
use crate::parallel::{Parallelism, ScatterSlice};
use crate::tree::kdtree::{KdNode, KdTree};

/// The per-node candidate pruning rule — the only thing that differs
/// between the filtering and blacklisting algorithms. Implementations
/// must be pure functions of `(node, candidates, centers)`: the engine
/// may evaluate a node from any worker, and determinism relies on the
/// survivors (and the counted work charged to `dist`) depending on
/// nothing else. `scratch` is a reusable d-vector for midpoint tests.
pub(crate) trait PruneRule: Sync {
    fn prune(
        &self,
        node: &KdNode,
        candidates: &[u32],
        centers: &Matrix,
        dist: &mut DistCounter,
        scratch: &mut [f64],
    ) -> Vec<u32>;
}

/// One unit of the parallel decomposition: a subtree visit with the
/// candidate set that survived the path from the root.
struct KdTask<'t> {
    node: &'t KdNode,
    cands: Vec<u32>,
}

/// The expansion stops splitting once this many tasks exist. Fixed (never
/// derived from the thread count) so the task list — and therefore the
/// accumulator merge order — is a function of the tree and centers only.
const TASK_TARGET: usize = 64;
/// Subtrees lighter than this are not worth splitting further.
const MIN_TASK_WEIGHT: u32 = 256;

/// Scan a leaf's points against the surviving candidates (ties to the
/// lowest center index, as everywhere in the exact family).
#[allow(clippy::too_many_arguments)]
fn scan_leaf(
    data: &Matrix,
    centers: &Matrix,
    node: &KdNode,
    candidates: &[u32],
    labels: &ScatterSlice<'_, u32>,
    acc: &mut CentroidAccum,
    dist: &mut DistCounter,
    changed: &mut usize,
) {
    for &pi in &node.points {
        let p = data.row(pi as usize);
        let mut best = candidates[0];
        let mut best_d = f64::INFINITY;
        for &z in candidates {
            let dd = dist.d(p, centers.row(z as usize));
            if dd < best_d || (dd == best_d && z < best) {
                best_d = dd;
                best = z;
            }
        }
        // Safety: every point index lives in exactly one subtree, and
        // concurrent tasks own disjoint subtrees.
        unsafe {
            if labels.read(pi as usize) != best {
                labels.write(pi as usize, best);
                *changed += 1;
            }
        }
        acc.add_point(best as usize, p);
    }
}

/// Assign the whole subtree under `node` to the sole survivor `z` using
/// the stored aggregates (the O(d) whole-cell reassignment both papers
/// are built around).
fn assign_subtree(
    node: &KdNode,
    z: u32,
    labels: &ScatterSlice<'_, u32>,
    acc: &mut CentroidAccum,
    changed: &mut usize,
) {
    acc.add_aggregate(z as usize, &node.sum, node.weight as f64);
    let mut delta = 0usize;
    node.for_each_point(&mut |pi| {
        // Safety: disjoint subtrees, as in `scan_leaf`.
        unsafe {
            if labels.read(pi as usize) != z {
                labels.write(pi as usize, z);
                delta += 1;
            }
        }
    });
    *changed += delta;
}

/// Visit one node: leaf scan, prune test, single-survivor settlement, or
/// recursion into the children. During the expansion phase `spill`
/// collects the children that would recurse as [`KdTask`]s instead — the
/// node's own work happens identically either way.
#[allow(clippy::too_many_arguments)]
fn visit<'t, P: PruneRule>(
    rule: &P,
    data: &Matrix,
    centers: &Matrix,
    node: &'t KdNode,
    candidates: &[u32],
    labels: &ScatterSlice<'_, u32>,
    acc: &mut CentroidAccum,
    dist: &mut DistCounter,
    changed: &mut usize,
    scratch: &mut [f64],
    spill: Option<&mut Vec<KdTask<'t>>>,
) {
    if node.is_leaf() {
        scan_leaf(data, centers, node, candidates, labels, acc, dist, changed);
        return;
    }
    let remaining = rule.prune(node, candidates, centers, dist, scratch);
    debug_assert!(!remaining.is_empty(), "prune rules always keep a survivor");
    if remaining.len() == 1 {
        assign_subtree(node, remaining[0], labels, acc, changed);
        return;
    }
    let left: &'t KdNode = node.left.as_ref().unwrap();
    let right: &'t KdNode = node.right.as_ref().unwrap();
    match spill {
        Some(out) => {
            out.push(KdTask { node: left, cands: remaining.clone() });
            out.push(KdTask { node: right, cands: remaining });
        }
        None => {
            visit(
                rule, data, centers, left, &remaining, labels, acc, dist, changed,
                scratch, None,
            );
            visit(
                rule, data, centers, right, &remaining, labels, acc, dist, changed,
                scratch, None,
            );
        }
    }
}

/// Run one full filtering pass over the tree: thread-count-independent
/// expansion, then the parallel task phase with per-task accumulators
/// merged in task order. Returns the number of points whose assignment
/// changed.
#[allow(clippy::too_many_arguments)]
pub(crate) fn filter_pass<P: PruneRule>(
    rule: &P,
    data: &Matrix,
    tree: &KdTree,
    centers: &Matrix,
    labels: &mut [u32],
    acc: &mut CentroidAccum,
    dist: &mut DistCounter,
    par: &Parallelism,
) -> usize {
    let k = centers.rows();
    let d = data.cols();
    let sink = ScatterSlice::new(labels);
    let mut changed = 0usize;
    let mut scratch = vec![0.0f64; d];
    let all: Vec<u32> = (0..k as u32).collect();
    // Expansion: repeatedly visit the heaviest splittable task's node
    // (settling what the prune test decides outright) and spill the
    // children that still need a recursive visit back into the list.
    let mut tasks: Vec<KdTask<'_>> = vec![KdTask { node: &tree.root, cands: all }];
    crate::parallel::expand_tasks(
        &mut tasks,
        TASK_TARGET,
        |t| {
            (!t.node.is_leaf() && t.node.weight >= MIN_TASK_WEIGHT)
                .then_some(t.node.weight)
        },
        |t, out| {
            visit(
                rule,
                data,
                centers,
                t.node,
                &t.cands,
                &sink,
                acc,
                dist,
                &mut changed,
                &mut scratch,
                Some(out),
            );
        },
    );
    // Task phase: private accumulators and counters, merged in task order.
    let results = par.run_tasks(tasks, |task| {
        let mut task_acc = CentroidAccum::new(k, d);
        let mut dc = DistCounter::new();
        let mut task_changed = 0usize;
        let mut task_scratch = vec![0.0f64; d];
        visit(
            rule,
            data,
            centers,
            task.node,
            &task.cands,
            &sink,
            &mut task_acc,
            &mut dc,
            &mut task_changed,
            &mut task_scratch,
            None,
        );
        (task_acc, dc.count(), task_changed)
    });
    for (task_acc, count, task_changed) in results {
        acc.merge(&task_acc);
        dist.add_bulk(count);
        changed += task_changed;
    }
    changed
}
