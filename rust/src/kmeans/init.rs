//! Initialization: k-means++ [1] and uniform sampling.
//!
//! The paper evaluates every algorithm on *the same* 10 k-means++ seeds per
//! dataset, so initialization lives outside the per-algorithm counters: the
//! coordinator generates the centers once per `(dataset, k, restart)` and
//! hands identical copies to each algorithm. The `DistCounter` passed here
//! is therefore a separate "init" counter, not an algorithm counter.
//!
//! # Parallel, pruned D² sampling
//!
//! Both seeders keep one invariant sacred: the chosen centers are a
//! function of `(data, k, seed)` only. Two accelerations ride under it:
//!
//! * **Sharding** ([`kmeans_plus_plus_par`]): the per-point `d2`/`near`
//!   updates of a round are element-wise independent, so they shard over
//!   point chunks with disjoint writes; the weighted draw itself sums `d2`
//!   sequentially in canonical point order on the calling thread. Any
//!   thread count therefore reproduces the sequential seeding byte for
//!   byte — same centers, same counted distances.
//! * **Triangle-inequality pruning** (Raff, "Exact Acceleration of
//!   K-Means++ and K-Means||"): when candidate `q` is drawn, one distance
//!   per already-chosen center `c_j` is computed up front; a point `x`
//!   whose current nearest center `c` satisfies `d(c, q) >= 2 d(x, c)`
//!   cannot be improved by `q` (`d(x, q) >= d(c, q) - d(x, c) >= d(x,
//!   c)`), so its point-side evaluation is skipped. The skip is *exact*:
//!   every `d2` value — and hence the sampled sequence — is bit-identical
//!   to the unpruned loop; only the counted distance work shrinks. The
//!   real-arithmetic argument is made robust to floating point by
//!   [`prune_slack`]: the prune only fires when the margin also covers
//!   the worst-case relative rounding of the three squared distances
//!   involved, so a skipped evaluation provably could not have changed
//!   the stored (computed) `d2` value.

use crate::data::{Matrix, SourceView};
use crate::metrics::DistCounter;
use crate::parallel::{Parallelism, SharedSlices};
use crate::rng::Rng;

/// Multiplicative safety factor for the triangle prune: skip only when
/// `cc2 >= 4 * d2 * slack`. Each of the three squared distances in the
/// argument is a d-term sum of non-negative squares, so its relative
/// error is at most ~(d+3) ulps; a 16x cushion on top makes the prune
/// conservatively sound — a fired prune implies even the *computed*
/// point-side distance could not have been below the stored `d2` — at
/// the cost of a vanishing fraction of the pruning opportunities. A pure
/// function of the dimension, so it is identical at every thread count.
fn prune_slack(d: usize) -> f64 {
    1.0 + 16.0 * (d as f64 + 4.0) * f64::EPSILON
}

/// k-means++ seeding (Arthur & Vassilvitskii): first center uniform, each
/// subsequent center sampled proportionally to the squared distance to the
/// nearest already-chosen center. Sequential convenience wrapper over
/// [`kmeans_plus_plus_par`].
pub fn kmeans_plus_plus(
    data: &Matrix,
    k: usize,
    seed: u64,
    dist: &mut DistCounter,
) -> Matrix {
    kmeans_plus_plus_par(data, k, seed, dist, &Parallelism::sequential())
}

/// k-means++ seeding over `par`'s thread budget, with Raff-style
/// triangle-inequality pruning. Byte-identical centers to
/// [`kmeans_plus_plus`] at every thread count (see the module docs).
pub fn kmeans_plus_plus_par(
    data: &Matrix,
    k: usize,
    seed: u64,
    dist: &mut DistCounter,
    par: &Parallelism,
) -> Matrix {
    kmeans_plus_plus_src(data.into(), k, seed, dist, par)
}

/// [`kmeans_plus_plus_par`] over any data source backend. The chosen rows
/// are gathered resident as they are drawn ([`SourceView::read_rows`] —
/// exact bits), so the arithmetic, the RNG stream, and the counted
/// distances match the in-RAM seeding bit for bit on every backend.
pub fn kmeans_plus_plus_src(
    src: SourceView<'_>,
    k: usize,
    seed: u64,
    dist: &mut DistCounter,
    par: &Parallelism,
) -> Matrix {
    assert!(k >= 1 && k <= src.rows(), "k={k} out of range");
    let n = src.rows();
    let cols = src.cols();
    let mut rng = Rng::derive(seed, "init/kmeans++");
    let mut chosen: Vec<usize> = Vec::with_capacity(k);
    // Resident copies of the chosen rows (k·d floats — the init working
    // set stays small however large the streamed dataset is).
    let mut cand_rows: Vec<Vec<f64>> = Vec::with_capacity(k);

    let first = rng.below(n);
    chosen.push(first);
    cand_rows.push(src.read_rows(&[first]).as_slice().to_vec());

    // Squared distance to the nearest chosen center, updated
    // incrementally, plus that center's identity (which feeds the
    // triangle pruning).
    let mut d2 = vec![0.0f64; n];
    let mut near = vec![0u32; n];
    {
        let first_row = &cand_rows[0];
        let d2_sh = SharedSlices::new(&mut d2);
        let tallies = par.map_chunks(n, |r| {
            let d2c = unsafe { d2_sh.range(r.clone()) };
            let mut dc = DistCounter::new();
            src.visit(r.clone(), |start, block| {
                for (off, p) in block.chunks_exact(cols).enumerate() {
                    d2c[start + off - r.start] = dc.sq(p, first_row);
                }
            });
            dc.count()
        });
        for t in tallies {
            dist.add_bulk(t);
        }
    }

    // Squared distances from every already-chosen center to the newest
    // one — the O(k) pruning precomputation that saves O(n) point-side
    // evaluations per round.
    let mut cc2 = vec![0.0f64; k];
    let slack = prune_slack(cols);
    while chosen.len() < k {
        let next = match rng.choose_weighted(&d2) {
            Some(i) => i,
            // All remaining mass zero (fewer distinct points than k):
            // fall back to an unchosen index to keep k centers.
            None => (0..n).find(|i| !chosen.contains(i)).unwrap_or(0),
        };
        let next_row = src.read_rows(&[next]).as_slice().to_vec();
        for (j, row) in cand_rows.iter().enumerate() {
            cc2[j] = dist.sq(row, &next_row);
        }
        let new_id = chosen.len() as u32;
        chosen.push(next);
        {
            let cc2 = &cc2;
            let next_row = &next_row;
            let d2_sh = SharedSlices::new(&mut d2);
            let near_sh = SharedSlices::new(&mut near);
            let tallies = par.map_chunks(n, |r| {
                let d2c = unsafe { d2_sh.range(r.clone()) };
                let nearc = unsafe { near_sh.range(r.clone()) };
                let mut dc = DistCounter::new();
                src.visit(r.clone(), |start, block| {
                    for (off, p) in block.chunks_exact(cols).enumerate() {
                        let j = start + off - r.start;
                        if d2c[j] <= 0.0 {
                            continue;
                        }
                        // Triangle pruning (exact; see module docs): in
                        // squares, d(c,q)² >= 4 d(x,c)² ⇔ d(c,q) >=
                        // 2 d(x,c), with `slack` absorbing the rounding
                        // of the computed squared distances.
                        if cc2[nearc[j] as usize] >= 4.0 * d2c[j] * slack {
                            continue;
                        }
                        let nd = dc.sq(p, next_row);
                        if nd < d2c[j] {
                            d2c[j] = nd;
                            nearc[j] = new_id;
                        }
                    }
                });
                dc.count()
            });
            for t in tallies {
                dist.add_bulk(t);
            }
        }
        cand_rows.push(next_row);
    }
    src.read_rows(&chosen)
}

/// Counter-based uniform draw for the `k-means||` selection step: hash
/// `(seed, round, point)` through splitmix64 into `[0, 1)`. Every point's
/// Bernoulli decision is a pure function of those three values — no shared
/// RNG stream to advance — so the selected oversample set is invariant to
/// scan order, thread count, chunking, and source backend.
fn bernoulli_u(sel_seed: u64, round: usize, point: usize) -> f64 {
    let mut s = sel_seed
        ^ (round as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (point as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03);
    let z = crate::rng::splitmix64(&mut s);
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// `k-means||` seeding (Bahmani et al., "Scalable k-means++"): instead of
/// k strictly sequential D² draws, run a few oversampling rounds that each
/// select ~`oversample · k` candidates in one pass (point `j` joins with
/// probability `min(1, l · d2[j] / φ)`), then recluster the small weighted
/// candidate set down to `k` with weighted k-means++. One full pass per
/// round instead of one per center — the natural seeder for streamed
/// sources, where every pass over the data costs real I/O.
///
/// Deterministic contract: the centers are a function of `(data, k, seed,
/// rounds, oversample)` only — identical at every thread count and on
/// every source backend. The per-candidate `d2` updates reuse the
/// triangle-pruned, sharded machinery of [`kmeans_plus_plus_par`];
/// the per-point selection uses counter-based draws ([`bernoulli_u`]) so
/// it never depends on scan order. Sequential convenience wrapper over
/// [`init_kmeanspar_par`].
pub fn init_kmeanspar(
    data: &Matrix,
    k: usize,
    seed: u64,
    rounds: usize,
    oversample: f64,
    dist: &mut DistCounter,
) -> Matrix {
    init_kmeanspar_par(data, k, seed, rounds, oversample, dist, &Parallelism::sequential())
}

/// [`init_kmeanspar`] over `par`'s thread budget.
pub fn init_kmeanspar_par(
    data: &Matrix,
    k: usize,
    seed: u64,
    rounds: usize,
    oversample: f64,
    dist: &mut DistCounter,
    par: &Parallelism,
) -> Matrix {
    init_kmeanspar_src(data.into(), k, seed, rounds, oversample, dist, par)
}

/// [`init_kmeanspar`] over any data source backend (the default init for
/// streamed fits).
pub fn init_kmeanspar_src(
    src: SourceView<'_>,
    k: usize,
    seed: u64,
    rounds: usize,
    oversample: f64,
    dist: &mut DistCounter,
    par: &Parallelism,
) -> Matrix {
    assert!(k >= 1 && k <= src.rows(), "k={k} out of range");
    assert!(oversample > 0.0, "oversample must be positive");
    let n = src.rows();
    let cols = src.cols();
    let mut rng = Rng::derive(seed, "init/kmeans||");

    let first = rng.below(n);
    // The counter seed for the per-point Bernoulli draws, taken from the
    // stream once up front so every later draw is order-independent.
    let sel_seed = rng.next_u64();

    let mut candidates: Vec<usize> = vec![first];
    let mut cand_rows: Vec<Vec<f64>> =
        vec![src.read_rows(&[first]).as_slice().to_vec()];

    // Squared distance to the nearest candidate plus its identity, exactly
    // as in k-means++ (the identity feeds both the triangle pruning and
    // the final per-candidate weights).
    let mut d2 = vec![0.0f64; n];
    let mut near = vec![0u32; n];
    {
        let first_row = &cand_rows[0];
        let d2_sh = SharedSlices::new(&mut d2);
        let tallies = par.map_chunks(n, |r| {
            let d2c = unsafe { d2_sh.range(r.clone()) };
            let mut dc = DistCounter::new();
            src.visit(r.clone(), |start, block| {
                for (off, p) in block.chunks_exact(cols).enumerate() {
                    d2c[start + off - r.start] = dc.sq(p, first_row);
                }
            });
            dc.count()
        });
        for t in tallies {
            dist.add_bulk(t);
        }
    }

    let slack = prune_slack(cols);
    let l = oversample * k as f64;
    for round in 0..rounds {
        // φ in canonical point order (bit-identical on every backend).
        let phi: f64 = d2.iter().sum();
        if !(phi > 0.0) {
            break;
        }
        // Select this round's candidates: `u · φ < l · d2[j]` is the
        // Bernoulli(min(1, l·d2/φ)) test without a division. Already
        // chosen points have d2 = 0 and never re-enter.
        let fresh: Vec<usize> = (0..n)
            .filter(|&j| bernoulli_u(sel_seed, round, j) * phi < l * d2[j])
            .collect();
        if fresh.is_empty() {
            continue;
        }
        let fresh_rows = src.read_rows(&fresh);
        for (fi, &fj) in fresh.iter().enumerate() {
            let new_row = fresh_rows.row(fi);
            // Triangle-pruning precomputation vs every current candidate.
            let mut cc2 = vec![0.0f64; cand_rows.len()];
            for (j, row) in cand_rows.iter().enumerate() {
                cc2[j] = dist.sq(row, new_row);
            }
            let new_id = candidates.len() as u32;
            candidates.push(fj);
            {
                let cc2 = &cc2;
                let d2_sh = SharedSlices::new(&mut d2);
                let near_sh = SharedSlices::new(&mut near);
                let tallies = par.map_chunks(n, |r| {
                    let d2c = unsafe { d2_sh.range(r.clone()) };
                    let nearc = unsafe { near_sh.range(r.clone()) };
                    let mut dc = DistCounter::new();
                    src.visit(r.clone(), |start, block| {
                        for (off, p) in block.chunks_exact(cols).enumerate() {
                            let j = start + off - r.start;
                            if d2c[j] <= 0.0 {
                                continue;
                            }
                            if cc2[nearc[j] as usize] >= 4.0 * d2c[j] * slack {
                                continue;
                            }
                            let nd = dc.sq(p, new_row);
                            if nd < d2c[j] {
                                d2c[j] = nd;
                                nearc[j] = new_id;
                            }
                        }
                    });
                    dc.count()
                });
                for t in tallies {
                    dist.add_bulk(t);
                }
            }
            cand_rows.push(new_row.to_vec());
        }
    }

    // Per-candidate weights: how many points it is nearest to (a tally
    // over the maintained `near`, no distance computations).
    let mut weights = vec![0.0f64; cand_rows.len()];
    for &c in near.iter() {
        weights[c as usize] += 1.0;
    }

    weighted_recluster(src, &candidates, &cand_rows, &weights, k, &mut rng, dist)
}

/// The recluster step of `k-means||`: weighted k-means++ over the small
/// resident candidate set (sequential, counted, unpruned — the set is
/// ~`oversample · k · rounds` rows, so pruning would buy nothing). Fewer
/// candidates than `k` pads with the first unchosen data rows, mirroring
/// k-means++'s degenerate-data fallback.
fn weighted_recluster(
    src: SourceView<'_>,
    candidates: &[usize],
    cand_rows: &[Vec<f64>],
    weights: &[f64],
    k: usize,
    rng: &mut Rng,
    dist: &mut DistCounter,
) -> Matrix {
    let m = cand_rows.len();
    if m <= k {
        let mut rows: Vec<Vec<f64>> = cand_rows.to_vec();
        let mut have: Vec<usize> = candidates.to_vec();
        let n = src.rows();
        let mut i = 0;
        while rows.len() < k {
            while i < n && have.contains(&i) {
                i += 1;
            }
            let idx = if i < n { i } else { 0 };
            rows.push(src.read_rows(&[idx]).as_slice().to_vec());
            have.push(idx);
            i += 1;
        }
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        return Matrix::from_rows(&refs);
    }
    let mut chosen: Vec<usize> = Vec::with_capacity(k);
    let first = rng.choose_weighted(weights).unwrap_or(0);
    chosen.push(first);
    let mut d2: Vec<f64> = (0..m)
        .map(|i| dist.sq(&cand_rows[i], &cand_rows[first]))
        .collect();
    let mut wd2: Vec<f64> = (0..m).map(|i| weights[i] * d2[i]).collect();
    while chosen.len() < k {
        let next = match rng.choose_weighted(&wd2) {
            Some(i) => i,
            None => (0..m).find(|i| !chosen.contains(i)).unwrap_or(0),
        };
        chosen.push(next);
        for i in 0..m {
            if d2[i] > 0.0 {
                let nd = dist.sq(&cand_rows[i], &cand_rows[next]);
                if nd < d2[i] {
                    d2[i] = nd;
                }
            }
            wd2[i] = weights[i] * d2[i];
        }
    }
    let refs: Vec<&[f64]> = chosen.iter().map(|&i| cand_rows[i].as_slice()).collect();
    Matrix::from_rows(&refs)
}

/// Extend an existing center set to `k` rows — the warm-started sweep
/// protocol: keep `base` (a previous, smaller-k solution) and add the
/// missing centers by the same D² sampling k-means++ uses, measured
/// against the current set. `base.rows()` may equal `k` (returns a copy).
/// Sequential convenience wrapper over [`extend_centers_par`].
pub fn extend_centers(
    data: &Matrix,
    base: &Matrix,
    k: usize,
    seed: u64,
    dist: &mut DistCounter,
) -> Matrix {
    extend_centers_par(data, base, k, seed, dist, &Parallelism::sequential())
}

/// [`extend_centers`] over `par`'s thread budget with the same pruned D²
/// rounds as [`kmeans_plus_plus_par`]; byte-identical to the sequential
/// version at every thread count.
pub fn extend_centers_par(
    data: &Matrix,
    base: &Matrix,
    k: usize,
    seed: u64,
    dist: &mut DistCounter,
    par: &Parallelism,
) -> Matrix {
    assert!(base.rows() <= k, "cannot shrink {} centers to k={k}", base.rows());
    assert!(k <= data.rows(), "k={k} out of range");
    assert_eq!(base.cols(), data.cols(), "center/data dimension mismatch");
    let n = data.rows();
    let mut rng = Rng::derive(seed, "init/extend");
    let mut rows: Vec<Vec<f64>> = base.iter_rows().map(|r| r.to_vec()).collect();
    let mut chosen: Vec<usize> = Vec::new();

    // Nearest base center per point (distance² and identity).
    let mut d2 = vec![f64::INFINITY; n];
    let mut near = vec![0u32; n];
    {
        let d2_sh = SharedSlices::new(&mut d2);
        let near_sh = SharedSlices::new(&mut near);
        let tallies = par.map_chunks(n, |r| {
            let d2c = unsafe { d2_sh.range(r.clone()) };
            let nearc = unsafe { near_sh.range(r.clone()) };
            let mut dc = DistCounter::new();
            for (j, i) in r.clone().enumerate() {
                let mut best = f64::INFINITY;
                let mut bi = 0u32;
                for c in 0..base.rows() {
                    let nd = dc.sq(data.row(i), base.row(c));
                    if nd < best {
                        best = nd;
                        bi = c as u32;
                    }
                }
                d2c[j] = best;
                nearc[j] = bi;
            }
            dc.count()
        });
        for t in tallies {
            dist.add_bulk(t);
        }
    }

    let mut cc2 = vec![0.0f64; k];
    let slack = prune_slack(data.cols());
    while rows.len() < k {
        let next = match rng.choose_weighted(&d2) {
            Some(i) => i,
            // All remaining mass zero: fall back to an unchosen index.
            None => (0..n).find(|i| !chosen.contains(i)).unwrap_or(0),
        };
        chosen.push(next);
        for (j, row) in rows.iter().enumerate() {
            cc2[j] = dist.sq(row, data.row(next));
        }
        let new_id = rows.len() as u32;
        rows.push(data.row(next).to_vec());
        {
            let cc2 = &cc2;
            let d2_sh = SharedSlices::new(&mut d2);
            let near_sh = SharedSlices::new(&mut near);
            let tallies = par.map_chunks(n, |r| {
                let d2c = unsafe { d2_sh.range(r.clone()) };
                let nearc = unsafe { near_sh.range(r.clone()) };
                let mut dc = DistCounter::new();
                for (j, i) in r.clone().enumerate() {
                    if d2c[j] <= 0.0 {
                        continue;
                    }
                    if cc2[nearc[j] as usize] >= 4.0 * d2c[j] * slack {
                        continue;
                    }
                    let nd = dc.sq(data.row(i), data.row(next));
                    if nd < d2c[j] {
                        d2c[j] = nd;
                        nearc[j] = new_id;
                    }
                }
                dc.count()
            });
            for t in tallies {
                dist.add_bulk(t);
            }
        }
    }
    let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
    Matrix::from_rows(&refs)
}

/// Uniform random distinct-index sampling (baseline init for tests).
pub fn random_init(data: &Matrix, k: usize, seed: u64) -> Matrix {
    assert!(k >= 1 && k <= data.rows());
    let mut rng = Rng::derive(seed, "init/random");
    let mut idx: Vec<usize> = (0..data.rows()).collect();
    rng.shuffle(&mut idx);
    idx.truncate(k);
    data.select_rows(&idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    /// The textbook unpruned D² loop, kept as the reference the pruned
    /// implementation must reproduce center-for-center. Returns the
    /// centers and the unpruned distance-evaluation count.
    fn naive_kmeans_plus_plus(data: &Matrix, k: usize, seed: u64) -> (Matrix, u64) {
        let n = data.rows();
        let mut rng = Rng::derive(seed, "init/kmeans++");
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        let mut dist = DistCounter::new();
        let first = rng.below(n);
        chosen.push(first);
        let mut d2: Vec<f64> = (0..n)
            .map(|i| dist.sq(data.row(i), data.row(first)))
            .collect();
        while chosen.len() < k {
            let next = match rng.choose_weighted(&d2) {
                Some(i) => i,
                None => (0..n).find(|i| !chosen.contains(i)).unwrap_or(0),
            };
            chosen.push(next);
            for i in 0..n {
                if d2[i] > 0.0 {
                    let nd = dist.sq(data.row(i), data.row(next));
                    if nd < d2[i] {
                        d2[i] = nd;
                    }
                }
            }
        }
        (data.select_rows(&chosen), dist.count())
    }

    #[test]
    fn kpp_returns_k_distinct_centers_from_data() {
        let data = synth::gaussian_blobs(200, 3, 4, 0.3, 1);
        let mut dist = DistCounter::new();
        let c = kmeans_plus_plus(&data, 4, 7, &mut dist);
        assert_eq!((c.rows(), c.cols()), (4, 3));
        // every center is an actual data row
        for i in 0..4 {
            assert!((0..data.rows()).any(|r| data.row(r) == c.row(i)));
        }
        // distinct rows (blob data has no duplicates)
        for i in 0..4 {
            for j in (i + 1)..4 {
                assert_ne!(c.row(i), c.row(j));
            }
        }
        // At least the first full scan is always paid; later rounds are
        // triangle-pruned, so the pre-pruning n*(k-1) floor no longer
        // applies.
        assert!(dist.count() >= 200);
    }

    #[test]
    fn kpp_pruning_matches_naive_and_saves_work() {
        for seed in [7u64, 42, 1000] {
            // Well-separated blobs: most points sit far closer to their
            // blob's chosen center than to any newly drawn candidate, so
            // the triangle test prunes heavily.
            let data = synth::gaussian_blobs(400, 3, 5, 0.1, seed);
            let mut pruned_dist = DistCounter::new();
            let pruned = kmeans_plus_plus(&data, 5, seed, &mut pruned_dist);
            let (naive, naive_count) = naive_kmeans_plus_plus(&data, 5, seed);
            assert_eq!(pruned, naive, "seed {seed}: pruning changed the centers");
            // The pruned run pays k²/2 extra center-center evals but must
            // still come out well ahead of the unpruned point-side cost.
            assert!(
                pruned_dist.count() < naive_count,
                "seed {seed}: pruned {} >= naive {naive_count}",
                pruned_dist.count()
            );
        }
    }

    #[test]
    fn kpp_parallel_is_byte_identical() {
        let data = synth::gaussian_blobs(700, 4, 6, 0.5, 9);
        let mut d_seq = DistCounter::new();
        let seq = kmeans_plus_plus(&data, 10, 3, &mut d_seq);
        for threads in [2usize, 4] {
            let par = Parallelism::new(threads);
            let mut d_par = DistCounter::new();
            let p = kmeans_plus_plus_par(&data, 10, 3, &mut d_par, &par);
            assert_eq!(p, seq, "threads={threads}");
            assert_eq!(d_par.count(), d_seq.count(), "threads={threads}");
        }
    }

    #[test]
    fn kpp_deterministic_in_seed() {
        let data = synth::gaussian_blobs(100, 2, 3, 0.5, 2);
        let mut d1 = DistCounter::new();
        let mut d2 = DistCounter::new();
        let a = kmeans_plus_plus(&data, 5, 42, &mut d1);
        let b = kmeans_plus_plus(&data, 5, 42, &mut d2);
        assert_eq!(a, b);
        let c = kmeans_plus_plus(&data, 5, 43, &mut d2);
        assert_ne!(a, c);
    }

    #[test]
    fn kpp_spreads_over_blobs() {
        // With well-separated blobs, k-means++ should hit all of them
        // almost surely.
        let data = synth::gaussian_blobs(300, 2, 3, 0.05, 3);
        let mut dist = DistCounter::new();
        let c = kmeans_plus_plus(&data, 3, 1, &mut dist);
        // pairwise center distances must be blob-scale, not noise-scale
        for i in 0..3 {
            for j in (i + 1)..3 {
                assert!(crate::kernels::dist(c.row(i), c.row(j)) > 1.0);
            }
        }
    }

    #[test]
    fn kpp_handles_duplicates_fewer_distinct_than_k() {
        let rows: Vec<Vec<f64>> = vec![vec![1.0, 1.0]; 10];
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let data = Matrix::from_rows(&refs);
        let mut dist = DistCounter::new();
        let c = kmeans_plus_plus(&data, 3, 1, &mut dist);
        assert_eq!(c.rows(), 3); // padded from duplicate points
    }

    #[test]
    fn extend_centers_keeps_base_and_reaches_k() {
        let data = synth::gaussian_blobs(200, 3, 4, 0.3, 5);
        let mut dist = DistCounter::new();
        let base = kmeans_plus_plus(&data, 3, 1, &mut dist);
        let ext = extend_centers(&data, &base, 6, 2, &mut dist);
        assert_eq!((ext.rows(), ext.cols()), (6, 3));
        for i in 0..3 {
            assert_eq!(ext.row(i), base.row(i), "base center {i} must survive");
        }
        // Added rows are actual data points.
        for i in 3..6 {
            assert!((0..data.rows()).any(|r| data.row(r) == ext.row(i)));
        }
        // k == base.rows() is an identity.
        let same = extend_centers(&data, &base, 3, 9, &mut dist);
        assert_eq!(same, base);
    }

    #[test]
    fn extend_centers_parallel_is_byte_identical() {
        let data = synth::gaussian_blobs(500, 3, 5, 0.4, 6);
        let mut dist = DistCounter::new();
        let base = kmeans_plus_plus(&data, 4, 1, &mut dist);
        let mut d_seq = DistCounter::new();
        let seq = extend_centers(&data, &base, 9, 2, &mut d_seq);
        for threads in [2usize, 4] {
            let par = Parallelism::new(threads);
            let mut d_par = DistCounter::new();
            let p = extend_centers_par(&data, &base, 9, 2, &mut d_par, &par);
            assert_eq!(p, seq, "threads={threads}");
            assert_eq!(d_par.count(), d_seq.count(), "threads={threads}");
        }
    }

    #[test]
    fn random_init_distinct_indices() {
        let data = synth::gaussian_blobs(50, 2, 2, 0.5, 4);
        let c = random_init(&data, 10, 9);
        assert_eq!(c.rows(), 10);
    }

    /// The textbook unpruned `k-means||` loop, mirroring the production
    /// RNG and counter-draw streams exactly but evaluating every
    /// point-candidate distance. The pruned implementation must reproduce
    /// its centers bit for bit while counting no more distances.
    fn naive_kmeanspar(
        data: &Matrix,
        k: usize,
        seed: u64,
        rounds: usize,
        oversample: f64,
    ) -> (Matrix, u64) {
        let n = data.rows();
        let mut rng = Rng::derive(seed, "init/kmeans||");
        let mut dist = DistCounter::new();
        let first = rng.below(n);
        let sel_seed = rng.next_u64();
        let mut candidates = vec![first];
        let mut cand_rows: Vec<Vec<f64>> = vec![data.row(first).to_vec()];
        let mut d2: Vec<f64> = (0..n)
            .map(|i| dist.sq(data.row(i), data.row(first)))
            .collect();
        let mut near = vec![0u32; n];
        let l = oversample * k as f64;
        for round in 0..rounds {
            let phi: f64 = d2.iter().sum();
            if !(phi > 0.0) {
                break;
            }
            let fresh: Vec<usize> = (0..n)
                .filter(|&j| bernoulli_u(sel_seed, round, j) * phi < l * d2[j])
                .collect();
            for &fj in &fresh {
                let new_row = data.row(fj).to_vec();
                // Pay the same cc2 precomputation the pruned version pays
                // (it is part of its counted work).
                for row in cand_rows.iter() {
                    dist.sq(row, &new_row);
                }
                let new_id = candidates.len() as u32;
                candidates.push(fj);
                for i in 0..n {
                    if d2[i] > 0.0 {
                        let nd = dist.sq(data.row(i), &new_row);
                        if nd < d2[i] {
                            d2[i] = nd;
                            near[i] = new_id;
                        }
                    }
                }
                cand_rows.push(new_row);
            }
        }
        let mut weights = vec![0.0f64; cand_rows.len()];
        for &c in near.iter() {
            weights[c as usize] += 1.0;
        }
        let centers = weighted_recluster(
            data.into(),
            &candidates,
            &cand_rows,
            &weights,
            k,
            &mut rng,
            &mut dist,
        );
        (centers, dist.count())
    }

    #[test]
    fn kpar_matches_naive_reference_and_prunes() {
        for seed in [7u64, 42, 1000] {
            let data = synth::gaussian_blobs(400, 3, 5, 0.1, seed);
            let mut dc = DistCounter::new();
            let pruned = init_kmeanspar(&data, 5, seed, 3, 2.0, &mut dc);
            let (naive, naive_count) = naive_kmeanspar(&data, 5, seed, 3, 2.0);
            assert_eq!(pruned, naive, "seed {seed}: pruning changed the centers");
            assert!(
                dc.count() <= naive_count,
                "seed {seed}: pruned {} > naive {naive_count}",
                dc.count()
            );
        }
    }

    #[test]
    fn kpar_returns_k_centers_with_bounded_init_cost() {
        let data = synth::gaussian_blobs(600, 4, 8, 0.3, 21);
        let mut dc = DistCounter::new();
        let c = init_kmeanspar(&data, 8, 13, 4, 2.0, &mut dc);
        assert_eq!((c.rows(), c.cols()), (8, 4));
        for i in 0..8 {
            for j in (i + 1)..8 {
                assert_ne!(c.row(i), c.row(j), "duplicate center");
            }
        }
        // Floor: the first full pass always costs n evaluations. Ceiling:
        // the initial pass plus one (possibly pruned) pass per accepted
        // candidate plus the resident recluster — generously bounded by
        // (1 + candidates) passes with candidates <= a few * l * rounds.
        let n = 600u64;
        assert!(dc.count() >= n, "floor: {} < {n}", dc.count());
        let max_candidates = 1 + 8 * (2 * 4) * 4; // 1 + k * 2l * rounds
        let ceiling = n * (1 + max_candidates as u64) + 200_000;
        assert!(dc.count() <= ceiling, "ceiling: {} > {ceiling}", dc.count());
    }

    #[test]
    fn kpar_deterministic_across_threads_and_seeded() {
        let data = synth::gaussian_blobs(500, 3, 6, 0.4, 23);
        let mut d_seq = DistCounter::new();
        let seq = init_kmeanspar(&data, 6, 5, 3, 2.0, &mut d_seq);
        for threads in [2usize, 4] {
            let par = Parallelism::new(threads);
            let mut d_par = DistCounter::new();
            let p = init_kmeanspar_par(&data, 6, 5, 3, 2.0, &mut d_par, &par);
            assert_eq!(p, seq, "threads={threads}");
            assert_eq!(d_par.count(), d_seq.count(), "threads={threads}");
        }
        let mut d_other = DistCounter::new();
        let other = init_kmeanspar(&data, 6, 6, 3, 2.0, &mut d_other);
        assert_ne!(other, seq, "seed must matter");
    }

    #[test]
    fn kpar_identical_on_every_source_backend() {
        let data = synth::gaussian_blobs(300, 3, 4, 0.5, 29);
        let dir = std::env::temp_dir().join(format!(
            "covermeans_init_src_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("init_backends.dmat");
        crate::data::write_dmat(&path, &data).unwrap();
        let mut d_ram = DistCounter::new();
        let ram = init_kmeanspar(&data, 4, 11, 3, 2.0, &mut d_ram);
        for (name, ds) in [
            (
                "mmap",
                crate::data::DataSource::open(
                    &path,
                    crate::data::SourceBackend::Mmap,
                    0,
                    0,
                )
                .unwrap(),
            ),
            (
                "chunked",
                crate::data::DataSource::open(
                    &path,
                    crate::data::SourceBackend::Chunked,
                    7,
                    0,
                )
                .unwrap(),
            ),
        ] {
            let mut d_src = DistCounter::new();
            let c = init_kmeanspar_src(
                ds.view(),
                4,
                11,
                3,
                2.0,
                &mut d_src,
                &Parallelism::sequential(),
            );
            assert_eq!(c, ram, "{name}: centers differ from in-RAM");
            assert_eq!(d_src.count(), d_ram.count(), "{name}: counts differ");
        }
        let mut d_pp = DistCounter::new();
        let pp_ram = kmeans_plus_plus(&data, 4, 11, &mut d_pp);
        let ds = crate::data::DataSource::open(
            &path,
            crate::data::SourceBackend::Chunked,
            1,
            0,
        )
        .unwrap();
        let mut d_pp_src = DistCounter::new();
        let pp_src = kmeans_plus_plus_src(
            ds.view(),
            4,
            11,
            &mut d_pp_src,
            &Parallelism::sequential(),
        );
        assert_eq!(pp_src, pp_ram, "k-means++ must also be backend-invariant");
        assert_eq!(d_pp_src.count(), d_pp.count());
    }

    #[test]
    fn kpar_handles_duplicates_fewer_distinct_than_k() {
        let rows: Vec<Vec<f64>> = vec![vec![1.0, 1.0]; 10];
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let data = Matrix::from_rows(&refs);
        let mut dist = DistCounter::new();
        let c = init_kmeanspar(&data, 3, 1, 3, 2.0, &mut dist);
        assert_eq!(c.rows(), 3); // padded from duplicate points
    }
}
