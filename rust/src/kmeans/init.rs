//! Initialization: k-means++ [1] and uniform sampling.
//!
//! The paper evaluates every algorithm on *the same* 10 k-means++ seeds per
//! dataset, so initialization lives outside the per-algorithm counters: the
//! coordinator generates the centers once per `(dataset, k, restart)` and
//! hands identical copies to each algorithm. The `DistCounter` passed here
//! is therefore a separate "init" counter, not an algorithm counter.
//!
//! # Parallel, pruned D² sampling
//!
//! Both seeders keep one invariant sacred: the chosen centers are a
//! function of `(data, k, seed)` only. Two accelerations ride under it:
//!
//! * **Sharding** ([`kmeans_plus_plus_par`]): the per-point `d2`/`near`
//!   updates of a round are element-wise independent, so they shard over
//!   point chunks with disjoint writes; the weighted draw itself sums `d2`
//!   sequentially in canonical point order on the calling thread. Any
//!   thread count therefore reproduces the sequential seeding byte for
//!   byte — same centers, same counted distances.
//! * **Triangle-inequality pruning** (Raff, "Exact Acceleration of
//!   K-Means++ and K-Means||"): when candidate `q` is drawn, one distance
//!   per already-chosen center `c_j` is computed up front; a point `x`
//!   whose current nearest center `c` satisfies `d(c, q) >= 2 d(x, c)`
//!   cannot be improved by `q` (`d(x, q) >= d(c, q) - d(x, c) >= d(x,
//!   c)`), so its point-side evaluation is skipped. The skip is *exact*:
//!   every `d2` value — and hence the sampled sequence — is bit-identical
//!   to the unpruned loop; only the counted distance work shrinks. The
//!   real-arithmetic argument is made robust to floating point by
//!   [`prune_slack`]: the prune only fires when the margin also covers
//!   the worst-case relative rounding of the three squared distances
//!   involved, so a skipped evaluation provably could not have changed
//!   the stored (computed) `d2` value.

use crate::data::Matrix;
use crate::metrics::DistCounter;
use crate::parallel::{Parallelism, SharedSlices};
use crate::rng::Rng;

/// Multiplicative safety factor for the triangle prune: skip only when
/// `cc2 >= 4 * d2 * slack`. Each of the three squared distances in the
/// argument is a d-term sum of non-negative squares, so its relative
/// error is at most ~(d+3) ulps; a 16x cushion on top makes the prune
/// conservatively sound — a fired prune implies even the *computed*
/// point-side distance could not have been below the stored `d2` — at
/// the cost of a vanishing fraction of the pruning opportunities. A pure
/// function of the dimension, so it is identical at every thread count.
fn prune_slack(d: usize) -> f64 {
    1.0 + 16.0 * (d as f64 + 4.0) * f64::EPSILON
}

/// k-means++ seeding (Arthur & Vassilvitskii): first center uniform, each
/// subsequent center sampled proportionally to the squared distance to the
/// nearest already-chosen center. Sequential convenience wrapper over
/// [`kmeans_plus_plus_par`].
pub fn kmeans_plus_plus(
    data: &Matrix,
    k: usize,
    seed: u64,
    dist: &mut DistCounter,
) -> Matrix {
    kmeans_plus_plus_par(data, k, seed, dist, &Parallelism::sequential())
}

/// k-means++ seeding over `par`'s thread budget, with Raff-style
/// triangle-inequality pruning. Byte-identical centers to
/// [`kmeans_plus_plus`] at every thread count (see the module docs).
pub fn kmeans_plus_plus_par(
    data: &Matrix,
    k: usize,
    seed: u64,
    dist: &mut DistCounter,
    par: &Parallelism,
) -> Matrix {
    assert!(k >= 1 && k <= data.rows(), "k={k} out of range");
    let n = data.rows();
    let mut rng = Rng::derive(seed, "init/kmeans++");
    let mut chosen: Vec<usize> = Vec::with_capacity(k);

    let first = rng.below(n);
    chosen.push(first);

    // Squared distance to the nearest chosen center, updated
    // incrementally, plus that center's identity (which feeds the
    // triangle pruning).
    let mut d2 = vec![0.0f64; n];
    let mut near = vec![0u32; n];
    {
        let d2_sh = SharedSlices::new(&mut d2);
        let tallies = par.map_chunks(n, |r| {
            let d2c = unsafe { d2_sh.range(r.clone()) };
            let mut dc = DistCounter::new();
            for (j, i) in r.clone().enumerate() {
                d2c[j] = dc.sq(data.row(i), data.row(first));
            }
            dc.count()
        });
        for t in tallies {
            dist.add_bulk(t);
        }
    }

    // Squared distances from every already-chosen center to the newest
    // one — the O(k) pruning precomputation that saves O(n) point-side
    // evaluations per round.
    let mut cc2 = vec![0.0f64; k];
    let slack = prune_slack(data.cols());
    while chosen.len() < k {
        let next = match rng.choose_weighted(&d2) {
            Some(i) => i,
            // All remaining mass zero (fewer distinct points than k):
            // fall back to an unchosen index to keep k centers.
            None => (0..n).find(|i| !chosen.contains(i)).unwrap_or(0),
        };
        for (j, &c) in chosen.iter().enumerate() {
            cc2[j] = dist.sq(data.row(c), data.row(next));
        }
        let new_id = chosen.len() as u32;
        chosen.push(next);
        {
            let cc2 = &cc2;
            let d2_sh = SharedSlices::new(&mut d2);
            let near_sh = SharedSlices::new(&mut near);
            let tallies = par.map_chunks(n, |r| {
                let d2c = unsafe { d2_sh.range(r.clone()) };
                let nearc = unsafe { near_sh.range(r.clone()) };
                let mut dc = DistCounter::new();
                for (j, i) in r.clone().enumerate() {
                    if d2c[j] <= 0.0 {
                        continue;
                    }
                    // Triangle pruning (exact; see module docs): in
                    // squares, d(c,q)² >= 4 d(x,c)² ⇔ d(c,q) >= 2 d(x,c),
                    // with `slack` absorbing the rounding of the computed
                    // squared distances.
                    if cc2[nearc[j] as usize] >= 4.0 * d2c[j] * slack {
                        continue;
                    }
                    let nd = dc.sq(data.row(i), data.row(next));
                    if nd < d2c[j] {
                        d2c[j] = nd;
                        nearc[j] = new_id;
                    }
                }
                dc.count()
            });
            for t in tallies {
                dist.add_bulk(t);
            }
        }
    }
    data.select_rows(&chosen)
}

/// Extend an existing center set to `k` rows — the warm-started sweep
/// protocol: keep `base` (a previous, smaller-k solution) and add the
/// missing centers by the same D² sampling k-means++ uses, measured
/// against the current set. `base.rows()` may equal `k` (returns a copy).
/// Sequential convenience wrapper over [`extend_centers_par`].
pub fn extend_centers(
    data: &Matrix,
    base: &Matrix,
    k: usize,
    seed: u64,
    dist: &mut DistCounter,
) -> Matrix {
    extend_centers_par(data, base, k, seed, dist, &Parallelism::sequential())
}

/// [`extend_centers`] over `par`'s thread budget with the same pruned D²
/// rounds as [`kmeans_plus_plus_par`]; byte-identical to the sequential
/// version at every thread count.
pub fn extend_centers_par(
    data: &Matrix,
    base: &Matrix,
    k: usize,
    seed: u64,
    dist: &mut DistCounter,
    par: &Parallelism,
) -> Matrix {
    assert!(base.rows() <= k, "cannot shrink {} centers to k={k}", base.rows());
    assert!(k <= data.rows(), "k={k} out of range");
    assert_eq!(base.cols(), data.cols(), "center/data dimension mismatch");
    let n = data.rows();
    let mut rng = Rng::derive(seed, "init/extend");
    let mut rows: Vec<Vec<f64>> = base.iter_rows().map(|r| r.to_vec()).collect();
    let mut chosen: Vec<usize> = Vec::new();

    // Nearest base center per point (distance² and identity).
    let mut d2 = vec![f64::INFINITY; n];
    let mut near = vec![0u32; n];
    {
        let d2_sh = SharedSlices::new(&mut d2);
        let near_sh = SharedSlices::new(&mut near);
        let tallies = par.map_chunks(n, |r| {
            let d2c = unsafe { d2_sh.range(r.clone()) };
            let nearc = unsafe { near_sh.range(r.clone()) };
            let mut dc = DistCounter::new();
            for (j, i) in r.clone().enumerate() {
                let mut best = f64::INFINITY;
                let mut bi = 0u32;
                for c in 0..base.rows() {
                    let nd = dc.sq(data.row(i), base.row(c));
                    if nd < best {
                        best = nd;
                        bi = c as u32;
                    }
                }
                d2c[j] = best;
                nearc[j] = bi;
            }
            dc.count()
        });
        for t in tallies {
            dist.add_bulk(t);
        }
    }

    let mut cc2 = vec![0.0f64; k];
    let slack = prune_slack(data.cols());
    while rows.len() < k {
        let next = match rng.choose_weighted(&d2) {
            Some(i) => i,
            // All remaining mass zero: fall back to an unchosen index.
            None => (0..n).find(|i| !chosen.contains(i)).unwrap_or(0),
        };
        chosen.push(next);
        for (j, row) in rows.iter().enumerate() {
            cc2[j] = dist.sq(row, data.row(next));
        }
        let new_id = rows.len() as u32;
        rows.push(data.row(next).to_vec());
        {
            let cc2 = &cc2;
            let d2_sh = SharedSlices::new(&mut d2);
            let near_sh = SharedSlices::new(&mut near);
            let tallies = par.map_chunks(n, |r| {
                let d2c = unsafe { d2_sh.range(r.clone()) };
                let nearc = unsafe { near_sh.range(r.clone()) };
                let mut dc = DistCounter::new();
                for (j, i) in r.clone().enumerate() {
                    if d2c[j] <= 0.0 {
                        continue;
                    }
                    if cc2[nearc[j] as usize] >= 4.0 * d2c[j] * slack {
                        continue;
                    }
                    let nd = dc.sq(data.row(i), data.row(next));
                    if nd < d2c[j] {
                        d2c[j] = nd;
                        nearc[j] = new_id;
                    }
                }
                dc.count()
            });
            for t in tallies {
                dist.add_bulk(t);
            }
        }
    }
    let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
    Matrix::from_rows(&refs)
}

/// Uniform random distinct-index sampling (baseline init for tests).
pub fn random_init(data: &Matrix, k: usize, seed: u64) -> Matrix {
    assert!(k >= 1 && k <= data.rows());
    let mut rng = Rng::derive(seed, "init/random");
    let mut idx: Vec<usize> = (0..data.rows()).collect();
    rng.shuffle(&mut idx);
    idx.truncate(k);
    data.select_rows(&idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    /// The textbook unpruned D² loop, kept as the reference the pruned
    /// implementation must reproduce center-for-center. Returns the
    /// centers and the unpruned distance-evaluation count.
    fn naive_kmeans_plus_plus(data: &Matrix, k: usize, seed: u64) -> (Matrix, u64) {
        let n = data.rows();
        let mut rng = Rng::derive(seed, "init/kmeans++");
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        let mut dist = DistCounter::new();
        let first = rng.below(n);
        chosen.push(first);
        let mut d2: Vec<f64> = (0..n)
            .map(|i| dist.sq(data.row(i), data.row(first)))
            .collect();
        while chosen.len() < k {
            let next = match rng.choose_weighted(&d2) {
                Some(i) => i,
                None => (0..n).find(|i| !chosen.contains(i)).unwrap_or(0),
            };
            chosen.push(next);
            for i in 0..n {
                if d2[i] > 0.0 {
                    let nd = dist.sq(data.row(i), data.row(next));
                    if nd < d2[i] {
                        d2[i] = nd;
                    }
                }
            }
        }
        (data.select_rows(&chosen), dist.count())
    }

    #[test]
    fn kpp_returns_k_distinct_centers_from_data() {
        let data = synth::gaussian_blobs(200, 3, 4, 0.3, 1);
        let mut dist = DistCounter::new();
        let c = kmeans_plus_plus(&data, 4, 7, &mut dist);
        assert_eq!((c.rows(), c.cols()), (4, 3));
        // every center is an actual data row
        for i in 0..4 {
            assert!((0..data.rows()).any(|r| data.row(r) == c.row(i)));
        }
        // distinct rows (blob data has no duplicates)
        for i in 0..4 {
            for j in (i + 1)..4 {
                assert_ne!(c.row(i), c.row(j));
            }
        }
        // At least the first full scan is always paid; later rounds are
        // triangle-pruned, so the pre-pruning n*(k-1) floor no longer
        // applies.
        assert!(dist.count() >= 200);
    }

    #[test]
    fn kpp_pruning_matches_naive_and_saves_work() {
        for seed in [7u64, 42, 1000] {
            // Well-separated blobs: most points sit far closer to their
            // blob's chosen center than to any newly drawn candidate, so
            // the triangle test prunes heavily.
            let data = synth::gaussian_blobs(400, 3, 5, 0.1, seed);
            let mut pruned_dist = DistCounter::new();
            let pruned = kmeans_plus_plus(&data, 5, seed, &mut pruned_dist);
            let (naive, naive_count) = naive_kmeans_plus_plus(&data, 5, seed);
            assert_eq!(pruned, naive, "seed {seed}: pruning changed the centers");
            // The pruned run pays k²/2 extra center-center evals but must
            // still come out well ahead of the unpruned point-side cost.
            assert!(
                pruned_dist.count() < naive_count,
                "seed {seed}: pruned {} >= naive {naive_count}",
                pruned_dist.count()
            );
        }
    }

    #[test]
    fn kpp_parallel_is_byte_identical() {
        let data = synth::gaussian_blobs(700, 4, 6, 0.5, 9);
        let mut d_seq = DistCounter::new();
        let seq = kmeans_plus_plus(&data, 10, 3, &mut d_seq);
        for threads in [2usize, 4] {
            let par = Parallelism::new(threads);
            let mut d_par = DistCounter::new();
            let p = kmeans_plus_plus_par(&data, 10, 3, &mut d_par, &par);
            assert_eq!(p, seq, "threads={threads}");
            assert_eq!(d_par.count(), d_seq.count(), "threads={threads}");
        }
    }

    #[test]
    fn kpp_deterministic_in_seed() {
        let data = synth::gaussian_blobs(100, 2, 3, 0.5, 2);
        let mut d1 = DistCounter::new();
        let mut d2 = DistCounter::new();
        let a = kmeans_plus_plus(&data, 5, 42, &mut d1);
        let b = kmeans_plus_plus(&data, 5, 42, &mut d2);
        assert_eq!(a, b);
        let c = kmeans_plus_plus(&data, 5, 43, &mut d2);
        assert_ne!(a, c);
    }

    #[test]
    fn kpp_spreads_over_blobs() {
        // With well-separated blobs, k-means++ should hit all of them
        // almost surely.
        let data = synth::gaussian_blobs(300, 2, 3, 0.05, 3);
        let mut dist = DistCounter::new();
        let c = kmeans_plus_plus(&data, 3, 1, &mut dist);
        // pairwise center distances must be blob-scale, not noise-scale
        for i in 0..3 {
            for j in (i + 1)..3 {
                assert!(crate::kernels::dist(c.row(i), c.row(j)) > 1.0);
            }
        }
    }

    #[test]
    fn kpp_handles_duplicates_fewer_distinct_than_k() {
        let rows: Vec<Vec<f64>> = vec![vec![1.0, 1.0]; 10];
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let data = Matrix::from_rows(&refs);
        let mut dist = DistCounter::new();
        let c = kmeans_plus_plus(&data, 3, 1, &mut dist);
        assert_eq!(c.rows(), 3); // padded from duplicate points
    }

    #[test]
    fn extend_centers_keeps_base_and_reaches_k() {
        let data = synth::gaussian_blobs(200, 3, 4, 0.3, 5);
        let mut dist = DistCounter::new();
        let base = kmeans_plus_plus(&data, 3, 1, &mut dist);
        let ext = extend_centers(&data, &base, 6, 2, &mut dist);
        assert_eq!((ext.rows(), ext.cols()), (6, 3));
        for i in 0..3 {
            assert_eq!(ext.row(i), base.row(i), "base center {i} must survive");
        }
        // Added rows are actual data points.
        for i in 3..6 {
            assert!((0..data.rows()).any(|r| data.row(r) == ext.row(i)));
        }
        // k == base.rows() is an identity.
        let same = extend_centers(&data, &base, 3, 9, &mut dist);
        assert_eq!(same, base);
    }

    #[test]
    fn extend_centers_parallel_is_byte_identical() {
        let data = synth::gaussian_blobs(500, 3, 5, 0.4, 6);
        let mut dist = DistCounter::new();
        let base = kmeans_plus_plus(&data, 4, 1, &mut dist);
        let mut d_seq = DistCounter::new();
        let seq = extend_centers(&data, &base, 9, 2, &mut d_seq);
        for threads in [2usize, 4] {
            let par = Parallelism::new(threads);
            let mut d_par = DistCounter::new();
            let p = extend_centers_par(&data, &base, 9, 2, &mut d_par, &par);
            assert_eq!(p, seq, "threads={threads}");
            assert_eq!(d_par.count(), d_seq.count(), "threads={threads}");
        }
    }

    #[test]
    fn random_init_distinct_indices() {
        let data = synth::gaussian_blobs(50, 2, 2, 0.5, 4);
        let c = random_init(&data, 10, 9);
        assert_eq!(c.rows(), 10);
    }
}
