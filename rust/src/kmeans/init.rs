//! Initialization: k-means++ [1] and uniform sampling.
//!
//! The paper evaluates every algorithm on *the same* 10 k-means++ seeds per
//! dataset, so initialization lives outside the per-algorithm counters: the
//! coordinator generates the centers once per `(dataset, k, restart)` and
//! hands identical copies to each algorithm. The `DistCounter` passed here
//! is therefore a separate "init" counter, not an algorithm counter.

use crate::data::Matrix;
use crate::metrics::DistCounter;
use crate::rng::Rng;

/// k-means++ seeding (Arthur & Vassilvitskii): first center uniform, each
/// subsequent center sampled proportionally to the squared distance to the
/// nearest already-chosen center.
pub fn kmeans_plus_plus(
    data: &Matrix,
    k: usize,
    seed: u64,
    dist: &mut DistCounter,
) -> Matrix {
    assert!(k >= 1 && k <= data.rows(), "k={k} out of range");
    let n = data.rows();
    let mut rng = Rng::derive(seed, "init/kmeans++");
    let mut chosen: Vec<usize> = Vec::with_capacity(k);

    let first = rng.below(n);
    chosen.push(first);

    // Squared distance to the nearest chosen center, updated incrementally.
    let mut d2: Vec<f64> = (0..n)
        .map(|i| dist.sq(data.row(i), data.row(first)))
        .collect();

    while chosen.len() < k {
        let next = match rng.choose_weighted(&d2) {
            Some(i) => i,
            // All remaining mass zero (fewer distinct points than k):
            // fall back to an unchosen index to keep k centers.
            None => (0..n).find(|i| !chosen.contains(i)).unwrap_or(0),
        };
        chosen.push(next);
        for i in 0..n {
            if d2[i] > 0.0 {
                let nd = dist.sq(data.row(i), data.row(next));
                if nd < d2[i] {
                    d2[i] = nd;
                }
            }
        }
    }
    data.select_rows(&chosen)
}

/// Extend an existing center set to `k` rows — the warm-started sweep
/// protocol: keep `base` (a previous, smaller-k solution) and add the
/// missing centers by the same D² sampling k-means++ uses, measured
/// against the current set. `base.rows()` may equal `k` (returns a copy).
pub fn extend_centers(
    data: &Matrix,
    base: &Matrix,
    k: usize,
    seed: u64,
    dist: &mut DistCounter,
) -> Matrix {
    assert!(base.rows() <= k, "cannot shrink {} centers to k={k}", base.rows());
    assert!(k <= data.rows(), "k={k} out of range");
    assert_eq!(base.cols(), data.cols(), "center/data dimension mismatch");
    let n = data.rows();
    let mut rng = Rng::derive(seed, "init/extend");
    let mut rows: Vec<Vec<f64>> = base.iter_rows().map(|r| r.to_vec()).collect();
    let mut chosen: Vec<usize> = Vec::new();

    let mut d2: Vec<f64> = (0..n)
        .map(|i| {
            let mut best = f64::INFINITY;
            for c in 0..base.rows() {
                let nd = dist.sq(data.row(i), base.row(c));
                if nd < best {
                    best = nd;
                }
            }
            best
        })
        .collect();

    while rows.len() < k {
        let next = match rng.choose_weighted(&d2) {
            Some(i) => i,
            // All remaining mass zero: fall back to an unchosen index.
            None => (0..n).find(|i| !chosen.contains(i)).unwrap_or(0),
        };
        chosen.push(next);
        rows.push(data.row(next).to_vec());
        for i in 0..n {
            if d2[i] > 0.0 {
                let nd = dist.sq(data.row(i), data.row(next));
                if nd < d2[i] {
                    d2[i] = nd;
                }
            }
        }
    }
    let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
    Matrix::from_rows(&refs)
}

/// Uniform random distinct-index sampling (baseline init for tests).
pub fn random_init(data: &Matrix, k: usize, seed: u64) -> Matrix {
    assert!(k >= 1 && k <= data.rows());
    let mut rng = Rng::derive(seed, "init/random");
    let mut idx: Vec<usize> = (0..data.rows()).collect();
    rng.shuffle(&mut idx);
    idx.truncate(k);
    data.select_rows(&idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn kpp_returns_k_distinct_centers_from_data() {
        let data = synth::gaussian_blobs(200, 3, 4, 0.3, 1);
        let mut dist = DistCounter::new();
        let c = kmeans_plus_plus(&data, 4, 7, &mut dist);
        assert_eq!((c.rows(), c.cols()), (4, 3));
        // every center is an actual data row
        for i in 0..4 {
            assert!((0..data.rows()).any(|r| data.row(r) == c.row(i)));
        }
        // distinct rows (blob data has no duplicates)
        for i in 0..4 {
            for j in (i + 1)..4 {
                assert_ne!(c.row(i), c.row(j));
            }
        }
        assert!(dist.count() >= 200 * 3);
    }

    #[test]
    fn kpp_deterministic_in_seed() {
        let data = synth::gaussian_blobs(100, 2, 3, 0.5, 2);
        let mut d1 = DistCounter::new();
        let mut d2 = DistCounter::new();
        let a = kmeans_plus_plus(&data, 5, 42, &mut d1);
        let b = kmeans_plus_plus(&data, 5, 42, &mut d2);
        assert_eq!(a, b);
        let c = kmeans_plus_plus(&data, 5, 43, &mut d2);
        assert_ne!(a, c);
    }

    #[test]
    fn kpp_spreads_over_blobs() {
        // With well-separated blobs, k-means++ should hit all of them
        // almost surely.
        let data = synth::gaussian_blobs(300, 2, 3, 0.05, 3);
        let mut dist = DistCounter::new();
        let c = kmeans_plus_plus(&data, 3, 1, &mut dist);
        // pairwise center distances must be blob-scale, not noise-scale
        for i in 0..3 {
            for j in (i + 1)..3 {
                assert!(crate::data::matrix::dist(c.row(i), c.row(j)) > 1.0);
            }
        }
    }

    #[test]
    fn kpp_handles_duplicates_fewer_distinct_than_k() {
        let rows: Vec<Vec<f64>> = vec![vec![1.0, 1.0]; 10];
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let data = Matrix::from_rows(&refs);
        let mut dist = DistCounter::new();
        let c = kmeans_plus_plus(&data, 3, 1, &mut dist);
        assert_eq!(c.rows(), 3); // padded from duplicate points
    }

    #[test]
    fn extend_centers_keeps_base_and_reaches_k() {
        let data = synth::gaussian_blobs(200, 3, 4, 0.3, 5);
        let mut dist = DistCounter::new();
        let base = kmeans_plus_plus(&data, 3, 1, &mut dist);
        let ext = extend_centers(&data, &base, 6, 2, &mut dist);
        assert_eq!((ext.rows(), ext.cols()), (6, 3));
        for i in 0..3 {
            assert_eq!(ext.row(i), base.row(i), "base center {i} must survive");
        }
        // Added rows are actual data points.
        for i in 3..6 {
            assert!((0..data.rows()).any(|r| data.row(r) == ext.row(i)));
        }
        // k == base.rows() is an identity.
        let same = extend_centers(&data, &base, 3, 9, &mut dist);
        assert_eq!(same, base);
    }

    #[test]
    fn random_init_distinct_indices() {
        let data = synth::gaussian_blobs(50, 2, 2, 0.5, 4);
        let c = random_init(&data, 10, 9);
        assert_eq!(c.rows(), 10);
    }
}
