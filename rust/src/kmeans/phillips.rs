//! Phillips' compare-means [15] — the earliest triangle-inequality
//! acceleration the paper builds on (§2.2, Eq. 5): keep no stored bounds,
//! but per point first tighten `d(x, c_a)` and then skip every candidate
//! `c_j` with `d(c_a, c_j) >= 2 d(x, c_a)`, which by Eq. 5 cannot be
//! nearer. Exact, memoryless, and the conceptual ancestor of the Eq. 9
//! node-level filter in Cover-means.

use crate::data::Matrix;
use crate::kmeans::bounds::{CentroidAccum, InterCenter};
use crate::kmeans::KMeansParams;
use crate::metrics::{DistCounter, IterationLog, RunResult, Stopwatch};

pub fn run(data: &Matrix, init: &Matrix, params: &KMeansParams) -> RunResult {
    let n = data.rows();
    let d = data.cols();
    let k = init.rows();
    let sw = Stopwatch::start();
    let mut dist = DistCounter::new();

    let mut centers = init.clone();
    let mut labels = vec![0u32; n];
    let mut acc = CentroidAccum::new(k, d);
    let mut movement: Vec<f64> = Vec::with_capacity(k);
    let mut log = IterationLog::new();
    let mut converged = false;
    let mut iterations = 0;

    // Iteration 1: plain full scan (no previous assignment to seed Eq. 5).
    {
        acc.clear();
        for i in 0..n {
            let p = data.row(i);
            let mut best = 0u32;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let dd = dist.d(p, centers.row(c));
                if dd < best_d {
                    best_d = dd;
                    best = c as u32;
                }
            }
            labels[i] = best;
            acc.add_point(best as usize, p);
        }
        acc.update_centers(&mut centers, &mut dist, &mut movement);
        iterations = 1;
        log.push(1, dist.count(), sw.elapsed(), n);
    }

    for iter in 2..=params.max_iter {
        iterations = iter;
        let ic = InterCenter::compute(&centers, &mut dist);
        acc.clear();
        let mut changed = 0usize;

        for i in 0..n {
            let p = data.row(i);
            let a = labels[i] as usize;
            // Tighten the anchor distance, then Eq. 5 filter against it.
            let mut best = a as u32;
            let mut best_d = dist.d(p, centers.row(a));
            for j in 0..k {
                if j == a {
                    continue;
                }
                // Filter against the *current* best (a running variant of
                // Eq. 5, strictly stronger than anchoring on a alone).
                if ic.d(best as usize, j) >= 2.0 * best_d {
                    continue;
                }
                let dj = dist.d(p, centers.row(j));
                if dj < best_d || (dj == best_d && (j as u32) < best) {
                    best_d = dj;
                    best = j as u32;
                }
            }
            if labels[i] != best {
                labels[i] = best;
                changed += 1;
            }
            acc.add_point(best as usize, p);
        }

        acc.update_centers(&mut centers, &mut dist, &mut movement);
        log.push(iter, dist.count(), sw.elapsed(), changed);
        if changed == 0 {
            converged = true;
            break;
        }
    }

    RunResult {
        labels,
        centers,
        iterations,
        distances: dist.count(),
        build_dist: 0,
        time: sw.elapsed(),
        build_time: std::time::Duration::ZERO,
        log,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(400, 4, 6, 1.0, 31);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 6, 24, &mut dc);
        let params = KMeansParams::default();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_p = run(&data, &init_c, &params);
        assert_eq!(r_p.labels, r_l.labels);
        assert_eq!(r_p.iterations, r_l.iterations);
    }

    #[test]
    fn saves_distances_on_clustered_data() {
        let data = synth::istanbul(0.002, 32);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 25, 25, &mut dc);
        let params = KMeansParams::default();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_p = run(&data, &init_c, &params);
        assert_eq!(r_p.labels, r_l.labels);
        assert!(r_p.distances < r_l.distances);
    }

    #[test]
    fn weaker_than_stored_bounds_late() {
        // Phillips has no stored bounds, so once centers stabilize it
        // still pays ~n distance tightenings per iteration — more than
        // Hamerly-family methods on easy data.
        let data = synth::gaussian_blobs(600, 3, 6, 0.2, 33);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 6, 26, &mut dc);
        let params = KMeansParams::default();
        let r_p = run(&data, &init_c, &params);
        let r_s = crate::kmeans::shallot::run(&data, &init_c, &params);
        assert_eq!(r_p.labels, r_s.labels);
        assert!(r_p.distances >= r_s.distances);
    }
}
