//! Phillips' compare-means [15] — the earliest triangle-inequality
//! acceleration the paper builds on (§2.2, Eq. 5): keep no stored bounds,
//! but per point first tighten `d(x, c_a)` and then skip every candidate
//! `c_j` with `d(c_a, c_j) >= 2 d(x, c_a)`, which by Eq. 5 cannot be
//! nearer. Exact, memoryless, and the conceptual ancestor of the Eq. 9
//! node-level filter in Cover-means.

use crate::data::Matrix;
use crate::kmeans::bounds::{accumulate_in_order, CentroidAccum, InterCenter};
use crate::kmeans::driver::{DriverState, Fit, KMeansDriver};
use crate::kmeans::{Algorithm, KMeansParams};
use crate::metrics::{DistCounter, RunResult};
use crate::parallel::{Parallelism, SharedSlices};

/// Memoryless Eq. 5 driver: only the labels persist between iterations.
pub(crate) struct PhillipsDriver<'a> {
    data: &'a Matrix,
    labels: Vec<u32>,
    par: Parallelism,
}

impl<'a> PhillipsDriver<'a> {
    pub(crate) fn new(data: &'a Matrix, par: Parallelism) -> PhillipsDriver<'a> {
        PhillipsDriver { data, labels: vec![0u32; data.rows()], par }
    }

}

impl KMeansDriver for PhillipsDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Phillips
    }

    /// Iteration 1: plain full scan (no previous assignment to seed Eq. 5).
    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let data = self.data;
        let n = data.rows();
        let k = centers.rows();
        {
            let labels_sh = SharedSlices::new(&mut self.labels);
            let counts = self.par.map_chunks(n, |r| {
                let labels = unsafe { labels_sh.range(r.clone()) };
                let mut dc = DistCounter::new();
                for (j, i) in r.clone().enumerate() {
                    let p = data.row(i);
                    let mut best = 0u32;
                    let mut best_d = f64::INFINITY;
                    for c in 0..k {
                        let dd = dc.d(p, centers.row(c));
                        if dd < best_d {
                            best_d = dd;
                            best = c as u32;
                        }
                    }
                    labels[j] = best;
                }
                dc.count()
            });
            for count in counts {
                dist.add_bulk(count);
            }
        }
        accumulate_in_order(data, &self.labels, acc);
        n
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let k = centers.rows();
        let ic = InterCenter::compute_par(centers, dist, &self.par);
        let data = self.data;
        let n = data.rows();
        let mut changed = 0usize;
        {
            let ic = &ic;
            let labels_sh = SharedSlices::new(&mut self.labels);
            let results = self.par.map_chunks(n, |r| {
                let labels = unsafe { labels_sh.range(r.clone()) };
                let mut dc = DistCounter::new();
                let mut changed = 0usize;
                for (jj, i) in r.clone().enumerate() {
                    let p = data.row(i);
                    let a = labels[jj] as usize;
                    // Tighten the anchor distance, then Eq. 5 filter.
                    let mut best = a as u32;
                    let mut best_d = dc.d(p, centers.row(a));
                    for j in 0..k {
                        if j == a {
                            continue;
                        }
                        // Filter against the *current* best (a running
                        // variant of Eq. 5, strictly stronger than
                        // anchoring on a alone).
                        if ic.d(best as usize, j) >= 2.0 * best_d {
                            continue;
                        }
                        let dj = dc.d(p, centers.row(j));
                        if dj < best_d || (dj == best_d && (j as u32) < best) {
                            best_d = dj;
                            best = j as u32;
                        }
                    }
                    if labels[jj] != best {
                        labels[jj] = best;
                        changed += 1;
                    }
                }
                (changed, dc.count())
            });
            for (ch, count) in results {
                changed += ch;
                dist.add_bulk(count);
            }
        }
        accumulate_in_order(data, &self.labels, acc);
        changed
    }

    fn labels(&self) -> &[u32] {
        &self.labels
    }

    fn save_state(&self) -> Option<DriverState> {
        Some(DriverState::new(self.labels.clone()))
    }

    fn load_state(&mut self, state: &DriverState) -> anyhow::Result<()> {
        self.labels = state.labels_checked(self.data.rows())?.to_vec();
        Ok(())
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.labels
    }
}

/// Legacy shim: drive compare-means through the shared loop.
pub fn run(data: &Matrix, init: &Matrix, params: &KMeansParams) -> RunResult {
    Fit::from_driver(
        data,
        Box::new(PhillipsDriver::new(data, Parallelism::new(params.threads))),
        init,
        params.max_iter,
        params.tol,
    )
    .run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, KMeansParams};
    use crate::metrics::DistCounter;

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(400, 4, 6, 1.0, 31);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 6, 24, &mut dc);
        let params = KMeansParams::default();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_p = run(&data, &init_c, &params);
        assert_eq!(r_p.labels, r_l.labels);
        assert_eq!(r_p.iterations, r_l.iterations);
    }

    #[test]
    fn saves_distances_on_clustered_data() {
        let data = synth::istanbul(0.002, 32);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 25, 25, &mut dc);
        let params = KMeansParams::default();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_p = run(&data, &init_c, &params);
        assert_eq!(r_p.labels, r_l.labels);
        assert!(r_p.distances < r_l.distances);
    }

    #[test]
    fn weaker_than_stored_bounds_late() {
        // Phillips has no stored bounds, so once centers stabilize it
        // still pays ~n distance tightenings per iteration — more than
        // Hamerly-family methods on easy data.
        let data = synth::gaussian_blobs(600, 3, 6, 0.2, 33);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 6, 26, &mut dc);
        let params = KMeansParams::default();
        let r_p = run(&data, &init_c, &params);
        let r_s = crate::kmeans::shallot::run(&data, &init_c, &params);
        assert_eq!(r_p.labels, r_s.labels);
        assert!(r_p.distances >= r_s.distances);
    }
}
