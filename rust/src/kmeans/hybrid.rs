//! Hybrid Cover-means -> Shallot (paper §3.4).
//!
//! The tree pass saves distance computations while the centers still move
//! a lot (it can prune candidates in iteration 1 already); the
//! stored-bounds pass wins once the centers stabilize. The hybrid runs
//! Cover-means for `switch_at` iterations (paper default 7), then hands
//! Shallot the upper/lower bounds and second-nearest identities that the
//! tree traversal produced as a by-product (Eqs. 15-18) — *without* the
//! full n x k scan every stored-bounds algorithm normally pays to
//! initialize its bounds.

use std::sync::Arc;

use crate::data::Matrix;
use crate::kmeans::bounds::CentroidAccum;
use crate::kmeans::driver::{DriverState, Fit, KMeansDriver};
use crate::kmeans::shallot::ShallotState;
use crate::kmeans::{cover, hamerly, shallot, Algorithm, KMeansParams, Workspace};
use crate::metrics::{DistCounter, RunResult};
use crate::parallel::Parallelism;
use crate::tree::CoverTree;

/// Phase-switching driver: Cover-means passes for iterations
/// `1..=switch_at`, Shallot passes afterwards, with the bound hand-off in
/// [`KMeansDriver::post_update`] at the switch iteration. Both phases
/// shard over `par`'s thread budget with exactness-preserving reductions.
pub(crate) struct HybridDriver<'a> {
    data: &'a Matrix,
    tree: Arc<CoverTree>,
    switch_at: usize,
    state: ShallotState,
    par: Parallelism,
}

impl<'a> HybridDriver<'a> {
    pub(crate) fn new(
        data: &'a Matrix,
        tree: Arc<CoverTree>,
        switch_at: usize,
        par: Parallelism,
    ) -> HybridDriver<'a> {
        HybridDriver {
            data,
            tree,
            switch_at,
            state: ShallotState::unassigned(data.rows()),
            par,
        }
    }

    fn pass(
        &mut self,
        iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        if iter <= self.switch_at {
            cover::iterate_pass(
                self.data,
                &self.tree,
                centers,
                &mut self.state.labels,
                &mut self.state.upper,
                &mut self.state.lower,
                &mut self.state.second,
                acc,
                dist,
                &self.par,
            )
        } else {
            shallot::iterate_pass(
                self.data,
                centers,
                &mut self.state,
                acc,
                dist,
                &self.par,
            )
        }
    }
}

impl KMeansDriver for HybridDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Hybrid
    }

    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(1, centers, acc, dist)
    }

    fn iterate(
        &mut self,
        iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(iter, centers, acc, dist)
    }

    fn post_update(&mut self, iter: usize, movement: &[f64]) {
        // At iter == switch_at this is the hand-off (§3.4): the tree pass
        // left bounds valid for the pre-movement centers; carry them
        // across the movement exactly like the stored-bounds algorithms
        // do (§2.2). Afterwards it is Shallot's per-iteration maintenance.
        // Cover-phase iterations overwrite their bounds anyway.
        if iter >= self.switch_at {
            hamerly::update_bounds(
                &mut self.state.upper,
                &mut self.state.lower,
                &self.state.labels,
                movement,
            );
        }
    }

    fn labels(&self) -> &[u32] {
        &self.state.labels
    }

    fn save_state(&self) -> Option<DriverState> {
        Some(self.state.to_driver_state())
    }

    fn load_state(&mut self, state: &DriverState) -> anyhow::Result<()> {
        self.state = ShallotState::from_driver_state(state, self.data.rows())?;
        Ok(())
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.state.labels
    }
}

/// Legacy shim: drive the Hybrid through the shared loop, reusing (or
/// building) the workspace's cover tree.
pub fn run(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> RunResult {
    let par = ws.parallelism_opts(params.threads, params.pin_workers);
    let (tree, fresh) = ws.cover_tree_arc_par(data, params.cover, &par);
    let (build_dist, build_time) = if fresh {
        (tree.build_distances, tree.build_time)
    } else {
        (0, std::time::Duration::ZERO)
    };
    Fit::from_driver(
        data,
        Box::new(HybridDriver::new(data, tree, params.switch_at, par)),
        init,
        params.max_iter,
        params.tol,
    )
    .with_build_cost(build_dist, build_time)
    .run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;
    use crate::tree::CoverTreeParams;

    fn hybrid_params() -> KMeansParams {
        KMeansParams {
            cover: CoverTreeParams { scale_factor: 1.2, min_node_size: 10 },
            ..KMeansParams::with_algorithm(Algorithm::Hybrid)
        }
    }

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(500, 3, 6, 1.0, 25);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 6, 19, &mut dc);
        let params = hybrid_params();
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_h = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_h.labels, r_l.labels);
        assert_eq!(r_h.iterations, r_l.iterations);
    }

    #[test]
    fn matches_lloyd_geo_many_clusters() {
        let data = synth::istanbul(0.002, 26);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 30, 20, &mut dc);
        let params = hybrid_params();
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_h = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_h.labels, r_l.labels);
        assert_eq!(r_h.iterations, r_l.iterations);
    }

    #[test]
    fn converges_during_tree_phase_on_easy_data() {
        // Well-separated blobs converge in < 7 iterations; the hybrid must
        // terminate inside the cover phase.
        let data = synth::gaussian_blobs(300, 2, 3, 0.05, 27);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 3, 21, &mut dc);
        let params = hybrid_params();
        let mut ws = Workspace::new();
        let r = run(&data, &init_c, &params, &mut ws);
        assert!(r.converged);
        assert!(r.iterations <= 7, "iterations {}", r.iterations);
    }

    #[test]
    fn switch_at_respected_and_uses_fewer_distances_late() {
        let data = synth::istanbul(0.003, 28);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 40, 22, &mut dc);
        let params = KMeansParams { switch_at: 3, ..hybrid_params() };
        let mut ws = Workspace::new();
        let r_h = run(&data, &init_c, &params, &mut ws);
        let r_c = crate::kmeans::cover::run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_h.labels, r_c.labels);
        if r_h.iterations > 8 {
            // Late iterations: the hybrid (Shallot phase) must be cheaper
            // per iteration than the pure tree method.
            let late_h = r_h.log.stats.last().unwrap().dist_cum
                - r_h.log.stats[r_h.log.len() - 2].dist_cum;
            let late_c = r_c.log.stats.last().unwrap().dist_cum
                - r_c.log.stats[r_c.log.len() - 2].dist_cum;
            assert!(late_h <= late_c, "late hybrid {late_h} vs cover {late_c}");
        }
    }

    #[test]
    fn switch_at_zero_is_pure_shallot_with_scan_init() {
        // Degenerate configuration: switch_at = 0 skips the tree phase;
        // the Shallot phase then starts from iteration 1 with unseeded
        // bounds. Guard: we document switch_at >= 1; value 0 must still
        // terminate and be exact (first Shallot iteration sees u=0, l=0,
        // forcing full searches).
        let data = synth::gaussian_blobs(200, 2, 4, 0.5, 29);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 4, 23, &mut dc);
        let params = KMeansParams { switch_at: 1, ..hybrid_params() };
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_h = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_h.labels, r_l.labels);
    }
}
