//! Hybrid Cover-means -> Shallot (paper §3.4).
//!
//! The tree pass saves distance computations while the centers still move
//! a lot (it can prune candidates in iteration 1 already); the
//! stored-bounds pass wins once the centers stabilize. The hybrid runs
//! Cover-means for `switch_at` iterations (paper default 7), then hands
//! Shallot the upper/lower bounds and second-nearest identities that the
//! tree traversal produced as a by-product (Eqs. 15-18) — *without* the
//! full n x k scan every stored-bounds algorithm normally pays to
//! initialize its bounds.

use crate::data::Matrix;
use crate::kmeans::bounds::{CentroidAccum, InterCenter};
use crate::kmeans::shallot::{run_from_state, ShallotState};
use crate::kmeans::{cover, hamerly, KMeansParams, Workspace};
use crate::metrics::{DistCounter, IterationLog, RunResult, Stopwatch};

pub fn run(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> RunResult {
    let n = data.rows();
    let d = data.cols();
    let k = init.rows();

    let fresh = ws
        .cover
        .as_ref()
        .map(|t| t.params != params.cover)
        .unwrap_or(true);
    let tree = ws.cover_tree(data, params.cover);
    let (build_dist, build_time) = if fresh {
        (tree.build_distances, tree.build_time)
    } else {
        (0, std::time::Duration::ZERO)
    };

    let sw = Stopwatch::start();
    let mut dist = DistCounter::new();
    let mut centers = init.clone();
    let mut state = ShallotState {
        labels: vec![u32::MAX; n],
        second: vec![0u32; n],
        upper: vec![0.0f64; n],
        lower: vec![0.0f64; n],
    };
    let mut acc = CentroidAccum::new(k, d);
    let mut movement: Vec<f64> = Vec::with_capacity(k);
    let mut log = IterationLog::new();
    let mut converged = false;
    let mut iterations = 0;

    // --- Phase 1: Cover-means iterations.
    let switch_at = params.switch_at.min(params.max_iter);
    for iter in 1..=switch_at {
        iterations = iter;
        let ic = InterCenter::compute(&centers, &mut dist);
        acc.clear();
        let changed = cover::assign_pass(
            data,
            tree,
            &centers,
            &ic,
            &mut state.labels,
            &mut state.upper,
            &mut state.lower,
            &mut state.second,
            &mut acc,
            &mut dist,
        );
        acc.update_centers(&mut centers, &mut dist, &mut movement);
        log.push(iter, dist.count(), sw.elapsed(), changed);
        if changed == 0 {
            converged = true;
            break;
        }
        if iter == switch_at {
            // Hand-off: the stored bounds are valid for the pre-movement
            // centers; carry them across the movement exactly like the
            // stored-bounds algorithms do (§2.2).
            hamerly::update_bounds(
                &mut state.upper,
                &mut state.lower,
                &state.labels,
                &movement,
            );
        }
    }

    // --- Phase 2: Shallot from the tree-seeded state.
    if !converged && iterations < params.max_iter {
        let (iters, conv) = run_from_state(
            data,
            &mut centers,
            &mut state,
            params,
            iterations + 1,
            &mut dist,
            &sw,
            &mut log,
        );
        iterations = iters;
        converged = conv;
    }

    RunResult {
        labels: state.labels,
        centers,
        iterations,
        distances: dist.count(),
        build_dist,
        time: sw.elapsed(),
        build_time,
        log,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;
    use crate::tree::CoverTreeParams;

    fn hybrid_params() -> KMeansParams {
        KMeansParams {
            cover: CoverTreeParams { scale_factor: 1.2, min_node_size: 10 },
            ..KMeansParams::with_algorithm(Algorithm::Hybrid)
        }
    }

    #[test]
    fn matches_lloyd_exactly() {
        let data = synth::gaussian_blobs(500, 3, 6, 1.0, 25);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 6, 19, &mut dc);
        let params = hybrid_params();
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_h = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_h.labels, r_l.labels);
        assert_eq!(r_h.iterations, r_l.iterations);
    }

    #[test]
    fn matches_lloyd_geo_many_clusters() {
        let data = synth::istanbul(0.002, 26);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 30, 20, &mut dc);
        let params = hybrid_params();
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_h = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_h.labels, r_l.labels);
        assert_eq!(r_h.iterations, r_l.iterations);
    }

    #[test]
    fn converges_during_tree_phase_on_easy_data() {
        // Well-separated blobs converge in < 7 iterations; the hybrid must
        // terminate inside the cover phase.
        let data = synth::gaussian_blobs(300, 2, 3, 0.05, 27);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 3, 21, &mut dc);
        let params = hybrid_params();
        let mut ws = Workspace::new();
        let r = run(&data, &init_c, &params, &mut ws);
        assert!(r.converged);
        assert!(r.iterations <= 7, "iterations {}", r.iterations);
    }

    #[test]
    fn switch_at_respected_and_uses_fewer_distances_late() {
        let data = synth::istanbul(0.003, 28);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 40, 22, &mut dc);
        let params = KMeansParams { switch_at: 3, ..hybrid_params() };
        let mut ws = Workspace::new();
        let r_h = run(&data, &init_c, &params, &mut ws);
        let r_c = crate::kmeans::cover::run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_h.labels, r_c.labels);
        if r_h.iterations > 8 {
            // Late iterations: the hybrid (Shallot phase) must be cheaper
            // per iteration than the pure tree method.
            let late_h = r_h.log.stats.last().unwrap().dist_cum
                - r_h.log.stats[r_h.log.len() - 2].dist_cum;
            let late_c = r_c.log.stats.last().unwrap().dist_cum
                - r_c.log.stats[r_c.log.len() - 2].dist_cum;
            assert!(late_h <= late_c, "late hybrid {late_h} vs cover {late_c}");
        }
    }

    #[test]
    fn switch_at_zero_is_pure_shallot_with_scan_init() {
        // Degenerate configuration: switch_at = 0 skips the tree phase;
        // the Shallot phase then starts from iteration 1 with unseeded
        // bounds. Guard: we document switch_at >= 1; value 0 must still
        // terminate and be exact (first Shallot iteration sees u=0, l=0,
        // forcing full searches).
        let data = synth::gaussian_blobs(200, 2, 4, 0.5, 29);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 4, 23, &mut dc);
        let params = KMeansParams { switch_at: 1, ..hybrid_params() };
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_h = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_h.labels, r_l.labels);
    }
}
