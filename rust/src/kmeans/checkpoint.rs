//! Crash-safe checkpoints of in-progress fits (`.kmc` files).
//!
//! A checkpoint is a complete snapshot of a [`crate::kmeans::Fit`] at an
//! iteration boundary: the centers' exact f64 bit patterns, the driver's
//! cross-iteration state (labels and stored bounds, see
//! [`DriverState`]), the counted-distance total, the per-iteration log,
//! and the run's provenance (algorithm, seed, iteration, convergence).
//! Resuming from it replays the remaining iterations **bit-identically**
//! to the uninterrupted run — same labels, same center bits, same counted
//! distances (`rust/tests/crash_resume.rs`).
//!
//! The on-disk format mirrors the `.kmm` model format: a `CMKC` magic, a
//! format version, a config fingerprint, the header, the payload, and a
//! trailing FNV-1a checksum over everything before it. Writes go through
//! [`crate::data::io::atomic_write`], so at every instant one of
//! `path` / `path.prev` holds a complete valid snapshot; [`load_any`
//! ](KMeansCheckpoint::load_any) walks the generations (`path`, `path.tmp`,
//! `path.prev`) and resumes from the newest one that validates.
//!
//! What is *not* stored: spatial indexes (cover / k-d trees — their builds
//! are deterministic, so resume rebuilds them and then overwrites the
//! re-charged build cost with the checkpointed one), thread count and
//! worker pinning (the parallel reductions are exactness-preserving, so a
//! fit checkpointed at `threads = 4` resumes bit-identically at
//! `threads = 1` and vice versa), and wall-clock times (excluded from the
//! identity contract).

use std::fmt;
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::data::io::{atomic_write, bin, fnv1a, sibling_path};
use crate::data::Matrix;
use crate::kmeans::driver::DriverState;
use crate::kmeans::{Algorithm, KMeansParams};
use crate::metrics::IterationStat;

const MAGIC: &[u8] = b"CMKC";
const FORMAT_VERSION: u32 = 1;

/// Upper bound on driver state slots — all in-tree drivers use at most 2
/// f64 + 1 u32 slots; a header claiming more is corrupt, not ambitious.
const MAX_SLOTS: u32 = 64;

/// When and where a fit writes its snapshots.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CheckpointConfig {
    /// Snapshot file (`.kmc`); `path.tmp` / `path.prev` are its in-flight
    /// and previous generations.
    pub path: PathBuf,
    /// Write every N iterations (0 = no periodic trigger). A snapshot is
    /// always written when the run completes, whatever the triggers.
    pub every: usize,
    /// Also write when this many seconds elapsed since the last snapshot
    /// (0 = no time trigger).
    pub secs: u64,
}

impl CheckpointConfig {
    pub fn new(path: impl Into<PathBuf>) -> CheckpointConfig {
        CheckpointConfig { path: path.into(), every: 0, secs: 0 }
    }
}

/// Which on-disk generation a checkpoint was loaded from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Generation {
    /// The primary file.
    Current,
    /// The in-flight temp (`.tmp`) — a crash landed between the sync and
    /// the rename, leaving a complete snapshot under the temp name.
    Temp,
    /// The retained previous generation (`.prev`) — the primary is
    /// missing or failed validation.
    Previous,
}

impl Generation {
    /// The actual file this generation lives at, for a primary `path`.
    pub fn path_for(&self, path: &Path) -> PathBuf {
        match self {
            Generation::Current => path.to_path_buf(),
            Generation::Temp => sibling_path(path, ".tmp"),
            Generation::Previous => sibling_path(path, ".prev"),
        }
    }
}

impl fmt::Display for Generation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Generation::Current => "current",
            Generation::Temp => "in-flight temp",
            Generation::Previous => "previous generation",
        })
    }
}

/// Fingerprint of everything that determines the iteration sequence: the
/// algorithm, the data (shape plus sampled content), k, the convergence
/// knobs, and the tree construction parameters. Resuming under a different
/// fingerprint would silently produce a hybrid of two runs, so loads
/// reject mismatches ([`KMeansCheckpoint::validate`]).
///
/// Deliberately excluded: `threads` / `pin_workers` (exactness-preserving,
/// see the module docs), the mini-batch knobs (mini-batch is not
/// checkpointable), and the checkpoint triggers themselves (when to
/// snapshot does not change what is computed).
pub fn config_fingerprint(params: &KMeansParams, data: &Matrix, k: usize) -> u64 {
    config_fingerprint_src(params, data.into(), k)
}

/// [`config_fingerprint`] over any data source backend. The sampling
/// indices depend only on the flat element count, so a dataset served
/// in-RAM, mmapped, or chunk-streamed yields the *same* fingerprint —
/// a fit checkpointed from one backend resumes from any other.
pub fn config_fingerprint_src(
    params: &KMeansParams,
    src: crate::data::SourceView<'_>,
    k: usize,
) -> u64 {
    let mut buf = Vec::with_capacity(96 + 1024 * 8);
    buf.extend_from_slice(params.algorithm.name().as_bytes());
    bin::put_u64(&mut buf, src.rows() as u64);
    bin::put_u64(&mut buf, src.cols() as u64);
    bin::put_u64(&mut buf, k as u64);
    bin::put_u64(&mut buf, params.max_iter as u64);
    bin::put_f64(&mut buf, params.tol);
    bin::put_f64(&mut buf, params.cover.scale_factor);
    bin::put_u64(&mut buf, params.cover.min_node_size as u64);
    bin::put_u64(&mut buf, params.kd.leaf_size as u64);
    bin::put_u64(&mut buf, params.kd.max_depth as u64);
    bin::put_u64(&mut buf, params.switch_at as u64);
    // Sampled data content, the workspace cache's DataKey idiom: up to
    // 1024 evenly-spaced elements' exact bit patterns. Catches "same
    // shape, different dataset" without an O(nd) pass per snapshot.
    let len = src.rows() * src.cols();
    let step = (len / 1024).max(1);
    let mut i = 0;
    while i < len {
        buf.extend_from_slice(&src.flat_element(i).to_bits().to_le_bytes());
        i += step;
    }
    fnv1a(&buf)
}

/// One snapshot of an in-progress (or just-completed) fit — everything
/// [`crate::kmeans::Fit::restore`] needs to continue bit-identically.
#[derive(Debug, Clone, PartialEq)]
pub struct KMeansCheckpoint {
    /// [`config_fingerprint`] of the run that wrote this snapshot.
    pub fingerprint: u64,
    pub algorithm: Algorithm,
    pub k: usize,
    pub dim: usize,
    /// Point count of the dataset the fit runs over.
    pub n: usize,
    /// Seed provenance (the k-means++ init already happened; recorded so
    /// a resumed run reports the same provenance, not replayed).
    pub seed: u64,
    /// Completed iterations at snapshot time.
    pub iter: u64,
    pub converged: bool,
    /// Cumulative counted distance computations (excludes tree build).
    pub distances: u64,
    /// Tree construction distances charged to the original run.
    pub build_dist: u64,
    /// Tree construction time charged to the original run.
    pub build_time: Duration,
    /// Centers after iteration `iter`, exact f64 bit patterns.
    pub centers: Matrix,
    /// Per-iteration series up to and including iteration `iter`.
    pub log: Vec<IterationStat>,
    /// The driver's cross-iteration state (labels, stored bounds).
    pub state: DriverState,
}

impl KMeansCheckpoint {
    /// Serialize to the `.kmc` byte format. Round-trips bit-identically.
    pub fn to_bytes(&self) -> Vec<u8> {
        let name = self.algorithm.name().as_bytes();
        let state_len: usize = self.state.labels.len() * 4
            + self.state.f64_slots.iter().map(|s| 8 + s.len() * 8).sum::<usize>()
            + self.state.u32_slots.iter().map(|s| 8 + s.len() * 4).sum::<usize>();
        let mut out = Vec::with_capacity(
            128 + name.len()
                + self.k * self.dim * 8
                + self.log.len() * 32
                + state_len,
        );
        out.extend_from_slice(MAGIC);
        bin::put_u32(&mut out, FORMAT_VERSION);
        bin::put_u64(&mut out, self.fingerprint);
        bin::put_u32(&mut out, self.k as u32);
        bin::put_u32(&mut out, self.dim as u32);
        bin::put_u64(&mut out, self.n as u64);
        bin::put_u32(&mut out, name.len() as u32);
        out.extend_from_slice(name);
        bin::put_u64(&mut out, self.seed);
        bin::put_u64(&mut out, self.iter);
        out.push(self.converged as u8);
        bin::put_u64(&mut out, self.distances);
        bin::put_u64(&mut out, self.build_dist);
        bin::put_u64(&mut out, self.build_time.as_nanos() as u64);
        for &v in self.centers.as_slice() {
            bin::put_f64(&mut out, v);
        }
        bin::put_u32(&mut out, self.log.len() as u32);
        for s in &self.log {
            bin::put_u64(&mut out, s.iter as u64);
            bin::put_u64(&mut out, s.dist_cum);
            bin::put_u64(&mut out, s.time_cum.as_nanos() as u64);
            bin::put_u64(&mut out, s.changed as u64);
        }
        bin::put_u64(&mut out, self.state.labels.len() as u64);
        for &l in &self.state.labels {
            bin::put_u32(&mut out, l);
        }
        bin::put_u32(&mut out, self.state.f64_slots.len() as u32);
        for slot in &self.state.f64_slots {
            bin::put_u64(&mut out, slot.len() as u64);
            for &v in slot {
                bin::put_f64(&mut out, v);
            }
        }
        bin::put_u32(&mut out, self.state.u32_slots.len() as u32);
        for slot in &self.state.u32_slots {
            bin::put_u64(&mut out, slot.len() as u64);
            for &v in slot {
                bin::put_u32(&mut out, v);
            }
        }
        let sum = fnv1a(&out);
        bin::put_u64(&mut out, sum);
        out
    }

    /// Parse the `.kmc` byte format, verifying the magic, checksum,
    /// version and structure — a truncated or bit-flipped file fails with
    /// a diagnosable error instead of yielding a silently corrupt resume.
    pub fn from_bytes(buf: &[u8]) -> Result<KMeansCheckpoint> {
        if buf.len() < MAGIC.len() + 4 {
            bail!("not a covermeans checkpoint: {} bytes is too short", buf.len());
        }
        if &buf[..MAGIC.len()] != MAGIC {
            bail!(
                "not a covermeans checkpoint: bad magic {:?}",
                &buf[..MAGIC.len()]
            );
        }
        if buf.len() < MAGIC.len() + 8 {
            bail!("truncated checkpoint: no room for a checksum");
        }
        let (body, tail) = buf.split_at(buf.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        let actual = fnv1a(body);
        if stored != actual {
            bail!(
                "checkpoint checksum mismatch (stored {stored:#018x}, computed \
                 {actual:#018x}): the file is truncated or corrupt"
            );
        }
        let mut r = bin::Reader::new(&body[MAGIC.len()..]);
        let version = r.u32()?;
        if version != FORMAT_VERSION {
            bail!(
                "unsupported checkpoint format version {version} \
                 (this build reads {FORMAT_VERSION})"
            );
        }
        let fingerprint = r.u64()?;
        let k = r.u32()? as usize;
        let dim = r.u32()? as usize;
        let n = r.u64()? as usize;
        if k == 0 || dim == 0 || n == 0 || k > n {
            bail!("corrupt checkpoint header: k={k}, dim={dim}, n={n}");
        }
        let name_len = r.u32()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)
            .context("algorithm name is not UTF-8")?;
        let algorithm = Algorithm::parse(name)
            .with_context(|| format!("unknown algorithm {name:?} in checkpoint header"))?;
        let seed = r.u64()?;
        let iter = r.u64()?;
        let converged = match r.take(1)?[0] {
            0 => false,
            1 => true,
            other => bail!("corrupt convergence flag {other}"),
        };
        let distances = r.u64()?;
        let build_dist = r.u64()?;
        let build_time = Duration::from_nanos(r.u64()?);
        let center_bytes = k
            .checked_mul(dim)
            .and_then(|c| c.checked_mul(8))
            .context("checkpoint dimensions overflow")?;
        let mut centers = Vec::with_capacity(k * dim);
        for c in r.take(center_bytes)?.chunks_exact(8) {
            centers.push(f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())));
        }
        let log_len = r.u32()? as usize;
        if r.remaining() < log_len.checked_mul(32).context("log length overflow")? {
            bail!("checkpoint log claims {log_len} entries, payload is too short");
        }
        let mut log = Vec::with_capacity(log_len);
        for _ in 0..log_len {
            log.push(IterationStat {
                iter: r.u64()? as usize,
                dist_cum: r.u64()?,
                time_cum: Duration::from_nanos(r.u64()?),
                changed: r.u64()? as usize,
            });
        }
        let labels_len = r.u64()? as usize;
        if labels_len != n {
            bail!("checkpointed labels have {labels_len} entries, expected {n}");
        }
        let mut labels = Vec::with_capacity(n);
        for c in r
            .take(n.checked_mul(4).context("label length overflow")?)?
            .chunks_exact(4)
        {
            labels.push(u32::from_le_bytes(c.try_into().unwrap()));
        }
        let mut state = DriverState::new(labels);
        let f64_slots = r.u32()?;
        if f64_slots > MAX_SLOTS {
            bail!("corrupt checkpoint: {f64_slots} f64 state slots");
        }
        for _ in 0..f64_slots {
            let len = r.u64()? as usize;
            let bytes = r
                .take(len.checked_mul(8).context("slot length overflow")?)
                .context("truncated f64 state slot")?;
            let mut slot = Vec::with_capacity(len);
            for c in bytes.chunks_exact(8) {
                slot.push(f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())));
            }
            state = state.with_f64(slot);
        }
        let u32_slots = r.u32()?;
        if u32_slots > MAX_SLOTS {
            bail!("corrupt checkpoint: {u32_slots} u32 state slots");
        }
        for _ in 0..u32_slots {
            let len = r.u64()? as usize;
            let bytes = r
                .take(len.checked_mul(4).context("slot length overflow")?)
                .context("truncated u32 state slot")?;
            let mut slot = Vec::with_capacity(len);
            for c in bytes.chunks_exact(4) {
                slot.push(u32::from_le_bytes(c.try_into().unwrap()));
            }
            state = state.with_u32(slot);
        }
        if r.remaining() != 0 {
            bail!("{} trailing bytes after the driver state", r.remaining());
        }
        Ok(KMeansCheckpoint {
            fingerprint,
            algorithm,
            k,
            dim,
            n,
            seed,
            iter,
            converged,
            distances,
            build_dist,
            build_time,
            centers: Matrix::from_vec(centers, k, dim),
            log,
            state,
        })
    }

    /// Write the snapshot crash-safely (temp → sync → rename; previous
    /// generation retained as `path.prev` — see
    /// [`crate::data::io::atomic_write`]).
    pub fn save(&self, path: &Path) -> Result<()> {
        atomic_write(path, &self.to_bytes())
            .with_context(|| format!("write checkpoint {path:?}"))
    }

    /// Read one specific file back.
    pub fn load(path: &Path) -> Result<KMeansCheckpoint> {
        let buf = std::fs::read(path)
            .with_context(|| format!("read checkpoint {path:?}"))?;
        KMeansCheckpoint::from_bytes(&buf)
            .with_context(|| format!("parse checkpoint {path:?}"))
    }

    /// Load the best available generation of the checkpoint at `path`:
    /// try `path`, `path.tmp` and `path.prev`, drop any that fail
    /// validation, and return the survivor with the highest iteration
    /// count together with which [`Generation`] it was. A torn write can
    /// corrupt at most the generation being written, so as long as one
    /// snapshot was ever completed this finds a valid one.
    pub fn load_any(path: &Path) -> Result<(KMeansCheckpoint, Generation)> {
        let mut best: Option<(KMeansCheckpoint, Generation)> = None;
        let mut errors = Vec::new();
        for gen in [Generation::Current, Generation::Temp, Generation::Previous] {
            let p = gen.path_for(path);
            if !p.exists() {
                continue;
            }
            match KMeansCheckpoint::load(&p) {
                Ok(c) => {
                    let better = match &best {
                        None => true,
                        Some((b, _)) => c.iter > b.iter,
                    };
                    if better {
                        best = Some((c, gen));
                    }
                }
                Err(e) => errors.push(format!("{gen} {p:?}: {e:#}")),
            }
        }
        match best {
            Some(found) => Ok(found),
            None if errors.is_empty() => {
                bail!("no checkpoint at {path:?} (nor a .tmp/.prev generation)")
            }
            None => bail!(
                "no loadable checkpoint at {path:?}; every generation failed: {}",
                errors.join("; ")
            ),
        }
    }

    /// Reject resuming under a configuration or dataset other than the
    /// one that wrote the snapshot (see [`config_fingerprint`]).
    pub fn validate(
        &self,
        params: &KMeansParams,
        data: &Matrix,
        k: usize,
    ) -> Result<()> {
        self.validate_src(params, data.into(), k)
    }

    /// [`KMeansCheckpoint::validate`] over any data source backend — the
    /// fingerprint is backend-invariant, so a snapshot written from an
    /// in-RAM fit validates against the same dataset streamed from disk.
    pub fn validate_src(
        &self,
        params: &KMeansParams,
        src: crate::data::SourceView<'_>,
        k: usize,
    ) -> Result<()> {
        let want = config_fingerprint_src(params, src, k);
        if self.fingerprint != want {
            bail!(
                "checkpoint fingerprint mismatch (checkpoint {:#018x}, this \
                 run {:#018x}): the snapshot was written by a different \
                 algorithm, dataset, or configuration (checkpoint says {} \
                 k={} over n={} d={}); refusing to resume",
                self.fingerprint,
                want,
                self.algorithm.name(),
                self.k,
                self.n,
                self.dim,
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "covermeans_ckpt_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample() -> KMeansCheckpoint {
        KMeansCheckpoint {
            fingerprint: 0xDEAD_BEEF_0BAD_F00D,
            algorithm: Algorithm::Hamerly,
            k: 2,
            dim: 3,
            n: 4,
            seed: 7,
            iter: 5,
            converged: false,
            distances: 1234,
            build_dist: 56,
            build_time: Duration::from_nanos(789),
            centers: Matrix::from_vec(
                vec![1.0, -0.0, f64::NAN, 2.5, 3.5, -4.5],
                2,
                3,
            ),
            log: vec![
                IterationStat {
                    iter: 1,
                    dist_cum: 100,
                    time_cum: Duration::from_nanos(10),
                    changed: 4,
                },
                IterationStat {
                    iter: 5,
                    dist_cum: 1234,
                    time_cum: Duration::from_nanos(50),
                    changed: 1,
                },
            ],
            state: DriverState::new(vec![0, 1, 1, 0])
                .with_f64(vec![0.25, 0.5, 0.75, 1.0])
                .with_f64(vec![9.0, 8.0, 7.0, 6.0])
                .with_u32(vec![1, 0, 0, 1]),
        }
    }

    #[test]
    fn roundtrip_is_bit_identical() {
        let c = sample();
        let back = KMeansCheckpoint::from_bytes(&c.to_bytes()).unwrap();
        // NaN centers break a direct PartialEq comparison; compare bits.
        assert_eq!(
            c.centers
                .as_slice()
                .iter()
                .map(|v| v.to_bits())
                .collect::<Vec<_>>(),
            back.centers
                .as_slice()
                .iter()
                .map(|v| v.to_bits())
                .collect::<Vec<_>>()
        );
        assert_eq!(back.fingerprint, c.fingerprint);
        assert_eq!(back.algorithm, c.algorithm);
        assert_eq!((back.k, back.dim, back.n), (c.k, c.dim, c.n));
        assert_eq!((back.seed, back.iter, back.converged), (7, 5, false));
        assert_eq!(back.distances, c.distances);
        assert_eq!(back.build_dist, c.build_dist);
        assert_eq!(back.build_time, c.build_time);
        assert_eq!(back.log, c.log);
        assert_eq!(back.state, c.state);
    }

    #[test]
    fn corruption_is_diagnosed_never_panics() {
        let buf = sample().to_bytes();
        // The whole container is checksummed, so every fault in the
        // shared battery must land on the checksum or the magic.
        crate::testutil::corruption::assert_rejects_faults(
            ".kmc checkpoint",
            &buf,
            buf.len(),
            KMeansCheckpoint::from_bytes,
        );
    }

    #[test]
    fn save_load_any_prefers_newest_valid_generation() {
        let dir = tmpdir();
        let path = dir.join("gen_pref.kmc");
        let mut c = sample();
        c.iter = 3;
        c.save(&path).unwrap();
        c.iter = 6;
        c.save(&path).unwrap();
        let (loaded, gen) = KMeansCheckpoint::load_any(&path).unwrap();
        assert_eq!(loaded.iter, 6);
        assert_eq!(gen, Generation::Current);
        // Corrupt the current generation: the previous one must win.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let (loaded, gen) = KMeansCheckpoint::load_any(&path).unwrap();
        assert_eq!(loaded.iter, 3);
        assert_eq!(gen, Generation::Previous);
        // Truncate it instead: same fallback.
        std::fs::write(&path, &std::fs::read(&path).unwrap()[..10]).unwrap();
        let (loaded, gen) = KMeansCheckpoint::load_any(&path).unwrap();
        assert_eq!(loaded.iter, 3);
        assert_eq!(gen, Generation::Previous);
        // Corrupt the fallback too: the error lists every failure.
        let prev = Generation::Previous.path_for(&path);
        std::fs::write(&prev, b"garbage").unwrap();
        let err = KMeansCheckpoint::load_any(&path).unwrap_err();
        assert!(format!("{err:#}").contains("no loadable checkpoint"));
        // Remove every generation: a diagnosable miss.
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_file(&prev).unwrap();
        let err = KMeansCheckpoint::load_any(&path).unwrap_err();
        assert!(format!("{err:#}").contains("no checkpoint at"));
    }

    #[test]
    fn load_any_reads_orphaned_temp() {
        // A crash after sync but before rename leaves only `path.tmp`.
        let dir = tmpdir();
        let path = dir.join("orphan.kmc");
        let c = sample();
        std::fs::write(Generation::Temp.path_for(&path), c.to_bytes()).unwrap();
        let (loaded, gen) = KMeansCheckpoint::load_any(&path).unwrap();
        assert_eq!(loaded.iter, c.iter);
        assert_eq!(gen, Generation::Temp);
    }

    #[test]
    fn fingerprint_separates_configs_but_not_threads() {
        let data = crate::data::synth::gaussian_blobs(60, 2, 3, 0.5, 11);
        let p = KMeansParams::default();
        let base = config_fingerprint(&p, &data, 3);
        assert_eq!(base, config_fingerprint(&p, &data, 3), "deterministic");
        assert_ne!(base, config_fingerprint(&p, &data, 4), "k matters");
        let other_alg =
            KMeansParams::with_algorithm(Algorithm::CoverMeans);
        assert_ne!(base, config_fingerprint(&other_alg, &data, 3));
        let other_tol = KMeansParams { tol: 1e-6, ..p };
        assert_ne!(base, config_fingerprint(&other_tol, &data, 3));
        let other_data = crate::data::synth::gaussian_blobs(60, 2, 3, 0.5, 12);
        assert_ne!(base, config_fingerprint(&p, &other_data, 3));
        // threads / pin_workers are exactness-preserving: same fingerprint.
        let threaded = KMeansParams { threads: 4, pin_workers: true, ..p };
        assert_eq!(base, config_fingerprint(&threaded, &data, 3));
    }

    #[test]
    fn validate_rejects_mismatch_with_context() {
        let data = crate::data::synth::gaussian_blobs(60, 2, 3, 0.5, 11);
        let p = KMeansParams::default();
        let mut c = sample();
        c.fingerprint = config_fingerprint(&p, &data, 3);
        assert!(c.validate(&p, &data, 3).is_ok());
        let err = c.validate(&p, &data, 4).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("fingerprint mismatch"), "{msg}");
        assert!(msg.contains("Hamerly"), "names the checkpoint's origin: {msg}");
    }
}
