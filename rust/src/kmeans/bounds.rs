//! Shared per-iteration machinery: inter-center distances (Eq. 5 filter
//! input), center movement, and the centroid accumulator used by every
//! assignment phase (Eq. 2).

use crate::data::Matrix;
use crate::metrics::DistCounter;
use crate::parallel::{Parallelism, ScatterSlice};

/// Below this k the parallel inter-center path is not worth the dispatch:
/// the whole matrix is cheaper than waking the pool.
const PAR_MIN_K: usize = 64;

/// Split rows `0..k` of the upper triangle into ranges of roughly equal
/// *pair* count (row i owns the k-1-i pairs (i, j>i); a naive equal-row
/// split would give the first range almost all the work).
fn triangle_ranges(k: usize, target: usize) -> Vec<std::ops::Range<usize>> {
    let total = k * (k - 1) / 2;
    let per = total.div_ceil(target.max(1)).max(1);
    let mut out = Vec::new();
    let mut start = 0usize;
    let mut acc = 0usize;
    for i in 0..k {
        acc += k - 1 - i;
        if (acc >= per || i + 1 == k) && start <= i {
            out.push(start..i + 1);
            start = i + 1;
            acc = 0;
        }
    }
    out
}

/// Inter-center distance matrix plus `s_i = 1/2 min_{j != i} d(c_i, c_j)`,
/// recomputed at the start of each iteration (paper §2.2: "computed and
/// stored at the beginning of each iteration"). Costs k(k-1)/2 counted
/// distance computations.
#[derive(Debug, Clone)]
pub struct InterCenter {
    pub k: usize,
    /// Row-major k x k distances (symmetric, zero diagonal).
    pub cc: Vec<f64>,
    /// Half the distance to the nearest other center.
    pub s: Vec<f64>,
}

impl InterCenter {
    /// Sequential inter-center pass, cache-blocked through
    /// [`crate::kernels::pairwise_upper`]: an 8-row block of centers stays
    /// hot while 32-row tiles stream past it, instead of re-streaming the
    /// whole matrix once per row. Byte-identical to the classic pair loop
    /// it replaced — each cell holds the same single distance evaluation,
    /// tiling only reorders *which pair is computed when*, and the
    /// `nearest` reduction below is an order-free row minimum.
    pub fn compute(centers: &Matrix, dist: &mut DistCounter) -> InterCenter {
        let k = centers.rows();
        let mut cc = vec![0.0; k * k];
        let mut pairs = 0u64;
        crate::kernels::pairwise_upper(centers, |i, j, d| {
            cc[i * k + j] = d;
            cc[j * k + i] = d;
            pairs += 1;
        });
        dist.add_bulk(pairs);
        let mut nearest = vec![f64::INFINITY; k];
        for i in 0..k {
            for j in 0..k {
                if j != i && cc[i * k + j] < nearest[i] {
                    nearest[i] = cc[i * k + j];
                }
            }
        }
        let s = nearest.iter().map(|&d| 0.5 * d).collect();
        InterCenter { k, cc, s }
    }

    /// Like [`InterCenter::compute`], sharding the O(k²d) upper-triangle
    /// distance work over `par` — the dominant per-iteration cost of
    /// large-k fits. Byte-identical to the sequential path at every thread
    /// count: every cell (i, j) holds the same single distance evaluation
    /// (the cell's owner is its smaller coordinate, so writes are
    /// disjoint), per-shard distance tallies fold back as integer sums,
    /// and `nearest` is a row-wise minimum — order-free over f64s — merged
    /// deterministically after the shards complete. Small k (or a
    /// sequential budget) falls through to the classic pair loop, which
    /// produces identical bits.
    pub fn compute_par(
        centers: &Matrix,
        dist: &mut DistCounter,
        par: &Parallelism,
    ) -> InterCenter {
        let k = centers.rows();
        if par.threads() <= 1 || k < PAR_MIN_K {
            return InterCenter::compute(centers, dist);
        }
        let mut cc = vec![0.0; k * k];
        {
            let cc_sc = ScatterSlice::new(&mut cc);
            let ranges = triangle_ranges(k, par.threads() * 4);
            let counts = par.run_tasks(ranges, |rows| {
                let mut dc = DistCounter::new();
                for i in rows {
                    for j in (i + 1)..k {
                        let d = dc.d(centers.row(i), centers.row(j));
                        // Safety: cell (i, j) and its mirror (j, i) are
                        // written only by the task owning row i (i < j),
                        // so all writes are pairwise disjoint.
                        unsafe {
                            cc_sc.write(i * k + j, d);
                            cc_sc.write(j * k + i, d);
                        }
                    }
                }
                dc.count()
            });
            for c in counts {
                dist.add_bulk(c);
            }
        }
        let mut nearest = vec![f64::INFINITY; k];
        for i in 0..k {
            for j in 0..k {
                if j != i && cc[i * k + j] < nearest[i] {
                    nearest[i] = cc[i * k + j];
                }
            }
        }
        let s = nearest.iter().map(|&d| 0.5 * d).collect();
        InterCenter { k, cc, s }
    }

    #[inline]
    pub fn d(&self, i: usize, j: usize) -> f64 {
        self.cc[i * self.k + j]
    }

    /// Indices of all other centers sorted by distance from center `i`
    /// (used by the annulus searches of Exponion and Shallot). Allocates;
    /// callers should reuse via `sorted_neighbors_into`.
    pub fn sorted_neighbors(&self, i: usize) -> Vec<(f64, u32)> {
        let mut v = Vec::with_capacity(self.k - 1);
        self.sorted_neighbors_into(i, &mut v);
        v
    }

    pub fn sorted_neighbors_into(&self, i: usize, out: &mut Vec<(f64, u32)>) {
        out.clear();
        for j in 0..self.k {
            if j != i {
                let d = self.d(i, j);
                // A NaN here means an upstream center update produced a
                // NaN coordinate (e.g. an empty-cluster edge case). Fail
                // with a diagnosable message in every build profile —
                // pruning against a garbage neighbor order would silently
                // corrupt the fit — instead of the former opaque panic
                // inside a sort comparator. The check is O(k) per list,
                // trivial next to the sort, and the total-order sort
                // below itself never panics.
                assert!(
                    !d.is_nan(),
                    "NaN inter-center distance between centers {i} and {j} \
                     (an upstream center update produced a NaN coordinate)"
                );
                out.push((d, j as u32));
            }
        }
        out.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    }
}

/// Centroid accumulator: the running `sum_{a(s)=i} s` and counts of Eq. 2.
#[derive(Debug, Clone)]
pub struct CentroidAccum {
    pub sums: Matrix,
    pub counts: Vec<f64>,
}

impl CentroidAccum {
    pub fn new(k: usize, d: usize) -> Self {
        CentroidAccum { sums: Matrix::zeros(k, d), counts: vec![0.0; k] }
    }

    pub fn clear(&mut self) {
        self.sums.as_mut_slice().fill(0.0);
        self.counts.fill(0.0);
    }

    #[inline]
    pub fn add_point(&mut self, c: usize, p: &[f64]) {
        let row = self.sums.row_mut(c);
        for (r, &v) in row.iter_mut().zip(p) {
            *r += v;
        }
        self.counts[c] += 1.0;
    }

    #[inline]
    pub fn remove_point(&mut self, c: usize, p: &[f64]) {
        let row = self.sums.row_mut(c);
        for (r, &v) in row.iter_mut().zip(p) {
            *r -= v;
        }
        self.counts[c] -= 1.0;
    }

    /// Add an aggregated subtree (`S_x`, `w_x`) at once — the cover tree
    /// reassignment of paper §3.2.
    #[inline]
    pub fn add_aggregate(&mut self, c: usize, sum: &[f64], weight: f64) {
        let row = self.sums.row_mut(c);
        for (r, &v) in row.iter_mut().zip(sum) {
            *r += v;
        }
        self.counts[c] += weight;
    }

    /// Fold another accumulator into this one (the per-task reduction of
    /// the parallel tree passes). Callers must merge in a deterministic
    /// order — floating-point summation order affects the low bits, and
    /// the determinism contract requires the order to be a function of
    /// the data only, never of the thread count.
    pub fn merge(&mut self, other: &CentroidAccum) {
        debug_assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self
            .sums
            .as_mut_slice()
            .iter_mut()
            .zip(other.sums.as_slice())
        {
            *a += b;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }

    #[inline]
    pub fn remove_aggregate(&mut self, c: usize, sum: &[f64], weight: f64) {
        let row = self.sums.row_mut(c);
        for (r, &v) in row.iter_mut().zip(sum) {
            *r -= v;
        }
        self.counts[c] -= weight;
    }

    /// Produce the next centers (Eq. 2). Empty clusters keep their previous
    /// center (ELKI's behaviour), so their movement is 0. Returns per-center
    /// movement distances `d(c'_i, c_i)` (counted, as the bound updates of
    /// §2.2 consume them).
    pub fn update_centers(
        &self,
        centers: &mut Matrix,
        dist: &mut DistCounter,
        movement: &mut Vec<f64>,
    ) {
        let k = centers.rows();
        let d = centers.cols();
        movement.clear();
        let mut new_row = vec![0.0; d];
        for i in 0..k {
            if self.counts[i] > 0.0 {
                let inv = 1.0 / self.counts[i];
                let srow = self.sums.row(i);
                for j in 0..d {
                    new_row[j] = srow[j] * inv;
                }
                let mv = dist.d(centers.row(i), &new_row);
                centers.row_mut(i).copy_from_slice(&new_row);
                movement.push(mv);
            } else {
                movement.push(0.0);
            }
        }
    }
}

/// Fill `acc` with the center sums of `labels` in canonical point order
/// (ascending index). This is the single accumulation convention behind
/// the per-point drivers' parallel passes: the chunk workers only compute
/// labels, and this sequential pass reproduces the sums bit-identically
/// at every thread count.
pub(crate) fn accumulate_in_order(
    data: &crate::data::Matrix,
    labels: &[u32],
    acc: &mut CentroidAccum,
) {
    accumulate_in_order_src(data.into(), labels, acc);
}

/// Source-generic [`accumulate_in_order`]: one sequential ascending-index
/// pass over any backend. The chunked backend streams the pass in blocks,
/// but the per-point add order is the canonical order either way, so the
/// sums are bit-identical across backends (and to the in-RAM path).
pub(crate) fn accumulate_in_order_src(
    src: crate::data::SourceView<'_>,
    labels: &[u32],
    acc: &mut CentroidAccum,
) {
    let cols = src.cols();
    src.visit(0..labels.len(), |start, block| {
        for (off, p) in block.chunks_exact(cols).enumerate() {
            acc.add_point(labels[start + off] as usize, p);
        }
    });
}

/// Dense nearest + second-nearest scan of a point against all centers,
/// counting k distances. Ties break to the lowest index. Returns
/// `(c1, d1, c2, d2)`; for k == 1, `c2 == c1` and `d2 == +inf`.
///
/// The scan itself is the batched [`crate::kernels::argmin2`] kernel
/// (dispatch hoisted out of the k-row loop); it performs the exact
/// comparison sequence of the historical per-row loop, so results are
/// byte-identical and the count stays one evaluation per center.
#[inline]
pub fn nearest_two(
    point: &[f64],
    centers: &Matrix,
    dist: &mut DistCounter,
) -> (u32, f64, u32, f64) {
    dist.add_bulk(centers.rows() as u64);
    crate::kernels::argmin2(point, centers)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn centers2() -> Matrix {
        Matrix::from_rows(&[&[0.0, 0.0], &[4.0, 0.0], &[0.0, 3.0]])
    }

    #[test]
    fn intercenter_symmetric_and_s() {
        let mut dist = DistCounter::new();
        let ic = InterCenter::compute(&centers2(), &mut dist);
        assert_eq!(dist.count(), 3); // k(k-1)/2
        assert_eq!(ic.d(0, 1), 4.0);
        assert_eq!(ic.d(1, 0), 4.0);
        assert_eq!(ic.d(0, 2), 3.0);
        assert_eq!(ic.s[0], 1.5); // half of min(4, 3)
        assert_eq!(ic.d(1, 1), 0.0); // diagonal zero
    }

    #[test]
    fn triangle_ranges_cover_all_rows() {
        for k in [2usize, 64, 100, 257] {
            for target in [1usize, 4, 16] {
                let ranges = triangle_ranges(k, target);
                let mut next = 0usize;
                for r in &ranges {
                    assert_eq!(r.start, next, "k={k} target={target}");
                    assert!(r.end > r.start);
                    next = r.end;
                }
                assert_eq!(next, k, "k={k} target={target}");
            }
        }
    }

    #[test]
    fn compute_is_bit_identical_to_naive_pair_loop() {
        // The tiled pass must be invisible next to the classic row-wise
        // upper-triangle loop: same cells, same count, same bits.
        let data = crate::data::synth::gaussian_blobs(50, 5, 6, 0.8, 11);
        let mut dc = DistCounter::new();
        let ic = InterCenter::compute(&data, &mut dc);
        let k = data.rows();
        assert_eq!(dc.count(), (k * (k - 1) / 2) as u64);
        let mut dc2 = DistCounter::new();
        let mut cc = vec![0.0; k * k];
        let mut nearest = vec![f64::INFINITY; k];
        for i in 0..k {
            for j in (i + 1)..k {
                let d = dc2.d(data.row(i), data.row(j));
                cc[i * k + j] = d;
                cc[j * k + i] = d;
                if d < nearest[i] {
                    nearest[i] = d;
                }
                if d < nearest[j] {
                    nearest[j] = d;
                }
            }
        }
        assert_eq!(dc2.count(), dc.count());
        for (idx, (a, b)) in ic.cc.iter().zip(&cc).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "cc[{idx}]");
        }
        for (i, &nd) in nearest.iter().enumerate() {
            assert_eq!(ic.s[i].to_bits(), (0.5 * nd).to_bits(), "s[{i}]");
        }
    }

    #[test]
    fn compute_par_is_bit_identical_to_sequential() {
        // Above the PAR_MIN_K gate so the sharded path actually runs.
        let k = 80;
        let data = crate::data::synth::gaussian_blobs(k, 6, 8, 1.0, 77);
        let mut d_seq = DistCounter::new();
        let seq = InterCenter::compute(&data, &mut d_seq);
        for threads in [1usize, 2, 4] {
            let par = crate::parallel::Parallelism::new(threads);
            let mut d_par = DistCounter::new();
            let p = InterCenter::compute_par(&data, &mut d_par, &par);
            assert_eq!(d_par.count(), d_seq.count(), "threads={threads}");
            assert_eq!(p.k, seq.k);
            for (i, (a, b)) in p.cc.iter().zip(&seq.cc).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "cc[{i}] threads={threads}");
            }
            for (i, (a, b)) in p.s.iter().zip(&seq.s).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "s[{i}] threads={threads}");
            }
        }
    }

    #[test]
    fn sorted_neighbors_order() {
        let mut dist = DistCounter::new();
        let ic = InterCenter::compute(&centers2(), &mut dist);
        let nb = ic.sorted_neighbors(0);
        assert_eq!(nb.len(), 2);
        assert_eq!(nb[0].1, 2); // distance 3 before distance 4
        assert_eq!(nb[1].1, 1);
    }

    #[test]
    fn accum_roundtrip_and_update() {
        let mut acc = CentroidAccum::new(2, 2);
        acc.add_point(0, &[1.0, 1.0]);
        acc.add_point(0, &[3.0, 1.0]);
        acc.add_aggregate(1, &[10.0, 0.0], 2.0);
        let mut centers = Matrix::from_rows(&[&[0.0, 0.0], &[9.0, 9.0]]);
        let mut dist = DistCounter::new();
        let mut mv = Vec::new();
        acc.update_centers(&mut centers, &mut dist, &mut mv);
        assert_eq!(centers.row(0), &[2.0, 1.0]);
        assert_eq!(centers.row(1), &[5.0, 0.0]);
        assert_eq!(mv.len(), 2);
        assert!(mv[0] > 0.0 && mv[1] > 0.0);
        // removal restores
        acc.remove_point(0, &[3.0, 1.0]);
        assert_eq!(acc.counts[0], 1.0);
    }

    #[test]
    fn empty_cluster_keeps_center() {
        let acc = CentroidAccum::new(1, 2);
        let mut centers = Matrix::from_rows(&[&[7.0, 8.0]]);
        let mut dist = DistCounter::new();
        let mut mv = Vec::new();
        acc.update_centers(&mut centers, &mut dist, &mut mv);
        assert_eq!(centers.row(0), &[7.0, 8.0]);
        assert_eq!(mv[0], 0.0);
        assert_eq!(dist.count(), 0);
    }

    #[test]
    fn nearest_two_ties_lowest_index() {
        let centers = Matrix::from_rows(&[&[1.0], &[-1.0], &[1.0]]);
        let mut dist = DistCounter::new();
        let (c1, d1, c2, d2) = nearest_two(&[0.0], &centers, &mut dist);
        assert_eq!(c1, 0); // ties: 0 before 1 and 2
        assert_eq!(d1, 1.0);
        assert_eq!(d2, 1.0);
        assert!(c2 == 1 || c2 == 2);
        assert_eq!(dist.count(), 3);
    }

    #[test]
    fn nearest_two_single_center() {
        let centers = Matrix::from_rows(&[&[2.0]]);
        let mut dist = DistCounter::new();
        let (c1, d1, _c2, d2) = nearest_two(&[0.0], &centers, &mut dist);
        assert_eq!((c1, d1), (0, 2.0));
        assert!(d2.is_infinite());
    }
}
