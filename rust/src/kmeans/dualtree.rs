//! Dual-tree k-means assignment (Curtin, arXiv:1601.03754): traverse the
//! point cover tree and a per-iteration cover tree over the k centers
//! *simultaneously*, pruning per node *pair* instead of per point-tree
//! node.
//!
//! The single-tree pass (`kmeans::cover`) scans every surviving candidate
//! center at each point-tree node; at large k the root-level scan alone
//! costs ~k distance computations per iteration, because the Eq. 9 filter
//! barely prunes when the node radius is large. The dual pass instead
//! carries a small set of [`Entry`]s — disjoint *center-tree subtrees*
//! that partition the surviving centers — and only computes distances to
//! the routing centers of subtrees it actually expands. A node pair
//! (point node `x`, center subtree `E`) is pruned with a bound over the
//! whole pair, so distant center groups cost O(1) per point node instead
//! of O(|group|).
//!
//! The center tree is rebuilt from the inter-center matrix
//! ([`InterCenter`]) whenever any center moved — pure table lookups, so
//! the rebuild adds **zero** counted distance computations (see
//! [`crate::tree::centers`]). The converged tail of a fit (all movements
//! exactly 0.0) and warm refits reuse the cached tree.
//!
//! # Pruning bounds (proofs)
//!
//! Throughout, `x` is a point-tree node with routing object `p` and cover
//! radius `r_x` (every point `q` of the subtree has `d(q, p) <= r_x`), and
//! an entry `E` holds a center subtree with routing center `E.c` at
//! *exact* distance `E.d = d(p, centers[E.c])` and cover radius `E.r`
//! (every center `c` of the subtree has `d(centers[E.c], c) <= E.r`). The
//! *incumbent* is the entry minimizing `(d, c)` lexicographically; its
//! routing center `c1` at distance `d1` gives the upper bound
//! `d(q, c1) <= d1 + r_x` for every `q` in the ball.
//!
//! * **Pair prune** — drop `E` when `E.d - E.r > d1 + 2 r_x` (strict).
//!   For every `q` in the ball and every center `c` in `E`:
//!   `d(q, c) >= d(p, c) - r_x >= (E.d - E.r) - r_x > d1 + r_x >= d(q, c1)`.
//!   Strictly worse than a surviving center, so `c` is never the
//!   `(distance, index)`-argmin — the strict inequality makes the prune
//!   tie-safe (a center that could *tie* the incumbent is never dropped,
//!   so the lowest-index tie-break matches the Standard algorithm). The
//!   incumbent itself always survives (`d1 - E.r <= d1 <= d1 + 2 r_x`).
//! * **Pair settle** — assign the whole point subtree to `c1` when the
//!   incumbent is *resolved* (a single concrete center, `E.r = 0`) and
//!   `l2 > d1 + 2 r_x` (strict), where `l2 = min over other entries of
//!   (E.d - E.r)`: every other center `c` has
//!   `d(q, c) >= l2 - r_x > d1 + r_x >= d(q, c1)`, so `c1` is the unique
//!   nearest center of every point in the subtree. An unresolved
//!   incumbent cannot settle — its own subtree hides centers whose lower
//!   bound `d1 - E.r` can never exceed the threshold — so the refinement
//!   loop expands it first.
//! * **Child descent** (point child `y` at stored distance `dxy` from
//!   `p`, radius `r_y`) reuses the same bounds shifted by the triangle
//!   inequality: `d(q, c1) <= d1 + dxy + r_y` (or `dy1 + r_y` after one
//!   fresh distance `dy1 = d(p_y, c1)`), and
//!   `d(q, c) >= (E.d - E.r) - dxy - r_y` — the analogues of the paper's
//!   Eqs. 12-13 with the candidate list replaced by subtree entries.
//! * **Retarget prune** (moving entries from `p` to `p_y`): for `c` in
//!   `E`, `d(p_y, c) >= |E.d - dxy| - E.r`, and via the inter-center
//!   matrix `d(p_y, c) >= cc(c_b, E.c) - E.r - d_b` for the running best
//!   `(c_b, d_b)` at `p_y`. Either bound exceeding `d_b + 2 r_y`
//!   (strictly) drops the pair for the whole child ball, by the pair
//!   prune argument verbatim.
//!
//! Leaf points are finally scanned against the fully-resolved entry list
//! with exactly the single-tree pass's Eq. 12-14/Eq. 9 singleton logic,
//! so per-point tie handling is *identical* to `kmeans::cover` — which
//! the exactness suite pins against the Standard algorithm.
//!
//! # Parallel decomposition
//!
//! Same scheme as the single-tree pass: a sequential expansion peels the
//! top of the *point* tree into at most ~[`TASK_TARGET`] pair tasks
//! (point subtree + its entry list) via the shared
//! [`crate::parallel::expand_tasks`] policy, charging its distances to
//! the caller's counter in a fixed order; the task phase runs each pair
//! task with a private [`CentroidAccum`]/[`DistCounter`] and merges in
//! task order. Labels go through a [`ScatterSlice`] (disjoint point
//! subtrees). The center tree, the entry lists, and the task list are all
//! computed sequentially from the data alone, so `threads = N` is
//! byte-identical to `threads = 1`.

use std::sync::Arc;

use crate::data::Matrix;
use crate::kmeans::bounds::{CentroidAccum, InterCenter};
use crate::kmeans::driver::{DriverState, Fit, KMeansDriver};
use crate::kmeans::{Algorithm, KMeansParams, Workspace};
use crate::metrics::{DistCounter, RunResult};
use crate::parallel::{Parallelism, ScatterSlice};
use crate::tree::centers::{CenterNode, CenterTree, CenterTreeCache, CENTER_MIN_NODE};
use crate::tree::covertree::{CoverTree, CoverTreeParams, Node};

/// One surviving center group at the current point-tree node: a disjoint
/// center subtree (`node = Some`) or a single resolved center
/// (`node = None`, `r == 0`). `d` is always the *exact* distance from the
/// current routing object to `centers[c]`; `r` is the subtree cover
/// radius. The entries at any moment partition the surviving centers.
#[derive(Clone, Copy)]
struct Entry<'c> {
    node: Option<&'c CenterNode>,
    c: u32,
    d: f64,
    r: f64,
}

/// One unit of the parallel decomposition: a point subtree with the entry
/// list that survived the path from the root.
struct Task<'t, 'c> {
    node: &'t Node,
    entries: Vec<Entry<'c>>,
}

/// The expansion stops splitting once this many tasks exist. Fixed (never
/// derived from the thread count) so the task list — and therefore the
/// accumulator merge order — is a function of the trees and centers only.
const TASK_TARGET: usize = 64;
/// Point subtrees lighter than this are not worth splitting further.
const MIN_TASK_WEIGHT: u32 = 256;

/// Mutable per-task view of the traversal (mirrors `cover::Ctx`).
struct Ctx<'a> {
    data: &'a Matrix,
    centers: &'a Matrix,
    ic: &'a InterCenter,
    labels: ScatterSlice<'a, u32>,
    acc: &'a mut CentroidAccum,
    dist: &'a mut DistCounter,
    changed: usize,
}

/// Incumbent of an entry list: index, routing center, its exact distance,
/// and `l2` — the minimum `E.d - E.r` over the *other* entries (a lower
/// bound on the distance from the routing object to every non-incumbent
/// center), `+inf` when the incumbent is alone.
fn scan_entries(entries: &[Entry<'_>]) -> (usize, u32, f64, f64) {
    debug_assert!(!entries.is_empty());
    let mut bi = 0usize;
    for (i, e) in entries.iter().enumerate().skip(1) {
        let b = &entries[bi];
        if e.d < b.d || (e.d == b.d && e.c < b.c) {
            bi = i;
        }
    }
    let mut l2 = f64::INFINITY;
    for (i, e) in entries.iter().enumerate() {
        if i != bi {
            l2 = l2.min(e.d - e.r);
        }
    }
    (bi, entries[bi].c, entries[bi].d, l2)
}

/// Expand entry `i` (a center subtree) at routing object `p`: replace it
/// with entries for its children and singletons. The self-child and the
/// routing center's own singleton inherit the already-known exact
/// distance; coincident singletons (`ds == 0`, an identical center
/// vector) inherit it too. Every other child/singleton is first tested
/// with the two creation-time bounds (triangle via the parent routing
/// center, inter-center filter via the running best) and only on survival
/// pays one counted distance. Dropped groups are provably never the
/// nearest center of any point in `ball(p, r_x)` — see the pair-prune
/// proof in the module docs, with the lower bound
/// `|E.d - parent_dist| - radius` (triangle through the parent center).
fn expand(ctx: &mut Ctx<'_>, p: &[f64], r_x: f64, entries: &mut Vec<Entry<'_>>, i: usize) {
    let e = entries.remove(i);
    let nd = e.node.expect("expand requires a node entry");
    // Running best over the survivors plus the removed entry's own routing
    // center (its distance is exact and carried into a child/singleton).
    let (mut best_c, mut best_d) = (e.c, e.d);
    for s in entries.iter() {
        if s.d < best_d || (s.d == best_d && s.c < best_c) {
            best_d = s.d;
            best_c = s.c;
        }
    }
    for ch in &nd.children {
        if ch.center == nd.center {
            // Self-child: same routing center, the distance carries over.
            entries.push(Entry { node: Some(ch), c: e.c, d: e.d, r: ch.radius });
            continue;
        }
        // Triangle bound through the parent center: for every center c in
        // ch's subtree, d(p, c) >= |d(p, c_E) - d(c_E, c_ch)| - r_ch.
        let lb = (e.d - ch.parent_dist).abs() - ch.radius;
        if lb > best_d + 2.0 * r_x {
            continue;
        }
        // Inter-center filter: d(p, c) >= cc(best, c) - d(p, best) and
        // cc(best, c) >= cc(best, c_ch) - r_ch.
        let cc = ctx.ic.d(best_c as usize, ch.center as usize);
        if cc - ch.radius - best_d > best_d + 2.0 * r_x {
            continue;
        }
        let dch = ctx.dist.d(p, ctx.centers.row(ch.center as usize));
        if dch < best_d || (dch == best_d && ch.center < best_c) {
            best_d = dch;
            best_c = ch.center;
        }
        entries.push(Entry { node: Some(ch), c: ch.center, d: dch, r: ch.radius });
    }
    for &(cs, ds) in &nd.singletons {
        if cs == nd.center || ds == 0.0 {
            // The routing center itself, or a center coincident with it
            // (identical vector): the exact distance is already known.
            if e.d < best_d || (e.d == best_d && cs < best_c) {
                best_d = e.d;
                best_c = cs;
            }
            entries.push(Entry { node: None, c: cs, d: e.d, r: 0.0 });
            continue;
        }
        let lb = (e.d - ds).abs();
        if lb > best_d + 2.0 * r_x {
            continue;
        }
        let cc = ctx.ic.d(best_c as usize, cs as usize);
        if cc - best_d > best_d + 2.0 * r_x {
            continue;
        }
        let dcs = ctx.dist.d(p, ctx.centers.row(cs as usize));
        if dcs < best_d || (dcs == best_d && cs < best_c) {
            best_d = dcs;
            best_c = cs;
        }
        entries.push(Entry { node: None, c: cs, d: dcs, r: 0.0 });
    }
}

/// The pair refinement loop at one point-tree node: alternate pruning,
/// settlement checks, and center-subtree expansion until the ball settles
/// (`Some(c1)`) or no center subtree's radius dominates the point node's
/// (`None` — descend the point tree instead). Expansion policy: largest
/// radius first among node entries with `r >= r_x` (tie to the lowest
/// routing center), the classic dual-tree larger-side descent; an
/// unresolved incumbent that alone blocks a settle is expanded regardless
/// of its radius. Every step is a pure function of `(entries, trees,
/// centers)` — no thread-count dependence.
fn refine(
    ctx: &mut Ctx<'_>,
    p: &[f64],
    r_x: f64,
    entries: &mut Vec<Entry<'_>>,
) -> Option<u32> {
    loop {
        let (bi, c1, d1, l2) = scan_entries(entries);
        if l2 > d1 + 2.0 * r_x {
            if entries[bi].node.is_none() {
                // Pair settle (see module docs): c1 is the unique nearest
                // center of every point in ball(p, r_x).
                return Some(c1);
            }
            // Only the incumbent's own unresolved subtree blocks the
            // settle — expand it and re-check.
            expand(ctx, p, r_x, entries, bi);
            continue;
        }
        // Pair prune: strictly dominated entries can never produce the
        // argmin for any point in the ball (proof in module docs). The
        // incumbent never satisfies the condition, so it survives.
        entries.retain(|e| e.d - e.r <= d1 + 2.0 * r_x);
        // Largest-radius-first expansion while a center subtree's radius
        // dominates the point node's.
        let mut pick: Option<(usize, f64, u32)> = None;
        for (i, e) in entries.iter().enumerate() {
            if e.node.is_some() && e.r >= r_x {
                let better = match pick {
                    None => true,
                    Some((_, pr, pc)) => e.r > pr || (e.r == pr && e.c < pc),
                };
                if better {
                    pick = Some((i, e.r, e.c));
                }
            }
        }
        match pick {
            Some((i, _, _)) => expand(ctx, p, r_x, entries, i),
            None => return None,
        }
    }
}

/// Expand every remaining node entry so the list holds only resolved
/// centers — the flat candidate list the leaf point scan consumes.
fn resolve_full(ctx: &mut Ctx<'_>, p: &[f64], r_x: f64, entries: &mut Vec<Entry<'_>>) {
    loop {
        let Some(i) = entries.iter().position(|e| e.node.is_some()) else {
            break;
        };
        expand(ctx, p, r_x, entries, i);
    }
}

/// Assign the whole point subtree to `c` via the stored aggregates (§3.2).
fn assign_subtree(ctx: &mut Ctx<'_>, node: &Node, c: u32) {
    ctx.acc.add_aggregate(c as usize, &node.sum, node.weight as f64);
    let labels = ctx.labels;
    let mut changed = 0usize;
    node.for_each_point(&mut |pi| {
        // Safety: every point index occurs in exactly one subtree, and
        // concurrent tasks own disjoint subtrees.
        unsafe {
            if labels.read(pi as usize) != c {
                labels.write(pi as usize, c);
                changed += 1;
            }
        }
    });
    ctx.changed += changed;
}

fn assign_point(ctx: &mut Ctx<'_>, pi: u32, c: u32) {
    let i = pi as usize;
    ctx.acc.add_point(c as usize, ctx.data.row(i));
    // Safety: singletons belong to exactly one node; tasks are disjoint.
    unsafe {
        if ctx.labels.read(i) != c {
            ctx.labels.write(i, c);
            ctx.changed += 1;
        }
    }
}

/// Scan a node's singleton points against a fully-resolved entry list.
/// This is verbatim the single-tree pass's per-point logic (Eqs. 12-14
/// with `r_y = 0` plus the Eq. 9 running filter, ties to the lowest
/// index), so leaf-level tie behavior is identical to `kmeans::cover` —
/// and therefore to the Standard algorithm.
fn scan_singletons(ctx: &mut Ctx<'_>, node: &Node, cands: &[Entry<'_>]) {
    debug_assert!(cands.iter().all(|e| e.node.is_none()));
    // Best and second-best resolved candidates (ties to the lowest id).
    let mut c1 = (cands[0].c, cands[0].d);
    let mut d2 = f64::INFINITY;
    for e in &cands[1..] {
        if e.d < c1.1 || (e.d == c1.1 && e.c < c1.0) {
            d2 = c1.1;
            c1 = (e.c, e.d);
        } else if e.d < d2 {
            d2 = e.d;
        }
    }
    for &(pi, dq) in &node.singletons {
        // Eq. 12 (r_y = 0): no computation at all.
        if c1.1 + dq <= d2 - dq {
            assign_point(ctx, pi, c1.0);
            continue;
        }
        let q = ctx.data.row(pi as usize);
        // Eq. 13: exact distance to the inherited nearest only.
        let dq1 = ctx.dist.d(q, ctx.centers.row(c1.0 as usize));
        if dq1 <= d2 - dq {
            assign_point(ctx, pi, c1.0);
            continue;
        }
        // Eq. 14 prune + Eq. 9 running filter, then exact argmin.
        let mut best = (c1.0, dq1);
        for e in cands {
            if e.c == c1.0 {
                continue;
            }
            // Eq. 14 with r_y = 0: skip without computing.
            if e.d - dq > dq1 {
                continue;
            }
            // Eq. 9 with r = 0 against the running best.
            let cc = ctx.ic.d(best.0 as usize, e.c as usize);
            if cc >= 2.0 * best.1 {
                continue;
            }
            let dj = ctx.dist.d(q, ctx.centers.row(e.c as usize));
            if dj < best.1 || (dj == best.1 && e.c < best.0) {
                best = (e.c, dj);
            }
        }
        assign_point(ctx, pi, best.0);
    }
}

/// Move the surviving entries from routing object `p` (distance frame of
/// `entries`) to the child routing object `p_y`. The incumbent is always
/// carried (its fresh distance `dy1` is already computed); every other
/// entry is first tested with the stale-frame triangle bound and the
/// inter-center filter against the running best, and only on survival
/// pays one counted distance at `p_y`. Dropped entries are provably never
/// the argmin for any point in `ball(p_y, r_y)` (retarget prune, module
/// docs).
#[allow(clippy::too_many_arguments)]
fn retarget<'c>(
    ctx: &mut Ctx<'_>,
    entries: &[Entry<'c>],
    bi: usize,
    dy1: f64,
    dxy: f64,
    ry: f64,
    py: &[f64],
) -> Vec<Entry<'c>> {
    let mut out = Vec::with_capacity(entries.len());
    let inc = entries[bi];
    out.push(Entry { node: inc.node, c: inc.c, d: dy1, r: inc.r });
    let (mut best_c, mut best_d) = (inc.c, dy1);
    for (i, e) in entries.iter().enumerate() {
        if i == bi {
            continue;
        }
        // Triangle through the old routing object: for c in E,
        // d(p_y, c) >= |d(p, c_E) - d(p, p_y)| - E.r.
        let lb = (e.d - dxy).abs() - e.r;
        if lb > best_d + 2.0 * ry {
            continue;
        }
        // Inter-center filter against the running best at p_y.
        let cc = ctx.ic.d(best_c as usize, e.c as usize);
        if cc - e.r - best_d > best_d + 2.0 * ry {
            continue;
        }
        let de = ctx.dist.d(py, ctx.centers.row(e.c as usize));
        if de < best_d || (de == best_d && e.c < best_c) {
            best_d = de;
            best_c = e.c;
        }
        out.push(Entry { node: e.node, c: e.c, d: de, r: e.r });
    }
    out
}

/// Recursive pair traversal of one point-tree node with its entry list.
/// With `spill == None` children recurse directly; during the expansion
/// phase `spill` collects the children that would recurse as [`Task`]s
/// instead — the node's own work (refinement, settles, singleton scans)
/// happens identically either way.
fn assign_node<'t, 'c>(
    ctx: &mut Ctx<'_>,
    node: &'t Node,
    mut entries: Vec<Entry<'c>>,
    mut spill: Option<&mut Vec<Task<'t, 'c>>>,
) {
    let p = ctx.data.row(node.routing as usize);
    let r_x = node.radius;

    if let Some(c1) = refine(ctx, p, r_x, &mut entries) {
        assign_subtree(ctx, node, c1);
        return;
    }

    if node.children.is_empty() {
        // Leaf: resolve everything and run the exact per-point scan.
        resolve_full(ctx, p, r_x, &mut entries);
        scan_singletons(ctx, node, &entries);
        return;
    }

    // Interior nodes carry no singletons by construction; handle any (a
    // future tree-shape change) through a fully-resolved copy.
    if !node.singletons.is_empty() {
        let mut full = entries.clone();
        resolve_full(ctx, p, r_x, &mut full);
        scan_singletons(ctx, node, &full);
    }

    let (bi, c1, d1, l2) = scan_entries(&entries);
    let inc_resolved = entries[bi].node.is_none();
    for child in &node.children {
        if child.routing == node.routing {
            // Self-child: identical routing object, every entry distance
            // carries over; only the radius shrank.
            match spill.as_deref_mut() {
                Some(out) => out.push(Task { node: child, entries: entries.clone() }),
                None => assign_node(ctx, child, entries.clone(), None),
            }
            continue;
        }
        let dxy = child.parent_dist;
        let ry = child.radius;
        // Child settle, zero computation (Eq. 12 analogue): for q in the
        // child ball, d(q, c1) <= d1 + dxy + ry and every other center
        // has d(q, c) >= l2 - dxy - ry. Needs a resolved incumbent (an
        // unresolved one hides centers l2 does not cover).
        if inc_resolved && l2 - dxy - ry > d1 + dxy + ry {
            assign_subtree(ctx, child, c1);
            continue;
        }
        // One fresh distance to the incumbent center (Eq. 13 analogue).
        let py = ctx.data.row(child.routing as usize);
        let dy1 = ctx.dist.d(py, ctx.centers.row(c1 as usize));
        if inc_resolved && l2 - dxy - ry > dy1 + ry {
            assign_subtree(ctx, child, c1);
            continue;
        }
        let child_entries = retarget(ctx, &entries, bi, dy1, dxy, ry, py);
        match spill.as_deref_mut() {
            Some(out) => out.push(Task { node: child, entries: child_entries }),
            None => assign_node(ctx, child, child_entries, None),
        }
    }
}

/// Run one full dual-tree assignment pass. Returns the number of points
/// whose assignment changed.
///
/// Same two phases as the single-tree pass regardless of thread count: a
/// sequential expansion peels the top of the point tree into at most
/// ~[`TASK_TARGET`] pair tasks (charging its distances to the caller's
/// counter), then the tasks run — concurrently when `par` has the budget,
/// inline otherwise — each with a private accumulator merged back in task
/// order. `threads = N` is therefore byte-identical to `threads = 1`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn assign_pass(
    data: &Matrix,
    tree: &CoverTree,
    ctree: &CenterTree,
    centers: &Matrix,
    ic: &InterCenter,
    labels: &mut [u32],
    acc: &mut CentroidAccum,
    dist: &mut DistCounter,
    par: &Parallelism,
) -> usize {
    let k = centers.rows();
    let d = data.cols();
    let sink = ScatterSlice::new(labels);
    let root = &tree.root;
    let mut changed;
    let tasks = {
        let mut ctx = Ctx { data, centers, ic, labels: sink, acc, dist, changed: 0 };
        // Root pair: the whole point tree against the whole center tree —
        // one counted distance seeds the traversal.
        let p = data.row(root.routing as usize);
        let d0 = ctx.dist.d(p, centers.row(ctree.root.center as usize));
        let entries = vec![Entry {
            node: Some(&ctree.root),
            c: ctree.root.center,
            d: d0,
            r: ctree.root.radius,
        }];
        let mut tasks: Vec<Task> = vec![Task { node: root, entries }];
        crate::parallel::expand_tasks(
            &mut tasks,
            TASK_TARGET,
            |t| {
                (!t.node.children.is_empty() && t.node.weight >= MIN_TASK_WEIGHT)
                    .then_some(t.node.weight)
            },
            |t, out| assign_node(&mut ctx, t.node, t.entries, Some(out)),
        );
        changed = ctx.changed;
        tasks
    };
    // Task phase: private accumulators, merged in task order below.
    let results = par.run_tasks(tasks, |task| {
        let mut task_acc = CentroidAccum::new(k, d);
        let mut dc = DistCounter::new();
        let mut ctx = Ctx {
            data,
            centers,
            ic,
            labels: sink,
            acc: &mut task_acc,
            dist: &mut dc,
            changed: 0,
        };
        assign_node(&mut ctx, task.node, task.entries, None);
        (task_acc, dc.count(), ctx.changed)
    });
    for (task_acc, count, task_changed) in results {
        acc.merge(&task_acc);
        dist.add_bulk(count);
        changed += task_changed;
    }
    changed
}

/// The dual-tree driver: the shared point cover tree, the per-iteration
/// center tree cache, and the labels.
pub(crate) struct DualDriver<'a> {
    data: &'a Matrix,
    tree: Arc<CoverTree>,
    labels: Vec<u32>,
    par: Parallelism,
    cache: CenterTreeCache,
    center_params: CoverTreeParams,
}

impl<'a> DualDriver<'a> {
    pub(crate) fn new(
        data: &'a Matrix,
        tree: Arc<CoverTree>,
        par: Parallelism,
    ) -> DualDriver<'a> {
        let n = data.rows();
        // The center tree shares the point tree's scale factor but uses
        // its own (much smaller) leaf threshold: k is orders of magnitude
        // below n, and the point tree's default minimum of 100 would
        // collapse the center tree to one flat leaf for most k.
        let center_params = CoverTreeParams {
            scale_factor: tree.params.scale_factor,
            min_node_size: CENTER_MIN_NODE,
        };
        DualDriver {
            data,
            tree,
            labels: vec![u32::MAX; n],
            par,
            cache: CenterTreeCache::new(),
            center_params,
        }
    }

    fn pass(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        let ic = InterCenter::compute_par(centers, dist, &self.par);
        // Center-tree (re)build from the k x k lookup: zero counted
        // distances (see module docs).
        let ctree =
            self.cache
                .get_or_build(centers.rows(), self.center_params, &|i, j| ic.d(i, j));
        assign_pass(
            self.data,
            &self.tree,
            ctree,
            centers,
            &ic,
            &mut self.labels,
            acc,
            dist,
            &self.par,
        )
    }
}

impl KMeansDriver for DualDriver<'_> {
    fn algorithm(&self) -> Algorithm {
        Algorithm::DualTree
    }

    fn init_state(
        &mut self,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(centers, acc, dist)
    }

    fn iterate(
        &mut self,
        _iter: usize,
        centers: &Matrix,
        acc: &mut CentroidAccum,
        dist: &mut DistCounter,
    ) -> usize {
        self.pass(centers, acc, dist)
    }

    fn post_update(&mut self, _iter: usize, movement: &[f64]) {
        // The center tree indexes the current centers; any nonzero
        // movement makes it stale. The all-zero case (converged tail,
        // empty-cluster stasis) keeps the cached tree — a rebuild from
        // the identical lookup would be bit-identical anyway.
        if movement.iter().any(|&m| m != 0.0) {
            self.cache.invalidate();
        }
    }

    fn labels(&self) -> &[u32] {
        &self.labels
    }

    fn save_state(&self) -> Option<DriverState> {
        // The center-tree cache is rebuilt on demand at zero counted
        // distances (from the InterCenter matrix), so labels are the
        // whole cross-iteration state.
        Some(DriverState::new(self.labels.clone()))
    }

    fn load_state(&mut self, state: &DriverState) -> anyhow::Result<()> {
        self.labels = state.labels_checked(self.data.rows())?.to_vec();
        Ok(())
    }

    fn finish(self: Box<Self>) -> Vec<u32> {
        self.labels
    }
}

/// Legacy shim: drive the dual-tree algorithm through the shared loop,
/// reusing (or building) the workspace's point cover tree.
pub fn run(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    ws: &mut Workspace,
) -> RunResult {
    let par = ws.parallelism_opts(params.threads, params.pin_workers);
    let (tree, fresh) = ws.cover_tree_arc_par(data, params.cover, &par);
    let (build_dist, build_time) = if fresh {
        (tree.build_distances, tree.build_time)
    } else {
        (0, std::time::Duration::ZERO)
    };
    Fit::from_driver(
        data,
        Box::new(DualDriver::new(data, tree, par)),
        init,
        params.max_iter,
        params.tol,
    )
    .with_build_cost(build_dist, build_time)
    .run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{init, lloyd, Algorithm, KMeansParams};
    use crate::metrics::DistCounter;
    use crate::tree::CoverTreeParams;

    fn params_small_leaf() -> KMeansParams {
        KMeansParams {
            cover: CoverTreeParams { scale_factor: 1.2, min_node_size: 10 },
            ..KMeansParams::with_algorithm(Algorithm::DualTree)
        }
    }

    #[test]
    fn matches_lloyd_exactly_blobs() {
        let data = synth::gaussian_blobs(500, 3, 5, 1.0, 19);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 5, 13, &mut dc);
        let params = params_small_leaf();
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_d = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_d.labels, r_l.labels);
        assert_eq!(r_d.iterations, r_l.iterations);
    }

    #[test]
    fn matches_lloyd_exactly_geo() {
        let data = synth::istanbul(0.002, 20);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 25, 14, &mut dc);
        let params = params_small_leaf();
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_d = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_d.labels, r_l.labels);
        assert_eq!(r_d.iterations, r_l.iterations);
    }

    #[test]
    fn matches_lloyd_on_duplicate_heavy_data() {
        let data = synth::traffic(0.00005, 23);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 10, 17, &mut dc);
        let params = params_small_leaf();
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_d = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_d.labels, r_l.labels, "exactness on duplicate-heavy data");
    }

    #[test]
    fn matches_lloyd_k_equals_one() {
        let data = synth::gaussian_blobs(120, 2, 1, 0.5, 7);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 1, 3, &mut dc);
        let params = params_small_leaf();
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_d = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_d.labels, r_l.labels);
    }

    #[test]
    fn beats_single_tree_at_large_k() {
        // The dual pass's reason to exist: at large k the single-tree
        // pass pays ~k distances at the point root where its Eq. 9 filter
        // cannot prune; the dual pass only touches expanded center-node
        // routings. Counted assignment distances must come out lower.
        let data = synth::istanbul(0.003, 21);
        let k = 64;
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, k, 15, &mut dc);
        let params = KMeansParams { max_iter: 5, ..params_small_leaf() };
        let cover_params = KMeansParams {
            algorithm: Algorithm::CoverMeans,
            ..params
        };
        let r_d = run(&data, &init_c, &params, &mut Workspace::new());
        let r_c = crate::kmeans::cover::run(
            &data,
            &init_c,
            &cover_params,
            &mut Workspace::new(),
        );
        assert_eq!(r_d.labels, r_c.labels, "both must be exact");
        assert!(
            r_d.distances < r_c.distances,
            "dual {} vs cover {}",
            r_d.distances,
            r_c.distances
        );
    }

    #[test]
    fn default_leaf_size_matches_too() {
        let data = synth::mnist(10, 0.005, 24);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 15, 18, &mut dc);
        let params = KMeansParams::with_algorithm(Algorithm::DualTree);
        let mut ws = Workspace::new();
        let r_l = lloyd::run(&data, &init_c, &params);
        let r_d = run(&data, &init_c, &params, &mut ws);
        assert_eq!(r_d.labels, r_l.labels);
    }
}
