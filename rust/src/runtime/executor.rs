//! Artifact manifest + padded chunked execution of the assign step.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::Matrix;
use crate::runtime::PAD_CENTER_VALUE;

/// One row of `artifacts/manifest.tsv` (written by `python -m compile.aot`).
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestEntry {
    pub chunk: usize,
    pub d: usize,
    pub k: usize,
    pub file: String,
    /// Static VMEM footprint estimate of the kernel at this shape (bytes).
    pub vmem_bytes: u64,
    /// Fraction of kernel FLOPs that are MXU-eligible matmul FLOPs.
    pub mxu_fraction: f64,
}

/// Parsed artifact manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 6 {
                bail!("{path:?} line {}: expected 6 columns", lineno + 1);
            }
            entries.push(ManifestEntry {
                chunk: cols[0].parse().context("chunk")?,
                d: cols[1].parse().context("d")?,
                k: cols[2].parse().context("k")?,
                file: cols[3].to_string(),
                vmem_bytes: cols[4].parse().context("vmem")?,
                mxu_fraction: cols[5].parse().context("mxu")?,
            });
        }
        if entries.is_empty() {
            bail!("{path:?}: empty manifest");
        }
        Ok(Manifest { entries })
    }

    /// Smallest lattice shape covering `(d, k)` (min padded area d*k;
    /// ties broken toward smaller d). `None` if nothing fits.
    pub fn pick(&self, d: usize, k: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| e.d >= d && e.k >= k)
            .min_by_key(|e| (e.d * e.k, e.d))
    }
}

/// Output of one assign call over the full dataset (unpadded).
#[derive(Debug, Clone)]
pub struct AssignOutput {
    /// Nearest center per point.
    pub labels: Vec<u32>,
    /// Distance to the nearest center.
    pub d1: Vec<f64>,
    /// Distance to the second-nearest center.
    pub d2: Vec<f64>,
    /// Per-cluster weighted sums of assigned points (k x d).
    pub sums: Matrix,
    /// Per-cluster assigned weight.
    pub counts: Vec<f64>,
}

/// Executes the AOT assign-step artifacts on the PJRT CPU client with the
/// padding protocol of `python/compile/model.py`. Executables are compiled
/// lazily per lattice shape and cached.
pub struct AssignExecutor {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    compiled: HashMap<(usize, usize, usize), xla::PjRtLoadedExecutable>,
    /// Reused staging buffers (hot path: no per-chunk allocation).
    x_buf: Vec<f32>,
    w_buf: Vec<f32>,
}

impl AssignExecutor {
    /// Load the manifest from [`crate::runtime::artifacts_dir`].
    pub fn load_default() -> Result<AssignExecutor> {
        Self::new(&crate::runtime::artifacts_dir())
    }

    pub fn new(dir: &Path) -> Result<AssignExecutor> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(AssignExecutor {
            client,
            manifest,
            dir: dir.to_path_buf(),
            compiled: HashMap::new(),
            x_buf: Vec::new(),
            w_buf: Vec::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn executable(
        &mut self,
        entry: &ManifestEntry,
    ) -> Result<&xla::PjRtLoadedExecutable> {
        let key = (entry.chunk, entry.d, entry.k);
        if !self.compiled.contains_key(&key) {
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path")?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
            self.compiled.insert(key, exe);
        }
        Ok(self.compiled.get(&key).unwrap())
    }

    /// Uniform-weight assignment of every row of `data` against `centers`.
    pub fn assign(&mut self, data: &Matrix, centers: &Matrix) -> Result<AssignOutput> {
        self.assign_weighted(data, None, centers)
    }

    /// Weighted assignment; `weights` defaults to 1.0 per row. Points with
    /// weight 0 still receive labels/d1/d2 but contribute nothing to the
    /// partial sums — the same mechanism the padding uses.
    pub fn assign_weighted(
        &mut self,
        data: &Matrix,
        weights: Option<&[f64]>,
        centers: &Matrix,
    ) -> Result<AssignOutput> {
        let n = data.rows();
        let d = data.cols();
        let k = centers.rows();
        anyhow::ensure!(centers.cols() == d, "dimension mismatch");
        if let Some(w) = weights {
            anyhow::ensure!(w.len() == n, "weights length mismatch");
        }
        let entry = self
            .manifest
            .pick(d, k)
            .with_context(|| format!("no artifact covers d={d}, k={k}"))?
            .clone();
        let (chunk, dl, kl) = (entry.chunk, entry.d, entry.k);

        // Padded center literal (shared by all chunks).
        let mut c_buf = vec![PAD_CENTER_VALUE; kl * dl];
        for i in 0..k {
            let row = centers.row(i);
            for j in 0..dl {
                c_buf[i * dl + j] = if j < d { row[j] as f32 } else { 0.0 };
            }
        }
        let c_lit = xla::Literal::vec1(&c_buf)
            .reshape(&[kl as i64, dl as i64])
            .map_err(|e| anyhow!("reshape centers: {e:?}"))?;

        let mut out = AssignOutput {
            labels: Vec::with_capacity(n),
            d1: Vec::with_capacity(n),
            d2: Vec::with_capacity(n),
            sums: Matrix::zeros(k, d),
            counts: vec![0.0; k],
        };

        let mut start = 0usize;
        while start < n {
            let rows = (n - start).min(chunk);
            // Stage the padded chunk.
            self.x_buf.clear();
            self.x_buf.resize(chunk * dl, 0.0);
            self.w_buf.clear();
            self.w_buf.resize(chunk, 0.0);
            for r in 0..rows {
                let src = data.row(start + r);
                let dst = &mut self.x_buf[r * dl..r * dl + d];
                for (o, &v) in dst.iter_mut().zip(src) {
                    *o = v as f32;
                }
                self.w_buf[r] = weights.map(|w| w[start + r] as f32).unwrap_or(1.0);
            }
            let x_lit = xla::Literal::vec1(&self.x_buf)
                .reshape(&[chunk as i64, dl as i64])
                .map_err(|e| anyhow!("reshape x: {e:?}"))?;
            let w_lit = xla::Literal::vec1(&self.w_buf);

            let exe = self.executable(&entry)?;
            let result = exe
                .execute::<xla::Literal>(&[x_lit, w_lit, c_lit.clone()])
                .map_err(|e| anyhow!("execute: {e:?}"))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?
                .to_tuple()
                .map_err(|e| anyhow!("untuple: {e:?}"))?;
            anyhow::ensure!(tuple.len() == 5, "expected 5 outputs, got {}", tuple.len());

            let labels: Vec<i32> =
                tuple[0].to_vec().map_err(|e| anyhow!("labels: {e:?}"))?;
            let d1: Vec<f32> = tuple[1].to_vec().map_err(|e| anyhow!("d1: {e:?}"))?;
            let d2: Vec<f32> = tuple[2].to_vec().map_err(|e| anyhow!("d2: {e:?}"))?;
            let sums: Vec<f32> = tuple[3].to_vec().map_err(|e| anyhow!("sums: {e:?}"))?;
            let counts: Vec<f32> =
                tuple[4].to_vec().map_err(|e| anyhow!("counts: {e:?}"))?;

            for r in 0..rows {
                out.labels.push(labels[r] as u32);
                out.d1.push(d1[r] as f64);
                out.d2.push(d2[r] as f64);
            }
            for i in 0..k {
                for j in 0..d {
                    let v = sums[i * dl + j] as f64;
                    let cur = out.sums.get(i, j);
                    out.sums.set(i, j, cur + v);
                }
                out.counts[i] += counts[i] as f64;
            }
            // Sentinel centers must never capture weight.
            debug_assert!(counts[k..].iter().all(|&c| c == 0.0));

            start += rows;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_from(rows: &[(usize, usize, usize)]) -> Manifest {
        Manifest {
            entries: rows
                .iter()
                .map(|&(chunk, d, k)| ManifestEntry {
                    chunk,
                    d,
                    k,
                    file: format!("assign_c{chunk}_d{d}_k{k}.hlo.txt"),
                    vmem_bytes: 1,
                    mxu_fraction: 0.5,
                })
                .collect(),
        }
    }

    #[test]
    fn pick_smallest_cover() {
        let m = manifest_from(&[(1024, 8, 16), (1024, 64, 512), (1024, 16, 64)]);
        assert_eq!(m.pick(5, 10).unwrap().d, 8);
        assert_eq!(m.pick(9, 10).unwrap().d, 16);
        assert_eq!(m.pick(16, 64).unwrap().k, 64);
        assert_eq!(m.pick(64, 65).unwrap().k, 512);
        assert!(m.pick(100, 10).is_none());
        assert!(m.pick(8, 1000).is_none());
    }

    #[test]
    fn manifest_load_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("cm_mani_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), "# header only\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::write(dir.join("manifest.tsv"), "1024\t8\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::write(
            dir.join("manifest.tsv"),
            "# c\td\tk\tfile\tv\tm\n1024\t8\t16\ta.hlo.txt\t100\t0.9\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.entries[0].k, 16);
    }
}
